"""Paper Fig. 2/3: Ax implementation ladder across element counts.

The paper compares (original global-memory, OpenACC, shared-memory,
optimized CUDA) on P100/V100.  The CPU-container analog compares:

  * ``listing1`` — paper Listing 1 with materialized intermediates
                   (original version's memory traffic; barriered),
  * ``fused``    — single XLA fusion (shared-memory version's locality),
  * ``pallas``   — the TPU kernel (interpret mode: correctness path; its
                   wall time is NOT meaningful on CPU, so its *derived*
                   column reports the HBM-traffic ratio from the HLO
                   instead — the quantity the kernel actually optimizes).

The ladder's top rungs are the *fused CG iterations* (core/cg_fused.py):
v1 runs one multi-output Pallas call per iteration carrying the mask and
the p·c·Ap partial with it (30 Eq.-2 streams -> 17 with the carried r·c·r,
DESIGN.md §3.3); v2 runs the whole iteration in two slab-resident Pallas
kernels — in-kernel gather-scatter, merged vector updates, structural
mask/weight, diagonal metric — for 13 streams (DESIGN.md §3.4).
Interpret-mode wall time is reported for completeness but is emulator
time, not hardware time; the derived stream ratios are the claims.

CSV: name,us_per_call,derived  where derived = achieved GFLOP/s (model
flops C_ax = D*(12n+17)) for timed variants.

Set ``REPRO_BENCH_QUICK=1`` to shrink the sweep (CI smoke).
"""
from __future__ import annotations

import os

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.ax import ax_local_fused, ax_local_listing1
from repro.core.cost import (CG_READ_STREAMS, CG_WRITE_STREAMS,
                             FUSED_CG_READ_STREAMS, FUSED_CG_WRITE_STREAMS,
                             FUSED_V2_READ_STREAMS, FUSED_V2_WRITE_STREAMS,
                             ax_local_flops, cg_iter_flops)
from repro.core.sem import derivative_matrix
from repro.kernels import ops

QUICK = bool(os.environ.get("REPRO_BENCH_QUICK"))
N_GLL = 6 if QUICK else 10
ELEMENT_SWEEP = (8,) if QUICK else (64, 256, 1024)


def _time(fn, *args, reps=5):
    # shared methodology (benchmarks/timing.py): warmup-discard +
    # median-of-reps, each rep synced and timed individually.
    from benchmarks.timing import measure

    return measure(fn, *args, reps=reps, warmup=1)


def run():
    rows = []
    rng = np.random.default_rng(0)
    D = jnp.asarray(derivative_matrix(N_GLL), jnp.float32)
    for E in ELEMENT_SWEEP:
        u = jnp.asarray(rng.normal(size=(E, N_GLL, N_GLL, N_GLL)),
                        jnp.float32)
        g = jnp.asarray(rng.normal(size=(E, 6, N_GLL, N_GLL, N_GLL)),
                        jnp.float32)
        flops = ax_local_flops(E, N_GLL)

        f_l1 = jax.jit(lambda u, g: ax_local_listing1(u, D, g))
        f_fu = jax.jit(lambda u, g: ax_local_fused(u, D, g))
        t_l1 = _time(f_l1, u, g)
        t_fu = _time(f_fu, u, g)
        rows.append((f"ax_listing1_e{E}", t_l1 * 1e6,
                     f"{flops / t_l1 / 1e9:.2f}GF/s"))
        rows.append((f"ax_fused_e{E}", t_fu * 1e6,
                     f"{flops / t_fu / 1e9:.2f}GF/s"))

        # pallas: interpret-mode timing is NOT meaningful on CPU; derived
        # reports the fusion win it encodes — intermediate (temp) buffer
        # bytes of listing1 vs the fused schedule, plus the analytic HBM
        # stream count (14 streams -> 8 = 1.75x less traffic, cf. Eq. 2).
        ma_l1 = f_l1.lower(u, g).compile().memory_analysis()
        ma_fu = f_fu.lower(u, g).compile().memory_analysis()
        t_pl = _time(lambda u, g: ops.nekbone_ax(u, D, g, interpret=True),
                     u, g, reps=1)
        tr = (ma_l1.temp_size_in_bytes / max(ma_fu.temp_size_in_bytes, 1)
              if ma_l1 and ma_fu else float("nan"))
        rows.append((f"ax_pallas_e{E}", t_pl * 1e6,
                     f"temp_l1/fused={tr:.2f}x;streams_14v8=1.75x"))

        # fused CG iteration rungs (DESIGN.md §3): v1 — one multi-output
        # Pallas call per iteration replaces operator + mask + the p·c·Ap
        # reduction; v2 — the whole iteration in two slab-resident kernels
        # (in-kernel gather-scatter + merged vector updates).  Timed for one
        # interpret-mode iteration (emulator time — the derived stream
        # ratios are the claims).
        t_v1 = _time_cg_fused(E, "v1")
        rows.append((f"cg_fused_iter_e{E}", t_v1 * 1e6,
                     _fused_streams_derived()))
        # the v2 row reports what ax_impl="auto" actually dispatches to at
        # this E (kernels/autotune.pick_pipeline): below the amortization
        # threshold auto routes to v1 — the row then carries v1's time
        # (tagged in derived), so the rung can never regress past v1 at
        # small E and reflects the dispatched pipeline's wall time.
        auto = _auto_pipeline(E)
        if auto == "pallas_fused_cg":
            t_auto, tag = t_v1, ";auto=fused_v1"
        else:
            t_auto, tag = _time_cg_fused(E, "v2"), ";auto=fused_v2"
        rows.append((f"cg_fused_v2_iter_e{E}", t_auto * 1e6,
                     _fused_v2_streams_derived() + tag))
        # mixed-precision rung (DESIGN.md §7): the same 13-stream v2
        # iteration with bf16 storage / f32 accumulation — half the
        # bytes/DOF/iter of the f32 row above (the derived column carries
        # the exact ratio; interpret-mode wall time is emulator time).
        rows.append((f"cg_fused_v2_bf16_iter_e{E}",
                     _time_cg_fused(E, "v2", precision="bf16") * 1e6,
                     _v2_precision_derived("bf16")))
        # s-step ladder (DESIGN.md §8): one full cycle (s iterations) of
        # the v3 matrix-powers pipeline per s — the derived column carries
        # the amortized bytes/DOF/iter against the v2 row at the same
        # precision (strictly fewer for every s > 1; s = 1 reproduces the
        # v2 budget exactly, which the regression gate pins).
        for s in (1, 2, 4):
            rows.append((f"cg_sstep_v3_s{s}_iter_e{E}",
                         _time_cg_sstep(E, s) * 1e6,
                         _sstep_derived(s)))
        # preconditioned rungs (DESIGN.md §9): one fused PCG iteration
        # through the v2 pipeline — Jacobi carries the preconditioned
        # residual (+1 stream), Chebyshev adds the halo'd polynomial-apply
        # kernel (+5 streams, the win booked in iteration count).
        rows.append((f"pcg_jacobi_iter_e{E}",
                     _time_pcg(E, "jacobi") * 1e6, _pcg_derived("jacobi")))
        rows.append((f"pcg_cheb4_iter_e{E}",
                     _time_pcg(E, "cheb4") * 1e6, _pcg_derived("cheb")))
        # p-multigrid rung (DESIGN.md §13): one full symmetric V-cycle
        # inside the fused PCG iteration — the most streams/iter on the
        # ladder, bought back several times over in iteration count (the
        # pcg_iters_tol row below carries the counts).
        rows.append((f"pcg_pmg_iter_e{E}",
                     _time_pcg(E, "pmg") * 1e6, _pcg_derived("pmg")))
    # iterations-to-tolerance (the PCG headline, DESIGN.md §9.4): solved
    # once at the sweep's smallest point — the derived column carries the
    # iteration counts of the plain / Jacobi / Chebyshev(4) tolerance-
    # driven fused solves, the quantity the stream surcharge buys down.
    rows.append((f"pcg_iters_tol_e{ELEMENT_SWEEP[0]}", 0.0,
                 _pcg_iters_derived(ELEMENT_SWEEP[0])))
    return rows


def _auto_pipeline(E: int) -> str:
    """The pipeline ax_impl="auto" resolves to for this sweep point."""
    from repro.configs.nekbone import PAPER_CASES
    from repro.kernels.autotune import pick_pipeline

    grid = (PAPER_CASES[E].grid if E in PAPER_CASES else (2, 2, E // 4))
    return pick_pipeline(grid, N_GLL, jnp.float32)


def _fused_streams_derived() -> str:
    base = CG_READ_STREAMS + CG_WRITE_STREAMS
    fused = FUSED_CG_READ_STREAMS + FUSED_CG_WRITE_STREAMS
    return (f"streams_{base}v{fused}={base / fused:.2f}x"
            f";flops={cg_iter_flops(1, N_GLL)}perDOF")


def _fused_v2_streams_derived() -> str:
    base = CG_READ_STREAMS + CG_WRITE_STREAMS
    v2 = FUSED_V2_READ_STREAMS + FUSED_V2_WRITE_STREAMS
    return (f"streams_{base}v{v2}={base / v2:.2f}x"
            f";streams_iter={v2}")


def _v2_precision_derived(precision: str) -> str:
    from repro.core.cost import bytes_per_dof_iter

    lo = sum(bytes_per_dof_iter("fused_v2", precision))
    f32 = sum(bytes_per_dof_iter("fused_v2", "f32"))
    return (f"B/dof/iter_{lo}v{f32}={lo / f32:.2f}x"
            f";streams_iter={FUSED_V2_READ_STREAMS + FUSED_V2_WRITE_STREAMS}")


def _sstep_derived(s: int) -> str:
    from repro.core.cost import bytes_per_dof_iter, sstep_effective_streams

    v3 = sum(bytes_per_dof_iter("sstep_v3", "f32", s=s))
    v2 = sum(bytes_per_dof_iter("fused_v2", "f32"))
    return (f"B/dof/iter_{v3:g}v{v2}={v3 / v2:.2f}x"
            f";streams_eff={sstep_effective_streams(s, 4):.2f};s={s}")


def _pcg_derived(kind: str) -> str:
    from repro.core.cost import (CHEB_DEFAULT_K, PMG_DEFAULT_K,
                                 bytes_per_dof_iter, cheb_effective_streams,
                                 pmg_effective_streams)

    pipeline = {"jacobi": "fused_v2_jacobi",
                "pmg": "fused_v2_pmg"}.get(kind, "fused_v2_cheb")
    pcg = sum(bytes_per_dof_iter(pipeline, "f32"))
    v2 = sum(bytes_per_dof_iter("fused_v2", "f32"))
    if kind == "jacobi":
        extra = ""
    elif kind == "pmg":
        extra = f";eff={pmg_effective_streams(10, PMG_DEFAULT_K, 4):.2f}"
    else:
        extra = f";eff={cheb_effective_streams(CHEB_DEFAULT_K, 4):.2f}"
    return f"B/dof/iter_{pcg:g}v{v2:g}={pcg / v2:.2f}x{extra}"


def _pcg_case(E: int):
    from repro.configs.nekbone import PAPER_CASES
    from repro.core.nekbone import NekboneCase

    grid = (PAPER_CASES[E].grid if E in PAPER_CASES else (2, 2, E // 4))
    case = NekboneCase(n=N_GLL, grid=grid, dtype=jnp.float32)
    _, f = case.manufactured()
    return case, f


def _time_pcg(E: int, name: str) -> float:
    """One fused PCG iteration (v2 pipeline + preconditioner), timed like
    the other fused rows.  The preconditioner setup (diagonal / Lanczos
    interval) is a one-time per-case cost and stays outside the timed
    region."""
    from repro.core.precond import pcg_fused_v2_fixed_iters

    case, f = _pcg_case(E)
    spec = case.precond_spec(name)

    def one_iter():
        return pcg_fused_v2_fixed_iters(f, D=case.D, g=case.g,
                                        grid=case.grid, niter=1,
                                        precond=spec, mask=case.mask,
                                        c=case.c)

    from benchmarks.timing import measure

    return measure(lambda: one_iter().x, reps=1, warmup=1)


def _pcg_iters_derived(E: int) -> str:
    """Tolerance-driven iteration counts: plain vs Jacobi vs Chebyshev vs
    p-multigrid (the §13 headline — pmg trades the largest per-iteration
    stream budget for the smallest count)."""
    from repro.core.precond import cg_fused_tol

    case, f = _pcg_case(E)
    r0 = float(jnp.sqrt(jnp.abs(jnp.sum(f * case.c * f))))
    tol = 1e-6 * r0
    counts = {}
    for name in (None, "jacobi", "cheb4", "pmg"):
        spec = case.precond_spec(name) if name else None
        res = cg_fused_tol(f, D=case.D, g=case.g, grid=case.grid, tol=tol,
                           max_iter=500, precond=spec, mask=case.mask,
                           c=case.c)
        counts[name or "plain"] = int(res.iters)
    return (f"iters@rtol1e-6:plain={counts['plain']}"
            f";jacobi={counts['jacobi']};cheb4={counts['cheb4']}"
            f";pmg={counts['pmg']}")


def _time_cg_sstep(E: int, s: int) -> float:
    """One full s-step cycle (s iterations) of the v3 pipeline, timed like
    the other fused rows (interpret-mode emulator time; the derived byte
    ratios are the claims).  theta is precomputed outside the timed region
    — the power-iteration setup is a per-problem one-time cost, not part
    of the cycle this row prices."""
    from repro.configs.nekbone import PAPER_CASES
    from repro.core.cg_sstep import cg_sstep_fixed_iters, estimate_theta
    from repro.core.nekbone import NekboneCase

    grid = (PAPER_CASES[E].grid if E in PAPER_CASES else (2, 2, E // 4))
    case = NekboneCase(n=N_GLL, grid=grid, dtype=jnp.float32)
    _, f = case.manufactured()
    theta = estimate_theta(case.D, case.g, case.grid, case.mask)

    def one_cycle():
        return cg_sstep_fixed_iters(f, D=case.D, g=case.g, grid=case.grid,
                                    niter=s, s=s, mask=case.mask, c=case.c,
                                    theta=theta)

    from benchmarks.timing import measure

    return measure(lambda: one_cycle().x, reps=1, warmup=1)


def _time_cg_fused(E: int, version: str, precision: str | None = None) -> float:
    from repro.configs.nekbone import PAPER_CASES
    from repro.core.cg_fused import (cg_fused_fixed_iters,
                                     cg_fused_v2_fixed_iters)
    from repro.core.nekbone import NekboneCase

    grid = (PAPER_CASES[E].grid if E in PAPER_CASES else (2, 2, E // 4))
    case = NekboneCase(n=N_GLL, grid=grid, dtype=jnp.float32)
    _, f = case.manufactured()

    if version == "v2":
        def one_iter():
            return cg_fused_v2_fixed_iters(f, D=case.D, g=case.g,
                                           grid=case.grid, niter=1,
                                           mask=case.mask, c=case.c,
                                           precision=precision)
    else:
        def one_iter():
            return cg_fused_fixed_iters(f, D=case.D, g=case.g,
                                        mask=case.mask, c=case.c,
                                        grid=case.grid, niter=1,
                                        precision=precision)

    from benchmarks.timing import measure

    return measure(lambda: one_iter().x, reps=1, warmup=1)
