"""Paper §III-A (Eq. 1-2): does the implementation match the cost model?

Counts the *compiled* work of one CG iteration (loop-corrected dot flops
from the HLO + cost_analysis bytes) against the paper's model
``C(D, n) = D (12n + 34)`` and the 24D-read/6D-write traffic, across
polynomial degrees — then repeats the byte accounting for the *step-fused*
iteration (core/cg_fused.py), whose analytic budget is 15D reads / 4D
writes (DESIGN.md §3.3).  CSV derived column: measured/model ratios, and
for the fused rows the achieved-vs-Eq.-2 stream counts.

Set ``REPRO_BENCH_QUICK=1`` to shrink the sweep (CI smoke).
"""
from __future__ import annotations

import os

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.cost import (cg_iter_bytes, cg_iter_flops, fused_cg_iter_bytes,
                             fused_intensity, intensity)
from repro.core.nekbone import NekboneCase
from repro.launch.hlo_analysis import analyze_hlo

QUICK = bool(os.environ.get("REPRO_BENCH_QUICK"))
N_SWEEP = (6,) if QUICK else (6, 8, 10)
GRID = (2, 2, 2) if QUICK else (4, 4, 4)


def run():
    rows = []
    for n in N_SWEEP:
        case = NekboneCase(n=n, grid=GRID, dtype=jnp.float32,
                           ax_impl="fused")
        D = case.mesh.ndof

        def cg_iter(x, r, p):
            w = case.ax_full(p)
            dot = case.dot()
            alpha = dot(r, r) / dot(p, w)
            x2 = x + alpha * p
            r2 = r - alpha * w
            beta = dot(r2, r2) / dot(r, r)
            return x2, r2, r2 + beta * p

        aval = jax.ShapeDtypeStruct(case.mask.shape, jnp.float32)
        compiled = jax.jit(cg_iter).lower(aval, aval, aval).compile()
        hlo_dot = analyze_hlo(compiled.as_text())["dot_flops"]
        bytes_acc = _bytes_accessed(compiled)

        model_flops = cg_iter_flops(D, n)
        model_bytes = sum(cg_iter_bytes(D, itemsize=4))
        # dots are the 12n part of (12n + 34)
        dot_model = D * 12 * n
        rows.append((f"eq1_dotflops_n{n}", 0.0,
                     f"hlo/model={hlo_dot / dot_model:.3f}"))
        rows.append((f"eq2_bytes_n{n}", 0.0,
                     f"xla/model={bytes_acc / model_bytes:.3f}"))
        rows.append((f"intensity_n{n}", 0.0,
                     f"I={intensity(n, 4):.3f}flop/B(fp32)"))

        # --- fused iteration: achieved vs Eq.-2 stream counts -------------
        # The kernel pins its own traffic (inputs/outputs of the pallas_call
        # are exactly the 10-read/1-write set); the remaining vector pass is
        # counted from the fused-iteration model.  Report both the analytic
        # budget ratio and XLA's byte estimate of the whole fused iteration.
        fused_model_bytes = sum(fused_cg_iter_bytes(D, itemsize=4))
        rows.append((f"eq2_fused_streams_n{n}", 0.0,
                     f"fused/eq2={fused_model_bytes / model_bytes:.3f}"
                     f";I_fused={fused_intensity(n, 4):.3f}flop/B"))

        fused_bytes = _fused_iteration_bytes(n)
        if fused_bytes is not None:
            rows.append((f"eq2_fused_xla_n{n}", 0.0,
                         f"xla/fusedmodel={fused_bytes / fused_model_bytes:.3f}"))
    return rows


def _bytes_accessed(compiled) -> float:
    """`cost_analysis()` returns a dict on new jax, a 1-list of dicts on
    older releases."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return float(ca.get("bytes accessed", 0))


def _fused_iteration_bytes(n: int) -> float | None:
    """XLA's byte estimate for one step-fused CG iteration (niter=1 solve).

    Interpret-mode Pallas lowers to ordinary HLO on CPU, so cost_analysis
    over-counts relative to a real TPU pallas_call; the analytic rows above
    are the load-bearing ones and this is a cross-check only.
    """
    from repro.core.cg_fused import cg_fused_fixed_iters

    case = NekboneCase(n=n, grid=GRID, dtype=jnp.float32,
                       ax_impl="pallas_fused_cg")

    def one_iter(f):
        return cg_fused_fixed_iters(f, D=case.D, g=case.g, mask=case.mask,
                                    c=case.c, grid=case.grid, niter=1).x

    try:
        aval = jax.ShapeDtypeStruct(case.mask.shape, jnp.float32)
        compiled = jax.jit(one_iter).lower(aval).compile()
        return _bytes_accessed(compiled)
    except Exception:
        return None
