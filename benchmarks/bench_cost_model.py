"""Paper §III-A (Eq. 1-2): does the implementation match the cost model?

Counts the *compiled* work of one CG iteration (loop-corrected dot flops
from the HLO + cost_analysis bytes) against the paper's model
``C(D, n) = D (12n + 34)`` and the 24D-read/6D-write traffic, across
polynomial degrees.  CSV derived column: measured/model ratios.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.cost import cg_iter_bytes, cg_iter_flops, intensity
from repro.core.nekbone import NekboneCase
from repro.launch.hlo_analysis import analyze_hlo


def run():
    rows = []
    for n in (6, 8, 10):
        case = NekboneCase(n=n, grid=(4, 4, 4), dtype=jnp.float32,
                           ax_impl="fused")
        D = case.mesh.ndof

        def cg_iter(x, r, p):
            w = case.ax_full(p)
            dot = case.dot()
            alpha = dot(r, r) / dot(p, w)
            x2 = x + alpha * p
            r2 = r - alpha * w
            beta = dot(r2, r2) / dot(r, r)
            return x2, r2, r2 + beta * p

        aval = jax.ShapeDtypeStruct(case.mask.shape, jnp.float32)
        compiled = jax.jit(cg_iter).lower(aval, aval, aval).compile()
        hlo_dot = analyze_hlo(compiled.as_text())["dot_flops"]
        ca = compiled.cost_analysis()
        bytes_acc = float(ca.get("bytes accessed", 0))

        model_flops = cg_iter_flops(D, n)
        model_bytes = sum(cg_iter_bytes(D, itemsize=4))
        # dots are the 12n part of (12n + 34)
        dot_model = D * 12 * n
        rows.append((f"eq1_dotflops_n{n}", 0.0,
                     f"hlo/model={hlo_dot / dot_model:.3f}"))
        rows.append((f"eq2_bytes_n{n}", 0.0,
                     f"xla/model={bytes_acc / model_bytes:.3f}"))
        rows.append((f"intensity_n{n}", 0.0,
                     f"I={intensity(n, 4):.3f}flop/B(fp32)"))
    return rows
