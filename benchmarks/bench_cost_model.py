"""Paper §III-A (Eq. 1-2): does the implementation match the cost model?

Counts the *compiled* work of one CG iteration (loop-corrected dot flops
from the HLO + cost_analysis bytes) against the paper's model
``C(D, n) = D (12n + 34)`` and the 24D-read/6D-write traffic, across
polynomial degrees — then repeats the byte accounting for the *step-fused*
iterations (core/cg_fused.py): v1's analytic budget is 13D reads / 4D
writes (DESIGN.md §3.3, with the carried r·c·r) and v2's is 9D reads / 4D
writes (DESIGN.md §3.4 — two slab-resident kernels, zero standalone
full-field passes).  CSV derived column: measured/model ratios, and for
the fused rows the achieved-vs-Eq.-2 stream counts (the v2 row carries the
headline ``streams/iter`` number).

Set ``REPRO_BENCH_QUICK=1`` to shrink the sweep (CI smoke).
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.core.cost import (CHEB_DEFAULT_K, CHEB_V2_READ_STREAMS,
                             CHEB_V2_WRITE_STREAMS, FUSED_CG_READ_STREAMS,
                             FUSED_CG_WRITE_STREAMS, FUSED_V2_READ_STREAMS,
                             FUSED_V2_WRITE_STREAMS, JACOBI_V2_READ_STREAMS,
                             JACOBI_V2_WRITE_STREAMS, SSTEP_DEFAULT_S,
                             bytes_per_dof_iter, cg_iter_bytes,
                             cheb_effective_streams, cheb_flops_per_dof,
                             fused_cg_iter_bytes, fused_intensity,
                             fused_v2_cg_iter_bytes, fused_v2_intensity,
                             fused_v2_plane_streams, intensity,
                             ir_overhead_streams, pipeline_intensity,
                             sstep_effective_streams, sstep_intensity,
                             sstep_streams)
from repro.core.nekbone import NekboneCase
from repro.launch.hlo_analysis import analyze_hlo

QUICK = bool(os.environ.get("REPRO_BENCH_QUICK"))
N_SWEEP = (6,) if QUICK else (6, 8, 10)
GRID = (2, 2, 2) if QUICK else (4, 4, 4)


def run():
    rows = []
    for n in N_SWEEP:
        case = NekboneCase(n=n, grid=GRID, dtype=jnp.float32,
                           ax_impl="fused")
        D = case.mesh.ndof

        def cg_iter(x, r, p):
            w = case.ax_full(p)
            dot = case.dot()
            alpha = dot(r, r) / dot(p, w)
            x2 = x + alpha * p
            r2 = r - alpha * w
            beta = dot(r2, r2) / dot(r, r)
            return x2, r2, r2 + beta * p

        aval = jax.ShapeDtypeStruct(case.mask.shape, jnp.float32)
        compiled = jax.jit(cg_iter).lower(aval, aval, aval).compile()
        hlo_dot = analyze_hlo(compiled.as_text())["dot_flops"]
        bytes_acc = _bytes_accessed(compiled)

        model_bytes = sum(cg_iter_bytes(D, itemsize=4))
        # dots are the 12n part of (12n + 34)
        dot_model = D * 12 * n
        rows.append((f"eq1_dotflops_n{n}", 0.0,
                     f"hlo/model={hlo_dot / dot_model:.3f}"))
        rows.append((f"eq2_bytes_n{n}", 0.0,
                     f"xla/model={bytes_acc / model_bytes:.3f}"))
        rows.append((f"intensity_n{n}", 0.0,
                     f"I={intensity(n, 4):.3f}flop/B(fp32)"))

        # --- fused iteration: achieved vs Eq.-2 stream counts -------------
        # The kernel pins its own traffic (inputs/outputs of the pallas_call
        # are exactly the 10-read/1-write set); the remaining vector pass is
        # counted from the fused-iteration model.  Report both the analytic
        # budget ratio and XLA's byte estimate of the whole fused iteration.
        v1_streams = FUSED_CG_READ_STREAMS + FUSED_CG_WRITE_STREAMS
        fused_model_bytes = sum(fused_cg_iter_bytes(D, itemsize=4))
        rows.append((f"eq2_fused_streams_n{n}", 0.0,
                     f"streams/iter={v1_streams}"
                     f";fused/eq2={fused_model_bytes / model_bytes:.3f}"
                     f";I_fused={fused_intensity(n, 4):.3f}flop/B"))

        fused_bytes = _fused_iteration_bytes(n, "v1")
        if fused_bytes is not None:
            rows.append((f"eq2_fused_xla_n{n}", 0.0,
                         f"xla/fusedmodel={fused_bytes / fused_model_bytes:.3f}"))

        # --- v2: whole iteration in two slab kernels (DESIGN.md §3.4) -----
        # The analytic budget is the claim: 9R + 4W full-field streams; the
        # O(E n^2) boundary-plane side channel is reported as the fraction
        # of one stream it costs at sz=1 (the worst slab split).
        v2_streams = FUSED_V2_READ_STREAMS + FUSED_V2_WRITE_STREAMS
        v2_model_bytes = sum(fused_v2_cg_iter_bytes(D, itemsize=4))
        rows.append((f"eq2_fused_v2_streams_n{n}", 0.0,
                     f"streams/iter={v2_streams}"
                     f";v2/eq2={v2_model_bytes / model_bytes:.3f}"
                     f";I_v2={fused_v2_intensity(n, 4):.3f}flop/B"
                     f";planes={fused_v2_plane_streams(n, 1):.3f}str"))

        v2_bytes = _fused_iteration_bytes(n, "v2")
        if v2_bytes is not None:
            rows.append((f"eq2_fused_v2_xla_n{n}", 0.0,
                         f"xla/v2model={v2_bytes / v2_model_bytes:.3f}"))

        # --- v3: s-step matrix-powers pipeline (DESIGN.md §8) -------------
        # The s-sweep is the claim ladder: (4s+9)/s amortized streams per
        # iteration, exactly the v2 budget at s=1, 6.25 at the default
        # s=4; 'eff' folds in the matrix-powers halo side channel at the
        # default sz=4 slab split (<= 9 effective streams at s=4).
        for s_ in (1, 2, SSTEP_DEFAULT_S):
            rs, ws = sstep_streams(s_)
            v3_bytes = sum(bytes_per_dof_iter("sstep_v3", "f32", s=s_))
            rows.append((f"eq2_sstep_v3_s{s_}_streams_n{n}", 0.0,
                         f"streams/iter={rs + ws:g}"
                         f";eff={sstep_effective_streams(s_, 4):.2f}"
                         f";B/dof/iter_f32={v3_bytes:g}"
                         f";I_v3={sstep_intensity(n, s_, 4):.3f}flop/B"))

        # --- precision ladder (DESIGN.md §7): the 13 v2 streams re-priced
        # per storage dtype — bf16 halves f32's bytes/DOF/iter and doubles
        # its intensity; these rows land in BENCH_<tag>.json and are what
        # benchmarks/check_regression.py holds across PRs.
        for pol in ("f64", "f32", "bf16"):
            rb, wb = bytes_per_dof_iter("fused_v2", pol)
            re_, we = bytes_per_dof_iter("fused_v2", pol, exact=True, n=n)
            rows.append((f"v2_bytes_{pol}_n{n}", 0.0,
                         f"B/dof/iter={rb + wb}"
                         f";exact={re_ + we:.2f}"
                         f";I={pipeline_intensity(n, 'fused_v2', pol):.3f}"
                         "flop/B"))
        # v3 at the default s: the same policies re-price 6.25 streams;
        # the exact column folds in the matrix-powers halo (10/sz).
        for pol in ("f64", "f32", "bf16"):
            rb, wb = bytes_per_dof_iter("sstep_v3", pol)
            re_, we = bytes_per_dof_iter("sstep_v3", pol, exact=True, n=n)
            rows.append((f"v3_bytes_{pol}_n{n}", 0.0,
                         f"B/dof/iter={rb + wb:g}"
                         f";exact={re_ + we:.2f}"
                         f";I={pipeline_intensity(n, 'sstep_v3', pol):.3f}"
                         "flop/B"))
        # refinement surcharge: the hi-precision outer pass, amortized over
        # the default 12-iteration bf16 inner sweeps, in bf16-stream units.
        rows.append((f"v2_bf16_ir_overhead_n{n}", 0.0,
                     f"+{ir_overhead_streams(12):.2f}str@inner12"))

        # --- preconditioned rungs (DESIGN.md §9) --------------------------
        # Jacobi: the z-carried PCG pipeline adds exactly one stream to v2
        # (the fused operator diagonal).  Chebyshev(k): +5 streams for the
        # halo'd polynomial-apply kernel, k-independent headline; the halo
        # side channel (8k/sz) and the extra model flops are reported so
        # the bytes-to-solution trade is auditable.
        jac = JACOBI_V2_READ_STREAMS + JACOBI_V2_WRITE_STREAMS
        rows.append((f"eq2_pcg_jacobi_streams_n{n}", 0.0,
                     f"streams/iter={jac}"
                     f";+v2={jac - v2_streams}"
                     f";B/dof/iter_f32="
                     f"{sum(bytes_per_dof_iter('fused_v2_jacobi', 'f32')):g}"))
        chv = CHEB_V2_READ_STREAMS + CHEB_V2_WRITE_STREAMS
        for k_ in (1, 2, CHEB_DEFAULT_K):
            rows.append((f"eq2_pcg_cheb_k{k_}_streams_n{n}", 0.0,
                         f"streams/iter={chv}"
                         f";eff={cheb_effective_streams(k_, 4):.2f}"
                         f";flops/dof={cheb_flops_per_dof(n, k_)}"
                         f";k={k_}"))
        for pol in ("f64", "f32", "bf16"):
            rb, wb = bytes_per_dof_iter("fused_v2_jacobi", pol)
            re_, we = bytes_per_dof_iter("fused_v2_jacobi", pol, exact=True,
                                         n=n)
            rows.append((f"pcg_jacobi_bytes_{pol}_n{n}", 0.0,
                         f"B/dof/iter={rb + wb:g};exact={re_ + we:.2f}"))
            rb, wb = bytes_per_dof_iter("fused_v2_cheb", pol)
            re_, we = bytes_per_dof_iter("fused_v2_cheb", pol, exact=True,
                                         n=n)
            rows.append((f"pcg_cheb_bytes_{pol}_n{n}", 0.0,
                         f"B/dof/iter={rb + wb:g};exact={re_ + we:.2f}"))

        # --- sharded scaling ladder (DESIGN.md §10) -----------------------
        # Per-device effective streams of the z-sharded drivers, from the
        # collective cost model (no multi-device execution here — the
        # parity and collective-count facts behind these numbers are
        # carried by tests/distributed_checks.py).  Strong scaling holds
        # the paper grid's EZ=32 and splits it d ways: the collective
        # channel (8/ez_local for s-step) grows as local slabs shrink.
        # Weak scaling holds ez_local=8: per-device traffic is flat in d —
        # the flat rows *are* the claim, pinned by the gate.
        for d in (1, 2, 4, 8):
            eff = sstep_effective_streams(SSTEP_DEFAULT_S, 4, ndev=d, ez=32)
            rows.append((f"sstep_v3_sharded_strong_d{d}_n{n}", 0.0,
                         f"eff={eff:g};ez_local={32 // d}"))
        for d in (1, 2, 4, 8):
            eff = sstep_effective_streams(SSTEP_DEFAULT_S, 4, ndev=d,
                                          ez=8 * d)
            rows.append((f"sstep_v3_sharded_weak_d{d}_n{n}", 0.0,
                         f"eff={eff:g};ez_local=8"))
        for pol in ("f64", "f32", "bf16"):
            rj, wj = bytes_per_dof_iter("fused_v2_jacobi", pol, exact=True,
                                        n=n, ndev=8, ez=32)
            rows.append((f"pcg_jacobi_sharded_d8_{pol}_n{n}", 0.0,
                         f"exactB/dof/iter={rj + wj:g}"))
            rc, wc = bytes_per_dof_iter("fused_v2_cheb", pol, exact=True,
                                        n=n, ndev=8, ez=32)
            rows.append((f"pcg_cheb_sharded_d8_{pol}_n{n}", 0.0,
                         f"exactB/dof/iter={rc + wc:g}"
                         f";eff={cheb_effective_streams(CHEB_DEFAULT_K, 4, ndev=8, ez=32, n=n):g}"))
    return rows


def _bytes_accessed(compiled) -> float:
    """`cost_analysis()` returns a dict on new jax, a 1-list of dicts on
    older releases."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return float(ca.get("bytes accessed", 0))


def _fused_iteration_bytes(n: int, version: str) -> float | None:
    """XLA's byte estimate for one step-fused CG iteration (niter=1 solve).

    Interpret-mode Pallas lowers to ordinary HLO on CPU, so cost_analysis
    over-counts relative to a real TPU pallas_call; the analytic rows above
    are the load-bearing ones and this is a cross-check only.
    """
    from repro.core.cg_fused import (cg_fused_fixed_iters,
                                     cg_fused_v2_fixed_iters)

    case = NekboneCase(n=n, grid=GRID, dtype=jnp.float32)

    if version == "v2":
        def one_iter(f):
            return cg_fused_v2_fixed_iters(f, D=case.D, g=case.g,
                                           grid=case.grid, niter=1).x
    else:
        def one_iter(f):
            return cg_fused_fixed_iters(f, D=case.D, g=case.g,
                                        mask=case.mask, c=case.c,
                                        grid=case.grid, niter=1).x

    try:
        aval = jax.ShapeDtypeStruct(case.mask.shape, jnp.float32)
        compiled = jax.jit(one_iter).lower(aval).compile()
        return _bytes_accessed(compiled)
    except Exception:
        return None
