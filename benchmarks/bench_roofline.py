"""Paper Fig. 4: measured roofline via the memcpy-bandwidth probe.

Paper §V: "instead of executing the computations, a cudaMemcpy() on the GPU
is executed for each load and store in each CG iteration ... exactly double
the amount of data movement necessary".  CPU analog: time ``jnp.copy`` over
the 30*D-word CG working set; the measured roofline is then
``BW * I(n)`` (Eq. 2) and the achieved CG performance is compared to it.

CSV rows:
  roofline_bw_eNNN      — measured copy bandwidth (GB/s in derived)
  roofline_bound_eNNN   — BW * I(n): attainable GFLOP/s
  cg_achieved_eNNN      — achieved GFLOP/s of a full CG iteration (fused)
  cg_fraction_eNNN      — achieved / bound (the paper reports 77-92%)
  roofline_fraction_<pipeline>_eNNN — the same measured-roofline fraction
      per *Pallas pipeline* (fused_v2 / jacobi / cheb / sstep_v3): one
      iteration of the real driver against BW * pipeline_intensity — the
      per-pipeline report DESIGN.md §11 specifies.  On CPU the drivers
      run in Pallas interpret mode, so the fractions are emulator-time
      demonstrations of the methodology; on a TPU backend they are the
      paper-grade measurement.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.core.cost import (cg_iter_flops, intensity,
                             pipeline_flops_per_dof, pipeline_intensity)
from repro.core.nekbone import NekboneCase

QUICK = bool(os.environ.get("REPRO_BENCH_QUICK"))
N_GLL = 10
ELEMENT_SWEEP = (64,) if QUICK else (64, 256, 1024)


def _time(fn, *args, reps=5):
    # shared methodology (benchmarks/timing.py): warmup-discard +
    # median-of-reps, each rep synced and timed individually.
    from benchmarks.timing import measure

    return measure(fn, *args, reps=reps, warmup=1)


def run():
    rows = []
    for E in ELEMENT_SWEEP:
        grid = {64: (4, 4, 4), 256: (4, 8, 8), 1024: (8, 8, 16)}[E]
        case = NekboneCase(n=N_GLL, grid=grid, dtype=jnp.float32,
                           ax_impl="fused")
        D = case.mesh.ndof
        itemsize = 4

        # --- bandwidth probe: copy the 30*D-word working set -------------
        words = 30 * D
        buf = jnp.arange(words, dtype=jnp.float32)
        copy = jax.jit(lambda b: b + 0.0)      # one read + one write stream
        t_copy = _time(copy, buf)
        bw = 2 * words * itemsize / t_copy     # bytes moved / s
        rows.append((f"roofline_bw_e{E}", t_copy * 1e6,
                     f"{bw / 1e9:.2f}GB/s"))

        bound = bw * intensity(N_GLL, itemsize)
        rows.append((f"roofline_bound_e{E}", 0.0,
                     f"{bound / 1e9:.2f}GF/s"))
        # beyond-paper: bf16 storage halves every stream of the
        # memory-bound operator => the attainable roofline doubles
        # (I(10) 1.28 -> 2.57 flop/B); fp32 accumulation inside the kernel
        # keeps CG convergence (tests/test_precision.py parity sweep +
        # cg_ir_fixed_iters for fp64-grade residuals, DESIGN.md §7).
        rows.append((f"roofline_bound_bf16_e{E}", 0.0,
                     f"{bw * intensity(N_GLL, 2) / 1e9:.2f}GF/s(2x)"))
        # the fused-v2 pipeline under each precision policy: same bandwidth,
        # policy-priced streams — the attainable GF/s ladder the
        # mixed-precision work climbs (cost.pipeline_intensity).
        for pol in ("f32", "bf16"):
            bnd = bw * pipeline_intensity(N_GLL, "fused_v2", pol)
            rows.append((f"roofline_v2_{pol}_e{E}", 0.0,
                         f"{bnd / 1e9:.2f}GF/s"))

        # --- achieved: one full CG iteration (paper's measured quantity) --
        u_ex, f = case.manufactured()

        def cg_iter(x, r, p):
            w = case.ax_full(p)
            dot = case.dot()
            alpha = dot(r, r) / dot(p, w)
            x2 = x + alpha * p
            r2 = r - alpha * w
            beta = dot(r2, r2) / dot(r, r)
            return x2, r2, r2 + beta * p

        step = jax.jit(cg_iter)
        x = jnp.zeros_like(f)
        t_it = _time(step, x, f, f)
        flops = cg_iter_flops(D, N_GLL)
        achieved = flops / t_it
        rows.append((f"cg_achieved_e{E}", t_it * 1e6,
                     f"{achieved / 1e9:.2f}GF/s"))
        rows.append((f"cg_fraction_e{E}", 0.0,
                     f"{achieved / bound:.1%}_of_measured_roofline"))
    rows.extend(_pipeline_fraction_rows())
    return rows


# per-pipeline measured-roofline fractions (DESIGN.md §11).  QUICK shrinks
# the case to (n=6, E=8): the pipelines run in interpret mode on CPU, and
# a paper-size case would dominate the CI smoke budget; the full sweep
# uses the paper's (n=10, E=64) point.
_FRACTION_PIPELINES = (("fused_v2", "fused_v2"), ("jacobi", "fused_v2_jacobi"),
                       ("cheb", "fused_v2_cheb"), ("sstep_v3", "sstep_v3"))


def _pipeline_fraction_rows():
    from repro.core.cg_fused import cg_fused_v2_fixed_iters
    from repro.core.cg_sstep import cg_sstep_fixed_iters, estimate_theta
    from repro.core.precond import pcg_fused_v2_fixed_iters

    n, grid = ((6, (2, 2, 2)) if QUICK else (10, (4, 4, 4)))
    E = grid[0] * grid[1] * grid[2]
    case = NekboneCase(n=n, grid=grid, dtype=jnp.float32)
    ndof = case.mesh.ndof
    _, f = case.manufactured()

    # bandwidth probe on this case's 30-stream working set (same probe as
    # the headline rows, re-measured at this size).
    words = 30 * ndof
    buf = jnp.arange(words, dtype=jnp.float32)
    copy = jax.jit(lambda b: b + 0.0)
    bw = 2 * words * 4 / _time(copy, buf)

    s = 4
    theta = estimate_theta(case.D, case.g, case.grid, case.mask)

    def t_v2():
        return _time(lambda: cg_fused_v2_fixed_iters(
            f, D=case.D, g=case.g, grid=case.grid, niter=1, mask=case.mask,
            c=case.c).x, reps=1)

    def t_pcg(name):
        spec = case.precond_spec(name)
        return _time(lambda: pcg_fused_v2_fixed_iters(
            f, D=case.D, g=case.g, grid=case.grid, niter=1, precond=spec,
            mask=case.mask, c=case.c).x, reps=1)

    def t_sstep():
        # one full cycle = s iterations; report the amortized per-iteration
        # time (the quantity pipeline_intensity prices).
        return _time(lambda: cg_sstep_fixed_iters(
            f, D=case.D, g=case.g, grid=case.grid, niter=s, s=s,
            mask=case.mask, c=case.c, theta=theta).x, reps=1) / s

    timers = {"fused_v2": t_v2, "fused_v2_jacobi": lambda: t_pcg("jacobi"),
              "fused_v2_cheb": lambda: t_pcg("cheb4"),
              "sstep_v3": t_sstep}
    rows = []
    for name, pipeline in _FRACTION_PIPELINES:
        t_iter = timers[pipeline]()
        achieved = pipeline_flops_per_dof(n, pipeline) * ndof / t_iter
        bound = bw * pipeline_intensity(n, pipeline, "f32")
        rows.append((f"roofline_fraction_{name}_e{E}", t_iter * 1e6,
                     f"{achieved / bound:.1%}_of_measured_roofline"))
    return rows
