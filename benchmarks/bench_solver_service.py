"""Standalone solver-service latency/throughput bench (schema v7 rows).

Thin entry over :func:`repro.launch.solver_service.bench_service` in the
CSV idiom of the other bench modules; ``benchmarks.run`` embeds the same
payload under the ``solver_service`` key.

  PYTHONPATH=src python -m benchmarks.bench_solver_service
  REPRO_BENCH_QUICK=1 ... python -m benchmarks.bench_solver_service
"""
from __future__ import annotations

import os


def run():
    """Yield ``(name, us_per_call, derived)`` rows like the other benches.

    ``us_per_call`` is per-request latency; ``derived`` is requests/s at
    that batch ceiling.
    """
    from repro.launch.solver_service import bench_service

    if os.environ.get("REPRO_BENCH_QUICK"):
        payload = bench_service(nelt=64, n=4, requests=4, max_b=2,
                                niter=3, repeats=1)
    else:
        payload = bench_service(nelt=64, requests=16, max_b=8, niter=25)
    for b, row in payload["rows"].items():
        yield (f"solver_service_E{payload['nelt']}_n{payload['n']}_b{b}",
               row["latency_ms_per_request"] * 1e3,
               f"{row['throughput_req_s']:.2f}req/s;"
               f"{row['dispatches']}dispatches")


def main():
    print("name,us_per_call,derived")
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
