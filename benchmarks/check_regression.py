"""CI perf-regression gate over the machine-readable bench JSON.

  PYTHONPATH=src python -m benchmarks.check_regression [FRESH.json]
      [--baseline benchmarks/baseline/BENCH_baseline.json] [--tol 0.05]
      [--timing-tol 0.10] [--timing-warn-only]

Diffs a fresh ``BENCH_<tag>.json`` (default: the newest one under
``$REPRO_BENCH_DIR`` / ``benchmarks/out``) against the committed baseline
and fails (exit 1) on:

* **streams/iter ladder** — the 30 → 17 → 13 Eq.-2 fusion ladder
  (DESIGN.md §6) must match the baseline *exactly*: a higher number is a
  real traffic regression, a lower one means someone improved the pipeline
  and must refresh the baseline to pin the win (benchmarks/README.md).
* **bytes/DOF/iter** — the per-(pipeline, precision) byte table
  (DESIGN.md §7) must match within ``--tol`` relative tolerance, and the
  bf16 column must stay ≈ half of f32 on every rung (the mixed-precision
  headline).
* **us/iter wall clock** — each measured per-iteration row the baseline
  pins (schema v6, DESIGN.md §11) must stay within ``--timing-tol``
  (+10% default) of the baseline.  Wall time is only comparable on the
  same backend kind, so a ``reference_backend`` mismatch between fresh
  and baseline downgrades every timing row to a warning — annotated,
  when both files carry the schema-v9 ``provenance`` record, with the
  exact fields (machine, jax version, x64 flag…) that differ; and because
  shared CI runners are noisy, ``--timing-warn-only`` routes timing
  violations to ``::warning::`` annotations (exit 0) while the
  stream-ladder and byte rows stay hard.
* **streams/RHS** — the multi-RHS amortization table (schema v7,
  DESIGN.md §12) must match the baseline exactly, and every pipeline's
  per-RHS streams must be *strictly decreasing* in b — a bigger batch
  must never cost more per RHS.  The measured ``solver_service``
  latency/throughput section is presence-checked (timing-like: warn-only
  under ``--timing-warn-only``), never value-gated.
* **schema presence** — a fresh file missing either analytic table fails:
  the gate exists precisely so these numbers cannot silently disappear.
  A fresh file missing the ``us_per_iter`` table the baseline holds is a
  *timing* violation (hard by default, warning under
  ``--timing-warn-only``).

Forward compatibility: rungs / pipelines / policy columns present in the
*fresh* file but absent from the baseline are **warnings**, not failures —
a PR that adds a ladder rung (a new pipeline) must not need a hand-edited
baseline to go green; the warning tells the author to pin the new row on
the next baseline refresh.  Rows the baseline *does* hold remain load-
bearing: missing or regressed ones still fail.  The bench JSON carries a
monotone ``schema_version`` int; a fresh/baseline version skew is also a
warning (the shared tables are still compared).

A missing or corrupt file is a hard error (exit 2) with a one-line
explanation — never a traceback, and never a silent pass.
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import sys

DEFAULT_BASELINE = pathlib.Path(__file__).parent / "baseline" / \
    "BENCH_baseline.json"
DEFAULT_TOL = 0.05
# wall-clock band: one-sided (+10%) — slower fails, faster is an
# improvement surfaced as a refresh-the-baseline warning.
DEFAULT_TIMING_TOL = 0.10


def _die(msg: str) -> None:
    print(msg, file=sys.stderr)
    sys.exit(2)


def load_bench_json(path: pathlib.Path, role: str) -> dict:
    """Load one bench JSON; exits 2 with a clear message when the file is
    missing, unreadable, or corrupt (a stale half-written artifact must
    fail loudly, not crash or pass)."""
    path = pathlib.Path(path)
    try:
        raw = path.read_text()
    except OSError as e:
        _die(f"ERROR: cannot read {role} bench json {path}: {e}")
    try:
        data = json.loads(raw)
    except ValueError as e:
        _die(f"ERROR: {role} bench json {path} is corrupt "
             f"(not valid JSON: {e}); delete it and re-run "
             "`python -m benchmarks.run`")
    if not isinstance(data, dict):
        _die(f"ERROR: {role} bench json {path} is corrupt "
             "(top level is not an object)")
    return data


def find_fresh(bench_dir: pathlib.Path | None = None) -> pathlib.Path:
    """Newest BENCH_*.json under $REPRO_BENCH_DIR (default benchmarks/out)."""
    if bench_dir is None:
        bench_dir = pathlib.Path(os.environ.get("REPRO_BENCH_DIR",
                                                "benchmarks/out"))
    cands = sorted(bench_dir.glob("BENCH_*.json"),
                   key=lambda p: p.stat().st_mtime)
    if not cands:
        _die(f"ERROR: no BENCH_*.json under {bench_dir}; run "
             "`python -m benchmarks.run` first (or pass the file "
             "explicitly)")
    return cands[-1]


def _provenance_delta(fresh: dict, base: dict) -> str:
    """Explain *why* two bench runs differ using the schema-v9 provenance
    records (machine tag, python/jax versions, backend, x64 flag).

    Returns a human-readable '; provenance: ...' suffix listing every
    field whose value differs between the two files, or an empty string
    when either side predates schema v9 (no provenance record) or
    nothing differs.  Appended to the reference_backend-mismatch warning
    so the reader learns e.g. that the baseline was cut on another
    machine or jax version rather than guessing.
    """
    fp, bp = fresh.get("provenance"), base.get("provenance")
    if not isinstance(fp, dict) or not isinstance(bp, dict):
        return ""
    deltas = [f"{k}: fresh={fp.get(k)!r} baseline={bp.get(k)!r}"
              for k in sorted(set(fp) | set(bp))
              if fp.get(k) != bp.get(k)]
    if not deltas:
        return ""
    return " [provenance delta: " + "; ".join(deltas) + "]"


def compare(fresh: dict, base: dict, tol: float = DEFAULT_TOL,
            warnings: list[str] | None = None,
            timing_tol: float = DEFAULT_TIMING_TOL,
            timing_problems: list[str] | None = None) -> list[str]:
    """All regressions of ``fresh`` against ``base`` (empty == gate passes).

    Forward-compat findings (rows *added* by the fresh run, schema-version
    skew) are appended to ``warnings`` when given — surfaced, never
    failing; see the module docstring.

    Wall-clock (us/iter) violations go to ``timing_problems`` when given —
    the caller decides whether they fail or warn (``--timing-warn-only``);
    when None they are ordinary problems.
    """
    problems: list[str] = []
    warnings = warnings if warnings is not None else []
    timing = timing_problems if timing_problems is not None else problems

    # --- us/iter wall clock: relative band, same-backend only -----------
    base_us = base.get("us_per_iter") or {}
    if base_us:
        base_be = base.get("reference_backend")
        fresh_be = fresh.get("reference_backend")
        fresh_us = fresh.get("us_per_iter")
        if base_be is not None and fresh_be != base_be:
            warnings.append(
                f"us/iter reference backend mismatch: fresh={fresh_be!r} "
                f"baseline={base_be!r} — wall time is not comparable "
                "across backends; timing rows skipped (refresh the "
                "baseline on this backend to re-arm them)"
                + _provenance_delta(fresh, base))
        elif not fresh_us:
            timing.append("fresh bench json has no us_per_iter table — "
                          "measured wall time silently disappeared "
                          "(baseline pins it)")
        else:
            for row, want in sorted(base_us.items()):
                got = fresh_us.get(row)
                if got is None:
                    timing.append(f"us/iter row '{row}' missing "
                                  f"(baseline: {want:g}us)")
                    continue
                w, g = float(want), float(got)
                if w > 0 and g > w * (1.0 + timing_tol):
                    timing.append(
                        f"us/iter '{row}': {g:g}us regressed past "
                        f"+{timing_tol:.0%} of baseline {w:g}us")
                elif w > 0 and g < w * (1.0 - timing_tol):
                    warnings.append(
                        f"us/iter '{row}': {g:g}us is >{timing_tol:.0%} "
                        f"faster than baseline {w:g}us — refresh the "
                        "baseline to pin the win")
            for row in sorted(set(fresh_us) - set(base_us)):
                warnings.append(
                    f"new us/iter row '{row}' = {fresh_us[row]:g}us not in "
                    "baseline — unchecked until the next baseline refresh "
                    "pins it")

    # --- schema version: skew is a warning, the tables still compare ----
    bv, fv = base.get("schema_version"), fresh.get("schema_version")
    if fv != bv:
        warnings.append(
            f"bench json schema_version skew: fresh={fv!r} baseline={bv!r} "
            "— comparing the shared tables; refresh the baseline to align")

    # --- streams/iter ladder: exact match -------------------------------
    base_streams = base.get("streams_per_iter") or {}
    fresh_streams = fresh.get("streams_per_iter")
    if not base_streams:
        problems.append("baseline has no streams_per_iter table "
                        "(refresh it per benchmarks/README.md)")
    elif not fresh_streams:
        problems.append("fresh bench json has no streams_per_iter table — "
                        "the ladder silently disappeared")
    else:
        for rung, want in sorted(base_streams.items()):
            got = fresh_streams.get(rung)
            if got is None:
                problems.append(f"streams/iter rung '{rung}' missing "
                                f"(baseline: {want})")
            elif got != want:
                direction = ("regressed" if got > want else
                             "improved — refresh the baseline to pin it")
                problems.append(f"streams/iter '{rung}': {got} != baseline "
                                f"{want} ({direction})")
        for rung in sorted(set(fresh_streams) - set(base_streams)):
            warnings.append(
                f"new streams/iter rung '{rung}' = {fresh_streams[rung]} "
                "not in baseline — unchecked until the next baseline "
                "refresh pins it")

    # --- streams/RHS amortization curve (schema v7): exact rows + the
    # strictly-decreasing-in-b invariant on whatever the fresh run emits -
    base_rhs = base.get("streams_per_rhs") or {}
    fresh_rhs = fresh.get("streams_per_rhs")
    if base_rhs and not fresh_rhs:
        problems.append("fresh bench json has no streams_per_rhs table — "
                        "the multi-RHS amortization curve silently "
                        "disappeared (baseline pins it)")
    elif base_rhs:
        for pipeline, rows in sorted(base_rhs.items()):
            got_rows = fresh_rhs.get(pipeline)
            if got_rows is None:
                problems.append(
                    f"streams/RHS pipeline '{pipeline}' missing")
                continue
            for b, want in sorted(rows.items(), key=lambda kv: int(kv[0])):
                got = got_rows.get(b)
                if got is None:
                    problems.append(f"streams/RHS '{pipeline}' b={b} "
                                    f"missing (baseline: {want})")
                elif got != want:
                    direction = ("regressed" if got > want else
                                 "improved — refresh the baseline to "
                                 "pin it")
                    problems.append(
                        f"streams/RHS '{pipeline}' b={b}: {got} != "
                        f"baseline {want} ({direction})")
        for pipeline in sorted(set(fresh_rhs) - set(base_rhs)):
            warnings.append(
                f"new streams/RHS pipeline '{pipeline}' not in baseline — "
                "unchecked until the next baseline refresh pins it")
    if fresh_rhs:
        for pipeline, rows in sorted(fresh_rhs.items()):
            seq = sorted(((int(b), float(v)) for b, v in rows.items()))
            for (b0, v0), (b1, v1) in zip(seq, seq[1:]):
                if v1 >= v0:
                    problems.append(
                        f"streams/RHS '{pipeline}' not strictly "
                        f"decreasing: b={b1} ({v1:g}) >= b={b0} ({v0:g}) "
                        "— a bigger batch must never cost more per RHS")

    # --- solver_service rows: presence only (measured wall clock — the
    # values are environment noise; disappearing silently is not) --------
    if base.get("solver_service") and not fresh.get("solver_service"):
        timing.append("fresh bench json has no solver_service section — "
                      "serving latency/throughput rows silently "
                      "disappeared (baseline pins their presence)")

    # --- bytes/DOF/iter: tolerance + the bf16 ≈ f32/2 invariant ---------
    base_bytes = base.get("bytes_per_dof_iter") or {}
    fresh_bytes = fresh.get("bytes_per_dof_iter")
    if not base_bytes:
        problems.append("baseline has no bytes_per_dof_iter table "
                        "(refresh it per benchmarks/README.md)")
        return problems
    if not fresh_bytes:
        problems.append("fresh bench json has no bytes_per_dof_iter table — "
                        "per-precision accounting silently disappeared")
        return problems

    for pipeline in sorted(set(fresh_bytes) - set(base_bytes)):
        warnings.append(
            f"new bytes/DOF/iter pipeline '{pipeline}' not in baseline — "
            "unchecked until the next baseline refresh pins it")
    for pipeline, pols in sorted(base_bytes.items()):
        got_pols = fresh_bytes.get(pipeline)
        if got_pols is None:
            problems.append(f"bytes/DOF/iter pipeline '{pipeline}' missing")
            continue
        for pol in sorted(set(got_pols) - set(pols)):
            warnings.append(
                f"new bytes/DOF/iter policy '{pipeline}/{pol}' not in "
                "baseline — unchecked until the next baseline refresh "
                "pins it")
        for pol, want in sorted(pols.items()):
            got = got_pols.get(pol)
            if got is None:
                problems.append(
                    f"bytes/DOF/iter '{pipeline}/{pol}' missing")
                continue
            for field in sorted(set(got) - set(want)):
                warnings.append(
                    f"new bytes/DOF/iter column '{pipeline}/{pol}/{field}' "
                    "not in baseline — unchecked until the next baseline "
                    "refresh pins it")
            # every numeric column the baseline pins must hold (headline
            # read/write and, when present, the *_exact side-channel
            # books); columns only the fresh file has are forward-compat.
            for field in sorted(want):
                w, g = float(want[field]), float(got.get(field, -1))
                if abs(g - w) > tol * max(abs(w), 1.0):
                    problems.append(
                        f"bytes/DOF/iter '{pipeline}/{pol}' {field}: "
                        f"{g:g} outside ±{tol:.0%} of baseline {w:g}")
        # bf16 must price at ~half of f32 on every rung present in fresh
        f32 = got_pols.get("f32")
        bf16 = got_pols.get("bf16")
        if f32 and bf16:
            tot32 = float(f32["read"]) + float(f32["write"])
            tot16 = float(bf16["read"]) + float(bf16["write"])
            if tot32 <= 0 or abs(tot16 / tot32 - 0.5) > tol:
                problems.append(
                    f"'{pipeline}': bf16 bytes/DOF/iter {tot16:g} is not "
                    f"≈ half of f32's {tot32:g} "
                    f"(ratio {tot16 / max(tot32, 1e-9):.3f})")
    return problems


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="diff a fresh BENCH_<tag>.json against the committed "
                    "baseline (streams ladder exact, bytes within "
                    "tolerance)")
    ap.add_argument("fresh", nargs="?", default=None,
                    help="fresh BENCH_<tag>.json (default: newest under "
                         "$REPRO_BENCH_DIR / benchmarks/out)")
    ap.add_argument("--baseline", default=str(DEFAULT_BASELINE),
                    help=f"committed baseline (default: {DEFAULT_BASELINE})")
    ap.add_argument("--tol", type=float, default=DEFAULT_TOL,
                    help="relative tolerance for byte counts "
                         f"(default {DEFAULT_TOL})")
    ap.add_argument("--timing-tol", type=float, default=DEFAULT_TIMING_TOL,
                    help="relative band for measured us/iter rows "
                         f"(default {DEFAULT_TIMING_TOL})")
    ap.add_argument("--timing-warn-only", action="store_true",
                    help="route us/iter band violations to ::warning:: "
                         "annotations (exit 0); stream/byte rows stay "
                         "hard — for noisy shared CI runners")
    args = ap.parse_args(argv)

    fresh_path = pathlib.Path(args.fresh) if args.fresh else find_fresh()
    fresh = load_bench_json(fresh_path, "fresh")
    base = load_bench_json(pathlib.Path(args.baseline), "baseline")

    warnings: list[str] = []
    timing_problems: list[str] = []
    try:
        problems = compare(fresh, base, tol=args.tol, warnings=warnings,
                           timing_tol=args.timing_tol,
                           timing_problems=timing_problems)
    except (KeyError, TypeError, AttributeError, ValueError) as e:
        # valid JSON, wrong shape (hand-edited table, scalar where an
        # object belongs): same contract as corrupt JSON — clear error,
        # exit 2, never a traceback.
        _die(f"ERROR: bench json structure is malformed ({e!r}); "
             f"re-generate {fresh_path} with `python -m benchmarks.run` "
             "or refresh the baseline per benchmarks/README.md")
    for w in warnings:
        print(f"WARNING: {w}", file=sys.stderr)
    if args.timing_warn_only:
        # GitHub-annotation format so band violations surface on the PR
        # without failing the (noisy-runner) smoke leg.
        for t in timing_problems:
            print(f"::warning::timing: {t}")
    else:
        problems = problems + timing_problems
    if problems:
        print(f"perf-regression gate FAILED ({fresh_path} vs "
              f"{args.baseline}):")
        for p in problems:
            print(f"  - {p}")
        return 1
    streams = fresh.get("streams_per_iter", {})
    print(f"perf-regression gate OK: {fresh_path} matches {args.baseline} "
          f"(streams/iter {streams}, bytes within ±{args.tol:.0%}, "
          f"us/iter within +{args.timing_tol:.0%})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
