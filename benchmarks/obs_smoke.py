"""CI smoke for the observability subsystem (DESIGN.md §14).

  JAX_ENABLE_X64=1 PYTHONPATH=src python -m benchmarks.obs_smoke

Three checks, mirroring benchmarks/serving_smoke.py's style:

* **Bitwise parity, tracing on vs off** — the same fused-v2 solve run
  cold (tracing off) and inside ``trace.recording()`` must produce
  bit-identical ``x``: instrumentation is host-side span bookkeeping
  around an unchanged ``_solve_resolved`` call, never a numerics change.
  The traced result must carry a ``SolveTelemetry``; the untraced one
  must not.
* **Paper-case pmg trace** — the E=1024/n=10 paper case solved through
  ``NekboneCase.solve(precond="pmg")`` with tracing on must write a
  schema-valid ``repro-trace/1`` JSONL file whose spans include the
  top-level ``solve``, the ``pmg.dispatch`` V-cycle application, and one
  ``pmg.vcycle.level`` span per ladder level.
* **Cost-model drift** — ``obs.drift.assert_no_drift()`` over fused_v2,
  fused_v2_jacobi, and sstep_v3: measured bytes/DOF/iter (jaxpr stream
  charge) within the calibrated band of the exact ``cost.py`` books,
  measured collective counts exactly matching the pinned contracts.

Exits non-zero naming the offending check; prints one CSV-ish row per
check so the log doubles as a record.
"""
from __future__ import annotations

import os
import pathlib
import sys
import tempfile

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

N, GRID, NITER = 5, (2, 2, 4), 8
PAPER_N, PAPER_GRID, PAPER_NITER = 10, (8, 8, 16), 3


def _check_bitwise() -> int:
    from repro.core.nekbone import NekboneCase
    from repro.obs import trace

    case = NekboneCase(n=N, grid=GRID, dtype=jnp.float64,
                       ax_impl="pallas_fused_cg_v2")
    _, f = case.manufactured()
    res_off = case.solve(f, niter=NITER)
    with trace.recording() as rec:
        res_on = case.solve(f, niter=NITER)
    bitwise = (np.asarray(res_off.x).tobytes()
               == np.asarray(res_on.x).tobytes())
    tel_ok = (res_on.telemetry is not None and res_off.telemetry is None
              and res_on.telemetry.iters == int(np.max(np.asarray(
                  res_on.iters_taken))))
    spans = [r["name"] for r in rec.records if r["type"] == "span"]
    ok = bitwise and tel_ok and "solve" in spans
    print(f"obs_smoke_bitwise,0.0,bitwise={bitwise};telemetry={tel_ok}"
          f";spans={len(spans)};{'OK' if ok else 'FAIL'}")
    if not ok:
        print(f"ERROR: tracing on/off parity failed (bitwise={bitwise}, "
              f"telemetry={tel_ok}, spans={spans})", file=sys.stderr)
    return not ok


def _check_paper_pmg_trace(out_dir: pathlib.Path) -> int:
    from repro.core.nekbone import NekboneCase
    from repro.obs import trace

    paper = NekboneCase(n=PAPER_N, grid=PAPER_GRID, dtype=jnp.float64,
                        ax_impl="pallas_fused_cg_v2")
    _, f = paper.manufactured()
    path = out_dir / "obs_smoke_pmg.trace.jsonl"
    with trace.recording(path) as rec:
        paper.solve(f, niter=PAPER_NITER, precond="pmg")
    problems = trace.validate_trace_file(path)
    spans = [r["name"] for r in rec.records if r["type"] == "span"]
    levels = sorted(r["attrs"]["level"] for r in rec.records
                    if r["type"] == "span" and r["name"] == "pmg.vcycle.level")
    ok = (not problems and "solve" in spans and "pmg.dispatch" in spans
          and len(levels) >= 2 and levels == list(range(len(levels))))
    print(f"obs_smoke_pmg_trace,0.0,schema_problems={len(problems)}"
          f";levels={'-'.join(map(str, levels))};spans={len(spans)}"
          f";{'OK' if ok else 'FAIL'}")
    if not ok:
        for p in problems:
            print(f"ERROR: trace schema: {p}", file=sys.stderr)
        print(f"ERROR: paper-case pmg trace check failed (spans={spans}, "
              f"levels={levels})", file=sys.stderr)
    return not ok


def _check_drift() -> int:
    from repro.obs import drift

    report = drift.check()
    for row in report.rows:
        print(f"obs_smoke_drift_{row.pipeline}_{row.check},0.0,"
              f"ratio={row.ratio};band={row.band};"
              f"{'OK' if row.ok else 'FAIL'}")
    if not report.ok:
        for row in report.failures():
            print(f"ERROR: model drift: {row.pipeline}/{row.check} "
                  f"measured={row.measured} expected={row.expected} "
                  f"({row.detail})", file=sys.stderr)
    return not report.ok


def main() -> int:
    out = os.environ.get("REPRO_BENCH_DIR")
    out_dir = pathlib.Path(out) if out else pathlib.Path(tempfile.mkdtemp(
        prefix="obs_smoke_"))
    out_dir.mkdir(parents=True, exist_ok=True)
    failures = _check_bitwise()
    failures += _check_paper_pmg_trace(out_dir)
    failures += _check_drift()
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
