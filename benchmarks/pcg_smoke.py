"""CI smoke for the preconditioning subsystem: interpret-mode PCG parity.

  JAX_ENABLE_X64=1 PYTHONPATH=src python -m benchmarks.pcg_smoke

Runs the fused v2 PCG pipelines (core/precond.py, DESIGN.md §9) on a
small paper-shaped case and asserts fp64 parity against the reference
``cg_fixed_iters(precond=M)`` solvers — Jacobi, and Chebyshev for
k in {1, 2, 4} (both sides sharing one Lanczos interval, so the
comparison isolates the kernels).  A final row checks the
tolerance-driven driver's prefix property against the fixed-iteration
trajectory.  Exits non-zero (naming the offending configuration) on any
parity miss; prints one CSV-ish row per configuration so the log doubles
as an iteration-advantage record.
"""
from __future__ import annotations

import sys

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

# interpret-mode parity floor: fp64 round-off through the different
# partial-sum associations and the z-carried Jacobi form (DESIGN.md §9.2),
# same budget as tests/test_precond.py.
RTOL = 1e-9
N, GRID, NITER = 5, (2, 2, 4), 10
K_SWEEP = (1, 2, 4)


def main() -> int:
    from repro.core import cg as cg_mod
    from repro.core import precond as pc
    from repro.core.nekbone import NekboneCase

    case = NekboneCase(n=N, grid=GRID, dtype=jnp.float64)
    _, f = case.manufactured()

    failures = 0

    def check(label, ref, fused):
        nonlocal failures
        h_ref = np.asarray(ref.rnorm_history)
        h_fus = np.asarray(fused.rnorm_history)
        hist_rel = float(np.abs(h_fus - h_ref).max() / h_ref[0])
        x_scale = np.abs(np.asarray(ref.x)).max() + 1e-300
        x_rel = float(np.abs(np.asarray(fused.x)
                             - np.asarray(ref.x)).max() / x_scale)
        ok = hist_rel < RTOL and x_rel < RTOL
        failures += not ok
        drop = float(h_fus[-1] / h_fus[0])
        print(f"pcg_smoke_{label},0.0,hist_rel={hist_rel:.2e}"
              f";x_rel={x_rel:.2e};rnorm_drop={drop:.2e}"
              f";{'OK' if ok else 'FAIL'}")
        if not ok:
            print(f"ERROR: {label} parity vs cg_fixed_iters exceeded "
                  f"{RTOL:g} (hist {hist_rel:.2e}, x {x_rel:.2e})",
                  file=sys.stderr)

    # --- Jacobi ---------------------------------------------------------
    diag = case.operator_diagonal()
    ref = cg_mod.cg_fixed_iters(
        case.ax_full, f, niter=NITER, dot=case.dot(),
        precond=cg_mod.jacobi_preconditioner(diag))
    fused = pc.pcg_fused_v2_fixed_iters(
        f, D=case.D, g=case.g, grid=case.grid, niter=NITER,
        precond=pc.JacobiPrecond(invdiag=1.0 / diag), mask=case.mask,
        c=case.c, interpret=True)
    check("jacobi", ref, fused)

    # --- Chebyshev, shared Lanczos interval -----------------------------
    lmin, lmax = pc.estimate_interval(case.D, case.g, case.grid, case.mask,
                                      case.c)
    for k in K_SWEEP:
        ref = cg_mod.cg_fixed_iters(
            case.ax_full, f, niter=NITER, dot=case.dot(),
            precond=pc.chebyshev_preconditioner(case.ax_full, k, lmin,
                                                lmax))
        fused = pc.pcg_fused_v2_fixed_iters(
            f, D=case.D, g=case.g, grid=case.grid, niter=NITER,
            precond=pc.ChebyshevPrecond(k=k, lmin=lmin, lmax=lmax),
            mask=case.mask, c=case.c, interpret=True)
        check(f"cheb_k{k}", ref, fused)

    # --- tolerance-driven prefix (unpreconditioned) ---------------------
    from repro.core.cg_fused import cg_fused_v2_fixed_iters

    fixed = cg_fused_v2_fixed_iters(f, D=case.D, g=case.g, grid=case.grid,
                                    niter=NITER, mask=case.mask, c=case.c,
                                    interpret=True)
    h_fix = np.asarray(fixed.rnorm_history)
    # the stiff SEM residual norm can *rise* before it falls (DESIGN.md
    # §7), so target the second-to-last entry: the first crossing sits
    # strictly inside (0, NITER) — a genuine early exit.
    tol = float(h_fix[-2]) * (1.0 + 1e-12)
    told = pc.cg_fused_tol(f, D=case.D, g=case.g, grid=case.grid, tol=tol,
                           max_iter=NITER, mask=case.mask, c=case.c,
                           interpret=True)
    it = int(told.iters)
    h_tol = np.asarray(told.rnorm_history)
    prefix = float(np.abs(h_tol[:it + 1] - h_fix[:it + 1]).max()
                   / h_fix[0])
    padded = bool(np.isnan(h_tol[it + 1:]).all())
    ok = 0 < it < NITER and prefix < RTOL and padded \
        and float(h_tol[it]) <= tol
    failures += not ok
    print(f"pcg_smoke_tol_prefix,0.0,iters={it};prefix_rel={prefix:.2e}"
          f";nan_padded={padded};{'OK' if ok else 'FAIL'}")
    if not ok:
        print(f"ERROR: tol-driven prefix check failed (iters {it}, "
              f"prefix {prefix:.2e}, padded {padded})", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
