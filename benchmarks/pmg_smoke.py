"""CI smoke for the p-multigrid preconditioner: fused-vs-reference parity
plus the iteration-count acceptance (DESIGN.md §13).

  JAX_ENABLE_X64=1 PYTHONPATH=src python -m benchmarks.pmg_smoke

Mirrors benchmarks/pcg_smoke.py.  Two checks:

* **Parity** — the fused V-cycle PCG driver (core/precond._pcg_pmg, all
  Pallas kernels in interpret mode) against reference PCG built on the
  XLA V-cycle (core/pmg.pmg_vcycle_reference) on a small case: the two
  cycles share the degree ladder, the smoothing intervals, and the exact
  base solve, so any miss isolates the kernels.
* **Acceptance** — on the paper E=1024/n=10 case, tolerance-driven
  pmg-PCG must reach rtol 1e-8 in at most half the iterations of
  Chebyshev(4)-PCG, and in at most :data:`PMG_MAX_ITERS_PAPER` (ISSUE 9;
  the V-cycle's stream surcharge has to buy at least a 2x count cut to
  be worth running).

Exits non-zero naming the offending check; prints one CSV-ish row per
check so the log doubles as an iteration-advantage record.
"""
from __future__ import annotations

import sys

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

RTOL = 1e-9
N, GRID, NITER = 5, (2, 2, 4), 10
PAPER_N, PAPER_GRID = 10, (8, 8, 16)
PMG_MAX_ITERS_PAPER = 15


def main() -> int:
    from repro.core import cg as cg_mod
    from repro.core import pmg
    from repro.core import precond as pc
    from repro.core.nekbone import NekboneCase

    failures = 0

    # --- parity: fused V-cycle PCG vs reference PCG ---------------------
    case = NekboneCase(n=N, grid=GRID, dtype=jnp.float64)
    _, f = case.manufactured()
    spec = case.precond_spec("pmg")
    M = pmg.pmg_vcycle_reference(spec, D=case.D, g=case.g, grid=case.grid,
                                 mask=case.mask, c=case.c)
    ref = cg_mod.cg_fixed_iters(case.ax_full, f, niter=NITER,
                                dot=case.dot(), precond=M)
    fused = pc.pcg_fused_v2_fixed_iters(
        f, D=case.D, g=case.g, grid=case.grid, niter=NITER, precond=spec,
        mask=case.mask, c=case.c, interpret=True)
    h_ref = np.asarray(ref.rnorm_history)
    h_fus = np.asarray(fused.rnorm_history)
    hist_rel = float(np.abs(h_fus - h_ref).max() / h_ref[0])
    x_scale = np.abs(np.asarray(ref.x)).max() + 1e-300
    x_rel = float(np.abs(np.asarray(fused.x)
                         - np.asarray(ref.x)).max() / x_scale)
    ok = hist_rel < RTOL and x_rel < RTOL
    failures += not ok
    print(f"pmg_smoke_parity,0.0,hist_rel={hist_rel:.2e}"
          f";x_rel={x_rel:.2e};ladder={'-'.join(map(str, spec.ns))}"
          f";{'OK' if ok else 'FAIL'}")
    if not ok:
        print(f"ERROR: fused V-cycle parity vs reference exceeded "
              f"{RTOL:g} (hist {hist_rel:.2e}, x {x_rel:.2e})",
              file=sys.stderr)

    # --- acceptance: paper case iteration counts ------------------------
    paper = NekboneCase(n=PAPER_N, grid=PAPER_GRID, dtype=jnp.float64)
    _, fp = paper.manufactured()
    r0 = float(jnp.sqrt(jnp.abs(jnp.sum(fp * paper.c * fp))))
    tol = 1e-8 * r0
    # cheb_sz=16 (one z-block): interpret-mode halo redundancy dominates
    # wall clock; the split only changes fp associations.
    kw = dict(D=paper.D, g=paper.g, grid=paper.grid, tol=tol, max_iter=60,
              mask=paper.mask, c=paper.c, interpret=True, cheb_sz=16)
    it_chb = int(pc.cg_fused_tol(fp, precond=paper.precond_spec("cheb4"),
                                 **kw).iters)
    res_pmg = pc.cg_fused_tol(fp, precond=paper.precond_spec("pmg"), **kw)
    it_pmg = int(res_pmg.iters)
    ok = (it_pmg <= it_chb // 2 and it_pmg <= PMG_MAX_ITERS_PAPER
          and float(res_pmg.rnorm) <= tol * 1.0001)
    failures += not ok
    print(f"pmg_smoke_iters_e1024,0.0,pmg={it_pmg};cheb4={it_chb}"
          f";bound={PMG_MAX_ITERS_PAPER};rtol=1e-8"
          f";{'OK' if ok else 'FAIL'}")
    if not ok:
        print(f"ERROR: pmg iteration acceptance failed: pmg={it_pmg}, "
              f"cheb4={it_chb}, need pmg <= min(cheb4//2, "
              f"{PMG_MAX_ITERS_PAPER})", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
