# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark driver: paper Fig. 2/3 (version ladder), Fig. 4 (measured
roofline), §III-A Eq. 1-2 (cost-model adherence).

  PYTHONPATH=src python -m benchmarks.run
"""
from __future__ import annotations

import sys


def main() -> None:
    from benchmarks import bench_ax_versions, bench_cost_model, bench_roofline

    print("name,us_per_call,derived")
    for mod, title in ((bench_ax_versions, "Fig2/3: Ax version ladder"),
                       (bench_roofline, "Fig4: measured roofline"),
                       (bench_cost_model, "Eq1-2: cost model")):
        print(f"# --- {title} ---", file=sys.stderr)
        for name, us, derived in mod.run():
            print(f"{name},{us:.1f},{derived}")


if __name__ == '__main__':
    main()
