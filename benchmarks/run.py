# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark driver: paper Fig. 2/3 (version ladder), Fig. 4 (measured
roofline), §III-A Eq. 1-2 (cost-model adherence).

  PYTHONPATH=src python -m benchmarks.run

Besides the CSV on stdout, the full result set is written as
``BENCH_<tag>.json`` (machine readable: rows + the stream-per-iteration
ladder + the per-precision bytes/DOF/iter table + us/call) under
``$REPRO_BENCH_DIR`` (default ``benchmarks/out``), with ``tag`` from
``$REPRO_BENCH_TAG`` (default ``local``) — CI uploads it as an artifact
and ``benchmarks/check_regression.py`` diffs it against the committed
``benchmarks/baseline/BENCH_baseline.json`` so the ladder cannot silently
regress.

The JSON is written atomically (tmp + rename): a crash mid-write can
never leave a corrupt ``BENCH_<tag>.json`` for the regression gate (or a
later run) to trip over, and an unwritable ``$REPRO_BENCH_DIR`` degrades
to a clear one-line error after the CSV instead of a traceback.
"""
from __future__ import annotations

import json
import os
import pathlib
import sys


def _bench_json_path() -> pathlib.Path:
    out_dir = pathlib.Path(os.environ.get("REPRO_BENCH_DIR",
                                          "benchmarks/out"))
    tag = os.environ.get("REPRO_BENCH_TAG", "local")
    return out_dir / f"BENCH_{tag}.json"


def write_json_atomic(path: pathlib.Path, payload: dict) -> bool:
    """Atomically (tmp + rename) write ``payload`` as JSON to ``path``.

    Returns False — after printing a clear one-line error to stderr —
    instead of raising when the directory is unwritable, the path is
    occupied by a directory, or any other OSError fires; the rename is
    atomic, so a stale ``BENCH_<tag>.json`` is either fully replaced or
    untouched, never half-written.
    """
    tmp = None
    try:
        path = pathlib.Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        tmp = path.with_name(path.name + f".tmp.{os.getpid()}")
        tmp.write_text(json.dumps(payload, indent=1))
        tmp.replace(path)
        return True
    except OSError as e:
        print(f"# ERROR: could not write bench json {path}: {e} "
              "(CSV above is complete; set $REPRO_BENCH_DIR to a writable "
              "directory to keep the machine-readable copy)",
              file=sys.stderr)
        if tmp is not None:
            try:
                tmp.unlink(missing_ok=True)
            except OSError:
                pass
        return False


def _precision_table() -> dict:
    """The ndof-independent bytes/DOF/iter table the regression gate holds.

    Every (pipeline rung, precision policy) point of DESIGN.md §6-7:
    stream counts are pipeline constants, the policy prices the bytes —
    bf16 is exactly half of f32 on every rung, which
    check_regression.py asserts.  Each entry carries both books: the
    headline ``read``/``write`` (side channels charged as zero, the §6
    convention) and the ``read_exact``/``write_exact`` column that folds
    in the modeled side channels (v2 boundary planes, v3 matrix-powers
    halo — ``cost.bytes_per_dof_iter(exact=True)`` at the paper's n=10
    with the default slab split).

    The ``<pipeline>_d8`` rows (schema v5, DESIGN.md §10) price the
    *sharded* pipelines at the 8-device strong-scaling point of the paper
    grid (EZ=32, ez_local=4): exact books only — ``read``/``write`` are
    ``bytes_per_dof_iter(exact=True, ndev=8, ez=32)``, the per-device
    collective channel folded in and split evenly — since a headline
    column that ignores the network would be meaningless for a
    distributed rung.  The bf16 == f32/2 invariant holds there too (every
    channel scales with the storage itemsize).
    """
    from repro.core import cost

    table = {}
    for pipeline in cost.PIPELINE_STREAMS:
        table[pipeline] = {}
        for pol in ("f64", "f32", "bf16"):
            rb, wb = cost.bytes_per_dof_iter(pipeline, pol)
            re_, we = cost.bytes_per_dof_iter(pipeline, pol, exact=True)
            table[pipeline][pol] = {"read": rb, "write": wb,
                                    "read_exact": round(re_, 4),
                                    "write_exact": round(we, 4)}
    for pipeline in ("fused_v2", "fused_v2_jacobi", "fused_v2_cheb",
                     "sstep_v3"):
        entry = {}
        for pol in ("f64", "f32", "bf16"):
            re_, we = cost.bytes_per_dof_iter(pipeline, pol, exact=True,
                                              ndev=8, ez=32)
            entry[pol] = {"read": round(re_, 4), "write": round(we, 4)}
        table[pipeline + "_d8"] = entry
    return table


def _streams_ladder() -> dict:
    """The Eq.-2 fusion ladder (reads+writes per DOF per CG iteration) —
    the cross-PR perf-trajectory headline the gate matches *exactly*.

    The s-step rung is amortized per iteration (4s+9 streams per s
    iterations, DESIGN.md §8); its s=1 point must stay exactly the v2
    number.  The PCG rungs (DESIGN.md §9) are per-iteration too: Jacobi is
    v2 + 1 (the fused diagonal stream), Chebyshev is v2 + 5 (the
    polynomial apply kernel) with the win booked in iteration count.  The
    ``*_sharded_d8`` rungs (DESIGN.md §10) are *effective* per-device
    streams of the z-sharded drivers at the 8-device strong-scaling point
    (EZ=32): headline + halo + the per-device collective channel.
    """
    from repro.core import cost

    return {
        "eq2": cost.CG_READ_STREAMS + cost.CG_WRITE_STREAMS,
        "fused_v1": (cost.FUSED_CG_READ_STREAMS
                     + cost.FUSED_CG_WRITE_STREAMS),
        "fused_v2": (cost.FUSED_V2_READ_STREAMS
                     + cost.FUSED_V2_WRITE_STREAMS),
        "sstep_v3": sum(cost.sstep_streams(cost.SSTEP_DEFAULT_S)),
        "sstep_v3_s1": sum(cost.sstep_streams(1)),
        "fused_v2_jacobi": (cost.JACOBI_V2_READ_STREAMS
                            + cost.JACOBI_V2_WRITE_STREAMS),
        "fused_v2_cheb": (cost.CHEB_V2_READ_STREAMS
                          + cost.CHEB_V2_WRITE_STREAMS),
        "sstep_v3_sharded_d8": cost.sstep_effective_streams(
            cost.SSTEP_DEFAULT_S, 4, ndev=8, ez=32),
        "fused_v2_jacobi_sharded_d8": (
            cost.JACOBI_V2_READ_STREAMS + cost.JACOBI_V2_WRITE_STREAMS
            + cost.v2_plane_collective_streams(10, 32 // 8)),
        "fused_v2_cheb_sharded_d8": cost.cheb_effective_streams(
            cost.CHEB_DEFAULT_K, 4, ndev=8, ez=32, n=10),
        # p-multigrid rung (schema v8, DESIGN.md §13): the full symmetric
        # V-cycle's per-iteration budget at the paper's n=10 ladder —
        # deliberately the most streams/iter of any rung; the win is the
        # iteration count (pcg_iters_tol rows).
        "fused_v2_pmg": sum(cost.pmg_streams(10)),
        # multi-RHS rungs (schema v7, DESIGN.md §12): per-RHS streams of
        # the batched block pipeline — the shared operator streams divide
        # by b, the per-RHS vector streams stay put.
        **{f"{base}_rhs{b}": cost.streams_per_rhs(b, base)
           for base in ("fused_v2", "sstep_v3")
           for b in cost.MULTI_RHS_BATCHES},
    }


def _streams_per_rhs_table() -> dict:
    """Per-RHS streams vs batch (schema v7, DESIGN.md §12) — the
    amortization curve check_regression.py holds exactly AND requires to
    be strictly decreasing in b on every pipeline (the whole point of the
    block solver: a bigger batch must never cost more per RHS)."""
    from repro.core import cost

    return {base: {str(b): cost.streams_per_rhs(b, base)
                   for b in (1,) + cost.MULTI_RHS_BATCHES}
            for base in ("fused_v2", "sstep_v3")}


def _solver_service_section(quick: bool) -> dict | None:
    """Latency/throughput rows from the solver-service bench (schema v7).

    Measured (wall-clock) — gated like the us/iter table: presence is
    checked when the baseline pins it, values are never hard-gated.  The
    quick profile keeps the interpret-mode CI leg to seconds.
    """
    from repro.launch.solver_service import bench_service

    try:
        if quick:
            return bench_service(nelt=64, n=4, requests=4, max_b=2,
                                 niter=3, repeats=1)
        return bench_service(nelt=64, requests=16, max_b=8, niter=25)
    except Exception as e:  # noqa: BLE001 — bench must not sink the run
        print(f"# WARNING: solver-service bench skipped: {e}",
              file=sys.stderr)
        return None


def _us_per_iter_table(sections: list) -> dict:
    """Measured wall-clock (us) of the per-iteration pipeline rungs.

    Extracted from the version-ladder section's ``*_iter_*`` rows — the
    fused v1/v2 iterations, the s-step cycles, and the PCG rungs — keyed
    by row name.  check_regression.py holds each entry within a relative
    band against the baseline *when the reference backend matches*
    (DESIGN.md §11): wall time is only comparable measured on the same
    backend kind, so the table travels with a ``reference_backend``
    record and cross-backend comparisons degrade to warnings.
    """
    table = {}
    for sec in sections:
        if not sec["module"].endswith("bench_ax_versions"):
            continue
        for row in sec["rows"]:
            if "_iter_" in row["name"] and row["us_per_call"] > 0.0:
                table[row["name"]] = row["us_per_call"]
    return table


def _reference_backend() -> str:
    import jax

    return jax.default_backend()


def _telemetry_section() -> dict | None:
    """Observability summary travelling with the bench (schema v9).

    The cost-model drift check (obs/drift.py) re-measured at bench time:
    per-pipeline measured-vs-book byte ratios and collective contracts.
    Summary-only (ok flag + per-row ratios) — the full report lives in
    the obs-smoke CI leg; here it stamps the bench JSON so a drifting
    model is visible next to the numbers it prices.  Never value-gated
    by check_regression.py, and never allowed to sink the bench run.
    """
    try:
        from repro.obs import drift

        report = drift.check()
        return {
            "drift": {
                "ok": report.ok,
                "rows": [{"pipeline": r.pipeline, "check": r.check,
                          "ok": r.ok, "ratio": r.ratio}
                         for r in report.rows],
            },
        }
    except Exception as e:  # noqa: BLE001 — telemetry must not sink the run
        print(f"# WARNING: telemetry section skipped: {e}", file=sys.stderr)
        return None


def main() -> None:
    from benchmarks import bench_ax_versions, bench_cost_model, bench_roofline
    from repro.obs import trace

    sections = []
    print("name,us_per_call,derived")
    # one env var away from a named profiler timeline (DESIGN.md §14):
    # $REPRO_PROFILE_DIR wraps the whole ladder in jax.profiler traces.
    with trace.profiling(os.environ.get("REPRO_PROFILE_DIR")):
        for mod, title in ((bench_ax_versions, "Fig2/3: Ax version ladder"),
                           (bench_roofline, "Fig4: measured roofline"),
                           (bench_cost_model, "Eq1-2: cost model")):
            print(f"# --- {title} ---", file=sys.stderr)
            rows = []
            for name, us, derived in mod.run():
                print(f"{name},{us:.1f},{derived}")
                rows.append({"name": name, "us_per_call": round(us, 1),
                             "derived": derived})
            sections.append({"title": title, "module": mod.__name__,
                             "rows": rows})

    quick = bool(os.environ.get("REPRO_BENCH_QUICK"))
    payload = {
        "schema": "repro-bench/9",
        # monotone int for forward-compat decisions (check_regression.py
        # warns on version skew instead of failing on unknown tables).
        # v5: sharded rungs — *_sharded_d8 ladder entries and the
        # <pipeline>_d8 per-device byte rows (DESIGN.md §10).
        # v6: measured-time rows — the us_per_iter table + the
        # reference_backend record it is only comparable under
        # (DESIGN.md §11); the gate holds each entry within a relative
        # band alongside the exact stream ladder.
        # v7: multi-RHS rungs — *_rhs{b} ladder entries + byte rows, the
        # streams_per_rhs amortization table (exact + strictly decreasing
        # in b), and the measured solver_service latency/throughput
        # section (DESIGN.md §12).
        # v8: p-multigrid rung — fused_v2_pmg ladder entry + byte rows
        # (headline and exact V-cycle books, DESIGN.md §13) and the
        # pcg_pmg_iter / extended pcg_iters_tol measured rows; baseline
        # refreshed for the new rows.
        # v9: observability — a full ``provenance`` record (machine tag,
        # python/jax versions, backend, x64 flag; DESIGN.md §14) that
        # check_regression.py uses to *explain* reference_backend
        # mismatches, and a ``telemetry`` section carrying the
        # cost-model drift summary (never value-gated).
        "schema_version": 9,
        "tag": os.environ.get("REPRO_BENCH_TAG", "local"),
        "quick": quick,
        "reference_backend": _reference_backend(),
        "provenance": trace.provenance(),
        "telemetry": _telemetry_section(),
        "streams_per_iter": _streams_ladder(),
        # the second axis of the ladder (DESIGN.md §7): bytes each stream
        # carries under each precision policy, per DOF per iteration.
        "bytes_per_dof_iter": _precision_table(),
        "streams_per_rhs": _streams_per_rhs_table(),
        "us_per_iter": _us_per_iter_table(sections),
        "solver_service": _solver_service_section(quick),
        "sections": sections,
    }
    path = _bench_json_path()
    if write_json_atomic(path, payload):
        print(f"# wrote {path}", file=sys.stderr)


if __name__ == '__main__':
    main()
