# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark driver: paper Fig. 2/3 (version ladder), Fig. 4 (measured
roofline), §III-A Eq. 1-2 (cost-model adherence).

  PYTHONPATH=src python -m benchmarks.run

Besides the CSV on stdout, the full result set is written as
``BENCH_<tag>.json`` (machine readable: rows + the stream-per-iteration
ladder + us/call) under ``$REPRO_BENCH_DIR`` (default ``benchmarks/out``),
with ``tag`` from ``$REPRO_BENCH_TAG`` (default ``local``) — CI uploads it
as an artifact so the perf trajectory is tracked across PRs.
"""
from __future__ import annotations

import json
import os
import pathlib
import sys


def _bench_json_path() -> pathlib.Path:
    out_dir = pathlib.Path(os.environ.get("REPRO_BENCH_DIR",
                                          "benchmarks/out"))
    tag = os.environ.get("REPRO_BENCH_TAG", "local")
    return out_dir / f"BENCH_{tag}.json"


def main() -> None:
    from benchmarks import bench_ax_versions, bench_cost_model, bench_roofline
    from repro.core import cost

    sections = []
    print("name,us_per_call,derived")
    for mod, title in ((bench_ax_versions, "Fig2/3: Ax version ladder"),
                       (bench_roofline, "Fig4: measured roofline"),
                       (bench_cost_model, "Eq1-2: cost model")):
        print(f"# --- {title} ---", file=sys.stderr)
        rows = []
        for name, us, derived in mod.run():
            print(f"{name},{us:.1f},{derived}")
            rows.append({"name": name, "us_per_call": round(us, 1),
                         "derived": derived})
        sections.append({"title": title, "module": mod.__name__,
                         "rows": rows})

    payload = {
        "schema": "repro-bench/1",
        "tag": os.environ.get("REPRO_BENCH_TAG", "local"),
        "quick": bool(os.environ.get("REPRO_BENCH_QUICK")),
        # the Eq.-2 fusion ladder this repo climbs (reads+writes per DOF
        # per CG iteration) — the cross-PR perf-trajectory headline.
        "streams_per_iter": {
            "eq2": cost.CG_READ_STREAMS + cost.CG_WRITE_STREAMS,
            "fused_v1": (cost.FUSED_CG_READ_STREAMS
                         + cost.FUSED_CG_WRITE_STREAMS),
            "fused_v2": (cost.FUSED_V2_READ_STREAMS
                         + cost.FUSED_V2_WRITE_STREAMS),
        },
        "sections": sections,
    }
    path = _bench_json_path()
    try:
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(payload, indent=1))
        print(f"# wrote {path}", file=sys.stderr)
    except OSError as e:                      # read-only checkout: CSV stands
        print(f"# could not write {path}: {e}", file=sys.stderr)


if __name__ == '__main__':
    main()
