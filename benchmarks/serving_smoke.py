"""Serving smoke: interpret-mode multi-RHS sweep + b=1 parity assert.

The CI ``serving-smoke`` leg (ci.yml): exercises the whole serving stack
end to end on CPU — the batched block kernels (interpret mode), the
driver registry, and the solver service's queue/bucket/dispatch path —
and asserts the two invariants that make the fast path trustworthy:

  * b=1 through ``cg_block_fixed_iters`` is fp64-BITWISE identical to
    the single-RHS v2 driver (the block kernels are the v2 arithmetic,
    not an approximation of it);
  * every lane of a b>1 batch matches its own independent single-RHS
    solve bitwise (lanes don't contaminate each other).

  JAX_ENABLE_X64=1 PYTHONPATH=src python -m benchmarks.serving_smoke
"""
from __future__ import annotations

import sys

import numpy as np
import jax.numpy as jnp


def main() -> int:
    from repro.configs.nekbone import NekboneConfig
    from repro.core.cg_block import cg_block_fixed_iters
    from repro.core.cg_fused import cg_fused_v2_fixed_iters
    from repro.launch.solver_service import SolveRequest, SolverService

    if not jnp.asarray(1.0, jnp.float64).dtype == jnp.float64:
        print("serving_smoke: needs JAX_ENABLE_X64=1 for the bitwise "
              "parity assert", file=sys.stderr)
        return 2

    cfg = NekboneConfig(name="smoke", n=5, grid=(2, 2, 4),
                        dtype="float64", ax_impl="pallas_fused_cg_v2")
    case = cfg.make_case()
    _, f = case.manufactured()
    niter = 12
    kw = dict(D=case.D, g=case.g, grid=case.grid, niter=niter,
              mask=case.mask, c=case.c)

    ref = cg_fused_v2_fixed_iters(f, **kw)
    rng = np.random.default_rng(0)

    for b in (1, 2, 4):
        lanes = [f] + [jnp.asarray(
            rng.standard_normal(f.shape)) * case.mask
            for _ in range(b - 1)]
        res = cg_block_fixed_iters(jnp.stack(lanes), **kw)
        # lane 0 is always the manufactured rhs: bitwise vs single-RHS v2.
        np.testing.assert_array_equal(np.asarray(res.x[0]),
                                      np.asarray(ref.x))
        np.testing.assert_array_equal(np.asarray(res.history[0]),
                                      np.asarray(ref.history))
        # every other lane matches its own independent solve bitwise.
        for j in range(1, b):
            solo = cg_fused_v2_fixed_iters(lanes[j], **kw)
            np.testing.assert_array_equal(np.asarray(res.x[j]),
                                          np.asarray(solo.x))
        print(f"serving_smoke: b={b} bitwise parity OK "
              f"(rnorm {[f'{float(r):.3e}' for r in res.rnorm]})")

    # service path: queue -> bucket -> batched dispatch, same answers.
    svc = SolverService(max_b=4)
    ids = [svc.submit(SolveRequest(f=f, config=cfg, niter=niter))
           for _ in range(3)]
    results = svc.drain()
    assert [r.request_id for r in results] == ids
    assert len(svc.dispatch_log) == 1, svc.dispatch_log
    for r in results:
        np.testing.assert_array_equal(np.asarray(r.x), np.asarray(ref.x))
    print(f"serving_smoke: service drained {len(results)} requests in "
          f"{len(svc.dispatch_log)} dispatch "
          f"(pipeline {results[0].pipeline}) — parity OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
