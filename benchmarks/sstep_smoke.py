"""CI smoke for the v3 s-step pipeline: interpret-mode parity, s-sweep.

  JAX_ENABLE_X64=1 PYTHONPATH=src python -m benchmarks.sstep_smoke

Runs the matrix-powers pipeline (core/cg_sstep.py) on a small paper-shaped
case for s in {1, 2, 4} and asserts fp64 parity against the reference
``cg_fixed_iters`` — the same gate the tier-1 tests pin, kept in the
quick-bench CI leg so the v3 rung cannot silently break between the test
matrix and the bench artifact.  Exits non-zero (with the offending s) on
any parity miss; prints one CSV-ish row per s so the log doubles as an
s-sweep record.
"""
from __future__ import annotations

import sys

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

# interpret-mode parity floor: fp64 round-off through the different
# partial-sum associations (same budget as tests/test_cg_sstep.py).
RTOL = 1e-9
N, GRID, NITER = 5, (2, 2, 4), 10
S_SWEEP = (1, 2, 4)


def main() -> int:
    from repro.core import cg as cg_mod
    from repro.core.cg_sstep import cg_sstep_fixed_iters
    from repro.core.cost import sstep_effective_streams, sstep_streams
    from repro.core.nekbone import NekboneCase

    case = NekboneCase(n=N, grid=GRID, dtype=jnp.float64)
    _, f = case.manufactured()
    ref = cg_mod.cg_fixed_iters(case.ax_full, f, niter=NITER,
                                dot=case.dot())
    h_ref = np.asarray(ref.rnorm_history)
    x_ref = np.asarray(ref.x)
    x_scale = np.abs(x_ref).max() + 1e-300

    failures = 0
    for s in S_SWEEP:
        res = cg_sstep_fixed_iters(f, D=case.D, g=case.g, grid=case.grid,
                                   niter=NITER, s=s, mask=case.mask,
                                   c=case.c, interpret=True)
        h = np.asarray(res.rnorm_history)
        hist_rel = float(np.abs(h - h_ref).max() / h_ref[0])
        x_rel = float(np.abs(np.asarray(res.x) - x_ref).max() / x_scale)
        ok = hist_rel < RTOL and x_rel < RTOL
        failures += not ok
        streams = sum(sstep_streams(s))
        print(f"sstep_smoke_s{s},0.0,hist_rel={hist_rel:.2e}"
              f";x_rel={x_rel:.2e};streams/iter={streams:g}"
              f";eff={sstep_effective_streams(s, 4):.2f}"
              f";{'OK' if ok else 'FAIL'}")
        if not ok:
            print(f"ERROR: s={s} parity vs cg_fixed_iters exceeded "
                  f"{RTOL:g} (hist {hist_rel:.2e}, x {x_rel:.2e})",
                  file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
