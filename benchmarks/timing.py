"""Bench-side re-export of the shared wall-clock helper.

The implementation lives in ``repro.kernels.timing`` so the autotune
sweeps and the benches share one measurement methodology (warmup-discard
+ median-of-reps, DESIGN.md §11); this module exists so bench code can
say ``from benchmarks.timing import measure`` without importing from the
kernel layer explicitly.
"""
from repro.kernels.timing import measure, median  # noqa: F401

__all__ = ["measure", "median"]
