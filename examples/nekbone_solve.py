"""End-to-end Nekbone driver (the paper's application, §V protocol).

Runs the full benchmark the paper measures: SEM Poisson on a box of
elements at polynomial degree 9, 100 CG iterations, sweeping the element
count, reporting achieved GFLOP/s against the paper's cost model — plus a
correctness solve against the manufactured solution and the beyond-paper
extras (Jacobi preconditioning, mixed-precision iterative refinement).

  PYTHONPATH=src python examples/nekbone_solve.py [--elements 128]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.nekbone import PAPER_CASES
from repro.core.cost import cg_iter_flops
from repro.core.nekbone import NekboneCase


def run_case(nelt: int, niter: int = 100):
    nb = PAPER_CASES[nelt]
    case = NekboneCase(n=nb.n, grid=nb.grid, dtype=jnp.float32,
                       ax_impl="fused")
    u_ex, f = case.manufactured()

    solve = jax.jit(lambda f: case.solve(f, niter=niter))
    res = solve(f)
    jax.block_until_ready(res.x)
    t0 = time.time()
    res = solve(f)
    jax.block_until_ready(res.x)
    dt = time.time() - t0

    flops = cg_iter_flops(case.mesh.ndof, case.n) * niter
    err = float(case.solution_error(res.x, u_ex))
    print(f"E={nelt:5d}  ndof={case.mesh.ndof:9d}  {niter} CG iters in "
          f"{dt:6.2f}s  -> {flops / dt / 1e9:6.2f} GF/s   max-err {err:.2e}")
    return case, f, u_ex


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--elements", type=int, default=128,
                    choices=sorted(PAPER_CASES))
    ap.add_argument("--iters", type=int, default=100)
    ap.add_argument("--sweep", action="store_true",
                    help="paper's element sweep (64..1024; slow on CPU)")
    args = ap.parse_args()

    print("== Nekbone (paper §V: degree 9, 100 CG iterations) ==")
    sweep = (64, 128, 256) if args.sweep else (args.elements,)
    for E in sweep:
        case, f, u_ex = run_case(E, args.iters)

    print("\n== fused CG iteration (Pallas pipelines, DESIGN.md §3) ==")
    # v1: one multi-output Pallas call per iteration (masked Ax + the p·c·Ap
    # partial; r·c·r carried through the loop state).  v2: the whole
    # iteration in two slab-resident kernels — in-kernel gather-scatter with
    # O(n^2) boundary-plane side channels, merged vector updates, structural
    # mask/weight, diagonal metric.  Interpret mode off-TPU: correctness,
    # not speed — compare residual histories against the XLA path.
    from repro.core.cost import (CG_READ_STREAMS, CG_WRITE_STREAMS,
                                 FUSED_CG_READ_STREAMS,
                                 FUSED_CG_WRITE_STREAMS,
                                 FUSED_V2_READ_STREAMS,
                                 FUSED_V2_WRITE_STREAMS,
                                 sstep_effective_streams, sstep_streams)

    small = NekboneCase(n=6, grid=(2, 2, 2), dtype=jnp.float32,
                        ax_impl="fused")
    res_x, _ = small.solve_manufactured(niter=10)
    v3 = sum(sstep_streams(4))
    print(f"streams/iter: {CG_READ_STREAMS}R+{CG_WRITE_STREAMS}W (Eq. 2) -> "
          f"{FUSED_CG_READ_STREAMS}R+{FUSED_CG_WRITE_STREAMS}W (fused v1) -> "
          f"{FUSED_V2_READ_STREAMS}R+{FUSED_V2_WRITE_STREAMS}W (fused v2) -> "
          f"{v3:g} (s-step v3 @ s=4; "
          f"{sstep_effective_streams(4, 4):.2f} eff w/ halo)")
    for impl in ("pallas_fused_cg", "pallas_fused_cg_v2"):
        small.ax_impl = impl
        res_f, _ = small.solve_manufactured(niter=10)
        drift = float(jnp.nanmax(jnp.abs(res_f.rnorm_history -
                                         res_x.rnorm_history) /
                                 jnp.abs(res_x.rnorm_history)))
        print(f"residual-history drift vs XLA CG over 10 iters "
              f"({impl}): {drift:.2e}")

    print("\n== beyond-paper: s-step CG (matrix-powers pipeline, "
          "DESIGN.md §8) ==")
    # one matrix-powers cycle evaluates the whole s-vector Krylov basis in
    # a single slab residency (metric/D/mask loaded once per s operator
    # applications) and the s recurrence steps solve in f64 on (2s+1)-
    # coefficient coordinates — one host round-trip per s iterations.
    for s in (1, 2, 4):
        small.ax_impl = "pallas_sstep_v3"
        small.s = s
        res_s, _ = small.solve_manufactured(niter=8)
        drift = float(jnp.nanmax(jnp.abs(
            res_s.rnorm_history - res_x.rnorm_history[:9]) /
            jnp.abs(res_x.rnorm_history[:9])))
        print(f"  s={s}: {sum(sstep_streams(s)):5.2f} streams/iter "
              f"(eff {sstep_effective_streams(s, 4):5.2f}), history drift "
              f"vs XLA CG over 8 iters: {drift:.2e}")

    print("\n== beyond-paper: preconditioning + solve-to-tolerance "
          "(DESIGN.md §9) ==")
    # The precond subsystem (core/precond.py) is wired through the config:
    # NekboneConfig(precond=...) -> make_case() -> case.solve(tol=...).
    # On the v2 fused pipeline the Jacobi apply is fused into the update
    # kernel (14 streams/iter, one more than plain v2) and the Chebyshev
    # polynomial evaluates in one halo'd slab residency per iteration (18
    # streams/iter); tolerance-driven solves run the same bodies under a
    # while_loop, so each trajectory prefixes its fixed-iteration twin.
    from repro.configs.nekbone import NekboneConfig
    from repro.core.cost import (CHEB_V2_READ_STREAMS,
                                 CHEB_V2_WRITE_STREAMS,
                                 JACOBI_V2_READ_STREAMS,
                                 JACOBI_V2_WRITE_STREAMS,
                                 cheb_effective_streams)

    pcg_cfg = NekboneConfig(name="pcg-demo", n=6, grid=(2, 2, 4),
                            dtype="float32", ax_impl="pallas_fused_cg_v2")
    for pc_name in (None, "jacobi", "cheb"):
        pcase = pcg_cfg.make_case(precond=pc_name)
        r, _ = pcase.solve_manufactured(tol=1e-5, max_iter=300)
        streams = {"jacobi": JACOBI_V2_READ_STREAMS
                   + JACOBI_V2_WRITE_STREAMS,
                   "cheb": CHEB_V2_READ_STREAMS
                   + CHEB_V2_WRITE_STREAMS}.get(
                       pc_name, FUSED_V2_READ_STREAMS
                       + FUSED_V2_WRITE_STREAMS)
        eff = (f" (eff {cheb_effective_streams(pcase.cheb_k, 4):.1f} "
               "w/ halo)" if pc_name == "cheb" else "")
        print(f"  {pc_name or 'plain':>6}: {int(r.iters):3d} iters to "
              f"tol @ {streams} streams/iter{eff}")
    # per-call override by registry name works on any ax_impl (the old
    # boolean spelling precond=True|False finished its deprecation cycle
    # and now raises TypeError):
    r_plain, _ = case.solve_manufactured(tol=1e-6, max_iter=500)
    r_pc, _ = case.solve_manufactured(tol=1e-6, max_iter=500,
                                      precond="jacobi")
    print(f"  reference path, iterations to 1e-6: "
          f"plain={int(r_plain.iters)} jacobi={int(r_pc.iters)}")

    print("\n== beyond-paper: mixed-precision fused CG (DESIGN.md §7) ==")
    # bf16 storage halves every stream of the 13-stream v2 pipeline; the
    # iterative-refinement outer loop (cg_ir_fixed_iters) recovers the
    # caller-precision residual floor from the bf16-priced inner solves.
    # (true fp64 outer residuals need JAX_ENABLE_X64=1; the structure is
    # identical in fp32, demonstrated here on a small case.)
    from repro.core.cg_fused import cg_ir_fixed_iters
    from repro.core.cost import bytes_per_dof_iter, ir_overhead_streams

    for pol in ("f64", "f32", "bf16"):
        rb, wb = bytes_per_dof_iter("fused_v2", pol)
        print(f"  fused_v2 bytes/DOF/iter {pol:>4}: {rb + wb:3d} "
              f"({rb}R + {wb}W)")
    print(f"  bf16_ir outer-pass surcharge: "
          f"+{ir_overhead_streams(20):.2f} bf16-streams/iter @ 20-iter sweeps")

    mp = NekboneCase(n=6, grid=(2, 2, 2), dtype=jnp.float32)
    _, fmp = mp.manufactured()
    ir = cg_ir_fixed_iters(fmp, D=mp.D, g=mp.g, grid=mp.grid, niter=20,
                           precision="bf16_ir", outer_iters=3)
    print("bf16_ir outer residual norms:",
          [f"{float(v):.2e}" for v in ir.rnorm_history],
          f"({int(ir.iters)} bf16-priced inner iterations)")


if __name__ == "__main__":
    main()
