"""Quickstart: the paper's operator in five minutes.

Builds a degree-9 spectral-element Poisson problem (the paper's setting),
applies the fused tensor-product operator through all three implementations
(Listing-1 reference, XLA-fused, Pallas TPU kernel in interpret mode),
verifies they agree, and solves the system with CG.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

import jax.numpy as jnp

from repro.core.nekbone import NekboneCase


def main():
    # Paper setup: polynomial degree 9 -> n = 10 GLL points, 64 elements.
    case = NekboneCase(n=10, grid=(4, 4, 4), dtype=jnp.float32)
    print(f"case: {case.mesh.nelt} elements, {case.mesh.ndof} local DOFs, "
          f"intensity I(n)={case.cost.intensity:.3f} flop/byte")

    rng = np.random.default_rng(0)
    u = jnp.asarray(rng.normal(size=(case.mesh.nelt, 10, 10, 10)),
                    jnp.float32)

    outs = {}
    for impl in ("listing1", "fused", "pallas"):
        case.ax_impl = impl
        outs[impl] = case.ax_local(u)
    for name, w in outs.items():
        err = float(jnp.abs(w - outs["fused"]).max())
        print(f"ax[{name:9s}]  max|diff vs fused| = {err:.2e}")

    case.ax_impl = "fused"
    res, u_exact = case.solve_manufactured(tol=1e-5, max_iter=300)
    print(f"CG: {int(res.iters)} iterations, residual {float(res.rnorm):.2e}, "
          f"solution max-error {float(case.solution_error(res.x, u_exact)):.2e}")


if __name__ == "__main__":
    main()
