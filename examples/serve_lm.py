"""Batched serving example across architecture families.

Prefills a batch of prompts and decodes greedily for three different
architecture families — a KV-cache transformer (qwen2.5), the attention-free
RWKV6 (O(1) recurrent cache: the ``long_500k`` story), and the hybrid Hymba
(attention ∥ SSM) — through the same serve_prefill/serve_step interface the
dry-run lowers at production shapes.

  PYTHONPATH=src python examples/serve_lm.py
"""
from repro.configs import get
from repro.launch.serve import serve


def main():
    for name in ("qwen2.5-14b", "rwkv6-1.6b", "hymba-1.5b"):
        cfg = get(name).reduced()
        tokens, stats = serve(cfg, batch=4, prompt_len=24, gen=12)
        print(f"{name:16s} generated {tokens.shape[1]} tokens/seq x "
              f"{tokens.shape[0]} seqs | prefill {stats['prefill_s']:.2f}s | "
              f"decode {stats['tok_per_s']:.1f} tok/s")


if __name__ == "__main__":
    main()
