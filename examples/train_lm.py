"""End-to-end LM training driver (~100M-param config, CPU-runnable demo).

Trains a trimmed qwen2.5-family model on the deterministic synthetic stream
with the full production loop: AdamW + cosine schedule, remat, gradient
accumulation, async atomic checkpointing, preemption handler, straggler
watchdog, and auto-resume.  Loss visibly drops within ~30 steps.

At full scale the same loop runs under ``launch/mesh.make_production_mesh``
with FSDP+TP shardings (exercised by the dry-run) — nothing here changes.

  PYTHONPATH=src python examples/train_lm.py --steps 30
  # kill it mid-run and re-run: it resumes from the last checkpoint.
"""
import argparse
import dataclasses

from repro.configs import get
from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--hundred-m", action="store_true",
                    help="use a ~100M-param config instead of the smoke "
                         "config (minutes per step on CPU)")
    args = ap.parse_args()

    cfg = get("qwen2.5-14b").reduced()
    if args.hundred_m:
        cfg = dataclasses.replace(
            cfg, n_layers=8, d_model=768, n_heads=12, n_kv_heads=4,
            head_dim=64, d_ff=2048, vocab=32768)   # ~0.1B params
    print(f"training {cfg.name} variant: ~{cfg.param_count()/1e6:.1f}M params")

    _, losses = train(cfg, steps=args.steps, batch=args.batch, seq=args.seq,
                      ckpt_dir=args.ckpt_dir, ckpt_every=10,
                      grad_accum=args.grad_accum, peak_lr=3e-3)
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f}")


if __name__ == "__main__":
    main()
