"""repro: Nekbone tensor-product operations on TPU (JAX + Pallas).

Reproduction + TPU adaptation of "Optimization of Tensor-product Operations
in Nekbone on GPUs" (Karp et al., 2020) with a production-grade multi-pod
training/serving substrate.  See DESIGN.md for the system map.

Top-level surface (lazy — importing ``repro`` stays dependency-free):

    import repro
    res = repro.solve(1024, niter=100)          # paper case, manufactured
    res = repro.solve(case, f, b=8, tol=1e-8)   # multi-RHS block solve

``repro.solve`` dispatches through the driver registry
(:mod:`repro.core.solvers`) and returns a
:class:`repro.core.cg.SolveResult`.
"""
__version__ = "1.0.0"

_LAZY = {
    "solve": ("repro.core.solvers", "solve"),
    "SolveResult": ("repro.core.cg", "SolveResult"),
}


def __getattr__(name):
    try:
        module, attr = _LAZY[name]
    except KeyError:
        raise AttributeError(
            f"module {__name__!r} has no attribute {name!r}") from None
    import importlib

    return getattr(importlib.import_module(module), attr)


def __dir__():
    return sorted([*globals(), *_LAZY])
