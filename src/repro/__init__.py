"""repro: Nekbone tensor-product operations on TPU (JAX + Pallas).

Reproduction + TPU adaptation of "Optimization of Tensor-product Operations
in Nekbone on GPUs" (Karp et al., 2020) with a production-grade multi-pod
training/serving substrate.  See DESIGN.md for the system map.
"""
__version__ = "1.0.0"
