"""Checkpoint substrate: async, atomic, elastic-restore."""
from repro.checkpoint.manager import CheckpointManager

__all__ = ["CheckpointManager"]
