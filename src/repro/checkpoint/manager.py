"""Fault-tolerant checkpointing (DESIGN.md §3).

Guarantees:
  * **Atomicity** — writes go to ``<dir>/tmp.<step>`` and are renamed to
    ``<dir>/step_<k>`` only after an fsync'd manifest; a crash mid-write
    never corrupts the latest checkpoint.
  * **Async** — ``save(..., blocking=False)`` snapshots device arrays to
    host then writes on a background thread; the train loop continues.
  * **Elastic restore** — arrays are saved unsharded (numpy) with the pytree
    structure in the manifest; ``restore`` re-shards onto whatever mesh the
    restarted job has (different device count included).
  * **Retention** — ``keep`` newest checkpoints are retained.
  * **Preemption** — ``install_sigterm_handler`` saves synchronously and
    exits cleanly on SIGTERM (the TPU-pod eviction signal).

On a real multi-host pod each host would write only its addressable shards
(process-local io); this container is single-process so arrays are gathered.
The manifest format already carries per-leaf sharding metadata to make that
switch local to ``_write_leaf``.
"""
from __future__ import annotations

import json
import pathlib
import shutil
import signal
import sys
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["CheckpointManager"]

_SEP = "/"


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    flat = {}
    for path, leaf in leaves:
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        flat[key] = leaf
    return flat, treedef


class CheckpointManager:
    def __init__(self, directory: str | pathlib.Path, *, keep: int = 3):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------------
    def _step_dirs(self) -> list[tuple[int, pathlib.Path]]:
        out = []
        for p in self.dir.glob("step_*"):
            try:
                out.append((int(p.name.split("_")[1]), p))
            except (IndexError, ValueError):
                continue
        return sorted(out)

    def latest_step(self) -> int | None:
        ds = self._step_dirs()
        return ds[-1][0] if ds else None

    # ------------------------------------------------------------------
    def save(self, step: int, tree, *, blocking: bool = True,
             extra_meta: dict | None = None):
        """Checkpoint ``tree`` at ``step``.  Async unless ``blocking``."""
        self.wait()                       # one in-flight save at a time
        flat, _ = _flatten(tree)
        # Snapshot to host memory first (cheap, device->host copy), so the
        # background writer never touches live device buffers.
        host = {k: np.asarray(v) for k, v in flat.items()}
        meta = {
            "step": step,
            "time": time.time(),
            "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                       for k, v in host.items()},
            "extra": extra_meta or {},
        }

        def write():
            tmp = self.dir / f"tmp.{step}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            np.savez(tmp / "arrays.npz", **host)
            with open(tmp / "manifest.json", "w") as f:
                json.dump(meta, f)
                f.flush()
            final = self.dir / f"step_{step}"
            if final.exists():
                shutil.rmtree(final)
            tmp.rename(final)
            self._gc()

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        ds = self._step_dirs()
        for _, p in ds[:-self.keep] if self.keep else []:
            shutil.rmtree(p, ignore_errors=True)

    # ------------------------------------------------------------------
    def restore(self, tree_like, step: int | None = None, *,
                shardings=None):
        """Restore into the structure of ``tree_like``.

        ``tree_like`` may be a pytree of arrays or ShapeDtypeStructs.
        ``shardings``: optional matching pytree of NamedShardings — arrays
        are placed (re-sharded) onto them, enabling elastic restarts on a
        different mesh.  Returns (step, tree).
        """
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        d = self.dir / f"step_{step}"
        data = np.load(d / "arrays.npz")
        flat_like, treedef = _flatten(tree_like)
        shard_flat = None
        if shardings is not None:
            shard_flat, _ = _flatten(shardings)
        out = {}
        for key, like in flat_like.items():
            arr = data[key]
            if tuple(arr.shape) != tuple(like.shape):
                raise ValueError(f"{key}: ckpt {arr.shape} != {like.shape}")
            if shard_flat is not None:
                out[key] = jax.device_put(arr, shard_flat[key])
            else:
                out[key] = jnp.asarray(arr, like.dtype)
        leaves = [out[k] for k in flat_like]
        return step, jax.tree_util.tree_unflatten(treedef, leaves)

    # ------------------------------------------------------------------
    def install_sigterm_handler(self, get_state, *, exit_code: int = 0):
        """On SIGTERM (preemption), save synchronously and exit."""

        def handler(signum, frame):
            step, tree = get_state()
            self.save(step, tree, blocking=True,
                      extra_meta={"preempted": True})
            sys.exit(exit_code)

        signal.signal(signal.SIGTERM, handler)
