"""Shims for the span of jax releases this repo runs on.

The reference container pins an older jax than the names some modules were
written against; everything version-sensitive funnels through here so call
sites stay clean.
"""
from __future__ import annotations

import jax

__all__ = ["shard_map", "make_mesh", "set_mesh", "axis_size",
           "CompilerParams"]

# Pallas-TPU compiler params: renamed TPUCompilerParams -> CompilerParams.
import jax.experimental.pallas.tpu as _pltpu

CompilerParams = getattr(_pltpu, "CompilerParams",
                         getattr(_pltpu, "TPUCompilerParams", None))


def axis_size(axis_name):
    """``jax.lax.axis_size``, or the classic ``psum(1, axis)`` before it."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)

try:
    shard_map = jax.shard_map
except AttributeError:                       # pre-0.6 spelling
    import functools

    from jax.experimental.shard_map import shard_map as _shard_map

    @functools.wraps(_shard_map)
    def shard_map(*args, **kwargs):
        if "check_vma" in kwargs:            # renamed from check_rep
            kwargs["check_rep"] = kwargs.pop("check_vma")
        return _shard_map(*args, **kwargs)


def set_mesh(mesh):
    """Context manager installing ``mesh`` as the ambient mesh.

    ``jax.set_mesh`` on new jax, ``jax.sharding.use_mesh`` on mid releases,
    and the ``Mesh`` object's own context manager (thread resources) before
    that.
    """
    sm = getattr(jax, "set_mesh", None)
    if sm is not None:
        return sm(mesh)
    um = getattr(jax.sharding, "use_mesh", None)
    if um is not None:
        return um(mesh)
    return mesh


def make_mesh(shape, names, *, auto: bool = True, devices=None):
    """``jax.make_mesh`` with Auto axis types where supported."""
    kw = {} if devices is None else {"devices": devices}
    if auto and hasattr(jax.sharding, "AxisType"):
        kw["axis_types"] = (jax.sharding.AxisType.Auto,) * len(names)
    return jax.make_mesh(shape, names, **kw)
