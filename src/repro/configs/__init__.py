"""Architecture registry: ``get(name)`` / ``ARCHS`` / per-shape input specs."""
from __future__ import annotations

from repro.configs.base import SHAPES, ArchConfig, ShapeCell

from repro.configs import (arctic_480b, codeqwen1_5_7b, gemma2_27b,
                           hymba_1_5b, llava_next_mistral_7b,
                           nemotron_4_340b, qwen2_5_14b, qwen3_moe_30b_a3b,
                           rwkv6_1_6b, whisper_large_v3)

ARCHS: dict[str, ArchConfig] = {
    c.CONFIG.name: c.CONFIG
    for c in (rwkv6_1_6b, gemma2_27b, codeqwen1_5_7b, nemotron_4_340b,
              qwen2_5_14b, llava_next_mistral_7b, whisper_large_v3,
              qwen3_moe_30b_a3b, arctic_480b, hymba_1_5b)
}


def get(name: str) -> ArchConfig:
    try:
        return ARCHS[name]
    except KeyError:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}") from None


def cells():
    """All (arch, shape) dry-run cells, with sub-quadratic rule applied.

    ``long_500k`` only runs for archs that are not pure full attention
    (DESIGN.md §4); pure-attention archs report the cell as 'skipped'.
    """
    out = []
    for name, cfg in ARCHS.items():
        for sname, cell in SHAPES.items():
            skipped = (sname == "long_500k" and cfg.is_pure_full_attention)
            out.append((name, sname, skipped))
    return out


__all__ = ["ARCHS", "get", "cells", "SHAPES", "ArchConfig", "ShapeCell"]
