"""Snowflake Arctic 480B — dense-MoE hybrid: 128-expert top-2 MoE with a
parallel dense residual MLP in every layer.

[hf:Snowflake/snowflake-arctic-base; hf]  35L d_model=7168 56H (GQA kv=8)
d_ff=4864 (both the dense residual and each expert), 128 experts top-2,
vocab=32000.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="arctic-480b",
    family="moe",
    block="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=4864,
    d_ff_expert=4864,
    n_experts=128,
    top_k=2,
    dense_residual=True,
    vocab=32000,
    # 4.7e11 params: bf16 storage + bf16 Adam moments (DESIGN.md §3).
    param_dtype="bfloat16",
    opt_moment_dtype="bfloat16",
    source="hf:Snowflake/snowflake-arctic-base",
)
