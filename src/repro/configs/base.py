"""Architecture configuration schema + input-shape cells.

Every assigned architecture is a frozen :class:`ArchConfig`; the four
assigned input-shape cells are :data:`SHAPES`.  ``reduced()`` derives the
small same-family variant used by the CPU smoke tests; the full configs are
only ever lowered via the dry-run (ShapeDtypeStruct, no allocation).
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["ArchConfig", "ShapeCell", "SHAPES", "GLOBAL_WINDOW"]

GLOBAL_WINDOW = 0            # sentinel in window patterns: full attention
_BIG_WINDOW = 1 << 30


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                # 0 -> d_model // n_heads
    block: str = "dense"             # dense | moe | rwkv | hymba
    # attention / block details
    act: str = "silu"
    gated: bool = True
    qkv_bias: bool = False
    qk_norm: bool = False
    attn_softcap: float | None = None
    logit_softcap: float | None = None
    rope_theta: float = 10000.0
    windows: tuple[int, ...] | None = None   # repeating pattern; 0 = global
    sandwich_norm: bool = False              # gemma2 pre+post norms
    norm: str = "rms"                        # rms | layernorm
    norm_eps: float = 1e-6
    pos_emb: str = "rope"                    # rope | learned
    scale_embed: bool = False                # gemma-style sqrt(d) embed scale
    tie_embeddings: bool = False
    # moe
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    dense_residual: bool = False             # arctic parallel dense FFN
    capacity_factor: float = 1.25
    # ssm / hybrid
    ssm_state: int = 0
    # encoder-decoder (whisper): n_layers = decoder layers
    enc_layers: int = 0
    audio_ctx: int = 0
    # vlm (llava): stub patch embeddings prepended to the text sequence
    img_tokens: int = 0
    # training / compute
    remat: bool = True
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    opt_moment_dtype: str = "float32"        # bf16 for the >100B archs
    use_kernels: bool = False                # Pallas paths (TPU / interpret)
    attn_impl: str = "chunked"               # naive | chunked | flash
    # provenance
    source: str = ""

    # ------------------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def layer_windows(self) -> np.ndarray:
        """Per-layer attention windows, (n_layers,) int32; global -> 2^30."""
        if self.windows is None:
            w = [GLOBAL_WINDOW] * self.n_layers
        else:
            w = [self.windows[i % len(self.windows)]
                 for i in range(self.n_layers)]
        return np.asarray([_BIG_WINDOW if x == GLOBAL_WINDOW else x
                           for x in w], np.int32)

    def window_pattern(self) -> tuple:
        """Static per-sublayer windows (None = global), length = the pattern
        period p, with p | n_layers.  The layer scan runs over n_layers/p
        *groups* whose body unrolls p sub-layers, so every attention call
        sees a **static** window and the banded block-skipping schedule can
        engage (models/attention.py)."""
        if self.windows is None:
            return (None,)
        p = len(self.windows)
        if self.n_layers % p:
            raise ValueError(f"window pattern period {p} must divide "
                             f"n_layers={self.n_layers}")
        return tuple(None if w == GLOBAL_WINDOW else int(w)
                     for w in self.windows)

    @property
    def is_pure_full_attention(self) -> bool:
        """True when every token-mixing layer is unwindowed softmax attention
        (these archs skip the ``long_500k`` cell; DESIGN.md §4)."""
        if self.block in ("rwkv",):
            return False
        if self.block == "hymba":
            return False
        lw = self.layer_windows()
        return bool((lw >= _BIG_WINDOW).all())

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Analytic parameter count N (for MODEL_FLOPS = 6*N*D)."""
        d, hd = self.d_model, self.hd
        n_attn = d * (self.n_heads * hd) * 2 + d * (self.n_kv_heads * hd) * 2
        mlp_mats = 3 if self.gated else 2
        n_mlp = mlp_mats * d * self.d_ff
        n_layer = 0
        if self.block == "rwkv":
            n_layer = 5 * d * d + d * (5 * 32) + 5 * 32 * d + d * 64 + 64 * d \
                + 2 * d * self.d_ff + d * d
        elif self.block == "moe":
            n_exp = mlp_mats * d * self.d_ff_expert * self.n_experts
            n_layer = n_attn + n_exp + d * self.n_experts
            if self.dense_residual:
                n_layer += n_mlp
        elif self.block == "hymba":
            di = 2 * d
            n_ssm = d * 2 * di + di * (max(1, d // 16) + 2 * self.ssm_state) \
                + max(1, d // 16) * di + di * d
            n_layer = n_attn + n_ssm + n_mlp
        else:
            n_layer = n_attn + n_mlp
        total = self.n_layers * n_layer
        if self.enc_layers:
            total += self.enc_layers * (n_attn + n_mlp)      # encoder stack
            total += self.n_layers * n_attn                   # cross-attn
        total += self.vocab * d * (1 if self.tie_embeddings else 2)
        return int(total)

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top-k experts count)."""
        if self.block != "moe":
            return self.param_count()
        mlp_mats = 3 if self.gated else 2
        per_exp = mlp_mats * self.d_model * self.d_ff_expert
        inactive = self.n_layers * per_exp * (self.n_experts - self.top_k)
        return int(self.param_count() - inactive)

    # ------------------------------------------------------------------
    def reduced(self) -> "ArchConfig":
        """Small same-family config for CPU smoke tests."""
        def shrink_heads(h):
            return max(1, min(h, 4))

        kv = shrink_heads(self.n_kv_heads)
        heads = max(kv * max(1, min(self.n_heads // max(self.n_kv_heads, 1), 2)), kv)
        return dataclasses.replace(
            self,
            n_layers=2,
            enc_layers=2 if self.enc_layers else 0,
            d_model=64,
            n_heads=heads,
            n_kv_heads=kv,
            head_dim=16,
            d_ff=128,
            d_ff_expert=32 if self.d_ff_expert else 0,
            n_experts=8 if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            capacity_factor=8.0,
            vocab=512,
            audio_ctx=24 if self.audio_ctx else 0,
            img_tokens=8 if self.img_tokens else 0,
            # keep a period-2 pattern (one windowed + one global layer) so
            # both attention schedules stay covered by the smoke tests
            windows=tuple(min(w, 16) if w else 0 for w in self.windows[:2])
            if self.windows else None,
            param_dtype="float32",
            compute_dtype="float32",
            remat=False,
        )


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}
