"""CodeQwen1.5 7B — qwen1.5 architecture (MHA, QKV bias).

[hf:Qwen/CodeQwen1.5-7B; hf]  32L d_model=4096 32H (GQA kv=32 = MHA)
d_ff=13440 vocab=92416.  SiLU-gated MLP, RoPE theta 1e6 (64k context).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="codeqwen1.5-7b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    head_dim=128,
    d_ff=13440,
    vocab=92416,
    qkv_bias=True,
    rope_theta=1e6,
    source="hf:Qwen/CodeQwen1.5-7B",
)
