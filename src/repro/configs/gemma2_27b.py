"""Gemma 2 27B — local+global alternating attention, logit soft-capping.

[arXiv:2408.00118; hf]  46L d_model=4608 32H (GQA kv=16) d_ff=36864
vocab=256000.  Sliding window 4096 on alternating layers, attention softcap
50, final-logit softcap 30, sandwich (pre+post) norms, tied embeddings,
sqrt(d) embedding scale, gelu-gated MLP.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-27b",
    family="dense",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=36864,
    vocab=256000,
    act="gelu",
    gated=True,
    windows=(4096, 0),
    attn_softcap=50.0,
    logit_softcap=30.0,
    sandwich_norm=True,
    scale_embed=True,
    tie_embeddings=True,
    source="arXiv:2408.00118",
)
