"""Hymba 1.5B — hybrid: attention heads and Mamba heads in parallel.

[arXiv:2411.13676; hf]  32L d_model=1600 25H (GQA kv=5, head_dim 64)
d_ff=5504 vocab=32001 ssm_state=16.  Most layers use sliding-window
attention (1024); layers {0, 16, 31} are global — pattern below.  The SSM
path runs in parallel with attention in every block, outputs mean-combined
after per-path normalization.
"""
from repro.configs.base import ArchConfig

_WINDOWS = tuple(0 if i in (0, 16, 31) else 1024 for i in range(32))

CONFIG = ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    block="hymba",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab=32001,
    ssm_state=16,
    windows=_WINDOWS,
    source="arXiv:2411.13676",
)
