"""LLaVA-NeXT (Mistral-7B backbone) — VLM with anyres tiling stub.

[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]  Backbone: 32L
d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000.  Per the assignment the
vision frontend is a STUB: ``input_specs()`` provides precomputed patch
embeddings (anyres: base 576 + 4 tiles x 576 = 2880 tokens) prepended to the
text sequence.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=32000,
    rope_theta=1e6,
    img_tokens=2880,
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
)
