"""Nekbone case configurations (the paper's own experiment grid).

Paper §V: polynomial degree 9 (n = 10 GLL points), 64 - 4096 elements per
GPU, 100 CG iterations.  Element grids are chosen to keep the box roughly
cubic, matching Nekbone's ``data.rea`` defaults.
"""
from __future__ import annotations

import dataclasses

__all__ = ["NekboneConfig", "PAPER_CASES", "paper_case"]


@dataclasses.dataclass(frozen=True)
class NekboneConfig:
    name: str
    n: int                               # GLL points per direction (= p + 1)
    grid: tuple[int, int, int]           # element grid (per device)
    niter: int = 100                     # paper: 100 CG iterations
    dtype: str = "float32"               # TPU target; fp64 on CPU oracle
    # "auto" resolves to the measured-fastest fused CG pipeline for the
    # case shape (kernels/autotune.pick_pipeline; E-threshold heuristic
    # off-TPU) — see NekboneCase.ax_impl for the full value list.
    ax_impl: str = "pallas"
    # Fused-pipeline precision policy (DESIGN.md §7, core/precision.py):
    # "f64" | "f32" | "bf16" | "bf16_ir" | "f32_ir", or None to leave the
    # solver dtype entirely to ``dtype`` (pre-policy behaviour — a
    # non-refined policy would otherwise *override* ``dtype`` with its
    # storage dtype).  "bf16_ir" is the mixed-precision target (bf16
    # storage streams, f32 accumulation, iterative-refinement outer loop).
    precision: str | None = None
    # s-step cycle length for ax_impl="pallas_sstep_v3" (DESIGN.md §8):
    # iterations per matrix-powers cycle.  s=1 reproduces the v2 stream
    # budget exactly; s=4 is the tuned default (6.25 streams/iter, <= 9
    # effective with the halo side channel).  Ignored by other ax_impls.
    s: int = 4
    # Preconditioner (DESIGN.md §9 and §13, core/precond.py): None (the
    # paper's unpreconditioned protocol), "jacobi" (diagonal — fused into
    # the v2 pipeline at 14 streams/iter), "cheb" (Chebyshev polynomial
    # of order ``cheb_k`` — 18 streams/iter, condition-number-driven
    # iteration reduction), or "pmg" / "pmg[cheb<k>]" (p-multigrid
    # V-cycle with fused Chebyshev smoothers, core/pmg.py — the highest
    # streams/iter and by far the fewest iterations; §13.4 books).  The
    # v2 fused pipeline dispatches to the fused PCG drivers; every other
    # ax_impl applies the reference (XLA) preconditioner through
    # core/cg.py.
    precond: str | None = None
    cheb_k: int = 4
    # Default RHS batch (DESIGN.md §12): b > 1 routes unpreconditioned
    # v2-family solves through the multi-RHS block kernels
    # (core/cg_block.py), amortizing the shared operator streams over the
    # batch (core/cost.multi_rhs_streams).  The solver service buckets
    # requests by (grid, n, precision, precond) and solves them at b up
    # to this value per dispatch.
    b: int = 1

    @property
    def nelt(self) -> int:
        ex, ey, ez = self.grid
        return ex * ey * ez

    @property
    def ndof(self) -> int:
        return self.nelt * self.n ** 3

    def make_case(self, **overrides):
        """Instantiate the runnable :class:`repro.core.nekbone.NekboneCase`
        for this configuration (keyword overrides win)."""
        from repro.core.nekbone import NekboneCase

        kwargs = dict(n=self.n, grid=self.grid,
                      dtype=jnp_dtype(self.dtype), ax_impl=self.ax_impl,
                      precision=self.precision, s=self.s,
                      precond=self.precond, cheb_k=self.cheb_k, b=self.b)
        kwargs.update(overrides)
        return NekboneCase(**kwargs)


def jnp_dtype(name: str):
    import jax.numpy as jnp

    return jnp.dtype(name)


def _case(nelt: int, grid) -> NekboneConfig:
    return NekboneConfig(name=f"nekbone-e{nelt}", n=10, grid=grid)


# Element counts from the paper's sweep (Fig. 2/3), degree 9.
PAPER_CASES = {
    64: _case(64, (4, 4, 4)),
    128: _case(128, (4, 4, 8)),
    256: _case(256, (4, 8, 8)),
    512: _case(512, (8, 8, 8)),
    1024: _case(1024, (8, 8, 16)),
    2048: _case(2048, (8, 16, 16)),
    3584: _case(3584, (16, 16, 14)),     # Kebnekaise point (448*8)
    4096: _case(4096, (16, 16, 16)),
}


def paper_case(nelt: int = 1024, precision: str | None = None,
               precond: str | None = None) -> NekboneConfig:
    """A paper-grid case, optionally re-priced under a precision policy
    and/or preconditioned (DESIGN.md §9 — the beyond-paper PCG workload)."""
    cfg = PAPER_CASES[nelt]
    if precision != cfg.precision:
        cfg = dataclasses.replace(cfg, precision=precision)
    if precond != cfg.precond:
        cfg = dataclasses.replace(cfg, precond=precond)
    return cfg
