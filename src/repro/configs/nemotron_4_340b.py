"""Nemotron-4 340B — GQA + squared-ReLU MLP, the largest assigned arch.

[arXiv:2402.16819; unverified]  96L d_model=18432 96H (GQA kv=8)
d_ff=73728 vocab=256000.  Non-gated squared-ReLU MLP, LayerNorm,
head_dim = 192.  FSDP spans pod+data for this arch (3.4e11 params).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="nemotron-4-340b",
    family="dense",
    n_layers=96,
    d_model=18432,
    n_heads=96,
    n_kv_heads=8,
    head_dim=192,
    d_ff=73728,
    vocab=256000,
    act="relu2",
    gated=False,
    norm="layernorm",
    # 3.4e11 params: bf16 storage + bf16 Adam moments + pod-spanning FSDP
    # keep the per-chip footprint inside 16 GB HBM (DESIGN.md §3).
    param_dtype="bfloat16",
    opt_moment_dtype="bfloat16",
    source="arXiv:2402.16819",
)
