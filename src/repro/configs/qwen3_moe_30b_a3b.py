"""Qwen3-30B-A3B — 128-expert top-8 MoE with qk-norm.

[hf:Qwen/Qwen3-30B-A3B; hf]  48L d_model=2048 32H (GQA kv=4, head_dim 128)
expert d_ff=768, vocab=151936, 128 experts top-8, no shared expert.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    block="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    head_dim=128,
    d_ff=768,
    d_ff_expert=768,
    n_experts=128,
    top_k=8,
    vocab=151936,
    qk_norm=True,
    rope_theta=1e6,
    source="hf:Qwen/Qwen3-30B-A3B",
)
