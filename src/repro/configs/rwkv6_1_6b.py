"""RWKV6 "Finch" 1.6B — attention-free, data-dependent decay.

[arXiv:2404.05892; unverified]  24L d_model=2048 d_ff=7168 vocab=65536.
Head size 64 (RWKV convention) -> 32 heads.  The WKV recurrence is the most
direct beneficiary of the paper's streaming optimization (DESIGN.md §4).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-1.6b",
    family="ssm",
    block="rwkv",
    n_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=7168,
    vocab=65536,
    pos_emb="none",
    gated=False,
    tie_embeddings=False,
    source="arXiv:2404.05892",
)
