"""ShapeDtypeStruct input stand-ins + PartitionSpecs per (arch x shape) cell.

Used by the dry-run (lower/compile with no allocation) and by the launchers.
``kind``:
  * train   — ``loss/train_step`` inputs: token batch (+ modality stubs)
  * prefill — ``serve_prefill`` inputs: full prompt (+ modality stubs)
  * decode  — ``serve_step`` inputs: one token + KV/recurrent cache of
              ``seq_len`` (the cache is an *input*, per the assignment:
              "one new token with a KV cache of seq_len")
``long_500k`` (batch 1) marks the cache context-parallel: the cache sequence
axis is sharded over the ``data`` axis (DESIGN.md §3).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import jax.tree_util as jtu
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeCell
from repro.distributed.sharding import RULES

__all__ = ["input_specs", "cache_specs", "extra_specs"]


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _div(mesh, dim, axes):
    if axes is None or mesh is None:
        return None
    ax = (axes,) if isinstance(axes, str) else tuple(axes)
    sz = 1
    for a in ax:
        sz *= mesh.shape[a] if a in mesh.axis_names else 1
    return axes if (sz > 1 and dim % sz == 0) else None


def extra_specs(cfg: ArchConfig, batch: int):
    """Modality-stub inputs (precomputed embeddings), or None."""
    cdt = jnp.dtype(cfg.compute_dtype)
    if cfg.img_tokens:
        return {"img_embeds": _sds((batch, cfg.img_tokens, cfg.d_model), cdt)}
    if cfg.enc_layers:
        return {"audio_embeds": _sds((batch, cfg.audio_ctx, cfg.d_model), cdt)}
    return None


def _extra_pspecs(extra, mesh):
    if extra is None:
        return None
    return {k: P(_div(mesh, v.shape[0], RULES.dp), None, None)
            for k, v in extra.items()}


def cache_specs(cfg: ArchConfig, cache_tree, mesh, *, context_parallel: bool):
    """PartitionSpec tree for a stacked (leading-L) decode cache."""

    def spec_for(path, leaf):
        name = getattr(path[-1], "key", str(path[-1]))
        shape = leaf.shape            # (L, B, ...)
        B = shape[1]
        dp = _div(mesh, B, RULES.dp)
        if name in ("k", "v"):
            Hkv, S = shape[2], shape[3]
            if context_parallel:
                return P(None, None, _div(mesh, Hkv, RULES.tp),
                         _div(mesh, S, RULES.seq), None)
            tp_h = _div(mesh, Hkv, RULES.tp)
            if tp_h is None:          # kv heads < TP degree: shard sequence
                return P(None, dp, None, _div(mesh, S, RULES.tp), None)
            return P(None, dp, tp_h, None, None)
        if name in ("xk", "xv"):
            return P(None, dp, _div(mesh, shape[2], RULES.tp), None, None)
        if name == "state":           # rwkv (L, B, H, hd, hd)
            return P(None, dp, _div(mesh, shape[2], RULES.tp), None, None)
        if name in ("tm_x", "cm_x"):
            return P(None, dp, None, None)
        if name == "conv":            # (L, B, K-1, di)
            return P(None, dp, None, _div(mesh, shape[3], RULES.tp))
        if name == "h":               # (L, B, di, n)
            return P(None, dp, _div(mesh, shape[2], RULES.tp), None)
        return P(*([None] * len(shape)))

    return jtu.tree_map_with_path(spec_for, cache_tree)


def input_specs(cfg: ArchConfig, cell: ShapeCell, mesh=None):
    """Returns (avals_kwargs, pspecs_kwargs) for the cell's step function.

    Keys mirror the step-function signatures in ``launch/steps.py``.
    """
    B, S = cell.global_batch, cell.seq_len
    dp = _div(mesh, B, RULES.dp)

    if cell.kind == "train":
        text = S - (cfg.img_tokens or 0)
        batch = {"tokens": _sds((B, text + 1), jnp.int32)}
        bspec = {"tokens": P(dp, None)}
        extra = extra_specs(cfg, B)
        return ({"batch": batch, "extra": extra},
                {"batch": bspec, "extra": _extra_pspecs(extra, mesh)})

    if cell.kind == "prefill":
        text = S - (cfg.img_tokens or 0)
        tokens = _sds((B, text), jnp.int32)
        extra = extra_specs(cfg, B)
        return ({"tokens": tokens, "extra": extra},
                {"tokens": P(dp, None), "extra": _extra_pspecs(extra, mesh)})

    if cell.kind == "decode":
        from repro.models import model as Mdl

        cp = cell.name == "long_500k"
        cache = jax.eval_shape(
            lambda: Mdl.init_cache(cfg, B, S, context_parallel=cp))
        cspec = cache_specs(cfg, cache, mesh, context_parallel=cp)
        tokens = _sds((B, 1), jnp.int32)
        index = _sds((), jnp.int32)
        return ({"tokens": tokens, "cache": cache, "index": index},
                {"tokens": P(dp, None), "cache": cspec, "index": P()})

    raise ValueError(cell.kind)
