"""Whisper large-v3 — encoder-decoder with conv frontend (stub).

[arXiv:2212.04356; unverified]  32 encoder + 32 decoder layers,
d_model=1280 20H (MHA kv=20) d_ff=5120 vocab=51866, learned positions,
LayerNorm, GELU.  The mel/conv frontend is a STUB per the assignment:
``input_specs()`` provides precomputed frame embeddings (B, 1500, d).
Decode cells exercise the decoder self-attention cache + cross-attention.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-large-v3",
    family="audio",
    n_layers=32,
    enc_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    head_dim=64,
    d_ff=5120,
    vocab=51866,
    act="gelu",
    gated=False,
    norm="layernorm",
    pos_emb="learned",
    audio_ctx=1500,
    source="arXiv:2212.04356",
)
