"""Core Nekbone components: SEM operators, gather-scatter, CG, cost model.

Submodules: ``sem``, ``geom``, ``ax``, ``gs``, ``cg``, ``cost``, ``nekbone``.
Note: functions whose names collide with submodule names (e.g. ``cg``) are
not re-exported at package level — import them from their module.
"""
from repro.core import ax, cg, cost, geom, gs, nekbone, sem  # noqa: F401
from repro.core.cost import CostModel
from repro.core.geom import BoxMesh
from repro.core.nekbone import NekboneCase
from repro.core.sem import SEMOperators, derivative_matrix, gll_points_weights

__all__ = [
    "ax", "cg", "cost", "geom", "gs", "nekbone", "sem",
    "CostModel", "BoxMesh", "NekboneCase",
    "SEMOperators", "derivative_matrix", "gll_points_weights",
]
