"""Local spectral-element Poisson operator ``w = D^T (G (D u))`` per element.

Three implementations, mirroring the paper's version ladder:

* :func:`ax_local_listing1` — faithful transcription of the paper's Listing 1
  (the *original* Nekbone GPU version): two passes with ``ur/us/ut``
  materialized between them.  This is the paper-faithful baseline.
* :func:`ax_local_fused` — single fused expression; XLA is free to fuse the
  element-wise geometry application with the contractions (the analog of the
  *shared-memory* version: less HBM traffic, still compiler-scheduled).
* ``kernels/nekbone_ax.py`` (via :func:`ax_local`) — the Pallas kernel: the
  paper's optimized 2-D-thread-structure kernel re-derived for TPU (whole
  element block resident in VMEM, both stages fused, single HBM round-trip).

Layout: ``u[e, k, j, i]`` with ``i`` <-> x <-> the paper's ``r`` direction.
``D[a, b] = dl_b/dx(x_a)`` so an x-derivative contracts ``u``'s last axis with
``D``'s second axis.  ``g[e, m, k, j, i]`` with m in (rr, rs, rt, ss, st, tt).
"""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["ax_local_listing1", "ax_local_fused", "local_grad3", "local_grad3_t",
           "apply_metric", "ax_local"]


def local_grad3(u: jnp.ndarray, D: jnp.ndarray):
    """Reference-space gradient: returns (wr, ws, wt), each like ``u``.

    wr[e,k,j,i] = sum_l D[i,l] u[e,k,j,l]   (x / r direction)
    ws[e,k,j,i] = sum_l D[j,l] u[e,k,l,i]   (y / s direction)
    wt[e,k,j,i] = sum_l D[k,l] u[e,l,j,i]   (z / t direction)
    """
    wr = jnp.einsum("il,ekjl->ekji", D, u)
    ws = jnp.einsum("jl,ekli->ekji", D, u)
    wt = jnp.einsum("kl,elji->ekji", D, u)
    return wr, ws, wt


def local_grad3_t(ur: jnp.ndarray, us: jnp.ndarray, ut: jnp.ndarray,
                  D: jnp.ndarray) -> jnp.ndarray:
    """Transposed gradient (assembly of weak-form contributions).

    w[e,k,j,i] = sum_l D[l,i] ur[e,k,j,l] + D[l,j] us[e,k,l,i]
                 + D[l,k] ut[e,l,j,i]
    """
    w = jnp.einsum("li,ekjl->ekji", D, ur)
    w += jnp.einsum("lj,ekli->ekji", D, us)
    w += jnp.einsum("lk,elji->ekji", D, ut)
    return w


def apply_metric(wr, ws, wt, g):
    """Apply the 6-entry symmetric metric: (ur, us, ut) = G @ (wr, ws, wt)."""
    grr, grs, grt, gss, gst, gtt = (g[:, m] for m in range(6))
    ur = grr * wr + grs * ws + grt * wt
    us = grs * wr + gss * ws + gst * wt
    ut = grt * wr + gst * ws + gtt * wt
    return ur, us, ut


def ax_local_listing1(u: jnp.ndarray, D: jnp.ndarray,
                      g: jnp.ndarray) -> jnp.ndarray:
    """Paper Listing 1: two explicit passes with materialized intermediates.

    Pass 1 computes and *stores* ``ur, us, ut`` (in the original CUDA version
    these round-trip through global memory); pass 2 re-reads them for the
    transposed contraction.  Kept un-fused on purpose via
    ``jax.lax.optimization_barrier`` so benchmarks see the original version's
    memory traffic.
    """
    import jax

    wr, ws, wt = local_grad3(u, D)
    ur, us, ut = apply_metric(wr, ws, wt, g)
    # Force materialization between the two passes (global-memory round trip
    # in the original implementation).
    ur, us, ut = jax.lax.optimization_barrier((ur, us, ut))
    return local_grad3_t(ur, us, ut, D)


def ax_local_fused(u: jnp.ndarray, D: jnp.ndarray, g: jnp.ndarray) -> jnp.ndarray:
    """Single fused expression; XLA fuses geometry with the contractions."""
    wr, ws, wt = local_grad3(u, D)
    ur, us, ut = apply_metric(wr, ws, wt, g)
    return local_grad3_t(ur, us, ut, D)


def ax_local(u: jnp.ndarray, D: jnp.ndarray, g: jnp.ndarray, *,
             impl: str = "fused", **kw) -> jnp.ndarray:
    """Dispatch between implementations (``listing1`` | ``fused`` |
    ``pallas`` | ``pallas_fused_cg`` | ``pallas_fused_cg_v2``).

    The ``pallas_fused_cg*`` names select the step-fused CG pipelines
    (core/cg_fused.py); their *local operator* is the same Pallas kernel
    math, so standalone ``ax`` applications route to it here and only the
    solve loop differs.
    """
    if impl == "listing1":
        return ax_local_listing1(u, D, g)
    if impl == "fused":
        return ax_local_fused(u, D, g)
    if impl in ("pallas", "pallas_fused_cg", "pallas_fused_cg_v2",
                "pallas_sstep_v3"):
        from repro.kernels import ops as kernel_ops

        return kernel_ops.nekbone_ax(u, D, g, **kw)
    raise ValueError(f"unknown ax impl: {impl!r}")
