"""Conjugate-gradient solvers, matching Nekbone's CG structure.

Nekbone stores vectors element-wise *duplicated* (each shared node appears in
every touching element); inner products therefore use a weight ``c`` equal to
``mask / multiplicity`` so each unique DOF is counted once.  The operator
``A`` is matrix-free: local tensor-product, gather-scatter, boundary mask.

Provided solvers:
  * :func:`cg` — tolerance-driven, ``lax.while_loop`` (jit-able).
  * :func:`cg_fixed_iters` — fixed iteration count (`Nekbone runs 100`),
    ``lax.fori_loop``; returns the residual-norm history for benchmarking.
  * :func:`ir_solve` — mixed-precision iterative refinement: high-precision
    residual, low-precision inner CG (beyond-paper: recovers fp64-grade
    residuals on hardware whose fast path is fp32/bf16 — the TPU story).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["CGResult", "SolveResult", "cg", "cg_fixed_iters", "ir_solve",
           "weighted_dot", "jacobi_preconditioner"]


class CGResult(NamedTuple):
    x: jnp.ndarray
    iters: jnp.ndarray          # scalar int
    rnorm: jnp.ndarray          # final weighted residual norm (sqrt(r.c.r))
    rnorm_history: jnp.ndarray  # (max_iter+1,) padded with final value / nan


@dataclasses.dataclass(eq=False)
class SolveResult:
    """Named result of every public solve driver (DESIGN.md §12).

    Replaces the ad-hoc ``(x, hist)`` tuple returns: the solution, the
    residual-norm history, how many iterations actually ran, the achieved
    relative tolerance ``rnorm / history[0]``, and which pipeline /
    preconditioner produced it.  For multi-RHS block solves ``x`` carries
    a leading batch axis and ``history``/``rnorm``/``achieved_rtol`` are
    per-RHS (history: ``(b, niter+1)``).

    Backward compat: iterating still unpacks as the legacy two-tuple
    ``x, hist = result``, and the :class:`CGResult` attribute surface
    (``iters``, ``rnorm_history``) is aliased.  Registered as a JAX
    pytree (pipeline/precond ride as static aux data) so drivers can
    return it from inside ``jax.jit``.
    """

    x: jnp.ndarray
    history: jnp.ndarray
    iters_taken: jnp.ndarray
    achieved_rtol: jnp.ndarray
    rnorm: jnp.ndarray
    pipeline: str | None = None
    precond: str | None = None
    # Host-side telemetry (obs/metrics.SolveTelemetry), attached by
    # solvers.solve_case only when a trace recorder is active — never
    # populated inside jit and deliberately NOT part of the pytree
    # flatten (it would otherwise have to round-trip as aux data and
    # break jit-returned results on comparison).
    telemetry: object = None

    # -- legacy (x, hist) tuple protocol --------------------------------
    def __iter__(self):
        return iter((self.x, self.history))

    def __len__(self) -> int:
        return 2

    def __getitem__(self, i):
        return (self.x, self.history)[i]

    # -- CGResult attribute aliases -------------------------------------
    @property
    def iters(self):
        return self.iters_taken

    @property
    def rnorm_history(self):
        return self.history

    @classmethod
    def from_cg(cls, res: CGResult, *, pipeline: str | None = None,
                precond: str | None = None) -> "SolveResult":
        """Lift a :class:`CGResult` (or any x/iters/rnorm/rnorm_history
        record) into the named surface."""
        hist = res.rnorm_history
        r0 = hist[..., 0]
        denom = jnp.where(r0 > 0, r0, jnp.ones_like(r0))
        return cls(x=res.x, history=hist, iters_taken=res.iters,
                   achieved_rtol=res.rnorm / denom, rnorm=res.rnorm,
                   pipeline=pipeline, precond=precond)


def _solve_result_flatten(res: SolveResult):
    children = (res.x, res.history, res.iters_taken, res.achieved_rtol,
                res.rnorm)
    return children, (res.pipeline, res.precond)


def _solve_result_unflatten(aux, children):
    x, history, iters_taken, achieved_rtol, rnorm = children
    pipeline, precond = aux
    return SolveResult(x=x, history=history, iters_taken=iters_taken,
                       achieved_rtol=achieved_rtol, rnorm=rnorm,
                       pipeline=pipeline, precond=precond)


jax.tree_util.register_pytree_node(SolveResult, _solve_result_flatten,
                                   _solve_result_unflatten)


def weighted_dot(c: jnp.ndarray, psum_axes=None) -> Callable:
    """Nekbone ``glsc3``: ``dot(u, v) = sum(u * c * v)`` (+ mesh psum)."""

    def dot(u, v):
        s = jnp.sum(u * c * v)
        if psum_axes:
            s = jax.lax.psum(s, psum_axes)
        return s

    return dot


def _plain_dot(u, v):
    return jnp.vdot(u, v)


def cg(A: Callable, b: jnp.ndarray, *, x0=None, dot: Callable | None = None,
       max_iter: int = 100, tol: float = 1e-8, precond: Callable | None = None,
       ) -> CGResult:
    """Preconditioned conjugate gradients with early exit (while_loop)."""
    dot = dot or _plain_dot
    M = precond or (lambda r: r)
    x = jnp.zeros_like(b) if x0 is None else x0
    r = b - A(x) if x0 is not None else b
    z = M(r)
    p = z
    rtz = dot(r, z)
    r0 = jnp.sqrt(jnp.abs(dot(r, r)))
    hist = jnp.full((max_iter + 1,), jnp.nan, dtype=r0.dtype).at[0].set(r0)
    tol2 = jnp.asarray(tol, r0.dtype) ** 2

    def cond(state):
        _, r, _, rtz, _, k, _ = state
        rr = jnp.abs(rtz)  # with M=I, rtz = r.c.r
        return jnp.logical_and(k < max_iter, rr > tol2)

    def body(state):
        x, r, p, rtz, hist, k, _ = state
        w = A(p)
        pap = dot(p, w)
        alpha = rtz / pap
        x = x + alpha * p
        r = r - alpha * w
        z = M(r)
        rtz_new = dot(r, z)
        beta = rtz_new / rtz
        p = z + beta * p
        rn = jnp.sqrt(jnp.abs(dot(r, r)))
        hist = hist.at[k + 1].set(rn)
        return x, r, p, rtz_new, hist, k + 1, rn

    state = (x, r, p, rtz, hist, jnp.asarray(0), r0)
    x, r, p, rtz, hist, k, rn = jax.lax.while_loop(cond, body, state)
    return SolveResult.from_cg(
        CGResult(x=x, iters=k, rnorm=rn, rnorm_history=hist),
        pipeline="reference")


def cg_fixed_iters(A: Callable, b: jnp.ndarray, *, niter: int,
                   dot: Callable | None = None, x0=None,
                   precond: Callable | None = None) -> CGResult:
    """Nekbone-style CG: exactly ``niter`` iterations (fori_loop)."""
    dot = dot or _plain_dot
    M = precond or (lambda r: r)
    x = jnp.zeros_like(b) if x0 is None else x0
    r = b if x0 is None else b - A(x)
    z = M(r)
    p = z
    rtz = dot(r, z)
    r0 = jnp.sqrt(jnp.abs(dot(r, r)))
    hist = jnp.full((niter + 1,), jnp.nan, dtype=r0.dtype).at[0].set(r0)

    def body(k, state):
        x, r, p, rtz, hist = state
        w = A(p)
        pap = dot(p, w)
        alpha = rtz / pap
        x = x + alpha * p
        r = r - alpha * w
        z = M(r)
        rtz_new = dot(r, z)
        beta = rtz_new / rtz
        p = z + beta * p
        hist = hist.at[k + 1].set(jnp.sqrt(jnp.abs(dot(r, r))))
        return x, r, p, rtz_new, hist

    x, r, p, rtz, hist = jax.lax.fori_loop(0, niter, body, (x, r, p, rtz, hist))
    return SolveResult.from_cg(
        CGResult(x=x, iters=jnp.asarray(niter), rnorm=hist[niter],
                 rnorm_history=hist),
        pipeline="reference")


def ir_solve(A_hi: Callable, b: jnp.ndarray, inner_solve: Callable, *,
             outer_iters: int = 3, lo_dtype=jnp.float32) -> SolveResult:
    """Mixed-precision iterative refinement.

    ``x_{k+1} = x_k + inner_solve(lo(b - A_hi x_k))`` with the residual formed
    in the precision of ``b`` and the correction solved in ``lo_dtype``.
    Returns a :class:`SolveResult` whose ``history`` holds the
    ``outer_iters + 1`` outer residual norms (legacy ``x, norms = ...``
    unpacking still works).
    """
    hi = b.dtype
    x = jnp.zeros_like(b)
    norms = [jnp.linalg.norm(b.ravel())]
    for _ in range(outer_iters):
        r = b - A_hi(x)
        e = inner_solve(r.astype(lo_dtype))
        x = x + e.astype(hi)
        norms.append(jnp.linalg.norm((b - A_hi(x)).ravel()))
    hist = jnp.stack(norms)
    return SolveResult.from_cg(
        CGResult(x=x, iters=jnp.asarray(outer_iters), rnorm=hist[-1],
                 rnorm_history=hist),
        pipeline="ir")


def jacobi_preconditioner(diag: jnp.ndarray) -> Callable:
    """Diagonal (Jacobi) preconditioner — the paper's future-work item."""
    inv = jnp.where(diag != 0, 1.0 / diag, 0.0)

    def M(r):
        return r * inv

    return M
