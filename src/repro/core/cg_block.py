"""Multi-RHS (block) CG through the batched v2 slab pipeline (DESIGN.md §12).

The serving-side amortization axis: one operator, b right-hand sides.  Each
iteration runs the two batched slab kernels
(:func:`repro.kernels.nekbone_ax.nekbone_ax_slab_block_pallas` /
``nekbone_cg_update_block_pallas``), which load the operator residents —
D, D^T, the 3 metric diagonals, the per-axis mask/weight factors — once per
slab residency and reuse them across the batch, so the shared operator
streams are divided by b while the per-RHS vector streams stay put
(:func:`repro.core.cost.multi_rhs_streams`).

The CG scalar recurrences stay *independent per RHS*: rtz/alpha/beta travel
as length-b vectors (one lane per RHS), the pap/rcr kernel partials come
back as (nblk, b) and are reduced per lane.  The per-RHS arithmetic is the
single-RHS v2 arithmetic operation for operation — at ``b = 1`` the fixed-
iteration driver is fp64-bitwise identical to
:func:`repro.core.cg_fused.cg_fused_v2_fixed_iters` (pinned by
tests/test_cg_block.py).

Both drivers accept ``B`` of shape (b, E, n, n, n) — or (E, n, n, n),
treated as ``b = 1`` — and return a :class:`repro.core.cg.SolveResult`
with per-RHS ``history`` (b, niter+1), ``rnorm``, and ``achieved_rtol``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.cg import CGResult, SolveResult
from repro.core.cg_fused import _check_box_fields
from repro.core.geom import box_outer
from repro.core.precision import resolve_policy
from repro.kernels import autotune as _autotune
from repro.kernels import nekbone_ax as _ax

__all__ = ["cg_block_fixed_iters", "cg_block_tol"]


def _block_iter(x3, r3, p3, rtz, beta, *, D, Dt, g3, mx, my, mz, cx, cy, cz,
                zero_plane, n: int, grid: tuple[int, int, int], sz: int,
                interpret: bool, acc_name: str, layout: str = "fold",
                grid_order: str = "parallel"):
    """One full batched v2 CG iteration (both block kernels + stitch).

    The multi-RHS sibling of :func:`repro.core.cg_fused._v2_iter`:
    identical structure with a leading RHS axis on the fields and planes
    and per-lane scalar recurrences (``rtz``/``beta``: (b,)).  Returns
    ``(x3, r3, p3, rtz_new, beta_new)``.
    """
    nrhs = p3.shape[0]
    p3, w3, bot, top, pap_b = _ax.nekbone_ax_slab_block_pallas(
        p3, r3, D, Dt, g3, mx, my, mz, beta.reshape(1, nrhs),
        n=n, grid=grid, sz=sz, interpret=interpret, acc_dtype=acc_name,
        layout=layout, grid_order=grid_order)
    pap = jnp.sum(pap_b, axis=0)
    alpha = rtz / pap
    # cross-block stitch operands, shifted along the block axis per RHS.
    addb = jnp.concatenate([zero_plane, top[:, :-1]], axis=1)
    addt = jnp.concatenate([bot[:, 1:], zero_plane], axis=1)
    x3, r3, rcr_b = _ax.nekbone_cg_update_block_pallas(
        x3, p3, r3, w3, addb, addt, alpha.reshape(1, nrhs), cx, cy, cz,
        n=n, grid=grid, sz=sz, interpret=interpret, acc_dtype=acc_name)
    rtz_new = jnp.sum(rcr_b, axis=0)
    beta = rtz_new / rtz
    return x3, r3, p3, rtz_new, beta


def _block_init(B, cx, cy, cz, *, n, grid, acc, x_name):
    """Shared state setup: per-RHS rtz0 (one single-RHS-shaped reduction
    per lane, so the b=1 arithmetic is exactly ``_cg_fused_v2``'s) and the
    zero stitch plane."""
    ex, ey, _ = grid
    nrhs, E = B.shape[0], B.shape[1]
    n3 = n ** 3
    pln = ey * ex * n * n
    B2 = B.reshape(nrhs, E, n3)
    c2 = box_outer(cz, cy, cx).reshape(E, n3).astype(acc)
    rtz0 = jnp.stack([jnp.sum(B2[j].astype(acc) * c2 * B2[j].astype(acc))
                      for j in range(nrhs)])
    zero_plane = jnp.zeros((nrhs, 1, pln), B.dtype)
    state = (jnp.zeros(B2.shape, jnp.dtype(x_name)), B2,
             jnp.zeros_like(B2), rtz0, jnp.zeros((nrhs,), acc))
    return state, zero_plane


@functools.partial(jax.jit, static_argnames=("n", "grid", "niter", "sz",
                                             "interpret", "acc_name",
                                             "x_name", "layout",
                                             "grid_order"))
def _cg_block(B, D, Dt, g3, mx, my, mz, cx, cy, cz, *, n: int,
              grid: tuple[int, int, int], niter: int, sz: int,
              interpret: bool, acc_name: str, x_name: str,
              layout: str = "fold",
              grid_order: str = "parallel") -> CGResult:
    nrhs = B.shape[0]
    acc = jnp.dtype(acc_name)
    (x3, r3, p3, rtz0, beta0), zero_plane = _block_init(
        B, cx, cy, cz, n=n, grid=grid, acc=acc, x_name=x_name)

    def body(k, state):
        x3, r3, p3, rtz, beta, hist = state
        hist = hist.at[:, k].set(jnp.sqrt(jnp.abs(rtz)))
        x3, r3, p3, rtz_new, beta = _block_iter(
            x3, r3, p3, rtz, beta, D=D, Dt=Dt, g3=g3, mx=mx, my=my, mz=mz,
            cx=cx, cy=cy, cz=cz, zero_plane=zero_plane, n=n, grid=grid,
            sz=sz, interpret=interpret, acc_name=acc_name, layout=layout,
            grid_order=grid_order)
        return x3, r3, p3, rtz_new, beta, hist

    hist0 = jnp.full((nrhs, niter + 1), jnp.nan, dtype=acc)
    state = (x3, r3, p3, rtz0, beta0, hist0)
    x3, r3, p3, rtz_last, beta, hist = jax.lax.fori_loop(0, niter, body,
                                                         state)
    hist = hist.at[:, niter].set(jnp.sqrt(jnp.abs(rtz_last)))
    return CGResult(x=x3, iters=jnp.asarray(niter), rnorm=hist[:, niter],
                    rnorm_history=hist)


@functools.partial(jax.jit, static_argnames=("n", "grid", "max_iter", "sz",
                                             "interpret", "acc_name",
                                             "x_name", "layout",
                                             "grid_order"))
def _cg_block_tol(B, D, Dt, g3, mx, my, mz, cx, cy, cz, tol2, *, n: int,
                  grid: tuple[int, int, int], max_iter: int, sz: int,
                  interpret: bool, acc_name: str, x_name: str,
                  layout: str = "fold",
                  grid_order: str = "parallel") -> CGResult:
    nrhs = B.shape[0]
    acc = jnp.dtype(acc_name)
    (x3, r3, p3, rtz0, beta0), zero_plane = _block_init(
        B, cx, cy, cz, n=n, grid=grid, acc=acc, x_name=x_name)
    tol2 = jnp.asarray(tol2, acc)

    # cg()'s stopping rule per RHS, jointly: iterate while any RHS is
    # still above tol (converged lanes keep iterating — harmless, their
    # recurrences stay finite — so the batch exits together and every
    # lane's trajectory is a prefix of its fixed-iteration one).
    def cond(state):
        _, _, _, rtz, _, _, kk = state
        return jnp.logical_and(kk < max_iter,
                               jnp.any(jnp.abs(rtz) > tol2))

    def body(state):
        x3, r3, p3, rtz, beta, hist, kk = state
        hist = hist.at[:, kk].set(jnp.sqrt(jnp.abs(rtz)))
        x3, r3, p3, rtz_new, beta = _block_iter(
            x3, r3, p3, rtz, beta, D=D, Dt=Dt, g3=g3, mx=mx, my=my, mz=mz,
            cx=cx, cy=cy, cz=cz, zero_plane=zero_plane, n=n, grid=grid,
            sz=sz, interpret=interpret, acc_name=acc_name, layout=layout,
            grid_order=grid_order)
        return x3, r3, p3, rtz_new, beta, hist, kk + 1

    hist0 = jnp.full((nrhs, max_iter + 1), jnp.nan, dtype=acc)
    state = (x3, r3, p3, rtz0, beta0, hist0, jnp.asarray(0))
    x3, r3, p3, rtz, beta, hist, kk = jax.lax.while_loop(cond, body, state)
    hist = hist.at[:, kk].set(jnp.sqrt(jnp.abs(rtz)))
    return CGResult(x=x3, iters=kk, rnorm=hist[:, kk], rnorm_history=hist)


def _prepare_block(B, D, g, grid, mask, c, sz, layout, grid_order,
                   interpret, precision):
    """Shared public-driver setup: batch-axis lift, precision policy,
    autotuned (sz, layout, grid_order) at this RHS count, box-field
    validation, factor/operator preparation."""
    from repro.kernels import ops as kernel_ops

    B = jnp.asarray(B)
    if B.ndim == 4:
        B = B[None]
    if B.ndim != 5:
        raise ValueError(
            f"cg_block expects (b, E, n, n, n) or (E, n, n, n); "
            f"got shape {B.shape}")
    policy = resolve_policy(precision, B.dtype)
    B = jnp.asarray(B, policy.storage_dtype)
    nrhs, E = B.shape[0], B.shape[1]
    n = B.shape[-1]
    grid = tuple(grid)
    if interpret is None:
        interpret = kernel_ops.default_interpret()
    if sz is None and layout is None and grid_order is None:
        sz, layout, grid_order = _autotune.pick_slab_config(
            grid, n, B.dtype, acc_dtype=policy.accum, nrhs=nrhs)
    elif sz is None:
        sz = _autotune.pick_slab_sz(grid, n, B.dtype,
                                    acc_dtype=policy.accum, nrhs=nrhs)
    layout = "fold" if layout is None else layout
    grid_order = "parallel" if grid_order is None else grid_order

    _check_box_fields(grid, n, mask, c)
    (mx, my, mz), (cx, cy, cz) = kernel_ops.slab_axis_factors(grid, n,
                                                             B.dtype)
    D_op = jnp.asarray(D, policy.op_storage_dtype)
    g3 = kernel_ops.diag_metric(
        jnp.asarray(g, policy.op_storage_dtype), E, n)
    return (policy, B, n, grid, sz, layout, grid_order, interpret,
            (mx, my, mz), (cx, cy, cz), D_op, g3)


def cg_block_fixed_iters(B: jnp.ndarray, *, D: jnp.ndarray, g: jnp.ndarray,
                         grid: tuple[int, int, int], niter: int,
                         mask: jnp.ndarray | None = None,
                         c: jnp.ndarray | None = None,
                         sz: int | None = None,
                         layout: str | None = None,
                         grid_order: str | None = None,
                         interpret: bool | None = None,
                         precision=None) -> SolveResult:
    """Fixed-iteration multi-RHS CG through the batched v2 kernels.

    Args:
      B:     (b, E, n, n, n) assembled, masked right-hand sides — or a
             single (E, n, n, n) RHS, solved as ``b = 1``; elements
             z-major over ``grid``.
      D, g, grid, niter, mask, c, sz, layout, grid_order, interpret,
      precision: exactly :func:`repro.core.cg_fused.cg_fused_v2_fixed_iters`
             (the autotuned slab config additionally keys on b — the RHS
             batch scales the VMEM footprint).

    Returns a :class:`SolveResult` with per-RHS ``history`` (b, niter+1),
    ``rnorm`` and ``achieved_rtol`` (b,).  At ``b = 1`` the trajectory is
    fp64-bitwise identical to the single-RHS v2 driver.
    """
    (policy, B, n, grid, sz, layout, grid_order, interpret,
     (mx, my, mz), (cx, cy, cz), D_op, g3) = _prepare_block(
        B, D, g, grid, mask, c, sz, layout, grid_order, interpret,
        precision)
    nrhs = B.shape[0]
    # tracing: the batched solve is one jitted program; the host
    # boundary is this dispatch, recorded as a single span when on.
    from repro.obs import trace as _trace

    rec = _trace.active()
    with (rec.span("block.dispatch", b=nrhs, niter=niter)
          if rec is not None else _trace.NULL_SPAN):
        res = _cg_block(B.reshape(nrhs, B.shape[1], n ** 3), D_op,
                        D_op.T, g3, mx, my, mz, cx, cy, cz, n=n,
                        grid=grid, niter=niter, sz=sz,
                        interpret=interpret, acc_name=policy.accum,
                        x_name=policy.x_storage_dtype.name, layout=layout,
                        grid_order=grid_order)
    return SolveResult.from_cg(
        res._replace(x=res.x.reshape(B.shape)),
        pipeline=f"fused_v2_rhs{nrhs}")


def cg_block_tol(B: jnp.ndarray, *, D: jnp.ndarray, g: jnp.ndarray,
                 grid: tuple[int, int, int], tol: float = 1e-8,
                 max_iter: int = 100,
                 mask: jnp.ndarray | None = None,
                 c: jnp.ndarray | None = None,
                 sz: int | None = None,
                 layout: str | None = None,
                 grid_order: str | None = None,
                 interpret: bool | None = None,
                 precision=None) -> SolveResult:
    """Tolerance-driven multi-RHS CG: iterate until *every* RHS meets
    :func:`repro.core.cg.cg`'s stopping rule (``|rtz| > tol**2`` checked
    before each iteration) or ``max_iter``.

    Converged lanes keep iterating until the whole batch is done — the
    per-RHS histories are prefixes of the fixed-iteration trajectories,
    NaN-padded to ``max_iter + 1``; ``iters`` is the joint count run.
    """
    (policy, B, n, grid, sz, layout, grid_order, interpret,
     (mx, my, mz), (cx, cy, cz), D_op, g3) = _prepare_block(
        B, D, g, grid, mask, c, sz, layout, grid_order, interpret,
        precision)
    nrhs = B.shape[0]
    from repro.obs import trace as _trace

    rec = _trace.active()
    with (rec.span("block.dispatch", b=nrhs, tol=tol)
          if rec is not None else _trace.NULL_SPAN):
        res = _cg_block_tol(B.reshape(nrhs, B.shape[1], n ** 3), D_op,
                            D_op.T, g3, mx, my, mz, cx, cy, cz,
                            float(tol) ** 2, n=n, grid=grid,
                            max_iter=max_iter, sz=sz, interpret=interpret,
                            acc_name=policy.accum,
                            x_name=policy.x_storage_dtype.name,
                            layout=layout, grid_order=grid_order)
    return SolveResult.from_cg(
        res._replace(x=res.x.reshape(B.shape)),
        pipeline=f"fused_v2_rhs{nrhs}")
