"""Step-fused conjugate gradients: the whole iteration in Pallas kernels.

``cg_fixed_iters`` (core/cg.py) composes the operator and the three inner
products from separate XLA ops; per iteration the vectors ``p``, ``w``,
``r``, ``c`` are re-read from HBM for every reduction the paper's Eq. 2
charges for.  This module runs the iteration the way the cost model wants it
counted (DESIGN.md §3), at two fusion levels:

**v1** (:func:`cg_fused_fixed_iters`, DESIGN.md §3.3): one multi-output
Pallas kernel computes the masked local operator and the ``p·c·Ap`` partial
in the same VMEM residency; the direct-stiffness summation and the vector
updates remain XLA passes.  The ``r·c·r`` reduction is *carried* through the
loop state (it equals the previous iteration's post-update reduction), so
the kernel never re-reads ``r``/``c`` — 17 streams/iteration against
Eq. 2's 30.

**v2** (:func:`cg_fused_v2_fixed_iters`, DESIGN.md §3.4): zero standalone
full-field XLA passes.  The grid marches whole z-slabs, so the x/y
direct-stiffness summation and the intra-block z interfaces are summed on
the VMEM-resident kernel output; the two cross-block boundary planes travel
as O(E n^2) side outputs and are stitched in VMEM by a second, merged
vector-update kernel that also performs both axpys and the post-update
``r·c·r`` partial.  The ``p = r + beta p`` update folds into the next
iteration's operator kernel (beta enters as a scalar operand), and the
structured box's mask / inner-product weight are rebuilt in-kernel from
per-axis factors while the axis-aligned metric collapses to its diagonal —
13 streams/iteration.

**sharded** (:func:`cg_fused_sharded_fixed_iters`): the v1 pipeline per
shard inside ``shard_map``, with ``ds_sum_sharded`` exchanging the
cross-shard z-planes and the inner-product partials ``psum``-reduced.

All variants are *algebraically identical* to
:func:`repro.core.cg.cg_fixed_iters` with ``M = I``; the inner products are
summed in a different association (per-block then tree), so histories agree
to dtype round-off, which the fp64-interpret parity tests pin down
(tests/test_cg_fused.py, tests/test_cg_fused_v2.py).

**mixed precision** (DESIGN.md §7): every entry point takes a
``precision`` policy (:mod:`repro.core.precision`) splitting the *storage*
dtype — what ``x``/``r``/``p``/``w`` and the metric occupy in HBM, hence
what every stream above is billed in — from the *accumulation* dtype the
kernels upcast to for the contractions and the ``p·c·Ap`` / ``r·c·r``
partials.  bf16 storage halves f32's bytes/iteration; the stalled bf16
residual floor is recovered by :func:`cg_ir_fixed_iters`, which wraps the
low-precision inner solve in an iterative-refinement outer loop whose
residuals are formed in the caller's (high) precision.

Preconditions: ``b`` must be assembled ("continuous": coincident copies
equal — manufactured right-hand sides are) and masked; unpreconditioned CG
only (Nekbone's benchmark configuration and the paper's §V protocol).  The
v2 path additionally requires the structured axis-aligned box fields
(diagonal metric, factorizable mask — what ``BoxMesh`` produces).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

import repro.core.gs as gs_mod
from repro.core.cg import CGResult, SolveResult
from repro.core.geom import box_axis_factors, box_outer
from repro.core.precision import resolve_policy
from repro.kernels import autotune as _autotune
from repro.kernels import nekbone_ax as _ax

__all__ = ["cg_fused_fixed_iters", "cg_fused_v2_fixed_iters",
           "cg_fused_sharded_fixed_iters", "cg_ir_fixed_iters"]


# ---------------------------------------------------------------------------
# v1: fused operator+pap kernel, XLA assembly and vector pass
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("n", "grid", "niter", "block_e",
                                             "interpret", "acc_name",
                                             "x_name"))
def _cg_fused(b, D, Dt, g2, mask2, c, *, n: int,
              grid: tuple[int, int, int], niter: int, block_e: int,
              interpret: bool, acc_name: str, x_name: str) -> CGResult:
    E = b.shape[0]
    n3 = n ** 3
    # inner products, alpha/beta, and the residual history live in the
    # policy's accumulation dtype; the fori_loop carries r/p in the storage
    # dtype (= b.dtype) and x in the policy's (possibly wider) x-storage
    # dtype, so the HBM residency is exactly what Eq. 2 bills.
    acc = jnp.dtype(acc_name)
    x_dtype = jnp.dtype(x_name)
    c_acc = c.astype(acc)
    # r·c·r is carried through the loop: each iteration's post-update
    # reduction (fused by XLA with the axpys that produce r) is next
    # iteration's rtz, so the kernel needs no r/c operands (DESIGN.md §3.3).
    rtz0 = jnp.sum(b.astype(acc) * c_acc * b.astype(acc))

    def body(k, state):
        x, r, p, rtz, hist = state
        hist = hist.at[k].set(jnp.sqrt(jnp.abs(rtz)))
        w2, pap_b = _ax.nekbone_ax_pap_pallas(
            p.reshape(E, n3), D, Dt, g2, mask2,
            n=n, block_e=block_e, interpret=interpret, acc_dtype=acc_name)
        pap = jnp.sum(pap_b)            # tree-reduce the per-block partials
        # mask commutes with gs (coincident copies share their mask value),
        # so the kernel's masked output assembles directly.
        w = gs_mod.ds_sum_local(w2.reshape(b.shape), grid)
        alpha = rtz / pap
        # axpys evaluated in acc, stored (the loop carry) in storage dtype;
        # for the f32/f64 policies this is bit-identical to pre-policy code.
        x = (x.astype(acc) + alpha * p.astype(acc)).astype(x_dtype)
        r = (r.astype(acc) - alpha * w.astype(acc)).astype(b.dtype)
        # fused by XLA with the axpy above; carried as the next rtz.  The
        # reduction sees the *stored* r so the carried scalar matches the
        # residual the next iteration's kernel actually reads.
        rtz_new = jnp.sum(r.astype(acc) * c_acc * r.astype(acc))
        beta = rtz_new / rtz
        p = (r.astype(acc) + beta * p.astype(acc)).astype(b.dtype)
        return x, r, p, rtz_new, hist

    x = jnp.zeros(b.shape, x_dtype)
    hist0 = jnp.full((niter + 1,), jnp.nan, dtype=acc)
    state = (x, b, b, rtz0, hist0)
    x, r, p, rtz_last, hist = jax.lax.fori_loop(0, niter, body, state)
    hist = hist.at[niter].set(jnp.sqrt(jnp.abs(rtz_last)))
    return CGResult(x=x, iters=jnp.asarray(niter), rnorm=hist[niter],
                    rnorm_history=hist)


def cg_fused_fixed_iters(b: jnp.ndarray, *, D: jnp.ndarray, g: jnp.ndarray,
                         mask: jnp.ndarray, c: jnp.ndarray,
                         grid: tuple[int, int, int], niter: int,
                         block_e: int | None = None,
                         interpret: bool | None = None,
                         precision=None) -> CGResult:
    """Fixed-iteration CG through the fused-iteration Pallas pipeline (v1).

    Args:
      b:     (E, n, n, n) assembled, masked right-hand side.
      D:     (n, n) derivative matrix.
      g:     (E, 6, n, n, n) metric fields.
      mask:  (E, n, n, n) Dirichlet mask (0/1 valued).
      c:     (E, n, n, n) inner-product weight (mask / multiplicity).
      grid:  element grid (EX, EY, EZ) with EX*EY*EZ == E.
      niter: iteration count (the paper runs 100).
      block_e: elements per VMEM block; default: autotuned divisor of E
               (kernels/autotune.py).
      interpret: force Pallas interpret mode (default: off-TPU detection).
      precision: policy name / :class:`~repro.core.precision.PrecisionPolicy`
               / ``None`` (infer from ``b.dtype``): operands are cast to the
               storage dtype, kernels accumulate in the accum dtype
               (DESIGN.md §7).

    Returns a :class:`repro.core.cg.CGResult` whose ``rnorm_history`` matches
    ``cg_fixed_iters`` to round-off (of the policy's storage dtype).
    """
    from repro.kernels import ops as kernel_ops

    policy = resolve_policy(precision, b.dtype)
    b = jnp.asarray(b, policy.storage_dtype)
    E = b.shape[0]
    n = b.shape[-1]
    if interpret is None:
        interpret = kernel_ops.default_interpret()
    if block_e is None:
        block_e = _autotune.pick_block_e(E, n, b.dtype,
                                         acc_dtype=policy.accum)
    while E % block_e:
        block_e //= 2                  # fused path avoids padding: divisor
    block_e = max(block_e, 1)

    n3 = n ** 3
    # operator data (D, metric) in the policy's op-storage dtype: refined
    # policies keep it wide — rounding A itself floors the refinement.
    D = jnp.asarray(D, policy.op_storage_dtype)
    g2 = jnp.asarray(g, policy.op_storage_dtype).reshape(E, 6, n3)
    mask2 = jnp.asarray(mask, b.dtype).reshape(E, n3)
    c = jnp.asarray(c, b.dtype)
    return SolveResult.from_cg(
        _cg_fused(b, D, D.T, g2, mask2, c, n=n, grid=tuple(grid),
                  niter=niter, block_e=block_e, interpret=interpret,
                  acc_name=policy.accum,
                  x_name=policy.x_storage_dtype.name),
        pipeline="fused_v1")


# ---------------------------------------------------------------------------
# v2: slab gather-scatter + merged vector-update kernel
# ---------------------------------------------------------------------------

def _check_box_fields(grid, n, mask, c) -> None:
    """Verify caller-supplied mask/c match the structural box fields.

    The v2 kernels *rebuild* both from per-axis factors
    (``geom.box_axis_factors``), so silently accepting a different mask or
    weight would compute a different problem.  Skipped under tracing
    (concrete mesh fields are checked at build time).
    """
    (mx, my, mz), (cx, cy, cz) = box_axis_factors(grid, n)
    for name, field, want in (
            ("mask", mask, box_outer(mz, my, mx).reshape(-1, n, n, n)),
            ("c", c, box_outer(cz, cy, cx).reshape(-1, n, n, n))):
        if field is None:
            continue
        try:
            got = np.asarray(field, np.float64)
        except jax.errors.TracerArrayConversionError:
            continue
        if got.shape != want.shape or not np.array_equal(got, want):
            raise ValueError(
                f"pallas_fused_cg_v2 requires the structured box {name} "
                "(per-axis factorizable); supplied field differs")


def _v2_iter(x2, r2, p2, rtz, beta, *, D, Dt, g3, mx, my, mz, cx, cy, cz,
             zero_plane, n: int, grid: tuple[int, int, int], sz: int,
             interpret: bool, acc_name: str, layout: str = "fold",
             grid_order: str = "parallel"):
    """One full v2 CG iteration (both slab kernels + the plane stitch).

    Shared by the fixed-iteration driver below and the tolerance-driven
    driver (:func:`repro.core.precond.cg_fused_tol`), so the tol-driven
    trajectory is the fixed-iteration trajectory *by construction* — the
    acceptance property the tests pin.  Returns
    ``(x2, r2, p2, rtz_new, beta_new)``.
    """
    # front half: p = r + beta p, masked Ax, pap partial, in-block
    # assembly; boundary planes leave as (nblk, pln) side outputs.
    p2, w2, bot, top, pap_b = _ax.nekbone_ax_slab_pallas(
        p2, r2, D, Dt, g3, mx, my, mz, beta.reshape(1, 1),
        n=n, grid=grid, sz=sz, interpret=interpret, acc_dtype=acc_name,
        layout=layout, grid_order=grid_order)
    pap = jnp.sum(pap_b)
    alpha = rtz / pap
    # cross-block stitch operands: each block receives its neighbours'
    # boundary planes (zeros at the global ends) — O(E n^2) traffic.
    addb = jnp.concatenate([zero_plane, top[:-1]], axis=0)
    addt = jnp.concatenate([bot[1:], zero_plane], axis=0)
    # back half: stitch w in VMEM, both axpys, post-update r·c·r.
    x2, r2, rcr_b = _ax.nekbone_cg_update_pallas(
        x2, p2, r2, w2, addb, addt, alpha.reshape(1, 1), cx, cy, cz,
        n=n, grid=grid, sz=sz, interpret=interpret, acc_dtype=acc_name)
    rtz_new = jnp.sum(rcr_b)
    beta = rtz_new / rtz
    return x2, r2, p2, rtz_new, beta


@functools.partial(jax.jit, static_argnames=("n", "grid", "niter", "sz",
                                             "interpret", "acc_name",
                                             "x_name", "layout",
                                             "grid_order"))
def _cg_fused_v2(b, D, Dt, g3, mx, my, mz, cx, cy, cz, *, n: int,
                 grid: tuple[int, int, int], niter: int, sz: int,
                 interpret: bool, acc_name: str, x_name: str,
                 layout: str = "fold",
                 grid_order: str = "parallel") -> CGResult:
    ex, ey, ez = grid
    E = b.shape[0]
    n3 = n ** 3
    pln = ey * ex * n * n
    acc = jnp.dtype(acc_name)
    x_dtype = jnp.dtype(x_name)
    b2 = b.reshape(E, n3)
    # one-time initial reduction; c rebuilt from the factors in-jit (an XLA
    # constant) so no full-field weight operand enters the pipeline.
    c2 = box_outer(cz, cy, cx).reshape(E, n3).astype(acc)
    rtz0 = jnp.sum(b2.astype(acc) * c2 * b2.astype(acc))
    zero_plane = jnp.zeros((1, pln), b.dtype)

    def body(k, state):
        x2, r2, p2, rtz, beta, hist = state
        hist = hist.at[k].set(jnp.sqrt(jnp.abs(rtz)))
        x2, r2, p2, rtz_new, beta = _v2_iter(
            x2, r2, p2, rtz, beta, D=D, Dt=Dt, g3=g3, mx=mx, my=my, mz=mz,
            cx=cx, cy=cy, cz=cz, zero_plane=zero_plane, n=n, grid=grid,
            sz=sz, interpret=interpret, acc_name=acc_name, layout=layout,
            grid_order=grid_order)
        return x2, r2, p2, rtz_new, beta, hist

    hist0 = jnp.full((niter + 1,), jnp.nan, dtype=acc)
    state = (jnp.zeros(b2.shape, x_dtype), b2, jnp.zeros_like(b2), rtz0,
             jnp.zeros((), acc), hist0)
    x2, r2, p2, rtz_last, beta, hist = jax.lax.fori_loop(0, niter, body,
                                                         state)
    hist = hist.at[niter].set(jnp.sqrt(jnp.abs(rtz_last)))
    return CGResult(x=x2.reshape(b.shape), iters=jnp.asarray(niter),
                    rnorm=hist[niter], rnorm_history=hist)


def cg_fused_v2_fixed_iters(b: jnp.ndarray, *, D: jnp.ndarray,
                            g: jnp.ndarray, grid: tuple[int, int, int],
                            niter: int, mask: jnp.ndarray | None = None,
                            c: jnp.ndarray | None = None,
                            sz: int | None = None,
                            layout: str | None = None,
                            grid_order: str | None = None,
                            interpret: bool | None = None,
                            precision=None) -> CGResult:
    """Fixed-iteration CG, whole iteration in two Pallas kernels (v2).

    Args:
      b:     (E, n, n, n) assembled, masked right-hand side; elements
             z-major over ``grid``.
      D:     (n, n) derivative matrix.
      g:     (E, 6, n, n, n) metric (off-diagonals must be zero — the
             axis-aligned box), or pre-packed (E, 3, n, n, n) diagonal.
      grid:  element grid (EX, EY, EZ).
      niter: iteration count.
      mask/c: optional — the kernels rebuild both from per-axis factors;
             when passed (concrete) they are validated against the
             structural fields and otherwise unused.
      sz:    slabs per block; default: autotuned divisor of EZ
             (kernels/autotune.pick_slab_sz).
      layout, grid_order: contraction layout / grid iteration order for
             the slab kernel (defaults: jointly autotuned with sz when
             all three are None, kernels/autotune.pick_slab_config).
      interpret: force Pallas interpret mode (default: off-TPU detection).
      precision: policy name / policy / ``None`` (infer from ``b.dtype``):
             b and the metric are cast to the storage dtype, both kernels
             accumulate in the accum dtype (DESIGN.md §7).

    Returns a :class:`repro.core.cg.CGResult` whose ``rnorm_history``
    matches ``cg_fixed_iters`` to round-off (of the storage dtype).
    """
    from repro.kernels import ops as kernel_ops

    policy = resolve_policy(precision, b.dtype)
    b = jnp.asarray(b, policy.storage_dtype)
    E = b.shape[0]
    n = b.shape[-1]
    grid = tuple(grid)
    if interpret is None:
        interpret = kernel_ops.default_interpret()
    if sz is None and layout is None and grid_order is None:
        sz, layout, grid_order = _autotune.pick_slab_config(
            grid, n, b.dtype, acc_dtype=policy.accum)
    elif sz is None:
        sz = _autotune.pick_slab_sz(grid, n, b.dtype,
                                    acc_dtype=policy.accum)
    layout = "fold" if layout is None else layout
    grid_order = "parallel" if grid_order is None else grid_order

    _check_box_fields(grid, n, mask, c)
    (mx, my, mz), (cx, cy, cz) = kernel_ops.slab_axis_factors(grid, n,
                                                             b.dtype)
    # operator data (D, metric) in the policy's op-storage dtype: refined
    # policies keep it wide — rounding A itself floors the refinement.
    D = jnp.asarray(D, policy.op_storage_dtype)
    g3 = kernel_ops.diag_metric(
        jnp.asarray(g, policy.op_storage_dtype), E, n)
    return SolveResult.from_cg(
        _cg_fused_v2(b, D, D.T, g3, mx, my, mz, cx, cy, cz, n=n,
                     grid=grid, niter=niter, sz=sz, interpret=interpret,
                     acc_name=policy.accum,
                     x_name=policy.x_storage_dtype.name,
                     layout=layout, grid_order=grid_order),
        pipeline="fused_v2")


# ---------------------------------------------------------------------------
# sharded: the fused pipeline per shard inside shard_map
# ---------------------------------------------------------------------------

def cg_fused_sharded_fixed_iters(b: jnp.ndarray, *, D: jnp.ndarray,
                                 g: jnp.ndarray, mask: jnp.ndarray,
                                 c: jnp.ndarray,
                                 grid_local: tuple[int, int, int],
                                 axis_names, niter: int,
                                 block_e: int | None = None,
                                 interpret: bool | None = None,
                                 precision=None) -> CGResult:
    """Fused-iteration CG with elements sharded along z, for ``shard_map``.

    Per shard and iteration: the fused operator+pap kernel on the local
    element block, ``ds_sum_sharded`` (core/gs.py) for the assembly — its
    ``halo_exchange_z`` ppermutes the cross-shard interface planes — and the
    XLA vector pass.  The two inner products are global: per-block kernel
    partials are summed locally, then ``psum``-reduced over ``axis_names``,
    so every shard sees identical ``alpha``/``beta`` and the iteration is
    SPMD-uniform.

    Args are the shard-local blocks (``b``: (E_local, n, n, n) etc.);
    ``grid_local`` is the local element grid (EX, EY, EZ_local).  The rtz
    carry matches :func:`cg_fused_fixed_iters`, as does the ``precision``
    policy treatment (storage-dtype shards, accum-dtype scalars — the psum
    partials travel in the accum dtype, so cross-shard reductions never
    round to storage).
    """
    from repro.kernels import ops as kernel_ops

    policy = resolve_policy(precision, b.dtype)
    b = jnp.asarray(b, policy.storage_dtype)
    E = b.shape[0]
    n = b.shape[-1]
    axis_names = tuple(axis_names)
    if interpret is None:
        interpret = kernel_ops.default_interpret()
    if block_e is None:
        block_e = _autotune.pick_block_e(E, n, b.dtype,
                                         acc_dtype=policy.accum)
    while E % block_e:
        block_e //= 2
    block_e = max(block_e, 1)

    n3 = n ** 3
    D = jnp.asarray(D, policy.op_storage_dtype)
    Dt = D.T
    g2 = jnp.asarray(g, policy.op_storage_dtype).reshape(E, 6, n3)
    mask2 = jnp.asarray(mask, b.dtype).reshape(E, n3)
    acc = policy.accum_dtype
    x_dtype = policy.x_storage_dtype
    c_acc = jnp.asarray(c, b.dtype).astype(acc)

    def gsum(v):
        return jax.lax.psum(v, axis_names)

    rtz0 = gsum(jnp.sum(b.astype(acc) * c_acc * b.astype(acc)))

    def body(k, state):
        x, r, p, rtz, hist = state
        hist = hist.at[k].set(jnp.sqrt(jnp.abs(rtz)))
        w2, pap_b = _ax.nekbone_ax_pap_pallas(
            p.reshape(E, n3), D, Dt, g2, mask2,
            n=n, block_e=block_e, interpret=interpret,
            acc_dtype=policy.accum)
        pap = gsum(jnp.sum(pap_b))
        w = gs_mod.ds_sum_sharded(w2.reshape(b.shape), grid_local,
                                  axis_names)
        alpha = rtz / pap
        x = (x.astype(acc) + alpha * p.astype(acc)).astype(x_dtype)
        r = (r.astype(acc) - alpha * w.astype(acc)).astype(b.dtype)
        rtz_new = gsum(jnp.sum(r.astype(acc) * c_acc * r.astype(acc)))
        beta = rtz_new / rtz
        p = (r.astype(acc) + beta * p.astype(acc)).astype(b.dtype)
        return x, r, p, rtz_new, hist

    x = jnp.zeros(b.shape, x_dtype)
    hist0 = jnp.full((niter + 1,), jnp.nan, dtype=acc)
    state = (x, b, b, rtz0, hist0)
    x, r, p, rtz_last, hist = jax.lax.fori_loop(0, niter, body, state)
    hist = hist.at[niter].set(jnp.sqrt(jnp.abs(rtz_last)))
    return SolveResult.from_cg(
        CGResult(x=x, iters=jnp.asarray(niter), rnorm=hist[niter],
                 rnorm_history=hist),
        pipeline="fused_v1_sharded")


# ---------------------------------------------------------------------------
# iterative refinement: low-precision fused inner solves, high-precision
# residuals (DESIGN.md §7)
# ---------------------------------------------------------------------------

def cg_ir_fixed_iters(b: jnp.ndarray, *, D: jnp.ndarray, g: jnp.ndarray,
                      grid: tuple[int, int, int], niter: int = 100,
                      precision="bf16_ir", outer_iters: int | None = None,
                      inner_iters: int | None = None,
                      mask: jnp.ndarray | None = None,
                      c: jnp.ndarray | None = None, variant: str = "v2",
                      sz: int | None = None, block_e: int | None = None,
                      s: int = 4,
                      interpret: bool | None = None) -> CGResult:
    """Mixed-precision CG: fused low-precision inner solves wrapped in an
    iterative-refinement outer loop (DESIGN.md §7).

    Low-precision storage stalls plain CG at the storage dtype's round-off
    floor (bf16: ~4e-3 relative).  This driver recovers the high-precision
    floor while keeping every *inner* iteration at the policy's
    bf16/f32-priced streams:

        r_k = b - A x_k                    (caller precision — ``b.dtype``)
        e_k ≈ solve(A e = r_k / s_k)       (fused pipeline, storage dtype,
                                            ``inner_iters`` iterations)
        x_{k+1} = x_k + s_k e_k            (caller precision)

    with ``s_k = max|r_k|`` so each scaled inner problem spends the narrow
    mantissa on the digits that are still wrong — per sweep the residual
    drops by what an ``inner_iters``-iteration CG achieves, floored near
    storage eps, and the floors *compound* across sweeps.  The outer
    residual/axpy pass costs ~14 caller-precision streams amortized over
    ``inner_iters`` fused iterations (``cost.ir_overhead_streams``).

    Each sweep is a *restart* — it discards the Krylov space — so the
    inner solves must run long enough to get past the residual-norm
    transient (CG minimizes the A-norm of the error; on stiff SEM cases
    the residual norm first *rises* for tens of iterations).  The default
    therefore runs full-length sweeps: ``inner_iters = niter`` per sweep,
    a few sweeps (bf16 stalls ~1e-2 relative per sweep on the paper case,
    so 3 sweeps pass fp64's 100-iteration floor; see
    tests/test_precision.py).

    Args:
      b:       (E, n, n, n) assembled, masked right-hand side, in the
               precision the refined residuals should reach (f64 under
               ``JAX_ENABLE_X64`` — the oracle; f32 on TPU).
      D, g, grid: as :func:`cg_fused_v2_fixed_iters`.
      niter:   inner iterations per refinement sweep (the paper's fixed-
               iteration protocol runs 100).
      precision: refinement policy (default ``bf16_ir``); the policy's
               storage dtype prices the inner iterations.
      outer_iters: refinement sweeps (default: 3 for sub-f32 storage,
               2 otherwise).
      inner_iters: override the per-sweep inner count (default ``niter``).
      mask/c:  optional structural fields; rebuilt from the box's per-axis
               factors when omitted.
      variant: inner pipeline — ``"v2"`` (two slab kernels), ``"v1"``, or
               ``"sstep"`` (the v3 s-step matrix-powers pipeline,
               core/cg_sstep.py — its f64 Gram recurrence composes with
               refinement unchanged: the basis streams at the policy's
               storage width, the outer residuals stay in ``b.dtype``).
      sz / block_e / s / interpret: forwarded to the inner pipeline.

    Returns a :class:`repro.core.cg.CGResult`: ``x`` in ``b.dtype``,
    ``rnorm_history`` holding the ``outer_iters + 1`` *outer* weighted
    residual norms (``sqrt(r·c·r)`` in ``b.dtype`` — directly comparable to
    ``cg_fixed_iters``'s history), ``iters`` the total inner count.
    """
    from repro.core.ax import ax_local_fused

    policy = resolve_policy(precision, b.dtype)
    hi = b.dtype
    grid = tuple(grid)
    n = b.shape[-1]
    if outer_iters is None:
        # bf16 sweeps contract fast early (rhs rounding + the bf16
        # r-recursion drift dominate, ~1e-1..1e-2 each) then slow to the
        # restarted-Krylov tail rate; five compound past the fp64
        # 100-iteration floor on the paper's E=1024/n=10 case.  f32
        # sweeps stall ~1e-6: two reach the f64 round-off region.
        outer_iters = 5 if policy.storage_dtype.itemsize < 4 else 2
    if inner_iters is None:
        inner_iters = niter

    if mask is None or c is None:
        (mxf, myf, mzf), (cxf, cyf, czf) = box_axis_factors(grid, n)
        if mask is None:
            mask = box_outer(mzf, myf, mxf).reshape(b.shape)
        if c is None:
            c = box_outer(czf, cyf, cxf).reshape(b.shape)
    mask_hi = jnp.asarray(mask, hi)
    c_hi = jnp.asarray(c, hi)
    D_hi = jnp.asarray(D, hi)
    g_hi = jnp.asarray(g, hi)

    @jax.jit
    def refresh(x):
        """High-precision residual and its weighted norm (one ax_full)."""
        w = gs_mod.ds_sum_local(ax_local_fused(x, D_hi, g_hi), grid)
        r = b - w * mask_hi
        return r, jnp.sqrt(jnp.abs(jnp.sum(r * c_hi * r)))

    theta = None
    if variant == "sstep":
        from repro.core.cg_sstep import estimate_theta

        # theta depends only on (D, g, grid, mask) — estimate once here,
        # not once per refinement sweep inside cg_sstep_fixed_iters.
        theta = estimate_theta(D_hi, g_hi, grid, mask_hi)

    def inner(r_scaled):
        if variant == "sstep":
            from repro.core.cg_sstep import cg_sstep_fixed_iters

            return cg_sstep_fixed_iters(
                r_scaled, D=D, g=g, grid=grid, niter=inner_iters, s=s,
                mask=mask, c=c, sz=sz, theta=theta, interpret=interpret,
                precision=policy)
        if variant == "v2":
            # forward the caller's mask/c so the v2 path *validates* them
            # against the structural box fields — the outer refresh uses
            # them, and a silent mismatch would refine toward a different
            # operator than the inner pipeline solves.
            return cg_fused_v2_fixed_iters(
                r_scaled, D=D, g=g, grid=grid, niter=inner_iters,
                mask=mask, c=c, sz=sz, interpret=interpret,
                precision=policy)
        return cg_fused_fixed_iters(
            r_scaled, D=D, g=g, mask=mask, c=c, grid=grid,
            niter=inner_iters, block_e=block_e, interpret=interpret,
            precision=policy)

    x = jnp.zeros_like(b)
    r = b
    norms = [jnp.sqrt(jnp.abs(jnp.sum(b * c_hi * b)))]
    # tracing: recorder read once per solve; one `is None` test per
    # sweep when off, a timed "ir.sweep" span per refinement when on.
    from repro.obs import trace as _trace

    rec = _trace.active()
    for sweep in range(outer_iters):
        with (rec.span("ir.sweep", sweep=sweep, variant=variant,
                       inner_iters=inner_iters)
              if rec is not None else _trace.NULL_SPAN):
            # inf-norm scaling: the downcast spends the narrow mantissa
            # on the digits that are still wrong, not on the
            # already-converged scale.
            scale = jnp.max(jnp.abs(r))
            scale = jnp.where(scale > 0, scale, jnp.ones((), hi))
            e = inner((r / scale).astype(hi)).x
            x = x + scale * e.astype(hi)
            r, rn = refresh(x)
            norms.append(rn)
    hist = jnp.stack(norms)
    return SolveResult.from_cg(
        CGResult(x=x, iters=jnp.asarray(outer_iters * inner_iters),
                 rnorm=hist[-1], rnorm_history=hist),
        pipeline="ir")
