"""Step-fused conjugate gradients: one Pallas call per iteration.

``cg_fixed_iters`` (core/cg.py) composes the operator and the three inner
products from separate XLA ops; per iteration the vectors ``p``, ``w``,
``r``, ``c`` are re-read from HBM for every reduction the paper's Eq. 2
charges for.  This module runs the iteration the way the cost model wants it
counted (DESIGN.md §3):

* one multi-output Pallas kernel (``kernels/nekbone_ax.nekbone_ax_dots``)
  computes the masked local operator **and** emits per-element-block partial
  sums for ``p·c·Ap`` and ``r·c·z`` in the same VMEM residency — the mask
  pass and the two standalone reduction passes disappear;
* the partials are tree-reduced (``jnp.sum`` over the ``E/block_e`` blocks)
  on the host side of the ``pallas_call``;
* the direct-stiffness summation stays outside the kernel (it crosses
  element-block boundaries) but commutes with the mask, so the kernel's
  masked output feeds it directly;
* the remaining vector updates (x/r/p axpys + the post-update residual
  reduction) are one fused XLA pass.

The iteration is *algebraically identical* to :func:`repro.core.cg.cg_fixed_iters`
with ``M = I``; the inner products are summed in a different association
(per-block then tree), so histories agree to dtype round-off, which the
fp64-interpret parity test pins down (tests/test_cg_fused.py).

Preconditions: ``b`` must be assembled ("continuous": coincident copies
equal — manufactured right-hand sides are) and masked; unpreconditioned CG
only (Nekbone's benchmark configuration and the paper's §V protocol).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

import repro.core.gs as gs_mod
from repro.core.cg import CGResult
from repro.kernels import autotune as _autotune
from repro.kernels import nekbone_ax as _ax

__all__ = ["cg_fused_fixed_iters"]


@functools.partial(jax.jit, static_argnames=("n", "grid", "niter", "block_e",
                                             "interpret"))
def _cg_fused(b, D, Dt, g2, mask2, c, *, n: int,
              grid: tuple[int, int, int], niter: int, block_e: int,
              interpret: bool) -> CGResult:
    E = b.shape[0]
    n3 = n ** 3
    c2 = c.reshape(E, n3)
    # inner products accumulate in f32 (f64 on the oracle path) even for
    # bf16 fields — matching the kernel partials' dtype; alpha/beta are cast
    # back so the fori_loop carry stays in the field dtype.
    acc = jnp.float64 if b.dtype == jnp.float64 else jnp.float32

    def body(k, state):
        x, r, p, hist, _ = state
        w2, pap_b, rcz_b = _ax.nekbone_ax_dots_pallas(
            p.reshape(E, n3), D, Dt, g2, mask2, r.reshape(E, n3), c2,
            n=n, block_e=block_e, interpret=interpret)
        pap = jnp.sum(pap_b)            # tree-reduce the per-block partials
        rtz = jnp.sum(rcz_b)            # == r·c·z for the *current* r
        hist = hist.at[k].set(jnp.sqrt(jnp.abs(rtz)).astype(b.dtype))
        # mask commutes with gs (coincident copies share their mask value),
        # so the kernel's masked output assembles directly.
        w = gs_mod.ds_sum_local(w2.reshape(b.shape), grid)
        alpha = (rtz / pap).astype(b.dtype)
        x = x + alpha * p
        r = r - alpha * w
        # fused by XLA with the axpy above
        rtz_new = jnp.sum(r.astype(acc) * c.astype(acc) * r.astype(acc))
        beta = (rtz_new / rtz).astype(b.dtype)
        p = r + beta * p
        return x, r, p, hist, rtz_new

    x = jnp.zeros_like(b)
    hist0 = jnp.full((niter + 1,), jnp.nan, dtype=b.dtype)
    state = (x, b, b, hist0, jnp.zeros((), acc))
    x, r, p, hist, rtz_last = jax.lax.fori_loop(0, niter, body, state)
    hist = hist.at[niter].set(jnp.sqrt(jnp.abs(rtz_last)).astype(b.dtype))
    return CGResult(x=x, iters=jnp.asarray(niter), rnorm=hist[niter],
                    rnorm_history=hist)


def cg_fused_fixed_iters(b: jnp.ndarray, *, D: jnp.ndarray, g: jnp.ndarray,
                         mask: jnp.ndarray, c: jnp.ndarray,
                         grid: tuple[int, int, int], niter: int,
                         block_e: int | None = None,
                         interpret: bool | None = None) -> CGResult:
    """Fixed-iteration CG through the fused-iteration Pallas pipeline.

    Args:
      b:     (E, n, n, n) assembled, masked right-hand side.
      D:     (n, n) derivative matrix.
      g:     (E, 6, n, n, n) metric fields.
      mask:  (E, n, n, n) Dirichlet mask (0/1 valued).
      c:     (E, n, n, n) inner-product weight (mask / multiplicity).
      grid:  element grid (EX, EY, EZ) with EX*EY*EZ == E.
      niter: iteration count (the paper runs 100).
      block_e: elements per VMEM block; default: autotuned divisor of E
               (kernels/autotune.py).
      interpret: force Pallas interpret mode (default: off-TPU detection).

    Returns a :class:`repro.core.cg.CGResult` whose ``rnorm_history`` matches
    ``cg_fixed_iters`` to round-off.
    """
    from repro.kernels import ops as kernel_ops

    E = b.shape[0]
    n = b.shape[-1]
    if interpret is None:
        interpret = kernel_ops.default_interpret()
    if block_e is None:
        block_e = _autotune.pick_block_e(E, n, b.dtype)
    while E % block_e:
        block_e //= 2                  # fused path avoids padding: divisor
    block_e = max(block_e, 1)

    n3 = n ** 3
    D = jnp.asarray(D, b.dtype)
    g2 = jnp.asarray(g, b.dtype).reshape(E, 6, n3)
    mask2 = jnp.asarray(mask, b.dtype).reshape(E, n3)
    c = jnp.asarray(c, b.dtype)
    return _cg_fused(b, D, D.T, g2, mask2, c, n=n, grid=tuple(grid),
                     niter=niter, block_e=block_e, interpret=interpret)
