"""Step-fused conjugate gradients: the whole iteration in Pallas kernels.

``cg_fixed_iters`` (core/cg.py) composes the operator and the three inner
products from separate XLA ops; per iteration the vectors ``p``, ``w``,
``r``, ``c`` are re-read from HBM for every reduction the paper's Eq. 2
charges for.  This module runs the iteration the way the cost model wants it
counted (DESIGN.md §3), at two fusion levels:

**v1** (:func:`cg_fused_fixed_iters`, DESIGN.md §3.3): one multi-output
Pallas kernel computes the masked local operator and the ``p·c·Ap`` partial
in the same VMEM residency; the direct-stiffness summation and the vector
updates remain XLA passes.  The ``r·c·r`` reduction is *carried* through the
loop state (it equals the previous iteration's post-update reduction), so
the kernel never re-reads ``r``/``c`` — 17 streams/iteration against
Eq. 2's 30.

**v2** (:func:`cg_fused_v2_fixed_iters`, DESIGN.md §3.4): zero standalone
full-field XLA passes.  The grid marches whole z-slabs, so the x/y
direct-stiffness summation and the intra-block z interfaces are summed on
the VMEM-resident kernel output; the two cross-block boundary planes travel
as O(E n^2) side outputs and are stitched in VMEM by a second, merged
vector-update kernel that also performs both axpys and the post-update
``r·c·r`` partial.  The ``p = r + beta p`` update folds into the next
iteration's operator kernel (beta enters as a scalar operand), and the
structured box's mask / inner-product weight are rebuilt in-kernel from
per-axis factors while the axis-aligned metric collapses to its diagonal —
13 streams/iteration.

**sharded** (:func:`cg_fused_sharded_fixed_iters`): the v1 pipeline per
shard inside ``shard_map``, with ``ds_sum_sharded`` exchanging the
cross-shard z-planes and the inner-product partials ``psum``-reduced.

All variants are *algebraically identical* to
:func:`repro.core.cg.cg_fixed_iters` with ``M = I``; the inner products are
summed in a different association (per-block then tree), so histories agree
to dtype round-off, which the fp64-interpret parity tests pin down
(tests/test_cg_fused.py, tests/test_cg_fused_v2.py).

Preconditions: ``b`` must be assembled ("continuous": coincident copies
equal — manufactured right-hand sides are) and masked; unpreconditioned CG
only (Nekbone's benchmark configuration and the paper's §V protocol).  The
v2 path additionally requires the structured axis-aligned box fields
(diagonal metric, factorizable mask — what ``BoxMesh`` produces).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

import repro.core.gs as gs_mod
from repro.core.cg import CGResult
from repro.core.geom import box_axis_factors, box_outer
from repro.kernels import autotune as _autotune
from repro.kernels import nekbone_ax as _ax

__all__ = ["cg_fused_fixed_iters", "cg_fused_v2_fixed_iters",
           "cg_fused_sharded_fixed_iters"]


def _acc_dtype(dtype) -> jnp.dtype:
    return jnp.float64 if dtype == jnp.float64 else jnp.float32


# ---------------------------------------------------------------------------
# v1: fused operator+pap kernel, XLA assembly and vector pass
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("n", "grid", "niter", "block_e",
                                             "interpret"))
def _cg_fused(b, D, Dt, g2, mask2, c, *, n: int,
              grid: tuple[int, int, int], niter: int, block_e: int,
              interpret: bool) -> CGResult:
    E = b.shape[0]
    n3 = n ** 3
    # inner products accumulate in f32 (f64 on the oracle path) even for
    # bf16 fields — matching the kernel partials' dtype; alpha/beta are cast
    # back so the fori_loop carry stays in the field dtype.
    acc = _acc_dtype(b.dtype)
    c_acc = c.astype(acc)
    # r·c·r is carried through the loop: each iteration's post-update
    # reduction (fused by XLA with the axpys that produce r) is next
    # iteration's rtz, so the kernel needs no r/c operands (DESIGN.md §3.3).
    rtz0 = jnp.sum(b.astype(acc) * c_acc * b.astype(acc))

    def body(k, state):
        x, r, p, rtz, hist = state
        hist = hist.at[k].set(jnp.sqrt(jnp.abs(rtz)).astype(b.dtype))
        w2, pap_b = _ax.nekbone_ax_pap_pallas(
            p.reshape(E, n3), D, Dt, g2, mask2,
            n=n, block_e=block_e, interpret=interpret)
        pap = jnp.sum(pap_b)            # tree-reduce the per-block partials
        # mask commutes with gs (coincident copies share their mask value),
        # so the kernel's masked output assembles directly.
        w = gs_mod.ds_sum_local(w2.reshape(b.shape), grid)
        alpha = (rtz / pap).astype(b.dtype)
        x = x + alpha * p
        r = r - alpha * w
        # fused by XLA with the axpy above; carried as the next rtz
        rtz_new = jnp.sum(r.astype(acc) * c_acc * r.astype(acc))
        beta = (rtz_new / rtz).astype(b.dtype)
        p = r + beta * p
        return x, r, p, rtz_new, hist

    x = jnp.zeros_like(b)
    hist0 = jnp.full((niter + 1,), jnp.nan, dtype=b.dtype)
    state = (x, b, b, rtz0, hist0)
    x, r, p, rtz_last, hist = jax.lax.fori_loop(0, niter, body, state)
    hist = hist.at[niter].set(jnp.sqrt(jnp.abs(rtz_last)).astype(b.dtype))
    return CGResult(x=x, iters=jnp.asarray(niter), rnorm=hist[niter],
                    rnorm_history=hist)


def cg_fused_fixed_iters(b: jnp.ndarray, *, D: jnp.ndarray, g: jnp.ndarray,
                         mask: jnp.ndarray, c: jnp.ndarray,
                         grid: tuple[int, int, int], niter: int,
                         block_e: int | None = None,
                         interpret: bool | None = None) -> CGResult:
    """Fixed-iteration CG through the fused-iteration Pallas pipeline (v1).

    Args:
      b:     (E, n, n, n) assembled, masked right-hand side.
      D:     (n, n) derivative matrix.
      g:     (E, 6, n, n, n) metric fields.
      mask:  (E, n, n, n) Dirichlet mask (0/1 valued).
      c:     (E, n, n, n) inner-product weight (mask / multiplicity).
      grid:  element grid (EX, EY, EZ) with EX*EY*EZ == E.
      niter: iteration count (the paper runs 100).
      block_e: elements per VMEM block; default: autotuned divisor of E
               (kernels/autotune.py).
      interpret: force Pallas interpret mode (default: off-TPU detection).

    Returns a :class:`repro.core.cg.CGResult` whose ``rnorm_history`` matches
    ``cg_fixed_iters`` to round-off.
    """
    from repro.kernels import ops as kernel_ops

    E = b.shape[0]
    n = b.shape[-1]
    if interpret is None:
        interpret = kernel_ops.default_interpret()
    if block_e is None:
        block_e = _autotune.pick_block_e(E, n, b.dtype)
    while E % block_e:
        block_e //= 2                  # fused path avoids padding: divisor
    block_e = max(block_e, 1)

    n3 = n ** 3
    D = jnp.asarray(D, b.dtype)
    g2 = jnp.asarray(g, b.dtype).reshape(E, 6, n3)
    mask2 = jnp.asarray(mask, b.dtype).reshape(E, n3)
    c = jnp.asarray(c, b.dtype)
    return _cg_fused(b, D, D.T, g2, mask2, c, n=n, grid=tuple(grid),
                     niter=niter, block_e=block_e, interpret=interpret)


# ---------------------------------------------------------------------------
# v2: slab gather-scatter + merged vector-update kernel
# ---------------------------------------------------------------------------

def _check_box_fields(grid, n, mask, c) -> None:
    """Verify caller-supplied mask/c match the structural box fields.

    The v2 kernels *rebuild* both from per-axis factors
    (``geom.box_axis_factors``), so silently accepting a different mask or
    weight would compute a different problem.  Skipped under tracing
    (concrete mesh fields are checked at build time).
    """
    (mx, my, mz), (cx, cy, cz) = box_axis_factors(grid, n)
    for name, field, want in (
            ("mask", mask, box_outer(mz, my, mx).reshape(-1, n, n, n)),
            ("c", c, box_outer(cz, cy, cx).reshape(-1, n, n, n))):
        if field is None:
            continue
        try:
            got = np.asarray(field, np.float64)
        except jax.errors.TracerArrayConversionError:
            continue
        if got.shape != want.shape or not np.array_equal(got, want):
            raise ValueError(
                f"pallas_fused_cg_v2 requires the structured box {name} "
                "(per-axis factorizable); supplied field differs")


@functools.partial(jax.jit, static_argnames=("n", "grid", "niter", "sz",
                                             "interpret"))
def _cg_fused_v2(b, D, Dt, g3, mx, my, mz, cx, cy, cz, *, n: int,
                 grid: tuple[int, int, int], niter: int, sz: int,
                 interpret: bool) -> CGResult:
    ex, ey, ez = grid
    E = b.shape[0]
    n3 = n ** 3
    pln = ey * ex * n * n
    acc = _acc_dtype(b.dtype)
    b2 = b.reshape(E, n3)
    # one-time initial reduction; c rebuilt from the factors in-jit (an XLA
    # constant) so no full-field weight operand enters the pipeline.
    c2 = box_outer(cz, cy, cx).reshape(E, n3).astype(acc)
    rtz0 = jnp.sum(b2.astype(acc) * c2 * b2.astype(acc))
    zero_plane = jnp.zeros((1, pln), b.dtype)

    def body(k, state):
        x2, r2, p2, rtz, beta, hist = state
        hist = hist.at[k].set(jnp.sqrt(jnp.abs(rtz)).astype(b.dtype))
        # front half: p = r + beta p, masked Ax, pap partial, in-block
        # assembly; boundary planes leave as (nblk, pln) side outputs.
        p2, w2, bot, top, pap_b = _ax.nekbone_ax_slab_pallas(
            p2, r2, D, Dt, g3, mx, my, mz, beta.reshape(1, 1),
            n=n, grid=grid, sz=sz, interpret=interpret)
        pap = jnp.sum(pap_b)
        alpha = rtz / pap
        # cross-block stitch operands: each block receives its neighbours'
        # boundary planes (zeros at the global ends) — O(E n^2) traffic.
        addb = jnp.concatenate([zero_plane, top[:-1]], axis=0)
        addt = jnp.concatenate([bot[1:], zero_plane], axis=0)
        # back half: stitch w in VMEM, both axpys, post-update r·c·r.
        x2, r2, rcr_b = _ax.nekbone_cg_update_pallas(
            x2, p2, r2, w2, addb, addt, alpha.reshape(1, 1), cx, cy, cz,
            n=n, grid=grid, sz=sz, interpret=interpret)
        rtz_new = jnp.sum(rcr_b)
        beta = rtz_new / rtz
        return x2, r2, p2, rtz_new, beta, hist

    hist0 = jnp.full((niter + 1,), jnp.nan, dtype=b.dtype)
    state = (jnp.zeros_like(b2), b2, jnp.zeros_like(b2), rtz0,
             jnp.zeros((), acc), hist0)
    x2, r2, p2, rtz_last, beta, hist = jax.lax.fori_loop(0, niter, body,
                                                         state)
    hist = hist.at[niter].set(jnp.sqrt(jnp.abs(rtz_last)).astype(b.dtype))
    return CGResult(x=x2.reshape(b.shape), iters=jnp.asarray(niter),
                    rnorm=hist[niter], rnorm_history=hist)


def cg_fused_v2_fixed_iters(b: jnp.ndarray, *, D: jnp.ndarray,
                            g: jnp.ndarray, grid: tuple[int, int, int],
                            niter: int, mask: jnp.ndarray | None = None,
                            c: jnp.ndarray | None = None,
                            sz: int | None = None,
                            interpret: bool | None = None) -> CGResult:
    """Fixed-iteration CG, whole iteration in two Pallas kernels (v2).

    Args:
      b:     (E, n, n, n) assembled, masked right-hand side; elements
             z-major over ``grid``.
      D:     (n, n) derivative matrix.
      g:     (E, 6, n, n, n) metric (off-diagonals must be zero — the
             axis-aligned box), or pre-packed (E, 3, n, n, n) diagonal.
      grid:  element grid (EX, EY, EZ).
      niter: iteration count.
      mask/c: optional — the kernels rebuild both from per-axis factors;
             when passed (concrete) they are validated against the
             structural fields and otherwise unused.
      sz:    slabs per block; default: autotuned divisor of EZ
             (kernels/autotune.pick_slab_sz).
      interpret: force Pallas interpret mode (default: off-TPU detection).

    Returns a :class:`repro.core.cg.CGResult` whose ``rnorm_history``
    matches ``cg_fixed_iters`` to round-off.
    """
    from repro.kernels import ops as kernel_ops

    E = b.shape[0]
    n = b.shape[-1]
    grid = tuple(grid)
    if interpret is None:
        interpret = kernel_ops.default_interpret()
    if sz is None:
        sz = _autotune.pick_slab_sz(grid, n, b.dtype)

    _check_box_fields(grid, n, mask, c)
    (mx, my, mz), (cx, cy, cz) = kernel_ops.slab_axis_factors(grid, n,
                                                             b.dtype)
    D = jnp.asarray(D, b.dtype)
    g3 = kernel_ops.diag_metric(jnp.asarray(g, b.dtype), E, n)
    return _cg_fused_v2(b, D, D.T, g3, mx, my, mz, cx, cy, cz, n=n,
                        grid=grid, niter=niter, sz=sz, interpret=interpret)


# ---------------------------------------------------------------------------
# sharded: the fused pipeline per shard inside shard_map
# ---------------------------------------------------------------------------

def cg_fused_sharded_fixed_iters(b: jnp.ndarray, *, D: jnp.ndarray,
                                 g: jnp.ndarray, mask: jnp.ndarray,
                                 c: jnp.ndarray,
                                 grid_local: tuple[int, int, int],
                                 axis_names, niter: int,
                                 block_e: int | None = None,
                                 interpret: bool | None = None) -> CGResult:
    """Fused-iteration CG with elements sharded along z, for ``shard_map``.

    Per shard and iteration: the fused operator+pap kernel on the local
    element block, ``ds_sum_sharded`` (core/gs.py) for the assembly — its
    ``halo_exchange_z`` ppermutes the cross-shard interface planes — and the
    XLA vector pass.  The two inner products are global: per-block kernel
    partials are summed locally, then ``psum``-reduced over ``axis_names``,
    so every shard sees identical ``alpha``/``beta`` and the iteration is
    SPMD-uniform.

    Args are the shard-local blocks (``b``: (E_local, n, n, n) etc.);
    ``grid_local`` is the local element grid (EX, EY, EZ_local).  The rtz
    carry matches :func:`cg_fused_fixed_iters`.
    """
    from repro.kernels import ops as kernel_ops

    E = b.shape[0]
    n = b.shape[-1]
    axis_names = tuple(axis_names)
    if interpret is None:
        interpret = kernel_ops.default_interpret()
    if block_e is None:
        block_e = _autotune.pick_block_e(E, n, b.dtype)
    while E % block_e:
        block_e //= 2
    block_e = max(block_e, 1)

    n3 = n ** 3
    D = jnp.asarray(D, b.dtype)
    Dt = D.T
    g2 = jnp.asarray(g, b.dtype).reshape(E, 6, n3)
    mask2 = jnp.asarray(mask, b.dtype).reshape(E, n3)
    acc = _acc_dtype(b.dtype)
    c_acc = jnp.asarray(c, b.dtype).astype(acc)

    def gsum(v):
        return jax.lax.psum(v, axis_names)

    rtz0 = gsum(jnp.sum(b.astype(acc) * c_acc * b.astype(acc)))

    def body(k, state):
        x, r, p, rtz, hist = state
        hist = hist.at[k].set(jnp.sqrt(jnp.abs(rtz)).astype(b.dtype))
        w2, pap_b = _ax.nekbone_ax_pap_pallas(
            p.reshape(E, n3), D, Dt, g2, mask2,
            n=n, block_e=block_e, interpret=interpret)
        pap = gsum(jnp.sum(pap_b))
        w = gs_mod.ds_sum_sharded(w2.reshape(b.shape), grid_local,
                                  axis_names)
        alpha = (rtz / pap).astype(b.dtype)
        x = x + alpha * p
        r = r - alpha * w
        rtz_new = gsum(jnp.sum(r.astype(acc) * c_acc * r.astype(acc)))
        beta = (rtz_new / rtz).astype(b.dtype)
        p = r + beta * p
        return x, r, p, rtz_new, hist

    x = jnp.zeros_like(b)
    hist0 = jnp.full((niter + 1,), jnp.nan, dtype=b.dtype)
    state = (x, b, b, rtz0, hist0)
    x, r, p, rtz_last, hist = jax.lax.fori_loop(0, niter, body, state)
    hist = hist.at[niter].set(jnp.sqrt(jnp.abs(rtz_last)).astype(b.dtype))
    return CGResult(x=x, iters=jnp.asarray(niter), rnorm=hist[niter],
                    rnorm_history=hist)
