"""Communication-avoiding (s-step) CG on the v3 matrix-powers pipeline.

The v2 pipeline (core/cg_fused.py, DESIGN.md §3.4) fixed the per-iteration
stream count at 13; what every iteration still re-reads is the *operator
data* — the 3 metric diagonals, D, the mask factors — plus two scalar
(alpha/beta) host round-trips per iteration.  s-step CG amortizes both by
restructuring s iterations into one **cycle** (DESIGN.md §8):

1. **matrix-powers kernel** (`kernels/nekbone_ax.nekbone_ax_powers_kernel`)
   — evaluates the scaled Krylov basis ``V = [p, A'p, .., A'^s p, r, A'r,
   .., A'^{s-1} r]`` (``A' = A/theta``) in a single slab residency: metric,
   D, and mask factors are loaded once per s operator applications, and the
   ``(2s+1)^2`` Gram block ``G = V^T C V`` is reduced in-kernel.
2. **host recurrence** (this module, :func:`sstep_recurrence`) — the s-step
   coefficient updates run on the ``(2s+1)``-vector *coordinates*: every
   alpha/beta of the cycle is a pair of O(s^2) quadratic forms in ``G``,
   solved in float64 regardless of the device or the ``jax_enable_x64``
   flag (numpy on host — "Gram/recurrence always wide", the §7 policy
   extended).  One device->host sync per cycle replaces the 2-per-iteration
   scalar round-trips of v1/v2.
3. **multi-axpy update kernel** (`nekbone_sstep_update_kernel`) — applies
   the whole s-step of x/r/p updates in one pass over the basis and emits
   the post-cycle ``r·c·r`` partial over the *stored* residual.

Stream budget per cycle: 5 reads + (2s-1) basis writes (powers kernel),
(2s+2) reads + 3 writes (update kernel) = ``4s + 9`` streams per s
iterations (`cost.sstep_streams`) — exactly the v2 budget at s=1, 6.25
streams/iteration at the default s=4.  The matrix-powers halo (s ghost
slabs per block side) is the side channel: ``10/sz`` stream-equivalents
per iteration (`cost.sstep_halo_streams`), <= 9 effective streams at
(s, sz) = (4, 4).

Stability: the monomial basis conditions the Gram block like
``kappa(A)^{2s}``; the theta scaling (a one-time power-iteration estimate
of ||A||) keeps basis norms O(1) but not the angles, so parity with
``cg_fixed_iters`` degrades as s grows — s <= 4 holds fp64 round-off
parity on the paper-grid cases (tests/test_cg_sstep.py), larger s needs a
Newton/Chebyshev basis (out of scope, DESIGN.md §8 documents the limit).

Preconditions are the v2 pipeline's: assembled+masked ``b``, the
structured axis-aligned box (diagonal metric, factorizable mask),
fixed-iteration unpreconditioned solves.  The ``precision`` policy
(DESIGN.md §7) composes unchanged: basis vectors stream in the storage
dtype (rounded through storage *inside* the kernel chain, so Gram and
stored basis describe the same vectors), contractions and Gram partials
accumulate wide, and :func:`repro.core.cg_fused.cg_ir_fixed_iters`
accepts ``variant="sstep"`` to run s-step sweeps inside iterative
refinement.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

import repro.core.gs as gs_mod
from repro.core.cg import CGResult, SolveResult
from repro.core.geom import box_axis_factors, box_outer
from repro.core.precision import resolve_policy
from repro.kernels import autotune as _autotune
from repro.kernels import nekbone_ax as _ax

__all__ = ["cg_sstep_fixed_iters", "sstep_recurrence", "cycle_coefficients",
           "estimate_theta", "sstep_cycle_traceables"]


def sstep_recurrence(G: np.ndarray, s: int, m: int, theta: float):
    """Run m (<= s) CG iterations on s-step basis coordinates, in float64.

    With ``V = [p, A'p, .., A'^s p, r, A'r, .., A'^{s-1} r]`` and
    ``A V = theta * V T`` (``T`` the block shift), the CG two-term
    recurrence closes on coefficient vectors:

        rtz_j   = b_j' G b_j
        alpha_j = rtz_j / (a_j' G (theta T a_j))
        e_{j+1} = e_j + alpha_j a_j            (x - x0 coordinates)
        b_{j+1} = b_j - alpha_j theta T a_j    (r coordinates)
        beta_j  = rtz_{j+1} / rtz_j
        a_{j+1} = b_{j+1} + beta_j a_j         (p coordinates)

    The degree argument keeps T total: p_j involves powers <= j of p and
    <= j-1 of r, so ``T a_j`` for j <= s-1 never needs the truncated
    columns.  Everything is float64 numpy — the Gram/recurrence stays wide
    whatever the device precision.

    Args:
      G: (2s+1, 2s+1) assembled Gram matrix ``V^T C V``.
      s: basis powers; m: iterations to advance (final cycle may be short).
      theta: the basis scale (``A' = A/theta``).

    Returns ``(e, b, a, rtz_hist)`` — the three coefficient vectors after
    m steps and the list of the m start-of-iteration ``rtz`` values.
    """
    K = 2 * s + 1
    G = np.asarray(G, np.float64).reshape(K, K)
    G = 0.5 * (G + G.T)                  # kernel partials are symmetric
    T = np.zeros((K, K))
    for j in range(s):
        T[j + 1, j] = theta              # A (A'^j p) = theta A'^{j+1} p
    for j in range(s - 1):
        T[s + 2 + j, s + 1 + j] = theta
    a = np.zeros(K)
    a[0] = 1.0                           # p
    b = np.zeros(K)
    b[s + 1] = 1.0                       # r
    e = np.zeros(K)
    rtz_hist = []
    rtz = float(b @ G @ b)
    for _ in range(m):
        rtz_hist.append(rtz)
        Ta = T @ a
        alpha = rtz / float(a @ G @ Ta)
        e = e + alpha * a
        b = b - alpha * Ta
        rtz_new = float(b @ G @ b)
        beta = rtz_new / rtz
        a = b + beta * a
        rtz = rtz_new
    return e, b, a, rtz_hist


def cycle_coefficients(G: np.ndarray, s: int, m: int, theta: float,
                       tol2: float | None = None):
    """One cycle's recurrence + in-cycle tolerance resolution, shared by the
    single-device driver below and the sharded one
    (:func:`repro.distributed.sstep.cg_sstep_sharded_fixed_iters`).

    Runs :func:`sstep_recurrence` for ``m`` steps; with ``tol2`` set,
    applies :func:`repro.core.cg.cg`'s stopping rule at *iteration*
    granularity — stop before the first iteration whose start-of-iteration
    ``rtz`` is ``<= tol2`` — by re-running the O(s^2) f64 recurrence for
    the shorter count, so the update kernel applies exactly the iterations
    taken.

    Returns ``(coef, rtzs, m)``: the stacked f64 ``(3, 2s+1)`` coefficient
    block (x/r/p rows — the update kernel's layout), the ``m``
    start-of-iteration rtz values actually run, and the resolved step
    count (``m == 0`` means the tolerance was already met at cycle start
    and nothing should be applied).
    """
    e_c, b_c, a_c, rtzs = sstep_recurrence(G, s, m, theta)
    if tol2 is not None:
        stop = next((j for j, v in enumerate(rtzs) if abs(v) <= tol2), None)
        if stop is not None:
            if stop == 0:
                return None, [], 0
            e_c, b_c, a_c, rtzs = sstep_recurrence(G, s, stop, theta)
            m = stop
    return np.stack([e_c, b_c, a_c]), rtzs, m


@functools.partial(jax.jit, static_argnames=("grid", "iters"))
def _theta_power_iter(D, g, mask, *, grid: tuple[int, int, int],
                      iters: int):
    """Whole power iteration in one jitted program (one host sync).

    Module-level so the jit cache is shared across solves — a per-call
    closure would re-trace every time.  Degenerate inputs (zero/non-finite
    operator norms) carry the previous theta forward; the caller maps a
    non-finite final value to 1.0.
    """
    from repro.core.ax import ax_local_fused

    tiny = jnp.asarray(np.finfo(np.float64).tiny, mask.dtype)
    v0 = jnp.linspace(1.0, 2.0, mask.size).reshape(mask.shape) \
        .astype(mask.dtype) * mask

    def body(_, carry):
        v, theta = carry
        w = gs_mod.ds_sum_local(ax_local_fused(v, D, g), grid) * mask
        nrm = jnp.max(jnp.abs(w))
        ok = jnp.isfinite(nrm) & (nrm > 0)
        theta = jnp.where(ok, nrm / jnp.maximum(jnp.max(jnp.abs(v)), tiny),
                          theta)
        v = jnp.where(ok, w / jnp.where(ok, nrm, 1.0), v)
        return v, theta

    _, theta = jax.lax.fori_loop(
        0, iters, body, (v0, jnp.ones((), mask.dtype)))
    return theta


def estimate_theta(D: jnp.ndarray, g: jnp.ndarray,
                   grid: tuple[int, int, int], mask: jnp.ndarray,
                   iters: int = 8) -> float:
    """Power-iteration estimate of ||A|| for the basis scale.

    Any fixed positive theta leaves the recurrence *exact* (it is a
    diagonal rescale of the basis, accounted for in T); a ||A||-sized one
    keeps the monomial basis norms O(1) so the f64 Gram stays conditioned.
    A handful of deterministic power iterations on the assembled masked
    operator suffice — a one-time setup cost per solve (pass ``theta=`` to
    :func:`cg_sstep_fixed_iters` to amortize it across solves).
    """
    theta = float(_theta_power_iter(jnp.asarray(D), jnp.asarray(g),
                                    jnp.asarray(mask), grid=tuple(grid),
                                    iters=iters))
    if not np.isfinite(theta) or theta <= 0.0:
        return 1.0
    return theta


@functools.partial(jax.jit, static_argnames=("n", "grid", "sz", "s",
                                             "interpret", "acc_name",
                                             "layout", "grid_order"))
def _powers_call(p2, r2, D, Dt, gext, mx, my, mzext, cx, cy, cz, inv_theta,
                 *, n: int, grid: tuple[int, int, int], sz: int, s: int,
                 interpret: bool, acc_name: str, layout: str = "fold",
                 grid_order: str = "parallel"):
    """Halo-window gather + the matrix-powers pallas_call, one cycle."""
    pext = _ax.sstep_extend_field(p2, grid, sz, s)
    rext = _ax.sstep_extend_field(r2, grid, sz, s)
    return _ax.nekbone_ax_powers_pallas(
        pext, rext, D, Dt, gext, mx, my, mzext, cx, cy, cz, inv_theta,
        n=n, grid=grid, sz=sz, s=s, interpret=interpret, acc_dtype=acc_name,
        layout=layout, grid_order=grid_order)


def sstep_cycle_traceables(D: jnp.ndarray, g: jnp.ndarray,
                           grid: tuple[int, int, int], *, s: int = 4,
                           sz: int = 4, precision=None):
    """One s-step cycle's two launches as traceable closures + arg specs.

    Replicates exactly the operand prep of :func:`cg_sstep_fixed_iters`
    (operator dtypes, halo'd metric window, extended z factors) and
    returns ``((powers_fn, powers_args), (update_fn, update_args))``
    where the args are :class:`jax.ShapeDtypeStruct` specs for the
    per-cycle vector operands.  ``jax.make_jaxpr(fn)(*args)`` then yields
    the same program the driver launches once per cycle — the
    measurement surface :mod:`repro.obs.drift` charges against the
    ``cost.py`` books without running a solve.
    """
    from repro.kernels import ops as kernel_ops

    grid = tuple(grid)
    n = int(jnp.asarray(D).shape[0])
    n3 = n ** 3
    E = int(np.prod(grid))
    policy = resolve_policy(precision, jnp.asarray(D).dtype)
    (mx, my, mz), (cx, cy, cz) = kernel_ops.slab_axis_factors(
        grid, n, policy.storage_dtype)
    D_op = jnp.asarray(D, policy.op_storage_dtype)
    g3 = kernel_ops.diag_metric(jnp.asarray(g, policy.op_storage_dtype),
                                E, n)
    gext = _ax.sstep_extend_field(g3, grid, sz, s)
    mzext = _ax.sstep_extend_zfactor(mz, sz, s)
    inv_theta = jnp.full((1, 1), 1.0, policy.accum_dtype)

    def powers_fn(p2, r2):
        return _powers_call(p2, r2, D_op, D_op.T, gext, mx, my, mzext,
                            cx, cy, cz, inv_theta, n=n, grid=grid, sz=sz,
                            s=s, interpret=True, acc_name=policy.accum)

    def update_fn(x2, p2, r2, basis, coef):
        return _ax.nekbone_sstep_update_pallas(
            x2, p2, r2, basis, coef, cx, cy, cz, n=n, grid=grid, sz=sz,
            s=s, interpret=True, acc_dtype=policy.accum)

    field = jax.ShapeDtypeStruct((E, n3), policy.storage_dtype)
    xf = jax.ShapeDtypeStruct((E, n3), policy.x_storage_dtype)
    basis = jax.ShapeDtypeStruct((E, 2 * s - 1, n3), policy.storage_dtype)
    coef = jax.ShapeDtypeStruct((3, 2 * s + 1), policy.accum_dtype)
    return ((powers_fn, (field, field)),
            (update_fn, (xf, field, field, basis, coef)))


def cg_sstep_fixed_iters(b: jnp.ndarray, *, D: jnp.ndarray, g: jnp.ndarray,
                         grid: tuple[int, int, int], niter: int, s: int = 4,
                         mask: jnp.ndarray | None = None,
                         c: jnp.ndarray | None = None,
                         sz: int | None = None, theta: float | None = None,
                         layout: str | None = None,
                         grid_order: str | None = None,
                         tol: float | None = None,
                         interpret: bool | None = None,
                         precision=None) -> CGResult:
    """Fixed-iteration s-step CG through the v3 matrix-powers pipeline.

    Args:
      b:     (E, n, n, n) assembled, masked right-hand side; elements
             z-major over ``grid``.
      D:     (n, n) derivative matrix.
      g:     (E, 6, n, n, n) axis-aligned metric, or pre-packed diagonal.
      grid:  element grid (EX, EY, EZ).
      niter: total CG iterations (any value — the final cycle runs the
             remainder ``niter % s`` recurrence steps on a full basis).
             With ``tol`` set this is the *ceiling* (``max_iter``).
      s:     iterations per cycle (s >= 1; s=1 degenerates to the v2
             stream budget, s=4 is the tuned default — DESIGN.md §8).
      mask/c: optional structural fields, validated like the v2 path.
      sz:    slabs per block (default: joint (sz, s) autotune,
             `kernels/autotune.pick_slab_sz_sstep`).
      layout, grid_order: powers-kernel contraction layout / grid
             iteration order (defaults: jointly autotuned with sz when
             all three are None, `kernels/autotune.pick_sstep_config`).
      theta: basis scale override (default: power-iteration ||A|| estimate).
      tol:   optional tolerance for early exit (DESIGN.md §9.4): stop, as
             :func:`repro.core.cg.cg` does, *before* the first iteration
             whose start-of-iteration ``rtz = r·c·r`` is ``<= tol**2``.
             The cycle's rtz values are the f64 Gram quadratic forms, so
             the stopping point is resolved to *iteration* granularity:
             the recurrence is re-run for the shorter step count and the
             update kernel applies exactly the iterations taken.  The
             returned ``iters`` is the count actually run.
      interpret: force Pallas interpret mode (default: off-TPU detection).
      precision: policy name / policy / ``None`` (DESIGN.md §7) — basis
             and vectors stream in the storage dtype, Gram partials in the
             accum dtype, the recurrence always in host float64.

    Returns a :class:`repro.core.cg.CGResult` whose ``rnorm_history``
    matches ``cg_fixed_iters`` to round-off for small s (the in-cycle
    entries are the f64 Gram quadratic forms ``sqrt(b_j' G b_j)``; the
    final entry is the update kernel's stored-residual reduction).  With
    ``tol``, the history holds the ``iters + 1`` entries actually
    produced — a prefix of the fixed-iteration trajectory.
    """
    from repro.core.cg_fused import _check_box_fields
    from repro.kernels import ops as kernel_ops

    if s < 1:
        raise ValueError(f"s-step CG needs s >= 1, got {s}")
    policy = resolve_policy(precision, b.dtype)
    b = jnp.asarray(b, policy.storage_dtype)
    E = b.shape[0]
    n = b.shape[-1]
    grid = tuple(grid)
    ex, ey, ez = grid
    if interpret is None:
        interpret = kernel_ops.default_interpret()
    if sz is None and layout is None and grid_order is None:
        sz, layout, grid_order = _autotune.pick_sstep_config(
            grid, n, s, b.dtype, acc_dtype=policy.accum)
    elif sz is None:
        sz = _autotune.pick_slab_sz_sstep(grid, n, s, b.dtype,
                                          acc_dtype=policy.accum)
    layout = "fold" if layout is None else layout
    grid_order = "parallel" if grid_order is None else grid_order

    _check_box_fields(grid, n, mask, c)
    (mx, my, mz), (cx, cy, cz) = kernel_ops.slab_axis_factors(grid, n,
                                                              b.dtype)
    n3 = n ** 3
    acc = policy.accum_dtype
    x_dtype = policy.x_storage_dtype
    # operator data in the policy's op-storage dtype (refined policies keep
    # it wide, DESIGN.md §7); the halo'd metric windows are built once per
    # solve — the per-cycle kernel reads are what the cost model charges.
    D_op = jnp.asarray(D, policy.op_storage_dtype)
    g3 = kernel_ops.diag_metric(jnp.asarray(g, policy.op_storage_dtype),
                                E, n)
    gext = _ax.sstep_extend_field(g3, grid, sz, s)
    mzext = _ax.sstep_extend_zfactor(mz, sz, s)
    if theta is None:
        if mask is None:
            mask = box_outer(
                *reversed(box_axis_factors(grid, n)[0])).reshape(b.shape)
        theta = estimate_theta(jnp.asarray(D, b.dtype),
                               jnp.asarray(g, b.dtype), grid,
                               jnp.asarray(mask, b.dtype))
    inv_theta = jnp.full((1, 1), 1.0 / theta, acc)

    tol2 = None if tol is None else float(tol) ** 2
    x2 = jnp.zeros((E, n3), x_dtype)
    r2 = p2 = b.reshape(E, n3)
    hist: list[float] = []
    rcr_last = None
    it = 0
    # tracing: the recorder is read once per solve; when off the loop
    # pays one local `is None` test per cycle and allocates nothing.
    from repro.obs import trace as _trace

    rec = _trace.active()
    while it < niter:
        # per-cycle tolerance check on the previous update kernel's stored-
        # residual reduction — the same quantity the next cycle's Gram
        # would report as its start-of-iteration rtz, one powers launch
        # earlier (DESIGN.md §9.4).
        if tol2 is not None and rcr_last is not None \
                and abs(float(rcr_last)) <= tol2:
            break
        m = min(s, niter - it)
        with (rec.span("sstep.cycle", it=it, s=s)
              if rec is not None else _trace.NULL_SPAN):
            with _trace.profiler_annotation("nekbone.sstep_powers"):
                basis, gram_b = _powers_call(
                    p2, r2, D_op, D_op.T, gext, mx, my, mzext, cx, cy,
                    cz, inv_theta, n=n, grid=grid, sz=sz, s=s,
                    interpret=interpret, acc_name=policy.accum,
                    layout=layout, grid_order=grid_order)
            # the policy's gram dtype is always float64
            # (PrecisionPolicy.gram); cycle_coefficients resolves the
            # in-cycle stop (run only the iterations whose start rtz is
            # still above tol^2 — exactly cg()'s while_loop semantics).
            G = np.asarray(jnp.sum(gram_b, axis=0), np.dtype(policy.gram))
            coef_np, rtzs, m = cycle_coefficients(G, s, m, theta, tol2)
            if m == 0:
                break
            hist.extend(np.sqrt(np.abs(v)) for v in rtzs)
            coef = jnp.asarray(coef_np, acc)
            with _trace.profiler_annotation("nekbone.sstep_update"):
                x2, r2, p2, rcr_b = _ax.nekbone_sstep_update_pallas(
                    x2, p2, r2, basis, coef, cx, cy, cz, n=n, grid=grid,
                    sz=sz, s=s, interpret=interpret,
                    acc_dtype=policy.accum)
            rcr_last = jnp.sum(rcr_b)
        it += m
        if tol2 is not None and m < s:
            break
    if rcr_last is None:                  # niter == 0 (or tol met at start)
        c2 = box_outer(cz, cy, cx).reshape(E, n3).astype(acc)
        rcr_last = jnp.sum(r2.astype(acc) * c2 * r2.astype(acc))
    hist.append(float(np.sqrt(abs(float(rcr_last)))))
    hist_arr = jnp.asarray(np.asarray(hist, np.float64), acc)
    return SolveResult.from_cg(
        CGResult(x=x2.reshape(b.shape), iters=jnp.asarray(it),
                 rnorm=hist_arr[-1], rnorm_history=hist_arr),
        pipeline="sstep_v3")
