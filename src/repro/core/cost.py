"""The paper's cost model (Eq. 1-2) and exact operation counts.

Paper §III-A:  per CG iteration over ``D`` degrees of freedom with ``n`` GLL
points per direction,

    C(D, n) = D * (12 n + 34)                 flops            (Eq. 1)
    reads   = 24 D,   writes = 6 D            fp64 words
    I(n)    = (12 n + 34) / 240               flop/byte (fp64) (Eq. 2)

The 12n term is the six contractions (3 forward + 3 transposed, 2n flops
each per point); the constant covers the metric application and the CG
vector operations.  We keep the model exactly as published and additionally
expose dtype-general byte counts (the TPU build runs fp32/bf16, which doubles
/ quadruples I(n) — see DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses

__all__ = ["flops_per_dof", "cg_iter_flops", "cg_iter_bytes", "intensity",
           "ax_local_flops", "ax_local_bytes", "roofline_gflops", "CostModel",
           "CG_READ_STREAMS", "CG_WRITE_STREAMS", "FUSED_CG_READ_STREAMS",
           "FUSED_CG_WRITE_STREAMS", "fused_cg_iter_bytes", "fused_intensity",
           "FUSED_V2_READ_STREAMS", "FUSED_V2_WRITE_STREAMS",
           "fused_v2_cg_iter_bytes", "fused_v2_intensity",
           "fused_v2_plane_streams", "PIPELINE_STREAMS", "PRECISION_ITEMSIZE",
           "precision_itemsize", "bytes_per_dof_iter", "pipeline_intensity",
           "pipeline_flops_per_dof",
           "ir_overhead_streams", "SSTEP_DEFAULT_S", "sstep_cycle_streams",
           "sstep_streams", "sstep_halo_streams", "sstep_effective_streams",
           "sstep_intensity", "JACOBI_V2_READ_STREAMS",
           "JACOBI_V2_WRITE_STREAMS", "CHEB_V2_READ_STREAMS",
           "CHEB_V2_WRITE_STREAMS", "CHEB_DEFAULT_K", "cheb_halo_streams",
           "cheb_effective_streams", "cheb_flops_per_dof",
           "sstep_collective_streams", "cheb_collective_streams",
           "v2_plane_collective_streams",
           "PMG_DEFAULT_K", "PMG_COARSE_ITERS", "PMG_SMOOTH_RATIO",
           "pmg_degrees", "pmg_dof_fracs", "pmg_vcycle_streams",
           "pmg_streams", "pmg_halo_streams", "pmg_effective_streams",
           "pmg_flops_per_dof"]

# Eq. 2's stream counts: fp64 words moved per DOF per CG iteration when the
# operator, mask, and every inner product run as separate passes.
CG_READ_STREAMS = 24
CG_WRITE_STREAMS = 6

# The fused-iteration pipeline v1 (core/cg_fused.py, DESIGN.md §3.3) moves:
#   kernel:      reads p, 6 metric fields, mask        (8)    writes w (1)
#   vector pass: reads x, p, r, w, c                   (5)    writes x, r, p (3)
# The r·c·r reduction is carried through the loop state (it is XLA-fused
# into the vector pass that produces r), so the kernel reads no r/c — the
# original 10-read kernel accounting (15R + 4W = 19) drops to 13R + 4W = 17.
# The per-block dot partials are E/block_e scalars — charged as zero streams.
FUSED_CG_READ_STREAMS = 13
FUSED_CG_WRITE_STREAMS = 4

# The v2 pipeline (core/cg_fused.py, DESIGN.md §3.4) runs the whole
# iteration in two slab-resident Pallas kernels:
#   dots kernel:   reads p, r, 3 metric diagonals      (5)    writes p, w (2)
#   update kernel: reads x, p, r, w                    (4)    writes x, r (2)
# The direct-stiffness summation happens in-kernel (x/y and intra-block z)
# plus an O(E n^2) boundary-plane side channel (fused_v2_plane_streams);
# the Dirichlet mask and the weight c are rebuilt in VMEM from per-axis
# factors (O(E^{1/3} n) operands), and the axis-aligned box metric is
# diagonal, so only 3 of Eq. 2's 6 metric streams exist.
FUSED_V2_READ_STREAMS = 9
FUSED_V2_WRITE_STREAMS = 4

# The v3 pipeline (core/cg_sstep.py, DESIGN.md §8) runs s CG iterations per
# *cycle*: a matrix-powers slab kernel builds the 2s+1-vector Krylov basis
# {p, Ap..A^s p, r, Ar..A^{s-1} r} in one residency (re-reading the 3 metric
# diagonals, D, and the mask factors once per s operator applications) and
# emits the (2s+1)^2 Gram partials; a multi-axpy update kernel applies the
# whole s-step of x/r/p updates.  Per cycle:
#   powers kernel: reads p, r, 3 metric diagonals   (5)  writes 2s-1 basis
#   update kernel: reads x + the 2s+1 basis (incl.  (2s+2)  writes x, r, p (3)
#                  p and r, re-read)
# = (2s+7) reads + (2s+2) writes = 4s+9 streams per s iterations.  At s=1
# this is exactly the v2 budget (13); at the default s=4 it is 25/4 = 6.25
# streams/iter.  Redundant halo reads (the matrix-powers ghost region) are
# the side channel (:func:`sstep_halo_streams`); the effective total stays
# <= 9 streams/iter at (s, sz) = (4, 4) (:func:`sstep_effective_streams`).
SSTEP_DEFAULT_S = 4


def sstep_cycle_streams(s: int) -> tuple[int, int]:
    """(reads, writes) full-field streams per s-step *cycle* (s iterations)."""
    return 2 * s + 7, 2 * s + 2


def sstep_streams(s: int) -> tuple[float, float]:
    """(reads, writes) streams per DOF per CG *iteration* of the v3 s-step
    pipeline — the per-cycle budget amortized by 1/s.  ``sstep_streams(1)``
    equals the v2 budget exactly: (9, 4)."""
    r, w = sstep_cycle_streams(s)
    return r / float(s), w / float(s)


def sstep_halo_streams(s: int, sz: int) -> float:
    """Stream-equivalents of the v3 matrix-powers halo, per iteration.

    Chaining s operator applications in one residency needs ``s`` ghost
    slabs on each side of an ``sz``-slab block (each application pollutes
    one slab inward from the block edge); the kernel redundantly reads the
    5 halo'd fields (p, r, 3 metric diagonals) over ``2s`` extra slabs per
    block: ``5 * 2s / sz`` stream-fractions per cycle.  Amortized over the
    cycle's s iterations the two s factors cancel — ``10/sz`` per
    iteration whatever s is; ``s`` stays a parameter so the derivation is
    auditable (the halo *depth* does scale with s).  The analog of
    :func:`fused_v2_plane_streams` — charged as a side channel, not folded
    into the headline count."""
    return 2.0 * 5.0 * float(s) / (float(sz) * float(s))


def sstep_collective_streams(s: int, ez_local: int) -> float:
    """Per-device stream-equivalents of the sharded s-step halo exchange
    (DESIGN.md §10), per iteration.

    Per cycle each device sends its s top and s bottom slabs of *two*
    fields (p and r, stacked into one exchange) and receives the same from
    its neighbours: ``2 fields * s slabs * 2 directions`` slab transfers
    each way.  A slab is ``1/ez_local`` of a device-local field, and every
    transfer both reads the send buffer and writes the receive buffer, so
    the cycle costs ``2 * 2*2*s / ez_local`` stream-fractions —
    ``8/ez_local`` per iteration after the 1/s amortization (the two s
    factors cancel, exactly as in :func:`sstep_halo_streams`; the depth
    scales with s, the per-iteration cost does not).  This is the network
    side channel the single-device accounting has no slot for; compare
    one exchange *per iteration* (``8s/ez_local``-equivalent) to see the
    communication-avoiding win."""
    return 2.0 * 2.0 * 2.0 * float(s) / (float(ez_local) * float(s))


def cheb_collective_streams(k: int, ez_local: int) -> float:
    """Per-device stream-equivalents of the sharded Chebyshev apply's
    k-deep residual ghost exchange, per iteration: 1 field * k slabs * 2
    directions, sent and received, every iteration — ``4k/ez_local``
    (no 1/s amortization, like :func:`cheb_halo_streams`)."""
    return 2.0 * 2.0 * float(k) / float(ez_local)


def v2_plane_collective_streams(n: int, ez_local: int) -> float:
    """Per-device stream-equivalents of the sharded v2-family plane stitch
    (one boundary plane per direction per iteration, sent and received):
    ``4 / (n * ez_local)`` — the cross-shard slice of
    :func:`fused_v2_plane_streams`."""
    return 2.0 * 2.0 / (float(n) * float(ez_local))


def _local_ez(ndev: int, ez: int | None) -> int:
    if ndev == 1:
        return 0                      # unused: collective terms are zero
    if ez is None:
        raise ValueError("ndev > 1 needs the global EZ (ez=) to size the "
                         "per-device halo")
    if ez % ndev:
        raise ValueError(f"EZ {ez} not divisible by ndev {ndev}")
    return ez // ndev


def sstep_effective_streams(s: int, sz: int, ndev: int = 1,
                            ez: int | None = None) -> float:
    """Headline + halo side channel (+ the per-device collective channel
    when ``ndev > 1``): total effective streams/iteration of the v3
    pipeline.  <= 9 at the default (s, sz) = (4, 4): 6.25 + 2.5.
    ``ndev=1`` is the exact single-device identity (no collective term);
    ``ndev > 1`` needs the global ``ez`` and adds
    :func:`sstep_collective_streams` at ``ez_local = ez/ndev``."""
    r, w = sstep_streams(s)
    total = r + w + sstep_halo_streams(s, sz)
    ez_l = _local_ez(ndev, ez)
    if ndev > 1:
        total += sstep_collective_streams(s, ez_l)
    return total


def sstep_intensity(n: int, s: int, itemsize: int = 8) -> float:
    """Eq. 2 re-evaluated for the s-step pipeline (headline streams)."""
    r, w = sstep_streams(s)
    return flops_per_dof(n) / ((r + w) * float(itemsize))


# Preconditioned v2 pipelines (core/precond.py, DESIGN.md §9).
#
# Jacobi: the solver carries the *preconditioned* residual z = D^-1 r, so
# the slab front-half is the v2 kernel unchanged (reads p, z, 3 metric
# diagonals; writes p, w) and the merged PCG update kernel adds exactly one
# stream — the assembled operator diagonal:
#   update kernel: reads x, p, z, w, invdiag    (5)    writes x, z (2)
# = 10R + 4W = 14 streams/iter, one more than unpreconditioned v2.
JACOBI_V2_READ_STREAMS = 10
JACOBI_V2_WRITE_STREAMS = 4

# Chebyshev(k): one extra kernel per iteration evaluates z = q_k(A) r in a
# single halo'd slab residency (the §8 matrix-powers machinery):
#   cheb kernel:   reads r, 3 metric diagonals  (4)    writes z (1)
#   slab kernel:   reads p, z, 3 metric         (5)    writes p, w (2)
#   update kernel: reads x, p, r, w             (4)    writes x, r (2)
# = 13R + 5W = 18 streams/iter regardless of k (the k chained operator
# applications stay in VMEM); the matrix-powers halo — 4 fields over 2k
# ghost slabs per block, every iteration — is the side channel
# (:func:`cheb_halo_streams`).  The win is the *iteration count*: the
# preconditioned solve trades 18 + 8k/sz effective streams/iter against a
# condition-number-driven iteration reduction (§9.3's bytes-to-solution
# accounting; the E=1024/n=10 acceptance case converges to 1e-8 in ~2x
# fewer iterations at k=4).
CHEB_V2_READ_STREAMS = 13
CHEB_V2_WRITE_STREAMS = 5
CHEB_DEFAULT_K = 4


def cheb_halo_streams(k: int, sz: int) -> float:
    """Stream-equivalents of the Chebyshev kernel's matrix-powers halo.

    k chained applications need k ghost slabs per block side (§8.2's
    pollution argument); the kernel redundantly reads its 4 halo'd fields
    (r + 3 metric diagonals) over ``2k`` extra slabs per ``sz``-slab
    block, *every* iteration: ``8k/sz`` stream-fractions — unlike the v3
    halo there is no 1/s amortization, so a deep polynomial wants large
    slabs.  Charged as a side channel, not the headline."""
    return 2.0 * 4.0 * float(k) / float(sz)


def cheb_effective_streams(k: int, sz: int, ndev: int = 1,
                           ez: int | None = None, n: int = 10) -> float:
    """Headline + halo: total effective streams/iter of Chebyshev-PCG.
    ``ndev > 1`` adds the per-device collective channel (residual ghosts
    + the v2 plane stitch at the given ``n``) at ``ez_local = ez/ndev``;
    ``ndev=1`` is the exact single-device identity."""
    total = (CHEB_V2_READ_STREAMS + CHEB_V2_WRITE_STREAMS
             + cheb_halo_streams(k, sz))
    ez_l = _local_ez(ndev, ez)
    if ndev > 1:
        total += cheb_collective_streams(k, ez_l)
        total += v2_plane_collective_streams(n, ez_l)
    return total


# p-multigrid V-cycle preconditioner (core/pmg.py, DESIGN.md §13): the
# degree ladder n -> ceil(n/2) -> ... -> 2, each fine level smoothed twice
# (pre + post) by the fused Chebyshev(k) apply kernel, a fixed-iteration CG
# base solve at n=2.  The books below are *exact per-V-cycle counts off the
# shipped implementation* (precond._pcg_pmg), scaled per level by the DOF
# fraction phi_l = (n_l / n)^3 — a level-l field is phi_l of one fine-grid
# stream.  Unlike every other rung this one deliberately *raises*
# streams/iter: it buys iteration count (>= 2x fewer than cheb4 on the
# acceptance case), which is what dominates time-to-solution once the
# per-iteration pipeline is at its traffic floor.
# Defaults tuned empirically on the paper acceptance case (E=1024, n=10,
# rtol 1e-8; sweep over k x ratio x coarse_iters, benchmarks/pmg_smoke.py
# re-checks in CI): k=3 @ ratio=24 reached 1e-8 in 13 iterations vs
# Chebyshev(4)'s 36 — k=2 @ ratio=8 needed 20, k=3 @ ratio=32 gave 12
# with less interval-safety margin; coarse_iters below 12 started costing
# iterations (14 at 6) while 40 bought nothing.
PMG_DEFAULT_K = 3
PMG_COARSE_ITERS = 12
PMG_SMOOTH_RATIO = 24.0

# Exact per-smoothed-level stream table of one symmetric V-cycle, in units
# of one *level-l* field (multiply by phi_l).  Derived line by line from
# precond._pcg_pmg — see DESIGN.md §13.4 for the audit:
#   pre-smooth (cheb kernel)      4R 1W   | prolong-add z+=m*e  3R 1W
#   A z #1     (v2 slab kernel)   5R 2W   | A z #2 (slab)       5R 2W
#   res1 = r - w                  2R 1W   | res2 = r - w        2R 1W
#   c-weight   t = c * res        2R 1W   | post-smooth (cheb)  4R 1W
#   restrict interp (fine side)   1R  -   | z += dz             2R 1W
_PMG_LEVEL_READS = 30.0
_PMG_LEVEL_WRITES = 12.0
# ... and per coarse-transition, in units of one *level-(l+1)* field: the
# restrict interp's output write, the gather-scatter+mask pass (2R 1W) and
# the prolong interp's input read.
_PMG_COARSE_SIDE_READS = 3.0
_PMG_COARSE_SIDE_WRITES = 2.0


def pmg_degrees(n: int) -> tuple[int, ...]:
    """The p-coarsening ladder ``n -> ceil(n/2) -> ... -> 2`` (HipBone's
    degree halving; GLL count n = degree + 1 so n=2 is the trilinear base).
    """
    if n < 2:
        raise ValueError(f"need n >= 2 GLL points, got {n}")
    ns = [int(n)]
    while ns[-1] > 2:
        ns.append((ns[-1] + 1) // 2)
    return tuple(ns)


def pmg_dof_fracs(n: int) -> tuple[float, ...]:
    """Per-level DOF fractions ``phi_l = (n_l / n)^3`` of the ladder."""
    return tuple((nl / float(n)) ** 3 for nl in pmg_degrees(n))


def pmg_vcycle_streams(n: int = 10,
                       coarse_iters: int = PMG_COARSE_ITERS
                       ) -> tuple[float, float]:
    """(reads, writes) full-*fine*-field streams of ONE symmetric V-cycle.

    Sum of the exact level table over the smoothed levels, the transition
    table over the level boundaries, and ``coarse_iters`` Eq.-2 CG
    iterations (the base solve is plain-XLA CG: 24R + 6W) at the base
    fraction.  k-independent like the cheb rung — the k chained operator
    applications of a smoother stay in VMEM; only the halo grows with k
    (:func:`pmg_halo_streams`)."""
    fr = pmg_dof_fracs(n)
    reads = sum(_PMG_LEVEL_READS * f for f in fr[:-1])
    reads += sum(_PMG_COARSE_SIDE_READS * f for f in fr[1:])
    reads += CG_READ_STREAMS * coarse_iters * fr[-1]
    writes = sum(_PMG_LEVEL_WRITES * f for f in fr[:-1])
    writes += sum(_PMG_COARSE_SIDE_WRITES * f for f in fr[1:])
    writes += CG_WRITE_STREAMS * coarse_iters * fr[-1]
    return reads, writes


def pmg_streams(n: int = 10, coarse_iters: int = PMG_COARSE_ITERS
                ) -> tuple[float, float]:
    """(reads, writes) streams per DOF per PCG iteration of the pmg rung:
    the v2 iteration (9 + 4) plus one V-cycle, exactly as the cheb rung is
    v2 plus one polynomial apply."""
    vr, vw = pmg_vcycle_streams(n, coarse_iters)
    return FUSED_V2_READ_STREAMS + vr, FUSED_V2_WRITE_STREAMS + vw


def pmg_halo_streams(n: int, k: int = PMG_DEFAULT_K,
                     sz: int = 4) -> tuple[float, float]:
    """(reads, writes) side-channel stream-equivalents of one V-cycle: per
    smoothed level, two Chebyshev-apply halos (:func:`cheb_halo_streams`,
    redundant reads) and two v2 slab plane stitches
    (:func:`fused_v2_plane_streams`, split evenly), each at the level's
    DOF fraction.  ``sz`` is applied at every level (the per-level
    autotuned splits differ; the books take one representative split —
    that is the *formula's* exactness boundary, stated here)."""
    fr = pmg_dof_fracs(n)
    ns = pmg_degrees(n)
    reads = writes = 0.0
    for nl, f in zip(ns[:-1], fr[:-1]):
        reads += 2.0 * cheb_halo_streams(k, sz) * f
        half = 2.0 * fused_v2_plane_streams(nl, sz) / 2.0
        reads += half * f
        writes += half * f
    return reads, writes


def pmg_effective_streams(n: int = 10, k: int = PMG_DEFAULT_K,
                          sz: int = 4,
                          coarse_iters: int = PMG_COARSE_ITERS) -> float:
    """Headline + halo/plane side channels: total effective streams per
    PCG iteration of the pmg rung (single-device; there is no sharded
    V-cycle yet)."""
    r, w = pmg_streams(n, coarse_iters)
    hr, hw = pmg_halo_streams(n, k, sz)
    return r + w + hr + hw


def pmg_flops_per_dof(n: int, k: int = PMG_DEFAULT_K,
                      coarse_iters: int = PMG_COARSE_ITERS) -> float:
    """Eq.-1 flops/DOF/iter of pmg-PCG: the v2 iteration plus, per
    smoothed level at its DOF fraction, two Chebyshev applies (k operator
    applications + recurrence axpys each), two explicit operator
    applications, the transfer contractions (3 directions x 2n_c flops
    per fine point, both directions of the transition) and ~8 glue axpys;
    plus the base-level CG iterations.  All of it free in the
    memory-bound regime — the V-cycle is paid for in streams, priced by
    :func:`pmg_streams`."""
    ns = pmg_degrees(n)
    fr = pmg_dof_fracs(n)
    total = float(flops_per_dof(n))
    for lev, (nl, f) in enumerate(zip(ns[:-1], fr[:-1])):
        level = 2.0 * k * (12 * nl + 17 + 6)      # pre+post smoother
        level += 2.0 * (12 * nl + 17)             # the two A z residuals
        level += 2.0 * 3.0 * 2.0 * ns[lev + 1]    # interp down + up
        level += 8.0                              # residual/correction glue
        total += f * level
    total += fr[-1] * coarse_iters * flops_per_dof(ns[-1])
    return total


def cheb_flops_per_dof(n: int, k: int = CHEB_DEFAULT_K) -> int:
    """Eq.-1 flops/DOF/iter of Chebyshev-PCG: the CG iteration plus k
    operator applications (12n + 17 each) and the 3-vector recurrence
    axpys (6 flops per application per point).  Free in the memory-bound
    regime (§1) — the polynomial raises intensity, not time."""
    return flops_per_dof(n) + k * (12 * n + 17 + 6)


def flops_per_dof(n: int) -> int:
    """Eq. 1 coefficient: flops per DOF per CG iteration."""
    return 12 * n + 34


def cg_iter_flops(ndof: int, n: int) -> int:
    """Eq. 1: C(D, n)."""
    return ndof * flops_per_dof(n)


def cg_iter_bytes(ndof: int, itemsize: int = 8) -> tuple[int, int]:
    """(read_bytes, write_bytes) per CG iteration: 24 D reads, 6 D writes."""
    return 24 * ndof * itemsize, 6 * ndof * itemsize


def intensity(n: int, itemsize: int = 8) -> float:
    """Eq. 2 generalized to dtype: I = (12n+34) / (30 * itemsize)."""
    return flops_per_dof(n) / (30.0 * itemsize)


def fused_cg_iter_bytes(ndof: int, itemsize: int = 8) -> tuple[int, int]:
    """(read_bytes, write_bytes) of the step-fused CG iteration (v1, with
    the carried r·c·r): 13 D reads, 4 D writes (vs Eq. 2's 24 + 6 — a
    30/17 ≈ 1.76x traffic cut)."""
    return (FUSED_CG_READ_STREAMS * ndof * itemsize,
            FUSED_CG_WRITE_STREAMS * ndof * itemsize)


def fused_intensity(n: int, itemsize: int = 8) -> float:
    """Eq. 2 re-evaluated for the fused pipeline: same flops over 17 streams."""
    return flops_per_dof(n) / (
        (FUSED_CG_READ_STREAMS + FUSED_CG_WRITE_STREAMS) * float(itemsize))


def fused_v2_cg_iter_bytes(ndof: int, itemsize: int = 8) -> tuple[int, int]:
    """(read_bytes, write_bytes) of the v2 two-kernel iteration: 9 D reads,
    4 D writes (vs Eq. 2's 24 + 6 — a 30/13 ≈ 2.31x traffic cut).  The
    boundary-plane side channel is excluded here; see
    :func:`fused_v2_plane_streams` for its (sub-stream) size."""
    return (FUSED_V2_READ_STREAMS * ndof * itemsize,
            FUSED_V2_WRITE_STREAMS * ndof * itemsize)


def fused_v2_intensity(n: int, itemsize: int = 8) -> float:
    """Eq. 2 re-evaluated for the v2 pipeline: same flops over 13 streams."""
    return flops_per_dof(n) / (
        (FUSED_V2_READ_STREAMS + FUSED_V2_WRITE_STREAMS) * float(itemsize))


def fused_v2_plane_streams(n: int, sz: int) -> float:
    """Stream-equivalents of the v2 boundary-plane side channel.

    Per slab block of ``sz`` slabs the dots kernel writes two
    ``EX*EY*n^2``-word planes and the update kernel reads them back:
    4 plane transfers per ``sz*EX*EY*n^3`` DOFs = ``4 / (n * sz)`` of one
    full stream (0.1 at the paper's n=10 with sz=4) — why the accounting
    charges them as ~zero."""
    return 4.0 / (float(n) * float(sz))


# ---------------------------------------------------------------------------
# multi-RHS (block) accounting (DESIGN.md §12): shared operator streams / b
# + per-RHS vector streams.  The serving amortization axis — the only way
# under the single-RHS floors.
# ---------------------------------------------------------------------------

# Full-field streams per iteration that are *operator-side* — read once per
# slab residency and shared across all b right-hand sides of a block solve:
# the 3 metric diagonals (rr, ss, tt).  D/D^T and the per-axis mask/weight
# factors are shared too but are sub-stream (n^2 and extent*n words) and
# charged as ~zero, exactly as in the single-RHS books.
MULTI_RHS_SHARED_STREAMS = 3.0

# The ladder's rung family: *_rhs{b} entries are pinned at these batches.
MULTI_RHS_BATCHES = (2, 4, 8)


def multi_rhs_streams(b: int, pipeline: str = "fused_v2", *,
                      s: int = SSTEP_DEFAULT_S) -> tuple[float, float]:
    """(reads, writes) full-field streams per DOF per iteration *per RHS*
    of a b-way block solve — the exact books of the amortization.

    ``fused_v2``: of the 9 read streams, 3 are the shared metric
    diagonals, 6 are per-RHS vectors (p, r, x carried via the update
    kernel's operands); all 4 write streams are per-RHS.  Per RHS:

        reads = 6 + 3/b,  writes = 4        (13 at b=1, down to 10.375
                                             at b=8 — floor 10)

    ``sstep_v3``: the same 3 shared streams sit inside the per-cycle
    budget (2s+7 reads, 2s+2 writes over s iterations), so composing the
    s-step cycle with a b-way block divides them by s*b:

        reads = (2s+4)/s + 3/(s*b),  writes = (2s+2)/s

    which recovers the 6.25-stream s=4 rung exactly at b=1 and drops
    below it for every b > 1 (5.59375 at b=8) — the pinned
    ``streams_per_rhs`` trajectory.
    """
    b = float(b)
    if b < 1:
        raise ValueError(f"RHS batch must be >= 1, got {b}")
    if pipeline == "fused_v2":
        reads = (FUSED_V2_READ_STREAMS - MULTI_RHS_SHARED_STREAMS
                 + MULTI_RHS_SHARED_STREAMS / b)
        return reads, float(FUSED_V2_WRITE_STREAMS)
    if pipeline == "sstep_v3":
        cr, cw = sstep_cycle_streams(s)
        reads = ((cr - MULTI_RHS_SHARED_STREAMS) / float(s)
                 + MULTI_RHS_SHARED_STREAMS / (float(s) * b))
        return reads, cw / float(s)
    raise ValueError(f"no multi-RHS books for pipeline {pipeline!r}")


def streams_per_rhs(b: int, pipeline: str = "fused_v2", *,
                    s: int = SSTEP_DEFAULT_S) -> float:
    """Total (reads + writes) streams per DOF per iteration per RHS —
    the single scalar the regression gate pins per (pipeline, b) row,
    strictly decreasing in b."""
    r, w = multi_rhs_streams(b, pipeline, s=s)
    return r + w


def multi_rhs_halo_streams(b: int, s: int, sz: int) -> float:
    """Per-RHS v3 matrix-powers halo of a b-way block solve.

    Of the 5 halo'd fields (:func:`sstep_halo_streams`), p and r are
    per-RHS while the 3 metric diagonals are read once for the whole
    batch: ``2s * (2 + 3/b) / (sz * s)`` = ``(4 + 6/b)/sz`` per
    iteration per RHS (the ``10/sz`` single-RHS channel at b=1)."""
    return 2.0 * float(s) * (2.0 + 3.0 / float(b)) / (float(sz) * float(s))


def _multi_rhs_rung(pipeline: str) -> tuple[str, int] | None:
    """Split a ``<base>_rhs<b>`` ladder rung into (base, b); None if the
    name is not a multi-RHS rung."""
    base, sep, tail = pipeline.rpartition("_rhs")
    if not sep or not tail.isdigit():
        return None
    return base, int(tail)


# ---------------------------------------------------------------------------
# dtype-aware accounting (DESIGN.md §7): the stream *counts* above are fixed
# per pipeline; the precision policy sets the bytes each stream carries.
# ---------------------------------------------------------------------------

# (reads, writes) full-field streams per DOF per CG iteration, per pipeline
# rung of the DESIGN.md §6 ladder.  The s-step rung is s-dependent
# (:func:`sstep_streams`); the registry entry carries the default s=4 point
# (fractional streams: the per-cycle budget amortized by 1/s).
PIPELINE_STREAMS = {
    "eq2": (CG_READ_STREAMS, CG_WRITE_STREAMS),
    "fused_v1": (FUSED_CG_READ_STREAMS, FUSED_CG_WRITE_STREAMS),
    "fused_v2": (FUSED_V2_READ_STREAMS, FUSED_V2_WRITE_STREAMS),
    "sstep_v3": sstep_streams(SSTEP_DEFAULT_S),
    # preconditioned rungs (DESIGN.md §9): same per-iteration accounting,
    # the Chebyshev one buys its extra 5 streams back in iteration count.
    "fused_v2_jacobi": (JACOBI_V2_READ_STREAMS, JACOBI_V2_WRITE_STREAMS),
    "fused_v2_cheb": (CHEB_V2_READ_STREAMS, CHEB_V2_WRITE_STREAMS),
    # the p-multigrid rung (DESIGN.md §13) at the paper point (n=10) and
    # the default base-solve depth: the one rung that *spends* streams per
    # iteration to buy iteration count.
    "fused_v2_pmg": pmg_streams(10, PMG_COARSE_ITERS),
}
# multi-RHS rung family (DESIGN.md §12): per-RHS streams of the b-way
# block solves, both standalone (batched v2) and composed with the s-step
# cycle.  Values are *per RHS* — the quantity that drops below every
# single-RHS floor.
PIPELINE_STREAMS.update({
    f"{base}_rhs{nb}": multi_rhs_streams(nb, base)
    for base in ("fused_v2", "sstep_v3") for nb in MULTI_RHS_BATCHES
})

# Storage-dtype bytes per word, per precision-policy name
# (core/precision.py).  The refined policies price like their storage: the
# refinement outer loop's high-precision pass is charged separately
# (:func:`ir_overhead_streams`), amortized over the inner iterations.
PRECISION_ITEMSIZE = {"f64": 8, "f32": 4, "bf16": 2,
                      "f32_ir": 4, "bf16_ir": 2}


def precision_itemsize(precision) -> int:
    """Storage bytes/word of a policy name or PrecisionPolicy instance."""
    itemsize = getattr(precision, "itemsize", None)
    if itemsize is not None:
        return int(itemsize)
    return PRECISION_ITEMSIZE[str(precision)]


def bytes_per_dof_iter(pipeline: str, precision, *, exact: bool = False,
                       n: int = 10, sz: int = 4,
                       s: int = SSTEP_DEFAULT_S,
                       k: int = CHEB_DEFAULT_K, ndev: int = 1,
                       ez: int | None = None) -> tuple[float, float]:
    """(read_bytes, write_bytes) per DOF per CG iteration for a pipeline
    rung under a precision policy — the ndof-independent quantity the CI
    regression gate diffs (benchmarks/check_regression.py).

    ``exact=True`` stops charging the sub-stream side channels as exactly
    zero: the v2 boundary-plane channel (:func:`fused_v2_plane_streams` at
    the given ``n``/``sz`` — 2 plane writes by the dots kernel, 2 plane
    reads by the update kernel, split evenly; the Jacobi and Chebyshev
    PCG rungs inherit it, they reuse those kernels), the v3 matrix-powers
    halo (:func:`sstep_halo_streams` — redundant *reads* only), and the
    Chebyshev apply kernel's per-iteration halo
    (:func:`cheb_halo_streams`, also reads) are folded in.  The eq2 and
    fused_v1 rungs have no modeled side channel (v1's uncounted assembly
    pass follows the original §3.3 books, see DESIGN.md §6), so their
    exact numbers equal the headline ones.

    ``ndev > 1`` (needs the global ``ez`` and ``exact=True``) adds the
    per-device collective channel of the *sharded* pipelines (DESIGN.md
    §10): the s-step ghost-slab exchange
    (:func:`sstep_collective_streams`), the Chebyshev residual ghosts
    (:func:`cheb_collective_streams`), and the v2-family plane stitch
    (:func:`v2_plane_collective_streams`), each split evenly into the
    send-buffer read and the receive-buffer write.  ``ndev=1`` is the
    exact single-device identity; pipelines without a sharded variant
    (eq2, fused_v1) reject ``ndev > 1`` rather than silently reporting
    single-device traffic.
    """
    reads, writes = PIPELINE_STREAMS[pipeline]
    if pipeline == "sstep_v3" and s != SSTEP_DEFAULT_S:
        reads, writes = sstep_streams(s)
    if pipeline == "fused_v2_pmg" and n != 10:
        reads, writes = pmg_streams(n)
    rhs_rung = _multi_rhs_rung(pipeline)
    if rhs_rung is not None and rhs_rung[0] == "sstep_v3" \
            and s != SSTEP_DEFAULT_S:
        reads, writes = multi_rhs_streams(rhs_rung[1], "sstep_v3", s=s)
    if ndev > 1 and pipeline not in ("sstep_v3", "fused_v2",
                                     "fused_v2_jacobi", "fused_v2_cheb"):
        raise ValueError(f"pipeline {pipeline!r} has no sharded variant; "
                         "ndev > 1 is not meaningful for it")
    if ndev > 1 and not exact:
        raise ValueError("ndev > 1 only affects the exact accounting; "
                         "pass exact=True")
    if exact:
        ez_l = _local_ez(ndev, ez)
        if pipeline in ("fused_v2", "fused_v2_jacobi", "fused_v2_cheb"):
            half = fused_v2_plane_streams(n, sz) / 2.0
            reads, writes = reads + half, writes + half
            if pipeline == "fused_v2_cheb":
                reads = reads + cheb_halo_streams(k, sz)
            if ndev > 1:
                half_c = v2_plane_collective_streams(n, ez_l) / 2.0
                reads, writes = reads + half_c, writes + half_c
                if pipeline == "fused_v2_cheb":
                    half_k = cheb_collective_streams(k, ez_l) / 2.0
                    reads, writes = reads + half_k, writes + half_k
        elif pipeline == "fused_v2_pmg":
            # the outer v2 iteration's plane stitch, then the V-cycle's
            # own per-level halo/plane channels (pmg uses the smoother's
            # default order, not the standalone-cheb k)
            half = fused_v2_plane_streams(n, sz) / 2.0
            hr, hw = pmg_halo_streams(n, PMG_DEFAULT_K, sz)
            reads, writes = reads + half + hr, writes + half + hw
        elif pipeline == "sstep_v3":
            reads = reads + sstep_halo_streams(s, sz)
            if ndev > 1:
                half_s = sstep_collective_streams(s, ez_l) / 2.0
                reads, writes = reads + half_s, writes + half_s
        elif rhs_rung is not None:
            base, nb = rhs_rung
            if base == "fused_v2":
                # the boundary-plane side channel is per-RHS (every RHS's
                # planes travel), so the per-RHS charge is the b=1 one.
                half = fused_v2_plane_streams(n, sz) / 2.0
                reads, writes = reads + half, writes + half
            else:  # sstep_v3_rhs{b}: metric halo shared across the batch
                reads = reads + multi_rhs_halo_streams(nb, s, sz)
    itemsize = precision_itemsize(precision)
    return reads * itemsize, writes * itemsize


def pipeline_intensity(n: int, pipeline: str, precision) -> float:
    """Eq. 2 arithmetic intensity of a (pipeline, precision) point:
    same (12n + 34) flops over the policy-priced streams."""
    return pipeline_flops_per_dof(n, pipeline) / float(
        sum(bytes_per_dof_iter(pipeline, precision)))


def pipeline_flops_per_dof(n: int, pipeline: str, *,
                           s: int = SSTEP_DEFAULT_S,
                           k: int = CHEB_DEFAULT_K) -> float:
    """Eq.-1 flops per DOF per CG *iteration* of a pipeline rung.

    The fusion ladder (eq2, fused_v1, fused_v2, sstep_v3) moves the same
    arithmetic through fewer streams, so every rung keeps Eq. 1's
    (12n + 34); Jacobi-PCG adds the diagonal scale + the extra rtz books
    (~3 flops/DOF/iter on the merged update); Chebyshev-PCG adds k
    operator applications per iteration (:func:`cheb_flops_per_dof`) —
    its win is the *iteration count*, not the per-iteration rate."""
    if pipeline in ("eq2", "fused_v1", "fused_v2", "sstep_v3"):
        return float(flops_per_dof(n))
    if _multi_rhs_rung(pipeline) is not None:
        # block solves amortize *streams*, not arithmetic: every RHS does
        # full Eq.-1 work per iteration.
        return float(flops_per_dof(n))
    if pipeline == "fused_v2_jacobi":
        return float(flops_per_dof(n) + 3)
    if pipeline == "fused_v2_cheb":
        return float(cheb_flops_per_dof(n, k))
    if pipeline == "fused_v2_pmg":
        return pmg_flops_per_dof(n)
    raise ValueError(f"unknown pipeline {pipeline!r}")


def ir_overhead_streams(inner_iters: int, hi_itemsize: int = 8,
                        itemsize: int = 2) -> float:
    """Storage-stream equivalents the refinement outer loop adds per inner
    iteration.

    Each sweep runs one high-precision pass — the operator refresh
    (7R + 1W), the residual/solution axpys (4R + 2W) — ~14 ``hi_itemsize``
    words/DOF, amortized over ``inner_iters`` low-precision iterations and
    expressed in units of one storage-dtype stream.  At the defaults
    (bf16 inner, f64 outer, 12 inner iters) that is ~4.7 extra bf16
    streams on the v2 budget's 13: ~35 bytes/DOF/iter against unrefined
    f32 v2's 52 — the refined pipeline still moves ~1.5x fewer bytes."""
    return 14.0 * float(hi_itemsize) / (float(itemsize) * float(inner_iters))


def ax_local_flops(nelt: int, n: int) -> int:
    """Exact flops of the local tensor-product operator (both stages).

    Per point: 3 forward contractions (2n each), metric apply
    (6 mul + ... = 15: 9 mul + 6 add), 3 transposed contractions (2n each)
    summed into w (2 adds) => 12n + 17.
    """
    return nelt * n ** 3 * (12 * n + 17)


def ax_local_bytes(nelt: int, n: int, itemsize: int = 8) -> tuple[int, int]:
    """Minimal HBM traffic of the fused local operator.

    Reads: u (1 field) + G (6 fields) (+ D, negligible); writes: w (1 field).
    """
    ndof = nelt * n ** 3
    return 7 * ndof * itemsize, 1 * ndof * itemsize


def roofline_gflops(bandwidth_gbs: float, n: int, itemsize: int = 8) -> float:
    """Memory-roofline performance bound: BW * I(n) (paper §VI-B)."""
    return bandwidth_gbs * intensity(n, itemsize)


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Cost model instance for a given case size."""

    nelt: int
    n: int
    itemsize: int = 8

    @property
    def ndof(self) -> int:
        return self.nelt * self.n ** 3

    @property
    def cg_flops(self) -> int:
        return cg_iter_flops(self.ndof, self.n)

    @property
    def cg_read_bytes(self) -> int:
        return cg_iter_bytes(self.ndof, self.itemsize)[0]

    @property
    def cg_write_bytes(self) -> int:
        return cg_iter_bytes(self.ndof, self.itemsize)[1]

    @property
    def intensity(self) -> float:
        return intensity(self.n, self.itemsize)
