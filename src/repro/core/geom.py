"""Nekbone box-mesh geometry: elements, geometric factors, masks.

Nekbone discretizes the Poisson equation on a rectangular box split into a
structured ``EX x EY x EZ`` grid of hexahedral elements, each holding
``n^3`` GLL nodes.  All per-element fields use layout ``(E, k, j, i)`` with
``i`` the x-direction (fastest), matching Nekbone's Fortran ``u(i,j,k,e)``
(reversed index order, same memory order).

The Poisson operator needs the 6 unique entries of the symmetric metric
``G = w3 * J * (d xi / d x) (d xi / d x)^T`` per node; for affine box elements
only the diagonal (rr, ss, tt) entries are non-zero.  Entry order follows the
paper's Listing 1: ``(rr, rs, rt, ss, st, tt)``.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.sem import SEMOperators

__all__ = ["BoxMesh", "random_spd_metric", "axis_mask_factor",
           "axis_mult_factor", "box_axis_factors", "box_outer", "GEOM_RR",
           "GEOM_RS", "GEOM_RT", "GEOM_SS", "GEOM_ST", "GEOM_TT"]

GEOM_RR, GEOM_RS, GEOM_RT, GEOM_SS, GEOM_ST, GEOM_TT = range(6)


def axis_mask_factor(ne: int, n: int) -> np.ndarray:
    """Per-direction Dirichlet factor, shape ``(ne, n)``.

    The box mask is the outer product of these three factors: a node is
    masked iff it sits on the domain boundary in *some* direction, and 0/1
    products realize exactly that.  The slab kernels (kernels/nekbone_ax.py)
    rebuild the full mask from them in VMEM — three ``(extent, n)`` arrays
    instead of an ``(E, n^3)`` HBM stream.
    """
    m = np.ones((ne, n), dtype=np.float64)
    m[0, 0] = 0.0
    m[-1, -1] = 0.0
    return m


def axis_mult_factor(ne: int, n: int) -> np.ndarray:
    """Per-direction node multiplicity, shape ``(ne, n)``.

    A node on an interior element face is shared by 2 elements along that
    direction; multiplicities multiply across directions, so the full
    multiplicity field is the outer product of the three factors.
    """
    m = np.ones((ne, n), dtype=np.float64)
    if ne > 1:
        m[:-1, -1] = 2.0
        m[1:, 0] = 2.0
    return m


def box_axis_factors(shape: tuple[int, int, int], n: int):
    """Per-axis mask and ``c = mask/mult`` factors of the structured box.

    Returns ``((mx, my, mz), (cx, cy, cz))``, each ``(extent, n)`` float64;
    outer products over (z, y, x) reproduce :meth:`BoxMesh.dirichlet_mask`
    and ``mask/multiplicity`` bitwise (every value is an exact binary
    fraction).  The single source of the factorization the v2 slab kernels
    rebuild in VMEM.
    """
    masks = tuple(axis_mask_factor(ne, n) for ne in shape)
    cs = tuple(axis_mask_factor(ne, n) / axis_mult_factor(ne, n)
               for ne in shape)
    return masks, cs


def box_outer(fz, fy, fx):
    """Outer product of per-axis ``(extent, n)`` factors over the box.

    Returns ``(EZ, EY, EX, n, n, n)`` indexed ``(ez, ey, ex, k, j, i)`` —
    the element-grid view of :meth:`BoxMesh.grid_view`.  Pure broadcasting,
    so it accepts numpy and jax arrays alike; reshape ``(-1, n, n, n)`` for
    the flat element layout.
    """
    return (fz[:, None, None, :, None, None]
            * fy[None, :, None, None, :, None]
            * fx[None, None, :, None, None, :])


@dataclasses.dataclass(frozen=True)
class BoxMesh:
    """Structured box of spectral elements.

    Attributes:
      n:       GLL points per direction per element.
      shape:   element-grid extents ``(EX, EY, EZ)``.
      lengths: physical box size ``(Lx, Ly, Lz)``.
    """

    n: int
    shape: tuple[int, int, int]
    lengths: tuple[float, float, float] = (1.0, 1.0, 1.0)

    # ---- basic sizes -----------------------------------------------------
    @property
    def nelt(self) -> int:
        ex, ey, ez = self.shape
        return ex * ey * ez

    @property
    def nxyz(self) -> int:
        return self.n ** 3

    @property
    def ndof(self) -> int:
        """Element-local (duplicated) degrees of freedom, Nekbone's ``D``."""
        return self.nelt * self.nxyz

    @property
    def nunique(self) -> int:
        """Globally unique grid points."""
        ex, ey, ez = self.shape
        N = self.n - 1
        return (ex * N + 1) * (ey * N + 1) * (ez * N + 1)

    @property
    def element_size(self) -> tuple[float, float, float]:
        ex, ey, ez = self.shape
        lx, ly, lz = self.lengths
        return lx / ex, ly / ey, lz / ez

    @property
    def ops(self) -> SEMOperators:
        return SEMOperators(self.n)

    # ---- element-grid view ----------------------------------------------
    def grid_view(self, u: np.ndarray) -> np.ndarray:
        """Reshape ``(E, n, n, n)`` -> ``(EZ, EY, EX, n, n, n)`` (e = z-major)."""
        ex, ey, ez = self.shape
        return u.reshape((ez, ey, ex) + u.shape[1:])

    # ---- geometry --------------------------------------------------------
    def geometric_factors(self) -> np.ndarray:
        """Metric ``G`` for the Poisson operator, shape ``(E, 6, n, n, n)``.

        For an affine element of physical size (hx, hy, hz):
          J = hx hy hz / 8,  d r/d x = 2/hx (etc., diagonal), so
          G_rr = w3 * J * (2/hx)^2 = w3 * hy*hz / (2*hx),   off-diagonals 0.
        """
        hx, hy, hz = self.element_size
        w3 = self.ops.w3  # (n, n, n), indexed (k, j, i)
        g = np.zeros((self.nelt, 6, self.n, self.n, self.n), dtype=np.float64)
        g[:, GEOM_RR] = w3 * (hy * hz) / (2.0 * hx)
        g[:, GEOM_SS] = w3 * (hx * hz) / (2.0 * hy)
        g[:, GEOM_TT] = w3 * (hx * hy) / (2.0 * hz)
        return g

    def mass(self) -> np.ndarray:
        """Diagonal (lumped) mass matrix entries, shape ``(E, n, n, n)``.

        ``B = w_i w_j w_k * J`` — exact for the GLL-collocated SEM mass.
        """
        hx, hy, hz = self.element_size
        jac = hx * hy * hz / 8.0
        b = np.broadcast_to(self.ops.w3 * jac,
                            (self.nelt, self.n, self.n, self.n))
        return np.ascontiguousarray(b)

    def coords(self) -> np.ndarray:
        """Physical node coordinates, shape ``(E, n, n, n, 3)``."""
        ex, ey, ez = self.shape
        hx, hy, hz = self.element_size
        z1 = (self.ops.z + 1.0) / 2.0  # reference -> [0,1]
        xs = np.zeros((ez, ey, ex, self.n, self.n, self.n, 3))
        for e_z in range(ez):
            for e_y in range(ey):
                for e_x in range(ex):
                    x = (e_x + z1) * hx
                    y = (e_y + z1) * hy
                    z = (e_z + z1) * hz
                    xs[e_z, e_y, e_x, ..., 0] = x[None, None, :]
                    xs[e_z, e_y, e_x, ..., 1] = y[None, :, None]
                    xs[e_z, e_y, e_x, ..., 2] = z[:, None, None]
        return xs.reshape(self.nelt, self.n, self.n, self.n, 3)

    def dirichlet_mask(self) -> np.ndarray:
        """1.0 on interior nodes, 0.0 on the domain boundary, ``(E, n, n, n)``.

        Outer product of the three :func:`axis_mask_factor` arrays — the
        factorization the slab kernels exploit to avoid streaming the mask.
        """
        ex, ey, ez = self.shape
        m = box_outer(axis_mask_factor(ez, self.n),
                      axis_mask_factor(ey, self.n),
                      axis_mask_factor(ex, self.n))
        return np.ascontiguousarray(m.reshape(self.nelt, self.n, self.n, self.n))

    def multiplicity(self) -> np.ndarray:
        """Number of elements sharing each node, ``(E, n, n, n)``.

        Computed structurally: outer product of the three
        :func:`axis_mult_factor` arrays (faces -> 2, edges -> 4,
        corners -> 8).
        """
        ex, ey, ez = self.shape
        m = box_outer(axis_mult_factor(ez, self.n),
                      axis_mult_factor(ey, self.n),
                      axis_mult_factor(ex, self.n))
        return np.ascontiguousarray(m.reshape(self.nelt, self.n, self.n, self.n))


def random_spd_metric(rng: np.random.Generator, nelt: int, n: int,
                      jitter: float = 0.2) -> np.ndarray:
    """Random symmetric-positive-definite metric, shape ``(E, 6, n, n, n)``.

    Used by property tests: the Poisson operator built from any SPD metric
    must itself be symmetric positive semi-definite.
    """
    # Build G = L L^T + eps*I from a random L per node, then scale.
    L = rng.normal(size=(nelt, 3, 3, n, n, n)) * jitter
    L = L + np.eye(3)[None, :, :, None, None, None]
    G = np.einsum("eab...,ecb...->eac...", L, L)
    out = np.empty((nelt, 6, n, n, n))
    out[:, GEOM_RR] = G[:, 0, 0]
    out[:, GEOM_RS] = G[:, 0, 1]
    out[:, GEOM_RT] = G[:, 0, 2]
    out[:, GEOM_SS] = G[:, 1, 1]
    out[:, GEOM_ST] = G[:, 1, 2]
    out[:, GEOM_TT] = G[:, 2, 2]
    return out
