"""Gather-scatter (direct stiffness summation) for the structured box mesh.

Nekbone's ``gs_op`` sums the values of coincident nodes on shared element
faces/edges/corners so every copy holds the assembled value.  On the
structured box this reduces to, per direction, summing the two coincident
node planes of neighbouring elements — applied direction-by-direction the
edge/corner cases compose correctly (the operation is associative).

Distribution: elements are sharded along the *outermost* element-grid axis
(z).  Each shard performs the local summation, then exchanges its outer
boundary planes with its neighbours via ``lax.ppermute`` — the TPU analog of
Nekbone's nearest-neighbour MPI exchange.  The shard axis may be a hierarchy
(e.g. ``('pod', 'data')``): the exchange handles inner-axis neighbours and
the pod-boundary crossings with masked permutes, uniformly SPMD.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import compat

__all__ = ["ds_sum_local", "ds_sum_sharded", "halo_exchange_z"]


def ds_sum_local(u: jnp.ndarray, grid: tuple[int, int, int]) -> jnp.ndarray:
    """Direct-stiffness sum over a local (un-sharded) element grid.

    Args:
      u:    ``(E, n, n, n)`` with ``E = EX*EY*EZ`` and e z-major
            (``e = (ez*EY + ey)*EX + ex``), local layout ``(k, j, i)``.
      grid: ``(EX, EY, EZ)``.

    Returns the assembled field, same shape; coincident nodes carry the sum.
    """
    ex, ey, ez = grid
    n = u.shape[-1]
    v = u.reshape(ez, ey, ex, n, n, n)

    if ex > 1:  # x-direction: face i = n-1 of (.., ex) meets i = 0 of (.., ex+1)
        s = v[:, :, :-1, :, :, -1] + v[:, :, 1:, :, :, 0]
        v = v.at[:, :, :-1, :, :, -1].set(s)
        v = v.at[:, :, 1:, :, :, 0].set(s)
    if ey > 1:  # y-direction
        s = v[:, :-1, :, :, -1, :] + v[:, 1:, :, :, 0, :]
        v = v.at[:, :-1, :, :, -1, :].set(s)
        v = v.at[:, 1:, :, :, 0, :].set(s)
    if ez > 1:  # z-direction
        s = v[:-1, :, :, -1, :, :] + v[1:, :, :, 0, :, :]
        v = v.at[:-1, :, :, -1, :, :].set(s)
        v = v.at[1:, :, :, 0, :, :].set(s)
    return v.reshape(u.shape)


def _flat_shift(v: jnp.ndarray, axis_names: tuple, up: bool) -> jnp.ndarray:
    """Value of ``v`` on the previous (``up``) / next (``down``) shard in the
    lexicographic flattening of ``axis_names``; zeros at the global boundary.

    Recursive carry scheme: a cyclic permute over the innermost axis moves
    every block one step; blocks that wrapped around (crossed an inner-group
    boundary) are corrected by recursively flat-shifting them over the outer
    axes — exactly positional addition with carries.
    """
    axis_names = tuple(axis_names)
    inner = axis_names[-1]
    n = compat.axis_size(inner)
    idx = jax.lax.axis_index(inner)
    if up:
        perm = [(i, (i + 1) % n) for i in range(n)]
        at_edge = (idx == 0)                 # received a wrapped block
    else:
        perm = [((i + 1) % n, i) for i in range(n)]
        at_edge = (idx == n - 1)
    y = jax.lax.ppermute(v, inner, perm)
    edge = at_edge.astype(v.dtype)
    if len(axis_names) == 1:
        return y * (1.0 - edge)              # global boundary: zeros
    fix = _flat_shift(y * edge, axis_names[:-1], up)
    return y * (1.0 - edge) + fix * edge


def halo_exchange_z(top: jnp.ndarray, bottom: jnp.ndarray, axis_names):
    """Exchange z-boundary planes between lexicographic shard neighbours.

    Every shard sends ``top`` to the next shard and ``bottom`` to the
    previous shard in the flattened ``axis_names`` order (hierarchies like
    ``('pod', 'data')`` compose via carry permutes).  Returns
    ``(from_below, from_above)`` — zeros at the global boundaries, so
    callers can add unconditionally.
    """
    from_below = _flat_shift(top, axis_names, up=True)
    from_above = _flat_shift(bottom, axis_names, up=False)
    return from_below, from_above


def ds_sum_sharded(u: jnp.ndarray, grid_local: tuple[int, int, int],
                   axis_names) -> jnp.ndarray:
    """Direct-stiffness sum where the z element axis is sharded.

    To be called *inside* ``shard_map``.  ``u`` is the shard-local block
    ``(E_local, n, n, n)``; ``grid_local`` its local element grid
    ``(EX, EY, EZ_local)``.  The z interface planes between shards are
    exchanged with :func:`halo_exchange_z` and summed.

    The local pass runs first; because the cross-shard interface is a z-plane
    and the x/y summations act within that plane on each side independently,
    local-then-exchange produces the fully assembled result.
    """
    ex, ey, ez_l = grid_local
    n = u.shape[-1]
    v = ds_sum_local(u, grid_local).reshape(ez_l, ey, ex, n, n, n)

    top = v[-1, :, :, -1, :, :]     # (ey, ex, n, n) plane at local k = n-1
    bottom = v[0, :, :, 0, :, :]
    from_below, from_above = halo_exchange_z(top, bottom, axis_names)
    v = v.at[0, :, :, 0, :, :].add(from_below)
    v = v.at[-1, :, :, -1, :, :].add(from_above)
    return v.reshape(u.shape)
