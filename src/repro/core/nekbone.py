"""End-to-end Nekbone case: SEM Poisson on a box, solved with CG.

This is the composable entry point for the paper's system:

    case = NekboneCase(n=10, grid=(8, 8, 16))     # degree 9, 1024 elements
    res  = case.solve_manufactured(niter=100)      # paper's benchmark run
    err  = case.solution_error(res.x)

The operator pipeline is exactly Nekbone's ``ax``:
    w = mask( gather_scatter( ax_local(u) ) )
with ``ax_local`` selectable between the paper-faithful Listing-1 version,
the XLA-fused version, and the Pallas TPU kernel (DESIGN.md §2).

Distribution: :meth:`sharded_ops` returns the same functions expressed for a
``shard_map`` over a device mesh, sharding elements along the z element axis
and assembling interfaces with a ppermute halo exchange (core/gs.py).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp
import numpy as np

import repro.core.ax as ax_mod
import repro.core.cg as cg_mod
import repro.core.cg_fused as cg_fused_mod
import repro.core.gs as gs_mod
from repro.core.cost import CostModel
from repro.core.geom import BoxMesh

__all__ = ["NekboneCase"]


@dataclasses.dataclass
class NekboneCase:
    """A runnable Nekbone problem instance.

    Args:
      n:       GLL points per direction (degree + 1). Paper uses 10.
      grid:    element grid (EX, EY, EZ).
      lengths: physical box size.
      dtype:   compute dtype (fp64 validated on CPU; fp32/bf16 TPU target).
      ax_impl: 'listing1' | 'fused' | 'pallas' | 'pallas_fused_cg' |
               'pallas_fused_cg_v2' | 'pallas_sstep_v3' | 'auto'.
               'auto' resolves at construction to the measured-fastest
               fused pipeline for this case shape via the autotune cache
               (kernels/autotune.pick_pipeline): on TPU both fused CG
               pipelines are timed once per (backend, case key) and the
               winner is persisted; elsewhere the documented E-threshold
               heuristic applies (E < AUTO_V2_MIN_E selects v1 — small
               element counts cannot amortize v2's second kernel
               dispatch; preconditioned cases always select v2, the only
               pipeline with fused PCG drivers).  The requested value is
               kept in ``ax_impl_requested``.
               The fused_cg variants select the step-fused CG pipelines
               (core/cg_fused.py): v1 runs one multi-output Pallas call per
               iteration plus XLA assembly/vector passes (DESIGN.md §3.3);
               v2 runs the whole iteration in two slab-resident Pallas
               kernels with in-kernel gather-scatter (DESIGN.md §3.4);
               sstep_v3 runs s iterations per cycle through the
               matrix-powers pipeline (core/cg_sstep.py, DESIGN.md §8).
      s:       iterations per s-step cycle (the 'pallas_sstep_v3' knob;
               ignored by every other ax_impl).
      precision: 'f64' | 'f32' | 'bf16' | 'bf16_ir' | 'f32_ir' | None —
               the fused pipeline's precision policy (DESIGN.md §7).
               Non-refined policies also set the case ``dtype`` to the
               storage dtype; refined (``*_ir``) policies keep ``dtype``
               as the *outer* (residual) precision and route fixed-iter
               solves through ``cg_ir_fixed_iters``.  ``None`` keeps the
               pre-policy behaviour: everything in ``dtype``.
      precond: None | 'jacobi' | 'cheb' (optionally 'cheb<k>') | 'pmg'
               (optionally 'pmg[cheb<k>]') — the case's default
               preconditioner (DESIGN.md §9 and §13, core/precond.py).
               Solves through the v2 fused pipeline dispatch to the fused
               PCG drivers (Jacobi: 14 streams/iter, Chebyshev: 18, pmg:
               the §13.4 V-cycle budget — more streams/iter, far fewer
               iterations); other ``ax_impl`` choices apply the reference
               (XLA) preconditioner through ``core/cg.py``.
               ``solve(precond=...)`` overrides per call and takes the
               same registry *names* — the string surface is the API.
               The pre-subsystem booleans (``True`` for 'jacobi',
               ``False`` for unpreconditioned) completed their
               deprecation cycle and now raise ``TypeError``.
      cheb_k:  Chebyshev polynomial order for ``precond='cheb'``.
      b:       default RHS batch for this case (DESIGN.md §12).  ``b > 1``
               routes unpreconditioned v2-family solves through the
               multi-RHS block kernels (core/cg_block.py), amortizing the
               operator streams across the batch.
    """

    n: int = 10
    grid: tuple[int, int, int] = (4, 4, 4)
    lengths: tuple[float, float, float] = (1.0, 1.0, 1.0)
    dtype: jnp.dtype = jnp.float32
    ax_impl: str = "fused"
    precision: str | None = None
    s: int = 4
    precond: str | None = None
    cheb_k: int = 4
    b: int = 1

    def __post_init__(self):
        policy = None
        if self.precision is not None:
            from repro.core.precision import resolve_policy

            policy = resolve_policy(self.precision)
            if not policy.refine:
                # storage dtype IS the case dtype: mesh fields, rhs, and
                # the solver all live in it (Eq.-2 streams are billed here).
                self.dtype = policy.storage_dtype
        self.ax_impl_requested = self.ax_impl
        if self.ax_impl == "auto":
            from repro.kernels import autotune as _autotune

            self.ax_impl = _autotune.pick_pipeline(
                self.grid, self.n, self.dtype,
                acc_dtype=None if policy is None else policy.accum,
                precond=self.precond)
        self.mesh = BoxMesh(self.n, self.grid, self.lengths)
        ops = self.mesh.ops
        dt = self.dtype
        self.D = jnp.asarray(ops.D, dt)
        self.g = jnp.asarray(self.mesh.geometric_factors(), dt)
        self.mask = jnp.asarray(self.mesh.dirichlet_mask(), dt)
        self.mult = jnp.asarray(self.mesh.multiplicity(), dt)
        self.c = self.mask / self.mult          # Nekbone's weight vector
        self.bmass = jnp.asarray(self.mesh.mass(), dt)

    # ------------------------------------------------------------------
    @property
    def cost(self) -> CostModel:
        from repro.core.cost import precision_itemsize

        itemsize = (precision_itemsize(self.precision)
                    if self.precision is not None
                    else jnp.dtype(self.dtype).itemsize)
        return CostModel(self.mesh.nelt, self.n, itemsize)

    # ------------------------------------------------------------------
    def ax_local(self, u: jnp.ndarray) -> jnp.ndarray:
        return ax_mod.ax_local(u, self.D, self.g, impl=self.ax_impl)

    def ax_full(self, u: jnp.ndarray) -> jnp.ndarray:
        """Assembled, masked Poisson operator (single shard)."""
        w = self.ax_local(u)
        w = gs_mod.ds_sum_local(w, self.grid)
        return w * self.mask

    # ------------------------------------------------------------------
    def manufactured(self):
        """Manufactured solution  u = prod sin(pi x_d / L_d)  and its rhs.

        Returns ``(u_exact, f)`` with f the *weak-form* right-hand side
        ``B f_strong`` assembled and masked, ready for CG.
        """
        xyz = self.mesh.coords()
        lx, ly, lz = self.lengths
        sx = np.sin(np.pi * xyz[..., 0] / lx)
        sy = np.sin(np.pi * xyz[..., 1] / ly)
        sz = np.sin(np.pi * xyz[..., 2] / lz)
        u_ex = sx * sy * sz
        lap = np.pi ** 2 * (1 / lx ** 2 + 1 / ly ** 2 + 1 / lz ** 2)
        f_strong = lap * u_ex
        f = jnp.asarray(f_strong, self.dtype) * self.bmass
        f = gs_mod.ds_sum_local(f, self.grid) * self.mask
        return jnp.asarray(u_ex, self.dtype), f

    # ------------------------------------------------------------------
    def dot(self) -> Callable:
        return cg_mod.weighted_dot(self.c)

    def _precond_name(self, precond) -> str | None:
        """Resolve a ``solve(precond=...)`` argument against the case.

        ``None`` inherits the case's ``precond`` field; a string names a
        registry preconditioner.  The pre-subsystem booleans (``True`` =
        'jacobi', ``False`` = unpreconditioned) went through one release
        of ``DeprecationWarning`` compat and are now removed.
        """
        if precond is None:
            return self.precond
        if isinstance(precond, bool):
            raise TypeError(
                "solve(precond=True|False) was removed after its "
                "deprecation cycle; pass the registry name instead "
                "(precond='jacobi', 'cheb4', 'pmg', ...), or omit the "
                "argument / pass precond=None for unpreconditioned.")
        return str(precond)

    def precond_spec(self, name: str | None = None):
        """The case's preconditioner spec (core/precond.py), cached.

        The Jacobi diagonal / Chebyshev Lanczos interval depend only on
        the case's operator — like the s-step theta, they are one-time
        setup costs per case, not per solve.
        """
        from repro.core import precond as precond_mod

        name = name or self.precond
        if name is None:
            return None
        if name in ("cheb", "chebyshev"):
            name = f"cheb{self.cheb_k}"
        cache = getattr(self, "_precond_specs", None)
        if cache is None:
            cache = self._precond_specs = {}
        spec = cache.get(name)
        if spec is None:
            spec = precond_mod.make_preconditioner(
                name, D=self.D, g=self.g, grid=self.grid, mask=self.mask,
                c=self.c, lengths=self.lengths)
            cache[name] = spec
        return spec

    def _reference_preconditioner(self, name: str | None):
        """The XLA-composed ``M(r)`` for the non-fused solver paths."""
        from repro.core import precond as precond_mod

        if name is None:
            return None
        spec = self.precond_spec(name)
        if isinstance(spec, precond_mod.JacobiPrecond):
            return lambda r: r * spec.invdiag
        if isinstance(spec, precond_mod.PMGPrecond):
            from repro.core import pmg as pmg_mod

            return pmg_mod.pmg_vcycle_reference(
                spec, D=self.D, g=self.g, grid=self.grid, mask=self.mask,
                c=self.c)
        return precond_mod.chebyshev_preconditioner(
            self.ax_full, spec.k, spec.lmin, spec.lmax)

    def solve(self, f: jnp.ndarray, *, b: int | None = None,
              niter: int | None = None, tol: float = 1e-8,
              max_iter: int = 1000,
              precond: str | None = None) -> cg_mod.SolveResult:
        """Solve ``A x = f`` through the driver registry (DESIGN.md §12).

        Routing (pipeline × precond × tol × batch) lives in
        :mod:`repro.core.solvers`; this method is the per-case entry.  A
        5-D ``f`` of shape (b, E, n, n, n) is a multi-RHS batch; ``b``
        can also be passed explicitly to validate the batch size.
        """
        from repro.core import solvers as solvers_mod

        return solvers_mod.solve_case(self, f, b=b, niter=niter, tol=tol,
                                      max_iter=max_iter, precond=precond)

    def solve_manufactured(self, *, niter: int | None = None, tol: float = 1e-8,
                           max_iter: int = 1000,
                           precond: str | None = None):
        u_ex, f = self.manufactured()
        res = self.solve(f, niter=niter, tol=tol, max_iter=max_iter,
                         precond=precond)
        return res, u_ex

    def solution_error(self, x: jnp.ndarray, u_exact: jnp.ndarray) -> jnp.ndarray:
        """Weighted max-norm error against the exact solution."""
        return jnp.max(jnp.abs((x - u_exact) * self.mask))

    # ------------------------------------------------------------------
    def operator_diagonal(self) -> jnp.ndarray:
        """diag(A) for the Jacobi preconditioner, computed structurally.

        Delegates to :func:`repro.core.precond.operator_diagonal` (the
        preconditioning subsystem owns the algebra, DESIGN.md §9.2):
        element-local diagonal from three small ``D∘D`` einsums, then
        assembled; masked rows are 1 to keep the inverse finite.
        """
        from repro.core.precond import operator_diagonal

        return operator_diagonal(self.D, self.g, self.grid,
                                 self.mask).astype(self.dtype)

    # ------------------------------------------------------------------
    # Distributed (shard_map) operator set
    # ------------------------------------------------------------------
    def shard_grid(self, n_shards: int) -> tuple[int, int, int]:
        ex, ey, ez = self.grid
        if ez % n_shards:
            raise ValueError(f"EZ={ez} not divisible by {n_shards} shards")
        return ex, ey, ez // n_shards

    def sharded_ax_full(self, axis_names) -> Callable:
        """Per-shard assembled operator, for use inside ``shard_map``.

        Shard-local inputs: u, g, mask blocks split along the element axis
        (z-major ordering makes a leading-axis split a z-split).
        """
        axis_names = tuple(axis_names)

        def op(u_local, g_local, mask_local, grid_local):
            w = ax_mod.ax_local(u_local, self.D, g_local, impl=self.ax_impl)
            w = gs_mod.ds_sum_sharded(w, grid_local, axis_names)
            return w * mask_local

        return op
