"""End-to-end Nekbone case: SEM Poisson on a box, solved with CG.

This is the composable entry point for the paper's system:

    case = NekboneCase(n=10, grid=(8, 8, 16))     # degree 9, 1024 elements
    res  = case.solve_manufactured(niter=100)      # paper's benchmark run
    err  = case.solution_error(res.x)

The operator pipeline is exactly Nekbone's ``ax``:
    w = mask( gather_scatter( ax_local(u) ) )
with ``ax_local`` selectable between the paper-faithful Listing-1 version,
the XLA-fused version, and the Pallas TPU kernel (DESIGN.md §2).

Distribution: :meth:`sharded_ops` returns the same functions expressed for a
``shard_map`` over a device mesh, sharding elements along the z element axis
and assembling interfaces with a ppermute halo exchange (core/gs.py).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp
import numpy as np

import repro.core.ax as ax_mod
import repro.core.cg as cg_mod
import repro.core.cg_fused as cg_fused_mod
import repro.core.gs as gs_mod
from repro.core.cost import CostModel
from repro.core.geom import BoxMesh

__all__ = ["NekboneCase"]


@dataclasses.dataclass
class NekboneCase:
    """A runnable Nekbone problem instance.

    Args:
      n:       GLL points per direction (degree + 1). Paper uses 10.
      grid:    element grid (EX, EY, EZ).
      lengths: physical box size.
      dtype:   compute dtype (fp64 validated on CPU; fp32/bf16 TPU target).
      ax_impl: 'listing1' | 'fused' | 'pallas' | 'pallas_fused_cg' |
               'pallas_fused_cg_v2' | 'pallas_sstep_v3'.
               The fused_cg variants select the step-fused CG pipelines
               (core/cg_fused.py): v1 runs one multi-output Pallas call per
               iteration plus XLA assembly/vector passes (DESIGN.md §3.3);
               v2 runs the whole iteration in two slab-resident Pallas
               kernels with in-kernel gather-scatter (DESIGN.md §3.4);
               sstep_v3 runs s iterations per cycle through the
               matrix-powers pipeline (core/cg_sstep.py, DESIGN.md §8).
      s:       iterations per s-step cycle (the 'pallas_sstep_v3' knob;
               ignored by every other ax_impl).
      precision: 'f64' | 'f32' | 'bf16' | 'bf16_ir' | 'f32_ir' | None —
               the fused pipeline's precision policy (DESIGN.md §7).
               Non-refined policies also set the case ``dtype`` to the
               storage dtype; refined (``*_ir``) policies keep ``dtype``
               as the *outer* (residual) precision and route fixed-iter
               solves through ``cg_ir_fixed_iters``.  ``None`` keeps the
               pre-policy behaviour: everything in ``dtype``.
    """

    n: int = 10
    grid: tuple[int, int, int] = (4, 4, 4)
    lengths: tuple[float, float, float] = (1.0, 1.0, 1.0)
    dtype: jnp.dtype = jnp.float32
    ax_impl: str = "fused"
    precision: str | None = None
    s: int = 4

    def __post_init__(self):
        if self.precision is not None:
            from repro.core.precision import resolve_policy

            policy = resolve_policy(self.precision)
            if not policy.refine:
                # storage dtype IS the case dtype: mesh fields, rhs, and
                # the solver all live in it (Eq.-2 streams are billed here).
                self.dtype = policy.storage_dtype
        self.mesh = BoxMesh(self.n, self.grid, self.lengths)
        ops = self.mesh.ops
        dt = self.dtype
        self.D = jnp.asarray(ops.D, dt)
        self.g = jnp.asarray(self.mesh.geometric_factors(), dt)
        self.mask = jnp.asarray(self.mesh.dirichlet_mask(), dt)
        self.mult = jnp.asarray(self.mesh.multiplicity(), dt)
        self.c = self.mask / self.mult          # Nekbone's weight vector
        self.bmass = jnp.asarray(self.mesh.mass(), dt)

    # ------------------------------------------------------------------
    @property
    def cost(self) -> CostModel:
        from repro.core.cost import precision_itemsize

        itemsize = (precision_itemsize(self.precision)
                    if self.precision is not None
                    else jnp.dtype(self.dtype).itemsize)
        return CostModel(self.mesh.nelt, self.n, itemsize)

    # ------------------------------------------------------------------
    def ax_local(self, u: jnp.ndarray) -> jnp.ndarray:
        return ax_mod.ax_local(u, self.D, self.g, impl=self.ax_impl)

    def ax_full(self, u: jnp.ndarray) -> jnp.ndarray:
        """Assembled, masked Poisson operator (single shard)."""
        w = self.ax_local(u)
        w = gs_mod.ds_sum_local(w, self.grid)
        return w * self.mask

    # ------------------------------------------------------------------
    def manufactured(self):
        """Manufactured solution  u = prod sin(pi x_d / L_d)  and its rhs.

        Returns ``(u_exact, f)`` with f the *weak-form* right-hand side
        ``B f_strong`` assembled and masked, ready for CG.
        """
        xyz = self.mesh.coords()
        lx, ly, lz = self.lengths
        sx = np.sin(np.pi * xyz[..., 0] / lx)
        sy = np.sin(np.pi * xyz[..., 1] / ly)
        sz = np.sin(np.pi * xyz[..., 2] / lz)
        u_ex = sx * sy * sz
        lap = np.pi ** 2 * (1 / lx ** 2 + 1 / ly ** 2 + 1 / lz ** 2)
        f_strong = lap * u_ex
        f = jnp.asarray(f_strong, self.dtype) * self.bmass
        f = gs_mod.ds_sum_local(f, self.grid) * self.mask
        return jnp.asarray(u_ex, self.dtype), f

    # ------------------------------------------------------------------
    def dot(self) -> Callable:
        return cg_mod.weighted_dot(self.c)

    def solve(self, f: jnp.ndarray, *, niter: int | None = None,
              tol: float = 1e-8, max_iter: int = 1000,
              precond: bool = False) -> cg_mod.CGResult:
        M = None
        if precond:
            M = cg_mod.jacobi_preconditioner(self.operator_diagonal())
        fused = self.ax_impl in ("pallas_fused_cg", "pallas_fused_cg_v2",
                                 "pallas_sstep_v3")
        if (fused and niter is not None and M is None
                and self.precision is not None):
            from repro.core.precision import resolve_policy

            policy = resolve_policy(self.precision)
            if policy.refine:
                variant = {"pallas_fused_cg_v2": "v2",
                           "pallas_sstep_v3": "sstep"}.get(self.ax_impl,
                                                           "v1")
                return cg_fused_mod.cg_ir_fixed_iters(
                    f, D=self.D, g=self.g, grid=self.grid, niter=niter,
                    precision=policy, mask=self.mask, c=self.c,
                    variant=variant, s=self.s)
        if self.ax_impl == "pallas_sstep_v3" and niter is not None and M is None:
            from repro.core.cg_sstep import cg_sstep_fixed_iters, \
                estimate_theta

            # the basis scale depends only on the case's operator —
            # estimate once per case, not once per solve.
            theta = getattr(self, "_sstep_theta", None)
            if theta is None:
                theta = estimate_theta(self.D, self.g, self.grid,
                                       self.mask)
                self._sstep_theta = theta
            return cg_sstep_fixed_iters(
                f, D=self.D, g=self.g, grid=self.grid, niter=niter,
                s=self.s, mask=self.mask, c=self.c, theta=theta,
                precision=self.precision)
        if self.ax_impl == "pallas_fused_cg_v2" and niter is not None and M is None:
            return cg_fused_mod.cg_fused_v2_fixed_iters(
                f, D=self.D, g=self.g, grid=self.grid, niter=niter,
                mask=self.mask, c=self.c, precision=self.precision)
        if self.ax_impl == "pallas_fused_cg" and niter is not None and M is None:
            return cg_fused_mod.cg_fused_fixed_iters(
                f, D=self.D, g=self.g, mask=self.mask, c=self.c,
                grid=self.grid, niter=niter, precision=self.precision)
        if niter is not None:
            return cg_mod.cg_fixed_iters(self.ax_full, f, niter=niter,
                                         dot=self.dot(), precond=M)
        return cg_mod.cg(self.ax_full, f, tol=tol, max_iter=max_iter,
                         dot=self.dot(), precond=M)

    def solve_manufactured(self, *, niter: int | None = None, tol: float = 1e-8,
                           max_iter: int = 1000, precond: bool = False):
        u_ex, f = self.manufactured()
        res = self.solve(f, niter=niter, tol=tol, max_iter=max_iter,
                         precond=precond)
        return res, u_ex

    def solution_error(self, x: jnp.ndarray, u_exact: jnp.ndarray) -> jnp.ndarray:
        """Weighted max-norm error against the exact solution."""
        return jnp.max(jnp.abs((x - u_exact) * self.mask))

    # ------------------------------------------------------------------
    def operator_diagonal(self) -> jnp.ndarray:
        """diag(A) for the Jacobi preconditioner, computed structurally.

        diag over the element-local operator then assembled:  for the SEM
        Poisson operator, diag_local[p] = sum_l D[l,i]^2 G_rr[..l..] + ...;
        we compute it exactly with three small einsums.
        """
        grr = self.g[:, 0]
        gss = self.g[:, 3]
        gtt = self.g[:, 5]
        D2 = self.D * self.D  # (a, b): D[a,b]^2
        dr = jnp.einsum("li,ekjl->ekji", D2, grr)
        ds = jnp.einsum("lj,ekli->ekji", D2, gss)
        dt = jnp.einsum("lk,elji->ekji", D2, gtt)
        diag = dr + ds + dt
        diag = gs_mod.ds_sum_local(diag, self.grid)
        # masked rows: identity-like; keep 1 to avoid division by zero
        return jnp.where(self.mask > 0, diag, 1.0).astype(self.dtype)

    # ------------------------------------------------------------------
    # Distributed (shard_map) operator set
    # ------------------------------------------------------------------
    def shard_grid(self, n_shards: int) -> tuple[int, int, int]:
        ex, ey, ez = self.grid
        if ez % n_shards:
            raise ValueError(f"EZ={ez} not divisible by {n_shards} shards")
        return ex, ey, ez // n_shards

    def sharded_ax_full(self, axis_names) -> Callable:
        """Per-shard assembled operator, for use inside ``shard_map``.

        Shard-local inputs: u, g, mask blocks split along the element axis
        (z-major ordering makes a leading-axis split a z-split).
        """
        axis_names = tuple(axis_names)

        def op(u_local, g_local, mask_local, grid_local):
            w = ax_mod.ax_local(u_local, self.D, g_local, impl=self.ax_impl)
            w = gs_mod.ds_sum_sharded(w, grid_local, axis_names)
            return w * mask_local

        return op
