"""p-multigrid V-cycle preconditioner (DESIGN.md §13).

Polynomial-degree coarsening for the box Poisson operator: the same
element grid is rediscretized at a ladder of GLL orders
``n -> ceil(n/2) -> ... -> 2`` (:func:`repro.core.cost.pmg_degrees` — the
HipBone configuration, Chalmers et al. 2022), each fine level smoothed by
the fused Chebyshev(k) apply kernel on a *per-level* Lanczos interval,
levels coupled by tensor-product GLL interpolation
(:func:`gll_interp_matrix`), and the 2^3 base level solved by a few fixed
CG iterations.

The cycle is symmetric (pre- + post-smoothing with the same polynomial;
the Chebyshev smoother ``S = q_k(A)`` is a polynomial in ``A`` and hence
self-adjoint in the c-weighted inner product, so applying the recurrence
forward is already its own reversal) and the two-level operator

    M = 2S - SAS + (I - SA) P C P^T (I - AS)

is symmetric positive definite whenever ``lambda q_k(lambda) in (0, 2)``
on ``(0, lmax]`` — which the smoothing interval ``[lmax/ratio, lmax]``
guarantees: *below* the interval the error polynomial stays in (0, 1), so
``lambda q_k(lambda) = 1 - p(lambda)`` stays in (0, 1) there too (§13.3).
PCG theory therefore applies, up to the deliberate approximation that the
base solve ``C`` is a *fixed-iteration* CG (ISSUE: "a few fixed CG
iterations on the 2^3 operator") — verified the same way the Chebyshev
preconditioner was: interpret-mode parity vs the XLA reference cycle plus
the iters-to-tol acceptance check (benchmarks/pmg_smoke.py).

Transfer operators: prolongation is the element-local tensor-product
interpolation ``e_f = (J x J x J) e_c`` with ``J[i, c] = l_c(x_f[i])``
the coarse Lagrange cardinals at the fine GLL nodes.  Because both grids
contain the endpoints, the endpoint rows of ``J`` are exact 0/1 —
prolongation maps element-face values to element-face values, so it
preserves continuity and the masked (Dirichlet) subspace *exactly*.
Restriction is the c-weighted adjoint in the duplicated-local
representation:

    r_c = mask_c * gs( J^T (c_f * r_f) )

(the gather-scatter transfers onto the other factor of the c-dot for
continuous fields, DESIGN.md §3.2, making ``<u, P e>_c = <R u, e>_c``).

This module holds the spec, the setup (per-level rediscretization +
interval estimation) and the reference (XLA) cycle; the fused driver
lives in ``core/precond._pcg_pmg`` on top of the Pallas interpolation
kernel (`kernels/nekbone_ax.nekbone_interp_kernel`).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cost import (PMG_COARSE_ITERS, PMG_DEFAULT_K,
                             PMG_SMOOTH_RATIO, pmg_degrees)
from repro.core.geom import BoxMesh, box_outer
from repro.core.sem import gll_points_weights

__all__ = ["PMG_DEFAULT_K", "PMG_COARSE_ITERS", "PMG_SMOOTH_RATIO",
           "PMGPrecond", "pmg_degrees", "gll_interp_matrix", "interp3",
           "make_pmg_preconditioner", "level_operator", "pmg_level_pytree",
           "coarse_solve_fixed", "pmg_vcycle_reference"]


# ---------------------------------------------------------------------------
# GLL-to-GLL transfer matrices
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def gll_interp_matrix(n_to: int, n_from: int) -> np.ndarray:
    """``(n_to, n_from)`` Lagrange interpolation between GLL grids, f64.

    ``J[i, c] = l_c(x_to[i])`` with ``l_c`` the cardinal functions of the
    ``n_from``-point GLL grid (barycentric form).  Rows at coinciding
    nodes (always the two endpoints, GLL grids contain ±1) are exact
    0/1 — the structural-preservation property the V-cycle relies on.
    ``gll_interp_matrix(nf, nc)`` prolongs coarse -> fine; its transpose
    is the (unweighted part of the) restriction.
    """
    x_to = np.asarray(gll_points_weights(n_to)[0], np.float64)
    x_from = np.asarray(gll_points_weights(n_from)[0], np.float64)
    diff = x_from[:, None] - x_from[None, :]
    np.fill_diagonal(diff, 1.0)
    wbar = 1.0 / np.prod(diff, axis=1)
    J = np.zeros((n_to, n_from), np.float64)
    for i, xt in enumerate(x_to):
        d = xt - x_from
        hit = np.abs(d) < 1e-13
        if hit.any():
            J[i, int(np.argmax(hit))] = 1.0
        else:
            t = wbar / d
            J[i] = t / t.sum()
    return J


def _interp_axis(u: jnp.ndarray, mt: jnp.ndarray, axis: int) -> jnp.ndarray:
    """Contract ``u``'s ``axis`` with ``mt``'s rows (output dim appended
    last) — the exact ``dot_general`` the Pallas interp kernel issues, so
    an XLA reference built from this is fp64-bitwise against the kernel."""
    acc = jnp.float64 if u.dtype == jnp.float64 else jnp.float32
    return jax.lax.dot_general(u, mt, (((axis,), (0,)), ((), ())),
                               preferred_element_type=acc)


def interp3(u: jnp.ndarray, M: jnp.ndarray) -> jnp.ndarray:
    """Apply ``M`` (n_out, n_in) along each local axis of ``(E, n_in^3)``
    fields in natural ``(E, k, j, i)`` shape; returns ``(E, n_out^3)``
    natural.  The dense XLA reference for the Pallas interpolation kernel
    (same contraction pattern and order, bitwise at fp64)."""
    mt = jnp.asarray(M).T.astype(u.dtype)
    v = _interp_axis(u, mt, 3)                           # (E, k, j, io)
    v = _interp_axis(v, mt, 2).transpose(0, 1, 3, 2)     # (E, k, jo, io)
    v = _interp_axis(v, mt, 1).transpose(0, 3, 1, 2)     # (E, ko, jo, io)
    return v


# ---------------------------------------------------------------------------
# spec + setup
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PMGPrecond:
    """p-multigrid V-cycle preconditioner spec (static, hashable).

    ``ns`` is the degree ladder fine -> coarse (``pmg_degrees(n)``);
    ``intervals`` the per-*smoothed*-level Chebyshev smoothing intervals
    ``(lmax/ratio, lmax)`` from per-level Lanczos estimates (one per
    ``ns[:-1]`` entry); ``k`` the smoother order; ``coarse_iters`` the
    fixed CG iteration count of the 2^3 base solve.
    """

    ns: tuple[int, ...]
    k: int
    intervals: tuple[tuple[float, float], ...]
    coarse_iters: int
    lengths: tuple[float, float, float] = (1.0, 1.0, 1.0)
    name: str = dataclasses.field(default="pmg", init=False)

    def scalars(self, level: int) -> np.ndarray:
        """(k+1, 2) f64 Chebyshev recurrence table for a smoothed level."""
        from repro.core.precond import cheb_scalars

        lmin, lmax = self.intervals[level]
        return cheb_scalars(self.k, lmin, lmax)


@functools.lru_cache(maxsize=64)
def _level_mesh(n: int, grid: tuple[int, int, int],
                lengths: tuple[float, float, float]) -> BoxMesh:
    return BoxMesh(n, grid, lengths)


def level_operator(n: int, grid: tuple[int, int, int],
                   lengths: tuple[float, float, float] = (1.0, 1.0, 1.0)):
    """Rediscretized operator data at GLL order ``n``: ``(D, g, mask, c)``.

    The p-coarse levels are *rediscretizations* (HipBone-style), not
    Galerkin products: the same box at a lower order, so every level is
    exactly the operator the existing kernels already implement.
    """
    mesh = _level_mesh(int(n), tuple(grid), tuple(lengths))
    D = mesh.ops.D
    g = mesh.geometric_factors()
    mask = mesh.dirichlet_mask()
    c = mask / mesh.multiplicity()
    return D, g, mask, c


def make_pmg_preconditioner(*, D, g, grid: tuple[int, int, int],
                            mask=None, c=None, k: int = PMG_DEFAULT_K,
                            lengths: tuple[float, float, float] = (1, 1, 1),
                            coarse_iters: int = PMG_COARSE_ITERS,
                            smooth_ratio: float = PMG_SMOOTH_RATIO,
                            intervals=None) -> PMGPrecond:
    """Build a :class:`PMGPrecond` for the operator ``(D, g)`` on ``grid``.

    Per smoothed level the spectrum top ``lmax`` comes from the same
    weighted-Lanczos estimate the Chebyshev preconditioner uses
    (:func:`repro.core.precond.estimate_interval` — level 0 on the
    caller's operator data, coarser levels on their rediscretizations);
    the smoothing interval is ``[lmax / smooth_ratio, lmax]``: the
    smoother only needs to damp what the next-coarser space cannot
    represent, and clipping the interval bottom keeps the degree-k
    polynomial strong there (§13.3; over-estimating ``lmax`` stays the
    safe direction).  ``intervals`` overrides the estimate (a tuple of
    per-level ``(lmin, lmax)``).
    """
    from repro.core.precond import estimate_interval

    grid = tuple(grid)
    n = int(jnp.asarray(D).shape[-1])
    ns = pmg_degrees(n)
    if len(ns) < 2:
        raise ValueError(f"pmg needs n >= 3 to coarsen, got n = {n}")
    if intervals is not None:
        intervals = tuple((float(a), float(b)) for a, b in intervals)
        if len(intervals) != len(ns) - 1:
            raise ValueError(f"need {len(ns) - 1} per-level intervals for "
                             f"ladder {ns}, got {len(intervals)}")
    else:
        ivs = []
        for lev, nl in enumerate(ns[:-1]):
            if lev == 0 and mask is not None:
                lmax = estimate_interval(D, g, grid, mask, c)[1]
            else:
                Dl, gl, ml, cl = level_operator(nl, grid, lengths)
                lmax = estimate_interval(Dl, gl, grid, ml, cl)[1]
            ivs.append((lmax / float(smooth_ratio), lmax))
        intervals = tuple(ivs)
    return PMGPrecond(ns=ns, k=int(k), intervals=intervals,
                      coarse_iters=int(coarse_iters),
                      lengths=tuple(float(x) for x in lengths))


@functools.lru_cache(maxsize=8)
def pmg_level_pytree(spec: PMGPrecond, grid: tuple[int, int, int],
                     op_name: str, acc_name: str):
    """Per-level jnp arrays for the fused driver, as a (hashably cached)
    pytree ``(coefs, transfers, midops, coarse)``:

    * ``coefs[l]``  — (k+1, 2) Chebyshev table of smoothed level ``l``
      (``acc`` dtype, like the cheb driver's);
    * ``transfers[l]`` — ``J_l = gll_interp_matrix(ns[l], ns[l+1])`` in
      the op-storage dtype (``J_l`` restricts as-is via the interp
      kernel's row contraction; its transpose prolongs);
    * ``midops[l-1]`` for levels ``1..L-2`` — ``(D_l, g3_l, mx, my, mz,
      cx, cy, cz)`` in op-storage / factor form, exactly the operands
      the v2 slab + cheb kernels take;
    * ``coarse`` — ``(D_c, g_c, mask_c, c_c)`` natural-shape f-acc data
      for the shared fixed-CG base solve.
    """
    from repro.kernels import ops as kernel_ops

    op_dtype = jnp.dtype(op_name)
    acc_dtype = jnp.dtype(acc_name)
    ns = spec.ns
    E = grid[0] * grid[1] * grid[2]
    coefs = tuple(jnp.asarray(spec.scalars(lev), acc_dtype)
                  for lev in range(len(ns) - 1))
    transfers = tuple(jnp.asarray(gll_interp_matrix(ns[lev], ns[lev + 1]),
                                  op_dtype)
                      for lev in range(len(ns) - 1))
    midops = []
    for lev in range(1, len(ns) - 1):
        nl = ns[lev]
        Dl, gl, _, _ = level_operator(nl, grid, spec.lengths)
        g3l = kernel_ops.diag_metric(jnp.asarray(gl, op_dtype), E, nl)
        (mxl, myl, mzl), (cxl, cyl, czl) = kernel_ops.slab_axis_factors(
            grid, nl, op_dtype)
        midops.append((jnp.asarray(Dl, op_dtype), g3l,
                       mxl, myl, mzl, cxl, cyl, czl))
    nc = ns[-1]
    Dc, gc, mc, cc = level_operator(nc, grid, spec.lengths)
    coarse = (jnp.asarray(Dc, acc_dtype), jnp.asarray(gc, acc_dtype),
              jnp.asarray(mc, acc_dtype), jnp.asarray(cc, acc_dtype))
    return coefs, transfers, tuple(midops), coarse


# ---------------------------------------------------------------------------
# base solve — shared verbatim by the fused and reference cycles, so the
# interpret-mode parity smoke isolates the Pallas kernels
# ---------------------------------------------------------------------------

def coarse_solve_fixed(r: jnp.ndarray, D: jnp.ndarray, g: jnp.ndarray,
                       grid: tuple[int, int, int], mask: jnp.ndarray,
                       c: jnp.ndarray, *, iters: int) -> jnp.ndarray:
    """``iters`` fixed CG iterations on the rediscretized base operator.

    Plain XLA (``ax_local_fused`` + ``ds_sum_local`` + mask; c-weighted
    dots) from a zero initial guess.  The base system is tiny ((EX-1)
    (EY-1)(EZ-1) interior DOFs at n=2), so CG can converge *exactly*
    within ``iters`` — the zero-guarded alpha/beta turn further
    iterations into no-ops instead of 0/0 NaNs.
    """
    from repro.core.ax import ax_local_fused
    from repro.core.gs import ds_sum_local

    grid = tuple(grid)

    def A(v):
        return ds_sum_local(ax_local_fused(v, D, g), grid) * mask

    def dot(u, v):
        return jnp.sum(u * c * v)

    def safe_div(num, den):
        return jnp.where(den != 0, num / jnp.where(den != 0, den, 1.0), 0.0)

    def body(_, state):
        x, res, p, rtz = state
        w = A(p)
        alpha = safe_div(rtz, dot(p, w))
        x = x + alpha * p
        res = res - alpha * w
        rtz_new = dot(res, res)
        beta = safe_div(rtz_new, rtz)
        p = res + beta * p
        return x, res, p, rtz_new

    x0 = jnp.zeros_like(r)
    x, _, _, _ = jax.lax.fori_loop(0, int(iters), body,
                                   (x0, r, r, dot(r, r)))
    return x


# ---------------------------------------------------------------------------
# reference (XLA) V-cycle — the oracle the fused driver's parity smoke
# compares against, and a drop-in precond= callable for core/cg.py
# ---------------------------------------------------------------------------

def pmg_vcycle_reference(spec: PMGPrecond, *, D, g,
                         grid: tuple[int, int, int], mask, c):
    """Reference symmetric V-cycle ``M(r)`` on natural ``(E, n, n, n)``.

    Level 0 runs on the caller's operator data (``D``/``g``/``mask``/
    ``c`` — the case's own fields); coarser levels on their
    rediscretizations.  Same algebra as ``precond._pcg_pmg``: Chebyshev
    pre-smooth, restrict the residual, recurse, prolong-correct,
    Chebyshev post-smooth; base level via :func:`coarse_solve_fixed`.
    """
    grid = tuple(grid)
    ns = spec.ns
    L = len(ns)
    levels = []
    for lev in range(L):
        if lev == 0:
            levels.append((jnp.asarray(D), jnp.asarray(g),
                           jnp.asarray(mask), jnp.asarray(c)))
        else:
            Dl, gl, ml, cl = level_operator(ns[lev], grid, spec.lengths)
            levels.append((jnp.asarray(Dl), jnp.asarray(gl),
                           jnp.asarray(ml), jnp.asarray(cl)))
    transfers = [jnp.asarray(gll_interp_matrix(ns[lev], ns[lev + 1]))
                 for lev in range(L - 1)]
    coefs = [spec.scalars(lev) for lev in range(L - 1)]

    def apply_a(v, lev):
        from repro.core.ax import ax_local_fused
        from repro.core.gs import ds_sum_local

        Dl, gl, ml, _ = levels[lev]
        return ds_sum_local(ax_local_fused(v, Dl, gl), grid) * ml

    def smooth(r, lev):
        coef = coefs[lev]
        d = coef[0, 0] * r
        z = d
        res = r
        for i in range(1, spec.k + 1):
            res = res - apply_a(d, lev)
            d = coef[i, 0] * d + coef[i, 1] * res
            z = z + d
        return z

    def restrict(res, lev):
        from repro.core.gs import ds_sum_local

        _, _, _, cf = levels[lev]
        mc = levels[lev + 1][2]
        t = interp3(res * cf, transfers[lev].T)        # J^T (c_f r_f)
        return ds_sum_local(t, grid) * mc

    def prolong(e, lev):
        mf = levels[lev][2]
        return interp3(e, transfers[lev]) * mf

    def cycle(r, lev):
        # host-recursion V-cycle: each level is a real host region, so a
        # trace (when on) gets one timed "pmg.vcycle" span per level per
        # application — the fused driver's statically-unrolled ladder
        # only exposes its levels at setup (precond._dispatch).
        from repro.obs import trace as _trace

        rec = _trace.active()
        with (rec.span("pmg.vcycle", level=lev, n=ns[lev])
              if rec is not None else _trace.NULL_SPAN):
            if lev == L - 1:
                Dc, gc, mc, cc = levels[lev]
                return coarse_solve_fixed(r, Dc, gc, grid, mc, cc,
                                          iters=spec.coarse_iters)
            z = smooth(r, lev)
            z = z + prolong(
                cycle(restrict(r - apply_a(z, lev), lev), lev + 1), lev)
            return z + smooth(r - apply_a(z, lev), lev)

    def M(r):
        return cycle(r, 0)

    return M
