"""Precision policies for the fused CG pipeline (DESIGN.md §7).

The paper's arithmetic is fp64; the roofline analysis (§IV) shows the Ax
kernel bandwidth-bound at 77-92 % of peak, so once the stream *count* is
fixed (30 → 17 → 13, DESIGN.md §6) the remaining lever is the bytes *per*
stream.  A policy makes the field dtype a first-class parameter of the
pipeline, split into two independent choices:

* **storage** — the dtype ``x``/``r``/``p``/``w`` and the diagonal metric
  occupy in HBM.  This is what every stream of the Eq.-2 ladder is billed
  in: bf16 storage halves f32's traffic and quarters f64's.
* **accum** — the dtype the kernels upcast to on load and accumulate the
  tensor contractions, direct-stiffness sums, and the ``p·c·Ap`` /
  ``r·c·r`` partials in.  Accumulation is VMEM/register-resident, so a
  wide accum costs no HBM bytes.

Low-precision storage stalls CG at the storage dtype's round-off floor
(bf16: ~4e-3 relative); policies with ``refine=True`` wrap the inner
solve in an iterative-refinement outer loop
(:func:`repro.core.cg_fused.cg_ir_fixed_iters`) whose residuals are
formed in the caller's (high) precision — recovering fp64-class floors
from bf16-priced streams.

Named policies::

    f64      f64 storage, f64 accum          (CPU oracle / paper precision)
    f32      f32 storage, f32 accum          (TPU default)
    bf16     bf16 storage, f32 accum         (half of f32's bytes/iter)
    f32_ir   f32 storage, f32 accum, refined
    bf16_ir  bf16 vectors, f32 accum + x + metric, refined  (the target)

Every fused entry point accepts ``precision`` as a name, a
:class:`PrecisionPolicy`, or ``None`` (infer the non-refined policy from
the operand dtype — the pre-policy behaviour, bit-for-bit).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

__all__ = ["PrecisionPolicy", "POLICIES", "resolve_policy",
           "policy_from_dtype"]


@dataclasses.dataclass(frozen=True)
class PrecisionPolicy:
    """One (storage, accum, refine) point of the precision space.

    Attributes:
      name:    registry key (``POLICIES``) and autotune/bench label.
      storage: dtype name fields live in, in HBM (what streams are billed in).
      accum:   dtype in-kernel contractions and reduction partials use.
      refine:  wrap the solve in the iterative-refinement outer loop.
      x_storage: optional override for the *solution* vector's storage
               dtype.  ``x`` never feeds the operator — it only
               accumulates ``alpha p`` — so widening it leaves the tensor
               contractions' streams untouched while removing the
               ``O(storage-eps · kappa)`` residual noise that rounding the
               returned solution injects; the refined policies need that
               (the correction each sweep hands back IS a solution), so
               ``bf16_ir`` stores ``x`` in f32 at +2 of 26 bytes/DOF/iter.
      op_storage: optional override for the dtype of the operator's
               *defining data* — the diagonal metric and the derivative
               matrix.  Rounding them perturbs ``A`` itself, which caps
               iterative refinement's per-sweep contraction at a fixed
               ``O(op-eps · kappa_eff)`` floor no number of sweeps can
               pass; the refined bf16 policy therefore keeps the metric in
               f32 (3 of the v2 pipeline's 13 streams) while all CG
               *vectors* stream at bf16 width.
    """

    name: str
    storage: str
    accum: str
    refine: bool = False
    x_storage: str | None = None
    op_storage: str | None = None

    @property
    def storage_dtype(self) -> jnp.dtype:
        return jnp.dtype(self.storage)

    @property
    def accum_dtype(self) -> jnp.dtype:
        return jnp.dtype(self.accum)

    @property
    def x_storage_dtype(self) -> jnp.dtype:
        return jnp.dtype(self.x_storage or self.storage)

    @property
    def op_storage_dtype(self) -> jnp.dtype:
        return jnp.dtype(self.op_storage or self.storage)

    @property
    def itemsize(self) -> int:
        """Bytes per stored word — the Eq.-2 byte multiplier."""
        return self.storage_dtype.itemsize

    @property
    def eps(self) -> float:
        """Unit round-off of the *storage* dtype: the parity-test tolerance
        scale and the per-sweep floor of the refinement loop."""
        return float(jnp.finfo(self.storage_dtype).eps)

    @property
    def gram(self) -> str:
        """Dtype of the s-step Gram/recurrence solve — always float64.

        The v3 pipeline's (2s+1)^2 Gram block conditions like
        ``kappa(A)^{2s}`` (DESIGN.md §8), so the coefficient recurrence is
        solved host-side in f64 *regardless* of storage/accum — it is
        O(s^2) scalar work per cycle, never a stream.  Not configurable:
        a narrow Gram would silently break the s-step algebra for every
        policy at once.
        """
        return "float64"


POLICIES: dict[str, PrecisionPolicy] = {
    "f64": PrecisionPolicy("f64", "float64", "float64"),
    "f32": PrecisionPolicy("f32", "float32", "float32"),
    "bf16": PrecisionPolicy("bf16", "bfloat16", "float32"),
    "f32_ir": PrecisionPolicy("f32_ir", "float32", "float32", refine=True),
    "bf16_ir": PrecisionPolicy("bf16_ir", "bfloat16", "float32",
                               refine=True, x_storage="float32",
                               op_storage="float32"),
}


def policy_from_dtype(dtype) -> PrecisionPolicy:
    """The non-refined policy matching a bare operand dtype.

    This is the pre-policy implicit behaviour: f64 accumulates in f64
    (the CPU oracle), everything narrower accumulates in f32.
    """
    dtype = jnp.dtype(dtype)
    if dtype == jnp.float64:
        return POLICIES["f64"]
    if dtype == jnp.dtype(jnp.bfloat16):
        return POLICIES["bf16"]
    if dtype == jnp.float32:
        return POLICIES["f32"]
    # f16 etc.: storage as given, f32 accumulation — the TPU-safe default.
    return PrecisionPolicy(dtype.name, dtype.name, "float32")


def resolve_policy(precision, dtype=None) -> PrecisionPolicy:
    """Normalize a ``precision=`` argument to a :class:`PrecisionPolicy`.

    Args:
      precision: a policy name (``POLICIES`` key), a policy instance, or
                 ``None`` to infer from ``dtype``.
      dtype:     operand dtype used when ``precision`` is ``None``.
    """
    if precision is None:
        if dtype is None:
            raise ValueError("precision=None needs an operand dtype")
        return policy_from_dtype(dtype)
    if isinstance(precision, PrecisionPolicy):
        return precision
    try:
        return POLICIES[str(precision)]
    except KeyError:
        raise ValueError(
            f"unknown precision {precision!r}; expected one of "
            f"{sorted(POLICIES)} or a PrecisionPolicy") from None
