"""Preconditioning subsystem for the fused CG pipelines (DESIGN.md §9).

The paper's benchmark protocol runs *unpreconditioned* CG (its §V), and
flags diagonal preconditioning as future work; HipBone (Chalmers et al.,
PAPERS.md) shows the NekBone benchmark generalizes cleanly to
preconditioned solves on GPUs, and the tensor-product kernels this repo
fuses are exactly the building block a polynomial smoother/preconditioner
needs (Świrydowicz et al.).  This module makes preconditioning a
first-class workload layer over every existing pipeline:

* **Jacobi (diagonal) PCG fused into the v2 slab pipeline**
  (:func:`pcg_fused_v2_fixed_iters` with a :class:`JacobiPrecond`): the
  operator diagonal is computed once per case
  (:func:`operator_diagonal`), inverted, and kept slab-resident; the
  solver carries the *preconditioned* residual ``z = D^-1 r`` so the v2
  front-half kernel is reused unchanged (``p = z + beta p`` is its
  direction update) and the merged back-half
  (`kernels/nekbone_ax.nekbone_pcg_update_kernel`) applies ``M^-1``
  in-kernel — PCG costs exactly **one extra stream/iter** (14 vs 13,
  `cost.JACOBI_V2_*`, pinned by the regression gate).

* **Chebyshev polynomial PCG** (:class:`ChebyshevPrecond`):
  ``z = q_k(A) r`` with ``q_k`` the degree-k Chebyshev approximation of
  ``A^-1`` on an interval bracketing the spectrum.  One application is k
  chained assembled operator applications — the v3 matrix-powers
  structure — so the apply kernel
  (`kernels/nekbone_ax.nekbone_cheb_apply_kernel`) reuses the §8 halo
  machinery (k ghost slabs per side, `sstep_extend_field` windows) to
  evaluate the whole polynomial in **one slab residency**: r + 3 metric
  diagonals in, z out (18 streams/iter total, `cost.CHEB_V2_*`; the win
  is the iteration count).  The interval comes from
  :func:`estimate_interval` — a weighted-Lanczos eigenvalue estimate
  that extends ``cg_sstep.estimate_theta``'s one-sided power iteration
  to both ends of the spectrum.

* **Tolerance-driven fused solves** (:func:`cg_fused_tol`): the same
  per-iteration bodies under a ``lax.while_loop`` with
  :func:`repro.core.cg.cg`'s stopping rule (`|rtz| <= tol**2`, checked
  *before* each iteration), for the unpreconditioned v2 pipeline and
  both PCG variants.  The iteration body is shared with the
  fixed-iteration drivers (``cg_fused._v2_iter`` and the `_pcg_*` cores
  below run with a ``tol2 = -1`` sentinel), so the tolerance-driven
  trajectory reproduces the fixed-iteration trajectory as a prefix *by
  construction*.  The s-step driver gets the same semantics per cycle
  (``cg_sstep_fixed_iters(tol=...)``) with the stopping point resolved
  to iteration granularity through the f64 Gram recurrence.

Preconditions are the v2 pipeline's (structured axis-aligned box,
assembled+masked ``b``); the ``precision`` policy (DESIGN.md §7)
composes unchanged — the carried ``z`` streams at storage width and both
reduction partials see the *stored* vector; the operator diagonal and
the Chebyshev windows are operator data (``op_storage`` dtype).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

import repro.core.gs as gs_mod
from repro.core import pmg as _pmg
from repro.core.cg import CGResult, SolveResult
from repro.core.cg_fused import _check_box_fields, _v2_iter
from repro.core.cost import CHEB_DEFAULT_K, PMG_DEFAULT_K
from repro.core.geom import box_axis_factors, box_outer
from repro.core.precision import resolve_policy
from repro.kernels import autotune as _autotune
from repro.kernels import nekbone_ax as _ax

__all__ = ["CHEB_DEFAULT_K", "PMG_DEFAULT_K", "JacobiPrecond",
           "ChebyshevPrecond", "PMGPrecond",
           "make_preconditioner", "operator_diagonal", "estimate_interval",
           "cheb_scalars", "chebyshev_preconditioner",
           "pcg_fused_v2_fixed_iters", "cg_fused_tol"]

# re-exported so every preconditioner spec is importable from one place
# (the pmg module owns the V-cycle setup/reference; the fused driver
# lives here, next to its cheb/jacobi siblings).
PMGPrecond = _pmg.PMGPrecond


# ---------------------------------------------------------------------------
# operator diagonal (Jacobi)
# ---------------------------------------------------------------------------

def operator_diagonal(D: jnp.ndarray, g: jnp.ndarray, grid, mask) -> jnp.ndarray:
    """diag(A) of the assembled, masked SEM Poisson operator, structurally.

    For the tensor-product operator ``w = D^T G D u`` the element-local
    diagonal is three small contractions of ``D ∘ D`` against the metric
    diagonal; assembly (gather-scatter) then sums coincident copies.
    Masked rows are set to 1 (identity-like — they carry no residual), so
    the inverse never divides by zero.

    Args:
      D: (n, n); g: (E, 6, n, n, n) metric or its (E, 3, ...) diagonal;
      grid: element grid; mask: (E, n, n, n) Dirichlet mask.
    """
    g = jnp.asarray(g)
    if g.shape[1] == 6:
        grr, gss, gtt = g[:, 0], g[:, 3], g[:, 5]
    elif g.shape[1] == 3:
        grr, gss, gtt = g[:, 0], g[:, 1], g[:, 2]
    else:
        raise ValueError(f"metric must have 3 or 6 components, got {g.shape}")
    D2 = D * D  # (a, b): D[a,b]^2
    dr = jnp.einsum("li,ekjl->ekji", D2, grr)
    ds = jnp.einsum("lj,ekli->ekji", D2, gss)
    dt = jnp.einsum("lk,elji->ekji", D2, gtt)
    diag = gs_mod.ds_sum_local(dr + ds + dt, tuple(grid))
    return jnp.where(jnp.asarray(mask) > 0, diag, 1.0)


# ---------------------------------------------------------------------------
# Chebyshev recurrence scalars and the reference (XLA) applier
# ---------------------------------------------------------------------------

def cheb_scalars(k: int, lmin: float, lmax: float) -> np.ndarray:
    """Chebyshev-semi-iteration recurrence scalars for ``q_k(A) ≈ A^-1``.

    The incremental-residual form (Saad, *Iterative Methods*, Alg. 12.1,
    started from ``x0 = 0``) applied for ``k`` operator applications:

        d = coef[0,0] * r;  z = d;  res = r
        for i in 1..k:
            res -= A d
            d    = coef[i,0] * d + coef[i,1] * res
            z   += d

    yields the degree-k polynomial whose error ``1 - λ q_k(λ)`` is the
    scaled-and-shifted Chebyshev polynomial minimizing the max over
    ``[lmin, lmax]``.  On that interval ``λ q_k(λ) ∈ (0, 2)``, so ``q_k``
    is positive there — ``M^-1 = q_k(A)`` is SPD whenever the interval
    covers the spectrum (over-estimating ``lmax`` is the safe direction;
    under-estimating ``lmin`` only costs effectiveness, §9.3).

    Returns an (k+1, 2) float64 array: row 0 = (1/θ, 0) with
    ``θ = (lmax+lmin)/2``; row i = (ρ_i ρ_{i-1}, 2 ρ_i / δ) with
    ``δ = (lmax-lmin)/2``, ``σ1 = θ/δ``, ``ρ_0 = 1/σ1``,
    ``ρ_i = 1/(2σ1 - ρ_{i-1})``.
    """
    if k < 1:
        raise ValueError(f"Chebyshev order must be >= 1, got {k}")
    lmin = float(lmin)
    lmax = float(lmax)
    if not (0.0 < lmin < lmax) or not np.isfinite(lmax):
        raise ValueError(f"need 0 < lmin < lmax, got [{lmin}, {lmax}]")
    theta = 0.5 * (lmax + lmin)
    delta = 0.5 * (lmax - lmin)
    sigma1 = theta / delta
    rho_prev = 1.0 / sigma1
    coef = np.zeros((k + 1, 2), np.float64)
    coef[0, 0] = 1.0 / theta
    for i in range(1, k + 1):
        rho = 1.0 / (2.0 * sigma1 - rho_prev)
        coef[i, 0] = rho * rho_prev
        coef[i, 1] = 2.0 * rho / delta
        rho_prev = rho
    return coef


def chebyshev_preconditioner(A, k: int, lmin: float, lmax: float):
    """Reference (XLA-composed) Chebyshev applier ``M(r) = q_k(A) r``.

    The oracle the fused kernel's parity tests compare against, and a
    drop-in ``precond=`` callable for :func:`repro.core.cg.cg` /
    ``cg_fixed_iters`` on any operator ``A`` (not just the box).
    """
    coef = cheb_scalars(k, lmin, lmax)

    def M(r):
        d = coef[0, 0] * r
        z = d
        res = r
        for i in range(1, k + 1):
            res = res - A(d)
            d = coef[i, 0] * d + coef[i, 1] * res
            z = z + d
        return z

    return M


# ---------------------------------------------------------------------------
# spectrum interval estimate: weighted Lanczos (extends estimate_theta)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("grid", "iters"))
def _lanczos_tridiag(D, g, mask, c, *, grid: tuple[int, int, int],
                     iters: int):
    """``iters`` steps of Lanczos on the assembled masked operator.

    Runs in the c-weighted inner product (the one ``A`` is self-adjoint
    in on continuous fields — the same identity the fused pap partial
    rests on, DESIGN.md §3.2); the start vector is one operator
    application of the deterministic ramp ``cg_sstep._theta_power_iter``
    uses, which makes it continuous (gs output) and drops any component
    outside range(A).  Returns the tridiagonal entries
    ``(alphas[iters], betas[iters])`` — no reorthogonalization (the
    extreme Ritz values converge first, which is all the interval
    needs).
    """
    from repro.core.ax import ax_local_fused

    tiny = jnp.asarray(np.finfo(np.float32).tiny, mask.dtype)

    def A(v):
        return gs_mod.ds_sum_local(ax_local_fused(v, D, g), grid) * mask

    def dot(u, v):
        return jnp.sum(u * c * v)

    v0 = A(jnp.linspace(1.0, 2.0, mask.size).reshape(mask.shape)
           .astype(mask.dtype) * mask)
    q = v0 / jnp.maximum(jnp.sqrt(jnp.abs(dot(v0, v0))), tiny)

    def body(j, carry):
        q_prev, q, beta, alphas, betas = carry
        w = A(q)
        alpha = dot(w, q)
        w = w - alpha * q - beta * q_prev
        beta_new = jnp.sqrt(jnp.abs(dot(w, w)))
        q_new = w / jnp.maximum(beta_new, tiny)
        alphas = alphas.at[j].set(alpha)
        betas = betas.at[j].set(beta_new)
        return q, q_new, beta_new, alphas, betas

    zeros = jnp.zeros((iters,), mask.dtype)
    _, _, _, alphas, betas = jax.lax.fori_loop(
        0, iters, body, (jnp.zeros_like(q), q, jnp.zeros((), mask.dtype),
                         zeros, zeros))
    return alphas, betas


def estimate_interval(D: jnp.ndarray, g: jnp.ndarray,
                      grid: tuple[int, int, int], mask: jnp.ndarray,
                      c: jnp.ndarray | None = None,
                      iters: int = 16) -> tuple[float, float]:
    """Lanczos estimate of ``[λmin, λmax]`` for the Chebyshev interval.

    Extends ``cg_sstep.estimate_theta`` (a one-sided power iteration on
    ``‖A‖``) to both ends of the spectrum: the tridiagonal Ritz values of
    a short weighted-Lanczos run bracket the extreme eigenvalues from
    inside, so the returned interval applies safety factors in the
    *safe* directions — λmax is inflated (the SPD-critical end: the
    Chebyshev error polynomial is only bounded inside the interval's
    right edge) and λmin deflated (under-shooting it merely weakens the
    polynomial, §9.3).  A one-time setup cost per case, like theta.

    Returns a ``(lmin, lmax)`` float pair, guaranteed
    ``0 < lmin < lmax`` (degenerate estimates fall back to
    ``lmax / 100``).
    """
    grid = tuple(grid)
    if c is None:
        (mxf, myf, mzf), (cxf, cyf, czf) = box_axis_factors(grid,
                                                            mask.shape[-1])
        c = box_outer(czf, cyf, cxf).reshape(mask.shape)
    alphas, betas = _lanczos_tridiag(jnp.asarray(D), jnp.asarray(g),
                                     jnp.asarray(mask),
                                     jnp.asarray(c, mask.dtype),
                                     grid=grid, iters=int(iters))
    alphas = np.asarray(alphas, np.float64)
    betas = np.asarray(betas, np.float64)
    # truncate at Krylov breakdown (beta ~ 0): later entries are noise.
    scale = max(np.abs(alphas).max(), 1.0)
    good = np.nonzero(betas < 1e-12 * scale)[0]
    m = int(good[0]) + 1 if good.size else alphas.size
    T = np.diag(alphas[:m])
    if m > 1:
        off = betas[:m - 1]
        T += np.diag(off, 1) + np.diag(off, -1)
    ritz = np.linalg.eigvalsh(T)
    lmax = float(ritz[-1]) * 1.05
    lmin = float(ritz[0]) * 0.9
    if not np.isfinite(lmax) or lmax <= 0.0:
        return 0.01, 1.0
    if not np.isfinite(lmin) or lmin <= 0.0 or lmin >= lmax:
        lmin = lmax / 100.0
    return lmin, lmax


# ---------------------------------------------------------------------------
# preconditioner specs
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class JacobiPrecond:
    """Diagonal preconditioner: slab-resident assembled ``1/diag(A)``."""

    invdiag: jnp.ndarray                 # (E, n, n, n), 1 at masked rows
    name: str = dataclasses.field(default="jacobi", init=False)


@dataclasses.dataclass(frozen=True)
class ChebyshevPrecond:
    """Chebyshev polynomial preconditioner of order ``k`` on an interval."""

    k: int
    lmin: float
    lmax: float
    name: str = dataclasses.field(default="cheb", init=False)

    def scalars(self) -> np.ndarray:
        """The (k+1, 2) f64 recurrence-scalar table (:func:`cheb_scalars`)."""
        return cheb_scalars(self.k, self.lmin, self.lmax)


def make_preconditioner(name: str, *, D: jnp.ndarray, g: jnp.ndarray,
                        grid: tuple[int, int, int],
                        mask: jnp.ndarray | None = None,
                        c: jnp.ndarray | None = None,
                        k: int = CHEB_DEFAULT_K,
                        interval: tuple[float, float] | None = None,
                        lengths: tuple[float, float, float] = (1.0, 1.0,
                                                               1.0)):
    """Build a preconditioner spec from its registry name.

    Args:
      name: ``"jacobi"``; ``"cheb"``/``"chebyshev"`` (optionally with a
            trailing order, e.g. ``"cheb2"`` — overrides ``k``); or
            ``"pmg"`` (optionally with a smoother order, ``"pmg[cheb2]"``)
            — the p-multigrid V-cycle (DESIGN.md §13).
      D/g/grid: the operator's defining data, as the fused drivers take.
      mask/c: structural fields (rebuilt from the box factors if omitted).
      k: Chebyshev order (default :data:`CHEB_DEFAULT_K`; the pmg
         smoother has its own default, :data:`CHEB_DEFAULT_K` does not
         leak into it).
      interval: Chebyshev ``(lmin, lmax)`` override (default: the
            :func:`estimate_interval` Lanczos estimate — a one-time setup
            cost per case).
      lengths: physical box extents — pmg only (its coarse levels are
            rediscretizations of the same box, so they must know it).
    """
    grid = tuple(grid)
    if mask is None:
        n = jnp.asarray(D).shape[-1]
        (mxf, myf, mzf), _ = box_axis_factors(grid, n)
        mask = box_outer(mzf, myf, mxf).reshape(-1, n, n, n)
        mask = jnp.asarray(mask, jnp.asarray(g).dtype)
    key = str(name).lower()
    if key == "jacobi":
        diag = operator_diagonal(jnp.asarray(D), g, grid, mask)
        return JacobiPrecond(invdiag=1.0 / diag)
    if key.startswith("pmg"):
        suffix = key.removeprefix("pmg")
        kk = PMG_DEFAULT_K
        if suffix:
            inner = suffix.removeprefix("[cheb").removesuffix("]")
            if (suffix == f"[cheb{inner}]" and inner.isdigit()
                    and int(inner) >= 1):
                kk = int(inner)
            else:
                raise ValueError(f"unknown preconditioner {name!r}; the "
                                 "pmg spellings are 'pmg' and "
                                 "'pmg[cheb<k>]'")
        return _pmg.make_pmg_preconditioner(D=D, g=g, grid=grid, mask=mask,
                                            c=c, k=kk, lengths=lengths)
    if key.startswith("cheb"):
        suffix = key.removeprefix("chebyshev").removeprefix("cheb")
        if suffix:
            k = int(suffix)
        if interval is None:
            interval = estimate_interval(D, g, grid, mask, c)
        return ChebyshevPrecond(k=int(k), lmin=float(interval[0]),
                                lmax=float(interval[1]))
    raise ValueError(f"unknown preconditioner {name!r}; expected 'jacobi', "
                     "'cheb[<k>]', 'pmg', or 'pmg[cheb<k>]'")


# ---------------------------------------------------------------------------
# jitted solver cores.  All three share the stopping rule of core/cg.cg —
# the while_loop runs while  k < max_iter  AND  |rtz| > tol2 — and the
# fixed-iteration entry points reuse them with the sentinel tol2 = -1
# (never satisfied, so exactly max_iter iterations run and the trajectory
# is the tol-driven one's continuation — the prefix property).
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("n", "grid", "max_iter", "sz",
                                             "interpret", "acc_name",
                                             "x_name", "layout",
                                             "grid_order"))
def _cg_v2_tol(b, D, Dt, g3, mx, my, mz, cx, cy, cz, tol2, *, n: int,
               grid: tuple[int, int, int], max_iter: int, sz: int,
               interpret: bool, acc_name: str, x_name: str,
               layout: str = "fold",
               grid_order: str = "parallel") -> CGResult:
    ex, ey, ez = grid
    E = b.shape[0]
    n3 = n ** 3
    pln = ey * ex * n * n
    acc = jnp.dtype(acc_name)
    x_dtype = jnp.dtype(x_name)
    b2 = b.reshape(E, n3)
    c2 = box_outer(cz, cy, cx).reshape(E, n3).astype(acc)
    rtz0 = jnp.sum(b2.astype(acc) * c2 * b2.astype(acc))
    zero_plane = jnp.zeros((1, pln), b.dtype)
    hist0 = jnp.full((max_iter + 1,), jnp.nan, dtype=acc)
    tol2 = jnp.asarray(tol2, acc)

    def cond(state):
        _, _, _, rtz, _, _, kk = state
        return jnp.logical_and(kk < max_iter, jnp.abs(rtz) > tol2)

    def body(state):
        x2, r2, p2, rtz, beta, hist, kk = state
        hist = hist.at[kk].set(jnp.sqrt(jnp.abs(rtz)))
        x2, r2, p2, rtz_new, beta = _v2_iter(
            x2, r2, p2, rtz, beta, D=D, Dt=Dt, g3=g3, mx=mx, my=my, mz=mz,
            cx=cx, cy=cy, cz=cz, zero_plane=zero_plane, n=n, grid=grid,
            sz=sz, interpret=interpret, acc_name=acc_name, layout=layout,
            grid_order=grid_order)
        return x2, r2, p2, rtz_new, beta, hist, kk + 1

    state = (jnp.zeros(b2.shape, x_dtype), b2, jnp.zeros_like(b2), rtz0,
             jnp.zeros((), acc), hist0, jnp.asarray(0))
    x2, r2, p2, rtz, beta, hist, kk = jax.lax.while_loop(cond, body, state)
    hist = hist.at[kk].set(jnp.sqrt(jnp.abs(rtz)))
    return CGResult(x=x2.reshape(b.shape), iters=kk, rnorm=hist[kk],
                    rnorm_history=hist)


@functools.partial(jax.jit, static_argnames=("n", "grid", "max_iter", "sz",
                                             "interpret", "acc_name",
                                             "x_name", "layout",
                                             "grid_order"))
def _pcg_jacobi(b, invd, D, Dt, g3, mx, my, mz, cx, cy, cz, tol2, *, n: int,
                grid: tuple[int, int, int], max_iter: int, sz: int,
                interpret: bool, acc_name: str, x_name: str,
                layout: str = "fold",
                grid_order: str = "parallel") -> CGResult:
    """Fused Jacobi-PCG core: v2 slab front-half + PCG update back-half.

    The loop state carries ``z = invdiag * r`` instead of ``r``
    (DESIGN.md §9.2): the slab kernel's merged direction update
    ``p = z + beta p`` and its pap partial are then exactly PCG's, and
    only the update kernel needs the extra ``invdiag`` stream (14
    streams/iter).  ``rtz = r·c·z`` drives alpha/beta and the stopping
    rule (as in :func:`repro.core.cg.cg`); the history records the
    reconstructed ``sqrt(r·c·r)``, directly comparable to
    unpreconditioned CG's.
    """
    ex, ey, ez = grid
    E = b.shape[0]
    n3 = n ** 3
    pln = ey * ex * n * n
    acc = jnp.dtype(acc_name)
    x_dtype = jnp.dtype(x_name)
    b2 = b.reshape(E, n3)
    invd2 = invd.reshape(E, n3)
    c2 = box_outer(cz, cy, cx).reshape(E, n3).astype(acc)
    b_acc = b2.astype(acc)
    # z0 rounded through storage — the slab kernel reads the stored z
    # (§7 rule 1's analog for the carried vector).
    z0 = (invd2.astype(acc) * b_acc).astype(b.dtype)
    rtz0 = jnp.sum(b_acc * c2 * z0.astype(acc))
    rcr0 = jnp.sum(b_acc * c2 * b_acc)
    zero_plane = jnp.zeros((1, pln), b.dtype)
    hist0 = jnp.full((max_iter + 1,), jnp.nan, dtype=acc) \
        .at[0].set(jnp.sqrt(jnp.abs(rcr0)))
    tol2 = jnp.asarray(tol2, acc)

    def cond(state):
        _, _, _, rtz, _, _, kk = state
        return jnp.logical_and(kk < max_iter, jnp.abs(rtz) > tol2)

    def body(state):
        x2, z2, p2, rtz, beta, hist, kk = state
        p2, w2, bot, top, pap_b = _ax.nekbone_ax_slab_pallas(
            p2, z2, D, Dt, g3, mx, my, mz, beta.reshape(1, 1),
            n=n, grid=grid, sz=sz, interpret=interpret, acc_dtype=acc_name,
            layout=layout, grid_order=grid_order)
        alpha = rtz / jnp.sum(pap_b)
        addb = jnp.concatenate([zero_plane, top[:-1]], axis=0)
        addt = jnp.concatenate([bot[1:], zero_plane], axis=0)
        x2, z2, rtz_b, rcr_b = _ax.nekbone_pcg_update_pallas(
            x2, p2, z2, w2, addb, addt, alpha.reshape(1, 1), invd2,
            cx, cy, cz, n=n, grid=grid, sz=sz, interpret=interpret,
            acc_dtype=acc_name)
        rtz_new = jnp.sum(rtz_b)
        beta = rtz_new / rtz
        hist = hist.at[kk + 1].set(jnp.sqrt(jnp.abs(jnp.sum(rcr_b))))
        return x2, z2, p2, rtz_new, beta, hist, kk + 1

    state = (jnp.zeros(b2.shape, x_dtype), z0, jnp.zeros_like(z0), rtz0,
             jnp.zeros((), acc), hist0, jnp.asarray(0))
    x2, z2, p2, rtz, beta, hist, kk = jax.lax.while_loop(cond, body, state)
    return CGResult(x=x2.reshape(b.shape), iters=kk, rnorm=hist[kk],
                    rnorm_history=hist)


@functools.partial(jax.jit, static_argnames=("n", "grid", "max_iter", "sz",
                                             "sz_c", "k", "interpret",
                                             "acc_name", "x_name",
                                             "layout", "grid_order"))
def _pcg_cheb(b, D, Dt, g3, mx, my, mz, cx, cy, cz, coef, tol2, *, n: int,
              grid: tuple[int, int, int], max_iter: int, sz: int, sz_c: int,
              k: int, interpret: bool, acc_name: str, x_name: str,
              layout: str = "fold",
              grid_order: str = "parallel") -> CGResult:
    """Fused Chebyshev-PCG core: cheb apply + v2 slab + v2 update.

    Per iteration: the halo'd Chebyshev kernel evaluates
    ``z = q_k(A) r`` and the ``rtz = r·c·z`` partial in one slab
    residency (it runs at the *end* of the body, on the freshly updated
    residual, so the while_loop's stopping rule sees the same rtz
    :func:`repro.core.cg.cg` checks); the unmodified v2 slab and update
    kernels then run the direction update / operator / axpys — 13 + 5 =
    18 streams/iter (DESIGN.md §9.3), the win being the iteration count.
    """
    ex, ey, ez = grid
    E = b.shape[0]
    n3 = n ** 3
    pln = ey * ex * n * n
    acc = jnp.dtype(acc_name)
    x_dtype = jnp.dtype(x_name)
    b2 = b.reshape(E, n3)
    c2 = box_outer(cz, cy, cx).reshape(E, n3).astype(acc)
    rcr0 = jnp.sum(b2.astype(acc) * c2 * b2.astype(acc))
    zero_plane = jnp.zeros((1, pln), b.dtype)
    # halo'd operator windows for the cheb kernel, built once per solve
    # (loop-invariant); the per-iteration residual window gather below is
    # part of the halo side channel (§8.2's honesty note).
    gext = _ax.sstep_extend_field(g3, grid, sz_c, k)
    mzext = _ax.sstep_extend_zfactor(mz, sz_c, k)

    def cheb(r2):
        rext = _ax.sstep_extend_field(r2, grid, sz_c, k)
        z2, rtz_b = _ax.nekbone_cheb_apply_pallas(
            rext, D, Dt, gext, mx, my, mzext, cx, cy, cz, coef,
            n=n, grid=grid, sz=sz_c, k=k, interpret=interpret,
            acc_dtype=acc_name, layout=layout, grid_order=grid_order)
        return z2, jnp.sum(rtz_b)

    z0, rtz0 = cheb(b2)
    hist0 = jnp.full((max_iter + 1,), jnp.nan, dtype=acc) \
        .at[0].set(jnp.sqrt(jnp.abs(rcr0)))
    tol2 = jnp.asarray(tol2, acc)

    def cond(state):
        _, _, _, _, rtz, _, _, kk = state
        return jnp.logical_and(kk < max_iter, jnp.abs(rtz) > tol2)

    def body(state):
        x2, r2, z2, p2, rtz, rtz_prev, hist, kk = state
        beta = rtz / rtz_prev            # rtz_prev = 1 at k=0: p0 = 0
        p2, w2, bot, top, pap_b = _ax.nekbone_ax_slab_pallas(
            p2, z2, D, Dt, g3, mx, my, mz, beta.reshape(1, 1),
            n=n, grid=grid, sz=sz, interpret=interpret, acc_dtype=acc_name,
            layout=layout, grid_order=grid_order)
        alpha = rtz / jnp.sum(pap_b)
        addb = jnp.concatenate([zero_plane, top[:-1]], axis=0)
        addt = jnp.concatenate([bot[1:], zero_plane], axis=0)
        x2, r2, rcr_b = _ax.nekbone_cg_update_pallas(
            x2, p2, r2, w2, addb, addt, alpha.reshape(1, 1), cx, cy, cz,
            n=n, grid=grid, sz=sz, interpret=interpret, acc_dtype=acc_name)
        hist = hist.at[kk + 1].set(jnp.sqrt(jnp.abs(jnp.sum(rcr_b))))
        z2, rtz_new = cheb(r2)
        return x2, r2, z2, p2, rtz_new, rtz, hist, kk + 1

    state = (jnp.zeros(b2.shape, x_dtype), b2, z0, jnp.zeros_like(b2),
             rtz0, jnp.ones((), acc), hist0, jnp.asarray(0))
    x2, r2, z2, p2, rtz, rtz_prev, hist, kk = jax.lax.while_loop(cond, body,
                                                                 state)
    return CGResult(x=x2.reshape(b.shape), iters=kk, rnorm=hist[kk],
                    rnorm_history=hist)


@functools.partial(jax.jit, static_argnames=("n", "grid", "max_iter", "sz",
                                             "ns", "szs", "cheb_szs", "k",
                                             "coarse_iters", "interpret",
                                             "acc_name", "x_name",
                                             "layout", "grid_order"))
def _pcg_pmg(b, D, Dt, g3, mx, my, mz, cx, cy, cz, levels, tol2, *, n: int,
             grid: tuple[int, int, int], max_iter: int, sz: int,
             ns: tuple[int, ...], szs: tuple[int, ...],
             cheb_szs: tuple[int, ...], k: int, coarse_iters: int,
             interpret: bool, acc_name: str, x_name: str,
             layout: str = "fold",
             grid_order: str = "parallel") -> CGResult:
    """Fused p-multigrid PCG core (DESIGN.md §13).

    The :func:`_pcg_cheb` loop with the single polynomial apply replaced
    by a symmetric V-cycle over the degree ladder ``ns``: per smoothed
    level a Chebyshev(k) pre-smooth (the fused apply kernel on that
    level's rediscretized operator), an explicit residual via the v2 slab
    kernel (beta=0, planes stitched host-side), the c-weighted-adjoint
    restriction (c-multiply -> Pallas interp -> gather-scatter -> mask),
    recursion, tensor-product prolongation + masked correction, a second
    residual and a Chebyshev post-smooth — then ``rtz = r·c·z`` host-side
    in the accumulation dtype.  The recursion is a *static* Python unroll
    (the ladder is a static argname), so every level's kernels trace at
    their own ``n_l``/slab split (``szs``/``cheb_szs``, autotuned under
    per-level ``pmg:<level>`` keys).

    ``levels`` is the :func:`repro.core.pmg.pmg_level_pytree` operand
    pytree; level 0 runs on the caller's operator data (the same
    ``D``/``g3``/factor operands the unpreconditioned pipeline uses), and
    the base level is the shared fixed-CG solve
    (:func:`repro.core.pmg.coarse_solve_fixed` — shared with the XLA
    reference cycle so interpret-mode parity isolates the kernels).
    """
    ex, ey, ez = grid
    E = b.shape[0]
    n3 = n ** 3
    pln = ey * ex * n * n
    acc = jnp.dtype(acc_name)
    x_dtype = jnp.dtype(x_name)
    b2 = b.reshape(E, n3)
    c2 = box_outer(cz, cy, cx).reshape(E, n3).astype(acc)
    rcr0 = jnp.sum(b2.astype(acc) * c2 * b2.astype(acc))
    zero_plane = jnp.zeros((1, pln), b.dtype)
    coefs, transfers, midops, coarse = levels
    L = len(ns)
    # per-smoothed-level kernel operands, fine -> coarsest smoothed
    lops = [(D, Dt, g3, mx, my, mz, cx, cy, cz)]
    for (Dl, g3l, mxl, myl, mzl, cxl, cyl, czl) in midops:
        lops.append((Dl, Dl.T, g3l, mxl, myl, mzl, cxl, cyl, czl))
    # loop-invariant per-level windows and full structural fields
    gexts, mzexts, mask2s, c2s = [], [], [], []
    for lev in range(L - 1):
        _, _, g3l, mxl, myl, mzl, cxl, cyl, czl = lops[lev]
        nl3 = ns[lev] ** 3
        gexts.append(_ax.sstep_extend_field(g3l, grid, cheb_szs[lev], k))
        mzexts.append(_ax.sstep_extend_zfactor(mzl, cheb_szs[lev], k))
        mask2s.append(box_outer(mzl, myl, mxl).reshape(E, nl3))
        c2s.append(box_outer(czl, cyl, cxl).reshape(E, nl3).astype(acc))
    Dc, gc, maskc, cc = coarse
    nc = ns[-1]
    mask2s.append(maskc.reshape(E, nc ** 3))

    def smooth(r2l, lev):
        Dl, Dtl, _, mxl, myl, _, cxl, cyl, czl = lops[lev]
        rext = _ax.sstep_extend_field(r2l, grid, cheb_szs[lev], k)
        z2l, _ = _ax.nekbone_cheb_apply_pallas(
            rext, Dl, Dtl, gexts[lev], mxl, myl, mzexts[lev],
            cxl, cyl, czl, coefs[lev], n=ns[lev], grid=grid,
            sz=cheb_szs[lev], k=k, interpret=interpret, acc_dtype=acc_name,
            layout=layout, grid_order=grid_order)
        return z2l

    def apply_a(z2l, lev):
        nl, szl = ns[lev], szs[lev]
        Dl, Dtl, g3l, mxl, myl, mzl, *_ = lops[lev]
        _, w2, bot, top, _ = _ax.nekbone_ax_slab_pallas(
            jnp.zeros_like(z2l), z2l, Dl, Dtl, g3l, mxl, myl, mzl,
            jnp.zeros((1, 1), acc), n=nl, grid=grid, sz=szl,
            interpret=interpret, acc_dtype=acc_name, layout=layout,
            grid_order=grid_order)
        nblk = ez // szl
        if nblk > 1:
            vb = w2.reshape(nblk, szl, ey, ex, nl, nl, nl)
            plshape = (nblk - 1, ey, ex, nl, nl)
            vb = vb.at[1:, 0, :, :, 0, :, :].add(top[:-1].reshape(plshape))
            vb = vb.at[:-1, -1, :, :, -1, :, :].add(bot[1:].reshape(plshape))
            w2 = vb.reshape(E, nl ** 3)
        return w2

    def restrict(res2, lev):
        ncl = ns[lev + 1]
        t2 = (res2.astype(acc) * c2s[lev]).astype(res2.dtype)
        rc2 = _ax.nekbone_interp_pallas(
            t2, transfers[lev], nin=ns[lev], nout=ncl, grid=grid,
            sz=szs[lev], interpret=interpret, acc_dtype=acc_name)
        rc2 = gs_mod.ds_sum_local(
            rc2.reshape(E, ncl, ncl, ncl), grid).reshape(E, ncl ** 3)
        return rc2 * mask2s[lev + 1].astype(rc2.dtype)

    def prolong(ec2, lev):
        return _ax.nekbone_interp_pallas(
            ec2, jnp.swapaxes(transfers[lev], 0, 1), nin=ns[lev + 1],
            nout=ns[lev], grid=grid, sz=szs[lev], interpret=interpret,
            acc_dtype=acc_name)

    def vcycle_level(r2l, lev):
        if lev == L - 1:
            e4 = _pmg.coarse_solve_fixed(
                r2l.reshape(E, nc, nc, nc).astype(acc), Dc, gc, grid,
                maskc, cc, iters=coarse_iters)
            return e4.reshape(E, nc ** 3).astype(b.dtype)
        z2l = smooth(r2l, lev)
        res = (r2l.astype(acc) - apply_a(z2l, lev).astype(acc)) \
            .astype(r2l.dtype)
        ec = vcycle_level(restrict(res, lev), lev + 1)
        z2l = (z2l.astype(acc) + prolong(ec, lev).astype(acc)
               * mask2s[lev].astype(acc)).astype(r2l.dtype)
        res = (r2l.astype(acc) - apply_a(z2l, lev).astype(acc)) \
            .astype(r2l.dtype)
        return (z2l.astype(acc) + smooth(res, lev).astype(acc)) \
            .astype(r2l.dtype)

    def vcycle(r2):
        z2 = vcycle_level(r2, 0)
        return z2, jnp.sum(r2.astype(acc) * c2 * z2.astype(acc))

    z0, rtz0 = vcycle(b2)
    hist0 = jnp.full((max_iter + 1,), jnp.nan, dtype=acc) \
        .at[0].set(jnp.sqrt(jnp.abs(rcr0)))
    tol2 = jnp.asarray(tol2, acc)

    def cond(state):
        _, _, _, _, rtz, _, _, kk = state
        return jnp.logical_and(kk < max_iter, jnp.abs(rtz) > tol2)

    def body(state):
        x2, r2, z2, p2, rtz, rtz_prev, hist, kk = state
        beta = rtz / rtz_prev            # rtz_prev = 1 at k=0: p0 = 0
        p2, w2, bot, top, pap_b = _ax.nekbone_ax_slab_pallas(
            p2, z2, D, Dt, g3, mx, my, mz, beta.reshape(1, 1),
            n=n, grid=grid, sz=sz, interpret=interpret, acc_dtype=acc_name,
            layout=layout, grid_order=grid_order)
        alpha = rtz / jnp.sum(pap_b)
        addb = jnp.concatenate([zero_plane, top[:-1]], axis=0)
        addt = jnp.concatenate([bot[1:], zero_plane], axis=0)
        x2, r2, rcr_b = _ax.nekbone_cg_update_pallas(
            x2, p2, r2, w2, addb, addt, alpha.reshape(1, 1), cx, cy, cz,
            n=n, grid=grid, sz=sz, interpret=interpret, acc_dtype=acc_name)
        hist = hist.at[kk + 1].set(jnp.sqrt(jnp.abs(jnp.sum(rcr_b))))
        z2, rtz_new = vcycle(r2)
        return x2, r2, z2, p2, rtz_new, rtz, hist, kk + 1

    state = (jnp.zeros(b2.shape, x_dtype), b2, z0, jnp.zeros_like(b2),
             rtz0, jnp.ones((), acc), hist0, jnp.asarray(0))
    x2, r2, z2, p2, rtz, rtz_prev, hist, kk = jax.lax.while_loop(cond, body,
                                                                 state)
    return CGResult(x=x2.reshape(b.shape), iters=kk, rnorm=hist[kk],
                    rnorm_history=hist)


# ---------------------------------------------------------------------------
# public drivers
# ---------------------------------------------------------------------------

def _prepare(b, D, g, grid, mask, c, sz, interpret, precision, precond,
             layout=None, grid_order=None):
    """Shared operand preparation for the fused v2-family drivers."""
    from repro.kernels import ops as kernel_ops

    policy = resolve_policy(precision, b.dtype)
    b = jnp.asarray(b, policy.storage_dtype)
    E = b.shape[0]
    n = b.shape[-1]
    grid = tuple(grid)
    if interpret is None:
        interpret = kernel_ops.default_interpret()
    # only Jacobi changes the slab kernels' working set (the update
    # kernel holds the diagonal block); Chebyshev runs the unmodified
    # v2 kernels — its own apply kernel is tuned by pick_slab_sz_cheb
    # — so it shares the plain pick rather than re-measuring.
    jac = (isinstance(precond, JacobiPrecond)
           or (isinstance(precond, str) and precond == "jacobi"))
    if sz is None and layout is None and grid_order is None:
        sz, layout, grid_order = _autotune.pick_slab_config(
            grid, n, b.dtype, acc_dtype=policy.accum,
            precond="jacobi" if jac else None)
    elif sz is None:
        sz = _autotune.pick_slab_sz(grid, n, b.dtype,
                                    acc_dtype=policy.accum,
                                    precond="jacobi" if jac else None)
    layout = "fold" if layout is None else layout
    grid_order = "parallel" if grid_order is None else grid_order
    _check_box_fields(grid, n, mask, c)
    (mx, my, mz), (cx, cy, cz) = kernel_ops.slab_axis_factors(grid, n,
                                                              b.dtype)
    D_op = jnp.asarray(D, policy.op_storage_dtype)
    g3 = kernel_ops.diag_metric(jnp.asarray(g, policy.op_storage_dtype),
                                E, n)
    return (policy, b, n, grid, sz, layout, grid_order, interpret,
            (mx, my, mz), (cx, cy, cz), D_op, g3)


def _resolve_precond(precond, *, D, g, grid, mask, c):
    if precond is None or isinstance(precond, (JacobiPrecond,
                                               ChebyshevPrecond,
                                               _pmg.PMGPrecond)):
        return precond
    return make_preconditioner(str(precond), D=D, g=g, grid=grid,
                               mask=mask, c=c)


def _dispatch(b, precond, tol2, max_iter, *, policy, n, grid, sz, interpret,
              m_factors, c_factors, D_op, g3,
              cheb_sz: int | None = None, layout: str = "fold",
              grid_order: str = "parallel") -> CGResult:
    mx, my, mz = m_factors
    cx, cy, cz = c_factors
    common = dict(n=n, grid=grid, max_iter=max_iter, sz=sz,
                  interpret=interpret, acc_name=policy.accum,
                  x_name=policy.x_storage_dtype.name, layout=layout,
                  grid_order=grid_order)
    if precond is None:
        return _cg_v2_tol(b, D_op, D_op.T, g3, mx, my, mz, cx, cy, cz,
                          tol2, **common)
    if isinstance(precond, JacobiPrecond):
        invd = jnp.asarray(precond.invdiag, policy.op_storage_dtype) \
            .reshape(b.shape[0], n ** 3)
        return _pcg_jacobi(b, invd, D_op, D_op.T, g3, mx, my, mz,
                           cx, cy, cz, tol2, **common)
    if isinstance(precond, ChebyshevPrecond):
        sz_c = cheb_sz
        if sz_c is None:
            sz_c = _autotune.pick_slab_sz_cheb(grid, n, precond.k, b.dtype,
                                               acc_dtype=policy.accum)
        coef = jnp.asarray(precond.scalars(), policy.accum_dtype)
        return _pcg_cheb(b, D_op, D_op.T, g3, mx, my, mz, cx, cy, cz,
                         coef, tol2, sz_c=sz_c, k=precond.k, **common)
    if isinstance(precond, _pmg.PMGPrecond):
        from repro.obs import trace as _trace

        rec = _trace.active()
        ns_t = precond.ns
        # per-level slab splits: the Az/interp kernels at each degree get
        # their own ``pmg:<level>`` autotune key; the level-0 smoother may
        # reuse the caller's cheb_sz pin (the paper-case workloads pin it).
        # The per-level host work (autotune picks) is the V-cycle's host
        # boundary — the jitted driver unrolls the ladder statically, so
        # these "pmg.vcycle.level" spans are where the per-level structure
        # is visible to a trace (DESIGN.md §14.2).
        szs = []
        cheb_szs = []
        for lev in range(len(ns_t) - 1):
            with (rec.span("pmg.vcycle.level", level=lev, n=ns_t[lev],
                           k=precond.k)
                  if rec is not None else _trace.NULL_SPAN):
                szs.append(_autotune.pick_slab_sz(
                    grid, ns_t[lev], b.dtype, acc_dtype=policy.accum,
                    precond=f"pmg:{lev}"))
                cheb_szs.append(
                    cheb_sz if lev == 0 and cheb_sz is not None else
                    _autotune.pick_slab_sz_cheb(grid, ns_t[lev],
                                                precond.k, b.dtype,
                                                acc_dtype=policy.accum))
        szs, cheb_szs = tuple(szs), tuple(cheb_szs)
        levels = _pmg.pmg_level_pytree(precond, grid,
                                       policy.op_storage_dtype.name,
                                       policy.accum)
        with (rec.span("pmg.dispatch", levels=len(ns_t),
                       coarse_n=ns_t[-1])
              if rec is not None else _trace.NULL_SPAN):
            with _trace.profiler_annotation("nekbone.pcg_pmg"):
                return _pcg_pmg(b, D_op, D_op.T, g3, mx, my, mz, cx, cy,
                                cz, levels, tol2, ns=ns_t, szs=szs,
                                cheb_szs=cheb_szs, k=precond.k,
                                coarse_iters=precond.coarse_iters,
                                **common)
    raise TypeError(f"unsupported preconditioner {precond!r}")


def pcg_fused_v2_fixed_iters(b: jnp.ndarray, *, D: jnp.ndarray,
                             g: jnp.ndarray, grid: tuple[int, int, int],
                             niter: int, precond,
                             mask: jnp.ndarray | None = None,
                             c: jnp.ndarray | None = None,
                             sz: int | None = None,
                             cheb_sz: int | None = None,
                             layout: str | None = None,
                             grid_order: str | None = None,
                             interpret: bool | None = None,
                             precision=None) -> CGResult:
    """Fixed-iteration *preconditioned* CG through the fused v2 pipeline.

    The PCG sibling of :func:`repro.core.cg_fused.cg_fused_v2_fixed_iters`
    (same arguments and preconditions), with ``precond`` a
    :class:`JacobiPrecond`, a :class:`ChebyshevPrecond`, or a registry
    name (``"jacobi"`` / ``"cheb[<k>]"`` — built via
    :func:`make_preconditioner`, which costs a one-time diagonal / Lanczos
    setup).  ``precond=None`` degenerates to the unpreconditioned v2
    driver.

    Matches ``cg_fixed_iters(A, b, precond=M, dot=weighted)`` to
    round-off of the policy's storage dtype; the residual-norm history
    records ``sqrt(r·c·r)`` exactly like unpreconditioned CG, so
    preconditioned and plain trajectories are directly comparable.
    ``sz`` pins the v2 kernels' slab split and ``cheb_sz`` the Chebyshev
    apply kernel's (defaults: autotuned — deeper polynomials want larger
    ``cheb_sz``, the halo is ``8k/sz`` streams, cost.cheb_halo_streams).
    """
    (policy, b, n, grid, sz, layout, grid_order, interpret, m_factors,
     c_factors, D_op, g3) = _prepare(b, D, g, grid, mask, c, sz, interpret,
                                     precision, precond, layout, grid_order)
    # specs built by name use the caller's (full-precision) operator data;
    # the drivers cast the resulting fields to the policy's op-storage.
    precond = _resolve_precond(precond, D=D, g=g, grid=grid, mask=mask, c=c)
    # tol2 = -1 sentinel: |rtz| > -1 always holds, so exactly ``niter``
    # iterations run — the tol-driven path's trajectory continued.
    return SolveResult.from_cg(
        _dispatch(b, precond, -1.0, niter, policy=policy, n=n, grid=grid,
                  sz=sz, interpret=interpret, m_factors=m_factors,
                  c_factors=c_factors, D_op=D_op, g3=g3, cheb_sz=cheb_sz,
                  layout=layout, grid_order=grid_order),
        pipeline="fused_v2", precond=getattr(precond, "name", None))


def cg_fused_tol(b: jnp.ndarray, *, D: jnp.ndarray, g: jnp.ndarray,
                 grid: tuple[int, int, int], tol: float = 1e-8,
                 max_iter: int = 100, precond=None,
                 mask: jnp.ndarray | None = None,
                 c: jnp.ndarray | None = None, sz: int | None = None,
                 cheb_sz: int | None = None,
                 layout: str | None = None,
                 grid_order: str | None = None,
                 interpret: bool | None = None, precision=None) -> CGResult:
    """Tolerance-driven fused-v2 (P)CG: solve to ``tol``, not 100 iters.

    The ``lax.while_loop`` sibling of the fixed-iteration drivers, with
    :func:`repro.core.cg.cg`'s stopping rule: iterate while
    ``k < max_iter`` and ``|rtz| > tol**2`` (``rtz = r·c·z``; ``= r·c·r``
    unpreconditioned), checking *before* each iteration.  The bodies are
    the fixed-iteration bodies, so the returned ``rnorm_history`` is a
    prefix of the fixed-iteration trajectory (NaN-padded to
    ``max_iter + 1`` like :func:`repro.core.cg.cg`) and ``iters`` is the
    count actually run.

    Args are :func:`pcg_fused_v2_fixed_iters`'s with ``tol``/``max_iter``
    replacing ``niter``; ``precond=None`` runs the plain v2 pipeline.
    """
    (policy, b, n, grid, sz, layout, grid_order, interpret, m_factors,
     c_factors, D_op, g3) = _prepare(b, D, g, grid, mask, c, sz, interpret,
                                     precision, precond, layout, grid_order)
    precond = _resolve_precond(precond, D=D, g=g, grid=grid, mask=mask, c=c)
    return SolveResult.from_cg(
        _dispatch(b, precond, float(tol) ** 2, max_iter, policy=policy,
                  n=n, grid=grid, sz=sz, interpret=interpret,
                  m_factors=m_factors, c_factors=c_factors, D_op=D_op,
                  g3=g3, cheb_sz=cheb_sz, layout=layout,
                  grid_order=grid_order),
        pipeline="fused_v2", precond=getattr(precond, "name", None))
