"""Spectral-element method (SEM) 1-D building blocks.

Gauss-Lobatto-Legendre (GLL) nodes/weights and the spectral differentiation
matrix, exactly as used by Nekbone/Nek5000 (``zwgll`` / ``dgll`` in speclib).

Everything here is tiny (n <= ~32) and computed once at setup time, so it is
done in float64 numpy for accuracy and cast to the requested dtype by callers.
"""
from __future__ import annotations

import functools

import numpy as np

__all__ = [
    "legendre",
    "gll_points_weights",
    "derivative_matrix",
    "SEMOperators",
]


def legendre(N: int, x: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Legendre polynomial P_N and derivative P'_N at points ``x``.

    Uses the three-term recurrence; stable for the small N used in SEM.
    """
    x = np.asarray(x, dtype=np.float64)
    p0 = np.ones_like(x)
    if N == 0:
        return p0, np.zeros_like(x)
    p1 = x
    for k in range(1, N):
        p0, p1 = p1, ((2 * k + 1) * x * p1 - k * p0) / (k + 1)
    # derivative from the standard identity (1-x^2) P_N' = N (P_{N-1} - x P_N)
    with np.errstate(divide="ignore", invalid="ignore"):
        dp = N * (p0 - x * p1) / (1.0 - x * x)
    # endpoints: P_N'(+-1) = (+-1)^{N-1} N(N+1)/2
    endval = N * (N + 1) / 2.0
    dp = np.where(x == 1.0, endval, dp)
    dp = np.where(x == -1.0, (-1.0) ** (N - 1) * endval, dp)
    return p1, dp


@functools.lru_cache(maxsize=64)
def gll_points_weights(n: int) -> tuple[np.ndarray, np.ndarray]:
    """``n`` GLL points (degree N = n-1) and quadrature weights on [-1, 1].

    Points are the roots of (1-x^2) P'_N(x); weights w_i = 2/(N(N+1) P_N(x_i)^2).
    """
    if n < 2:
        raise ValueError(f"GLL rule needs n >= 2, got {n}")
    N = n - 1
    # Chebyshev-Gauss-Lobatto initial guess, then Newton on q(x) = P'_N(x).
    x = -np.cos(np.pi * np.arange(n) / N)
    for _ in range(100):
        p, dp = legendre(N, x)
        # q = (1-x^2) P'_N ; interior roots are roots of P'_N.
        # Newton for P'_N: P''_N from the Legendre ODE:
        # (1-x^2) P''_N = 2x P'_N - N(N+1) P_N
        with np.errstate(divide="ignore", invalid="ignore"):
            d2p = (2.0 * x * dp - N * (N + 1) * p) / (1.0 - x * x)
        dx = np.zeros_like(x)
        interior = slice(1, n - 1)
        dx[interior] = dp[interior] / d2p[interior]
        x = x - dx
        if np.max(np.abs(dx)) < 1e-15:
            break
    x[0], x[-1] = -1.0, 1.0
    p, _ = legendre(N, x)
    w = 2.0 / (N * (N + 1) * p * p)
    return x, w


@functools.lru_cache(maxsize=64)
def derivative_matrix(n: int) -> np.ndarray:
    """Spectral differentiation matrix D on the n GLL points.

    ``D[i, j] = dl_j/dx (x_i)`` where l_j are the Lagrange cardinal functions,
    i.e. ``(du/dx)(x_i) = sum_j D[i, j] u(x_j)`` — Nekbone's ``dxm1``.
    """
    x, _ = gll_points_weights(n)
    N = n - 1
    p, _ = legendre(N, x)
    D = np.zeros((n, n), dtype=np.float64)
    for i in range(n):
        for j in range(n):
            if i != j:
                D[i, j] = p[i] / (p[j] * (x[i] - x[j]))
    D[0, 0] = -N * (N + 1) / 4.0
    D[N, N] = N * (N + 1) / 4.0
    return D


class SEMOperators:
    """Bundle of per-degree SEM reference operators (numpy, float64).

    Attributes:
      n:      GLL points per direction (= degree + 1)
      z, w:   1-D GLL nodes and weights, shape (n,)
      D:      differentiation matrix, shape (n, n)  (Nekbone dxm1)
      Dt:     D transpose (Nekbone dxtm1)
      w3:     3-D quadrature weights w_i w_j w_k, shape (n, n, n)
    """

    def __init__(self, n: int):
        self.n = int(n)
        self.z, self.w = gll_points_weights(self.n)
        self.D = derivative_matrix(self.n)
        self.Dt = self.D.T.copy()
        self.w3 = (
            self.w[:, None, None] * self.w[None, :, None] * self.w[None, None, :]
        )

    def __repr__(self) -> str:  # pragma: no cover - debug nicety
        return f"SEMOperators(n={self.n})"
