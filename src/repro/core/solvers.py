"""The solve-driver registry: pipeline × precond × tol × multi-RHS routing.

Before this module the routing lived as branching inlined in
``NekboneCase.solve``; now it is one table (DESIGN.md §12).  A *route* is
a named row of :data:`REGISTRY`; :func:`route_name` is the pure function
(case, request) -> row, and :func:`solve_case` executes it.  The
top-level facade :func:`repro.solve` and the solver service
(launch/solver_service.py) both dispatch through here, so there is
exactly one place where "which driver runs this request" is decided.

Routes (every driver returns :class:`repro.core.cg.SolveResult`):

=================  ======================================================
``block``          multi-RHS batched v2 (core/cg_block.py) — b > 1, or
                   an explicitly batched RHS, unpreconditioned
``block_loop``     b > 1 with a preconditioner or a non-v2 pipeline:
                   per-RHS solves through this table, stacked
``ir``             refined-precision fixed-iters (cg_ir_fixed_iters)
``sstep``          v3 matrix-powers cycles (cg_sstep_fixed_iters;
                   tol-driven via the per-cycle host sync)
``v2``             fused v2 fixed-iters, plain or fused PCG
``v2_tol``         tolerance-driven fused v2 (P)CG (cg_fused_tol)
``v1``             fused v1 fixed-iters
``reference``      XLA reference CG (cg / cg_fixed_iters), optional
                   reference preconditioner
=================  ======================================================
"""
from __future__ import annotations

import warnings
from typing import Callable

import jax.numpy as jnp

import repro.core.cg as cg_mod
import repro.core.cg_fused as cg_fused_mod
from repro.core.cg import SolveResult

__all__ = ["REGISTRY", "route_name", "solve_case", "solve"]

# one-time flag for the documented b>1 s-step fallback warning below
# (tests reset it to re-assert the warning fires).
_SSTEP_BLOCK_WARNED = False


# ---------------------------------------------------------------------------
# drivers — uniform signature: (case, f, *, b, niter, tol, max_iter,
# pc_name) -> SolveResult.  ``pc_name`` is the already-resolved registry
# preconditioner name (None = unpreconditioned).
# ---------------------------------------------------------------------------

def _drive_block(case, f, *, b, niter, tol, max_iter, pc_name):
    from repro.core.cg_block import cg_block_fixed_iters, cg_block_tol

    if niter is not None:
        return cg_block_fixed_iters(
            f, D=case.D, g=case.g, grid=case.grid, niter=niter,
            mask=case.mask, c=case.c, precision=case.precision)
    return cg_block_tol(
        f, D=case.D, g=case.g, grid=case.grid, tol=tol, max_iter=max_iter,
        mask=case.mask, c=case.c, precision=case.precision)


def _drive_block_loop(case, f, *, b, niter, tol, max_iter, pc_name):
    """Per-RHS fallback for batched requests outside the block kernels'
    coverage (preconditioned, refined, or non-v2 pipelines): each RHS
    routes through the registry independently and the results stack."""
    parts = [_solve_resolved(case, f[j], b=1, niter=niter, tol=tol,
                             max_iter=max_iter, pc_name=pc_name)
             for j in range(f.shape[0])]
    return SolveResult(
        x=jnp.stack([p.x for p in parts]),
        history=jnp.stack([p.history for p in parts]),
        iters_taken=jnp.stack([p.iters_taken for p in parts]),
        achieved_rtol=jnp.stack([p.achieved_rtol for p in parts]),
        rnorm=jnp.stack([p.rnorm for p in parts]),
        pipeline=parts[0].pipeline, precond=parts[0].precond)


def _drive_ir(case, f, *, b, niter, tol, max_iter, pc_name):
    variant = {"pallas_fused_cg_v2": "v2",
               "pallas_sstep_v3": "sstep"}.get(case.ax_impl, "v1")
    return cg_fused_mod.cg_ir_fixed_iters(
        f, D=case.D, g=case.g, grid=case.grid, niter=niter,
        precision=case.precision, mask=case.mask, c=case.c,
        variant=variant, s=case.s)


def _drive_sstep(case, f, *, b, niter, tol, max_iter, pc_name):
    from repro.core.cg_sstep import cg_sstep_fixed_iters, estimate_theta

    # the basis scale depends only on the case's operator — estimate once
    # per case, not once per solve.
    theta = getattr(case, "_sstep_theta", None)
    if theta is None:
        theta = estimate_theta(case.D, case.g, case.grid, case.mask)
        case._sstep_theta = theta
    if niter is not None:
        return cg_sstep_fixed_iters(
            f, D=case.D, g=case.g, grid=case.grid, niter=niter, s=case.s,
            mask=case.mask, c=case.c, theta=theta,
            precision=case.precision)
    # tolerance-driven: the per-cycle host sync checks the stored-residual
    # reduction and the f64 Gram recurrence resolves the stopping point to
    # iteration granularity (DESIGN.md §9.4).
    return cg_sstep_fixed_iters(
        f, D=case.D, g=case.g, grid=case.grid, niter=max_iter, s=case.s,
        mask=case.mask, c=case.c, theta=theta, tol=tol,
        precision=case.precision)


def _drive_v2(case, f, *, b, niter, tol, max_iter, pc_name):
    from repro.core import precond as precond_mod

    spec = case.precond_spec(pc_name) if pc_name else None
    if spec is None:
        return cg_fused_mod.cg_fused_v2_fixed_iters(
            f, D=case.D, g=case.g, grid=case.grid, niter=niter,
            mask=case.mask, c=case.c, precision=case.precision)
    return precond_mod.pcg_fused_v2_fixed_iters(
        f, D=case.D, g=case.g, grid=case.grid, niter=niter, precond=spec,
        mask=case.mask, c=case.c, precision=case.precision)


def _drive_v2_tol(case, f, *, b, niter, tol, max_iter, pc_name):
    from repro.core import precond as precond_mod

    spec = case.precond_spec(pc_name) if pc_name else None
    return precond_mod.cg_fused_tol(
        f, D=case.D, g=case.g, grid=case.grid, tol=tol, max_iter=max_iter,
        precond=spec, mask=case.mask, c=case.c, precision=case.precision)


def _drive_v1(case, f, *, b, niter, tol, max_iter, pc_name):
    return cg_fused_mod.cg_fused_fixed_iters(
        f, D=case.D, g=case.g, mask=case.mask, c=case.c, grid=case.grid,
        niter=niter, precision=case.precision)


def _drive_reference(case, f, *, b, niter, tol, max_iter, pc_name):
    M = case._reference_preconditioner(pc_name)
    if niter is not None:
        return cg_mod.cg_fixed_iters(case.ax_full, f, niter=niter,
                                     dot=case.dot(), precond=M)
    return cg_mod.cg(case.ax_full, f, tol=tol, max_iter=max_iter,
                     dot=case.dot(), precond=M)


REGISTRY: dict[str, Callable] = {
    "block": _drive_block,
    "block_loop": _drive_block_loop,
    "ir": _drive_ir,
    "sstep": _drive_sstep,
    "v2": _drive_v2,
    "v2_tol": _drive_v2_tol,
    "v1": _drive_v1,
    "reference": _drive_reference,
}


def route_name(case, *, b: int = 1, niter: int | None = None,
               pc_name: str | None = None) -> str:
    """Which :data:`REGISTRY` row serves this request — the routing that
    used to live as branching in ``NekboneCase.solve``, as one pure
    function."""
    fused = case.ax_impl in ("pallas_fused_cg", "pallas_fused_cg_v2",
                             "pallas_sstep_v3")
    refined = False
    if fused and case.precision is not None:
        from repro.core.precision import resolve_policy

        refined = resolve_policy(case.precision).refine
    fused_v2_family = case.ax_impl in ("pallas_fused_cg_v2",
                                       "pallas_sstep_v3")
    if b > 1:
        # the batched kernels are the (unpreconditioned, non-refined) v2
        # pipeline; everything else solves per RHS through this table.
        if pc_name is None and not refined and (
                fused_v2_family or case.ax_impl == "pallas_fused_cg"):
            if case.ax_impl == "pallas_sstep_v3":
                # explicit, documented fallback: there is no batched
                # matrix-powers kernel — a b>1 s-step case runs the
                # multi-RHS *v2* block pipeline instead (same answer,
                # the v2 byte books).  Warn once per process so the
                # substitution is visible without spamming sweeps.
                global _SSTEP_BLOCK_WARNED
                if not _SSTEP_BLOCK_WARNED:
                    _SSTEP_BLOCK_WARNED = True
                    warnings.warn(
                        "b>1 on ax_impl='pallas_sstep_v3': no batched "
                        "s-step kernel exists; routing through the "
                        "multi-RHS v2 block pipeline (fused_v2_rhs<b>). "
                        "Set ax_impl='pallas_fused_cg_v2' to silence.",
                        UserWarning, stacklevel=3)
            return "block"
        return "block_loop"
    if refined and niter is not None and pc_name is None:
        return "ir"
    if case.ax_impl == "pallas_sstep_v3" and pc_name is None \
            and not refined:
        return "sstep"
    if case.ax_impl == "pallas_fused_cg_v2" and not refined:
        return "v2" if niter is not None else "v2_tol"
    if case.ax_impl == "pallas_fused_cg" and niter is not None \
            and pc_name is None and not refined:
        return "v1"
    return "reference"


def solve_case(case, f: jnp.ndarray, *, b: int | None = None,
               niter: int | None = None, tol: float = 1e-8,
               max_iter: int = 1000,
               precond: str | None = None) -> SolveResult:
    """Route one solve request through the registry.

    ``b`` is the RHS batch: ``None`` infers it from ``f``'s shape (a
    leading axis ahead of (E, n, n, n) is a batch), 1 forces a single-RHS
    solve, > 1 requires ``f`` of shape (b, E, n, n, n).  ``precond``
    accepts the registry names (resolved by
    :meth:`NekboneCase._precond_name`; the removed booleans raise
    ``TypeError`` there).
    """
    pc_name = case._precond_name(precond)
    f = jnp.asarray(f)
    batched = f.ndim == 5
    if b is None:
        b = f.shape[0] if batched else 1
    if batched and f.shape[0] != b:
        raise ValueError(f"b={b} but rhs has leading batch {f.shape[0]}")
    if b > 1 and not batched:
        raise ValueError(f"b={b} needs a (b, E, n, n, n) rhs; "
                         f"got {f.shape}")
    f_in = f[0] if (batched and b == 1) else f
    from repro.obs import trace as _trace

    rec = _trace.active()
    if rec is None:            # tracing off: the plain dispatch, nothing else
        res = _solve_resolved(case, f_in, b=b, niter=niter, tol=tol,
                              max_iter=max_iter, pc_name=pc_name)
    else:
        res = _traced_solve(rec, case, f_in, b=b, niter=niter, tol=tol,
                            max_iter=max_iter, pc_name=pc_name)
    # a batched rhs always comes back batched, even at b=1 through a
    # single-RHS route (callers index res.x[j] uniformly).
    if batched and b == 1 and res.x.ndim == 4:
        res = SolveResult(x=res.x[None], history=res.history[None],
                          iters_taken=res.iters_taken[None],
                          achieved_rtol=res.achieved_rtol[None],
                          rnorm=res.rnorm[None], pipeline=res.pipeline,
                          precond=res.precond, telemetry=res.telemetry)
    return res


def _solve_resolved(case, f, *, b, niter, tol, max_iter, pc_name):
    name = route_name(case, b=b, niter=niter, pc_name=pc_name)
    return REGISTRY[name](case, f, b=b, niter=niter, tol=tol,
                          max_iter=max_iter, pc_name=pc_name)


def _traced_solve(rec, case, f, *, b, niter, tol, max_iter, pc_name):
    """The tracing-on dispatch: same :func:`_solve_resolved` call (so
    the solve output is bitwise identical), wrapped in a ``solve`` span
    with a :class:`~repro.obs.metrics.SolveTelemetry` attached to the
    result's non-pytree ``telemetry`` field.  The ``block_until_ready``
    and the iters/rtol device reads in ``capture_solve`` are syncs the
    tracing-off path never pays."""
    import dataclasses

    import jax

    from repro.kernels import autotune as _autotune
    from repro.kernels.timing import stopwatch
    from repro.obs import metrics as obs_metrics

    route = route_name(case, b=b, niter=niter, pc_name=pc_name)
    at0 = _autotune.cache_stats()
    sw = stopwatch()
    with rec.span("solve", route=route, b=b, niter=niter,
                  precond=pc_name, ax_impl=getattr(case, "ax_impl", None)):
        res = _solve_resolved(case, f, b=b, niter=niter, tol=tol,
                              max_iter=max_iter, pc_name=pc_name)
        jax.block_until_ready(res.x)
    wall = sw.us()
    at1 = _autotune.cache_stats()
    rec.count("solves")
    tel = obs_metrics.capture_solve(
        res, route=route, b=b, niter=niter,
        tol=None if niter is not None else tol, wall_us=wall,
        phases={"dispatch": round(wall, 3)},
        autotune={k: at1[k] - at0.get(k, 0) for k in at1})
    return dataclasses.replace(res, telemetry=tel)


def solve(case_or_config, f: jnp.ndarray | None = None, *,
          b: int | None = None, niter: int | None = None,
          tol: float | None = None, max_iter: int = 1000,
          precond: str | None = None) -> SolveResult:
    """Top-level solve facade (re-exported as ``repro.solve``).

    Args:
      case_or_config: a :class:`repro.core.nekbone.NekboneCase`, a
          :class:`repro.configs.nekbone.NekboneConfig` (instantiated via
          ``make_case()``), or an int — a paper-grid element count
          (``repro.configs.nekbone.PAPER_CASES`` key).
      f: right-hand side(s), (E, n, n, n) or (b, E, n, n, n).  ``None``
          solves the case's manufactured problem (replicated to ``b``).
      b: RHS batch; default: inferred from ``f`` (or the case's ``b``).
      niter: fixed iteration count; ``None`` = tolerance-driven.
      tol: stopping tolerance for the tol-driven mode (default 1e-8);
          ignored when ``niter`` is given.
      precond: registry preconditioner name; ``None`` inherits the case.

    Returns a :class:`SolveResult`.
    """
    case = case_or_config
    if isinstance(case, int):
        from repro.configs.nekbone import PAPER_CASES

        case = PAPER_CASES[case]
    if hasattr(case, "make_case"):          # NekboneConfig
        case = case.make_case()
    if b is None and f is None:
        b = getattr(case, "b", 1)
    if f is None:
        _, f1 = case.manufactured()
        f = f1 if (b is None or b == 1) else jnp.stack([f1] * b)
    return solve_case(case, f, b=b, niter=niter,
                      tol=1e-8 if tol is None else tol,
                      max_iter=max_iter, precond=precond)
