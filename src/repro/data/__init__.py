"""Data substrate: deterministic synthetic LM stream + binary shard reader."""
from repro.data.pipeline import (SyntheticLMStream, MemmapTokenReader,
                                 make_batch_iterator)

__all__ = ["SyntheticLMStream", "MemmapTokenReader", "make_batch_iterator"]
