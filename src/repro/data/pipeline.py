"""Deterministic, restart-safe data pipeline.

Fault-tolerance contract (DESIGN.md §3): every batch is a pure function of
``(seed, step, shard)``.  A restarted job that resumes from step k produces
the exact same batch sequence — no iterator state needs checkpointing, and a
re-sharded (elastic) restart keeps per-host determinism because sharding is
by position, not by host identity.

Two sources:
  * :class:`SyntheticLMStream` — hash-based token stream with learnable
    bigram structure (a model can visibly reduce loss on it, used by the
    end-to-end training example).
  * :class:`MemmapTokenReader` — flat binary uint16/uint32 token files
    (the production path), read with zero-copy memmap windows.
"""
from __future__ import annotations

import dataclasses
import pathlib

import numpy as np

__all__ = ["SyntheticLMStream", "MemmapTokenReader", "make_batch_iterator"]


@dataclasses.dataclass(frozen=True)
class SyntheticLMStream:
    """Deterministic synthetic LM batches with structure worth learning.

    Token t+1 depends on token t through a fixed random permutation with
    noise: ``x[t+1] = perm[x[t]]`` with prob (1 - noise) else uniform.  A
    model that learns the permutation reaches loss ~= -log(1 - noise).
    """

    vocab: int
    seed: int = 0
    noise: float = 0.1

    def _perm(self) -> np.ndarray:
        rng = np.random.default_rng(self.seed ^ 0x5EED)
        return rng.permutation(self.vocab)

    def batch(self, step: int, batch_size: int, seq_len: int,
              shard: int = 0, n_shards: int = 1) -> np.ndarray:
        """(batch_size, seq_len + 1) int32 tokens for ``step``/``shard``."""
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 65_537 + shard)
        perm = self._perm()
        toks = np.empty((batch_size, seq_len + 1), np.int32)
        toks[:, 0] = rng.integers(0, self.vocab, batch_size)
        flip = rng.random((batch_size, seq_len)) < self.noise
        rand = rng.integers(0, self.vocab, (batch_size, seq_len))
        for t in range(seq_len):
            nxt = perm[toks[:, t]]
            toks[:, t + 1] = np.where(flip[:, t], rand[:, t], nxt)
        return toks


class MemmapTokenReader:
    """Reads fixed-length windows from a flat binary token file.

    Deterministic addressing: window ``i`` for step s, shard h of H is at
    offset ``((s * H + h) * batch + row) * stride mod usable``.
    """

    def __init__(self, path: str | pathlib.Path, *, dtype=np.uint16):
        self.path = pathlib.Path(path)
        self.tokens = np.memmap(self.path, dtype=dtype, mode="r")

    def batch(self, step: int, batch_size: int, seq_len: int,
              shard: int = 0, n_shards: int = 1) -> np.ndarray:
        stride = seq_len + 1
        usable = len(self.tokens) - stride
        if usable <= 0:
            raise ValueError(f"{self.path} too small for seq_len={seq_len}")
        base = (step * n_shards + shard) * batch_size
        out = np.empty((batch_size, stride), np.int32)
        for row in range(batch_size):
            off = ((base + row) * stride * 7919) % usable
            out[row] = self.tokens[off:off + stride]
        return out


def make_batch_iterator(source, *, batch_size: int, seq_len: int,
                        start_step: int = 0, shard: int = 0,
                        n_shards: int = 1):
    """Infinite iterator of ``{"tokens": (B, S+1) int32}`` from ``start_step``."""
    step = start_step
    while True:
        yield step, {"tokens": source.batch(step, batch_size, seq_len,
                                            shard, n_shards)}
        step += 1
