"""Distributed substrate: sharding rules, compression, overlap, CP attention,
and the sharded Nekbone solvers (s-step CG + PCG, DESIGN.md §10)."""
from repro.distributed import (compression, context_parallel, overlap,  # noqa: F401
                               pcg, sharding, sstep)
from repro.distributed.sharding import RULES, AxisRules, constrain

__all__ = ["compression", "context_parallel", "overlap", "pcg", "sharding",
           "sstep", "RULES", "AxisRules", "constrain"]
