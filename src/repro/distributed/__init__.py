"""Distributed substrate: sharding rules, compression, overlap, CP attention."""
from repro.distributed import (compression, context_parallel, overlap,  # noqa: F401
                               sharding)
from repro.distributed.sharding import RULES, AxisRules, constrain

__all__ = ["compression", "context_parallel", "overlap", "sharding",
           "RULES", "AxisRules", "constrain"]
