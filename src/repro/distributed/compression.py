"""Gradient compression for the cross-pod (DCN) all-reduce.

Cross-pod links are an order of magnitude slower than ICI, so the pod-level
gradient all-reduce is the multi-pod bottleneck for DP training.  Two
standard mitigations, both numerically audited by tests:

  * :func:`compressed_psum` — cast to bf16 (or any narrow dtype) before the
    ``psum`` over the pod axis and accumulate back in f32.  Halves DCN bytes;
    the mantissa loss is absorbed by Adam's second-moment normalization.
  * :func:`quantized_psum` — int8 with per-tensor scale and stochastic
    rounding (4x fewer bytes).  all_gather + dequant + sum so accumulation
    stays exact in f32 (a raw int8 psum would overflow).

Used by ``launch/train.py`` via ``--grad-compression {none,bf16,int8}``.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

__all__ = ["compressed_psum", "quantized_psum", "psum_tree"]


def compressed_psum(x: jnp.ndarray, axis_name: str,
                    dtype=jnp.bfloat16) -> jnp.ndarray:
    """psum with on-the-wire dtype ``dtype`` and f32 accumulation semantics.

    (all_gather + f32 sum rather than psum-of-bf16 so the reduction does not
    accumulate rounding across the pod count.)
    """
    lo = x.astype(dtype)
    g = jax.lax.all_gather(lo, axis_name)            # (pods, ...)
    return jnp.sum(g.astype(jnp.float32), axis=0).astype(x.dtype)


def quantized_psum(x: jnp.ndarray, axis_name: str, *,
                   key: jax.Array | None = None) -> jnp.ndarray:
    """int8 + per-tensor-scale all-reduce with stochastic rounding."""
    amax = jnp.max(jnp.abs(x)).astype(jnp.float32)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    y = x.astype(jnp.float32) / scale
    if key is not None:
        y = y + jax.random.uniform(key, x.shape, jnp.float32, -0.5, 0.5)
    q = jnp.clip(jnp.round(y), -127, 127).astype(jnp.int8)
    qg = jax.lax.all_gather(q, axis_name)            # (pods, ...)
    sg = jax.lax.all_gather(scale, axis_name)        # (pods,)
    out = jnp.einsum("p...,p->...", qg.astype(jnp.float32), sg)
    return out.astype(x.dtype)


def psum_tree(tree, axis_name: str, *, compression: str = "none",
              key: jax.Array | None = None):
    """Tree-wide gradient all-reduce with selectable wire format."""
    if compression == "none":
        return jax.lax.psum(tree, axis_name)
    if compression == "bf16":
        return jax.tree.map(
            lambda g: compressed_psum(g, axis_name), tree)
    if compression == "int8":
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        keys = (jax.random.split(key, len(leaves)) if key is not None
                else [None] * len(leaves))
        out = [quantized_psum(g, axis_name, key=k)
               for g, k in zip(leaves, keys)]
        return jax.tree_util.tree_unflatten(treedef, out)
    raise ValueError(f"unknown compression {compression!r}")
