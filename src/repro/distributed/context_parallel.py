"""Context-parallel decode attention (explicit shard_map form).

For ``long_500k`` (one sequence, 512k KV) the cache sequence axis is sharded
over ``data``.  Each shard computes attention over its KV slice and the
partial results combine exactly via the log-sum-exp trick:

    out = sum_s exp(m_s - m) * l_s * o_s  /  sum_s exp(m_s - m) * l_s

The GSPMD path (models/attention.decode_attention with a sequence-sharded
constraint) lets XLA derive the same all-reduces automatically; this module
is the explicit version — used to *verify* the partitioner's numerics and as
the hand-tuned fallback if the SPMD schedule regresses (EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["cp_decode_attention"]


def cp_decode_attention(q, k_shard, v_shard, *, axis_name: str,
                        kv_valid_len, window=None, softcap=None, scale=None):
    """Per-shard body (call inside shard_map over the sequence shards).

    q:        (B, H, 1, hd) replicated across shards.
    k_shard:  (B, Hkv, S_local, hd) this shard's KV slice.
    kv_valid_len: global number of valid cache entries (scalar); with a
    ``window`` only the last ``window`` of them are attended.
    Returns (B, H, 1, hd), identical on all shards.
    """
    B, H, _, hd = q.shape
    Hkv, S_loc = k_shard.shape[1], k_shard.shape[2]
    G = H // Hkv
    scale = hd ** -0.5 if scale is None else scale
    i = jax.lax.axis_index(axis_name)

    qg = q.reshape(B, Hkv, G, 1, hd).astype(jnp.float32)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg,
                   k_shard.astype(jnp.float32)) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    kpos = i * S_loc + jnp.arange(S_loc)
    mask = kpos < kv_valid_len
    if window is not None:
        mask &= kpos > kv_valid_len - 1 - window
    s = jnp.where(mask[None, None, None, None, :], s, -1e30)

    m_loc = s.max(-1, keepdims=True)                    # (B,Hkv,G,1,1)
    p = jnp.exp(s - m_loc)
    l_loc = p.sum(-1, keepdims=True)
    o_loc = jnp.einsum("bhgqk,bhkd->bhgqd", p, v_shard.astype(jnp.float32))

    m = jax.lax.pmax(m_loc, axis_name)
    corr = jnp.exp(m_loc - m)
    l = jax.lax.psum(l_loc * corr, axis_name)
    o = jax.lax.psum(o_loc * corr, axis_name)
    out = o / jnp.where(l == 0, 1.0, l)
    return out.reshape(B, H, 1, hd).astype(q.dtype)
