"""Compute/communication overlap: collective (all-gather) matmul.

The standard TP inefficiency is ``all_gather(x) @ w``: the interconnect is
idle while the MXU works and vice versa.  The collective matmul pipelines
them — each step matmuls the chunk it already has while ``ppermute``-ing
the next chunk around the ring, hiding (steps-1)/steps of the transfer
latency behind compute.  (XLA's ``--xla_tpu_enable_async_collective_...``
latency-hiding scheduler can do this for some patterns; this is the explicit
shard_map form, usable as a drop-in where profiling shows serialization.)

``collective_matmul_allgather(x_shard, w, axis)``:
  x is sharded over ``axis`` on its leading (row) dim; w is replicated or
  row-sharded to match x columns.  Computes ``all_gather(x) @ w`` without
  materializing the gather.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import compat

__all__ = ["collective_matmul_allgather"]


def collective_matmul_allgather(x: jnp.ndarray, w: jnp.ndarray,
                                axis_name: str) -> jnp.ndarray:
    """Per-shard body (call inside shard_map).

    x: (m_local, k) — this shard's rows of the global (m, k) operand.
    w: (k, n) replicated.
    Returns: (m_local * axis_size, n) == all_gather(x, tiled) @ w.

    Ring schedule: at step s we hold the block that originated at shard
    (i - s) mod P; matmul it into its output slot while forwarding it.
    """
    P = compat.axis_size(axis_name)
    i = jax.lax.axis_index(axis_name)
    m_loc, _ = x.shape
    n = w.shape[1]
    out = jnp.zeros((m_loc * P, n), x.dtype)
    if hasattr(jax.lax, "pcast"):   # mark the carry as device-varying (VMA)
        out = jax.lax.pcast(out, (axis_name,), to="varying")
    perm = [(p, (p + 1) % P) for p in range(P)]

    def body(s, carry):
        blk, out = carry
        src = (i - s) % P                      # owner of the block we hold
        y = jnp.dot(blk, w, preferred_element_type=jnp.float32).astype(x.dtype)
        out = jax.lax.dynamic_update_slice_in_dim(out, y, src * m_loc, axis=0)
        # forward the block around the ring (skipped result on last step)
        blk = jax.lax.ppermute(blk, axis_name, perm)
        return blk, out

    _, out = jax.lax.fori_loop(0, P, body, (x, out))
    return out
