"""Sharded Jacobi / Chebyshev PCG on the s-step halo machinery.

The two fused PCG pipelines (core/precond.py, DESIGN.md §9) distribute
over the same 1-D z-slab mesh as the sharded s-step driver
(:mod:`repro.distributed.sstep`), with per-iteration communication:

* **Jacobi** — the v2 slab front-half and the merged PCG update are
  shard-local; the cross-shard interface is exactly the inter-*block*
  plane stitch the single-device driver already performs, so the shard
  boundary costs one plane exchange (2 ``ppermute``\\ s) per iteration:
  the previous shard's top plane becomes the first block's ``addb``, the
  next shard's bottom plane the last block's ``addt``, and the global
  domain ends keep the zero planes (``gs.halo_exchange_z`` delivers
  zeros there).  Two stacked psums carry the scalars (``pap``;
  ``rtz``/``rcr`` ride one psum together).

* **Chebyshev** — ``z = q_k(A) r`` is the v3 matrix-powers structure, so
  its k-deep halo is the *same window logic* as s-step's s-deep one: the
  shard exchanges k ghost slabs of the residual (one
  ``halo_exchange_z``), feeds them to
  :func:`repro.kernels.nekbone_ax.sstep_extend_field` as the
  ``below``/``above`` padding, and the apply kernel runs unchanged on
  the local grid.  The loop-invariant metric/mask windows are built once
  on the global field and sharded by block, as in the s-step driver.

Both cores run their ``lax.while_loop`` inside ``shard_map``: the
stopping rule tests the psum'd ``rtz``, which is replicated, so the loop
is SPMD-uniform.  The fixed-iteration entry point reuses the tol core
with the ``tol2 = -1`` sentinel — the tol-driven trajectory is a prefix
of the fixed-iteration one *by construction*, exactly the single-device
contract (core/precond.py), and both match the single-device
trajectories to fp64 round-off (the psums reassociate partial sums;
everything else, including the exchanged planes, is bitwise).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

import repro.core.gs as gs_mod
from repro import compat
from repro.core.cg import CGResult
from repro.core.cg_fused import _check_box_fields
from repro.core.geom import box_outer
from repro.core.precision import resolve_policy
from repro.core.precond import (ChebyshevPrecond, JacobiPrecond,
                                _resolve_precond)
from repro.distributed.sharding import replicate, shard_leading
from repro.distributed.sstep import _resolve_mesh
from repro.kernels import autotune as _autotune
from repro.kernels import nekbone_ax as _ax

__all__ = ["pcg_sharded_fixed_iters", "pcg_sharded_tol"]


# ---------------------------------------------------------------------------
# shard bodies: whole while_loop per shard, psum'd scalars keep it uniform
# ---------------------------------------------------------------------------

def _stitch_planes(bot, top, axis_name: str):
    """Cross-shard edition of the v2 plane stitch: block ``i`` adds block
    ``i-1``'s top plane and block ``i+1``'s bottom plane; at shard edges
    those blocks live on the neighbour shard, so their planes arrive by
    ppermute (zeros at the global ends).  Returns ``(addb, addt)``."""
    fb, fa = gs_mod.halo_exchange_z(top[-1], bot[0], (axis_name,))
    addb = jnp.concatenate([fb[None], top[:-1]], axis=0)
    addt = jnp.concatenate([bot[1:], fa[None]], axis=0)
    return addb, addt


def _pcg_jacobi_shard(b2, invd2, D, Dt, g3, mx, my, mz, cx, cy, cz, tol2,
                      *, axis_name: str, n: int,
                      grid_local: tuple[int, int, int], sz: int,
                      max_iter: int, interpret: bool, acc_name: str,
                      x_name: str):
    """Sharded mirror of ``precond._pcg_jacobi`` (runs inside shard_map).

    Per iteration: 1 plane exchange (2 ppermutes) + 2 psums (pap;
    stacked rtz/rcr).
    """
    E = b2.shape[0]
    n3 = n ** 3
    acc = jnp.dtype(acc_name)
    x_dtype = jnp.dtype(x_name)
    c2 = box_outer(cz, cy, cx).reshape(E, n3).astype(acc)
    b_acc = b2.astype(acc)
    z0 = (invd2.astype(acc) * b_acc).astype(b2.dtype)
    s0 = jax.lax.psum(
        jnp.stack([jnp.sum(b_acc * c2 * z0.astype(acc)),
                   jnp.sum(b_acc * c2 * b_acc)]), axis_name)
    rtz0, rcr0 = s0[0], s0[1]
    hist0 = jnp.full((max_iter + 1,), jnp.nan, dtype=acc) \
        .at[0].set(jnp.sqrt(jnp.abs(rcr0)))
    tol2 = jnp.asarray(tol2, acc)

    def cond(state):
        _, _, _, rtz, _, _, kk = state
        return jnp.logical_and(kk < max_iter, jnp.abs(rtz) > tol2)

    def body(state):
        x2, z2, p2, rtz, beta, hist, kk = state
        p2, w2, bot, top, pap_b = _ax.nekbone_ax_slab_pallas(
            p2, z2, D, Dt, g3, mx, my, mz, beta.reshape(1, 1),
            n=n, grid=grid_local, sz=sz, interpret=interpret,
            acc_dtype=acc_name)
        alpha = rtz / jax.lax.psum(jnp.sum(pap_b), axis_name)
        addb, addt = _stitch_planes(bot, top, axis_name)
        x2, z2, rtz_b, rcr_b = _ax.nekbone_pcg_update_pallas(
            x2, p2, z2, w2, addb, addt, alpha.reshape(1, 1), invd2,
            cx, cy, cz, n=n, grid=grid_local, sz=sz, interpret=interpret,
            acc_dtype=acc_name)
        ss = jax.lax.psum(jnp.stack([jnp.sum(rtz_b), jnp.sum(rcr_b)]),
                          axis_name)
        rtz_new = ss[0]
        beta = rtz_new / rtz
        hist = hist.at[kk + 1].set(jnp.sqrt(jnp.abs(ss[1])))
        return x2, z2, p2, rtz_new, beta, hist, kk + 1

    state = (jnp.zeros(b2.shape, x_dtype), z0, jnp.zeros_like(z0), rtz0,
             jnp.zeros((), acc), hist0, jnp.asarray(0))
    x2, z2, p2, rtz, beta, hist, kk = jax.lax.while_loop(cond, body, state)
    return x2, kk, hist


def _pcg_cheb_shard(b2, D, Dt, g3, mx, my, mz, cx, cy, cz, gext, mzext,
                    coef, tol2, *, axis_name: str, n: int,
                    grid_local: tuple[int, int, int], sz: int, sz_c: int,
                    k: int, max_iter: int, interpret: bool, acc_name: str,
                    x_name: str):
    """Sharded mirror of ``precond._pcg_cheb`` (runs inside shard_map).

    The Chebyshev apply exchanges a k-deep residual ghost halo and feeds
    it to ``sstep_extend_field`` — identical window logic to the s-step
    cycle, at k instead of s.  Per iteration: 2 halo exchanges (planes +
    cheb ghosts, 4 ppermutes) + 2 psums (pap; stacked rtz/rcr).
    """
    ex, ey, ez_l = grid_local
    eyex = ey * ex
    E = b2.shape[0]
    n3 = n ** 3
    acc = jnp.dtype(acc_name)
    x_dtype = jnp.dtype(x_name)
    c2 = box_outer(cz, cy, cx).reshape(E, n3).astype(acc)
    rcr0_loc = jnp.sum(b2.astype(acc) * c2 * b2.astype(acc))

    def cheb(r2):
        r = r2.reshape(ez_l, eyex, n3)
        rb, ra = gs_mod.halo_exchange_z(r[ez_l - k:], r[:k], (axis_name,))
        rext = _ax.sstep_extend_field(r2, grid_local, sz_c, k,
                                      below=rb, above=ra)
        z2, rtz_b = _ax.nekbone_cheb_apply_pallas(
            rext, D, Dt, gext, mx, my, mzext, cx, cy, cz, coef,
            n=n, grid=grid_local, sz=sz_c, k=k, interpret=interpret,
            acc_dtype=acc_name)
        return z2, jnp.sum(rtz_b)

    z0, rtz0_loc = cheb(b2)
    s0 = jax.lax.psum(jnp.stack([rtz0_loc, rcr0_loc]), axis_name)
    rtz0 = s0[0]
    hist0 = jnp.full((max_iter + 1,), jnp.nan, dtype=acc) \
        .at[0].set(jnp.sqrt(jnp.abs(s0[1])))
    tol2 = jnp.asarray(tol2, acc)

    def cond(state):
        _, _, _, _, rtz, _, _, kk = state
        return jnp.logical_and(kk < max_iter, jnp.abs(rtz) > tol2)

    def body(state):
        x2, r2, z2, p2, rtz, rtz_prev, hist, kk = state
        beta = rtz / rtz_prev            # rtz_prev = 1 at k=0: p0 = 0
        p2, w2, bot, top, pap_b = _ax.nekbone_ax_slab_pallas(
            p2, z2, D, Dt, g3, mx, my, mz, beta.reshape(1, 1),
            n=n, grid=grid_local, sz=sz, interpret=interpret,
            acc_dtype=acc_name)
        alpha = rtz / jax.lax.psum(jnp.sum(pap_b), axis_name)
        addb, addt = _stitch_planes(bot, top, axis_name)
        x2, r2, rcr_b = _ax.nekbone_cg_update_pallas(
            x2, p2, r2, w2, addb, addt, alpha.reshape(1, 1), cx, cy, cz,
            n=n, grid=grid_local, sz=sz, interpret=interpret,
            acc_dtype=acc_name)
        z2, rtz_loc = cheb(r2)
        ss = jax.lax.psum(jnp.stack([rtz_loc, jnp.sum(rcr_b)]), axis_name)
        hist = hist.at[kk + 1].set(jnp.sqrt(jnp.abs(ss[1])))
        return x2, r2, z2, p2, ss[0], rtz, hist, kk + 1

    state = (jnp.zeros(b2.shape, x_dtype), b2, z0, jnp.zeros_like(b2),
             rtz0, jnp.ones((), acc), hist0, jnp.asarray(0))
    x2, r2, z2, p2, rtz, rtz_prev, hist, kk = jax.lax.while_loop(
        cond, body, state)
    return x2, kk, hist


# ---------------------------------------------------------------------------
# jitted shard_map wrappers
# ---------------------------------------------------------------------------

_JAC_STATICS = ("mesh", "axis_name", "n", "grid_local", "sz", "max_iter",
                "interpret", "acc_name", "x_name")


@functools.partial(jax.jit, static_argnames=_JAC_STATICS)
def _jacobi_call(b2, invd2, D, Dt, g3, mx, my, mz, cx, cy, cz, tol2, *,
                 mesh, axis_name, n, grid_local, sz, max_iter, interpret,
                 acc_name, x_name):
    ax = axis_name
    body = functools.partial(
        _pcg_jacobi_shard, axis_name=ax, n=n, grid_local=grid_local, sz=sz,
        max_iter=max_iter, interpret=interpret, acc_name=acc_name,
        x_name=x_name)
    return compat.shard_map(
        body, mesh=mesh,
        in_specs=(P(ax), P(ax), P(), P(), P(ax), P(), P(), P(ax), P(), P(),
                  P(ax), P()),
        out_specs=(P(ax), P(), P()),
        check_vma=False)(b2, invd2, D, Dt, g3, mx, my, mz, cx, cy, cz, tol2)


_CHEB_STATICS = _JAC_STATICS + ("sz_c", "k")


@functools.partial(jax.jit, static_argnames=_CHEB_STATICS)
def _cheb_call(b2, D, Dt, g3, mx, my, mz, cx, cy, cz, gext, mzext, coef,
               tol2, *, mesh, axis_name, n, grid_local, sz, sz_c, k,
               max_iter, interpret, acc_name, x_name):
    ax = axis_name
    body = functools.partial(
        _pcg_cheb_shard, axis_name=ax, n=n, grid_local=grid_local, sz=sz,
        sz_c=sz_c, k=k, max_iter=max_iter, interpret=interpret,
        acc_name=acc_name, x_name=x_name)
    return compat.shard_map(
        body, mesh=mesh,
        in_specs=(P(ax), P(), P(), P(ax), P(), P(), P(ax), P(), P(), P(ax),
                  P(ax), P(ax), P(), P()),
        out_specs=(P(ax), P(), P()),
        check_vma=False)(b2, D, Dt, g3, mx, my, mz, cx, cy, cz, gext,
                         mzext, coef, tol2)


# ---------------------------------------------------------------------------
# drivers
# ---------------------------------------------------------------------------

def _run(b, precond, tol2, max_iter, *, D, g, grid, mask, c, sz, cheb_sz,
         interpret, precision, mesh, axis_name, ndev) -> CGResult:
    from repro.kernels import ops as kernel_ops

    policy = resolve_policy(precision, b.dtype)
    b = jnp.asarray(b, policy.storage_dtype)
    E = b.shape[0]
    n = b.shape[-1]
    grid = tuple(grid)
    ex, ey, ez = grid
    mesh, axis_name, ndev = _resolve_mesh(mesh, axis_name, ndev)
    if ez % ndev:
        raise ValueError(f"EZ {ez} not divisible by mesh size {ndev}")
    ez_l = ez // ndev
    grid_local = (ex, ey, ez_l)
    if interpret is None:
        interpret = kernel_ops.default_interpret()
    # specs built by name use the caller's full-precision operator data on
    # the default device — a one-time setup, as in the single-device path.
    precond = _resolve_precond(precond, D=D, g=g, grid=grid, mask=mask, c=c)
    if precond is None:
        raise ValueError(
            "sharded PCG needs a preconditioner; for unpreconditioned "
            "sharded solves use distributed.sstep or cg_fused_sharded")
    if sz is None:
        jac = isinstance(precond, JacobiPrecond)
        sz = _autotune.pick_slab_sz(grid_local, n, b.dtype,
                                    acc_dtype=policy.accum,
                                    precond="jacobi" if jac else None)
    if ez_l % sz:
        raise ValueError(f"local EZ {ez_l} not divisible by sz {sz}")

    _check_box_fields(grid, n, mask, c)
    (mx, my, mz), (cx, cy, cz) = kernel_ops.slab_axis_factors(grid, n,
                                                              b.dtype)
    n3 = n ** 3
    D_op = jnp.asarray(D, policy.op_storage_dtype)
    g3 = kernel_ops.diag_metric(jnp.asarray(g, policy.op_storage_dtype),
                                E, n)

    shard = functools.partial(shard_leading, mesh=mesh, axis_name=axis_name)
    rep = functools.partial(replicate, mesh=mesh)
    statics = dict(mesh=mesh, axis_name=axis_name, n=n,
                   grid_local=grid_local, sz=sz, max_iter=max_iter,
                   interpret=interpret, acc_name=policy.accum,
                   x_name=policy.x_storage_dtype.name)
    b2 = shard(b.reshape(E, n3))
    tol2 = jnp.asarray(tol2, policy.accum_dtype)
    common = (rep(D_op), rep(D_op.T), shard(g3), rep(mx), rep(my),
              shard(mz), rep(cx), rep(cy), shard(cz))

    # tracing: the sharded solve is one jitted program — the host
    # boundary is this dispatch, recorded as a single span.
    from repro.obs import trace as _trace

    rec = _trace.active()
    if isinstance(precond, JacobiPrecond):
        invd2 = shard(jnp.asarray(precond.invdiag,
                                  policy.op_storage_dtype).reshape(E, n3))
        with (rec.span("pcg.sharded_dispatch", precond="jacobi",
                       ndev=ndev)
              if rec is not None else _trace.NULL_SPAN):
            x2, kk, hist = _jacobi_call(b2, invd2, *common, tol2,
                                        **statics)
    elif isinstance(precond, ChebyshevPrecond):
        k = int(precond.k)
        if k > ez_l:
            raise ValueError(
                f"Chebyshev halo k={k} exceeds local slab count {ez_l}")
        sz_c = cheb_sz
        if sz_c is None:
            sz_c = _autotune.pick_slab_sz_cheb(grid_local, n, k, b.dtype,
                                               acc_dtype=policy.accum)
        if ez_l % sz_c:
            raise ValueError(f"local EZ {ez_l} not divisible by "
                             f"cheb sz {sz_c}")
        # loop-invariant operator windows on the GLOBAL field, sharded by
        # block — only the residual ghosts cross the network per apply.
        gext = shard(_ax.sstep_extend_field(g3, grid, sz_c, k))
        mzext = shard(_ax.sstep_extend_zfactor(mz, sz_c, k))
        coef = rep(jnp.asarray(precond.scalars(), policy.accum_dtype))
        with (rec.span("pcg.sharded_dispatch", precond=f"cheb{k}",
                       ndev=ndev)
              if rec is not None else _trace.NULL_SPAN):
            x2, kk, hist = _cheb_call(b2, *common, gext, mzext, coef,
                                      tol2, sz_c=sz_c, k=k, **statics)
    else:
        raise TypeError(f"unsupported preconditioner {precond!r}")
    return CGResult(x=jnp.asarray(np.asarray(x2)).reshape(b.shape),
                    iters=kk, rnorm=hist[kk], rnorm_history=hist)


def pcg_sharded_fixed_iters(b: jnp.ndarray, *, D: jnp.ndarray,
                            g: jnp.ndarray, grid: tuple[int, int, int],
                            niter: int, precond,
                            mask: jnp.ndarray | None = None,
                            c: jnp.ndarray | None = None,
                            sz: int | None = None,
                            cheb_sz: int | None = None,
                            interpret: bool | None = None, precision=None,
                            mesh=None, axis_name: str = "z",
                            ndev: int | None = None) -> CGResult:
    """Fixed-iteration sharded PCG (Jacobi or Chebyshev), z-slab mesh.

    Drop-in for :func:`repro.core.precond.pcg_fused_v2_fixed_iters` on
    global arrays (same trajectory to fp64 round-off); ``mesh`` /
    ``axis_name`` / ``ndev`` as in
    :func:`repro.distributed.sstep.cg_sstep_sharded_fixed_iters`.  Runs
    the tol core with the ``tol2 = -1`` sentinel, so the tol-driven
    trajectory (:func:`pcg_sharded_tol`) is a prefix of this one.
    """
    return _run(b, precond, -1.0, niter, D=D, g=g, grid=grid, mask=mask,
                c=c, sz=sz, cheb_sz=cheb_sz, interpret=interpret,
                precision=precision, mesh=mesh, axis_name=axis_name,
                ndev=ndev)


def pcg_sharded_tol(b: jnp.ndarray, *, D: jnp.ndarray, g: jnp.ndarray,
                    grid: tuple[int, int, int], precond, tol: float = 1e-8,
                    max_iter: int = 100,
                    mask: jnp.ndarray | None = None,
                    c: jnp.ndarray | None = None, sz: int | None = None,
                    cheb_sz: int | None = None,
                    interpret: bool | None = None, precision=None,
                    mesh=None, axis_name: str = "z",
                    ndev: int | None = None) -> CGResult:
    """Tolerance-driven sharded PCG: stop when ``|rtz| <= tol**2``.

    The sharded sibling of :func:`repro.core.precond.cg_fused_tol`
    (preconditioned variants): same stopping rule, checked before each
    iteration on the psum'd (replicated) ``rtz``, so every shard exits
    together.  History is NaN-padded to ``max_iter + 1``.
    """
    return _run(b, precond, float(tol) ** 2, max_iter, D=D, g=g, grid=grid,
                mask=mask, c=c, sz=sz, cheb_sz=cheb_sz, interpret=interpret,
                precision=precision, mesh=mesh, axis_name=axis_name,
                ndev=ndev)
