"""GPipe-style pipeline parallelism over a mesh axis (shard_map form).

Completes the parallelism matrix (DP/TP/EP/SP elsewhere): stage ``s`` owns a
contiguous slice of layers; microbatches stream through with boundary
activations moving stage-to-stage by ``ppermute``.  The classic schedule —
``n_micro + n_stages - 1`` ticks, bubble fraction ``(S-1)/(M+S-1)`` — is
expressed as a ``lax.fori_loop`` so the whole pipeline jits as one program.

Usage (inside shard_map over the pipeline axis, e.g. 'pod'):

    out = pipeline_apply(stage_params_local, microbatches, stage_fn,
                         axis_name='pod', n_stages=2)

``stage_fn(params_local, x) -> x`` runs this stage's layers.  Input
microbatches: (M, mb, ...) fed to stage 0; output collected from the last
stage (every stage returns the full (M, mb, ...) buffer; non-final stages
return garbage rows that the caller discards by reading the last stage's
shard — see tests/distributed_checks.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import compat

__all__ = ["pipeline_apply"]


def pipeline_apply(stage_params, microbatches, stage_fn, *, axis_name: str):
    """Run the pipeline; call inside shard_map over ``axis_name``.

    stage_params: this stage's layer-slice params (pytree).
    microbatches: (M, mb, ...) — the global input, replicated per stage
                  (only stage 0 reads it).
    Returns (M, mb, ...): valid on the LAST stage (use a masked psum or
    read that shard to collect).
    """
    S = compat.axis_size(axis_name)
    sid = jax.lax.axis_index(axis_name)
    M = microbatches.shape[0]
    mb_shape = microbatches.shape[1:]
    ticks = M + S - 1
    fwd_perm = [(i, i + 1) for i in range(S - 1)]

    out = jnp.zeros_like(microbatches)
    cur = jnp.zeros(mb_shape, microbatches.dtype)

    def tick(t, carry):
        cur, out = carry
        # stage 0 ingests microbatch t (when in range)
        mb_idx = jnp.clip(t, 0, M - 1)
        feed = jax.lax.dynamic_index_in_dim(microbatches, mb_idx, 0,
                                            keepdims=False)
        x_in = jnp.where(sid == 0, feed, cur)
        y = stage_fn(stage_params, x_in)
        # my microbatch index this tick; valid while 0 <= m < M
        m = t - sid
        valid = jnp.logical_and(m >= 0, m < M)
        out = jax.lax.dynamic_update_index_in_dim(
            out, jnp.where(valid, y, jax.lax.dynamic_index_in_dim(
                out, jnp.clip(m, 0, M - 1), 0, keepdims=False)),
            jnp.clip(m, 0, M - 1), 0)
        # boundary activation moves to the next stage
        cur = jax.lax.ppermute(y, axis_name, fwd_perm)
        return cur, out

    _, out = jax.lax.fori_loop(0, ticks, tick, (cur, out))
    return out
