"""Sharding rules: how model tensors map onto the production mesh.

Axis convention (launch/mesh.py):
  * ``pod``   — data parallelism across pods (gradient all-reduce over DCN;
                params replicated, optionally FSDP'd for the largest archs)
  * ``data``  — FSDP parameter sharding + batch data parallelism (ICI)
  * ``model`` — Megatron-style tensor parallelism (heads / ffn hidden /
                experts / vocab)

All constraints go through :func:`constrain`, which is a no-op when no mesh
is active — the same model code runs in single-device smoke tests and in the
512-chip dry-run.  Dimensions are only sharded when divisible by the axis
size (helper :meth:`AxisRules.div`), so e.g. 8 KV heads on a 16-way model
axis degrade gracefully to replication instead of erroring.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

__all__ = ["AxisRules", "constrain", "current_mesh", "RULES", "set_rules",
           "solver_mesh", "shard_leading", "replicate"]


# ---------------------------------------------------------------------------
# solver mesh helpers (distributed/sstep.py, distributed/pcg.py): the sharded
# Nekbone drivers run on a 1-D mesh whose single axis carries the z element
# slabs — a much simpler world than the pod/data/model production mesh above.
# ---------------------------------------------------------------------------

def solver_mesh(ndev: int | None = None, axis_name: str = "z",
                devices=None):
    """A 1-D mesh over ``ndev`` devices for the sharded solver drivers.

    Defaults to every visible device.  Falls back to the plain ``Mesh``
    constructor where ``jax.make_mesh`` predates the ``devices`` argument,
    so sub-meshes (shard-count sweeps in the tests) work across the jax
    span this repo supports.
    """
    import numpy as np
    from repro import compat

    if devices is None:
        devices = jax.devices()
    if ndev is None:
        ndev = len(devices)
    devs = np.asarray(devices[:ndev])
    if ndev == len(jax.devices()) and devices is jax.devices():
        return compat.make_mesh((ndev,), (axis_name,))
    try:
        return compat.make_mesh((ndev,), (axis_name,), devices=devs)
    except TypeError:
        return jax.sharding.Mesh(devs.reshape(ndev), (axis_name,))


def shard_leading(x: jnp.ndarray, mesh, axis_name: str) -> jnp.ndarray:
    """``device_put`` with the leading axis split over ``axis_name``."""
    return jax.device_put(
        x, jax.sharding.NamedSharding(mesh, P(axis_name)))


def replicate(x: jnp.ndarray, mesh) -> jnp.ndarray:
    """``device_put`` fully replicated on ``mesh``."""
    return jax.device_put(x, jax.sharding.NamedSharding(mesh, P()))


def current_mesh():
    """The ambient mesh set by ``jax.sharding.use_mesh`` / ``with mesh:``.

    ``get_abstract_mesh`` only exists on newer jax; fall back to the thread
    resources the ``with mesh:`` context manager populates on 0.4.x.
    """
    get = getattr(jax.sharding, "get_abstract_mesh", None)
    if get is not None:
        m = get()
    else:
        from jax._src import mesh as _mesh_lib

        m = _mesh_lib.thread_resources.env.physical_mesh
    if m is None or m.empty:
        return None
    return m


def constrain(x: jnp.ndarray, spec: P) -> jnp.ndarray:
    """``with_sharding_constraint`` that is a no-op without an active mesh."""
    mesh = current_mesh()
    if mesh is None:
        return x
    # Drop axis names the current mesh doesn't have (e.g. 'pod' on 1-pod).
    def filt(entry):
        if entry is None:
            return None
        if isinstance(entry, (tuple, list)):
            kept = tuple(a for a in entry if a in mesh.axis_names)
            return kept if kept else None
        return entry if entry in mesh.axis_names else None

    spec = P(*(filt(e) for e in spec))
    return jax.lax.with_sharding_constraint(x, spec)


@dataclasses.dataclass
class AxisRules:
    """Logical-to-mesh mapping with divisibility-aware helpers.

    Mutable singleton (:data:`RULES`): launchers tune it per run via
    :func:`set_rules` (e.g. ``fsdp_pod=True`` for the >100B archs) and every
    module sees the change because they all hold the same object.
    """

    dp: tuple[str, ...] = ("pod", "data")   # batch / token parallelism
    fsdp: str | None = "data"               # parameter sharding
    fsdp_pod: bool = False                  # also FSDP over 'pod' (huge archs)
    tp: str | None = "model"                # tensor parallelism
    seq: str | None = "data"                # context parallelism (long decode)

    # -- axis-size helpers --------------------------------------------------
    def _size(self, axes) -> int:
        mesh = current_mesh()
        if mesh is None:
            return 1
        if isinstance(axes, str):
            axes = (axes,)
        s = 1
        for a in axes:
            if a in mesh.axis_names:
                s *= mesh.shape[a]
        return s

    def div(self, dim: int, axes):
        """Return ``axes`` if ``dim`` divides evenly over them, else None."""
        if axes is None:
            return None
        sz = self._size(axes)
        return axes if (sz > 1 and dim % sz == 0) else (axes if sz == 1 else None)

    @property
    def fsdp_axes(self):
        if self.fsdp is None:
            return None
        return ("pod", self.fsdp) if self.fsdp_pod else self.fsdp

    # -- common specs --------------------------------------------------------
    def act_btd(self, d: int | None = None) -> P:
        """Activations (batch, seq, d_model): batch over dp."""
        return P(self.dp, None, None)

    def act_bthd(self, heads: int) -> P:
        """(batch, seq, heads, head_dim): heads over tp when divisible."""
        return P(self.dp, None, self.div(heads, self.tp), None)

    def w_in(self, d_in: int, d_out: int) -> P:
        """Input-side weight (d_in, d_out): FSDP rows, TP cols."""
        return P(self.div(d_in, self.fsdp_axes), self.div(d_out, self.tp))

    def w_out(self, d_in: int, d_out: int) -> P:
        """Output-side weight (d_in, d_out): TP rows, FSDP cols."""
        return P(self.div(d_in, self.tp), self.div(d_out, self.fsdp_axes))

    def w_expert(self, n_exp: int, d_in: int, d_out: int) -> P:
        """Expert weights (E, d_in, d_out): experts over TP, FSDP on d_in."""
        return P(self.div(n_exp, self.tp), self.div(d_in, self.fsdp_axes), None)

    def embed(self, vocab: int, d: int) -> P:
        """Embedding / unembedding (vocab, d): vocab over TP, d over FSDP."""
        return P(self.div(vocab, self.tp), self.div(d, self.fsdp_axes))

    def kv_cache(self, kv_heads: int) -> P:
        """KV cache (batch, kv_heads, seq, head_dim)."""
        return P(self.dp, self.div(kv_heads, self.tp), None, None)

    def kv_cache_cp(self, kv_heads: int) -> P:
        """Context-parallel KV cache for long single-sequence decode:
        the *sequence* axis is sharded (batch is 1)."""
        return P(None, self.div(kv_heads, self.tp), self.seq, None)


RULES = AxisRules()


def set_rules(**kw) -> AxisRules:
    """Mutate the global rules in place (same object everywhere)."""
    for k, v in kw.items():
        if not hasattr(RULES, k):
            raise AttributeError(f"AxisRules has no field {k!r}")
        setattr(RULES, k, v)
    return RULES
