"""Distributed s-step CG: the v3 matrix-powers pipeline sharded along z.

Single-device s-step CG (core/cg_sstep.py, DESIGN.md §8) amortizes *memory*
traffic over s iterations; this module amortizes the *network* the same way
(DESIGN.md §10).  Elements are sharded along z over a 1-D device mesh —
z-major element ordering makes the leading axis a stack of contiguous
z-slabs, so a ``PartitionSpec("z")`` on axis 0 is exactly a z-slab
decomposition — and one s-step cycle performs precisely two collectives:

1. **one s-deep ghost-slab halo exchange** — the matrix-powers kernel needs
   ``halo = s`` slabs beyond each block, so shard-boundary blocks need the
   neighbour shard's s edge slabs of both p and r.  Both fields' slabs are
   stacked into a single buffer and exchanged with one
   :func:`repro.core.gs.halo_exchange_z` call (= 2 ``ppermute``\\ s, one per
   direction) per cycle, replacing the per-iteration neighbour traffic of a
   distributed v1/v2 pipeline: s iterations of operator applications ride
   on one exchange.
2. **one Gram psum** — each shard reduces its blocks' ``(2s+1)^2`` Gram
   partials locally; a single ``jax.lax.psum`` assembles the global
   ``G = V^T C V``.

Everything else is local: the f64 recurrence runs replicated on host (one
device->host sync per cycle, as in the single-device driver — the psum'd G
is identical on every shard so the host coefficients are too), and the
multi-axpy update kernel is collective-free (its ``r·c·r`` partials return
per-shard and are summed on host, keeping the cycle at exactly one psum).

**Overlap schedule** (the ring idiom of :mod:`repro.distributed.overlap`,
applied to halos instead of all-gathers): a shard's *interior* blocks —
all but ``nb = ceil(s/sz)`` blocks per side — build their halo windows
from shard-local slabs only, so their matrix-powers ``pallas_call`` has no
data dependence on the ``ppermute``\\ s.  The cycle issues the exchange,
runs the interior powers call, then runs the boundary blocks' powers call
on the arrived ghosts: XLA's latency-hiding scheduler can overlap the
halo transfer with the interior compute, the collective-matmul trick with
the roles of compute and communication unchanged.

Windows of *loop-invariant* operator data (the metric diagonal ``gext``
and the z mask factor ``mzext``) are built once per solve on the **global**
field — block ``i``'s window is the same slabs whether the padding came
from a neighbour shard or from the same device — and device_put sharded by
block, so only p and r ever cross the network.

Correctness: the sharded trajectory equals the single-device one to fp64
round-off (the Gram psum and the host rcr sum reassociate f64 partial
sums; everything else is bitwise), verified per s in
``tests/distributed_checks.py`` and gated by the collective-count test
(:func:`cycle_collective_counts`).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

import repro.core.gs as gs_mod
from repro import compat
from repro.core.cg import CGResult
from repro.core.cg_sstep import cycle_coefficients, estimate_theta
from repro.core.geom import box_axis_factors, box_outer
from repro.core.precision import resolve_policy
from repro.distributed.sharding import replicate, shard_leading, solver_mesh
from repro.kernels import autotune as _autotune
from repro.kernels import nekbone_ax as _ax

__all__ = ["cg_sstep_sharded_fixed_iters", "cycle_collective_counts",
           "cycle_traceables", "exchange_ghost_slabs", "count_collectives"]


# ---------------------------------------------------------------------------
# halo exchange
# ---------------------------------------------------------------------------

def exchange_ghost_slabs(f: jnp.ndarray, ez_local: int, halo: int,
                         axis_names) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Exchange ``halo`` ghost z-slabs of a shard-local field.

    To be called *inside* ``shard_map``.  ``f`` is ``(ez_local, ...)``
    slab-major (reshape ``(E_local, n^3)`` fields to ``(ez_l, EY*EX, n^3)``
    first).  Returns ``(below, above)`` — the neighbour shards' ``halo``
    edge slabs, zeros at the global domain ends (which is exactly the
    padding :func:`repro.kernels.nekbone_ax.sstep_extend_field` wants
    there).  Costs one ``ppermute`` per direction.
    """
    if not (0 < halo <= ez_local):
        raise ValueError(f"halo {halo} out of range for ez_local {ez_local}")
    return gs_mod.halo_exchange_z(f[ez_local - halo:], f[:halo], axis_names)


# ---------------------------------------------------------------------------
# the sharded cycle: one exchange, interior/boundary powers, one Gram psum
# ---------------------------------------------------------------------------

def _cycle_shard(p2, r2, D, Dt, gextl, mzextl, mx, my, cx, cy, czl,
                 inv_theta, *, axis_name: str, n: int,
                 grid_local: tuple[int, int, int], sz: int, s: int,
                 interpret: bool, acc_name: str | None):
    """Shard body of one matrix-powers cycle (runs inside ``shard_map``).

    Exactly 2 ppermutes (the stacked p/r ghost-slab exchange) and 1 psum
    (the Gram block) — the invariant the collective-count test pins.
    """
    ex, ey, ez_l = grid_local
    eyex = ey * ex
    n3 = n ** 3
    nblk = ez_l // sz
    L = sz + 2 * s
    block_e = sz * eyex
    p = p2.reshape(ez_l, eyex, n3)
    r = r2.reshape(ez_l, eyex, n3)

    # -- the one halo exchange of the cycle: p and r edge slabs stacked
    # into a single buffer so both fields (x both directions) ride on one
    # halo_exchange_z call = 2 ppermutes.
    buf = jnp.stack([p, r])                        # (2, ez_l, eyex, n3)
    from_below, from_above = exchange_ghost_slabs(
        jnp.swapaxes(buf, 0, 1), ez_l, s, (axis_name,))
    pb, rb = from_below[:, 0], from_below[:, 1]    # (s, eyex, n3) each
    pa, ra = from_above[:, 0], from_above[:, 1]

    def powers(pext, rext, gext, mzext, cz, nblocks):
        return _ax.nekbone_ax_powers_pallas(
            pext, rext, D, Dt, gext, mx, my, mzext, cx, cy, cz, inv_theta,
            n=n, grid=(ex, ey, nblocks * sz), sz=sz, s=s,
            interpret=interpret, acc_dtype=acc_name)

    nb = -(-s // sz)              # boundary blocks per side (windows need ghosts)
    if 2 * nb >= nblk:
        # shard too thin for an interior: single powers call on all blocks
        pext = _ax.sstep_extend_field(p2, grid_local, sz, s,
                                      below=pb, above=pa)
        rext = _ax.sstep_extend_field(r2, grid_local, sz, s,
                                      below=rb, above=ra)
        basis, gram_b = powers(pext, rext, gextl, mzextl, czl, nblk)
        gram_loc = jnp.sum(gram_b, axis=0)
    else:
        # -- overlap schedule: interior windows touch no ghost data, so the
        # interior powers call is independent of the ppermutes above and
        # XLA can run it while the boundary halo is in flight (the ring-
        # overlap idiom of distributed/overlap.py, halo edition).
        ii = np.arange(nb, nblk - nb)
        idx = ii[:, None] * sz - s + np.arange(L)[None, :]   # all local
        pint = p[idx].reshape(len(ii), L * eyex, n3)
        rint = r[idx].reshape(len(ii), L * eyex, n3)
        basis_i, gram_i = powers(
            pint, rint, gextl[nb:nblk - nb], mzextl[nb:nblk - nb],
            czl[nb * sz:(nblk - nb) * sz], len(ii))

        # -- boundary blocks: windows over [ghosts-below | local | ghosts-
        # above]; in padded coordinates block i's window starts at i*sz.
        fp = jnp.concatenate([pb, p, pa], axis=0)
        fr = jnp.concatenate([rb, r, ra], axis=0)
        ib = np.concatenate([np.arange(nb), np.arange(nblk - nb, nblk)])
        idxb = ib[:, None] * sz + np.arange(L)[None, :]
        pbnd = fp[idxb].reshape(2 * nb, L * eyex, n3)
        rbnd = fr[idxb].reshape(2 * nb, L * eyex, n3)
        gbnd = jnp.concatenate([gextl[:nb], gextl[nblk - nb:]], axis=0)
        mzbnd = jnp.concatenate([mzextl[:nb], mzextl[nblk - nb:]], axis=0)
        czbnd = jnp.concatenate([czl[:nb * sz], czl[(nblk - nb) * sz:]],
                                axis=0)
        basis_b, gram_bb = powers(pbnd, rbnd, gbnd, mzbnd, czbnd, 2 * nb)

        half = nb * block_e
        basis = jnp.concatenate(
            [basis_b[:half], basis_i, basis_b[half:]], axis=0)
        gram_loc = jnp.sum(gram_i, axis=0) + jnp.sum(gram_bb, axis=0)

    G = jax.lax.psum(gram_loc, axis_name)          # the one Gram psum
    return basis, G


def _cycle_mapped(mesh, axis_name: str, n: int,
                  grid_local: tuple[int, int, int], sz: int, s: int,
                  interpret: bool, acc_name: str | None):
    """shard_map-wrapped cycle on global operands (un-jitted; shared by the
    driver's jit below and the collective-count tracer)."""
    ax = axis_name
    body = functools.partial(
        _cycle_shard, axis_name=ax, n=n, grid_local=grid_local, sz=sz, s=s,
        interpret=interpret, acc_name=acc_name)
    return compat.shard_map(
        body, mesh=mesh,
        in_specs=(P(ax), P(ax), P(), P(), P(ax), P(ax), P(), P(), P(), P(),
                  P(ax), P()),
        out_specs=(P(ax), P()),
        check_vma=False)                      # pallas_call has no VMA rule


def _update_mapped(mesh, axis_name: str, n: int,
                   grid_local: tuple[int, int, int], sz: int, s: int,
                   interpret: bool, acc_name: str | None):
    """shard_map-wrapped multi-axpy update: collective-free; the per-block
    rcr partials come back sharded and are summed on host."""
    ax = axis_name

    def body(x2, p2, r2, basis, coef, cx, cy, czl):
        return _ax.nekbone_sstep_update_pallas(
            x2, p2, r2, basis, coef, cx, cy, czl, n=n, grid=grid_local,
            sz=sz, s=s, interpret=interpret, acc_dtype=acc_name)

    return compat.shard_map(
        body, mesh=mesh,
        in_specs=(P(ax), P(ax), P(ax), P(ax), P(), P(), P(), P(ax)),
        out_specs=(P(ax), P(ax), P(ax), P(ax)),
        check_vma=False)


@functools.partial(jax.jit, static_argnames=(
    "mesh", "axis_name", "n", "grid_local", "sz", "s", "interpret",
    "acc_name"))
def _cycle_call(p2, r2, D, Dt, gext, mzext, mx, my, cx, cy, cz, inv_theta,
                *, mesh, axis_name, n, grid_local, sz, s, interpret,
                acc_name):
    return _cycle_mapped(mesh, axis_name, n, grid_local, sz, s, interpret,
                         acc_name)(p2, r2, D, Dt, gext, mzext, mx, my, cx,
                                   cy, cz, inv_theta)


@functools.partial(jax.jit, static_argnames=(
    "mesh", "axis_name", "n", "grid_local", "sz", "s", "interpret",
    "acc_name"))
def _update_call(x2, p2, r2, basis, coef, cx, cy, cz, *, mesh, axis_name,
                 n, grid_local, sz, s, interpret, acc_name):
    return _update_mapped(mesh, axis_name, n, grid_local, sz, s, interpret,
                          acc_name)(x2, p2, r2, basis, coef, cx, cy, cz)


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

def _resolve_mesh(mesh, axis_name: str, ndev: int | None):
    if mesh is None:
        mesh = solver_mesh(ndev, axis_name=axis_name)
    if len(mesh.axis_names) != 1:
        raise ValueError(
            f"sharded solvers want a 1-D mesh, got axes {mesh.axis_names}")
    return mesh, mesh.axis_names[0], int(np.prod(mesh.devices.shape))


def cg_sstep_sharded_fixed_iters(
        b: jnp.ndarray, *, D: jnp.ndarray, g: jnp.ndarray,
        grid: tuple[int, int, int], niter: int, s: int = 4,
        mask: jnp.ndarray | None = None, c: jnp.ndarray | None = None,
        sz: int | None = None, theta: float | None = None,
        tol: float | None = None, interpret: bool | None = None,
        precision=None, mesh=None, axis_name: str = "z",
        ndev: int | None = None) -> CGResult:
    """Sharded s-step CG: z-slab decomposition over a 1-D mesh.

    Drop-in for :func:`repro.core.cg_sstep.cg_sstep_fixed_iters` (global
    arrays in, :class:`CGResult` out; trajectory equal to fp64 round-off)
    with the per-cycle communication contract of DESIGN.md §10: one s-deep
    ghost-slab halo exchange and one Gram psum per cycle, nothing else.

    Extra args over the single-device driver:
      mesh:      explicit 1-D device mesh (default:
                 :func:`repro.distributed.sharding.solver_mesh`).
      axis_name: mesh axis carrying the z slabs (default ``"z"``).
      ndev:      device count when building the default mesh (default: all).

    Constraints: ``EZ % ndev == 0``, ``EZ_local % sz == 0`` and
    ``s <= EZ_local`` (ghost slabs come from the adjacent shard only — a
    deeper halo would need multi-hop exchange, out of scope).
    """
    from repro.core.cg_fused import _check_box_fields
    from repro.kernels import ops as kernel_ops

    if s < 1:
        raise ValueError(f"s-step CG needs s >= 1, got {s}")
    policy = resolve_policy(precision, b.dtype)
    b = jnp.asarray(b, policy.storage_dtype)
    E = b.shape[0]
    n = b.shape[-1]
    grid = tuple(grid)
    ex, ey, ez = grid
    mesh, axis_name, ndev = _resolve_mesh(mesh, axis_name, ndev)
    if ez % ndev:
        raise ValueError(f"EZ {ez} not divisible by mesh size {ndev}")
    ez_l = ez // ndev
    grid_local = (ex, ey, ez_l)
    if interpret is None:
        interpret = kernel_ops.default_interpret()
    if sz is None:
        sz = _autotune.pick_slab_sz_sstep(grid_local, n, s, b.dtype,
                                          acc_dtype=policy.accum)
    if ez_l % sz:
        raise ValueError(f"local EZ {ez_l} not divisible by sz {sz}")
    if s > ez_l:
        raise ValueError(
            f"halo depth s={s} exceeds local slab count {ez_l} "
            f"(single-neighbour exchange)")

    _check_box_fields(grid, n, mask, c)
    (mx, my, mz), (cx, cy, cz) = kernel_ops.slab_axis_factors(grid, n,
                                                              b.dtype)
    n3 = n ** 3
    acc = policy.accum_dtype
    x_dtype = policy.x_storage_dtype
    D_op = jnp.asarray(D, policy.op_storage_dtype)
    g3 = kernel_ops.diag_metric(jnp.asarray(g, policy.op_storage_dtype),
                                E, n)
    # loop-invariant halo windows, built on the GLOBAL field: block i's
    # window holds the same slabs whether its halo padding was gathered
    # locally or exchanged from a neighbour, so these shard by block with
    # no per-cycle traffic.  Only p and r cross the network.
    gext = _ax.sstep_extend_field(g3, grid, sz, s)
    mzext = _ax.sstep_extend_zfactor(mz, sz, s)
    if theta is None:
        if mask is None:
            mask = box_outer(
                *reversed(box_axis_factors(grid, n)[0])).reshape(b.shape)
        theta = estimate_theta(jnp.asarray(D, b.dtype),
                               jnp.asarray(g, b.dtype), grid,
                               jnp.asarray(mask, b.dtype))
    inv_theta = jnp.full((1, 1), 1.0 / theta, acc)

    shard = functools.partial(shard_leading, mesh=mesh, axis_name=axis_name)
    rep = functools.partial(replicate, mesh=mesh)
    x2 = shard(jnp.zeros((E, n3), x_dtype))
    r2 = p2 = shard(b.reshape(E, n3))
    gext, mzext, cz = shard(gext), shard(mzext), shard(cz)
    D_op, mx, my, cx, cy, inv_theta = (
        rep(D_op), rep(mx), rep(my), rep(cx), rep(cy), rep(inv_theta))
    Dt_op = rep(D_op.T)
    statics = dict(mesh=mesh, axis_name=axis_name, n=n,
                   grid_local=grid_local, sz=sz, s=s, interpret=interpret,
                   acc_name=policy.accum)

    tol2 = None if tol is None else float(tol) ** 2
    hist: list[float] = []
    rcr_parts = None
    rcr_last = None
    it = 0
    # tracing: recorder read once per solve; one `is None` test per
    # sharded cycle when off.
    from repro.obs import trace as _trace

    rec = _trace.active()
    while it < niter:
        if rcr_parts is not None:
            # the update kernel's rcr partials come back per-shard (no
            # device collective — the psum budget stays at 1/cycle); the
            # global reduction is this host f64 sum.
            rcr_last = float(np.asarray(rcr_parts, np.float64).sum())
            if tol2 is not None and abs(rcr_last) <= tol2:
                break
        m = min(s, niter - it)
        with (rec.span("sstep.sharded_cycle", it=it, s=s, ndev=ndev)
              if rec is not None else _trace.NULL_SPAN):
            basis, G = _cycle_call(p2, r2, D_op, Dt_op, gext, mzext, mx,
                                   my, cx, cy, cz, inv_theta, **statics)
            Gh = np.asarray(G, np.dtype(policy.gram))
            coef_np, rtzs, m = cycle_coefficients(Gh, s, m, theta, tol2)
            if m == 0:
                break
            hist.extend(np.sqrt(np.abs(v)) for v in rtzs)
            coef = rep(jnp.asarray(coef_np, acc))
            x2, r2, p2, rcr_parts = _update_call(x2, p2, r2, basis, coef,
                                                 cx, cy, cz, **statics)
        it += m
        if tol2 is not None and m < s:
            break
    if rcr_parts is not None:
        rcr_last = float(np.asarray(rcr_parts, np.float64).sum())
    if rcr_last is None:                  # niter == 0 (or tol met at start)
        c2 = box_outer(np.asarray(cz, np.float64), np.asarray(cy, np.float64),
                       np.asarray(cx, np.float64)).reshape(E, n3)
        r_h = np.asarray(r2, np.float64)
        rcr_last = float(np.sum(r_h * c2 * r_h))
    hist.append(float(np.sqrt(abs(rcr_last))))
    hist_arr = jnp.asarray(np.asarray(hist, np.float64), acc)
    return CGResult(x=jnp.asarray(np.asarray(x2)).reshape(b.shape),
                    iters=jnp.asarray(it), rnorm=hist_arr[-1],
                    rnorm_history=hist_arr)


# ---------------------------------------------------------------------------
# collective accounting: trace a cycle, count the primitives
# ---------------------------------------------------------------------------

_COLLECTIVES = ("ppermute", "psum", "all_gather", "all_to_all")


def _walk_jaxpr(jaxpr, counts: dict):
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        for key in _COLLECTIVES:
            if key in name:
                counts[key] = counts.get(key, 0) + 1
        for v in eqn.params.values():
            _walk_param(v, counts)


def _walk_param(v, counts: dict):
    # duck-typed recursion: ClosedJaxpr has .jaxpr, Jaxpr has .eqns; sub-
    # jaxprs hide under different param keys across jax versions.
    if hasattr(v, "eqns"):
        _walk_jaxpr(v, counts)
    elif hasattr(v, "jaxpr") and hasattr(v.jaxpr, "eqns"):
        _walk_jaxpr(v.jaxpr, counts)
    elif isinstance(v, (tuple, list)):
        for x in v:
            _walk_param(x, counts)


def count_collectives(fn, *args) -> dict:
    """Counts of collective primitives in ``jax.make_jaxpr(fn)(*args)``.

    Keys: ``ppermute``, ``psum``, ``all_gather``, ``all_to_all`` (absent
    when zero).  ``args`` may be ``jax.ShapeDtypeStruct``\\ s.
    """
    closed = jax.make_jaxpr(fn)(*args)
    counts: dict = {}
    _walk_jaxpr(closed.jaxpr, counts)
    return counts


def cycle_traceables(*, grid: tuple[int, int, int], n: int,
                     s: int = 4, sz: int = 1, mesh=None,
                     axis_name: str = "z", ndev: int | None = None,
                     interpret: bool = True, precision=None):
    """The sharded cycle/update launches as traceable (fn, arg-spec) pairs.

    Returns ``((cycle_fn, cycle_args), (update_fn, update_args))`` with
    ``jax.ShapeDtypeStruct`` arg specs shaped exactly as the sharded
    driver's per-cycle operands.  Tracing needs no committed arrays, so
    this works at any ``ndev`` including 1 — the surface behind both
    :func:`cycle_collective_counts` (the §10 contract test) and the
    :mod:`repro.obs.drift` collective checks.
    """
    policy = resolve_policy(precision, jnp.float32)
    mesh, axis_name, ndev = _resolve_mesh(mesh, axis_name, ndev)
    ex, ey, ez = grid
    if ez % ndev:
        raise ValueError(f"EZ {ez} not divisible by mesh size {ndev}")
    ez_l = ez // ndev
    grid_local = (ex, ey, ez_l)
    if ez_l % sz or s > ez_l:
        raise ValueError((grid, ndev, sz, s))
    E = ex * ey * ez
    n3 = n ** 3
    L = sz + 2 * s
    Lee = L * ey * ex
    nblk = ez // sz
    K = 2 * s + 1
    st = policy.storage_dtype
    op = policy.op_storage_dtype
    acc = policy.accum_dtype
    S = jax.ShapeDtypeStruct
    field = S((E, n3), st)
    cycle_args = (field, field, S((n, n), op), S((n, n), op),
                  S((nblk, Lee, 3, n3), op), S((nblk, L, n), st),
                  S((ex, n), st), S((ey, n), st), S((ex, n), st),
                  S((ey, n), st), S((ez, n), st), S((1, 1), acc))
    update_args = (S((E, n3), policy.x_storage_dtype), field, field,
                   S((E, 2 * s - 1, n3), st), S((3, K), acc),
                   S((ex, n), st), S((ey, n), st), S((ez, n), st))
    cyc = _cycle_mapped(mesh, axis_name, n, grid_local, sz, s, interpret,
                        policy.accum)
    upd = _update_mapped(mesh, axis_name, n, grid_local, sz, s, interpret,
                         policy.accum)
    return (cyc, cycle_args), (upd, update_args)


def cycle_collective_counts(*, grid: tuple[int, int, int], n: int,
                            s: int = 4, sz: int = 1, mesh=None,
                            axis_name: str = "z", ndev: int | None = None,
                            interpret: bool = True,
                            precision=None) -> dict:
    """Collective counts of one sharded s-step cycle + update (traced).

    Returns ``{"cycle": {...}, "update": {...}}``.  The DESIGN.md §10
    contract — asserted by the acceptance test — is
    ``cycle == {"ppermute": 2, "psum": 1}`` (one stacked p/r halo exchange,
    one Gram reduction) and ``update == {}`` (collective-free).
    """
    (cyc, cycle_args), (upd, update_args) = cycle_traceables(
        grid=grid, n=n, s=s, sz=sz, mesh=mesh, axis_name=axis_name,
        ndev=ndev, interpret=interpret, precision=precision)
    return {"cycle": count_collectives(cyc, *cycle_args),
            "update": count_collectives(upd, *update_args)}
