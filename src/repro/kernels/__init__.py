"""Pallas TPU kernels for the compute hot-spots (see DESIGN.md §2, §4).

* ``nekbone_ax`` — the paper's tensor-product Poisson operator (primary).
* ``flash_attn`` — block online-softmax attention (prefill hot-spot).
* ``wkv6``       — RWKV6 linear-attention recurrence (state streaming).

``ops``   — jitted public wrappers (layout handling, padding, autotuning).
``ref``   — pure-jnp oracles used by the allclose test sweeps.
"""
from repro.kernels import ops, ref  # noqa: F401

__all__ = ["ops", "ref"]
