"""Block-size selection for the nekbone Ax kernels, with a persistent cache.

The element block size is the kernel family's one tuning knob: it trades
VMEM residency (larger blocks amortize the grid and give the MXU taller
``e*n^2 x n`` operands) against the double-buffering headroom the pipeline
needs.  Two block modes exist:

* **Flat blocks** (:func:`pick_block_e`): any power-of-two element count —
  the v1 kernels' mode, where the block never needs to know the element
  grid.
* **Slab blocks** (:func:`pick_slab_sz`): whole z-slabs of the element box,
  ``block_e = sz * EX * EY`` with ``sz | EZ`` — the v2 pipeline's mode
  (DESIGN.md §3.4), where the x/y direct-stiffness summation must be
  intra-block, so the block must cover complete slabs of the z-major
  element order.

Selection strategy (both modes):

* **Heuristic floor**: largest candidate whose ~14-array working set fits a
  VMEM budget (default 8 MiB of the ~16 MiB/core).  This is exact enough
  off-TPU, where kernels only run in interpret mode and wall time is
  meaningless.
* **Measurement** (on a TPU backend): times the real kernel over the
  candidates below the heuristic ceiling and keeps the fastest — the
  empirical analog of the paper's per-architecture tuning sweep (its
  Table 1 re-tunes the CUDA kernel per GPU generation).

Results are memoized in a process-wide cache and — for *measured*
selections — persisted as JSON under ``$REPRO_CACHE_DIR`` (default
``~/.cache/repro``), so repeated benchmark runs skip the re-measuring
sweep entirely.  The disk cache is corrupt-file tolerant: an unreadable or
malformed file is ignored and overwritten on the next measured pick.
``clear_cache`` wipes both layers (pass ``disk=False`` to keep the file).
"""
from __future__ import annotations

import json
import os
import pathlib
import threading
from typing import Callable

import jax
import jax.numpy as jnp

from repro.kernels import timing as _timing

__all__ = ["vmem_block_e", "pick_block_e", "candidate_blocks",
           "candidate_slab_sizes", "pick_slab_sz",
           "candidate_slab_sizes_sstep", "pick_slab_sz_sstep",
           "candidate_slab_sizes_cheb", "pick_slab_sz_cheb",
           "candidate_configs", "pick_slab_config", "pick_sstep_config",
           "pick_cheb_config", "pick_pipeline", "AUTO_V2_MIN_E",
           "clear_cache", "cache_info", "cache_path", "cache_stats"]

_CACHE: dict[tuple, object] = {}
_MEASURED: set[tuple] = set()     # keys whose value came from a timing sweep
_LOCK = threading.Lock()
_DISK_LOADED = False

VMEM_BUDGET_BYTES = 8 * 2 ** 20
# The kernels keep ~14 block-sized arrays live (fields in/out, 3 gradients,
# metric-applied temporaries) in the accumulation dtype.  For the multi-RHS
# block kernels (DESIGN.md §12) that count splits into operator-side
# residents shared across the batch (metric diagonals + mask box) and
# per-RHS vector arrays: live = _LIVE_SHARED + _LIVE_PER_RHS * b, which
# recovers 14 at b = 1.
_LIVE_SHARED = 4
_LIVE_PER_RHS = 10
_LIVE_ARRAYS = _LIVE_SHARED + _LIVE_PER_RHS


# ---------------------------------------------------------------------------
# disk persistence
# ---------------------------------------------------------------------------

def cache_path() -> pathlib.Path:
    """Location of the on-disk autotune cache (JSON)."""
    root = os.environ.get("REPRO_CACHE_DIR") or os.path.join(
        os.path.expanduser("~"), ".cache", "repro")
    return pathlib.Path(root) / "autotune.json"


def _load_disk_locked() -> None:
    """Merge the disk cache into memory once per process (caller holds lock).

    Tolerates a missing, unreadable, or corrupt file — autotuning then just
    re-measures and rewrites it.
    """
    global _DISK_LOADED
    if _DISK_LOADED:
        return
    _DISK_LOADED = True
    try:
        raw = json.loads(cache_path().read_text())
        for item in raw["entries"]:
            key = tuple(item["key"])
            val = item["value"]
            # three value shapes live in the file: ints (block/slab sizes,
            # the v1 format — kept readable for old caches), lists (joint
            # (sz, layout, grid_order) configs; tuples round-trip through
            # JSON as lists), and strings (pipeline picks).
            if isinstance(val, list):
                val, ok = tuple(val), len(val) > 0
            elif isinstance(val, str):
                ok = len(val) > 0
            else:
                val = int(val)
                ok = val >= 1
            if ok:
                _CACHE.setdefault(key, val)
                _MEASURED.add(key)     # the file only ever holds measured picks
    except Exception:
        pass


def _save_disk_locked() -> None:
    """Atomically rewrite the disk cache (caller holds lock).

    Only *measured* selections are written: heuristic picks are a pure
    function of the budget constants and must recompute when those change.
    """
    try:
        path = cache_path()
        path.parent.mkdir(parents=True, exist_ok=True)
        entries = [{"key": list(k),
                    "value": list(v) if isinstance(v, tuple) else v}
                   for k, v in sorted(_CACHE.items(), key=lambda kv: str(kv[0]))
                   if k in _MEASURED]
        payload = {"version": 1, "entries": entries}
        tmp = path.with_name(path.name + ".tmp")
        tmp.write_text(json.dumps(payload, indent=1))
        tmp.replace(path)
    except Exception:
        pass  # read-only cache dir: persistence is best-effort


# hit/miss totals for the telemetry layer (obs/metrics.SolveTelemetry
# reports the per-solve delta); guarded by _LOCK like the cache itself.
_STATS = {"hits": 0, "misses": 0}


def cache_stats() -> dict:
    """Process-lifetime autotune cache counters ``{"hits", "misses"}``."""
    with _LOCK:
        return dict(_STATS)


def _cached_pick(key: tuple, pick: Callable[[], tuple]):
    """Shared lookup -> pick -> memoize (+persist if measured) path.

    ``pick`` runs only on a cache miss — it may build an expensive measure
    closure (synthetic operands, device transfers), so the warm path must
    never touch it — and returns ``(best, measured)``.
    """
    from repro.obs import trace

    with _LOCK:
        _load_disk_locked()
        if key in _CACHE:
            _STATS["hits"] += 1
            trace.count("autotune.cache_hits")
            return _CACHE[key]
        _STATS["misses"] += 1
    trace.count("autotune.cache_misses")

    best, measured = pick()

    with _LOCK:
        _CACHE.setdefault(key, best)
        if measured:
            _MEASURED.add(key)
            _save_disk_locked()
        return _CACHE[key]


# ---------------------------------------------------------------------------
# flat element blocks (v1 kernels)
# ---------------------------------------------------------------------------

def vmem_block_e(E: int, n: int,
                 vmem_budget_bytes: int = VMEM_BUDGET_BYTES,
                 itemsize: int = 4) -> int:
    """Largest power-of-two element block whose working set fits the budget.

    The kernel keeps ~14 block-sized arrays live (u, w, 6 metric fields,
    3 gradients + 3 temporaries) in the accumulation dtype (f32, or f64 on
    the fp64 oracle path); lanes pad n^3 up to a multiple of 128.
    """
    n3_padded = -(-(n ** 3) // 128) * 128
    per_elem = _LIVE_ARRAYS * n3_padded * max(itemsize, 4)
    be = max(1, vmem_budget_bytes // per_elem)
    be = 1 << (be.bit_length() - 1)            # floor to power of two
    while be > 1 and E % be:
        be //= 2
    return be


def candidate_blocks(E: int, n: int, itemsize: int = 4) -> list[int]:
    """Power-of-two candidates (descending) from the VMEM ceiling down to 1,
    keeping only divisors of ``E`` so no padding is introduced."""
    ceil = vmem_block_e(E, n, itemsize=itemsize)
    cands = []
    be = ceil
    while be >= 1:
        if E % be == 0:
            cands.append(be)
        be //= 2
    return cands or [1]


def _default_measure(E: int, n: int, dtype,
                     acc_dtype=None) -> Callable[[int], float]:
    """Times the real Ax kernel on synthetic data for one block size."""
    import numpy as np

    from repro.core.sem import derivative_matrix
    from repro.kernels import nekbone_ax as _ax

    rng = np.random.default_rng(0)
    u2 = jnp.asarray(rng.normal(size=(E, n ** 3)), dtype)
    g2 = jnp.asarray(rng.normal(size=(E, 6, n ** 3)), dtype)
    D = jnp.asarray(derivative_matrix(n), dtype)
    Dt = D.T

    def measure(block_e: int) -> float:
        def f():
            return _ax.nekbone_ax_pallas(u2, D, Dt, g2, n=n,
                                         block_e=block_e, interpret=False,
                                         acc_dtype=acc_dtype)

        return _timing.measure(f, reps=3, warmup=1)

    return measure


def _acc_name(dtype, acc_dtype) -> str:
    """Resolved accumulation-dtype name for cache keys.

    Mirrors ``kernels/nekbone_ax._accum``: an explicit precision-policy
    choice wins, else f64 storage accumulates in f64 and everything
    narrower in f32.  Keys carry the resolved pair so e.g. (bf16, f32) and
    (bf16, f64) — different VMEM working sets, different kernels — never
    collide.
    """
    if acc_dtype is not None:
        return jnp.dtype(acc_dtype).name
    return "float64" if jnp.dtype(dtype) == jnp.float64 else "float32"


def pick_block_e(E: int, n: int, dtype=jnp.float32, *,
                 acc_dtype=None, backend: str | None = None,
                 measure: Callable[[int], float] | None = None) -> int:
    """Best ``block_e`` for ``(E, n, storage/accum dtypes)``, memoized.

    On a TPU backend (or when an explicit ``measure`` callable is supplied)
    the candidates are timed and the fastest wins; elsewhere the VMEM
    heuristic decides directly — interpret-mode wall time reflects the
    emulator, not the hardware, so measuring it would tune for noise.
    Measured picks persist to :func:`cache_path`.
    """
    dtype = jnp.dtype(dtype)
    backend = backend or jax.default_backend()
    acc_name = _acc_name(dtype, acc_dtype)
    key = (n, E, dtype.name, acc_name, backend)
    # the ~14 live block arrays sit in VMEM in the *accumulation* dtype,
    # so candidates must be sized by the wider of the pair — a (bf16, f64)
    # policy holds 8-byte temporaries off 2-byte streams.
    size_item = max(dtype.itemsize, jnp.dtype(acc_name).itemsize)

    def pick() -> tuple[int, bool]:
        cands = candidate_blocks(E, n, itemsize=size_item)
        m = measure
        if m is None and backend == "tpu":
            m = _default_measure(E, n, dtype, acc_dtype)
        if m is None:
            return cands[0], False
        return min(cands, key=m), True

    return _cached_pick(key, pick)


# ---------------------------------------------------------------------------
# slab blocks (v2 pipeline)
# ---------------------------------------------------------------------------

def candidate_slab_sizes(grid: tuple[int, int, int], n: int,
                         itemsize: int = 4, nrhs: int = 1) -> list[int]:
    """Slabs-per-block candidates (descending divisors of EZ).

    A slab block holds ``sz * EX * EY`` elements, so the VMEM ceiling caps
    ``sz``; ``sz`` must divide ``EZ`` so every block covers whole slabs with
    no padding.  ``sz = 1`` is always viable (the kernel needs at least one
    slab resident, even if that overshoots the budget on huge x/y extents).
    ``nrhs > 1`` (the multi-RHS block kernels) scales the per-RHS vector
    residents while the operator-side share stays constant, so viable sz
    shrinks as b grows.
    """
    ex, ey, ez = grid
    n3_padded = -(-(n ** 3) // 128) * 128
    live = _LIVE_SHARED + _LIVE_PER_RHS * nrhs
    per_elem = live * n3_padded * max(itemsize, 4)
    max_block = max(1, VMEM_BUDGET_BYTES // per_elem)
    sz_max = max(1, max_block // (ex * ey))
    cands = [s for s in range(ez, 0, -1) if ez % s == 0 and s <= sz_max]
    return cands or [1]


def _default_measure_slab(grid: tuple[int, int, int], n: int, dtype,
                          acc_dtype=None) -> Callable[[int], float]:
    """Times the v2 slab kernel on synthetic data for one config
    (slab count; optionally contraction layout and grid order)."""
    import numpy as np

    from repro.core.geom import axis_mask_factor
    from repro.core.sem import derivative_matrix
    from repro.kernels import nekbone_ax as _ax

    ex, ey, ez = grid
    E = ex * ey * ez
    rng = np.random.default_rng(0)
    p2 = jnp.asarray(rng.normal(size=(E, n ** 3)), dtype)
    r2 = jnp.asarray(rng.normal(size=(E, n ** 3)), dtype)
    g3 = jnp.asarray(rng.normal(size=(E, 3, n ** 3)), dtype)
    D = jnp.asarray(derivative_matrix(n), dtype)
    mx = jnp.asarray(axis_mask_factor(ex, n), dtype)
    my = jnp.asarray(axis_mask_factor(ey, n), dtype)
    mz = jnp.asarray(axis_mask_factor(ez, n), dtype)
    beta = jnp.zeros((1, 1), _ax._accum(jnp.dtype(dtype), acc_dtype))

    def measure(sz: int, layout: str = "fold",
                grid_order: str = "parallel") -> float:
        def f():
            return _ax.nekbone_ax_slab_pallas(
                p2, r2, D, D.T, g3, mx, my, mz, beta, n=n, grid=grid, sz=sz,
                interpret=False, acc_dtype=acc_dtype, layout=layout,
                grid_order=grid_order)

        return _timing.measure(f, reps=3, warmup=1)

    return measure


def _default_measure_slab_block(grid: tuple[int, int, int], n: int, dtype,
                                nrhs: int,
                                acc_dtype=None) -> Callable[[int], float]:
    """Times the batched (multi-RHS) v2 slab kernel on synthetic data."""
    import numpy as np

    from repro.core.geom import axis_mask_factor
    from repro.core.sem import derivative_matrix
    from repro.kernels import nekbone_ax as _ax

    ex, ey, ez = grid
    E = ex * ey * ez
    rng = np.random.default_rng(0)
    p3 = jnp.asarray(rng.normal(size=(nrhs, E, n ** 3)), dtype)
    r3 = jnp.asarray(rng.normal(size=(nrhs, E, n ** 3)), dtype)
    g3 = jnp.asarray(rng.normal(size=(E, 3, n ** 3)), dtype)
    D = jnp.asarray(derivative_matrix(n), dtype)
    mx = jnp.asarray(axis_mask_factor(ex, n), dtype)
    my = jnp.asarray(axis_mask_factor(ey, n), dtype)
    mz = jnp.asarray(axis_mask_factor(ez, n), dtype)
    beta = jnp.zeros((1, nrhs), _ax._accum(jnp.dtype(dtype), acc_dtype))

    def measure(sz: int, layout: str = "fold",
                grid_order: str = "parallel") -> float:
        def f():
            return _ax.nekbone_ax_slab_block_pallas(
                p3, r3, D, D.T, g3, mx, my, mz, beta, n=n, grid=grid,
                sz=sz, interpret=False, acc_dtype=acc_dtype, layout=layout,
                grid_order=grid_order)

        return _timing.measure(f, reps=3, warmup=1)

    return measure


def pick_slab_sz(grid: tuple[int, int, int], n: int, dtype=jnp.float32, *,
                 acc_dtype=None, backend: str | None = None,
                 precond: str | None = None, nrhs: int = 1,
                 measure: Callable[[int], float] | None = None) -> int:
    """Best slabs-per-block for the v2 pipeline on ``grid``, memoized.

    Same measure-on-TPU / heuristic-elsewhere policy as
    :func:`pick_block_e`; cache keys carry the full element grid because
    the slab layout (and the plane side-output sizes) depend on it, plus
    the resolved (storage, accum) dtype pair.  ``precond`` adds a cache-key
    dimension for the PCG update kernels (DESIGN.md §9): the Jacobi update
    holds one extra block array (the operator diagonal) live, so a
    measured pick for the plain pipeline must never be reused for the
    preconditioned one.  ``None`` keeps the pre-precond key shape so
    existing disk caches stay valid.  ``nrhs > 1`` (the multi-RHS block
    kernels, DESIGN.md §12) likewise joins the key — as an ``"rhs:<b>"``
    suffix, so b = 1 keeps the historical key shape — and switches both
    the VMEM heuristic and the measured sweep to the batched kernel.
    """
    dtype = jnp.dtype(dtype)
    backend = backend or jax.default_backend()
    ex, ey, ez = grid
    acc_name = _acc_name(dtype, acc_dtype)
    key = ("slab", n, ex, ey, ez, dtype.name, acc_name, backend)
    if precond is not None:
        key = key + (f"pc:{precond}",)
    if nrhs != 1:
        key = key + (f"rhs:{nrhs}",)
    # as in pick_block_e: VMEM residency is in the accumulation dtype
    size_item = max(dtype.itemsize, jnp.dtype(acc_name).itemsize)

    def pick() -> tuple[int, bool]:
        cands = candidate_slab_sizes(grid, n, itemsize=size_item, nrhs=nrhs)
        m = measure
        if m is None and backend == "tpu":
            if nrhs != 1:
                m = _default_measure_slab_block(grid, n, dtype, nrhs,
                                                acc_dtype)
            else:
                m = _default_measure_slab(grid, n, dtype, acc_dtype)
        if m is None:
            return cands[0], False
        return min(cands, key=m), True

    return _cached_pick(key, pick)


# ---------------------------------------------------------------------------
# s-step slab blocks (v3 matrix-powers pipeline): joint (sz, s) tuning
# ---------------------------------------------------------------------------

def candidate_slab_sizes_sstep(grid: tuple[int, int, int], n: int, s: int,
                               itemsize: int = 4) -> list[int]:
    """Slabs-per-block candidates for the v3 powers kernel, per ``s``.

    The working set is *s-dependent* twice over — the block marches
    ``sz + 2s`` slabs (owned + matrix-powers halo) and keeps the whole
    ``2s+1``-vector basis live alongside the operator temporaries — so the
    VMEM ceiling on ``sz`` shrinks as ``s`` grows and the two knobs must be
    tuned jointly.  ``sz = 1`` stays always viable, as in
    :func:`candidate_slab_sizes`.
    """
    ex, ey, ez = grid
    n3_padded = -(-(n ** 3) // 128) * 128
    live = 2 * s + 1 + 8        # basis vectors + gradients/temporaries
    per_slab = live * ex * ey * n3_padded * max(itemsize, 4)
    max_slabs = max(1, VMEM_BUDGET_BYTES // per_slab)
    sz_max = max(1, max_slabs - 2 * s)
    cands = [c for c in range(ez, 0, -1) if ez % c == 0 and c <= sz_max]
    return cands or [1]


def _default_measure_sstep(grid: tuple[int, int, int], n: int, s: int,
                           dtype, acc_dtype=None) -> Callable[[int], float]:
    """Times the v3 powers kernel on synthetic data for one config."""
    import numpy as np

    from repro.core.geom import box_axis_factors
    from repro.core.sem import derivative_matrix
    from repro.kernels import nekbone_ax as _ax

    ex, ey, ez = grid
    E = ex * ey * ez
    rng = np.random.default_rng(0)
    p2 = jnp.asarray(rng.normal(size=(E, n ** 3)), dtype)
    r2 = jnp.asarray(rng.normal(size=(E, n ** 3)), dtype)
    g3 = jnp.asarray(rng.normal(size=(E, 3, n ** 3)), dtype)
    D = jnp.asarray(derivative_matrix(n), dtype)
    (mx, my, mz), (cx, cy, cz) = box_axis_factors(grid, n)
    mx, my, cx, cy = (jnp.asarray(a, dtype) for a in (mx, my, cx, cy))
    cz = jnp.asarray(cz, dtype)
    acc = _ax._accum(jnp.dtype(dtype), acc_dtype)
    inv_theta = jnp.ones((1, 1), acc)

    def measure(sz: int, layout: str = "fold",
                grid_order: str = "parallel") -> float:
        pext = _ax.sstep_extend_field(p2, grid, sz, s)
        rext = _ax.sstep_extend_field(r2, grid, sz, s)
        gext = _ax.sstep_extend_field(g3, grid, sz, s)
        mzext = _ax.sstep_extend_zfactor(jnp.asarray(mz, dtype), sz, s)

        def f():
            return _ax.nekbone_ax_powers_pallas(
                pext, rext, D, D.T, gext, mx, my, mzext, cx, cy, cz,
                inv_theta, n=n, grid=grid, sz=sz, s=s, interpret=False,
                acc_dtype=acc_dtype, layout=layout, grid_order=grid_order)

        return _timing.measure(f, reps=3, warmup=1)

    return measure


def pick_slab_sz_sstep(grid: tuple[int, int, int], n: int, s: int,
                       dtype=jnp.float32, *, acc_dtype=None,
                       backend: str | None = None,
                       measure: Callable[[int], float] | None = None) -> int:
    """Best slabs-per-block for the v3 powers kernel at a given ``s``.

    Same measure-on-TPU / heuristic-elsewhere policy as
    :func:`pick_slab_sz`; the cache key gains ``s`` as a dimension — the
    halo depth and the live basis count both scale with it, so a pick for
    one ``s`` must never be reused for another.
    """
    dtype = jnp.dtype(dtype)
    backend = backend or jax.default_backend()
    ex, ey, ez = grid
    acc_name = _acc_name(dtype, acc_dtype)
    key = ("sstep", n, ex, ey, ez, s, dtype.name, acc_name, backend)
    size_item = max(dtype.itemsize, jnp.dtype(acc_name).itemsize)

    def pick() -> tuple[int, bool]:
        cands = candidate_slab_sizes_sstep(grid, n, s, itemsize=size_item)
        m = measure
        if m is None and backend == "tpu":
            m = _default_measure_sstep(grid, n, s, dtype, acc_dtype)
        if m is None:
            return cands[0], False
        return min(cands, key=m), True

    return _cached_pick(key, pick)


# ---------------------------------------------------------------------------
# Chebyshev-apply slab blocks (precond pipeline): halo'd like the v3 powers
# kernel, but the live set is the recurrence vectors (r, d, res, z) plus the
# operator temporaries — no 2s+1 basis, so the VMEM ceiling is looser
# ---------------------------------------------------------------------------

def candidate_slab_sizes_cheb(grid: tuple[int, int, int], n: int, k: int,
                              itemsize: int = 4) -> list[int]:
    """Slabs-per-block candidates for the Chebyshev-apply kernel, per ``k``.

    The block marches ``sz + 2k`` slabs (owned + the matrix-powers halo of
    the k chained applications, DESIGN.md §9.3) and keeps ~12 slab-sized
    arrays live (r, d, res, z + the operator gradients/temporaries), so
    the ceiling on ``sz`` shrinks with ``k`` like the v3 kernel's does
    with ``s``.  ``sz = 1`` stays always viable.
    """
    ex, ey, ez = grid
    n3_padded = -(-(n ** 3) // 128) * 128
    live = 12
    per_slab = live * ex * ey * n3_padded * max(itemsize, 4)
    max_slabs = max(1, VMEM_BUDGET_BYTES // per_slab)
    sz_max = max(1, max_slabs - 2 * k)
    cands = [c for c in range(ez, 0, -1) if ez % c == 0 and c <= sz_max]
    return cands or [1]


def _default_measure_cheb(grid: tuple[int, int, int], n: int, k: int,
                          dtype, acc_dtype=None) -> Callable[[int], float]:
    """Times the Chebyshev-apply kernel on synthetic data per config."""
    import numpy as np

    from repro.core.geom import box_axis_factors
    from repro.core.sem import derivative_matrix
    from repro.kernels import nekbone_ax as _ax

    ex, ey, ez = grid
    E = ex * ey * ez
    rng = np.random.default_rng(0)
    r2 = jnp.asarray(rng.normal(size=(E, n ** 3)), dtype)
    g3 = jnp.asarray(rng.normal(size=(E, 3, n ** 3)), dtype)
    D = jnp.asarray(derivative_matrix(n), dtype)
    (mx, my, mz), (cx, cy, cz) = box_axis_factors(grid, n)
    mx, my, cx, cy = (jnp.asarray(a, dtype) for a in (mx, my, cx, cy))
    cz = jnp.asarray(cz, dtype)
    acc = _ax._accum(jnp.dtype(dtype), acc_dtype)
    coef = jnp.ones((k + 1, 2), acc)

    def measure(sz: int, layout: str = "fold",
                grid_order: str = "parallel") -> float:
        rext = _ax.sstep_extend_field(r2, grid, sz, k)
        gext = _ax.sstep_extend_field(g3, grid, sz, k)
        mzext = _ax.sstep_extend_zfactor(jnp.asarray(mz, dtype), sz, k)

        def f():
            return _ax.nekbone_cheb_apply_pallas(
                rext, D, D.T, gext, mx, my, mzext, cx, cy, cz, coef,
                n=n, grid=grid, sz=sz, k=k, interpret=False,
                acc_dtype=acc_dtype, layout=layout, grid_order=grid_order)

        return _timing.measure(f, reps=3, warmup=1)

    return measure


def pick_slab_sz_cheb(grid: tuple[int, int, int], n: int, k: int,
                      dtype=jnp.float32, *, acc_dtype=None,
                      backend: str | None = None,
                      measure: Callable[[int], float] | None = None) -> int:
    """Best slabs-per-block for the Chebyshev-apply kernel at order ``k``.

    Same measure-on-TPU / heuristic-elsewhere policy as
    :func:`pick_slab_sz_sstep`; the cache key carries ``k`` (the precond
    dimension) — halo depth scales with it, so a pick for one order must
    never be reused for another.
    """
    dtype = jnp.dtype(dtype)
    backend = backend or jax.default_backend()
    ex, ey, ez = grid
    acc_name = _acc_name(dtype, acc_dtype)
    key = ("cheb", n, ex, ey, ez, k, dtype.name, acc_name, backend)
    size_item = max(dtype.itemsize, jnp.dtype(acc_name).itemsize)

    def pick() -> tuple[int, bool]:
        cands = candidate_slab_sizes_cheb(grid, n, k, itemsize=size_item)
        m = measure
        if m is None and backend == "tpu":
            m = _default_measure_cheb(grid, n, k, dtype, acc_dtype)
        if m is None:
            return cands[0], False
        return min(cands, key=m), True

    return _cached_pick(key, pick)


# ---------------------------------------------------------------------------
# joint (contraction layout x slab sz x grid order) configs — the
# measured-time sweep (DESIGN.md §11).  One pick per (backend/arch, case
# key, precision policy, precond), persisted like the sz-only picks above.
# ---------------------------------------------------------------------------

def candidate_configs(sz_cands: list[int]) -> list[tuple[int, str, str]]:
    """The joint sweep space: every (sz, layout, grid_order) triple.

    Ordered sz-major with the historical (fold, parallel) point first per
    sz, so a measured tie keeps the established configuration.
    """
    from repro.kernels.nekbone_ax import GRID_ORDERS, LAYOUTS

    return [(sz, ly, go) for sz in sz_cands
            for ly in LAYOUTS for go in GRID_ORDERS]


def _pick_config(key: tuple, sz_cands: list[int], measure,
                 default_measure_factory, backend: str):
    """Shared joint-config selection: measured sweep on TPU (or with an
    explicit ``measure(sz, layout, grid_order)``), else the heuristic
    (largest-fitting sz, fold, parallel) — the pre-sweep configuration."""
    def pick() -> tuple:
        m = measure
        if m is None and backend == "tpu":
            m = default_measure_factory()
        if m is None:
            return (sz_cands[0], "fold", "parallel"), False
        cands = candidate_configs(sz_cands)
        return min(cands, key=lambda c: m(*c)), True

    return _cached_pick(key, pick)


def pick_slab_config(grid: tuple[int, int, int], n: int, dtype=jnp.float32,
                     *, acc_dtype=None, backend: str | None = None,
                     precond: str | None = None, nrhs: int = 1,
                     measure=None) -> tuple[int, str, str]:
    """Best ``(sz, layout, grid_order)`` for the v2 slab kernel, memoized.

    The joint analog of :func:`pick_slab_sz`: on a TPU backend (or with an
    explicit ``measure``) every (slab size x contraction layout x grid
    iteration order) point is timed and the fastest wins; elsewhere the
    heuristic keeps the historical (fold, parallel) configuration at the
    VMEM-ceiling sz.  Keys use a new ``("cfg", "slab", ...)`` kind so
    sz-only picks (and their persisted caches) are never aliased.
    ``nrhs`` joins the key and the sweep exactly as in
    :func:`pick_slab_sz` (the RHS batch changes both the VMEM footprint
    and the measured optimum).
    """
    dtype = jnp.dtype(dtype)
    backend = backend or jax.default_backend()
    ex, ey, ez = grid
    acc_name = _acc_name(dtype, acc_dtype)
    key = ("cfg", "slab", n, ex, ey, ez, dtype.name, acc_name, backend)
    if precond is not None:
        key = key + (f"pc:{precond}",)
    if nrhs != 1:
        key = key + (f"rhs:{nrhs}",)
    size_item = max(dtype.itemsize, jnp.dtype(acc_name).itemsize)
    sz_cands = candidate_slab_sizes(grid, n, itemsize=size_item, nrhs=nrhs)
    if nrhs != 1:
        factory = lambda: _default_measure_slab_block(  # noqa: E731
            grid, n, dtype, nrhs, acc_dtype)
    else:
        factory = lambda: _default_measure_slab(  # noqa: E731
            grid, n, dtype, acc_dtype)
    return _pick_config(key, sz_cands, measure, factory, backend)


def pick_sstep_config(grid: tuple[int, int, int], n: int, s: int,
                      dtype=jnp.float32, *, acc_dtype=None,
                      backend: str | None = None,
                      measure=None) -> tuple[int, str, str]:
    """Best ``(sz, layout, grid_order)`` for the v3 powers kernel at ``s``."""
    dtype = jnp.dtype(dtype)
    backend = backend or jax.default_backend()
    ex, ey, ez = grid
    acc_name = _acc_name(dtype, acc_dtype)
    key = ("cfg", "sstep", n, ex, ey, ez, s, dtype.name, acc_name, backend)
    size_item = max(dtype.itemsize, jnp.dtype(acc_name).itemsize)
    sz_cands = candidate_slab_sizes_sstep(grid, n, s, itemsize=size_item)
    return _pick_config(
        key, sz_cands, measure,
        lambda: _default_measure_sstep(grid, n, s, dtype, acc_dtype), backend)


def pick_cheb_config(grid: tuple[int, int, int], n: int, k: int,
                     dtype=jnp.float32, *, acc_dtype=None,
                     backend: str | None = None,
                     measure=None) -> tuple[int, str, str]:
    """Best ``(sz, layout, grid_order)`` for the Chebyshev-apply kernel."""
    dtype = jnp.dtype(dtype)
    backend = backend or jax.default_backend()
    ex, ey, ez = grid
    acc_name = _acc_name(dtype, acc_dtype)
    key = ("cfg", "cheb", n, ex, ey, ez, k, dtype.name, acc_name, backend)
    size_item = max(dtype.itemsize, jnp.dtype(acc_name).itemsize)
    sz_cands = candidate_slab_sizes_cheb(grid, n, k, itemsize=size_item)
    return _pick_config(
        key, sz_cands, measure,
        lambda: _default_measure_cheb(grid, n, k, dtype, acc_dtype), backend)


# ---------------------------------------------------------------------------
# pipeline dispatch (NekboneCase ax_impl="auto"): measured-fastest pipeline
# per (backend, case key), with a documented E-threshold fallback
# ---------------------------------------------------------------------------

# Below this element count the v2 two-kernel slab pipeline loses to the v1
# single-call kernel on every backend we have measured: v2's fixed
# per-iteration overhead (a second pallas dispatch + the boundary-plane
# stitch between them) is amortized over E elements, and under ~16
# elements the amortization no longer covers it — the ROADMAP-cited
# E=8 inversion (3206 us v2 vs 2596 us v1 on the quick backend).  The
# heuristic only applies where wall time cannot be measured honestly
# (non-TPU backends run kernels in interpret mode); on TPU the dispatch is
# measured and cached instead.
AUTO_V2_MIN_E = 16


def _default_measure_pipeline(grid: tuple[int, int, int], n: int, dtype,
                              acc_dtype=None) -> Callable[[str], float]:
    """Times one fixed CG iteration of a full pipeline on the real case
    shape (manufactured solution, same setup as the benches)."""
    from repro.core import cg_fused as _cg
    from repro.core.nekbone import NekboneCase

    case = NekboneCase(n=n, grid=grid, dtype=dtype)
    _, b = case.manufactured()

    def measure(pipeline: str) -> float:
        if pipeline == "pallas_fused_cg_v2":
            def f():
                return _cg.cg_fused_v2_fixed_iters(
                    b, D=case.D, g=case.g, grid=grid, niter=1,
                    mask=case.mask, c=case.c).x
        else:
            def f():
                return _cg.cg_fused_fixed_iters(
                    b, D=case.D, g=case.g, mask=case.mask, c=case.c,
                    grid=grid, niter=1).x

        return _timing.measure(f, reps=3, warmup=1)

    return measure


def pick_pipeline(grid: tuple[int, int, int], n: int, dtype=jnp.float32, *,
                  acc_dtype=None, backend: str | None = None,
                  precond: str | None = None, measure=None) -> str:
    """The measured-fastest fused-CG pipeline for a case, memoized.

    Returns an ``ax_impl`` name: ``"pallas_fused_cg"`` (v1) or
    ``"pallas_fused_cg_v2"``.  Preconditioned cases always resolve to v2 —
    the fused PCG drivers only exist there (DESIGN.md §9).  On TPU (or
    with an explicit ``measure(pipeline) -> seconds``) both pipelines are
    timed on the real case shape and the faster wins, persisted per
    backend; elsewhere the documented :data:`AUTO_V2_MIN_E` threshold
    decides (small E -> v1, the amortization argument above).
    """
    dtype = jnp.dtype(dtype)
    backend = backend or jax.default_backend()
    ex, ey, ez = grid
    if precond is not None:
        return "pallas_fused_cg_v2"
    acc_name = _acc_name(dtype, acc_dtype)
    key = ("pipeline", n, ex, ey, ez, dtype.name, acc_name, backend)

    def pick() -> tuple:
        m = measure
        if m is None and backend == "tpu":
            m = _default_measure_pipeline(grid, n, dtype, acc_dtype)
        if m is None:
            small = ex * ey * ez < AUTO_V2_MIN_E
            return ("pallas_fused_cg" if small
                    else "pallas_fused_cg_v2"), False
        cands = ("pallas_fused_cg", "pallas_fused_cg_v2")
        return min(cands, key=m), True

    return _cached_pick(key, pick)


# ---------------------------------------------------------------------------
# cache maintenance
# ---------------------------------------------------------------------------

def clear_cache(*, disk: bool = True) -> None:
    """Forget all memoized selections; also removes the disk cache unless
    ``disk=False`` (tests use that to exercise the reload path)."""
    global _DISK_LOADED
    with _LOCK:
        _CACHE.clear()
        _MEASURED.clear()
        _DISK_LOADED = False           # next pick re-merges the file, if any
        if disk:
            try:
                cache_path().unlink(missing_ok=True)
            except Exception:
                pass


def cache_info() -> dict[tuple, int]:
    """Snapshot of the memoized selections (for tests / diagnostics)."""
    with _LOCK:
        return dict(_CACHE)
