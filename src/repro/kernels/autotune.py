"""block_e selection for the nekbone Ax kernels, with an in-process cache.

The element block size is the kernel family's one tuning knob: it trades
VMEM residency (larger blocks amortize the grid and give the MXU taller
``e*n^2 x n`` operands) against the double-buffering headroom the pipeline
needs.  Selection strategy:

* **Heuristic floor** (:func:`vmem_block_e`): largest power-of-two block
  whose ~14-array working set fits a VMEM budget (default 8 MiB of the
  ~16 MiB/core), further halved until it divides ``E``.  This is exact
  enough off-TPU, where kernels only run in interpret mode and wall time is
  meaningless.
* **Measurement** (:func:`pick_block_e` on a TPU backend): times the real
  kernel over the power-of-two candidates below the heuristic ceiling and
  keeps the fastest — the empirical analog of the paper's per-architecture
  tuning sweep (its Table 1 re-tunes the CUDA kernel per GPU generation).

Results are memoized in a process-wide cache keyed on
``(n, E, dtype, backend)`` so steady-state callers (one ``pallas_call`` per
CG iteration) never re-tune.  ``clear_cache`` exists for tests.
"""
from __future__ import annotations

import threading
from typing import Callable

import jax
import jax.numpy as jnp

__all__ = ["vmem_block_e", "pick_block_e", "candidate_blocks", "clear_cache",
           "cache_info"]

_CACHE: dict[tuple, int] = {}
_LOCK = threading.Lock()

VMEM_BUDGET_BYTES = 8 * 2 ** 20


def vmem_block_e(E: int, n: int,
                 vmem_budget_bytes: int = VMEM_BUDGET_BYTES,
                 itemsize: int = 4) -> int:
    """Largest power-of-two element block whose working set fits the budget.

    The kernel keeps ~14 block-sized arrays live (u, w, 6 metric fields,
    3 gradients + 3 temporaries) in the accumulation dtype (f32, or f64 on
    the fp64 oracle path); lanes pad n^3 up to a multiple of 128.
    """
    n3_padded = -(-(n ** 3) // 128) * 128
    per_elem = 14 * n3_padded * max(itemsize, 4)
    be = max(1, vmem_budget_bytes // per_elem)
    be = 1 << (be.bit_length() - 1)            # floor to power of two
    while be > 1 and E % be:
        be //= 2
    return be


def candidate_blocks(E: int, n: int, itemsize: int = 4) -> list[int]:
    """Power-of-two candidates (descending) from the VMEM ceiling down to 1,
    keeping only divisors of ``E`` so no padding is introduced."""
    ceil = vmem_block_e(E, n, itemsize=itemsize)
    cands = []
    be = ceil
    while be >= 1:
        if E % be == 0:
            cands.append(be)
        be //= 2
    return cands or [1]


def _default_measure(E: int, n: int, dtype) -> Callable[[int], float]:
    """Times the real Ax kernel on synthetic data for one block size."""
    import time

    import numpy as np

    from repro.core.sem import derivative_matrix
    from repro.kernels import nekbone_ax as _ax

    rng = np.random.default_rng(0)
    u2 = jnp.asarray(rng.normal(size=(E, n ** 3)), dtype)
    g2 = jnp.asarray(rng.normal(size=(E, 6, n ** 3)), dtype)
    D = jnp.asarray(derivative_matrix(n), dtype)
    Dt = D.T

    def measure(block_e: int) -> float:
        f = lambda: _ax.nekbone_ax_pallas(u2, D, Dt, g2, n=n,
                                          block_e=block_e, interpret=False)
        jax.block_until_ready(f())             # compile + warm
        t0 = time.perf_counter()
        for _ in range(3):
            out = f()
        jax.block_until_ready(out)
        return (time.perf_counter() - t0) / 3

    return measure


def pick_block_e(E: int, n: int, dtype=jnp.float32, *,
                 backend: str | None = None,
                 measure: Callable[[int], float] | None = None) -> int:
    """Best ``block_e`` for ``(E, n, dtype)`` on ``backend``, memoized.

    On a TPU backend (or when an explicit ``measure`` callable is supplied)
    the candidates are timed and the fastest wins; elsewhere the VMEM
    heuristic decides directly — interpret-mode wall time reflects the
    emulator, not the hardware, so measuring it would tune for noise.
    """
    dtype = jnp.dtype(dtype)
    backend = backend or jax.default_backend()
    key = (n, E, dtype.name, backend)
    with _LOCK:
        if key in _CACHE:
            return _CACHE[key]

    cands = candidate_blocks(E, n, itemsize=dtype.itemsize)
    if measure is None and backend == "tpu":
        measure = _default_measure(E, n, dtype)
    if measure is None:
        best = cands[0]
    else:
        best = min(cands, key=measure)

    with _LOCK:
        _CACHE.setdefault(key, best)
        return _CACHE[key]


def clear_cache() -> None:
    with _LOCK:
        _CACHE.clear()


def cache_info() -> dict[tuple, int]:
    """Snapshot of the memoized selections (for tests / diagnostics)."""
    with _LOCK:
        return dict(_CACHE)
