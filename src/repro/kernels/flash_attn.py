"""Block online-softmax (flash) attention forward kernel for TPU.

The prefill hot-spot for the dense-transformer architectures.  The paper's
locality insight maps directly: the small per-row state (running max ``m``,
normalizer ``l``, output accumulator) stays resident in VMEM while KV blocks
stream past — the KV-sequence axis plays the role of the paper's ``k`` layer
axis.

Supports GQA (q heads grouped over kv heads), causal masking, sliding
window, logit soft-capping (gemma2), and a ``q_offset`` for chunked prefill.

Forward-only: the training path uses the XLA chunked implementation in
``models/attention.py`` (differentiable, memory-bound-optimal); this kernel
serves inference prefill where no VJP is needed.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.compat import CompilerParams as _CompilerParams

__all__ = ["flash_attention"]

_NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                 scale: float, causal: bool, window: int | None,
                 softcap: float | None, q_offset: int, block_q: int,
                 block_k: int, kv_len: int, nk: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)            # (bq, d)
    k = k_ref[0].astype(jnp.float32)            # (bk, d)
    v = v_ref[0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)

    qpos = q_offset + iq * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    kpos = ik * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    mask = kpos < kv_len                         # KV padding
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= qpos - kpos < window
    s = jnp.where(mask, s, _NEG_INF)

    m_prev = m_ref[:, 0:1]                       # (bq, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    p = jnp.where(mask, p, 0.0)
    corr = jnp.exp(m_prev - m_new)               # (bq, 1)
    l_new = l_ref[:, 0:1] * corr + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(ik == nk - 1)
    def _finish():
        l = l_ref[:, 0:1]
        l = jnp.where(l == 0.0, 1.0, l)          # fully-masked (padded) rows
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "scale", "window", "softcap",
                              "q_offset", "block_q", "block_k", "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, scale: float | None = None,
                    window: int | None = None, softcap: float | None = None,
                    q_offset: int = 0, block_q: int = 512, block_k: int = 512,
                    interpret: bool = False) -> jnp.ndarray:
    """q: (B, Hq, Sq, d); k, v: (B, Hkv, Skv, d); returns (B, Hq, Sq, d)."""
    B, Hq, Sq, d = q.shape
    _, Hkv, Skv, _ = k.shape
    assert Hq % Hkv == 0, (Hq, Hkv)
    group = Hq // Hkv
    scale = float(d ** -0.5) if scale is None else float(scale)

    bq = min(block_q, max(Sq, 8))
    bk = min(block_k, max(Skv, 8))
    pad_q = (-Sq) % bq
    pad_k = (-Skv) % bk
    qf = q.reshape(B * Hq, Sq, d)
    kf = k.reshape(B * Hkv, Skv, d)
    vf = v.reshape(B * Hkv, Skv, d)
    if pad_q:
        qf = jnp.pad(qf, ((0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        kf = jnp.pad(kf, ((0, 0), (0, pad_k), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, pad_k), (0, 0)))
    nq = (Sq + pad_q) // bq
    nk = (Skv + pad_k) // bk

    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, q_offset=q_offset, block_q=bq, block_k=bk,
        kv_len=Skv, nk=nk)

    out = pl.pallas_call(
        kernel,
        grid=(B * Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, bk, d),
                         lambda bh, iq, ik, g=group: (bh // g, ik, 0)),
            pl.BlockSpec((1, bk, d),
                         lambda bh, iq, ik, g=group: (bh // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hq, Sq + pad_q, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 128), jnp.float32),   # running max m
            pltpu.VMEM((bq, 128), jnp.float32),   # normalizer l
            pltpu.VMEM((bq, d), jnp.float32),     # output accumulator
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name=f"flash_attn_bq{bq}_bk{bk}",
    )(qf, kf, vf)
    out = out[:, :Sq, :]
    return out.reshape(B, Hq, Sq, d)
