"""Pallas-TPU kernels for the Nekbone local Poisson operator (paper §IV-C).

This is the paper's optimized ``Ax`` kernel re-derived for the TPU memory
hierarchy (DESIGN.md §2).  The CUDA version marches an ``n x n`` thread layer
through the element's k-layers keeping the derivative matrix in shared memory
and per-thread columns in registers; the TPU version instead keeps a *block
of elements* fully resident in VMEM and folds the element/layer axes into the
M dimension of skinny matmuls so the MXU sees large, lane-aligned operands.

Both contraction stages and the metric application are fused into one kernel:
``u`` and the six metric fields are read from HBM exactly once and only ``w``
is written — the 7-read/1-write traffic floor of the operator (the paper's
Eq. 2 counts 24+6 streams for the *whole CG iteration*; the operator itself
is 7+1).

Two kernels share the block math (:func:`ax_block`):

* :func:`nekbone_ax_kernel` — the plain fused operator (the Fig. 2/3 ladder's
  top rung), 7 reads / 1 write.
* :func:`nekbone_ax_dots_kernel` — the fused *CG-iteration* kernel
  (DESIGN.md §3): in the same VMEM residency it also applies the Dirichlet
  mask and emits per-block partial sums for the two weighted inner products
  a CG iteration needs (``p·c·Ap`` and ``r·c·z``), so the separate reduction
  passes Eq. 2 charges for disappear from the HBM budget.  The ``p·c·Ap``
  partial uses the continuity identity (DESIGN.md §3.2): for a continuous
  ``p``, ``p·c·(mask · gs(w)) == Σ_j p_j (mask·w)_j`` element-locally, so no
  assembled ``w`` is needed inside the kernel.

HBM layout: callers pass natural ``(E, n, n, n)`` arrays; the wrapper
(`ops.nekbone_ax`) reshapes them (free, row-major) to ``(E, n^3)`` /
``(E, 6, n^3)`` so the minor dimension is ~n^3 (lane padding 1000 -> 1024,
2.4 % waste) instead of ``n`` (10 -> 128, 12.8x waste).

The kernels are generic in ``n`` (tested 2..16) and in the element block size
``block_e`` — the TPU analog of the paper's claim that the 2-D-thread kernel
is "not bound by shared memory" and ports across polynomial degrees "by only
changing a few constants".
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["LAYOUTS", "GRID_ORDERS",
           "nekbone_ax_kernel", "nekbone_ax_pallas", "ax_block",
           "ax_block_diag", "nekbone_ax_dots_kernel", "nekbone_ax_dots_pallas",
           "nekbone_ax_pap_kernel", "nekbone_ax_pap_pallas",
           "nekbone_ax_slab_kernel", "nekbone_ax_slab_pallas",
           "nekbone_cg_update_kernel", "nekbone_cg_update_pallas",
           "nekbone_ax_powers_kernel", "nekbone_ax_powers_pallas",
           "nekbone_sstep_update_kernel", "nekbone_sstep_update_pallas",
           "sstep_extend_field", "sstep_extend_zfactor",
           "nekbone_pcg_update_kernel", "nekbone_pcg_update_pallas",
           "nekbone_cheb_apply_kernel", "nekbone_cheb_apply_pallas",
           "nekbone_interp_kernel", "nekbone_interp_pallas"]

from repro.compat import CompilerParams as _CompilerParams
from repro.core.geom import box_outer as _box_outer


def _accum(dtype, acc_dtype: str | None) -> jnp.dtype:
    """In-kernel accumulation dtype for a given storage dtype.

    ``acc_dtype`` is the precision policy's explicit choice (DESIGN.md §7);
    ``None`` keeps the historical rule — f64 accumulates in f64 (the CPU
    oracle path), every narrower storage dtype (f32, bf16) in f32.  The
    kernels upcast operands to this dtype on load and downcast field
    outputs on store, so storage precision never touches the contraction
    or reduction arithmetic.
    """
    if acc_dtype is not None:
        return jnp.dtype(acc_dtype)
    return jnp.dtype(jnp.float64 if dtype == jnp.float64 else jnp.float32)


def _acc_tag(acc_dtype: str | None) -> str:
    """Kernel-name suffix for an explicit accumulation dtype."""
    return "" if acc_dtype is None else f"_acc{jnp.dtype(acc_dtype).name}"


def _dot(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """2-D matmul; accumulate in the (already upcast) operand dtype — f32 on
    the MXU, f64 on the interpret-mode oracle path."""
    acc = jnp.float64 if a.dtype == jnp.float64 else jnp.float32
    return jax.lax.dot(a, b, preferred_element_type=acc)


# Selectable contraction layouts for the per-layer tensor products (the
# static ``layout`` kernel parameter; autotune sweeps them per backend):
#
#   fold — fold (e, plane) axes into the M dimension of a skinny 2-D matmul
#          (e*n^2, n) x (n, n), transposing operands into position first
#          (the historical order; one dot shape for all three directions).
#   dng  — batched ``dot_general`` directly on the 4-D block, contracting
#          the needed axis in place against the supplied matrix's *rows*;
#          only the *output* is transposed into (e,k,j,i) order.
#   dnt  — ``dot_general`` on the 4-D block contracting against the *other*
#          orientation of the derivative matrix along its *columns*
#          (flipped dimension numbers).  Both D and Dt are VMEM-resident in
#          every kernel, so this needs no operand transposes at all — the
#          matrix unit just sees the opposite operand orientation.
#
# Every layout computes each output element as the *same* length-n dot
# product with the contraction kept innermost, so results are
# bitwise-identical at fp64 (gated by tests/test_kernels_ax.py); only the
# operand orientation the backend's matrix units see differs.  (A true
# matrix-on-LHS placement is *not* offered: XLA reassociates that GEMM and
# breaks bitwise parity, which the parity gate would reject.)
LAYOUTS = ("fold", "dng", "dnt")

# Grid-iteration-order knob for the slab-family pallas_calls: "parallel"
# declares the (1-D) slab grid embarrassingly parallel (the historical
# setting — lets Mosaic reorder/overlap block iterations), "arbitrary"
# forces sequential issue order (can win when the slab working set thrashes
# a shared cache level).  Swept jointly with (layout, sz) by autotune.
GRID_ORDERS = ("parallel", "arbitrary")


def _cfg_tag(layout: str, grid_order: str = "parallel") -> str:
    """Kernel-name suffix for a non-default (layout, grid order) config."""
    tag = "" if layout == "fold" else f"_ly{layout}"
    if grid_order != "parallel":
        tag += f"_go{grid_order}"
    return tag


def _dg(a: jnp.ndarray, m: jnp.ndarray, axis: int,
        maxis: int = 0) -> jnp.ndarray:
    """``dot_general`` contracting ``a``'s ``axis`` with matrix ``m``'s
    ``maxis``; output dims = a's free dims (in order) + m's free dim last."""
    acc = jnp.float64 if a.dtype == jnp.float64 else jnp.float32
    return jax.lax.dot_general(a, m, (((axis,), (maxis,)), ((), ())),
                               preferred_element_type=acc)


def _grad3(u: jnp.ndarray, Dt: jnp.ndarray, *, n: int, e: int,
           layout: str = "fold", D: jnp.ndarray | None = None):
    """Forward reference-space gradient on a VMEM block: (wr, ws, wt).

    Folds (e,k,j) / (e,k,i) / (e,j,i) into the M dimension of skinny matmuls
    so the MXU sees (e*n^2, n) x (n, n) operands (``layout="fold"``), or
    contracts the 4-D block in place via ``dot_general`` (``"dng"`` /
    ``"dnt"`` — see ``LAYOUTS``; ``"dnt"`` contracts against ``D`` along its
    columns and needs it passed in).
    """
    if layout in ("dng", "dnt"):
        u4 = u.reshape(e, n, n, n)
        m, maxis = (Dt, 0) if layout == "dng" else (D, 1)
        # wr[e,k,j,i] = sum_l u[e,k,j,l] Dt[l,i] — contract in place.
        wr = _dg(u4, m, 3, maxis)
        # ws[e,k,j,i] = sum_l u[e,k,l,i] Dt[l,j] -> (e,k,i,j), swap back.
        ws = _dg(u4, m, 2, maxis).transpose(0, 1, 3, 2)
        # wt[e,k,j,i] = sum_l u[e,l,j,i] Dt[l,k] -> (e,j,i,k), rotate back.
        wt = _dg(u4, m, 1, maxis).transpose(0, 3, 1, 2)
        return wr, ws, wt
    # wr[e,k,j,i] = sum_l u[e,k,j,l] D[i,l]      (M = e*n^2, K = n, N = n)
    wr = _dot(u.reshape(e * n * n, n), Dt).reshape(e, n, n, n)
    # ws[e,k,j,i] = sum_l u[e,k,l,i] D[j,l]: transpose j<->i, contract, undo.
    u_kij = u.reshape(e, n, n, n).transpose(0, 1, 3, 2)  # (e,k,i,l=j)
    ws = _dot(u_kij.reshape(e * n * n, n), Dt)
    ws = ws.reshape(e, n, n, n).transpose(0, 1, 3, 2)
    # wt[e,k,j,i] = sum_l u[e,l,j,i] D[k,l]: contract the layer axis.
    u_jil = u.reshape(e, n, n * n).transpose(0, 2, 1)    # (e, ji, l=k)
    wt = _dot(u_jil.reshape(e * n * n, n), Dt)
    wt = wt.reshape(e, n * n, n).transpose(0, 2, 1).reshape(e, n, n, n)
    return wr, ws, wt


def _grad3_t(ur: jnp.ndarray, us: jnp.ndarray, ut: jnp.ndarray,
             D: jnp.ndarray, *, n: int, e: int, layout: str = "fold",
             Dt: jnp.ndarray | None = None) -> jnp.ndarray:
    """Transposed gradient (weak-form assembly) on a VMEM block, (e, n^3).

    The three contributions are summed in the same order under every
    ``layout`` (fold order), so the reduction rounding is layout-invariant.
    ``"dnt"`` contracts against ``Dt`` along its columns (Dt[i,l] = D[l,i])
    and needs it passed in.
    """
    if layout in ("dng", "dnt"):
        m, maxis = (D, 0) if layout == "dng" else (Dt, 1)
        w = _dg(ur, m, 3, maxis)
        w += _dg(us, m, 2, maxis).transpose(0, 1, 3, 2)
        w += _dg(ut, m, 1, maxis).transpose(0, 3, 1, 2)
        return w.reshape(e, n ** 3)
    # w += sum_l D[l,i] ur[e,k,j,l]  ==  ur @ D
    w = _dot(ur.reshape(e * n * n, n), D).reshape(e, n, n, n)
    us_kij = us.transpose(0, 1, 3, 2)
    w += _dot(us_kij.reshape(e * n * n, n), D).reshape(e, n, n, n).transpose(0, 1, 3, 2)
    ut_jil = ut.reshape(e, n, n * n).transpose(0, 2, 1)
    wt2 = _dot(ut_jil.reshape(e * n * n, n), D)
    w += wt2.reshape(e, n * n, n).transpose(0, 2, 1).reshape(e, n, n, n)
    return w.reshape(e, n ** 3)


def ax_block(u: jnp.ndarray, D: jnp.ndarray, Dt: jnp.ndarray,
             g: jnp.ndarray, *, n: int, e: int) -> jnp.ndarray:
    """Block math of  w = D^T ( G (D u) )  on VMEM-resident arrays.

    Args:
      u: (e, n^3) nodal values for one block of ``e`` elements.
      D/Dt: (n, n) derivative matrix and its transpose.
      g: (e, 6, n^3) metric (rr, rs, rt, ss, st, tt).
    Returns (e, n^3), in the accumulation dtype of ``u``.
    """
    wr, ws, wt = _grad3(u, Dt, n=n, e=e)

    # ---- metric application (element-wise, VPU) ---------------------------
    grr, grs, grt, gss, gst, gtt = (
        g[:, m, :].reshape(e, n, n, n) for m in range(6))
    ur = grr * wr + grs * ws + grt * wt
    us = grs * wr + gss * ws + gst * wt
    ut = grt * wr + gst * ws + gtt * wt

    return _grad3_t(ur, us, ut, D, n=n, e=e)


def ax_block_diag(u: jnp.ndarray, D: jnp.ndarray, Dt: jnp.ndarray,
                  g3: jnp.ndarray, *, n: int, e: int,
                  layout: str = "fold") -> jnp.ndarray:
    """``ax_block`` for a *diagonal* metric (axis-aligned box elements).

    For the structured box mesh the off-diagonal metric entries are
    identically zero (core/geom.py), so the metric application collapses to
    three products and ``G`` to three HBM streams instead of six — half the
    metric traffic of the general kernel, with bit-identical results (adding
    an exactly-zero product is exact in floating point).

    Args:
      u: (e, n^3); g3: (e, 3, n^3) metric diagonal (rr, ss, tt).
    """
    wr, ws, wt = _grad3(u, Dt, n=n, e=e, layout=layout, D=D)
    grr, gss, gtt = (g3[:, m, :].reshape(e, n, n, n) for m in range(3))
    return _grad3_t(grr * wr, gss * ws, gtt * wt, D, n=n, e=e, layout=layout,
                    Dt=Dt)


def nekbone_ax_kernel(u_ref, d_ref, dt_ref, g_ref, w_ref, *, n: int,
                      block_e: int, acc_dtype: str | None = None):
    """Fused  w = D^T ( G (D u) )  for one block of ``block_e`` elements.

    Refs (VMEM blocks):
      u_ref:  (block_e, n^3)    nodal values
      d_ref:  (n, n)            derivative matrix D (dxm1)
      dt_ref: (n, n)            D^T (dxtm1) — passed separately so the kernel
                                body issues only layout-friendly matmuls
      g_ref:  (block_e, 6, n^3) metric (rr, rs, rt, ss, st, tt)
      w_ref:  (block_e, n^3)    output

    ``acc_dtype``: explicit accumulation dtype (precision policy); operands
    are upcast on load, the output downcast to ``w_ref``'s storage dtype.
    """
    f32 = _accum(u_ref.dtype, acc_dtype)
    u = u_ref[...].astype(f32)
    D = d_ref[...].astype(f32)
    Dt = dt_ref[...].astype(f32)
    g = g_ref[...].astype(f32)
    w = ax_block(u, D, Dt, g, n=n, e=block_e)
    w_ref[...] = w.astype(w_ref.dtype)


@functools.partial(jax.jit, static_argnames=("n", "block_e", "interpret",
                                             "acc_dtype"))
def nekbone_ax_pallas(u2: jnp.ndarray, D: jnp.ndarray, Dt: jnp.ndarray,
                      g2: jnp.ndarray, *, n: int, block_e: int,
                      interpret: bool = False,
                      acc_dtype: str | None = None) -> jnp.ndarray:
    """pallas_call wrapper on pre-flattened operands.

    Args:
      u2: (E, n^3), g2: (E, 6, n^3), D/Dt: (n, n); E divisible by block_e.
      acc_dtype: explicit in-kernel accumulation dtype name (default: the
        storage-derived rule of :func:`_accum`).
    """
    E = u2.shape[0]
    assert E % block_e == 0, (E, block_e)
    n3 = n ** 3
    grid = (E // block_e,)
    return pl.pallas_call(
        functools.partial(nekbone_ax_kernel, n=n, block_e=block_e,
                          acc_dtype=acc_dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_e, n3), lambda i: (i, 0)),
            pl.BlockSpec((n, n), lambda i: (0, 0)),
            pl.BlockSpec((n, n), lambda i: (0, 0)),
            pl.BlockSpec((block_e, 6, n3), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_e, n3), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((E, n3), u2.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
        name=f"nekbone_ax_n{n}_be{block_e}{_acc_tag(acc_dtype)}",
    )(u2, D, Dt, g2)


# ---------------------------------------------------------------------------
# Fused CG-iteration kernel: masked Ax + per-block partial inner products
# ---------------------------------------------------------------------------

def nekbone_ax_dots_kernel(p_ref, d_ref, dt_ref, g_ref, mask_ref, r_ref,
                           c_ref, w_ref, pap_ref, rcz_ref, *, n: int,
                           block_e: int, acc_dtype: str | None = None):
    """Masked Ax plus the two CG inner-product partials, one element block.

    In the same VMEM residency as the operator this computes

        w   = mask * (D^T G D p)                    (block output)
        pap = sum(p * w)                            (per-block partial)
        rcz = sum(r * c * r)                        (per-block partial)

    ``pap`` relies on ``p`` being continuous (all copies of a shared node
    equal — the CG invariant): then ``Σ_blocks pap == p·c·A p`` with
    ``A = mask ∘ gs ∘ ax_local``, because the gather-scatter transfers onto
    the other factor of the product (DESIGN.md §3.2).  ``rcz`` is the
    weighted residual norm ``r·c·z`` with ``z = r`` (unpreconditioned CG).

    Refs (VMEM blocks):
      p_ref:    (block_e, n^3)     search direction
      d_ref:    (n, n)             D;  dt_ref: (n, n)  D^T
      g_ref:    (block_e, 6, n^3)  metric
      mask_ref: (block_e, n^3)     Dirichlet mask (0/1)
      r_ref:    (block_e, n^3)     residual
      c_ref:    (block_e, n^3)     inner-product weight  mask/multiplicity
      w_ref:    (block_e, n^3)     masked local Ax output
      pap_ref:  (1, 1)             partial  Σ p * w
      rcz_ref:  (1, 1)             partial  Σ r * c * r
    """
    f32 = _accum(p_ref.dtype, acc_dtype)
    p = p_ref[...].astype(f32)
    D = d_ref[...].astype(f32)
    Dt = dt_ref[...].astype(f32)
    g = g_ref[...].astype(f32)
    w = ax_block(p, D, Dt, g, n=n, e=block_e)
    w = w * mask_ref[...].astype(f32)

    r = r_ref[...].astype(f32)
    c = c_ref[...].astype(f32)
    pap_ref[0, 0] = jnp.sum(p * w).astype(pap_ref.dtype)
    rcz_ref[0, 0] = jnp.sum(r * c * r).astype(rcz_ref.dtype)
    w_ref[...] = w.astype(w_ref.dtype)


@functools.partial(jax.jit, static_argnames=("n", "block_e", "interpret",
                                             "acc_dtype"))
def nekbone_ax_dots_pallas(p2: jnp.ndarray, D: jnp.ndarray, Dt: jnp.ndarray,
                           g2: jnp.ndarray, mask2: jnp.ndarray,
                           r2: jnp.ndarray, c2: jnp.ndarray, *, n: int,
                           block_e: int, interpret: bool = False,
                           acc_dtype: str | None = None):
    """Multi-output pallas_call for the fused CG iteration.

    Args: all field operands pre-flattened to (E, n^3) (g2: (E, 6, n^3));
    E divisible by block_e.  Returns ``(w2, pap_parts, rcz_parts)`` with the
    partials of shape ``(E // block_e, 1)`` — tree-reduce them with
    ``jnp.sum`` on the host side of the call.

    Partials accumulate (and are emitted) in ``acc_dtype`` when given, else
    f32 for <=f32 inputs and f64 for f64 (the paper's precision, exercised
    through interpret mode).
    """
    E = p2.shape[0]
    assert E % block_e == 0, (E, block_e)
    n3 = n ** 3
    nblk = E // block_e
    acc = _accum(p2.dtype, acc_dtype)
    field = pl.BlockSpec((block_e, n3), lambda i: (i, 0))
    part = pl.BlockSpec((1, 1), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(nekbone_ax_dots_kernel, n=n, block_e=block_e,
                          acc_dtype=acc_dtype),
        grid=(nblk,),
        in_specs=[
            field,                                      # p
            pl.BlockSpec((n, n), lambda i: (0, 0)),     # D
            pl.BlockSpec((n, n), lambda i: (0, 0)),     # Dt
            pl.BlockSpec((block_e, 6, n3), lambda i: (i, 0, 0)),  # g
            field,                                      # mask
            field,                                      # r
            field,                                      # c
        ],
        out_specs=(field, part, part),
        out_shape=(
            jax.ShapeDtypeStruct((E, n3), p2.dtype),
            jax.ShapeDtypeStruct((nblk, 1), acc),
            jax.ShapeDtypeStruct((nblk, 1), acc),
        ),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
        name=f"nekbone_ax_dots_n{n}_be{block_e}{_acc_tag(acc_dtype)}",
    )(p2, D, Dt, g2, mask2, r2, c2)


# ---------------------------------------------------------------------------
# pap-only kernel: the dots kernel with the r·c·r partial carried instead
# ---------------------------------------------------------------------------

def nekbone_ax_pap_kernel(p_ref, d_ref, dt_ref, g_ref, mask_ref, w_ref,
                          pap_ref, *, n: int, block_e: int,
                          acc_dtype: str | None = None):
    """Masked Ax plus the ``p·c·Ap`` partial only (DESIGN.md §3.3).

    The ``r·c·r`` partial of :func:`nekbone_ax_dots_kernel` equals the
    previous iteration's post-update reduction; once the solver carries that
    scalar through its loop state the kernel's ``r``/``c`` operands are dead
    weight — dropping them takes the fused-v1 iteration from 19 to 17
    streams.  Refs as in :func:`nekbone_ax_dots_kernel` minus ``r``/``c``
    and ``rcz``.
    """
    f32 = _accum(p_ref.dtype, acc_dtype)
    p = p_ref[...].astype(f32)
    D = d_ref[...].astype(f32)
    Dt = dt_ref[...].astype(f32)
    g = g_ref[...].astype(f32)
    w = ax_block(p, D, Dt, g, n=n, e=block_e)
    w = w * mask_ref[...].astype(f32)
    pap_ref[0, 0] = jnp.sum(p * w).astype(pap_ref.dtype)
    w_ref[...] = w.astype(w_ref.dtype)


@functools.partial(jax.jit, static_argnames=("n", "block_e", "interpret",
                                             "acc_dtype"))
def nekbone_ax_pap_pallas(p2: jnp.ndarray, D: jnp.ndarray, Dt: jnp.ndarray,
                          g2: jnp.ndarray, mask2: jnp.ndarray, *, n: int,
                          block_e: int, interpret: bool = False,
                          acc_dtype: str | None = None):
    """pallas_call wrapper: returns ``(w2, pap_parts)`` (carried-rtz path)."""
    E = p2.shape[0]
    assert E % block_e == 0, (E, block_e)
    n3 = n ** 3
    nblk = E // block_e
    acc = _accum(p2.dtype, acc_dtype)
    field = pl.BlockSpec((block_e, n3), lambda i: (i, 0))
    part = pl.BlockSpec((1, 1), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(nekbone_ax_pap_kernel, n=n, block_e=block_e,
                          acc_dtype=acc_dtype),
        grid=(nblk,),
        in_specs=[
            field,                                      # p
            pl.BlockSpec((n, n), lambda i: (0, 0)),     # D
            pl.BlockSpec((n, n), lambda i: (0, 0)),     # Dt
            pl.BlockSpec((block_e, 6, n3), lambda i: (i, 0, 0)),  # g
            field,                                      # mask
        ],
        out_specs=(field, part),
        out_shape=(
            jax.ShapeDtypeStruct((E, n3), p2.dtype),
            jax.ShapeDtypeStruct((nblk, 1), acc),
        ),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
        name=f"nekbone_ax_pap_n{n}_be{block_e}{_acc_tag(acc_dtype)}",
    )(p2, D, Dt, g2, mask2)


# ---------------------------------------------------------------------------
# v2 slab pipeline: in-kernel gather-scatter + merged vector updates
# (DESIGN.md §3.4).  The grid marches whole z-slabs of the element box so the
# x/y direct-stiffness summation and the intra-block z interfaces are summed
# on the VMEM-resident output; only the two block-boundary z-planes leave the
# kernel as O(E n^2) side outputs.  The Dirichlet mask and the inner-product
# weight c = mask/mult are *per-axis index products* on the structured box
# (core/geom.py), so both kernels rebuild them in VMEM from three tiny
# (extent, n) factor arrays instead of streaming full fields.
# ---------------------------------------------------------------------------

def nekbone_ax_slab_kernel(p_ref, r_ref, d_ref, dt_ref, g_ref, mx_ref, my_ref,
                           mz_ref, beta_ref, p_out, w_ref, bot_ref, top_ref,
                           pap_ref, *, n: int, ex: int, ey: int, sz: int,
                           acc_dtype: str | None = None,
                           layout: str = "fold"):
    """Fused CG front-half on one block of ``sz`` whole z-slabs.

    In one VMEM residency:

        p   = r + beta * p_prev              (merged-CG direction update)
        w   = mask * (D^T G D p)             (diagonal metric, structural mask)
        pap = sum(p * w)                     (partial, *before* assembly)
        w  <- ds_sum within the block        (x, y, and intra-block z faces)

    The block's outermost z-planes (after x/y assembly; untouched by the
    intra-block z summation) are emitted so the update kernel can stitch
    neighbouring blocks without a full-field pass.

    Refs (VMEM blocks; ``block_e = sz*ey*ex`` elements, z-major):
      p_ref:    (block_e, n^3)   previous search direction
      r_ref:    (block_e, n^3)   residual
      d_ref/dt_ref: (n, n)       D and D^T
      g_ref:    (block_e, 3, n^3) metric diagonal (rr, ss, tt)
      mx_ref:   (ex, n)          per-axis Dirichlet factors (my: (ey, n),
      my_ref:   (ey, n)           mz: the block's (sz, n) slice of (EZ, n))
      mz_ref:   (sz, n)
      beta_ref: (1, 1)           beta scalar (0 on the first iteration)
      p_out:    (block_e, n^3)   updated direction
      w_ref:    (block_e, n^3)   masked, block-assembled operator output
      bot_ref:  (1, ey*ex*n^2)   bottom boundary plane (k = 0 of slab 0)
      top_ref:  (1, ey*ex*n^2)   top boundary plane (k = n-1 of slab sz-1)
      pap_ref:  (1, 1)           partial  sum(p * mask * w_local)
    """
    block_e = sz * ey * ex
    f32 = _accum(p_ref.dtype, acc_dtype)
    out_dtype = w_ref.dtype
    beta = beta_ref[0, 0].astype(f32)
    p = r_ref[...].astype(f32) + beta * p_ref[...].astype(f32)
    # round the direction through the *storage* dtype before the operator:
    # the update kernel applies alpha to the stored p, so w must be A of
    # exactly that vector — an unrounded p here would make w inconsistent
    # with the CG algebra by O(storage eps), which diverges bf16 CG on
    # ill-conditioned cases.  For f32/f64 storage this is the identity.
    p = p.astype(out_dtype).astype(f32)
    D = d_ref[...].astype(f32)
    Dt = dt_ref[...].astype(f32)
    g3 = g_ref[...].astype(f32)
    w = ax_block_diag(p, D, Dt, g3, n=n, e=block_e, layout=layout)

    # structural mask: outer product of the three per-axis 0/1 factors
    mask = _box_outer(mz_ref[...].astype(f32), my_ref[...].astype(f32),
                      mx_ref[...].astype(f32))
    v = w.reshape(sz, ey, ex, n, n, n) * mask

    # continuity identity (DESIGN.md §3.2): the partial must see the
    # *unassembled* masked output — summation below redistributes values.
    pap_ref[0, 0] = jnp.sum(p.reshape(v.shape) * v).astype(pap_ref.dtype)

    # in-block direct stiffness: same pair sums, same order as
    # core/gs.ds_sum_local restricted to the block (x, then y, then z).
    if ex > 1:
        s = v[:, :, :-1, :, :, -1] + v[:, :, 1:, :, :, 0]
        v = v.at[:, :, :-1, :, :, -1].set(s)
        v = v.at[:, :, 1:, :, :, 0].set(s)
    if ey > 1:
        s = v[:, :-1, :, :, -1, :] + v[:, 1:, :, :, 0, :]
        v = v.at[:, :-1, :, :, -1, :].set(s)
        v = v.at[:, 1:, :, :, 0, :].set(s)
    if sz > 1:
        s = v[:-1, :, :, -1, :, :] + v[1:, :, :, 0, :, :]
        v = v.at[:-1, :, :, -1, :, :].set(s)
        v = v.at[1:, :, :, 0, :, :].set(s)

    w_ref[...] = v.reshape(block_e, n ** 3).astype(out_dtype)
    p_out[...] = p.astype(out_dtype)
    pln = ey * ex * n * n
    bot_ref[...] = v[0, :, :, 0, :, :].reshape(1, pln).astype(out_dtype)
    top_ref[...] = v[-1, :, :, -1, :, :].reshape(1, pln).astype(out_dtype)


@functools.partial(jax.jit, static_argnames=("n", "grid", "sz", "interpret",
                                             "acc_dtype", "layout",
                                             "grid_order"))
def nekbone_ax_slab_pallas(p2: jnp.ndarray, r2: jnp.ndarray, D: jnp.ndarray,
                           Dt: jnp.ndarray, g3: jnp.ndarray, mx: jnp.ndarray,
                           my: jnp.ndarray, mz: jnp.ndarray,
                           beta: jnp.ndarray, *, n: int,
                           grid: tuple[int, int, int], sz: int,
                           interpret: bool = False,
                           acc_dtype: str | None = None,
                           layout: str = "fold",
                           grid_order: str = "parallel"):
    """Multi-output pallas_call for the v2 slab dots kernel.

    Args:
      p2/r2: (E, n^3); g3: (E, 3, n^3); mx/my/mz: (EX|EY|EZ, n) per-axis
      mask factors; beta: (1, 1) scalar operand; grid: (EX, EY, EZ) with
      ``EZ % sz == 0`` and elements z-major.
      acc_dtype: explicit accumulation dtype (precision policy); the field
      outputs stay in the storage dtype of ``p2``, the pap partials in acc.
      layout/grid_order: static contraction layout (``LAYOUTS``) and grid
      iteration order (``GRID_ORDERS``) — autotuned jointly with ``sz``.

    Returns ``(p2_new, w2, bot, top, pap_parts)`` with the boundary planes of
    shape ``(EZ//sz, EY*EX*n^2)`` and partials ``(EZ//sz, 1)``.
    """
    ex, ey, ez = grid
    E = p2.shape[0]
    assert E == ex * ey * ez and ez % sz == 0, (grid, sz, E)
    block_e = sz * ey * ex
    nblk = ez // sz
    n3 = n ** 3
    pln = ey * ex * n * n
    acc = _accum(p2.dtype, acc_dtype)
    field = pl.BlockSpec((block_e, n3), lambda i: (i, 0))
    plane = pl.BlockSpec((1, pln), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(nekbone_ax_slab_kernel, n=n, ex=ex, ey=ey, sz=sz,
                          acc_dtype=acc_dtype, layout=layout),
        grid=(nblk,),
        in_specs=[
            field,                                      # p_prev
            field,                                      # r
            pl.BlockSpec((n, n), lambda i: (0, 0)),     # D
            pl.BlockSpec((n, n), lambda i: (0, 0)),     # Dt
            pl.BlockSpec((block_e, 3, n3), lambda i: (i, 0, 0)),  # g diag
            pl.BlockSpec((ex, n), lambda i: (0, 0)),    # mask factor x
            pl.BlockSpec((ey, n), lambda i: (0, 0)),    # mask factor y
            pl.BlockSpec((sz, n), lambda i: (i, 0)),    # mask factor z slice
            pl.BlockSpec((1, 1), lambda i: (0, 0)),     # beta
        ],
        out_specs=(field, field, plane, plane,
                   pl.BlockSpec((1, 1), lambda i: (i, 0))),
        out_shape=(
            jax.ShapeDtypeStruct((E, n3), p2.dtype),    # p
            jax.ShapeDtypeStruct((E, n3), p2.dtype),    # w
            jax.ShapeDtypeStruct((nblk, pln), p2.dtype),
            jax.ShapeDtypeStruct((nblk, pln), p2.dtype),
            jax.ShapeDtypeStruct((nblk, 1), acc),
        ),
        compiler_params=_CompilerParams(
            dimension_semantics=(grid_order,),
        ),
        interpret=interpret,
        name=(f"nekbone_ax_slab_n{n}_sz{sz}{_acc_tag(acc_dtype)}"
              f"{_cfg_tag(layout, grid_order)}"),
    )(p2, r2, D, Dt, g3, mx, my, mz, beta)


def nekbone_cg_update_kernel(x_ref, p_ref, r_ref, w_ref, addb_ref, addt_ref,
                             alpha_ref, cx_ref, cy_ref, cz_ref, x_out, r_out,
                             rcr_ref, *, n: int, ex: int, ey: int, sz: int,
                             acc_dtype: str | None = None):
    """Merged CG back-half on one slab block (DESIGN.md §3.4).

    In one VMEM residency: stitch the cross-block z-interface planes into
    ``w`` (completing the direct-stiffness summation), apply both axpys, and
    emit the weighted-norm partial of the *updated* residual:

        w   += neighbour boundary planes     (VMEM-local, O(n^2) operands)
        x   += alpha * p
        r   -= alpha * w
        rcr  = sum(r * c * r)                (c from per-axis factors)

    Refs:
      x_ref/p_ref/r_ref/w_ref: (block_e, n^3)
      addb_ref/addt_ref: (1, ey*ex*n^2)  neighbour planes to add at the
                         block's bottom / top boundary (zeros at the ends)
      alpha_ref: (1, 1)
      cx_ref/cy_ref/cz_ref: per-axis c = mask/mult factors ((ex|ey|sz), n)
      x_out/r_out: (block_e, n^3);  rcr_ref: (1, 1)
    """
    block_e = sz * ey * ex
    f32 = _accum(x_ref.dtype, acc_dtype)
    alpha = alpha_ref[0, 0].astype(f32)
    v = w_ref[...].astype(f32).reshape(sz, ey, ex, n, n, n)
    v = v.at[0, :, :, 0, :, :].add(
        addb_ref[...].astype(f32).reshape(ey, ex, n, n))
    v = v.at[-1, :, :, -1, :, :].add(
        addt_ref[...].astype(f32).reshape(ey, ex, n, n))

    x = x_ref[...].astype(f32) + alpha * p_ref[...].astype(f32)
    r = r_ref[...].astype(f32) - alpha * v.reshape(block_e, n ** 3)
    # the r·c·r partial must see the *stored* residual: the carried rtz is
    # next iteration's beta numerator, and that iteration reads the rounded
    # r from HBM.  Identity for f32/f64 storage; load-bearing for bf16.
    r = r.astype(r_out.dtype)

    c = _box_outer(cz_ref[...].astype(f32), cy_ref[...].astype(f32),
                   cx_ref[...].astype(f32))
    r6 = r.astype(f32).reshape(sz, ey, ex, n, n, n)
    rcr_ref[0, 0] = jnp.sum(r6 * c * r6).astype(rcr_ref.dtype)
    x_out[...] = x.astype(x_out.dtype)
    r_out[...] = r


@functools.partial(jax.jit, static_argnames=("n", "grid", "sz", "interpret",
                                             "acc_dtype"))
def nekbone_cg_update_pallas(x2: jnp.ndarray, p2: jnp.ndarray,
                             r2: jnp.ndarray, w2: jnp.ndarray,
                             addb: jnp.ndarray, addt: jnp.ndarray,
                             alpha: jnp.ndarray, cx: jnp.ndarray,
                             cy: jnp.ndarray, cz: jnp.ndarray, *, n: int,
                             grid: tuple[int, int, int], sz: int,
                             interpret: bool = False,
                             acc_dtype: str | None = None):
    """Multi-output pallas_call for the merged vector-update kernel.

    Args mirror :func:`nekbone_ax_slab_pallas`; ``addb``/``addt`` are the
    *shifted* boundary planes (``addb[b] = top[b-1]``, ``addt[b] = bot[b+1]``,
    zeros at the global ends).  Returns ``(x2_new, r2_new, rcr_parts)``.
    """
    ex, ey, ez = grid
    E = x2.shape[0]
    assert E == ex * ey * ez and ez % sz == 0, (grid, sz, E)
    block_e = sz * ey * ex
    nblk = ez // sz
    n3 = n ** 3
    pln = ey * ex * n * n
    acc = _accum(x2.dtype, acc_dtype)
    field = pl.BlockSpec((block_e, n3), lambda i: (i, 0))
    plane = pl.BlockSpec((1, pln), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(nekbone_cg_update_kernel, n=n, ex=ex, ey=ey, sz=sz,
                          acc_dtype=acc_dtype),
        grid=(nblk,),
        in_specs=[
            field, field, field, field,                 # x, p, r, w
            plane, plane,                               # addb, addt
            pl.BlockSpec((1, 1), lambda i: (0, 0)),     # alpha
            pl.BlockSpec((ex, n), lambda i: (0, 0)),    # c factor x
            pl.BlockSpec((ey, n), lambda i: (0, 0)),    # c factor y
            pl.BlockSpec((sz, n), lambda i: (i, 0)),    # c factor z slice
        ],
        out_specs=(field, field, pl.BlockSpec((1, 1), lambda i: (i, 0))),
        out_shape=(
            # x keeps its (possibly wider, DESIGN.md §7) storage dtype;
            # r stays in the field storage dtype.
            jax.ShapeDtypeStruct((E, n3), x2.dtype),
            jax.ShapeDtypeStruct((E, n3), r2.dtype),
            jax.ShapeDtypeStruct((nblk, 1), acc),
        ),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
        name=f"nekbone_cg_update_n{n}_sz{sz}{_acc_tag(acc_dtype)}",
    )(x2, p2, r2, w2, addb, addt, alpha, cx, cy, cz)


# ---------------------------------------------------------------------------
# Multi-RHS (block) v2 pipeline: the same two slab kernels carrying a static
# RHS-batch dimension b (DESIGN.md §12).  The operator-side residents — D,
# D^T, the 3 metric diagonals, and the per-axis mask/weight factors — are
# loaded ONCE per slab residency and reused across all b right-hand sides;
# only the vector streams (p, r, w, x) scale with b.  That amortization is
# the whole point: streams/RHS = per-RHS vector streams + shared operator
# streams / b (cost.multi_rhs_streams).  The per-RHS work is a static
# python unroll over identical single-RHS expression graphs, so at b=1 the
# arithmetic is operation-for-operation the b=1 kernel's and the block CG
# driver (core/cg_block.py) is fp64-bitwise identical to cg_fused_v2.
# Per-RHS scalars travel as length-b vectors: beta/alpha come in as (1, b)
# operands, the pap/rcr partials leave as (nblk, b) outputs.
# ---------------------------------------------------------------------------

def nekbone_ax_slab_block_kernel(p_ref, r_ref, d_ref, dt_ref, g_ref, mx_ref,
                                 my_ref, mz_ref, beta_ref, p_out, w_ref,
                                 bot_ref, top_ref, pap_ref, *, n: int,
                                 ex: int, ey: int, sz: int, nrhs: int,
                                 acc_dtype: str | None = None,
                                 layout: str = "fold"):
    """Batched CG front-half: ``nekbone_ax_slab_kernel`` over ``nrhs`` RHS.

    Refs are the single-RHS kernel's with a leading ``nrhs`` axis on the
    vector operands (``p_ref``/``r_ref``: (nrhs, block_e, n^3); planes
    (nrhs, 1, pln)) while the operator operands keep their shapes — they
    are read once and shared.  ``beta_ref`` is (1, nrhs), ``pap_ref``
    (1, nrhs).
    """
    block_e = sz * ey * ex
    f32 = _accum(p_ref.dtype, acc_dtype)
    out_dtype = w_ref.dtype
    pln = ey * ex * n * n
    # shared per-residency loads: operator data + structural mask, once
    # for all nrhs right-hand sides.
    D = d_ref[...].astype(f32)
    Dt = dt_ref[...].astype(f32)
    g3 = g_ref[...].astype(f32)
    mask = _box_outer(mz_ref[...].astype(f32), my_ref[...].astype(f32),
                      mx_ref[...].astype(f32))
    for j in range(nrhs):
        beta = beta_ref[0, j].astype(f32)
        p = r_ref[j].astype(f32) + beta * p_ref[j].astype(f32)
        # storage rounding of the direction — same contract as the
        # single-RHS kernel (alpha is applied to the *stored* p).
        p = p.astype(out_dtype).astype(f32)
        w = ax_block_diag(p, D, Dt, g3, n=n, e=block_e, layout=layout)
        v = w.reshape(sz, ey, ex, n, n, n) * mask
        # continuity identity: the partial sees the unassembled masked
        # output (DESIGN.md §3.2), one lane per RHS.
        pap_ref[0, j] = jnp.sum(p.reshape(v.shape) * v).astype(pap_ref.dtype)
        if ex > 1:
            s = v[:, :, :-1, :, :, -1] + v[:, :, 1:, :, :, 0]
            v = v.at[:, :, :-1, :, :, -1].set(s)
            v = v.at[:, :, 1:, :, :, 0].set(s)
        if ey > 1:
            s = v[:, :-1, :, :, -1, :] + v[:, 1:, :, :, 0, :]
            v = v.at[:, :-1, :, :, -1, :].set(s)
            v = v.at[:, 1:, :, :, 0, :].set(s)
        if sz > 1:
            s = v[:-1, :, :, -1, :, :] + v[1:, :, :, 0, :, :]
            v = v.at[:-1, :, :, -1, :, :].set(s)
            v = v.at[1:, :, :, 0, :, :].set(s)
        w_ref[j] = v.reshape(block_e, n ** 3).astype(out_dtype)
        p_out[j] = p.astype(out_dtype)
        bot_ref[j] = v[0, :, :, 0, :, :].reshape(1, pln).astype(out_dtype)
        top_ref[j] = v[-1, :, :, -1, :, :].reshape(1, pln).astype(out_dtype)


@functools.partial(jax.jit, static_argnames=("n", "grid", "sz", "interpret",
                                             "acc_dtype", "layout",
                                             "grid_order"))
def nekbone_ax_slab_block_pallas(p3: jnp.ndarray, r3: jnp.ndarray,
                                 D: jnp.ndarray, Dt: jnp.ndarray,
                                 g3: jnp.ndarray, mx: jnp.ndarray,
                                 my: jnp.ndarray, mz: jnp.ndarray,
                                 beta: jnp.ndarray, *, n: int,
                                 grid: tuple[int, int, int], sz: int,
                                 interpret: bool = False,
                                 acc_dtype: str | None = None,
                                 layout: str = "fold",
                                 grid_order: str = "parallel"):
    """Multi-output pallas_call for the batched v2 slab kernel.

    Args mirror :func:`nekbone_ax_slab_pallas` with a leading RHS axis:
    ``p3``/``r3`` are (b, E, n^3) and ``beta`` is (1, b).  Returns
    ``(p3_new, w3, bot, top, pap_parts)`` with planes (b, EZ//sz, pln)
    and partials (EZ//sz, b) — one lane per RHS.
    """
    ex, ey, ez = grid
    nrhs, E = p3.shape[0], p3.shape[1]
    assert E == ex * ey * ez and ez % sz == 0, (grid, sz, E)
    block_e = sz * ey * ex
    nblk = ez // sz
    n3 = n ** 3
    pln = ey * ex * n * n
    acc = _accum(p3.dtype, acc_dtype)
    field = pl.BlockSpec((nrhs, block_e, n3), lambda i: (0, i, 0))
    plane = pl.BlockSpec((nrhs, 1, pln), lambda i: (0, i, 0))
    return pl.pallas_call(
        functools.partial(nekbone_ax_slab_block_kernel, n=n, ex=ex, ey=ey,
                          sz=sz, nrhs=nrhs, acc_dtype=acc_dtype,
                          layout=layout),
        grid=(nblk,),
        in_specs=[
            field,                                      # p_prev (b, ., .)
            field,                                      # r      (b, ., .)
            pl.BlockSpec((n, n), lambda i: (0, 0)),     # D       shared
            pl.BlockSpec((n, n), lambda i: (0, 0)),     # Dt      shared
            pl.BlockSpec((block_e, 3, n3), lambda i: (i, 0, 0)),  # g diag
            pl.BlockSpec((ex, n), lambda i: (0, 0)),    # mask factor x
            pl.BlockSpec((ey, n), lambda i: (0, 0)),    # mask factor y
            pl.BlockSpec((sz, n), lambda i: (i, 0)),    # mask factor z
            pl.BlockSpec((1, nrhs), lambda i: (0, 0)),  # beta vector
        ],
        out_specs=(field, field, plane, plane,
                   pl.BlockSpec((1, nrhs), lambda i: (i, 0))),
        out_shape=(
            jax.ShapeDtypeStruct((nrhs, E, n3), p3.dtype),    # p
            jax.ShapeDtypeStruct((nrhs, E, n3), p3.dtype),    # w
            jax.ShapeDtypeStruct((nrhs, nblk, pln), p3.dtype),
            jax.ShapeDtypeStruct((nrhs, nblk, pln), p3.dtype),
            jax.ShapeDtypeStruct((nblk, nrhs), acc),
        ),
        compiler_params=_CompilerParams(
            dimension_semantics=(grid_order,),
        ),
        interpret=interpret,
        name=(f"nekbone_ax_slab_b{nrhs}_n{n}_sz{sz}{_acc_tag(acc_dtype)}"
              f"{_cfg_tag(layout, grid_order)}"),
    )(p3, r3, D, Dt, g3, mx, my, mz, beta)


def nekbone_cg_update_block_kernel(x_ref, p_ref, r_ref, w_ref, addb_ref,
                                   addt_ref, alpha_ref, cx_ref, cy_ref,
                                   cz_ref, x_out, r_out, rcr_ref, *, n: int,
                                   ex: int, ey: int, sz: int, nrhs: int,
                                   acc_dtype: str | None = None):
    """Batched CG back-half: ``nekbone_cg_update_kernel`` over ``nrhs`` RHS.

    The weight box ``c`` is rebuilt from its per-axis factors once and
    shared across the batch; plane stitch, both axpys, and the post-update
    r·c·r partial run per RHS (``alpha_ref``/``rcr_ref``: (1, nrhs)).
    """
    block_e = sz * ey * ex
    f32 = _accum(x_ref.dtype, acc_dtype)
    # shared per-residency load: the inner-product weight, once for all b.
    c = _box_outer(cz_ref[...].astype(f32), cy_ref[...].astype(f32),
                   cx_ref[...].astype(f32))
    for j in range(nrhs):
        alpha = alpha_ref[0, j].astype(f32)
        v = w_ref[j].astype(f32).reshape(sz, ey, ex, n, n, n)
        v = v.at[0, :, :, 0, :, :].add(
            addb_ref[j].astype(f32).reshape(ey, ex, n, n))
        v = v.at[-1, :, :, -1, :, :].add(
            addt_ref[j].astype(f32).reshape(ey, ex, n, n))
        x = x_ref[j].astype(f32) + alpha * p_ref[j].astype(f32)
        r = r_ref[j].astype(f32) - alpha * v.reshape(block_e, n ** 3)
        # rcr must see the *stored* residual (same contract as b=1).
        r = r.astype(r_out.dtype)
        r6 = r.astype(f32).reshape(sz, ey, ex, n, n, n)
        rcr_ref[0, j] = jnp.sum(r6 * c * r6).astype(rcr_ref.dtype)
        x_out[j] = x.astype(x_out.dtype)
        r_out[j] = r


@functools.partial(jax.jit, static_argnames=("n", "grid", "sz", "interpret",
                                             "acc_dtype"))
def nekbone_cg_update_block_pallas(x3: jnp.ndarray, p3: jnp.ndarray,
                                   r3: jnp.ndarray, w3: jnp.ndarray,
                                   addb: jnp.ndarray, addt: jnp.ndarray,
                                   alpha: jnp.ndarray, cx: jnp.ndarray,
                                   cy: jnp.ndarray, cz: jnp.ndarray, *,
                                   n: int, grid: tuple[int, int, int],
                                   sz: int, interpret: bool = False,
                                   acc_dtype: str | None = None):
    """Multi-output pallas_call for the batched merged-update kernel.

    Args mirror :func:`nekbone_cg_update_pallas` with a leading RHS axis
    ((b, E, n^3) fields, (b, EZ//sz, pln) shifted planes, (1, b) alpha).
    Returns ``(x3_new, r3_new, rcr_parts)`` with partials (EZ//sz, b).
    """
    ex, ey, ez = grid
    nrhs, E = x3.shape[0], x3.shape[1]
    assert E == ex * ey * ez and ez % sz == 0, (grid, sz, E)
    block_e = sz * ey * ex
    nblk = ez // sz
    n3 = n ** 3
    pln = ey * ex * n * n
    acc = _accum(x3.dtype, acc_dtype)
    field = pl.BlockSpec((nrhs, block_e, n3), lambda i: (0, i, 0))
    plane = pl.BlockSpec((nrhs, 1, pln), lambda i: (0, i, 0))
    return pl.pallas_call(
        functools.partial(nekbone_cg_update_block_kernel, n=n, ex=ex, ey=ey,
                          sz=sz, nrhs=nrhs, acc_dtype=acc_dtype),
        grid=(nblk,),
        in_specs=[
            field, field, field, field,                 # x, p, r, w
            plane, plane,                               # addb, addt
            pl.BlockSpec((1, nrhs), lambda i: (0, 0)),  # alpha vector
            pl.BlockSpec((ex, n), lambda i: (0, 0)),    # c factor x
            pl.BlockSpec((ey, n), lambda i: (0, 0)),    # c factor y
            pl.BlockSpec((sz, n), lambda i: (i, 0)),    # c factor z slice
        ],
        out_specs=(field, field, pl.BlockSpec((1, nrhs), lambda i: (i, 0))),
        out_shape=(
            jax.ShapeDtypeStruct((nrhs, E, n3), x3.dtype),
            jax.ShapeDtypeStruct((nrhs, E, n3), r3.dtype),
            jax.ShapeDtypeStruct((nblk, nrhs), acc),
        ),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
        name=f"nekbone_cg_update_b{nrhs}_n{n}_sz{sz}{_acc_tag(acc_dtype)}",
    )(x3, p3, r3, w3, addb, addt, alpha, cx, cy, cz)


# ---------------------------------------------------------------------------
# v3 s-step pipeline: matrix-powers slab kernel + multi-axpy update
# (DESIGN.md §8).  One kernel invocation evaluates the whole 2s+1-vector
# Krylov basis {p, Ap, .., A^s p, r, Ar, .., A^{s-1} r} of an s-step CG
# cycle in a single slab residency: the 3 metric diagonals, D/D^T, and the
# per-axis mask factors are loaded once per s operator applications and the
# chained contractions never leave VMEM.  Chaining A across block boundaries
# needs a matrix-powers ghost region: each application pollutes one slab
# inward from the block edge, so blocks march sz owned slabs plus s halo
# slabs on each side (zero-padded past the domain ends — zero elements
# contribute exactly the nothing a missing neighbour would).  The owned
# basis slices are fully assembled (the halo supplies both neighbours'
# direct-stiffness contributions in-block), so no plane side channel exists;
# the redundant halo reads are the side channel instead
# (cost.sstep_halo_streams).  The (2s+1)^2 Gram/moment block of the s-step
# recurrence is reduced in-kernel over the owned slabs and emitted as
# per-block partials; the s x s recurrence itself is solved in f64 on the
# host (core/cg_sstep.py).
# ---------------------------------------------------------------------------

def sstep_extend_field(f2: jnp.ndarray, grid: tuple[int, int, int], sz: int,
                       halo: int, below: jnp.ndarray | None = None,
                       above: jnp.ndarray | None = None) -> jnp.ndarray:
    """Gather per-block halo windows of a z-major field, zero-padded.

    Args:
      f2: (E, ...) element-major field (z-major over ``grid``); trailing
          dims are carried through.
      below/above: optional ``halo``-deep ghost slabs replacing the zero
          padding at the low/high z end — ``(halo, EY*EX, ...)`` (any
          layout reshapeable to it).  This is the distributed halo hook
          (distributed/sstep.py): when ``grid`` is a *shard-local* grid,
          the neighbour shards' boundary slabs go here and the resulting
          windows are exactly the single-device ones (zeros remain the
          correct padding at the global domain ends, where
          ``gs.halo_exchange_z`` delivers zeros).
    Returns (EZ//sz, (sz + 2*halo)*EY*EX, ...): block ``i`` holds slabs
    ``[i*sz - halo, i*sz + sz + halo)`` with zeros past the domain ends —
    the matrix-powers ghost region of the v3 powers kernel.  (A production
    TPU lowering would express these as overlapping block windows; the
    reference build materializes them, which the cost model charges as the
    halo side channel.)
    """
    ex, ey, ez = grid
    nblk = ez // sz
    L = sz + 2 * halo
    rest = f2.shape[1:]
    f = f2.reshape((ez, ey * ex) + rest)
    pad_shape = (halo,) + f.shape[1:]
    pb = (jnp.zeros(pad_shape, f2.dtype) if below is None
          else below.reshape(pad_shape).astype(f2.dtype))
    pa = (jnp.zeros(pad_shape, f2.dtype) if above is None
          else above.reshape(pad_shape).astype(f2.dtype))
    fp = jnp.concatenate([pb, f, pa], axis=0)
    idx = jnp.arange(nblk)[:, None] * sz + jnp.arange(L)[None, :]
    return fp[idx].reshape((nblk, L * ey * ex) + rest)


def sstep_extend_zfactor(fz: jnp.ndarray, sz: int, halo: int,
                         below: jnp.ndarray | None = None,
                         above: jnp.ndarray | None = None) -> jnp.ndarray:
    """Per-block halo windows of a per-axis z factor ``(EZ, n)``.

    Out-of-domain halo rows are padded with ones: the fields there are
    zero (``sstep_extend_field``), so the factor value is inert, and ones
    never introduce false Dirichlet zeros.  ``below``/``above`` replace
    the pad with neighbour-shard factor rows ``(halo, n)`` when ``fz`` is
    a shard-local slice (the distributed hook, as in
    :func:`sstep_extend_field`).  Returns (EZ//sz, sz+2*halo, n).
    """
    ez, n = fz.shape
    nblk = ez // sz
    L = sz + 2 * halo
    pb = (jnp.ones((halo, n), fz.dtype) if below is None
          else below.reshape(halo, n).astype(fz.dtype))
    pa = (jnp.ones((halo, n), fz.dtype) if above is None
          else above.reshape(halo, n).astype(fz.dtype))
    fp = jnp.concatenate([pb, fz, pa], axis=0)
    idx = jnp.arange(nblk)[:, None] * sz + jnp.arange(L)[None, :]
    return fp[idx]


def nekbone_ax_powers_kernel(pext_ref, rext_ref, d_ref, dt_ref, gext_ref,
                             mx_ref, my_ref, mzext_ref, cx_ref, cy_ref,
                             cz_ref, th_ref, basis_ref, gram_ref, *, n: int,
                             ex: int, ey: int, sz: int, s: int, halo: int,
                             acc_dtype: str | None = None,
                             layout: str = "fold"):
    """Matrix-powers front-half of one s-step CG cycle, one slab block.

    In one VMEM residency over ``L = sz + 2*halo`` slabs (``halo = s``):

        v_{j+1} = (1/theta) * mask * gs_block(D^T G D v_j)   chained s times
                  from v_0 = p, and s-1 times from v_0 = r
        G_ab    = sum_own(V_a * c * V_b)                     Gram partials

    with ``V = [p, Ap', .., A'^s p, r, A'r, .., A'^{s-1} r]`` (``A' = A /
    theta`` — the theta scaling keeps the monomial basis O(1) so the f64
    host recurrence stays conditioned, DESIGN.md §8).  Every basis vector
    is rounded through the *storage* dtype before it feeds the next
    application and before the Gram reduction: the update kernel combines
    the stored basis, so Gram and basis must describe the same (rounded)
    vectors — identities for f32/f64, load-bearing for bf16 (the §7 rules).

    The in-block direct stiffness runs over the whole extended block, so
    owned slabs receive both neighbours' contributions (computed
    redundantly in the halo) and the emitted basis needs no plane stitch.
    Gram partials reduce over owned slabs only — blocks partition E.

    Refs (VMEM blocks; ``Lee = L*ey*ex``, ``block_e = sz*ey*ex``):
      pext_ref/rext_ref: (1, Lee, n^3)  halo'd p / r windows
      d_ref/dt_ref: (n, n)
      gext_ref:  (1, Lee, 3, n^3)       halo'd metric diagonal
      mx_ref/my_ref: (ex|ey, n)         per-axis Dirichlet factors
      mzext_ref: (1, L, n)              halo'd z mask factor window
      cx_ref/cy_ref: (ex|ey, n)         per-axis c factors
      cz_ref:    (sz, n)                owned z c-factor slice
      th_ref:    (1, 1)                 1/theta basis scale
      basis_ref: (block_e, 2s-1, n^3)   owned [A'p..A'^s p, A'r..A'^{s-1}r]
      gram_ref:  (1, 2s+1, 2s+1)        Gram partial over owned slabs
    """
    L = sz + 2 * halo
    Lee = L * ey * ex
    block_e = sz * ey * ex
    n3 = n ** 3
    f32 = _accum(pext_ref.dtype, acc_dtype)
    out_dtype = basis_ref.dtype
    D = d_ref[...].astype(f32)
    Dt = dt_ref[...].astype(f32)
    g3 = gext_ref[0].astype(f32)
    inv_th = th_ref[0, 0].astype(f32)
    mask = _box_outer(mzext_ref[0].astype(f32), my_ref[...].astype(f32),
                      mx_ref[...].astype(f32))

    def apply_scaled(v):
        """One masked, block-assembled, theta-scaled operator application."""
        w = ax_block_diag(v, D, Dt, g3, n=n, e=Lee, layout=layout)
        v6 = w.reshape(L, ey, ex, n, n, n) * mask
        if ex > 1:
            t = v6[:, :, :-1, :, :, -1] + v6[:, :, 1:, :, :, 0]
            v6 = v6.at[:, :, :-1, :, :, -1].set(t)
            v6 = v6.at[:, :, 1:, :, :, 0].set(t)
        if ey > 1:
            t = v6[:, :-1, :, :, -1, :] + v6[:, 1:, :, :, 0, :]
            v6 = v6.at[:, :-1, :, :, -1, :].set(t)
            v6 = v6.at[:, 1:, :, :, 0, :].set(t)
        if L > 1:
            t = v6[:-1, :, :, -1, :, :] + v6[1:, :, :, 0, :, :]
            v6 = v6.at[:-1, :, :, -1, :, :].set(t)
            v6 = v6.at[1:, :, :, 0, :, :].set(t)
        return (v6.reshape(Lee, n3) * inv_th)

    def chain(v0, napps):
        vecs = [v0]
        v = v0
        for _ in range(napps):
            # round through storage: the next application and the Gram must
            # see exactly the vector the update kernel will re-read.
            v = apply_scaled(v).astype(out_dtype).astype(f32)
            vecs.append(v)
        return vecs

    p = pext_ref[0].astype(f32)
    r = rext_ref[0].astype(f32)
    V = chain(p, s) + chain(r, s - 1)          # order: p-powers, r-powers

    ho = halo * ey * ex
    own = [v[ho:ho + block_e] for v in V]
    c6 = _box_outer(cz_ref[...].astype(f32), cy_ref[...].astype(f32),
                    cx_ref[...].astype(f32))
    cw = c6.reshape(1, block_e * n3)
    Vo = jnp.stack([v.reshape(block_e * n3) for v in own])
    gram_ref[0] = _dot(Vo * cw, Vo.T).astype(gram_ref.dtype)

    # owned basis, minus p and r themselves (the update kernel re-reads
    # those from their own streams): [A'p..A'^s p, A'r..A'^{s-1} r].
    new = own[1:s + 1] + own[s + 2:]
    basis_ref[...] = jnp.stack(new, axis=1).astype(out_dtype)


@functools.partial(jax.jit, static_argnames=("n", "grid", "sz", "s",
                                             "interpret", "acc_dtype",
                                             "layout", "grid_order"))
def nekbone_ax_powers_pallas(pext: jnp.ndarray, rext: jnp.ndarray,
                             D: jnp.ndarray, Dt: jnp.ndarray,
                             gext: jnp.ndarray, mx: jnp.ndarray,
                             my: jnp.ndarray, mzext: jnp.ndarray,
                             cx: jnp.ndarray, cy: jnp.ndarray,
                             cz: jnp.ndarray, inv_theta: jnp.ndarray, *,
                             n: int, grid: tuple[int, int, int], sz: int,
                             s: int, interpret: bool = False,
                             acc_dtype: str | None = None,
                             layout: str = "fold",
                             grid_order: str = "parallel"):
    """Multi-output pallas_call for the v3 matrix-powers kernel.

    Args:
      pext/rext: (EZ//sz, Lee, n^3) halo windows (:func:`sstep_extend_field`
        with ``halo = s``); gext: (EZ//sz, Lee, 3, n^3); mzext:
        (EZ//sz, L, n) (:func:`sstep_extend_zfactor`); cz: (EZ, n) —
        blocked into owned (sz, n) slices; inv_theta: (1, 1) basis scale.

    Returns ``(basis, gram_parts)``: basis ``(E, 2s-1, n^3)`` in the
    storage dtype of ``pext``, Gram partials ``(EZ//sz, 2s+1, 2s+1)`` in
    the accumulation dtype.
    """
    ex, ey, ez = grid
    assert ez % sz == 0 and s >= 1, (grid, sz, s)
    halo = s
    L = sz + 2 * halo
    Lee = L * ey * ex
    block_e = sz * ey * ex
    nblk = ez // sz
    E = nblk * block_e
    n3 = n ** 3
    K = 2 * s + 1
    nb = 2 * s - 1
    assert pext.shape == (nblk, Lee, n3), (pext.shape, (nblk, Lee, n3))
    acc = _accum(pext.dtype, acc_dtype)
    ext = pl.BlockSpec((1, Lee, n3), lambda i: (i, 0, 0))
    return pl.pallas_call(
        functools.partial(nekbone_ax_powers_kernel, n=n, ex=ex, ey=ey,
                          sz=sz, s=s, halo=halo, acc_dtype=acc_dtype,
                          layout=layout),
        grid=(nblk,),
        in_specs=[
            ext,                                        # p window
            ext,                                        # r window
            pl.BlockSpec((n, n), lambda i: (0, 0)),     # D
            pl.BlockSpec((n, n), lambda i: (0, 0)),     # Dt
            pl.BlockSpec((1, Lee, 3, n3), lambda i: (i, 0, 0, 0)),  # g diag
            pl.BlockSpec((ex, n), lambda i: (0, 0)),    # mask factor x
            pl.BlockSpec((ey, n), lambda i: (0, 0)),    # mask factor y
            pl.BlockSpec((1, L, n), lambda i: (i, 0, 0)),  # mask z window
            pl.BlockSpec((ex, n), lambda i: (0, 0)),    # c factor x
            pl.BlockSpec((ey, n), lambda i: (0, 0)),    # c factor y
            pl.BlockSpec((sz, n), lambda i: (i, 0)),    # c factor z slice
            pl.BlockSpec((1, 1), lambda i: (0, 0)),     # 1/theta
        ],
        out_specs=(pl.BlockSpec((block_e, nb, n3), lambda i: (i, 0, 0)),
                   pl.BlockSpec((1, K, K), lambda i: (i, 0, 0))),
        out_shape=(
            jax.ShapeDtypeStruct((E, nb, n3), pext.dtype),
            jax.ShapeDtypeStruct((nblk, K, K), acc),
        ),
        compiler_params=_CompilerParams(
            dimension_semantics=(grid_order,),
        ),
        interpret=interpret,
        name=(f"nekbone_ax_powers_n{n}_sz{sz}_s{s}{_acc_tag(acc_dtype)}"
              f"{_cfg_tag(layout, grid_order)}"),
    )(pext, rext, D, Dt, gext, mx, my, mzext, cx, cy, cz, inv_theta)


def nekbone_sstep_update_kernel(x_ref, p_ref, r_ref, basis_ref, coef_ref,
                                cx_ref, cy_ref, cz_ref, x_out, r_out, p_out,
                                rcr_ref, *, n: int, ex: int, ey: int,
                                sz: int, s: int,
                                acc_dtype: str | None = None):
    """Multi-axpy back-half of one s-step cycle (DESIGN.md §8).

    Applies the whole s-step of vector updates in one pass over the basis:

        x += V @ e_s,   r = V @ b_s,   p = V @ a_s,   rcr = sum(r*c*r)

    with ``V = [p, basis.., r, basis..]`` in the powers kernel's column
    order and ``(e_s, b_s, a_s)`` the f64-solved recurrence coefficients
    (rows of ``coef_ref``).  The ``r·c·r`` partial reduces over the
    *stored* residual — it seeds the next cycle's final-history entry and
    must match what the next powers kernel reads from HBM (§7 rule 2).

    Refs:
      x_ref/p_ref/r_ref: (block_e, n^3)
      basis_ref: (block_e, 2s-1, n^3)   [A'p..A'^s p, A'r..A'^{s-1} r]
      coef_ref:  (3, 2s+1)              rows: x-, r-, p-update coefficients
      cx_ref/cy_ref/cz_ref: per-axis c factors ((ex|ey|sz), n)
      x_out/r_out/p_out: (block_e, n^3);  rcr_ref: (1, 1)
    """
    block_e = sz * ey * ex
    n3 = n ** 3
    f32 = _accum(x_ref.dtype, acc_dtype)
    coef = coef_ref[...].astype(f32)
    basis = basis_ref[...].astype(f32)
    p = p_ref[...].astype(f32)
    r = r_ref[...].astype(f32)
    # V column order (powers kernel): p, A'p..A'^s p, r, A'r..A'^{s-1} r
    terms = ([p] + [basis[:, m, :] for m in range(s)]
             + [r] + [basis[:, s + m, :] for m in range(s - 1)])
    xacc = x_ref[...].astype(f32)
    racc = jnp.zeros((block_e, n3), f32)
    pacc = jnp.zeros((block_e, n3), f32)
    for k, v in enumerate(terms):
        xacc = xacc + coef[0, k] * v
        racc = racc + coef[1, k] * v
        pacc = pacc + coef[2, k] * v
    r_st = racc.astype(r_out.dtype)
    c6 = _box_outer(cz_ref[...].astype(f32), cy_ref[...].astype(f32),
                    cx_ref[...].astype(f32))
    r6 = r_st.astype(f32).reshape(sz, ey, ex, n, n, n)
    rcr_ref[0, 0] = jnp.sum(r6 * c6 * r6).astype(rcr_ref.dtype)
    x_out[...] = xacc.astype(x_out.dtype)
    r_out[...] = r_st
    p_out[...] = pacc.astype(p_out.dtype)


@functools.partial(jax.jit, static_argnames=("n", "grid", "sz", "s",
                                             "interpret", "acc_dtype"))
def nekbone_sstep_update_pallas(x2: jnp.ndarray, p2: jnp.ndarray,
                                r2: jnp.ndarray, basis: jnp.ndarray,
                                coef: jnp.ndarray, cx: jnp.ndarray,
                                cy: jnp.ndarray, cz: jnp.ndarray, *, n: int,
                                grid: tuple[int, int, int], sz: int, s: int,
                                interpret: bool = False,
                                acc_dtype: str | None = None):
    """Multi-output pallas_call for the s-step update kernel.

    Args mirror :func:`nekbone_ax_powers_pallas`; ``coef`` is the (3, 2s+1)
    coefficient block (x/r/p rows).  Returns
    ``(x2_new, r2_new, p2_new, rcr_parts)``.
    """
    ex, ey, ez = grid
    E = x2.shape[0]
    assert E == ex * ey * ez and ez % sz == 0, (grid, sz, E)
    block_e = sz * ey * ex
    nblk = ez // sz
    n3 = n ** 3
    K = 2 * s + 1
    nb = 2 * s - 1
    acc = _accum(x2.dtype, acc_dtype)
    field = pl.BlockSpec((block_e, n3), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(nekbone_sstep_update_kernel, n=n, ex=ex, ey=ey,
                          sz=sz, s=s, acc_dtype=acc_dtype),
        grid=(nblk,),
        in_specs=[
            field, field, field,                        # x, p, r
            pl.BlockSpec((block_e, nb, n3), lambda i: (i, 0, 0)),  # basis
            pl.BlockSpec((3, K), lambda i: (0, 0)),     # coefficients
            pl.BlockSpec((ex, n), lambda i: (0, 0)),    # c factor x
            pl.BlockSpec((ey, n), lambda i: (0, 0)),    # c factor y
            pl.BlockSpec((sz, n), lambda i: (i, 0)),    # c factor z slice
        ],
        out_specs=(field, field, field,
                   pl.BlockSpec((1, 1), lambda i: (i, 0))),
        out_shape=(
            jax.ShapeDtypeStruct((E, n3), x2.dtype),    # x
            jax.ShapeDtypeStruct((E, n3), r2.dtype),    # r
            jax.ShapeDtypeStruct((E, n3), p2.dtype),    # p
            jax.ShapeDtypeStruct((nblk, 1), acc),
        ),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
        name=f"nekbone_sstep_update_n{n}_sz{sz}_s{s}{_acc_tag(acc_dtype)}",
    )(x2, p2, r2, basis, coef, cx, cy, cz)


# ---------------------------------------------------------------------------
# Preconditioning kernels (DESIGN.md §9).  Two PCG pipelines share the v2
# slab front-half (nekbone_ax_slab_kernel applied with z = M^-1 r in the
# residual slot — the direction update p = z + beta p and the p·c·Ap partial
# are already exactly what PCG needs):
#
# * Jacobi: the solver carries the *preconditioned* residual z = D^-1 r
#   instead of r, so the only new stream is the operator diagonal — the
#   merged update kernel below applies D^-1 to the stitched operator output
#   (z -= alpha D^-1 w), reconstructs r = D z in VMEM, and emits both the
#   r·c·z (beta numerator) and r·c·r (history) partials.  10R + 4W = 14
#   streams/iter, one more than unpreconditioned v2.
# * Chebyshev: z = q_k(A) r for the degree-k Chebyshev approximation of
#   A^-1 on an interval [lmin, lmax] ⊇ spec(A).  One application is k
#   chained assembled operator applications — exactly the v3 matrix-powers
#   structure, so the kernel reuses its halo machinery (k ghost slabs per
#   block side, sstep_extend_field windows) to evaluate the whole
#   polynomial in one slab residency: r + 3 metric diagonals in, z out.
# ---------------------------------------------------------------------------

def nekbone_pcg_update_kernel(x_ref, p_ref, z_ref, w_ref, addb_ref, addt_ref,
                              alpha_ref, invd_ref, cx_ref, cy_ref, cz_ref,
                              x_out, z_out, rtz_ref, rcr_ref, *, n: int,
                              ex: int, ey: int, sz: int,
                              acc_dtype: str | None = None):
    """Merged Jacobi-PCG back-half on one slab block (DESIGN.md §9.2).

    The solver carries z = D^-1 r (D = diag(A)); r itself never streams.
    In one VMEM residency: stitch the cross-block z-interface planes into
    ``w``, apply both axpys in z-coordinates, and emit the two weighted
    partials of the *updated*, *stored* residual:

        w   += neighbour boundary planes          (the v2 stitch)
        x   += alpha * p
        z   -= alpha * invdiag * w                (z-coordinate r-update)
        rtz  = sum(r * c * z) = sum(z * c * z / invdiag)
        rcr  = sum(r * c * r) = sum(z * c * z / invdiag^2)

    with ``r = z / invdiag`` reconstructed in VMEM (invdiag is 1 at masked
    rows, where z is identically 0, so the reconstruction is exact there).
    ``rtz`` is next iteration's beta numerator; ``rcr`` is the residual-
    norm history entry, directly comparable to unpreconditioned CG's.

    Refs as :func:`nekbone_cg_update_kernel` with ``z`` in place of ``r``
    plus ``invd_ref``: (block_e, n^3) assembled 1/diag(A), and the two
    (1, 1) partial outputs.
    """
    block_e = sz * ey * ex
    n3 = n ** 3
    f32 = _accum(x_ref.dtype, acc_dtype)
    alpha = alpha_ref[0, 0].astype(f32)
    v = w_ref[...].astype(f32).reshape(sz, ey, ex, n, n, n)
    v = v.at[0, :, :, 0, :, :].add(
        addb_ref[...].astype(f32).reshape(ey, ex, n, n))
    v = v.at[-1, :, :, -1, :, :].add(
        addt_ref[...].astype(f32).reshape(ey, ex, n, n))

    invd = invd_ref[...].astype(f32)
    x = x_ref[...].astype(f32) + alpha * p_ref[...].astype(f32)
    z = z_ref[...].astype(f32) - alpha * (invd * v.reshape(block_e, n3))
    # both partials must see the *stored* z (§7 rule 2): rtz is the beta
    # numerator of the iteration that re-reads z from HBM.
    z = z.astype(z_out.dtype)

    diag = 1.0 / invd                      # exact where invd == 1 (masked)
    c = _box_outer(cz_ref[...].astype(f32), cy_ref[...].astype(f32),
                   cx_ref[...].astype(f32))
    z6 = z.astype(f32).reshape(sz, ey, ex, n, n, n)
    d6 = diag.reshape(sz, ey, ex, n, n, n)
    rtz_ref[0, 0] = jnp.sum(z6 * c * z6 * d6).astype(rtz_ref.dtype)
    rcr_ref[0, 0] = jnp.sum(z6 * c * z6 * d6 * d6).astype(rcr_ref.dtype)
    x_out[...] = x.astype(x_out.dtype)
    z_out[...] = z


@functools.partial(jax.jit, static_argnames=("n", "grid", "sz", "interpret",
                                             "acc_dtype"))
def nekbone_pcg_update_pallas(x2: jnp.ndarray, p2: jnp.ndarray,
                              z2: jnp.ndarray, w2: jnp.ndarray,
                              addb: jnp.ndarray, addt: jnp.ndarray,
                              alpha: jnp.ndarray, invd2: jnp.ndarray,
                              cx: jnp.ndarray, cy: jnp.ndarray,
                              cz: jnp.ndarray, *, n: int,
                              grid: tuple[int, int, int], sz: int,
                              interpret: bool = False,
                              acc_dtype: str | None = None):
    """Multi-output pallas_call for the Jacobi-PCG update kernel.

    Args mirror :func:`nekbone_cg_update_pallas` with the carried
    preconditioned residual ``z2`` in the residual slot plus ``invd2``:
    (E, n^3) assembled 1/diag(A) in the operator-storage dtype.  Returns
    ``(x2_new, z2_new, rtz_parts, rcr_parts)``.
    """
    ex, ey, ez = grid
    E = x2.shape[0]
    assert E == ex * ey * ez and ez % sz == 0, (grid, sz, E)
    block_e = sz * ey * ex
    nblk = ez // sz
    n3 = n ** 3
    pln = ey * ex * n * n
    acc = _accum(x2.dtype, acc_dtype)
    field = pl.BlockSpec((block_e, n3), lambda i: (i, 0))
    plane = pl.BlockSpec((1, pln), lambda i: (i, 0))
    part = pl.BlockSpec((1, 1), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(nekbone_pcg_update_kernel, n=n, ex=ex, ey=ey,
                          sz=sz, acc_dtype=acc_dtype),
        grid=(nblk,),
        in_specs=[
            field, field, field, field,                 # x, p, z, w
            plane, plane,                               # addb, addt
            pl.BlockSpec((1, 1), lambda i: (0, 0)),     # alpha
            field,                                      # invdiag
            pl.BlockSpec((ex, n), lambda i: (0, 0)),    # c factor x
            pl.BlockSpec((ey, n), lambda i: (0, 0)),    # c factor y
            pl.BlockSpec((sz, n), lambda i: (i, 0)),    # c factor z slice
        ],
        out_specs=(field, field, part, part),
        out_shape=(
            jax.ShapeDtypeStruct((E, n3), x2.dtype),    # x
            jax.ShapeDtypeStruct((E, n3), z2.dtype),    # z
            jax.ShapeDtypeStruct((nblk, 1), acc),       # rtz partials
            jax.ShapeDtypeStruct((nblk, 1), acc),       # rcr partials
        ),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
        name=f"nekbone_pcg_update_n{n}_sz{sz}{_acc_tag(acc_dtype)}",
    )(x2, p2, z2, w2, addb, addt, alpha, invd2, cx, cy, cz)


def nekbone_cheb_apply_kernel(rext_ref, d_ref, dt_ref, gext_ref, mx_ref,
                              my_ref, mzext_ref, cx_ref, cy_ref, cz_ref,
                              coef_ref, z_ref, rtz_ref, *, n: int, ex: int,
                              ey: int, sz: int, k: int, halo: int,
                              acc_dtype: str | None = None,
                              layout: str = "fold"):
    """Chebyshev preconditioner application, one slab block (DESIGN.md §9.3).

    Evaluates ``z = q_k(A) r`` — the degree-k Chebyshev-semi-iteration
    approximation of ``A^-1`` on ``[lmin, lmax]`` — in one VMEM residency
    over ``L = sz + 2*halo`` slabs (``halo = k``), by the incremental-
    residual Chebyshev recurrence (the scalars are precomputed host-side
    in f64 from the interval, ``core/precond.cheb_scalars``):

        d   = coef[0,0] * r;   z = d;   res = r
        for i in 1..k:
            res -= A d                      (masked, block-assembled)
            d    = coef[i,0] * d + coef[i,1] * res
            z   += d
        rtz = sum_own(r * c * z)            (the PCG beta numerator)

    Each application of A pollutes one slab inward from the block edge
    (the matrix-powers ghost-region argument of §8.2), so k chained
    applications need exactly the v3 halo: owned slabs of ``z`` leave
    fully assembled, no plane side channel.  ``z`` is rounded through the
    storage dtype before the rtz reduction (§7 rule 2 — the v2 slab
    kernel re-reads the stored z as its direction-update operand).

    Refs (``Lee = L*ey*ex``, ``block_e = sz*ey*ex``):
      rext_ref:  (1, Lee, n^3)   halo'd residual window
      d_ref/dt_ref: (n, n)
      gext_ref:  (1, Lee, 3, n^3) halo'd metric diagonal
      mx_ref/my_ref: (ex|ey, n)  per-axis Dirichlet factors
      mzext_ref: (1, L, n)       halo'd z mask-factor window
      cx_ref/cy_ref: (ex|ey, n); cz_ref: (sz, n) owned z c-factor slice
      coef_ref:  (k+1, 2)        Chebyshev recurrence scalars
      z_ref:     (block_e, n^3)  owned q_k(A) r
      rtz_ref:   (1, 1)          partial  sum(r * c * z)
    """
    L = sz + 2 * halo
    Lee = L * ey * ex
    block_e = sz * ey * ex
    n3 = n ** 3
    f32 = _accum(rext_ref.dtype, acc_dtype)
    out_dtype = z_ref.dtype
    D = d_ref[...].astype(f32)
    Dt = dt_ref[...].astype(f32)
    g3 = gext_ref[0].astype(f32)
    mask = _box_outer(mzext_ref[0].astype(f32), my_ref[...].astype(f32),
                      mx_ref[...].astype(f32))
    coef = coef_ref[...].astype(f32)

    def apply_a(v):
        """One masked, block-assembled operator application (unscaled)."""
        w = ax_block_diag(v, D, Dt, g3, n=n, e=Lee, layout=layout)
        v6 = w.reshape(L, ey, ex, n, n, n) * mask
        if ex > 1:
            t = v6[:, :, :-1, :, :, -1] + v6[:, :, 1:, :, :, 0]
            v6 = v6.at[:, :, :-1, :, :, -1].set(t)
            v6 = v6.at[:, :, 1:, :, :, 0].set(t)
        if ey > 1:
            t = v6[:, :-1, :, :, -1, :] + v6[:, 1:, :, :, 0, :]
            v6 = v6.at[:, :-1, :, :, -1, :].set(t)
            v6 = v6.at[:, 1:, :, :, 0, :].set(t)
        if L > 1:
            t = v6[:-1, :, :, -1, :, :] + v6[1:, :, :, 0, :, :]
            v6 = v6.at[:-1, :, :, -1, :, :].set(t)
            v6 = v6.at[1:, :, :, 0, :, :].set(t)
        return v6.reshape(Lee, n3)

    r = rext_ref[0].astype(f32)
    d = coef[0, 0] * r
    z = d
    res = r
    for i in range(1, k + 1):
        res = res - apply_a(d)
        d = coef[i, 0] * d + coef[i, 1] * res
        z = z + d

    ho = halo * ey * ex
    z_own = z[ho:ho + block_e].astype(out_dtype)
    r_own = r[ho:ho + block_e]
    c6 = _box_outer(cz_ref[...].astype(f32), cy_ref[...].astype(f32),
                    cx_ref[...].astype(f32))
    z6 = z_own.astype(f32).reshape(sz, ey, ex, n, n, n)
    r6 = r_own.reshape(sz, ey, ex, n, n, n)
    rtz_ref[0, 0] = jnp.sum(r6 * c6 * z6).astype(rtz_ref.dtype)
    z_ref[...] = z_own


@functools.partial(jax.jit, static_argnames=("n", "grid", "sz", "k",
                                             "interpret", "acc_dtype",
                                             "layout", "grid_order"))
def nekbone_cheb_apply_pallas(rext: jnp.ndarray, D: jnp.ndarray,
                              Dt: jnp.ndarray, gext: jnp.ndarray,
                              mx: jnp.ndarray, my: jnp.ndarray,
                              mzext: jnp.ndarray, cx: jnp.ndarray,
                              cy: jnp.ndarray, cz: jnp.ndarray,
                              coef: jnp.ndarray, *, n: int,
                              grid: tuple[int, int, int], sz: int, k: int,
                              interpret: bool = False,
                              acc_dtype: str | None = None,
                              layout: str = "fold",
                              grid_order: str = "parallel"):
    """Multi-output pallas_call for the Chebyshev-apply kernel.

    Args:
      rext: (EZ//sz, Lee, n^3) halo'd residual windows
        (:func:`sstep_extend_field` with ``halo = k``); gext:
        (EZ//sz, Lee, 3, n^3); mzext: (EZ//sz, L, n)
        (:func:`sstep_extend_zfactor`); cz: (EZ, n) — blocked into owned
        (sz, n) slices; coef: (k+1, 2) Chebyshev recurrence scalars.

    Returns ``(z, rtz_parts)``: z ``(E, n^3)`` in the storage dtype of
    ``rext``, rtz partials ``(EZ//sz, 1)`` in the accumulation dtype.
    """
    ex, ey, ez = grid
    assert ez % sz == 0 and k >= 1, (grid, sz, k)
    halo = k
    L = sz + 2 * halo
    Lee = L * ey * ex
    block_e = sz * ey * ex
    nblk = ez // sz
    E = nblk * block_e
    n3 = n ** 3
    assert rext.shape == (nblk, Lee, n3), (rext.shape, (nblk, Lee, n3))
    assert coef.shape == (k + 1, 2), coef.shape
    acc = _accum(rext.dtype, acc_dtype)
    ext = pl.BlockSpec((1, Lee, n3), lambda i: (i, 0, 0))
    return pl.pallas_call(
        functools.partial(nekbone_cheb_apply_kernel, n=n, ex=ex, ey=ey,
                          sz=sz, k=k, halo=halo, acc_dtype=acc_dtype,
                          layout=layout),
        grid=(nblk,),
        in_specs=[
            ext,                                        # r window
            pl.BlockSpec((n, n), lambda i: (0, 0)),     # D
            pl.BlockSpec((n, n), lambda i: (0, 0)),     # Dt
            pl.BlockSpec((1, Lee, 3, n3), lambda i: (i, 0, 0, 0)),  # g diag
            pl.BlockSpec((ex, n), lambda i: (0, 0)),    # mask factor x
            pl.BlockSpec((ey, n), lambda i: (0, 0)),    # mask factor y
            pl.BlockSpec((1, L, n), lambda i: (i, 0, 0)),  # mask z window
            pl.BlockSpec((ex, n), lambda i: (0, 0)),    # c factor x
            pl.BlockSpec((ey, n), lambda i: (0, 0)),    # c factor y
            pl.BlockSpec((sz, n), lambda i: (i, 0)),    # c factor z slice
            pl.BlockSpec((k + 1, 2), lambda i: (0, 0)),  # cheb scalars
        ],
        out_specs=(pl.BlockSpec((block_e, n3), lambda i: (i, 0)),
                   pl.BlockSpec((1, 1), lambda i: (i, 0))),
        out_shape=(
            jax.ShapeDtypeStruct((E, n3), rext.dtype),
            jax.ShapeDtypeStruct((nblk, 1), acc),
        ),
        compiler_params=_CompilerParams(
            dimension_semantics=(grid_order,),
        ),
        interpret=interpret,
        name=(f"nekbone_cheb_apply_n{n}_sz{sz}_k{k}{_acc_tag(acc_dtype)}"
              f"{_cfg_tag(layout, grid_order)}"),
    )(rext, D, Dt, gext, mx, my, mzext, cx, cy, cz, coef)


def nekbone_interp_kernel(u_ref, mt_ref, v_ref, *, nin: int, nout: int,
                          block_e: int, acc_dtype: str | None = None):
    """Tensor-product GLL-to-GLL interpolation of one element block.

    The p-multigrid transfer operator (DESIGN.md §13): the VMEM-resident
    transfer matrix ``mt`` — ``(nin, nout)``, i.e. rows indexed by the
    *input* grid like the ``_dg`` convention — is contracted along each
    of the three local directions with the same dot_general + output-
    transpose pattern the ``dng`` operator layout uses, so one kernel
    serves both directions: ``mt = J^T`` prolongs (coarse -> fine),
    ``mt = J`` restricts (fine -> coarse, the unweighted core of the
    c-weighted adjoint — the c-multiply / gather-scatter / mask around
    it stay outside).  Purely element-local (interpolation never crosses
    element faces), so there is no halo or plane side channel and slab
    splits are fp64-bitwise by construction.

    Refs: u_ref (block_e, nin^3), mt_ref (nin, nout),
    v_ref (block_e, nout^3).
    """
    f32 = _accum(u_ref.dtype, acc_dtype)
    mt = mt_ref[...].astype(f32)
    u = u_ref[...].astype(f32).reshape(block_e, nin, nin, nin)
    v = _dg(u, mt, 3)                           # (e, k, j, io)
    v = _dg(v, mt, 2).transpose(0, 1, 3, 2)     # (e, k, jo, io)
    v = _dg(v, mt, 1).transpose(0, 3, 1, 2)     # (e, ko, jo, io)
    v_ref[...] = v.reshape(block_e, nout ** 3).astype(v_ref.dtype)


@functools.partial(jax.jit, static_argnames=("nin", "nout", "grid", "sz",
                                             "interpret", "acc_dtype",
                                             "grid_order"))
def nekbone_interp_pallas(u2: jnp.ndarray, mt: jnp.ndarray, *, nin: int,
                          nout: int, grid: tuple[int, int, int], sz: int,
                          interpret: bool = False,
                          acc_dtype: str | None = None,
                          grid_order: str = "parallel") -> jnp.ndarray:
    """pallas_call wrapper for :func:`nekbone_interp_kernel`.

    ``u2`` is ``(E, nin^3)`` flat-local; ``mt`` is ``(nin, nout)``;
    returns ``(E, nout^3)`` in the storage dtype of ``u2``.  Blocked by
    z-slabs of ``sz`` element layers like the rest of the slab family
    (same BlockSpec shape, grid and dimension-semantics machinery) so a
    V-cycle level reuses its autotuned slab split for the transfers.
    """
    ex, ey, ez = grid
    assert ez % sz == 0, (grid, sz)
    block_e = sz * ey * ex
    nblk = ez // sz
    E = nblk * block_e
    assert u2.shape == (E, nin ** 3), (u2.shape, (E, nin ** 3))
    assert mt.shape == (nin, nout), (mt.shape, (nin, nout))
    return pl.pallas_call(
        functools.partial(nekbone_interp_kernel, nin=nin, nout=nout,
                          block_e=block_e, acc_dtype=acc_dtype),
        grid=(nblk,),
        in_specs=[
            pl.BlockSpec((block_e, nin ** 3), lambda i: (i, 0)),
            pl.BlockSpec((nin, nout), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_e, nout ** 3), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((E, nout ** 3), u2.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=(grid_order,),
        ),
        interpret=interpret,
        name=(f"nekbone_interp_{nin}to{nout}_sz{sz}{_acc_tag(acc_dtype)}"
              f"{_cfg_tag('fold', grid_order)}"),
    )(u2, mt)
