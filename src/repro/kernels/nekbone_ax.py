"""Pallas-TPU kernel for the Nekbone local Poisson operator (paper §IV-C).

This is the paper's optimized ``Ax`` kernel re-derived for the TPU memory
hierarchy (DESIGN.md §2).  The CUDA version marches an ``n x n`` thread layer
through the element's k-layers keeping the derivative matrix in shared memory
and per-thread columns in registers; the TPU version instead keeps a *block
of elements* fully resident in VMEM and folds the element/layer axes into the
M dimension of skinny matmuls so the MXU sees large, lane-aligned operands.

Both contraction stages and the metric application are fused into one kernel:
``u`` and the six metric fields are read from HBM exactly once and only ``w``
is written — the 7-read/1-write traffic floor of the operator (the paper's
Eq. 2 counts 24+6 streams for the *whole CG iteration*; the operator itself
is 7+1).

HBM layout: callers pass natural ``(E, n, n, n)`` arrays; the wrapper
(`ops.nekbone_ax`) reshapes them (free, row-major) to ``(E, n^3)`` /
``(E, 6, n^3)`` so the minor dimension is ~n^3 (lane padding 1000 -> 1024,
2.4 % waste) instead of ``n`` (10 -> 128, 12.8x waste).

The kernel is generic in ``n`` (tested 2..16) and in the element block size
``block_e`` — the TPU analog of the paper's claim that the 2-D-thread kernel
is "not bound by shared memory" and ports across polynomial degrees "by only
changing a few constants".
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

__all__ = ["nekbone_ax_kernel", "nekbone_ax_pallas"]


def _dot(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """2-D matmul; f32 accumulation on the MXU (f64 stays f64: the paper's
    precision, exercised through interpret mode on CPU)."""
    acc = jnp.float64 if a.dtype == jnp.float64 else jnp.float32
    return jax.lax.dot(a, b, preferred_element_type=acc)


def nekbone_ax_kernel(u_ref, d_ref, dt_ref, g_ref, w_ref, *, n: int,
                      block_e: int):
    """Fused  w = D^T ( G (D u) )  for one block of ``block_e`` elements.

    Refs (VMEM blocks):
      u_ref:  (block_e, n^3)    nodal values
      d_ref:  (n, n)            derivative matrix D (dxm1)
      dt_ref: (n, n)            D^T (dxtm1) — passed separately so the kernel
                                body issues only layout-friendly matmuls
      g_ref:  (block_e, 6, n^3) metric (rr, rs, rt, ss, st, tt)
      w_ref:  (block_e, n^3)    output
    """
    e, n3 = block_e, n ** 3
    f32 = jnp.float64 if u_ref.dtype == jnp.float64 else jnp.float32
    u = u_ref[...].astype(f32)
    D = d_ref[...].astype(f32)
    Dt = dt_ref[...].astype(f32)

    # ---- forward gradient: fold (e,k,j) / (e,k,i) / (e,j,i) into M --------
    # wr[e,k,j,i] = sum_l u[e,k,j,l] D[i,l]      (M = e*n^2, K = n, N = n)
    wr = _dot(u.reshape(e * n * n, n), Dt).reshape(e, n, n, n)
    # ws[e,k,j,i] = sum_l u[e,k,l,i] D[j,l]: transpose j<->i, contract, undo.
    u_kij = u.reshape(e, n, n, n).transpose(0, 1, 3, 2)  # (e,k,i,l=j)
    ws = _dot(u_kij.reshape(e * n * n, n), Dt)
    ws = ws.reshape(e, n, n, n).transpose(0, 1, 3, 2)
    # wt[e,k,j,i] = sum_l u[e,l,j,i] D[k,l]: contract the layer axis.
    u_jil = u.reshape(e, n, n * n).transpose(0, 2, 1)    # (e, ji, l=k)
    wt = _dot(u_jil.reshape(e * n * n, n), Dt)
    wt = wt.reshape(e, n * n, n).transpose(0, 2, 1).reshape(e, n, n, n)

    # ---- metric application (element-wise, VPU) ---------------------------
    def gm(m):
        return g_ref[:, m, :].astype(f32).reshape(e, n, n, n)  # noqa: B023

    grr, grs, grt, gss, gst, gtt = (gm(m) for m in range(6))
    ur = grr * wr + grs * ws + grt * wt
    us = grs * wr + gss * ws + gst * wt
    ut = grt * wr + gst * ws + gtt * wt

    # ---- transposed gradient (same shapes, D^T) ---------------------------
    # w += sum_l D[l,i] ur[e,k,j,l]  ==  ur @ D
    w = _dot(ur.reshape(e * n * n, n), D).reshape(e, n, n, n)
    us_kij = us.transpose(0, 1, 3, 2)
    w += _dot(us_kij.reshape(e * n * n, n), D).reshape(e, n, n, n).transpose(0, 1, 3, 2)
    ut_jil = ut.reshape(e, n, n * n).transpose(0, 2, 1)
    wt2 = _dot(ut_jil.reshape(e * n * n, n), D)
    w += wt2.reshape(e, n * n, n).transpose(0, 2, 1).reshape(e, n, n, n)

    w_ref[...] = w.reshape(e, n3).astype(w_ref.dtype)


@functools.partial(jax.jit, static_argnames=("n", "block_e", "interpret"))
def nekbone_ax_pallas(u2: jnp.ndarray, D: jnp.ndarray, Dt: jnp.ndarray,
                      g2: jnp.ndarray, *, n: int, block_e: int,
                      interpret: bool = False) -> jnp.ndarray:
    """pallas_call wrapper on pre-flattened operands.

    Args:
      u2: (E, n^3), g2: (E, 6, n^3), D/Dt: (n, n); E divisible by block_e.
    """
    E = u2.shape[0]
    assert E % block_e == 0, (E, block_e)
    n3 = n ** 3
    grid = (E // block_e,)
    return pl.pallas_call(
        functools.partial(nekbone_ax_kernel, n=n, block_e=block_e),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_e, n3), lambda i: (i, 0)),
            pl.BlockSpec((n, n), lambda i: (0, 0)),
            pl.BlockSpec((n, n), lambda i: (0, 0)),
            pl.BlockSpec((block_e, 6, n3), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_e, n3), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((E, n3), u2.dtype),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
        name=f"nekbone_ax_n{n}_be{block_e}",
    )(u2, D, Dt, g2)
