"""Pallas-TPU kernels for the Nekbone local Poisson operator (paper §IV-C).

This is the paper's optimized ``Ax`` kernel re-derived for the TPU memory
hierarchy (DESIGN.md §2).  The CUDA version marches an ``n x n`` thread layer
through the element's k-layers keeping the derivative matrix in shared memory
and per-thread columns in registers; the TPU version instead keeps a *block
of elements* fully resident in VMEM and folds the element/layer axes into the
M dimension of skinny matmuls so the MXU sees large, lane-aligned operands.

Both contraction stages and the metric application are fused into one kernel:
``u`` and the six metric fields are read from HBM exactly once and only ``w``
is written — the 7-read/1-write traffic floor of the operator (the paper's
Eq. 2 counts 24+6 streams for the *whole CG iteration*; the operator itself
is 7+1).

Two kernels share the block math (:func:`ax_block`):

* :func:`nekbone_ax_kernel` — the plain fused operator (the Fig. 2/3 ladder's
  top rung), 7 reads / 1 write.
* :func:`nekbone_ax_dots_kernel` — the fused *CG-iteration* kernel
  (DESIGN.md §3): in the same VMEM residency it also applies the Dirichlet
  mask and emits per-block partial sums for the two weighted inner products
  a CG iteration needs (``p·c·Ap`` and ``r·c·z``), so the separate reduction
  passes Eq. 2 charges for disappear from the HBM budget.  The ``p·c·Ap``
  partial uses the continuity identity (DESIGN.md §3.2): for a continuous
  ``p``, ``p·c·(mask · gs(w)) == Σ_j p_j (mask·w)_j`` element-locally, so no
  assembled ``w`` is needed inside the kernel.

HBM layout: callers pass natural ``(E, n, n, n)`` arrays; the wrapper
(`ops.nekbone_ax`) reshapes them (free, row-major) to ``(E, n^3)`` /
``(E, 6, n^3)`` so the minor dimension is ~n^3 (lane padding 1000 -> 1024,
2.4 % waste) instead of ``n`` (10 -> 128, 12.8x waste).

The kernels are generic in ``n`` (tested 2..16) and in the element block size
``block_e`` — the TPU analog of the paper's claim that the 2-D-thread kernel
is "not bound by shared memory" and ports across polynomial degrees "by only
changing a few constants".
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

__all__ = ["nekbone_ax_kernel", "nekbone_ax_pallas", "ax_block",
           "nekbone_ax_dots_kernel", "nekbone_ax_dots_pallas"]

from repro.compat import CompilerParams as _CompilerParams


def _dot(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """2-D matmul; f32 accumulation on the MXU (f64 stays f64: the paper's
    precision, exercised through interpret mode on CPU)."""
    acc = jnp.float64 if a.dtype == jnp.float64 else jnp.float32
    return jax.lax.dot(a, b, preferred_element_type=acc)


def ax_block(u: jnp.ndarray, D: jnp.ndarray, Dt: jnp.ndarray,
             g: jnp.ndarray, *, n: int, e: int) -> jnp.ndarray:
    """Block math of  w = D^T ( G (D u) )  on VMEM-resident arrays.

    Args:
      u: (e, n^3) nodal values for one block of ``e`` elements.
      D/Dt: (n, n) derivative matrix and its transpose.
      g: (e, 6, n^3) metric (rr, rs, rt, ss, st, tt).
    Returns (e, n^3), in the accumulation dtype of ``u``.
    """
    # ---- forward gradient: fold (e,k,j) / (e,k,i) / (e,j,i) into M --------
    # wr[e,k,j,i] = sum_l u[e,k,j,l] D[i,l]      (M = e*n^2, K = n, N = n)
    wr = _dot(u.reshape(e * n * n, n), Dt).reshape(e, n, n, n)
    # ws[e,k,j,i] = sum_l u[e,k,l,i] D[j,l]: transpose j<->i, contract, undo.
    u_kij = u.reshape(e, n, n, n).transpose(0, 1, 3, 2)  # (e,k,i,l=j)
    ws = _dot(u_kij.reshape(e * n * n, n), Dt)
    ws = ws.reshape(e, n, n, n).transpose(0, 1, 3, 2)
    # wt[e,k,j,i] = sum_l u[e,l,j,i] D[k,l]: contract the layer axis.
    u_jil = u.reshape(e, n, n * n).transpose(0, 2, 1)    # (e, ji, l=k)
    wt = _dot(u_jil.reshape(e * n * n, n), Dt)
    wt = wt.reshape(e, n * n, n).transpose(0, 2, 1).reshape(e, n, n, n)

    # ---- metric application (element-wise, VPU) ---------------------------
    grr, grs, grt, gss, gst, gtt = (
        g[:, m, :].reshape(e, n, n, n) for m in range(6))
    ur = grr * wr + grs * ws + grt * wt
    us = grs * wr + gss * ws + gst * wt
    ut = grt * wr + gst * ws + gtt * wt

    # ---- transposed gradient (same shapes, D^T) ---------------------------
    # w += sum_l D[l,i] ur[e,k,j,l]  ==  ur @ D
    w = _dot(ur.reshape(e * n * n, n), D).reshape(e, n, n, n)
    us_kij = us.transpose(0, 1, 3, 2)
    w += _dot(us_kij.reshape(e * n * n, n), D).reshape(e, n, n, n).transpose(0, 1, 3, 2)
    ut_jil = ut.reshape(e, n, n * n).transpose(0, 2, 1)
    wt2 = _dot(ut_jil.reshape(e * n * n, n), D)
    w += wt2.reshape(e, n * n, n).transpose(0, 2, 1).reshape(e, n, n, n)
    return w.reshape(e, n ** 3)


def nekbone_ax_kernel(u_ref, d_ref, dt_ref, g_ref, w_ref, *, n: int,
                      block_e: int):
    """Fused  w = D^T ( G (D u) )  for one block of ``block_e`` elements.

    Refs (VMEM blocks):
      u_ref:  (block_e, n^3)    nodal values
      d_ref:  (n, n)            derivative matrix D (dxm1)
      dt_ref: (n, n)            D^T (dxtm1) — passed separately so the kernel
                                body issues only layout-friendly matmuls
      g_ref:  (block_e, 6, n^3) metric (rr, rs, rt, ss, st, tt)
      w_ref:  (block_e, n^3)    output
    """
    f32 = jnp.float64 if u_ref.dtype == jnp.float64 else jnp.float32
    u = u_ref[...].astype(f32)
    D = d_ref[...].astype(f32)
    Dt = dt_ref[...].astype(f32)
    g = g_ref[...].astype(f32)
    w = ax_block(u, D, Dt, g, n=n, e=block_e)
    w_ref[...] = w.astype(w_ref.dtype)


@functools.partial(jax.jit, static_argnames=("n", "block_e", "interpret"))
def nekbone_ax_pallas(u2: jnp.ndarray, D: jnp.ndarray, Dt: jnp.ndarray,
                      g2: jnp.ndarray, *, n: int, block_e: int,
                      interpret: bool = False) -> jnp.ndarray:
    """pallas_call wrapper on pre-flattened operands.

    Args:
      u2: (E, n^3), g2: (E, 6, n^3), D/Dt: (n, n); E divisible by block_e.
    """
    E = u2.shape[0]
    assert E % block_e == 0, (E, block_e)
    n3 = n ** 3
    grid = (E // block_e,)
    return pl.pallas_call(
        functools.partial(nekbone_ax_kernel, n=n, block_e=block_e),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_e, n3), lambda i: (i, 0)),
            pl.BlockSpec((n, n), lambda i: (0, 0)),
            pl.BlockSpec((n, n), lambda i: (0, 0)),
            pl.BlockSpec((block_e, 6, n3), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_e, n3), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((E, n3), u2.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
        name=f"nekbone_ax_n{n}_be{block_e}",
    )(u2, D, Dt, g2)


# ---------------------------------------------------------------------------
# Fused CG-iteration kernel: masked Ax + per-block partial inner products
# ---------------------------------------------------------------------------

def nekbone_ax_dots_kernel(p_ref, d_ref, dt_ref, g_ref, mask_ref, r_ref,
                           c_ref, w_ref, pap_ref, rcz_ref, *, n: int,
                           block_e: int):
    """Masked Ax plus the two CG inner-product partials, one element block.

    In the same VMEM residency as the operator this computes

        w   = mask * (D^T G D p)                    (block output)
        pap = sum(p * w)                            (per-block partial)
        rcz = sum(r * c * r)                        (per-block partial)

    ``pap`` relies on ``p`` being continuous (all copies of a shared node
    equal — the CG invariant): then ``Σ_blocks pap == p·c·A p`` with
    ``A = mask ∘ gs ∘ ax_local``, because the gather-scatter transfers onto
    the other factor of the product (DESIGN.md §3.2).  ``rcz`` is the
    weighted residual norm ``r·c·z`` with ``z = r`` (unpreconditioned CG).

    Refs (VMEM blocks):
      p_ref:    (block_e, n^3)     search direction
      d_ref:    (n, n)             D;  dt_ref: (n, n)  D^T
      g_ref:    (block_e, 6, n^3)  metric
      mask_ref: (block_e, n^3)     Dirichlet mask (0/1)
      r_ref:    (block_e, n^3)     residual
      c_ref:    (block_e, n^3)     inner-product weight  mask/multiplicity
      w_ref:    (block_e, n^3)     masked local Ax output
      pap_ref:  (1, 1)             partial  Σ p * w
      rcz_ref:  (1, 1)             partial  Σ r * c * r
    """
    f32 = jnp.float64 if p_ref.dtype == jnp.float64 else jnp.float32
    p = p_ref[...].astype(f32)
    D = d_ref[...].astype(f32)
    Dt = dt_ref[...].astype(f32)
    g = g_ref[...].astype(f32)
    w = ax_block(p, D, Dt, g, n=n, e=block_e)
    w = w * mask_ref[...].astype(f32)

    r = r_ref[...].astype(f32)
    c = c_ref[...].astype(f32)
    pap_ref[0, 0] = jnp.sum(p * w).astype(pap_ref.dtype)
    rcz_ref[0, 0] = jnp.sum(r * c * r).astype(rcz_ref.dtype)
    w_ref[...] = w.astype(w_ref.dtype)


@functools.partial(jax.jit, static_argnames=("n", "block_e", "interpret"))
def nekbone_ax_dots_pallas(p2: jnp.ndarray, D: jnp.ndarray, Dt: jnp.ndarray,
                           g2: jnp.ndarray, mask2: jnp.ndarray,
                           r2: jnp.ndarray, c2: jnp.ndarray, *, n: int,
                           block_e: int, interpret: bool = False):
    """Multi-output pallas_call for the fused CG iteration.

    Args: all field operands pre-flattened to (E, n^3) (g2: (E, 6, n^3));
    E divisible by block_e.  Returns ``(w2, pap_parts, rcz_parts)`` with the
    partials of shape ``(E // block_e, 1)`` — tree-reduce them with
    ``jnp.sum`` on the host side of the call.

    Partials accumulate in f32 for <=f32 inputs and f64 for f64 (the paper's
    precision, exercised through interpret mode).
    """
    E = p2.shape[0]
    assert E % block_e == 0, (E, block_e)
    n3 = n ** 3
    nblk = E // block_e
    acc = jnp.float64 if p2.dtype == jnp.float64 else jnp.float32
    field = pl.BlockSpec((block_e, n3), lambda i: (i, 0))
    part = pl.BlockSpec((1, 1), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(nekbone_ax_dots_kernel, n=n, block_e=block_e),
        grid=(nblk,),
        in_specs=[
            field,                                      # p
            pl.BlockSpec((n, n), lambda i: (0, 0)),     # D
            pl.BlockSpec((n, n), lambda i: (0, 0)),     # Dt
            pl.BlockSpec((block_e, 6, n3), lambda i: (i, 0, 0)),  # g
            field,                                      # mask
            field,                                      # r
            field,                                      # c
        ],
        out_specs=(field, part, part),
        out_shape=(
            jax.ShapeDtypeStruct((E, n3), p2.dtype),
            jax.ShapeDtypeStruct((nblk, 1), acc),
            jax.ShapeDtypeStruct((nblk, 1), acc),
        ),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
        name=f"nekbone_ax_dots_n{n}_be{block_e}",
    )(p2, D, Dt, g2, mask2, r2, c2)
