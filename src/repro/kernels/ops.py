"""Jitted public wrappers around the Pallas kernels.

Each wrapper:
  * accepts natural shapes and reshapes/pads to the kernel's HBM layout,
  * picks ``interpret=True`` automatically off-TPU (this container is
    CPU-only; the TPU lowering is exercised structurally by the dry-run),
  * exposes the tuning knobs (block sizes) with roofline-reasoned defaults.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import autotune as _autotune
from repro.kernels import flash_attn as _flash
from repro.kernels import nekbone_ax as _ax
from repro.kernels import wkv6 as _wkv6

__all__ = ["nekbone_ax", "nekbone_ax_dots", "flash_attention", "wkv6",
           "default_interpret"]


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pick_block_e(E: int, n: int, vmem_budget_bytes: int = 8 * 2 ** 20) -> int:
    """Back-compat alias for the VMEM heuristic (see kernels/autotune.py).

    Default ``block_e`` selection now goes through the cached
    :func:`repro.kernels.autotune.pick_block_e`, which measures candidates
    on real TPUs; this name is kept for callers of the static heuristic.
    """
    return _autotune.vmem_block_e(E, n, vmem_budget_bytes)


@functools.partial(jax.jit,
                   static_argnames=("block_e", "interpret"))
def _nekbone_ax_impl(u, D, Dt, g, block_e, interpret):
    E = u.shape[0]
    n = u.shape[-1]
    u2 = u.reshape(E, n ** 3)
    g2 = g.reshape(E, 6, n ** 3)
    w2 = _ax.nekbone_ax_pallas(u2, D, Dt, g2, n=n, block_e=block_e,
                               interpret=interpret)
    return w2.reshape(u.shape)


def nekbone_ax(u: jnp.ndarray, D: jnp.ndarray, g: jnp.ndarray, *,
               block_e: int | None = None,
               interpret: bool | None = None) -> jnp.ndarray:
    """Fused local Poisson operator  w = D^T (G (D u)).

    Args:
      u: (E, n, n, n) nodal values, layout [e, k, j, i].
      D: (n, n) derivative matrix (dxm1).
      g: (E, 6, n, n, n) metric fields (rr, rs, rt, ss, st, tt).
      block_e: elements per VMEM block (default: autotuned to ~8 MiB).
      interpret: force Pallas interpret mode (defaults to off-TPU detection).

    Elements are zero-padded to a multiple of ``block_e`` if needed.
    """
    E = u.shape[0]
    n = u.shape[-1]
    interpret = default_interpret() if interpret is None else interpret
    block_e = block_e or _autotune.pick_block_e(E, n, u.dtype)
    pad = (-E) % block_e
    if pad:
        u = jnp.concatenate([u, jnp.zeros((pad,) + u.shape[1:], u.dtype)])
        g = jnp.concatenate([g, jnp.zeros((pad,) + g.shape[1:], g.dtype)])
    w = _nekbone_ax_impl(u, D, jnp.asarray(D).T, g, block_e, interpret)
    return w[:E] if pad else w


def nekbone_ax_dots(p: jnp.ndarray, D: jnp.ndarray, g: jnp.ndarray,
                    mask: jnp.ndarray, r: jnp.ndarray, c: jnp.ndarray, *,
                    block_e: int | None = None,
                    interpret: bool | None = None):
    """Fused CG-iteration kernel: masked local Ax + the two inner products.

    Args:
      p, r: (E, n, n, n) search direction / residual (p continuous).
      D: (n, n); g: (E, 6, n, n, n); mask, c: (E, n, n, n).

    Returns ``(w, pap, rcz)``: the *masked local* operator output (still to
    be assembled with gs — mask and gs commute) and the tree-reduced scalars
    ``pap == p·c·(mask gs w)`` and ``rcz == r·c·r``.  Zero-padded blocks
    contribute zero to both partials, so arbitrary E is safe.
    """
    E = p.shape[0]
    n = p.shape[-1]
    interpret = default_interpret() if interpret is None else interpret
    block_e = block_e or _autotune.pick_block_e(E, n, p.dtype)
    pad = (-E) % block_e
    if pad:
        def zpad(x):
            return jnp.concatenate(
                [x, jnp.zeros((pad,) + x.shape[1:], x.dtype)])

        p, g, mask, r, c = map(zpad, (p, g, mask, r, c))
    Ep = p.shape[0]
    n3 = n ** 3
    w2, pap_b, rcz_b = _ax.nekbone_ax_dots_pallas(
        p.reshape(Ep, n3), jnp.asarray(D), jnp.asarray(D).T,
        g.reshape(Ep, 6, n3), mask.reshape(Ep, n3), r.reshape(Ep, n3),
        c.reshape(Ep, n3), n=n, block_e=block_e, interpret=interpret)
    w = w2.reshape(Ep, n, n, n)
    return (w[:E] if pad else w), jnp.sum(pap_b), jnp.sum(rcz_b)


def flash_attention(q, k, v, *, causal: bool = True, scale: float | None = None,
                    window: int | None = None, softcap: float | None = None,
                    q_offset: int = 0, block_q: int = 512, block_k: int = 512,
                    interpret: bool | None = None):
    """Block online-softmax attention (prefill hot-spot). See flash_attn.py."""
    interpret = default_interpret() if interpret is None else interpret
    return _flash.flash_attention(
        q, k, v, causal=causal, scale=scale, window=window, softcap=softcap,
        q_offset=q_offset, block_q=block_q, block_k=block_k,
        interpret=interpret)


def wkv6(r, k, v, w, u, *, initial_state=None, return_state: bool = False,
         block_t: int = 16, variant: str = "chunked",
         interpret: bool | None = None):
    """RWKV6 linear-attention recurrence (state streamed through VMEM)."""
    interpret = default_interpret() if interpret is None else interpret
    return _wkv6.wkv6(r, k, v, w, u, initial_state=initial_state,
                      return_state=return_state, block_t=block_t,
                      variant=variant, interpret=interpret)
