"""Jitted public wrappers around the Pallas kernels.

Each wrapper:
  * accepts natural shapes and reshapes/pads to the kernel's HBM layout,
  * picks ``interpret=True`` automatically off-TPU (this container is
    CPU-only; the TPU lowering is exercised structurally by the dry-run),
  * exposes the tuning knobs (block sizes) with roofline-reasoned defaults.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import autotune as _autotune
from repro.kernels import flash_attn as _flash
from repro.kernels import nekbone_ax as _ax
from repro.kernels import wkv6 as _wkv6

__all__ = ["nekbone_ax", "nekbone_ax_dots", "nekbone_ax_dots_slab",
           "nekbone_ax_dots_slab_block", "nekbone_cg_update",
           "nekbone_cg_update_block", "nekbone_ax_powers",
           "nekbone_sstep_update", "nekbone_pcg_update",
           "nekbone_cheb_precond", "nekbone_interp", "slab_axis_factors",
           "diag_metric", "flash_attention", "wkv6", "default_interpret"]


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pick_block_e(E: int, n: int, vmem_budget_bytes: int = 8 * 2 ** 20) -> int:
    """Back-compat alias for the VMEM heuristic (see kernels/autotune.py).

    Default ``block_e`` selection now goes through the cached
    :func:`repro.kernels.autotune.pick_block_e`, which measures candidates
    on real TPUs; this name is kept for callers of the static heuristic.
    """
    return _autotune.vmem_block_e(E, n, vmem_budget_bytes)


@functools.partial(jax.jit,
                   static_argnames=("block_e", "interpret"))
def _nekbone_ax_impl(u, D, Dt, g, block_e, interpret):
    E = u.shape[0]
    n = u.shape[-1]
    u2 = u.reshape(E, n ** 3)
    g2 = g.reshape(E, 6, n ** 3)
    w2 = _ax.nekbone_ax_pallas(u2, D, Dt, g2, n=n, block_e=block_e,
                               interpret=interpret)
    return w2.reshape(u.shape)


def nekbone_ax(u: jnp.ndarray, D: jnp.ndarray, g: jnp.ndarray, *,
               block_e: int | None = None,
               interpret: bool | None = None) -> jnp.ndarray:
    """Fused local Poisson operator  w = D^T (G (D u)).

    Args:
      u: (E, n, n, n) nodal values, layout [e, k, j, i].
      D: (n, n) derivative matrix (dxm1).
      g: (E, 6, n, n, n) metric fields (rr, rs, rt, ss, st, tt).
      block_e: elements per VMEM block (default: autotuned to ~8 MiB).
      interpret: force Pallas interpret mode (defaults to off-TPU detection).

    Elements are zero-padded to a multiple of ``block_e`` if needed.
    """
    E = u.shape[0]
    n = u.shape[-1]
    interpret = default_interpret() if interpret is None else interpret
    block_e = block_e or _autotune.pick_block_e(E, n, u.dtype)
    pad = (-E) % block_e
    if pad:
        u = jnp.concatenate([u, jnp.zeros((pad,) + u.shape[1:], u.dtype)])
        g = jnp.concatenate([g, jnp.zeros((pad,) + g.shape[1:], g.dtype)])
    w = _nekbone_ax_impl(u, D, jnp.asarray(D).T, g, block_e, interpret)
    return w[:E] if pad else w


def nekbone_ax_dots(p: jnp.ndarray, D: jnp.ndarray, g: jnp.ndarray,
                    mask: jnp.ndarray, r: jnp.ndarray, c: jnp.ndarray, *,
                    block_e: int | None = None,
                    interpret: bool | None = None):
    """Fused CG-iteration kernel: masked local Ax + the two inner products.

    Args:
      p, r: (E, n, n, n) search direction / residual (p continuous).
      D: (n, n); g: (E, 6, n, n, n); mask, c: (E, n, n, n).

    Returns ``(w, pap, rcz)``: the *masked local* operator output (still to
    be assembled with gs — mask and gs commute) and the tree-reduced scalars
    ``pap == p·c·(mask gs w)`` and ``rcz == r·c·r``.  Zero-padded blocks
    contribute zero to both partials, so arbitrary E is safe.
    """
    E = p.shape[0]
    n = p.shape[-1]
    interpret = default_interpret() if interpret is None else interpret
    block_e = block_e or _autotune.pick_block_e(E, n, p.dtype)
    pad = (-E) % block_e
    if pad:
        def zpad(x):
            return jnp.concatenate(
                [x, jnp.zeros((pad,) + x.shape[1:], x.dtype)])

        p, g, mask, r, c = map(zpad, (p, g, mask, r, c))
    Ep = p.shape[0]
    n3 = n ** 3
    w2, pap_b, rcz_b = _ax.nekbone_ax_dots_pallas(
        p.reshape(Ep, n3), jnp.asarray(D), jnp.asarray(D).T,
        g.reshape(Ep, 6, n3), mask.reshape(Ep, n3), r.reshape(Ep, n3),
        c.reshape(Ep, n3), n=n, block_e=block_e, interpret=interpret)
    w = w2.reshape(Ep, n, n, n)
    return (w[:E] if pad else w), jnp.sum(pap_b), jnp.sum(rcz_b)


def slab_axis_factors(grid: tuple[int, int, int], n: int, dtype):
    """Per-axis mask and c factors of the structured box, as jnp arrays.

    Thin dtype-casting wrapper over :func:`repro.core.geom.box_axis_factors`
    (the single source of the factorization); the factor values (0, 1, 1/2)
    are exact in every supported dtype, so the in-kernel outer products
    reproduce the full fields bitwise.
    """
    from repro.core.geom import box_axis_factors

    masks, cs = box_axis_factors(grid, n)
    return (tuple(jnp.asarray(m, dtype) for m in masks),
            tuple(jnp.asarray(c, dtype) for c in cs))


def diag_metric(g: jnp.ndarray, E: int, n: int) -> jnp.ndarray:
    """Pack the metric to its (rr, ss, tt) diagonal, shape (E, 3, n^3).

    Accepts an already-packed (E, 3, ...) metric, or the general 6-component
    one when its off-diagonal entries are (verifiably) zero — true for every
    axis-aligned ``BoxMesh``.  Tracers skip the check (callers under jit
    close over concrete mesh fields, so the check ran at trace time).
    """
    import numpy as np

    from repro.core.geom import GEOM_RR, GEOM_RS, GEOM_RT, GEOM_SS, GEOM_ST, \
        GEOM_TT

    if g.shape[1] == 3:
        return g.reshape(E, 3, n ** 3)
    if g.shape[1] != 6:
        raise ValueError(f"metric must have 3 or 6 components, got {g.shape}")
    try:
        off = np.asarray(g[:, (GEOM_RS, GEOM_RT, GEOM_ST)])
        if off.any():
            raise ValueError(
                "the slab (v2) pipeline requires an axis-aligned (diagonal-"
                "metric) mesh; off-diagonal metric entries are non-zero")
    except jax.errors.TracerArrayConversionError:
        pass
    return g[:, (GEOM_RR, GEOM_SS, GEOM_TT)].reshape(E, 3, n ** 3)


def nekbone_ax_dots_slab(p_prev: jnp.ndarray, r: jnp.ndarray,
                         D: jnp.ndarray, g3: jnp.ndarray,
                         grid: tuple[int, int, int], *, beta: float = 0.0,
                         sz: int | None = None,
                         layout: str | None = None,
                         grid_order: str | None = None,
                         interpret: bool | None = None,
                         acc_dtype: str | None = None):
    """v2 slab dots kernel on natural shapes, with the planes stitched.

    Computes ``p = r + beta * p_prev`` and the *fully assembled* masked
    operator output ``w = mask * gs(D^T G D p)`` — the kernel performs the
    x/y and intra-block z direct-stiffness summation in VMEM, and this
    wrapper adds the cross-block boundary planes host-side (the fused CG
    driver stitches them inside the update kernel instead).

    Args:
      p_prev, r: (E, n, n, n); elements z-major over ``grid``.
      D: (n, n); g3: (E, 3, n, n, n) metric diagonal (rr, ss, tt), or the
         full (E, 6, ...) metric of an axis-aligned box (off-diagonals
         validated zero, then dropped — see :func:`diag_metric`).
      grid: (EX, EY, EZ); beta: direction-update scalar.
      sz: slabs per block (default: autotuned divisor of EZ).
      layout, grid_order: contraction layout / grid iteration order
        (defaults: jointly autotuned with sz when all three are None,
        see :func:`repro.kernels.autotune.pick_slab_config`; otherwise
        the historical ``("fold", "parallel")``).
      acc_dtype: explicit in-kernel accumulation dtype (precision policy).

    Returns ``(p, w, pap)`` with ``pap == p·c·(mask gs w_local)`` tree-
    reduced from the per-block partials.
    """
    ex, ey, ez = grid = tuple(grid)
    E = p_prev.shape[0]
    n = p_prev.shape[-1]
    interpret = default_interpret() if interpret is None else interpret
    if sz is None and layout is None and grid_order is None:
        sz, layout, grid_order = _autotune.pick_slab_config(
            grid, n, p_prev.dtype, acc_dtype=acc_dtype)
    elif sz is None:
        sz = _autotune.pick_slab_sz(grid, n, p_prev.dtype,
                                    acc_dtype=acc_dtype)
    layout = "fold" if layout is None else layout
    grid_order = "parallel" if grid_order is None else grid_order
    n3 = n ** 3
    nblk = ez // sz
    (mx, my, mz), _ = slab_axis_factors(grid, n, p_prev.dtype)
    D = jnp.asarray(D, p_prev.dtype)
    g3 = diag_metric(jnp.asarray(g3, p_prev.dtype), E, n)
    acc = _ax._accum(p_prev.dtype, acc_dtype)
    beta_arr = jnp.full((1, 1), beta, acc)
    p2, w2, bot, top, pap_b = _ax.nekbone_ax_slab_pallas(
        p_prev.reshape(E, n3), r.reshape(E, n3), D, D.T,
        g3, mx, my, mz,
        beta_arr, n=n, grid=grid, sz=sz, interpret=interpret,
        acc_dtype=acc_dtype, layout=layout, grid_order=grid_order)
    vb = w2.reshape(nblk, sz, ey, ex, n, n, n)
    plane = (nblk - 1, ey, ex, n, n)
    if nblk > 1:
        vb = vb.at[1:, 0, :, :, 0, :, :].add(top[:-1].reshape(plane))
        vb = vb.at[:-1, -1, :, :, -1, :, :].add(bot[1:].reshape(plane))
    return (p2.reshape(p_prev.shape), vb.reshape(p_prev.shape),
            jnp.sum(pap_b))


def nekbone_ax_powers(p: jnp.ndarray, r: jnp.ndarray, D: jnp.ndarray,
                      g3: jnp.ndarray, grid: tuple[int, int, int], *,
                      s: int, theta: float = 1.0, sz: int | None = None,
                      layout: str | None = None,
                      grid_order: str | None = None,
                      interpret: bool | None = None,
                      acc_dtype: str | None = None):
    """v3 matrix-powers kernel on natural shapes (DESIGN.md §8).

    Builds the halo windows (``halo = s`` slabs, zero-padded past the
    domain) and evaluates the scaled Krylov basis of one s-step cycle —
    ``A' = (mask gs ax_local) / theta`` chained s times from ``p`` and
    s-1 times from ``r`` — plus the (2s+1)^2 Gram block of
    ``V = [p, A'p.., r, A'r..]`` under the weight ``c``.

    Args:
      p, r: (E, n, n, n), z-major over ``grid``; both continuous+masked.
      D: (n, n); g3: diagonal (E, 3, ...) or verifiably-diagonal 6-component
         metric; theta: basis scale (``A' = A/theta``).
      s: powers per cycle (>= 1); sz: slabs per block (default: autotuned).
      layout, grid_order: contraction layout / grid iteration order
        (defaults: jointly autotuned with sz when all three are None,
        see :func:`repro.kernels.autotune.pick_sstep_config`).

    Returns ``(basis, gram)``: basis ``(E, 2s-1, n, n, n)`` holding
    ``[A'p..A'^s p, A'r..A'^{s-1} r]`` and the summed ``(2s+1, 2s+1)``
    Gram matrix in the accumulation dtype.
    """
    ex, ey, ez = grid = tuple(grid)
    E = p.shape[0]
    n = p.shape[-1]
    interpret = default_interpret() if interpret is None else interpret
    if sz is None and layout is None and grid_order is None:
        sz, layout, grid_order = _autotune.pick_sstep_config(
            grid, n, s, p.dtype, acc_dtype=acc_dtype)
    elif sz is None:
        sz = _autotune.pick_slab_sz_sstep(grid, n, s, p.dtype,
                                          acc_dtype=acc_dtype)
    layout = "fold" if layout is None else layout
    grid_order = "parallel" if grid_order is None else grid_order
    n3 = n ** 3
    (mx, my, mz), (cx, cy, cz) = slab_axis_factors(grid, n, p.dtype)
    D = jnp.asarray(D, p.dtype)
    g3 = diag_metric(jnp.asarray(g3, p.dtype), E, n)
    acc = _ax._accum(p.dtype, acc_dtype)
    pext = _ax.sstep_extend_field(p.reshape(E, n3), grid, sz, s)
    rext = _ax.sstep_extend_field(r.reshape(E, n3), grid, sz, s)
    gext = _ax.sstep_extend_field(g3, grid, sz, s)
    mzext = _ax.sstep_extend_zfactor(mz, sz, s)
    inv_theta = jnp.full((1, 1), 1.0 / theta, acc)
    basis, gram_b = _ax.nekbone_ax_powers_pallas(
        pext, rext, D, D.T, gext, mx, my, mzext, cx, cy, cz, inv_theta,
        n=n, grid=grid, sz=sz, s=s, interpret=interpret, acc_dtype=acc_dtype,
        layout=layout, grid_order=grid_order)
    return (basis.reshape(E, 2 * s - 1, n, n, n), jnp.sum(gram_b, axis=0))


def nekbone_sstep_update(x: jnp.ndarray, p: jnp.ndarray, r: jnp.ndarray,
                         basis: jnp.ndarray, coef: jnp.ndarray,
                         grid: tuple[int, int, int], *, s: int,
                         sz: int | None = None,
                         interpret: bool | None = None,
                         acc_dtype: str | None = None):
    """v3 multi-axpy s-step update kernel on natural shapes.

    Applies the whole cycle of vector updates from the f64 recurrence
    coefficients: ``x += V e``, ``r = V b``, ``p = V a`` with ``V`` in the
    powers kernel's column order, plus the post-cycle weighted norm
    ``sum(r_new * c * r_new)`` (``c`` rebuilt in-kernel).

    Args:
      x, p, r: (E, n, n, n); basis: (E, 2s-1, n, n, n) from
      :func:`nekbone_ax_powers`; coef: (3, 2s+1) rows (e, b, a).

    Returns ``(x_new, r_new, p_new, rcr)``.
    """
    ex, ey, ez = grid = tuple(grid)
    E = x.shape[0]
    n = x.shape[-1]
    interpret = default_interpret() if interpret is None else interpret
    if sz is None:
        sz = _autotune.pick_slab_sz_sstep(grid, n, s, p.dtype,
                                          acc_dtype=acc_dtype)
    n3 = n ** 3
    _, (cx, cy, cz) = slab_axis_factors(grid, n, x.dtype)
    acc = _ax._accum(x.dtype, acc_dtype)
    x2, r2, p2, rcr_b = _ax.nekbone_sstep_update_pallas(
        x.reshape(E, n3), p.reshape(E, n3), r.reshape(E, n3),
        basis.reshape(E, 2 * s - 1, n3), jnp.asarray(coef, acc),
        cx, cy, cz, n=n, grid=grid, sz=sz, s=s, interpret=interpret,
        acc_dtype=acc_dtype)
    return (x2.reshape(x.shape), r2.reshape(x.shape), p2.reshape(x.shape),
            jnp.sum(rcr_b))


def nekbone_cg_update(x: jnp.ndarray, p: jnp.ndarray, r: jnp.ndarray,
                      w: jnp.ndarray, alpha: float,
                      grid: tuple[int, int, int], *,
                      addb: jnp.ndarray | None = None,
                      addt: jnp.ndarray | None = None,
                      sz: int | None = None,
                      interpret: bool | None = None,
                      acc_dtype: str | None = None):
    """Merged CG vector-update kernel on natural shapes.

    Computes ``x + alpha p``, ``r - alpha (w + planes)`` and the weighted
    norm ``sum(r_new * c * r_new)`` of the updated residual, with ``c``
    rebuilt in-kernel from the box's per-axis factors.

    Args:
      x, p, r, w: (E, n, n, n); grid: (EX, EY, EZ); alpha: step scalar.
      addb/addt: optional (EZ//sz, EY*EX*n^2) boundary planes added at each
                 block's bottom/top before the axpy (default zeros).

    Returns ``(x_new, r_new, rtz_new)``.
    """
    ex, ey, ez = grid = tuple(grid)
    E = x.shape[0]
    n = x.shape[-1]
    interpret = default_interpret() if interpret is None else interpret
    if sz is None:
        sz = _autotune.pick_slab_sz(grid, n, x.dtype, acc_dtype=acc_dtype)
    n3 = n ** 3
    nblk = ez // sz
    pln = ey * ex * n * n
    _, (cx, cy, cz) = slab_axis_factors(grid, n, x.dtype)
    acc = _ax._accum(x.dtype, acc_dtype)
    if addb is None:
        addb = jnp.zeros((nblk, pln), x.dtype)
    if addt is None:
        addt = jnp.zeros((nblk, pln), x.dtype)
    alpha_arr = jnp.full((1, 1), alpha, acc)
    x2, r2, rcr_b = _ax.nekbone_cg_update_pallas(
        x.reshape(E, n3), p.reshape(E, n3), r.reshape(E, n3),
        w.reshape(E, n3), addb.reshape(nblk, pln), addt.reshape(nblk, pln),
        alpha_arr, cx, cy, cz, n=n, grid=grid, sz=sz, interpret=interpret,
        acc_dtype=acc_dtype)
    return x2.reshape(x.shape), r2.reshape(x.shape), jnp.sum(rcr_b)


def nekbone_ax_dots_slab_block(p_prev: jnp.ndarray, r: jnp.ndarray,
                               D: jnp.ndarray, g3: jnp.ndarray,
                               grid: tuple[int, int, int], *,
                               beta=0.0, sz: int | None = None,
                               layout: str | None = None,
                               grid_order: str | None = None,
                               interpret: bool | None = None,
                               acc_dtype: str | None = None):
    """Batched v2 slab dots kernel on natural shapes (DESIGN.md §12).

    The multi-RHS sibling of :func:`nekbone_ax_dots_slab`: ``p_prev``/``r``
    carry a leading RHS-batch axis (b, E, n, n, n) and ``beta`` is a scalar
    or length-b vector.  The operator residents (D, metric diagonals, mask
    factors) are loaded once per slab residency and shared across the
    batch; the cross-block boundary planes are stitched host-side here.

    Returns ``(p, w, pap)`` with ``pap`` a length-b vector of per-RHS
    ``p·c·(mask gs w_local)`` partial reductions.
    """
    ex, ey, ez = grid = tuple(grid)
    nrhs, E = p_prev.shape[0], p_prev.shape[1]
    n = p_prev.shape[-1]
    interpret = default_interpret() if interpret is None else interpret
    if sz is None and layout is None and grid_order is None:
        sz, layout, grid_order = _autotune.pick_slab_config(
            grid, n, p_prev.dtype, acc_dtype=acc_dtype, nrhs=nrhs)
    elif sz is None:
        sz = _autotune.pick_slab_sz(grid, n, p_prev.dtype,
                                    acc_dtype=acc_dtype, nrhs=nrhs)
    layout = "fold" if layout is None else layout
    grid_order = "parallel" if grid_order is None else grid_order
    n3 = n ** 3
    nblk = ez // sz
    (mx, my, mz), _ = slab_axis_factors(grid, n, p_prev.dtype)
    D = jnp.asarray(D, p_prev.dtype)
    g3 = diag_metric(jnp.asarray(g3, p_prev.dtype), E, n)
    acc = _ax._accum(p_prev.dtype, acc_dtype)
    beta_arr = jnp.broadcast_to(jnp.asarray(beta, acc),
                                (nrhs,)).reshape(1, nrhs)
    p3, w3, bot, top, pap_b = _ax.nekbone_ax_slab_block_pallas(
        p_prev.reshape(nrhs, E, n3), r.reshape(nrhs, E, n3), D, D.T,
        g3, mx, my, mz, beta_arr, n=n, grid=grid, sz=sz,
        interpret=interpret, acc_dtype=acc_dtype, layout=layout,
        grid_order=grid_order)
    vb = w3.reshape(nrhs, nblk, sz, ey, ex, n, n, n)
    plane = (nrhs, nblk - 1, ey, ex, n, n)
    if nblk > 1:
        vb = vb.at[:, 1:, 0, :, :, 0, :, :].add(
            top[:, :-1].reshape(plane))
        vb = vb.at[:, :-1, -1, :, :, -1, :, :].add(
            bot[:, 1:].reshape(plane))
    return (p3.reshape(p_prev.shape), vb.reshape(p_prev.shape),
            jnp.sum(pap_b, axis=0))


def nekbone_cg_update_block(x: jnp.ndarray, p: jnp.ndarray, r: jnp.ndarray,
                            w: jnp.ndarray, alpha,
                            grid: tuple[int, int, int], *,
                            addb: jnp.ndarray | None = None,
                            addt: jnp.ndarray | None = None,
                            sz: int | None = None,
                            interpret: bool | None = None,
                            acc_dtype: str | None = None):
    """Batched merged CG vector-update kernel on natural shapes.

    The multi-RHS sibling of :func:`nekbone_cg_update`: fields carry a
    leading RHS-batch axis (b, E, n, n, n), ``alpha`` is a scalar or
    length-b vector, ``addb``/``addt`` are (b, EZ//sz, EY*EX*n^2).

    Returns ``(x_new, r_new, rtz_new)`` with ``rtz_new`` a length-b
    vector of per-RHS weighted norms of the updated residual.
    """
    ex, ey, ez = grid = tuple(grid)
    nrhs, E = x.shape[0], x.shape[1]
    n = x.shape[-1]
    interpret = default_interpret() if interpret is None else interpret
    if sz is None:
        sz = _autotune.pick_slab_sz(grid, n, x.dtype, acc_dtype=acc_dtype,
                                    nrhs=nrhs)
    n3 = n ** 3
    nblk = ez // sz
    pln = ey * ex * n * n
    _, (cx, cy, cz) = slab_axis_factors(grid, n, x.dtype)
    acc = _ax._accum(x.dtype, acc_dtype)
    if addb is None:
        addb = jnp.zeros((nrhs, nblk, pln), x.dtype)
    if addt is None:
        addt = jnp.zeros((nrhs, nblk, pln), x.dtype)
    alpha_arr = jnp.broadcast_to(jnp.asarray(alpha, acc),
                                 (nrhs,)).reshape(1, nrhs)
    x3, r3, rcr_b = _ax.nekbone_cg_update_block_pallas(
        x.reshape(nrhs, E, n3), p.reshape(nrhs, E, n3),
        r.reshape(nrhs, E, n3), w.reshape(nrhs, E, n3),
        addb.reshape(nrhs, nblk, pln), addt.reshape(nrhs, nblk, pln),
        alpha_arr, cx, cy, cz, n=n, grid=grid, sz=sz, interpret=interpret,
        acc_dtype=acc_dtype)
    return x3.reshape(x.shape), r3.reshape(x.shape), jnp.sum(rcr_b, axis=0)


def nekbone_pcg_update(x: jnp.ndarray, p: jnp.ndarray, z: jnp.ndarray,
                       w: jnp.ndarray, alpha: float, invdiag: jnp.ndarray,
                       grid: tuple[int, int, int], *,
                       addb: jnp.ndarray | None = None,
                       addt: jnp.ndarray | None = None,
                       sz: int | None = None,
                       interpret: bool | None = None,
                       acc_dtype: str | None = None):
    """Merged Jacobi-PCG vector-update kernel on natural shapes.

    The solver carries ``z = invdiag * r`` (the preconditioned residual,
    DESIGN.md §9.2); this computes ``x + alpha p``,
    ``z - alpha invdiag (w + planes)`` and the two weighted partials of
    the reconstructed residual ``r = z / invdiag``:
    ``rtz = r·c·z`` (the PCG beta numerator) and ``rcr = r·c·r`` (the
    history entry), with ``c`` rebuilt in-kernel.

    Args:
      x, p, z, w: (E, n, n, n); invdiag: (E, n, n, n) assembled 1/diag(A)
      (1 at masked rows); grid/alpha/addb/addt as
      :func:`nekbone_cg_update`.

    Returns ``(x_new, z_new, rtz, rcr)``.
    """
    ex, ey, ez = grid = tuple(grid)
    E = x.shape[0]
    n = x.shape[-1]
    interpret = default_interpret() if interpret is None else interpret
    if sz is None:
        sz = _autotune.pick_slab_sz(grid, n, x.dtype, acc_dtype=acc_dtype,
                                    precond="jacobi")
    n3 = n ** 3
    nblk = ez // sz
    pln = ey * ex * n * n
    _, (cx, cy, cz) = slab_axis_factors(grid, n, x.dtype)
    acc = _ax._accum(x.dtype, acc_dtype)
    if addb is None:
        addb = jnp.zeros((nblk, pln), x.dtype)
    if addt is None:
        addt = jnp.zeros((nblk, pln), x.dtype)
    alpha_arr = jnp.full((1, 1), alpha, acc)
    x2, z2, rtz_b, rcr_b = _ax.nekbone_pcg_update_pallas(
        x.reshape(E, n3), p.reshape(E, n3), z.reshape(E, n3),
        w.reshape(E, n3), addb.reshape(nblk, pln), addt.reshape(nblk, pln),
        alpha_arr, invdiag.reshape(E, n3), cx, cy, cz, n=n, grid=grid,
        sz=sz, interpret=interpret, acc_dtype=acc_dtype)
    return (x2.reshape(x.shape), z2.reshape(x.shape), jnp.sum(rtz_b),
            jnp.sum(rcr_b))


def nekbone_cheb_precond(r: jnp.ndarray, D: jnp.ndarray, g3: jnp.ndarray,
                         coef: jnp.ndarray, grid: tuple[int, int, int], *,
                         k: int, sz: int | None = None,
                         layout: str | None = None,
                         grid_order: str | None = None,
                         interpret: bool | None = None,
                         acc_dtype: str | None = None):
    """Chebyshev preconditioner application on natural shapes.

    Builds the halo windows (``halo = k`` slabs, like
    :func:`nekbone_ax_powers`) and evaluates ``z = q_k(A) r`` — k chained
    masked, assembled operator applications combined by the Chebyshev
    recurrence scalars (DESIGN.md §9.3) — plus the weighted partial
    ``rtz = r·c·z``.

    Args:
      r: (E, n, n, n), continuous + masked, z-major over ``grid``.
      D: (n, n); g3: diagonal (E, 3, ...) or verifiably-diagonal
         6-component metric; coef: (k+1, 2) recurrence scalars
         (:func:`repro.core.precond.cheb_scalars`).
      k: polynomial degree (>= 1); sz: slabs per block (default:
         autotuned, :func:`repro.kernels.autotune.pick_slab_sz_cheb`).
      layout, grid_order: contraction layout / grid iteration order
        (defaults: jointly autotuned with sz when all three are None,
        see :func:`repro.kernels.autotune.pick_cheb_config`).

    Returns ``(z, rtz)``.
    """
    ex, ey, ez = grid = tuple(grid)
    E = r.shape[0]
    n = r.shape[-1]
    interpret = default_interpret() if interpret is None else interpret
    if sz is None and layout is None and grid_order is None:
        sz, layout, grid_order = _autotune.pick_cheb_config(
            grid, n, k, r.dtype, acc_dtype=acc_dtype)
    elif sz is None:
        sz = _autotune.pick_slab_sz_cheb(grid, n, k, r.dtype,
                                         acc_dtype=acc_dtype)
    layout = "fold" if layout is None else layout
    grid_order = "parallel" if grid_order is None else grid_order
    n3 = n ** 3
    (mx, my, mz), (cx, cy, cz) = slab_axis_factors(grid, n, r.dtype)
    D = jnp.asarray(D, r.dtype)
    g3 = diag_metric(jnp.asarray(g3, r.dtype), E, n)
    acc = _ax._accum(r.dtype, acc_dtype)
    rext = _ax.sstep_extend_field(r.reshape(E, n3), grid, sz, k)
    gext = _ax.sstep_extend_field(g3, grid, sz, k)
    mzext = _ax.sstep_extend_zfactor(mz, sz, k)
    z2, rtz_b = _ax.nekbone_cheb_apply_pallas(
        rext, D, D.T, gext, mx, my, mzext, cx, cy, cz,
        jnp.asarray(coef, acc), n=n, grid=grid, sz=sz, k=k,
        interpret=interpret, acc_dtype=acc_dtype,
        layout=layout, grid_order=grid_order)
    return z2.reshape(r.shape), jnp.sum(rtz_b)


def nekbone_interp(u: jnp.ndarray, M: jnp.ndarray,
                   grid: tuple[int, int, int], *, sz: int | None = None,
                   interpret: bool | None = None,
                   acc_dtype: str | None = None) -> jnp.ndarray:
    """Tensor-product GLL-to-GLL interpolation on natural shapes.

    Applies ``M`` — ``(n_out, n_in)``, e.g.
    :func:`repro.core.pmg.gll_interp_matrix` — along each local direction
    of ``u`` (E, n_in, n_in, n_in): the p-multigrid transfer operator
    (DESIGN.md §13).  ``M`` itself prolongs when built fine-from-coarse;
    pass ``J.T`` for the matching restriction core.  Element-local, so
    the result is slab-split-invariant (fp64-bitwise across ``sz``).

    Returns (E, n_out, n_out, n_out) in ``u``'s dtype.
    """
    ex, ey, ez = grid = tuple(grid)
    E = u.shape[0]
    nin = u.shape[-1]
    M = jnp.asarray(M, u.dtype)
    nout = M.shape[0]
    assert M.shape == (nout, nin), (M.shape, (nout, nin))
    interpret = default_interpret() if interpret is None else interpret
    if sz is None:
        sz = _autotune.pick_slab_sz(grid, max(nin, nout), u.dtype,
                                    acc_dtype=acc_dtype,
                                    precond="pmg:interp")
    v2 = _ax.nekbone_interp_pallas(
        u.reshape(E, nin ** 3), M.T, nin=nin, nout=nout, grid=grid, sz=sz,
        interpret=interpret, acc_dtype=acc_dtype)
    return v2.reshape(E, nout, nout, nout)


def flash_attention(q, k, v, *, causal: bool = True, scale: float | None = None,
                    window: int | None = None, softcap: float | None = None,
                    q_offset: int = 0, block_q: int = 512, block_k: int = 512,
                    interpret: bool | None = None):
    """Block online-softmax attention (prefill hot-spot). See flash_attn.py."""
    interpret = default_interpret() if interpret is None else interpret
    return _flash.flash_attention(
        q, k, v, causal=causal, scale=scale, window=window, softcap=softcap,
        q_offset=q_offset, block_q=block_q, block_k=block_k,
        interpret=interpret)


def wkv6(r, k, v, w, u, *, initial_state=None, return_state: bool = False,
         block_t: int = 16, variant: str = "chunked",
         interpret: bool | None = None):
    """RWKV6 linear-attention recurrence (state streamed through VMEM)."""
    interpret = default_interpret() if interpret is None else interpret
    return _wkv6.wkv6(r, k, v, w, u, initial_state=initial_state,
                      return_state=return_state, block_t=block_t,
                      variant=variant, interpret=interpret)
