"""Jitted public wrappers around the Pallas kernels.

Each wrapper:
  * accepts natural shapes and reshapes/pads to the kernel's HBM layout,
  * picks ``interpret=True`` automatically off-TPU (this container is
    CPU-only; the TPU lowering is exercised structurally by the dry-run),
  * exposes the tuning knobs (block sizes) with roofline-reasoned defaults.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import flash_attn as _flash
from repro.kernels import nekbone_ax as _ax
from repro.kernels import wkv6 as _wkv6

__all__ = ["nekbone_ax", "flash_attention", "wkv6", "default_interpret"]


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pick_block_e(E: int, n: int, vmem_budget_bytes: int = 8 * 2 ** 20) -> int:
    """Largest power-of-two element block whose working set fits the budget.

    The kernel keeps ~14 block-sized fp32 arrays live (u, w, 6 metric fields,
    3 gradients + 3 temporaries); lanes pad n^3 up to a multiple of 128.
    """
    n3_padded = -(-(n ** 3) // 128) * 128
    per_elem = 14 * n3_padded * 4
    be = max(1, vmem_budget_bytes // per_elem)
    be = 1 << (be.bit_length() - 1)            # floor to power of two
    while be > 1 and E % be:
        be //= 2
    return be


@functools.partial(jax.jit,
                   static_argnames=("block_e", "interpret"))
def _nekbone_ax_impl(u, D, Dt, g, block_e, interpret):
    E = u.shape[0]
    n = u.shape[-1]
    u2 = u.reshape(E, n ** 3)
    g2 = g.reshape(E, 6, n ** 3)
    w2 = _ax.nekbone_ax_pallas(u2, D, Dt, g2, n=n, block_e=block_e,
                               interpret=interpret)
    return w2.reshape(u.shape)


def nekbone_ax(u: jnp.ndarray, D: jnp.ndarray, g: jnp.ndarray, *,
               block_e: int | None = None,
               interpret: bool | None = None) -> jnp.ndarray:
    """Fused local Poisson operator  w = D^T (G (D u)).

    Args:
      u: (E, n, n, n) nodal values, layout [e, k, j, i].
      D: (n, n) derivative matrix (dxm1).
      g: (E, 6, n, n, n) metric fields (rr, rs, rt, ss, st, tt).
      block_e: elements per VMEM block (default: autotuned to ~8 MiB).
      interpret: force Pallas interpret mode (defaults to off-TPU detection).

    Elements are zero-padded to a multiple of ``block_e`` if needed.
    """
    E = u.shape[0]
    n = u.shape[-1]
    interpret = default_interpret() if interpret is None else interpret
    block_e = block_e or _pick_block_e(E, n)
    pad = (-E) % block_e
    if pad:
        u = jnp.concatenate([u, jnp.zeros((pad,) + u.shape[1:], u.dtype)])
        g = jnp.concatenate([g, jnp.zeros((pad,) + g.shape[1:], g.dtype)])
    w = _nekbone_ax_impl(u, D, jnp.asarray(D).T, g, block_e, interpret)
    return w[:E] if pad else w


def flash_attention(q, k, v, *, causal: bool = True, scale: float | None = None,
                    window: int | None = None, softcap: float | None = None,
                    q_offset: int = 0, block_q: int = 512, block_k: int = 512,
                    interpret: bool | None = None):
    """Block online-softmax attention (prefill hot-spot). See flash_attn.py."""
    interpret = default_interpret() if interpret is None else interpret
    return _flash.flash_attention(
        q, k, v, causal=causal, scale=scale, window=window, softcap=softcap,
        q_offset=q_offset, block_q=block_q, block_k=block_k,
        interpret=interpret)


def wkv6(r, k, v, w, u, *, initial_state=None, return_state: bool = False,
         block_t: int = 16, variant: str = "chunked",
         interpret: bool | None = None):
    """RWKV6 linear-attention recurrence (state streamed through VMEM)."""
    interpret = default_interpret() if interpret is None else interpret
    return _wkv6.wkv6(r, k, v, w, u, initial_state=initial_state,
                      return_state=return_state, block_t=block_t,
                      variant=variant, interpret=interpret)
