"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth for the kernel allclose tests; they are written
for clarity, not speed.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["nekbone_ax_ref", "attention_ref", "wkv6_ref", "wkv6_chunked"]


def nekbone_ax_ref(u: jnp.ndarray, D: jnp.ndarray, g: jnp.ndarray) -> jnp.ndarray:
    """Oracle for kernels/nekbone_ax: the fused local Poisson operator.

    u: (E, n, n, n) [e, k, j, i];  D: (n, n);  g: (E, 6, n, n, n).
    """
    from repro.core.ax import ax_local_fused

    return ax_local_fused(u, D, g)


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                  causal: bool = True, scale: float | None = None,
                  window: int | None = None, softcap: float | None = None,
                  q_offset: int = 0) -> jnp.ndarray:
    """Naive attention oracle with GQA / sliding window / logit softcap.

    q: (B, Hq, Sq, d); k, v: (B, Hkv, Skv, d); Hq % Hkv == 0.
    ``q_offset`` is the absolute position of q[0] (for decode: Skv - Sq).
    Masking: position i attends to j iff j <= i (causal) and i - j < window.
    """
    B, Hq, Sq, d = q.shape
    Hkv = k.shape[1]
    group = Hq // Hkv
    scale = d ** -0.5 if scale is None else scale
    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q, kk).astype(jnp.float32) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    qpos = jnp.arange(Sq)[:, None] + q_offset
    kpos = jnp.arange(k.shape[2])[None, :]
    mask = jnp.ones((Sq, k.shape[2]), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= qpos - kpos < window
    s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vv).astype(q.dtype)


def wkv6_ref(r: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, w: jnp.ndarray,
             u: jnp.ndarray, *, initial_state: jnp.ndarray | None = None,
             return_state: bool = False):
    """Oracle for kernels/wkv6: the RWKV6 (Finch) linear-attention recurrence.

    Shapes: r, k, v, w: (B, H, T, d); u: (H, d).  Per head, with state
    S in R^{d_k x d_v}:

        o_t = S_{t-1}^T r_t + (r_t . (u * k_t)) v_t
        S_t = diag(w_t) S_{t-1} + k_t v_t^T

    where w_t in (0, 1) is the data-dependent per-channel decay.
    """
    B, H, T, d = r.shape
    S0 = (jnp.zeros((B, H, d, d), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))

    def step(S, xs):
        rt, kt, vt, wt = xs  # each (B, H, d)
        out = jnp.einsum("bhkv,bhk->bhv", S, rt.astype(jnp.float32))
        bonus = jnp.einsum("bhk,bhk->bh", rt, u[None] * kt)
        out = out + bonus[..., None] * vt
        S = wt[..., :, None] * S + jnp.einsum("bhk,bhv->bhkv", kt, vt)
        return S, out

    xs = tuple(x.transpose(2, 0, 1, 3) for x in (r, k, v, w))  # (T, B, H, d)
    S, outs = jax.lax.scan(step, S0, xs)
    o = outs.transpose(1, 2, 0, 3).astype(r.dtype)  # (B, H, T, d)
    if return_state:
        return o, S
    return o


def wkv6_chunked(r, k, v, w, u, *, initial_state=None, chunk: int = 16,
                 return_state: bool = False):
    """Differentiable chunked-parallel WKV6 (training path).

    Same algebra as the Pallas kernel's ``chunked`` variant (kernels/wkv6.py)
    expressed in batched jnp: a scan over T/chunk steps whose body is three
    matmuls.  Unlike the naive scan VJP (which materializes the (B, H, d, d)
    state per *time step* — ~34 GB/device at train_4k), the backward pass
    here stores per-chunk residuals only: T/chunk x (c, d) tensors.
    """
    B, H, T, d = r.shape
    c = min(chunk, T)
    while T % c:
        c -= 1
    nt = T // c
    f32 = jnp.float32
    S0 = (jnp.zeros((B, H, d, d), f32) if initial_state is None
          else initial_state.astype(f32))

    def to_chunks(x):
        return x.reshape(B, H, nt, c, d).transpose(2, 0, 1, 3, 4)

    rc, kc, vc, wc = map(to_chunks, (r, k, v, w))    # (nt, B, H, c, d)
    uu = u.astype(f32)[None]                          # (1, H, d)

    @jax.checkpoint
    def body(S, xs):
        rb, kb, vb, wb = (x.astype(f32) for x in xs)  # (B, H, c, d)
        logw = jnp.log(wb)
        cum = jnp.cumsum(logw, axis=2)
        p_incl = jnp.exp(cum)
        p_excl = jnp.exp(cum - logw)
        r_t = rb * p_excl
        k_t = kb * jnp.exp(-cum)
        A = jnp.einsum("bhtd,bhsd->bhts", r_t, k_t)
        ti = jnp.arange(c)
        A = jnp.where(ti[None, None, :, None] > ti[None, None, None, :], A, 0.0)
        bonus = jnp.einsum("bhtd,bhtd->bht", rb, uu[..., None, :] * kb)
        A = A + jnp.einsum("bht,ts->bhts", bonus, jnp.eye(c, dtype=f32))
        O = jnp.einsum("bhtd,bhdv->bhtv", r_t, S)
        O = O + jnp.einsum("bhts,bhsv->bhtv", A, vb)
        S = p_incl[:, :, -1][..., :, None] * (
            S + jnp.einsum("bhsd,bhsv->bhdv", k_t, vb))
        return S, O

    S, outs = jax.lax.scan(body, S0, (rc, kc, vc, wc))
    o = outs.transpose(1, 2, 0, 3, 4).reshape(B, H, T, d).astype(r.dtype)
    if return_state:
        return o, S
    return o
