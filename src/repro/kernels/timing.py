"""The one wall-clock measurement helper (DESIGN.md §11).

Every measured-time consumer — the autotune sweeps in
``kernels/autotune.py`` and the bench modules under ``benchmarks/`` (via
the ``benchmarks.timing`` re-export) — times through :func:`measure`, so
warmup handling and the median-of-reps estimator cannot drift apart
between the tuner and the benches that validate its picks.

Methodology: ``warmup`` calls are discarded (they absorb compilation and
first-touch cache effects), then each of ``reps`` calls is synced and
timed *individually* and the median is returned — the median is robust to
the one-sided noise wall-clock suffers (preemption, clock migration can
only add time, so the mean over-reports).  ``timer`` and ``sync`` are
injectable for unit tests (tests/test_timing.py).
"""
from __future__ import annotations

import time

__all__ = ["measure", "median", "stopwatch", "Stopwatch"]


class Stopwatch:
    """Monotonic elapsed-µs reader (``time.perf_counter_ns`` based — the
    same clock discipline as :func:`measure`).  The telemetry layer's
    phase timer: ``sw = stopwatch(); ...; sw.us()``."""

    __slots__ = ("_t0",)

    def __init__(self):
        self._t0 = time.perf_counter_ns()

    def us(self) -> float:
        """Microseconds since construction."""
        return (time.perf_counter_ns() - self._t0) / 1e3


def stopwatch() -> Stopwatch:
    """Start a :class:`Stopwatch` now."""
    return Stopwatch()


def median(xs) -> float:
    """Median of a non-empty sequence (upper median for even lengths —
    the conservative choice for one-sided timing noise)."""
    xs = sorted(xs)
    if not xs:
        raise ValueError("median() of empty sequence")
    return xs[len(xs) // 2]


def _default_sync(x):
    import jax
    return jax.block_until_ready(x)


def measure(fn, *args, reps: int = 5, warmup: int = 1, timer=None,
            sync=None) -> float:
    """Median wall-clock seconds of ``sync(fn(*args))`` over ``reps`` calls,
    after ``warmup`` discarded calls.

    Args:
      fn: callable under test; its (possibly async-dispatched) result is
        passed through ``sync`` so the work is actually finished inside
        the timed region.
      reps: timed repetitions (must be >= 1); the *median* is returned.
      warmup: discarded leading calls (compile + cache warm; may be 0 when
        the callable is already warm).
      timer: monotonic clock, ``time.perf_counter`` by default.
      sync: completion barrier, ``jax.block_until_ready`` by default
        (imported lazily so non-jax callables can use this too).
    """
    if reps < 1:
        raise ValueError(f"reps must be >= 1, got {reps}")
    if warmup < 0:
        raise ValueError(f"warmup must be >= 0, got {warmup}")
    timer = time.perf_counter if timer is None else timer
    sync = _default_sync if sync is None else sync
    for _ in range(warmup):
        sync(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = timer()
        sync(fn(*args))
        ts.append(timer() - t0)
    return median(ts)
