"""RWKV6 (Finch) WKV recurrence as a Pallas TPU kernel.

The most direct transfer of the paper's optimization (DESIGN.md §4): the
per-head state matrix ``S in R^{d x d}`` is the small operand held in fast
memory (VMEM scratch, the shared-memory analog) while the long axis — time,
playing the role of the paper's element ``k``-layers — streams past in
blocks.  Two bodies:

* ``variant='sequential'`` — faithful per-token recurrence (matches the
  reference CUDA WKV kernels; unconditionally stable for any decay).
* ``variant='chunked'`` — the optimized within-chunk *parallel* form: the
  recurrence over a time chunk of length ``c`` is algebraically rewritten as
  three MXU matmuls plus a masked (c, c) correlation, exactly the paper's
  "restructure many tiny contractions into a few large ones" move:

      r~_t = r_t * P_{t-1}      (P = inclusive cumprod of decay, P_{-1}=1)
      k~_s = k_s / P_s
      O    = r~ @ S0 + (strict_tril(r~ k~^T) + diag(r.(u*k))) @ V
      S'   = diag(P_c) (S0 + k~^T V)

  Stability: 1/P_s grows as decays accumulate, so the chunk size bounds the
  dynamic range (with w >= w_min the factor is w_min^{-c}).  The default
  c = 16 keeps f32 exact to ~1e-5 for the decay ranges RWKV6 produces
  (w = exp(-exp(x)) clipped to w >= 0.05 by construction in models/rwkv6.py).

Shapes: r, k, v, w: (B, H, T, d); u (bonus): (H, d).  Heads map to the
parallel grid axis; time blocks map to an 'arbitrary' axis with the state
carried in scratch between steps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.compat import CompilerParams as _CompilerParams

__all__ = ["wkv6"]


def _seq_body(r, k, v, w, u, S0):
    """Per-token recurrence over a (c, d) chunk. All f32. Returns (O, S)."""
    c, d = r.shape

    def step(t, carry):
        S, O = carry
        rt = jax.lax.dynamic_slice(r, (t, 0), (1, d))      # (1, d)
        kt = jax.lax.dynamic_slice(k, (t, 0), (1, d))
        vt = jax.lax.dynamic_slice(v, (t, 0), (1, d))
        wt = jax.lax.dynamic_slice(w, (t, 0), (1, d))
        out = jax.lax.dot(rt, S, preferred_element_type=jnp.float32)
        bonus = jnp.sum(rt * u * kt, axis=-1, keepdims=True)  # (1, 1)
        out = out + bonus * vt
        S = S * wt.T + kt.T @ vt
        O = jax.lax.dynamic_update_slice(O, out, (t, 0))
        return S, O

    O = jnp.zeros((c, d), jnp.float32)
    S, O = jax.lax.fori_loop(0, c, step, (S0, O))
    return O, S


def _chunk_body(r, k, v, w, u, S0):
    """Parallel within-chunk form (three matmuls). All f32. Returns (O, S)."""
    c, d = r.shape
    logw = jnp.log(w)
    cum = jnp.cumsum(logw, axis=0)                  # log P_t (inclusive)
    p_incl = jnp.exp(cum)
    p_excl = jnp.exp(cum - logw)                    # P_{t-1}
    r_t = r * p_excl
    k_t = k * jnp.exp(-cum)
    A = jax.lax.dot_general(r_t, k_t, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (c, c)
    ti = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
    A = jnp.where(si < ti, A, 0.0)                  # strictly causal
    bonus = jnp.sum(r * u * k, axis=-1)             # (c,)
    A = A + jnp.diag(bonus)
    O = jax.lax.dot(r_t, S0, preferred_element_type=jnp.float32)
    O = O + jax.lax.dot(A, v, preferred_element_type=jnp.float32)
    S = p_incl[-1][:, None] * (
        S0 + jax.lax.dot(k_t.T, v, preferred_element_type=jnp.float32))
    return O, S


def _wkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, o_ref, sout_ref,
                 s_scr, *, nt: int, variant: str):
    it = pl.program_id(1)

    @pl.when(it == 0)
    def _init():
        s_scr[...] = s0_ref[0].astype(jnp.float32)

    f32 = jnp.float32
    r = r_ref[0].astype(f32)
    k = k_ref[0].astype(f32)
    v = v_ref[0].astype(f32)
    w = w_ref[0].astype(f32)
    u = u_ref[...].astype(f32)                       # (1, d)

    body = _seq_body if variant == "sequential" else _chunk_body
    O, S = body(r, k, v, w, u, s_scr[...])
    o_ref[0] = O.astype(o_ref.dtype)
    s_scr[...] = S

    @pl.when(it == nt - 1)
    def _finish():
        sout_ref[0] = S.astype(sout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("return_state", "block_t",
                                             "variant", "interpret"))
def wkv6(r, k, v, w, u, *, initial_state=None, return_state: bool = False,
         block_t: int = 16, variant: str = "chunked", interpret: bool = False):
    """RWKV6 recurrence. r,k,v,w: (B,H,T,d); u: (H,d) -> (B,H,T,d) [, state].

    T is zero-padded to a multiple of ``block_t`` (padded steps use decay 1
    and contribute nothing: k rows are zero).
    """
    B, H, T, d = r.shape
    bt = block_t
    pad = (-T) % bt
    Tp = T + pad

    def flat(x, pad_value=0.0):
        x = x.reshape(B * H, T, d)
        if pad:
            x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)),
                        constant_values=pad_value)
        return x

    rf, kf, vf = flat(r), flat(k), flat(v)
    wf = flat(w, pad_value=1.0)                     # decay 1 on padding
    s0 = (jnp.zeros((B * H, d, d), jnp.float32) if initial_state is None
          else initial_state.reshape(B * H, d, d).astype(jnp.float32))
    uf = u.astype(jnp.float32)                      # (H, d)
    nt = Tp // bt

    kernel = functools.partial(_wkv6_kernel, nt=nt, variant=variant)
    o, s_out = pl.pallas_call(
        kernel,
        grid=(B * H, nt),
        in_specs=[
            pl.BlockSpec((1, bt, d), lambda bh, it: (bh, it, 0)),
            pl.BlockSpec((1, bt, d), lambda bh, it: (bh, it, 0)),
            pl.BlockSpec((1, bt, d), lambda bh, it: (bh, it, 0)),
            pl.BlockSpec((1, bt, d), lambda bh, it: (bh, it, 0)),
            pl.BlockSpec((1, d), lambda bh, it, h=H: (bh % h, 0)),
            pl.BlockSpec((1, d, d), lambda bh, it: (bh, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bt, d), lambda bh, it: (bh, it, 0)),
            pl.BlockSpec((1, d, d), lambda bh, it: (bh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, Tp, d), r.dtype),
            jax.ShapeDtypeStruct((B * H, d, d), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((d, d), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
        name=f"wkv6_{variant}_bt{bt}",
    )(rf, kf, vf, wf, uf, s0)

    o = o[:, :T, :].reshape(B, H, T, d)
    if return_state:
        return o, s_out.reshape(B, H, d, d)
    return o
