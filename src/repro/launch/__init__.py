"""Launchers: mesh construction, dry-run, roofline, train, serve.

NOTE: do NOT import ``dryrun`` from here — it sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` at import time and
must only be loaded as the ``python -m repro.launch.dryrun`` entry point.
"""
from repro.launch import mesh, steps  # noqa: F401

__all__ = ["mesh", "steps"]
