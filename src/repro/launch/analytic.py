"""Analytic per-cell cost model: MODEL_FLOPS and minimal HBM traffic.

Used as the roofline's "useful work" reference (MODEL_FLOPS = 6·N·D dense /
6·N_active·D MoE, §Roofline) and as the memory-term floor.  The HLO-derived
numbers (loop-corrected dot flops, cost_analysis bytes) are reported next to
these; their ratio exposes remat/redundancy overhead.

Conventions (per the assignment):
  * train  : 6 * N_active * tokens  + attention term 12 * L * S^2 * d_attn
             (causal halves the S^2 term; remat adds a fwd repeat -> x(8/6)
             reported separately as ``hlo/model`` ratio, not baked in here)
  * prefill: 2 * N_active * tokens  + 2 * L * S^2 * d_attn (causal halved)
  * decode : 2 * N_active * B       + 4 * B * L * S_cache * kv_width
Memory floor:
  * train  : params read (fwd+bwd) + grads + moments r/w + activation stream
  * prefill: params once + KV cache write + activation stream
  * decode : params once + KV cache read (the long-context wall)
Everything is *per device* given the mesh size.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.configs.base import ArchConfig, ShapeCell

__all__ = ["cell_cost", "CellCost"]


@dataclasses.dataclass(frozen=True)
class CellCost:
    model_flops_total: float      # whole step, all devices
    model_flops_per_dev: float
    hbm_bytes_per_dev: float      # analytic floor
    attn_flops_total: float
    notes: str = ""


def _dtype_bytes(name: str) -> int:
    return {"float32": 4, "bfloat16": 2, "float16": 2}[name]


def cell_cost(cfg: ArchConfig, cell: ShapeCell, n_devices: int,
              param_shards: int | None = None) -> CellCost:
    """``param_shards``: how many ways the params are sharded (serve mode
    replicates over the batch axes -> 16, not n_devices)."""
    N_act = cfg.active_param_count()
    N_tot = cfg.param_count()
    pshards = param_shards or n_devices
    L = cfg.n_layers
    pb = _dtype_bytes(cfg.param_dtype)
    cb = _dtype_bytes(cfg.compute_dtype)
    mb = _dtype_bytes(cfg.opt_moment_dtype)
    d = cfg.d_model
    B, S = cell.global_batch, cell.seq_len
    kv_width = 2 * cfg.n_kv_heads * cfg.hd          # K and V per token

    # Attention flops: qk^T and pv, causal => x1/2. Windowed layers bound S.
    windows = np.minimum(cfg.layer_windows(), S)
    attn_ctx = float(windows.sum()) / max(L, 1)     # avg effective context

    if cell.kind == "train":
        tokens = B * S
        flops = 6.0 * N_act * tokens
        attn = 12.0 * L * cfg.n_heads * cfg.hd * tokens * attn_ctx * 0.5
        flops_total = flops + attn
        # params: read fwd + read bwd (+ remat fwd) ~ 3x; grads write + read;
        # moments read+write; master params read+write.
        param_traffic = N_tot * (3 * pb + 2 * 4 + 4 * mb + 2 * pb)
        act_traffic = tokens * d * L * 12 * cb      # residual stream passes
        hbm = (param_traffic + act_traffic) / n_devices
        return CellCost(flops_total, flops_total / n_devices, hbm, attn)

    if cell.kind == "prefill":
        tokens = B * S
        flops = 2.0 * N_act * tokens
        attn = 4.0 * L * cfg.n_heads * cfg.hd * tokens * attn_ctx * 0.5
        flops_total = flops + attn
        cache_write = B * S * L * kv_width * cb
        hbm = (N_tot * pb / pshards
               + (cache_write + tokens * d * L * 6 * cb) / n_devices)
        return CellCost(flops_total, flops_total / n_devices, hbm, attn)

    # decode: one token per sequence against an S-long cache
    tokens = B
    flops = 2.0 * N_act * tokens
    if cfg.block == "rwkv":
        attn = 4.0 * B * L * cfg.n_heads * cfg.hd * cfg.hd  # state update
        cache_read = B * L * cfg.n_heads * cfg.hd * cfg.hd * 4
    else:
        attn = 4.0 * B * L * cfg.n_heads * cfg.hd * attn_ctx
        # sum over layers of min(window, S) cache entries, K+V each
        cache_read = B * float(windows.sum()) * kv_width * cb
    flops_total = flops + attn
    hbm = N_tot * pb / pshards + cache_read / n_devices
    return CellCost(flops_total, flops_total / n_devices, hbm, attn,
                    notes="cache-read dominated"
                    if cache_read / n_devices > N_tot * pb / pshards
                    else "param-read dominated")
