import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any other import (jax locks the device
count at first init); smoke tests and benches do NOT get 512 devices — only
this entry point does.

Per cell this produces a JSON artifact with:
  * ``cost_analysis()``  — per-device HLO flops / bytes accessed,
  * ``memory_analysis()``— per-device buffer sizes (proves it fits),
  * collective bytes     — parsed from the compiled HLO, summed per op kind
    (all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute; result-shape bytes convention, '-done' ops skipped),
  * analytic input footprints (params / optimizer / cache per device).

Artifacts are written incrementally (restartable) to ``artifacts/dryrun``;
``launch/roofline.py`` turns them into EXPERIMENTS.md §Roofline.

Usage:
  python -m repro.launch.dryrun --arch gemma2-27b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh both] [--force]
  python -m repro.launch.dryrun --nekbone --mesh single      # paper's own app
"""
import argparse
import json
import pathlib
import re
import time
import traceback

import jax
import jax.numpy as jnp

from repro.compat import set_mesh, shard_map
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, SHAPES, get
from repro.configs.specs import input_specs
from repro.distributed import sharding as shd
from repro.launch import steps as St
from repro.launch.analytic import cell_cost
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.mesh import make_production_mesh
from repro.models import model as Mdl

ART_DIR = pathlib.Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
                "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16}

_COLL_RE = re.compile(
    r"=\s*([^=]*?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_SHAPE_RE = re.compile(r"([a-z]+\d*)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    out: dict[str, dict[str, float]] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        shape_str, op, _ = m.groups()
        if f"{op}-done" in line:
            continue
        b = _shape_bytes(shape_str)
        rec = out.setdefault(op, {"bytes": 0, "count": 0})
        rec["bytes"] += b
        rec["count"] += 1
    return out


def _sharded_bytes(aval, spec, mesh) -> int:
    """Per-device bytes of an array sharded by ``spec`` on ``mesh``."""
    denom = 1
    for entry in (spec or ()):  # PartitionSpec iterates entries
        if entry is None:
            continue
        axes = entry if isinstance(entry, (tuple, list)) else (entry,)
        for a in axes:
            if a in mesh.axis_names:
                denom *= mesh.shape[a]
    return int(np.prod(aval.shape, dtype=np.int64)
               * jnp.dtype(aval.dtype).itemsize // max(denom, 1))


def _tree_device_bytes(avals, specs, mesh) -> int:
    flat_a = jax.tree.leaves(avals)
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    return int(sum(_sharded_bytes(a, s, mesh)
                   for a, s in zip(flat_a, flat_s)))


def _memory_analysis_dict(compiled) -> dict | None:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return None
    if ma is None:
        return None
    keys = ["argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes"]
    return {k: int(getattr(ma, k)) for k in keys if hasattr(ma, k)}


def _filter_spec(spec: P, mesh) -> P:
    """Drop axis names the mesh does not have (e.g. 'pod' on single-pod)."""
    def filt(entry):
        if entry is None:
            return None
        if isinstance(entry, (tuple, list)):
            kept = tuple(a for a in entry if a in mesh.axis_names)
            return kept if kept else None
        return entry if entry in mesh.axis_names else None

    return P(*(filt(e) for e in spec))


def _named(specs, mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, _filter_spec(s, mesh)), specs,
        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
def run_cell(arch: str, shape: str, mesh_kind: str, *,
             verbose: bool = True) -> dict:
    cfg = get(arch)
    cell = SHAPES[shape]
    multi = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    shd.set_rules(fsdp_pod=multi and cfg.param_count() > 1e11)

    rec = {"arch": arch, "shape": shape, "mesh": mesh_kind,
           "kind": cell.kind, "n_devices": mesh.devices.size,
           "params": cfg.param_count(),
           "active_params": cfg.active_param_count(),
           "tokens": cell.global_batch * (cell.seq_len if cell.kind != "decode"
                                          else 1)}

    if shape == "long_500k" and cfg.is_pure_full_attention:
        rec["skipped"] = "pure full attention (sub-quadratic rule)"
        return rec

    with set_mesh(mesh):
        avals, pspecs = input_specs(cfg, cell, mesh)
        params_aval = jax.eval_shape(
            lambda: Mdl.init_params(jax.random.PRNGKey(0), cfg))
        # Serving cells replicate params over the batch axes (TP only) when
        # they fit; >100B archs keep FSDP (EXPERIMENTS.md §Perf).
        dtype_bytes = jnp.dtype(cfg.param_dtype).itemsize
        serve_mode = (cell.kind != "train"
                      and cfg.param_count() * dtype_bytes / 16 < 8e9)
        param_spec = Mdl.param_specs(cfg, params_aval, mesh,
                                     serve=serve_mode)
        rec["serve_param_mode"] = "tp-replicated" if serve_mode else "fsdp"

        t0 = time.time()
        if cell.kind == "train":
            state_aval = jax.eval_shape(
                lambda p: St.TrainState(
                    params=p,
                    mu=jax.tree.map(lambda a: jax.ShapeDtypeStruct(
                        a.shape, jnp.dtype(cfg.opt_moment_dtype)), p),
                    nu=jax.tree.map(lambda a: jax.ShapeDtypeStruct(
                        a.shape, jnp.dtype(cfg.opt_moment_dtype)), p),
                    step=jax.ShapeDtypeStruct((), jnp.int32)),
                params_aval)
            state_spec = St.TrainState(params=param_spec, mu=param_spec,
                                       nu=param_spec, step=P())
            fn = St.make_train_step(cfg)
            metrics_spec = {"loss": P(), "lr": P(), "grad_norm": P(),
                            "step": P()}
            jitted = jax.jit(
                fn,
                in_shardings=(_named(state_spec, mesh),
                              _named(pspecs["batch"], mesh),
                              _named(pspecs["extra"], mesh)),
                out_shardings=(_named(state_spec, mesh),
                               _named(metrics_spec, mesh)),
                donate_argnums=(0,))
            lowered = jitted.lower(state_aval, avals["batch"], avals["extra"])
            rec["state_bytes_per_device"] = _tree_device_bytes(
                state_aval, state_spec, mesh)
        elif cell.kind == "prefill":
            fn = St.make_serve_prefill(cfg, max_len=cell.seq_len)
            from repro.configs.specs import cache_specs, _div
            out_cache_aval = jax.eval_shape(
                lambda: Mdl.init_cache(cfg, cell.global_batch, cell.seq_len))
            cspec = cache_specs(cfg, out_cache_aval, mesh,
                                context_parallel=False)
            logits_spec = P(_div(mesh, cell.global_batch, shd.RULES.dp),
                            None, None)
            # cache out_shardings left to the partitioner: forcing the spec
            # makes GSPMD re-shard the scan carry through an all-gather per
            # layer (measured on whisper; EXPERIMENTS.md §Perf) — inputs are
            # pinned, so the inferred output matches the declared input spec.
            jitted = jax.jit(
                fn,
                in_shardings=(_named(param_spec, mesh),
                              _named(pspecs["tokens"], mesh),
                              _named(pspecs["extra"], mesh)),
                out_shardings=(_named(logits_spec, mesh), None))
            lowered = jitted.lower(params_aval, avals["tokens"],
                                   avals["extra"])
            rec["cache_bytes_per_device"] = _tree_device_bytes(
                out_cache_aval, cspec, mesh)
        else:  # decode
            from repro.configs.specs import _div
            cp = cell.name == "long_500k"
            fn = St.make_serve_step(cfg, context_parallel=cp)
            logits_spec = P(_div(mesh, cell.global_batch, shd.RULES.dp),
                            None, None)
            jitted = jax.jit(
                fn,
                in_shardings=(_named(param_spec, mesh),
                              _named(pspecs["tokens"], mesh),
                              _named(pspecs["cache"], mesh),
                              NamedSharding(mesh, P())),
                out_shardings=(_named(logits_spec, mesh), None),
                donate_argnums=(2,))
            lowered = jitted.lower(params_aval, avals["tokens"],
                                   avals["cache"], avals["index"])
            rec["cache_bytes_per_device"] = _tree_device_bytes(
                avals["cache"], pspecs["cache"], mesh)

        rec["param_bytes_per_device"] = _tree_device_bytes(
            params_aval, param_spec, mesh)
        rec["time_lower_s"] = round(time.time() - t0, 2)

        t1 = time.time()
        compiled = lowered.compile()
        rec["time_compile_s"] = round(time.time() - t1, 2)

        ca = compiled.cost_analysis() or {}
        rec["flops_raw"] = float(ca.get("flops", -1))
        rec["bytes_accessed_raw"] = float(ca.get("bytes accessed", -1))
        rec["transcendentals"] = float(ca.get("transcendentals", -1))
        rec["memory_analysis"] = _memory_analysis_dict(compiled)
        try:
            hlo = compiled.as_text()
        except Exception:
            hlo = lowered.as_text()
        la = analyze_hlo(hlo)             # loop-corrected (see hlo_analysis)
        rec["dot_flops"] = la["dot_flops"]
        rec["collectives"] = la["collectives"]
        rec["collectives_raw"] = collective_bytes(hlo)
        rec["hlo_lines"] = hlo.count("\n")
        cc = cell_cost(cfg, cell, int(mesh.devices.size),
                       param_shards=(16 if rec.get("serve_param_mode")
                                     == "tp-replicated" else None))
        rec["model_flops_total"] = cc.model_flops_total
        rec["model_flops_per_dev"] = cc.model_flops_per_dev
        rec["analytic_hbm_bytes_per_dev"] = cc.hbm_bytes_per_dev
        if verbose:
            print(json.dumps({k: rec[k] for k in
                              ("arch", "shape", "mesh", "dot_flops",
                               "model_flops_per_dev", "bytes_accessed_raw",
                               "time_compile_s")}))
            print("memory_analysis:", rec["memory_analysis"])
            print("collectives:", {k: v["bytes"] for k, v in
                                   rec["collectives"].items()})
    return rec


def run_nekbone(mesh_kind: str, nelt_per_device: int = 1024,
                dtype=jnp.float32) -> dict:
    """Dry-run the paper's own app: sharded Nekbone CG step on the mesh.

    Elements shard along z over ('pod',)+('data',); 'model' participates via
    a second element-block axis fold — Nekbone is pure data-parallel + halo,
    so we flatten (data, model) into the element dimension.

    ``dtype=bfloat16`` is the beyond-paper variant: the operator is
    memory-bound (Eq. 2), so halving every stream doubles the attainable
    roofline; accumulation stays f32 inside the kernel and CG residual
    quality is recovered by iterative refinement (core/cg.py).
    """
    from repro.core.nekbone import NekboneCase

    multi = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    n_dev = int(mesh.devices.size)
    # Global grid: every device's (16,16,4) block stacked along z.
    case = NekboneCase(n=10, grid=(16, 16, 4), dtype=dtype,
                       ax_impl="fused")
    axes = mesh.axis_names
    E = 16 * 16 * 4 * n_dev
    dt = jnp.dtype(dtype)
    u_aval = jax.ShapeDtypeStruct((E, 10, 10, 10), dt)
    g_aval = jax.ShapeDtypeStruct((E, 6, 10, 10, 10), dt)
    m_aval = jax.ShapeDtypeStruct((E, 10, 10, 10), dt)

    espec = P(axes)     # elements sharded over ALL mesh axes (z-major)
    with set_mesh(mesh):
        op = case.sharded_ax_full(axes)

        def cg_iter(u, g, mask, c):
            # one matrix-free CG-style application + the vector ops
            w = shard_map(
                lambda ul, gl, ml: op(ul, gl, ml, (16, 16, 4)),
                mesh=mesh,
                in_specs=(espec, P(axes, None), espec),
                out_specs=espec, check_vma=False)(u, g, mask)
            pap = jnp.sum(w * c * u)
            alpha = 1.0 / pap
            return u + alpha * w, pap

        jitted = jax.jit(cg_iter,
                         in_shardings=(NamedSharding(mesh, espec),
                                       NamedSharding(mesh, P(axes)),
                                       NamedSharding(mesh, espec),
                                       NamedSharding(mesh, espec)))
        t0 = time.time()
        lowered = jitted.lower(u_aval, g_aval, m_aval, m_aval)
        compiled = lowered.compile()
        ca = compiled.cost_analysis() or {}
        hlo = compiled.as_text()
        la = analyze_hlo(hlo)
        ndof_dev = E * 1000 // n_dev
        itemsize = dt.itemsize
        rec = {"arch": f"nekbone-{dt.name}", "shape": f"e{E}",
               "mesh": mesh_kind,
               "kind": "cg_iter", "n_devices": n_dev,
               "flops_raw": float(ca.get("flops", -1)),
               "bytes_accessed_raw": float(ca.get("bytes accessed", -1)),
               "dot_flops": la["dot_flops"],
               "collectives": la["collectives"],
               "memory_analysis": _memory_analysis_dict(compiled),
               "time_compile_s": round(time.time() - t0, 2),
               "ndof": E * 1000,
               # paper Eq. 1 / Eq. 2 per device (fp32)
               "model_flops_per_dev": float(ndof_dev * (12 * 10 + 34)),
               "model_flops_total": float(E * 1000 * (12 * 10 + 34)),
               "analytic_hbm_bytes_per_dev": float(30 * ndof_dev * itemsize)}
        print(json.dumps({k: rec[k] for k in ("arch", "shape", "mesh",
                                              "dot_flops",
                                              "bytes_accessed_raw")}))
    return rec


# ---------------------------------------------------------------------------
def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--nekbone", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out-dir", default=str(ART_DIR))
    args = ap.parse_args()

    out_dir = pathlib.Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    if args.nekbone:
        for mk in meshes:
            for dtype in (jnp.float32, jnp.bfloat16):
                rec = run_nekbone(mk, dtype=dtype)
                name = f"nekbone-{jnp.dtype(dtype).name}__{mk}.json"
                (out_dir / name).write_text(json.dumps(rec))
        return

    cells = ([(args.arch, args.shape)] if args.arch and args.shape
             else [(a, s) for a in ARCHS for s in SHAPES])
    failures = []
    for arch, shape in cells:
        for mk in meshes:
            tag = f"{arch}__{shape}__{mk}".replace("/", "_")
            path = out_dir / f"{tag}.json"
            if path.exists() and not args.force:
                print(f"skip (exists): {tag}")
                continue
            print(f"=== {tag} ===", flush=True)
            try:
                rec = run_cell(arch, shape, mk)
            except Exception as e:  # record the failure, keep going
                rec = {"arch": arch, "shape": shape, "mesh": mk,
                       "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-4000:]}
                failures.append(tag)
                print(f"FAILED: {tag}: {e}", flush=True)
            path.write_text(json.dumps(rec, indent=1))
            jax.clear_caches()          # keep the sweep's RSS bounded
    if failures:
        print(f"\n{len(failures)} FAILED cells: {failures}")
        raise SystemExit(1)
    print("\nall requested cells OK")


if __name__ == "__main__":
    main()
