"""Loop-aware HLO cost extraction.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body **once**,
regardless of trip count (verified in this container — see EXPERIMENTS.md
§Dry-run), so scan-over-layers under-reports flops by ~n_layers and hides
every collective inside the layer loop.  This module re-derives
loop-corrected numbers from the compiled HLO text:

  * computations are parsed into blocks with a per-op name->shape map,
  * every ``while`` op records condition/body and its trip count — XLA
    annotates ``backend_config={"known_trip_count":{"n":"L"}}`` for scans
    (fallback: largest int literal in the condition computation),
  * call multipliers *accumulate* over call paths and compose through
    nesting (layer scan x attention kv scan x grad-accum scan),
  * per-computation costs are summed with their multipliers:
      - ``dot`` flops: 2 * prod(output shape) * prod(lhs contracting dims),
      - collective bytes by kind (result-shape convention; '-done' and
        '-update'/control ops skipped).

Validated against hand-counted toy scans in tests/test_hlo_analysis.py.
"""
from __future__ import annotations

import re
from collections import defaultdict

__all__ = ["analyze_hlo", "parse_computations"]

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
                "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16}

_COMP_HDR_RE = re.compile(r"^\s*(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\{\s*$")
_DEF_RE = re.compile(r"^\s*%?([\w.\-]+)\s*=\s*([a-z]+\d*)\[([0-9,]*)\]")
_WHILE_RE = re.compile(
    r"while\(.*?\),\s*condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_TRIP_RE = re.compile(r"known_trip_count[^0-9]*(\d+)")
_CALL_RE = re.compile(r"(?:calls|to_apply)=%?([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_DOT_RE = re.compile(
    r"=\s*([a-z]+\d*)\[([0-9,]*)\][^=]*?\bdot\(\s*"
    # newer dumps carry the operand shape inline: dot(f32[64,128]{1,0} %lhs
    r"(?:[a-z]+\d*\[([0-9,]*)\](?:\{[0-9,]*\})?\s+)?%?([\w.\-]+)")
_LHS_C_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_COLL_RE = re.compile(
    r"=\s*([^=]*?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(")
_SHAPE_RE = re.compile(r"([a-z]+\d*)\[([0-9,]*)\]")


def _elems(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def parse_computations(hlo: str):
    """-> ({name: [lines]}, entry_name)."""
    comps: dict[str, list[str]] = {}
    entry = None
    cur = None
    for line in hlo.splitlines():
        if cur is None or line.rstrip().endswith("{"):
            m = _COMP_HDR_RE.match(line)
            if m and line.rstrip().endswith("{"):
                name = m.group(2)
                comps[name] = cur = []
                if m.group(1):
                    entry = name
                continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is not None:
            cur.append(line)
    return comps, entry


def _while_edges(lines):
    """[(cond, body, trips)] for every while op in a computation."""
    out = []
    for line in lines:
        m = _WHILE_RE.search(line)
        if not m:
            continue
        cond, body = m.groups()
        t = _TRIP_RE.search(line)
        out.append((cond, body, int(t.group(1)) if t else None))
    return out


def _call_edges(lines):
    out = []
    for line in lines:
        if _WHILE_RE.search(line):
            continue
        for name in _CALL_RE.findall(line):
            out.append(name)
    return out


def _fallback_trips(comp_lines) -> int:
    best = 1
    for line in comp_lines or []:
        for c in _CONST_RE.findall(line):
            best = max(best, int(c))
    return best


def _multipliers(comps, entry):
    mult = defaultdict(float)

    def visit(name, m, depth=0):
        if name not in comps or depth > 64:
            return
        mult[name] += m
        for cond, body, trips in _while_edges(comps[name]):
            if trips is None:
                trips = _fallback_trips(comps.get(cond))
            visit(body, m * trips, depth + 1)
        for callee in _call_edges(comps[name]):
            visit(callee, m, depth + 1)

    visit(entry, 1.0)
    return mult


def _dot_flops(lines) -> float:
    shapes = {}
    for line in lines:
        d = _DEF_RE.match(line)
        if d:
            shapes[d.group(1)] = (d.group(2), d.group(3))
    total = 0.0
    for line in lines:
        m = _DOT_RE.search(line)
        if not m:
            continue
        _, odims, lhs_dims_inline, lhs_name = m.groups()
        out_elems = _elems(odims)
        k = 1
        lhs_dims = lhs_dims_inline
        if lhs_dims is None:
            lhs = shapes.get(lhs_name)
            lhs_dims = lhs[1] if lhs else None
        cm = _LHS_C_RE.search(line)
        if lhs_dims is not None and cm:
            ldims = [int(x) for x in lhs_dims.split(",") if x]
            for idx in cm.group(1).split(","):
                if idx:
                    k *= ldims[int(idx)]
        total += 2.0 * out_elems * k
    return total


def _coll_bytes(lines):
    out = {}
    for line in lines:
        m = _COLL_RE.search(line)
        if not m:
            continue
        shape_str, op, suffix = m.groups()
        if suffix == "-done":
            continue
        b = sum(_elems(dims) * _DTYPE_BYTES.get(dt, 4)
                for dt, dims in _SHAPE_RE.findall(shape_str))
        # XLA-CPU's FloatNormalization promotes bf16 reductions to f32
        # (``to_apply=%add..._promoted``); on TPU these collectives run in
        # bf16, so count the TPU-equivalent bytes.
        if "_promoted" in line:
            b //= 2
        rec = out.setdefault(op, {"bytes": 0.0, "count": 0.0})
        rec["bytes"] += b
        rec["count"] += 1
    return out


def analyze_hlo(hlo: str) -> dict:
    """Loop-corrected {dot_flops, collectives: {kind: {bytes, count}}}."""
    comps, entry = parse_computations(hlo)
    if entry is None and comps:
        entry = list(comps)[-1]
    if entry is None:
        return {"dot_flops": 0.0, "collectives": {}}
    mult = _multipliers(comps, entry)
    dot_flops = 0.0
    coll: dict[str, dict[str, float]] = {}
    for name, m in mult.items():
        if m <= 0:
            continue
        lines = comps[name]
        dot_flops += m * _dot_flops(lines)
        for op, rec in _coll_bytes(lines).items():
            agg = coll.setdefault(op, {"bytes": 0.0, "count": 0.0})
            agg["bytes"] += m * rec["bytes"]
            agg["count"] += m * rec["count"]
    return {"dot_flops": dot_flops, "collectives": coll}
