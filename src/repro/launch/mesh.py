"""Production mesh construction (multi-pod dry-run contract).

A function, not a module-level constant — importing this module never
touches jax device state.  Single pod: (data=16, model=16) = 256 chips;
multi-pod adds a leading pod axis: (pod=2, data=16, model=16) = 512 chips.
"""
from __future__ import annotations

import jax
import numpy as np

from repro import compat

__all__ = ["make_production_mesh", "make_mesh_for"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    ndev = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < ndev:
        raise RuntimeError(
            f"need {ndev} devices for mesh {shape}, have {len(devices)} — "
            "the dry-run sets XLA_FLAGS=--xla_force_host_platform_device_count=512")
    # jax.make_mesh uses all devices by default; slice when we have extras
    # (the dry-run process exposes 512 but the single-pod mesh needs 256).
    return compat.make_mesh(shape, axes, devices=devices[:ndev])


def make_mesh_for(n_devices: int, *, model_parallel: int = 1):
    """Small-scale mesh for tests/examples: (data, model) over what exists."""
    devices = jax.devices()[:n_devices]
    data = n_devices // model_parallel
    return compat.make_mesh((data, model_parallel), ("data", "model"),
                            devices=devices)
