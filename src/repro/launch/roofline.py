"""Roofline analysis from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch x shape) cell on the single-pod mesh:

    compute    = FLOPs / (chips * peak)        peak = 197e12 bf16 flop/s/chip
    memory     = HBM bytes / (chips * bw)      bw   = 819e9  B/s/chip
    collective = coll bytes / (chips * link)   link = 50e9   B/s/link (ICI)

FLOPs: loop-corrected HLO dot flops (per-device, see hlo_analysis.py) —
reported next to MODEL_FLOPS = 6·N(_active)·D so the useful-work ratio is
visible.  HBM bytes: the analytic per-device floor (params + activations +
cache streams; cost_analysis bytes are loop-undercounted).  Collective
bytes: loop-corrected per-device sum over all collective ops.

Output: a markdown table + dominant-term identification + a one-line
"what would move it" note per cell.
"""
from __future__ import annotations

import argparse
import json
import pathlib

PEAK_FLOPS = 197e12          # bf16 per chip (TPU v5e-class, per assignment)
HBM_BW = 819e9               # B/s per chip
LINK_BW = 50e9               # B/s per ICI link

ART_DIR = pathlib.Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"

_MOVE_NOTES = {
    "compute": "raise per-chip utilization: larger per-device batch, fuse "
               "small ops, MXU-align head/ff dims",
    "memory": "cut HBM traffic: bf16/fp8 streams, fuse passes, "
              "ring-buffer windowed KV, larger block residency",
    "collective": "cut/overlap comm: reduce-scatter instead of all-reduce, "
                  "collective-matmul overlap, pod-local FSDP",
}


def load_records(art_dir=ART_DIR, mesh: str = "single"):
    recs = []
    for p in sorted(pathlib.Path(art_dir).glob("*.json")):
        r = json.loads(p.read_text())
        if r.get("mesh") == mesh:
            recs.append(r)
    return recs


def terms(rec: dict) -> dict | None:
    if "error" in rec or rec.get("skipped"):
        return None
    flops_dev = rec.get("dot_flops", 0.0)          # already per device
    hbm_dev = rec.get("analytic_hbm_bytes_per_dev",
                      rec.get("bytes_accessed_raw", 0.0))
    coll_dev = sum(v["bytes"] for v in rec.get("collectives", {}).values())
    t_c = flops_dev / PEAK_FLOPS
    t_m = hbm_dev / HBM_BW
    t_x = coll_dev / LINK_BW
    dom = max((("compute", t_c), ("memory", t_m), ("collective", t_x)),
              key=lambda kv: kv[1])[0]
    total = max(t_c, t_m, t_x)
    model_dev = rec.get("model_flops_per_dev", 0.0)
    # fraction of the physics-mandated time (useful compute OR the memory
    # floor, whichever binds) that the compiled program achieves
    useful = max(model_dev / PEAK_FLOPS, t_m)
    return {
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
        "dominant": dom,
        "roofline_frac": min(useful / total, 1.0) if total else 0.0,
        "model_ratio": model_dev / flops_dev if flops_dev else 0.0,
        "move": _MOVE_NOTES[dom],
    }


def fmt_row(rec: dict) -> str:
    cellname = f"{rec['arch']} × {rec['shape']}"
    if rec.get("skipped"):
        return f"| {cellname} | — | — | — | skipped: {rec['skipped']} | — | — |"
    if "error" in rec:
        return f"| {cellname} | — | — | — | ERROR: {rec['error'][:60]} | — | — |"
    t = terms(rec)
    return ("| {c} | {t[compute_s]:.2e} | {t[memory_s]:.2e} | "
            "{t[collective_s]:.2e} | **{t[dominant]}** | {t[model_ratio]:.2f} "
            "| {t[roofline_frac]:.1%} |").format(c=cellname, t=t)


def table(recs) -> str:
    hdr = ("| cell | compute (s) | memory (s) | collective (s) | dominant | "
           "MODEL/HLO flops | roofline frac |\n"
           "|---|---|---|---|---|---|---|")
    return "\n".join([hdr] + [fmt_row(r) for r in recs])


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--art-dir", default=str(ART_DIR))
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args()
    recs = load_records(args.art_dir, args.mesh)
    print(table(recs))
    print()
    for r in recs:
        t = terms(r)
        if t:
            print(f"- {r['arch']} × {r['shape']}: dominant={t['dominant']}; "
                  f"move it down: {t['move']}")


if __name__ == "__main__":
    main()
