"""Serving launcher: batched prefill + decode loop.

Demonstrates the inference path end-to-end at CPU scale: a batch of
prompts is prefilled (building the KV / recurrent cache), then tokens are
decoded greedily step by step.  The same ``serve_prefill``/``serve_step``
closures are what the dry-run lowers at the production shapes.

  python -m repro.launch.serve --arch rwkv6-1.6b --reduced --batch 4 \
      --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get
from repro.launch import steps as St
from repro.models import model as M


def serve(cfg, *, batch: int = 4, prompt_len: int = 32, gen: int = 16,
          seed: int = 0):
    key = jax.random.PRNGKey(seed)
    params = M.init_params(key, cfg)
    max_len = prompt_len + gen + (cfg.img_tokens or 0)
    prefill = jax.jit(St.make_serve_prefill(cfg, max_len=max_len))
    step = jax.jit(St.make_serve_step(cfg), donate_argnums=(2,))

    prompts = jax.random.randint(jax.random.PRNGKey(1), (batch, prompt_len),
                                 0, cfg.vocab)
    extra = None
    if cfg.img_tokens:
        extra = {"img_embeds": jnp.zeros((batch, cfg.img_tokens, cfg.d_model),
                                         jnp.dtype(cfg.compute_dtype))}
    if cfg.enc_layers:
        extra = {"audio_embeds": jnp.zeros(
            (batch, cfg.audio_ctx, cfg.d_model),
            jnp.dtype(cfg.compute_dtype))}

    t0 = time.time()
    logits, cache = prefill(params, prompts, extra)
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    t_prefill = time.time() - t0

    out = [tok]
    offset = cfg.img_tokens or 0
    t1 = time.time()
    for i in range(gen - 1):
        idx = jnp.asarray(prompt_len + offset + i, jnp.int32)
        logits, cache = step(params, tok, cache, idx)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t1
    tokens = jnp.concatenate(out, axis=1)
    return tokens, {"prefill_s": t_prefill, "decode_s": t_decode,
                    "tok_per_s": batch * (gen - 1) / max(t_decode, 1e-9)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()
    cfg = get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    tokens, stats = serve(cfg, batch=args.batch, prompt_len=args.prompt_len,
                          gen=args.gen)
    print(f"[serve] generated {tokens.shape} tokens; "
          f"prefill {stats['prefill_s']:.2f}s, "
          f"decode {stats['decode_s']:.2f}s "
          f"({stats['tok_per_s']:.1f} tok/s)")


if __name__ == "__main__":
    main()
