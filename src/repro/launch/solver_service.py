"""Solver service: queued solve requests, bucketed onto batched solves.

The serving story for the multi-RHS fast path (DESIGN.md §12): clients
submit single right-hand sides; the service groups compatible requests —
same (grid, n, dtype, precision, precond, stopping rule) — into buckets
and dispatches each bucket as ONE multi-RHS block solve of batch up to
``max_b`` through the driver registry (:func:`repro.core.solvers.
solve_case`).  The batched v2 kernels amortize the shared operator
streams over the batch (:func:`repro.core.cost.multi_rhs_streams`), so a
full bucket is strictly cheaper per RHS than ``b`` sequential solves.

Rules (pinned by tests/test_solver_service.py):
  * requests in *different* buckets are never co-scheduled — a dispatch
    contains one bucket only;
  * a bucket with more than ``max_b`` pending requests splits into
    ceil(k / max_b) dispatches (overflow never silently truncates);
  * ``drain()`` on an empty queue returns ``[]`` and dispatches nothing;
  * results come back in submission order, each carrying its request id.

Warm start: :meth:`SolverService.warm_start` pre-populates the autotune
cache (``$REPRO_CACHE_DIR`` — the JSON layer persists across processes,
so a deploy can ship a pre-baked cache) and compiles the solver for each
expected (bucket, batch) shape, taking the measuring sweep and the XLA
compile off the first request's latency.

Bench: ``python -m repro.launch.solver_service --requests 32 --max-b 8``
emits latency/throughput rows (consumed by benchmarks/run.py, schema v7).
"""
from __future__ import annotations

import argparse
import dataclasses
import itertools
import time
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.cg import SolveResult

__all__ = ["SolveRequest", "ServiceResult", "DispatchRecord",
           "SolverService", "bench_service"]


@dataclasses.dataclass
class SolveRequest:
    """One queued solve: a right-hand side plus its case/stopping params.

    ``config`` is a :class:`repro.configs.nekbone.NekboneConfig` (the
    case is instantiated once per distinct case key and cached).
    ``precond=None`` inherits the config's preconditioner; pass a
    registry name to override (the boolean spellings are deprecated at
    the solve layer and not accepted here).
    """

    f: Any                                  # (E, n, n, n) rhs
    config: Any                             # NekboneConfig
    niter: int | None = None
    tol: float = 1e-8
    max_iter: int = 1000
    precond: str | None = None
    request_id: int = -1                    # assigned by submit()


@dataclasses.dataclass
class ServiceResult:
    """Per-request outcome of a dispatched bucket solve."""

    request_id: int
    x: Any
    history: Any
    iters_taken: Any
    achieved_rtol: Any
    rnorm: Any
    pipeline: str | None
    precond: str | None
    bucket: tuple                           # the bucket key it ran under
    batch_size: int                         # b of the dispatch it rode in
    batch_index: int                        # its lane in that dispatch


@dataclasses.dataclass(eq=False)
class DispatchRecord:
    """One dispatched batch: the audit row of ``SolverService.dispatch_log``.

    Promoted from the ad-hoc ``(bucket, request_ids)`` tuple; the typed
    fields feed :class:`repro.obs.metrics.ServiceMetrics` and the trace.

    Deprecation shim: the old tuple shape still works — iterating or
    indexing a record yields ``(bucket, request_ids)`` and records
    compare equal to that tuple (pinned by tests/test_solver_service.py)
    — but new code should use the named fields.
    """

    bucket: tuple
    request_ids: list
    batch_size: int = 0
    wall_us: float = 0.0
    pipeline: str | None = None

    def __post_init__(self):
        if not self.batch_size:
            self.batch_size = len(self.request_ids)

    # -- legacy (bucket, request_ids) tuple protocol --------------------
    def __iter__(self):
        return iter((self.bucket, self.request_ids))

    def __len__(self) -> int:
        return 2

    def __getitem__(self, i):
        return (self.bucket, self.request_ids)[i]

    def __eq__(self, other):
        if isinstance(other, tuple):
            return (self.bucket, self.request_ids) == other
        if isinstance(other, DispatchRecord):
            return ((self.bucket, self.request_ids)
                    == (other.bucket, other.request_ids))
        return NotImplemented

    def __hash__(self):
        return hash((self.bucket, tuple(self.request_ids)))


def _bucket_key(req: SolveRequest) -> tuple:
    """Compatibility key: everything that must match for two requests to
    share one batched solve (same compiled case + same stopping rule)."""
    cfg = req.config
    pc = req.precond if req.precond is not None else cfg.precond
    stop = (("niter", req.niter) if req.niter is not None
            else ("tol", float(req.tol), req.max_iter))
    return (tuple(cfg.grid), cfg.n, str(cfg.dtype), cfg.ax_impl,
            cfg.precision, pc, cfg.s, cfg.cheb_k, stop)


def _case_key(cfg) -> tuple:
    return (tuple(cfg.grid), cfg.n, str(cfg.dtype), cfg.ax_impl,
            cfg.precision, cfg.precond, cfg.s, cfg.cheb_k)


class SolverService:
    """Request queue + bucketed batch dispatch over the driver registry."""

    def __init__(self, *, max_b: int = 8):
        if max_b < 1:
            raise ValueError(f"max_b must be >= 1, got {max_b}")
        from repro.obs.metrics import ServiceMetrics

        self.max_b = max_b
        self._queue: list[SolveRequest] = []
        self._next_id = itertools.count()
        self._cases: dict[tuple, Any] = {}
        # One DispatchRecord per dispatched batch, in dispatch order —
        # the audit trail the scheduling tests pin (records still
        # unpack/compare as the legacy (bucket, request_ids) tuples).
        self.dispatch_log: list[DispatchRecord] = []
        # always-on queue/dispatch metrics (DESIGN.md §14.2): a handful
        # of host floats per dispatch, JSON-snapshot-able.
        self.metrics = ServiceMetrics()

    # ------------------------------------------------------------------
    @property
    def pending(self) -> int:
        return len(self._queue)

    def submit(self, req: SolveRequest) -> int:
        """Enqueue one request; returns its assigned request id."""
        rid = next(self._next_id)
        req.request_id = rid
        self._queue.append(req)
        self.metrics.observe_submit(len(self._queue))
        return rid

    # ------------------------------------------------------------------
    def _case_for(self, cfg):
        key = _case_key(cfg)
        case = self._cases.get(key)
        if case is None:
            case = cfg.make_case()
            self._cases[key] = case
        return case

    def _dispatch(self, bucket: tuple, chunk: list[SolveRequest]
                  ) -> list[ServiceResult]:
        from repro.core import solvers as solvers_mod

        from repro.kernels.timing import stopwatch
        from repro.obs import trace as _trace

        case = self._case_for(chunk[0].config)
        first = chunk[0]
        f = jnp.stack([jnp.asarray(r.f) for r in chunk])
        rec = _trace.active()
        sw = stopwatch()
        with (rec.span("service.dispatch", batch=len(chunk),
                       max_b=self.max_b)
              if rec is not None else _trace.NULL_SPAN):
            res: SolveResult = solvers_mod.solve_case(
                case, f, b=len(chunk), niter=first.niter, tol=first.tol,
                max_iter=first.max_iter, precond=first.precond)
            jax.block_until_ready(res.x)
        wall = sw.us()
        self.dispatch_log.append(DispatchRecord(
            bucket=bucket, request_ids=[r.request_id for r in chunk],
            batch_size=len(chunk), wall_us=wall, pipeline=res.pipeline))
        self.metrics.observe_dispatch(bucket, len(chunk), self.max_b, wall)

        def lane(arr, j):
            a = jnp.asarray(arr)
            return a[j] if a.ndim and a.shape[0] == len(chunk) else a

        return [ServiceResult(
            request_id=r.request_id, x=res.x[j],
            history=lane(res.history, j),
            iters_taken=lane(res.iters_taken, j),
            achieved_rtol=lane(res.achieved_rtol, j),
            rnorm=lane(res.rnorm, j), pipeline=res.pipeline,
            precond=res.precond, bucket=bucket, batch_size=len(chunk),
            batch_index=j) for j, r in enumerate(chunk)]

    def drain(self) -> list[ServiceResult]:
        """Dispatch everything queued; results in submission order.

        Buckets are formed over the *current* queue contents; each bucket
        splits into chunks of at most ``max_b`` (in submission order) and
        each chunk is one batched solve.
        """
        if not self._queue:
            return []
        queue, self._queue = self._queue, []
        self.metrics.observe_depth(0)
        buckets: dict[tuple, list[SolveRequest]] = {}
        for req in queue:
            buckets.setdefault(_bucket_key(req), []).append(req)
        out: dict[int, ServiceResult] = {}
        for bucket, reqs in buckets.items():
            for lo in range(0, len(reqs), self.max_b):
                for sr in self._dispatch(bucket, reqs[lo:lo + self.max_b]):
                    out[sr.request_id] = sr
        return [out[r.request_id] for r in queue]

    # ------------------------------------------------------------------
    def warm_start(self, configs, *, batches=None, niter: int = 1) -> int:
        """Pre-tune and pre-compile the expected (case, batch) shapes.

        For every config × batch size: runs the autotune pick at that RHS
        count (populating the in-memory + ``$REPRO_CACHE_DIR`` JSON cache
        — ship that file to skip the measuring sweep entirely) and traces
        one ``niter``-iteration batched solve so the XLA executable is
        resident before the first real request.  Returns the number of
        (case, b) combinations warmed.
        """
        from repro.core import solvers as solvers_mod
        from repro.kernels import autotune as _autotune

        batches = sorted(set(batches or (1, self.max_b)))
        warmed = 0
        for cfg in configs:
            case = self._case_for(cfg)
            if case.ax_impl in ("pallas_fused_cg", "pallas_fused_cg_v2",
                                "pallas_sstep_v3"):
                for b in batches:
                    _autotune.pick_slab_config(
                        tuple(case.grid), case.n, case.dtype,
                        precond=case.precond, nrhs=b)
            _, f1 = case.manufactured()
            for b in batches:
                f = f1[None] if b == 1 else jnp.stack([f1] * b)
                res = solvers_mod.solve_case(case, f, b=b, niter=niter)
                jax.block_until_ready(res.x)
                warmed += 1
        return warmed


# ---------------------------------------------------------------------------
# latency / throughput bench (schema v7 `solver_service` rows)
# ---------------------------------------------------------------------------

def bench_service(*, nelt: int = 64, n: int | None = None,
                  requests: int = 16, max_b: int = 8,
                  niter: int = 25, warm: bool = True,
                  repeats: int = 3) -> dict:
    """Measure request latency and drain throughput at several batches.

    Submits ``requests`` manufactured-RHS requests and drains with
    ``max_b`` in {1, ..., max_b}: b=1 is the sequential baseline (one
    solve per request), larger b amortizes the operator streams.  Returns
    a payload row set ``{str(b): {latency_ms_per_request,
    throughput_req_s, dispatches}}`` plus the environment.
    """
    from repro.configs.nekbone import paper_case

    cfg = paper_case(nelt)
    if n is not None:
        cfg = dataclasses.replace(cfg, n=n)
    cfg = dataclasses.replace(cfg, ax_impl="pallas_fused_cg_v2")
    case = cfg.make_case()
    _, f1 = case.manufactured()
    rows: dict[str, dict] = {}
    bs = sorted({b for b in (1, 2, 4, 8) if b <= max_b} | {max_b})
    for b in bs:
        svc = SolverService(max_b=b)
        svc._cases[_case_key(cfg)] = case
        if warm:
            svc.warm_start([cfg], batches=[min(b, requests)], niter=niter)
        best = float("inf")
        dispatches = 0
        for _ in range(repeats):
            for _ in range(requests):
                svc.submit(SolveRequest(f=f1, config=cfg, niter=niter))
            t0 = time.perf_counter()
            results = svc.drain()
            jax.block_until_ready([r.x for r in results])
            dt = time.perf_counter() - t0
            best = min(best, dt)
            dispatches = len(svc.dispatch_log)
            svc.dispatch_log.clear()
        rows[str(b)] = {
            "latency_ms_per_request": best * 1e3 / requests,
            "throughput_req_s": requests / best,
            "dispatches": dispatches,
        }
    return {"nelt": cfg.nelt, "n": cfg.n, "niter": niter,
            "requests": requests, "backend": jax.default_backend(),
            "rows": rows}


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--nelt", type=int, default=64)
    ap.add_argument("--n", type=int, default=None)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-b", type=int, default=8)
    ap.add_argument("--niter", type=int, default=25)
    ap.add_argument("--repeats", type=int, default=3)
    args = ap.parse_args()
    payload = bench_service(nelt=args.nelt, n=args.n,
                            requests=args.requests, max_b=args.max_b,
                            niter=args.niter, repeats=args.repeats)
    print(f"[solver-service] E={payload['nelt']} n={payload['n']} "
          f"niter={payload['niter']} requests={payload['requests']} "
          f"({payload['backend']})")
    for b, row in payload["rows"].items():
        print(f"  b<={b:>2}: {row['latency_ms_per_request']:8.2f} "
              f"ms/request  {row['throughput_req_s']:8.2f} req/s  "
              f"({row['dispatches']} dispatches)")


if __name__ == "__main__":
    main()
