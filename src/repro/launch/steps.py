"""The three step functions every (arch x shape) cell lowers.

  * ``train_step``    — fwd + bwd + AdamW update (+ optional cross-pod
                        gradient compression); donates the train state.
  * ``serve_prefill`` — full-prompt forward producing the KV cache.
  * ``serve_step``    — one-token decode against a seq_len cache.

These are *pure functions of (cfg, flags)* returning closures, so the
dry-run, the trainer, and the tests all lower exactly the same code.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.optim import adamw_init, adamw_update, cosine_schedule

__all__ = ["TrainState", "make_train_state", "make_train_step",
           "make_serve_prefill", "make_serve_step"]


class TrainState(NamedTuple):
    params: Any
    mu: Any
    nu: Any
    step: jnp.ndarray


def make_train_state(key, cfg) -> TrainState:
    params = M.init_params(key, cfg)
    opt = adamw_init(params,
                     moment_dtype=jnp.dtype(getattr(cfg, "opt_moment_dtype",
                                                    "float32")))
    return TrainState(params=params, mu=opt.mu, nu=opt.nu, step=opt.step)


def make_train_step(cfg, *, peak_lr: float = 3e-4, warmup: int = 100,
                    total_steps: int = 10_000, grad_compression: str = "none",
                    grad_accum: int = 1):
    """Returns train_step(state, batch, extra) -> (state, metrics)."""

    def loss(params, batch, extra):
        return M.loss_fn(params, cfg, batch, extra)

    def train_step(state: TrainState, batch, extra=None):
        tokens = batch["tokens"]
        if grad_accum > 1:
            B = tokens.shape[0]
            mb = B // grad_accum
            def acc_body(carry, i):
                gsum, lsum = carry
                sl = jax.lax.dynamic_slice_in_dim(tokens, i * mb, mb, axis=0)
                ex = (None if extra is None else jax.tree.map(
                    lambda a: jax.lax.dynamic_slice_in_dim(a, i * mb, mb, 0),
                    extra))
                l, g = jax.value_and_grad(loss)(state.params, {"tokens": sl}, ex)
                gsum = jax.tree.map(jnp.add, gsum, g)
                return (gsum, lsum + l), None
            gz = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              state.params)
            (grads, lsum), _ = jax.lax.scan(acc_body, (gz, 0.0),
                                            jnp.arange(grad_accum))
            grads = jax.tree.map(lambda g: g / grad_accum, grads)
            l = lsum / grad_accum
        else:
            l, grads = jax.value_and_grad(loss)(state.params, batch, extra)

        if grad_compression != "none":
            # Cross-pod DP all-reduce with a narrow wire format.  With pure
            # GSPMD the pod reduction is implicit in the sharded loss mean;
            # compression requires the explicit form, so it is applied in
            # shard_map over 'pod' by the caller (see launch/train.py).
            grads = jax.tree.map(
                lambda g: g.astype(jnp.bfloat16).astype(g.dtype)
                if grad_compression == "bf16" else g, grads)

        # Pin gradient sharding to the parameter sharding before the
        # optimizer update.  (§Perf note: hypothesised to convert the
        # batch-axis grad reduction into reduce-scatter; measurement showed
        # XLA already emits the reduction on TP-sharded shapes inside the
        # layer loop, so this is belt-and-braces for partitioner drift, not
        # a byte win — see EXPERIMENTS.md §Perf iteration log.)
        from repro.distributed.sharding import constrain, current_mesh
        from repro.models.model import param_specs as _pspecs

        mesh = current_mesh()
        if mesh is not None:
            specs = _pspecs(cfg, grads, mesh)
            grads = jax.tree.map(lambda g, s: constrain(g, s), grads, specs)

        lr = cosine_schedule(state.step, peak=peak_lr, warmup_steps=warmup,
                             total_steps=total_steps)
        new_params, opt, om = adamw_update(state.params, grads,
                                           _opt_state(state), lr=lr)
        new_state = TrainState(params=new_params, mu=opt.mu, nu=opt.nu,
                               step=opt.step)
        metrics = {"loss": l, "lr": lr, "grad_norm": om["grad_norm"],
                   "step": opt.step}
        return new_state, metrics

    return train_step


def _opt_state(state: TrainState):
    from repro.optim.adamw import AdamWState

    return AdamWState(step=state.step, mu=state.mu, nu=state.nu)


def make_serve_prefill(cfg, *, max_len: int, context_parallel: bool = False):
    def serve_prefill(params, tokens, extra=None):
        return M.prefill(params, cfg, tokens, extra, max_len=max_len,
                         context_parallel=context_parallel)

    return serve_prefill


def make_serve_step(cfg, *, context_parallel: bool = False):
    def serve_step(params, tokens, cache, index):
        return M.decode_step(params, cfg, tokens, cache, index,
                             context_parallel=context_parallel)

    return serve_step
