"""Training launcher: fault-tolerant loop around ``steps.make_train_step``.

Production behaviours wired in (all exercised by tests/examples at CPU
scale; the same code drives the dry-run meshes):

  * checkpoint/restart — atomic async checkpoints every ``--ckpt-every``
    steps, auto-resume from the latest on startup (restart-safe data
    pipeline: batches are a pure function of the step index),
  * preemption — SIGTERM triggers a synchronous save + clean exit,
  * elastic restarts — restore re-shards onto the current mesh,
  * straggler watchdog — per-step wall-time EWMA; steps slower than
    ``--straggler-factor`` x median are logged with the step index (on a
    real pod this feeds the controller's replace-node decision),
  * gradient accumulation (``--grad-accum``) and cross-pod gradient
    compression (``--grad-compression bf16|int8``).

Example (CPU, tiny arch):
  python -m repro.launch.train --arch qwen2.5-14b --reduced --steps 30 \
      --batch 8 --seq 128 --ckpt-dir /tmp/ck
"""
from __future__ import annotations

import argparse
import statistics
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import get
from repro.data import SyntheticLMStream
from repro.launch import steps as St


class StragglerWatchdog:
    """Flags steps whose wall time exceeds ``factor`` x running median."""

    def __init__(self, factor: float = 2.0, window: int = 32):
        self.factor = factor
        self.window = window
        self.times: list[float] = []
        self.flagged: list[tuple[int, float]] = []

    def observe(self, step: int, dt: float):
        if len(self.times) >= 8:
            med = statistics.median(self.times[-self.window:])
            if dt > self.factor * med:
                self.flagged.append((step, dt))
                print(f"[watchdog] step {step} took {dt:.3f}s "
                      f"(median {med:.3f}s) — straggler suspected")
        self.times.append(dt)


def train(cfg, *, steps: int = 30, batch: int = 8, seq: int = 128,
          ckpt_dir: str | None = None, ckpt_every: int = 10,
          peak_lr: float = 3e-4, grad_accum: int = 1,
          grad_compression: str = "none", seed: int = 0,
          log_every: int = 1):
    key = jax.random.PRNGKey(seed)
    state = St.make_train_state(key, cfg)
    step_fn = jax.jit(St.make_train_step(
        cfg, peak_lr=peak_lr, total_steps=max(steps, 100),
        warmup=max(steps // 10, 1), grad_accum=grad_accum,
        grad_compression=grad_compression), donate_argnums=(0,))

    start = 0
    mgr = None
    if ckpt_dir:
        mgr = CheckpointManager(ckpt_dir)
        if mgr.latest_step() is not None:
            start, state = mgr.restore(state)
            print(f"[train] resumed from step {start}")
        cur = {"state": None, "step": 0}
        mgr.install_sigterm_handler(lambda: (cur["step"], cur["state"]))

    data = SyntheticLMStream(vocab=cfg.vocab, seed=seed)
    wd = StragglerWatchdog()
    losses = []
    for step in range(start, steps):
        batch_np = data.batch(step, batch, seq)
        t0 = time.time()
        state, metrics = step_fn(state, {"tokens": jnp.asarray(batch_np)})
        loss = float(metrics["loss"])
        dt = time.time() - t0
        wd.observe(step, dt)
        losses.append(loss)
        if step % log_every == 0:
            print(f"[train] step {step:5d} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} {dt:.2f}s")
        if mgr:
            cur = {"state": state, "step": step + 1}
            if (step + 1) % ckpt_every == 0:
                mgr.save(step + 1, state, blocking=False)
    if mgr:
        mgr.save(steps, state, blocking=True)
    return state, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-scale config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--grad-compression", default="none",
                    choices=["none", "bf16", "int8"])
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()

    cfg = get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    _, losses = train(cfg, steps=args.steps, batch=args.batch, seq=args.seq,
                      ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                      peak_lr=args.lr, grad_accum=args.grad_accum,
                      grad_compression=args.grad_compression)
    print(f"[train] loss {losses[0]:.4f} -> {losses[-1]:.4f} "
          f"over {len(losses)} steps")


if __name__ == "__main__":
    main()
