"""LM substrate: unified model over all assigned architectures."""
from repro.models import attention, layers, model, moe, rwkv6, ssm  # noqa: F401

__all__ = ["attention", "layers", "model", "moe", "rwkv6", "ssm"]
