"""GQA attention: prefill (naive / chunked-XLA / Pallas-flash) and decode.

Implementation ladder (DESIGN.md §4):
  * ``naive``   — full (Sq, Skv) score matrix; the oracle, small shapes only.
  * ``chunked`` — nested-scan online softmax in pure XLA: flash-attention
    scheduling without the kernel.  Differentiable (training default) and
    compile-friendly at 32k+ (no S^2 materialization) — used by the dry-run.
  * ``flash``   — the Pallas kernel (kernels/flash_attn.py), inference
    prefill on real TPUs; validated against ``naive`` in interpret mode.

Decode attends a (B, Hkv, S, hd) KV cache updated at ``cache_index``.
Cache sharding (distributed/sharding.py): kv-heads over the TP axis when
divisible, otherwise the cache *sequence* axis is TP-sharded and XLA's SPMD
partitioner turns the softmax reductions into all-reduces — the same
partial-softmax scheme as ring/context-parallel attention.

Supports: GQA grouping, sliding window, gemma2 logit softcap, QKV biases
(qwen1.5/2.5), qk-norm (qwen3), and learned or rotary positions.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.compat import shard_map
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import RULES, constrain
from repro.models import layers as L

__all__ = ["init_attention", "attention", "decode_attention", "init_kv_cache"]

_NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------
def init_attention(key, cfg) -> dict:
    ks = jax.random.split(key, 6)
    d, H, Hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dt = jnp.dtype(cfg.param_dtype)
    p = {
        "wq": L.init_linear(ks[0], d, H * hd, bias=cfg.qkv_bias, dtype=dt),
        "wk": L.init_linear(ks[1], d, Hkv * hd, bias=cfg.qkv_bias, dtype=dt),
        "wv": L.init_linear(ks[2], d, Hkv * hd, bias=cfg.qkv_bias, dtype=dt),
        "wo": L.init_linear(ks[3], H * hd, d, dtype=dt),
    }
    if cfg.qk_norm:
        p["q_norm"] = L.init_norm(hd, dtype=dt)
        p["k_norm"] = L.init_norm(hd, dtype=dt)
    return p


def _project_qkv(x, p, cfg, positions):
    B, S, _ = x.shape
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    cdt = jnp.dtype(cfg.compute_dtype)
    q = L.linear(x, p["wq"], cdt).reshape(B, S, H, hd)
    k = L.linear(x, p["wk"], cdt).reshape(B, S, Hkv, hd)
    v = L.linear(x, p["wv"], cdt).reshape(B, S, Hkv, hd)
    if cfg.qk_norm:
        q = L.rms_norm(q, p["q_norm"], eps=cfg.norm_eps)
        k = L.rms_norm(k, p["k_norm"], eps=cfg.norm_eps)
    if cfg.pos_emb == "rope":
        q = L.rope(q, positions, theta=cfg.rope_theta)
        k = L.rope(k, positions, theta=cfg.rope_theta)
    q = constrain(q, RULES.act_bthd(H))
    k = constrain(k, RULES.act_bthd(Hkv))
    v = constrain(v, RULES.act_bthd(Hkv))
    return q, k, v


# ---------------------------------------------------------------------------
# Prefill implementations (q, k, v in (B, heads, S, hd))
# ---------------------------------------------------------------------------
def _naive(q, k, v, *, causal, window, cap, scale, q_offset):
    from repro.kernels.ref import attention_ref

    return attention_ref(q, k, v, causal=causal, scale=scale, window=window,
                        softcap=cap, q_offset=q_offset)


def _pick_block(s: int, want: int) -> int:
    b = min(want, s)
    while s % b:
        b //= 2
    return max(b, 1)


def _dp_size(mesh) -> int:
    s = 1
    for a in RULES.dp:
        if a in mesh.axis_names:
            s *= mesh.shape[a]
    return s


def _chunked(q, k, v, *, causal, window, cap, scale, q_offset,
             block_q=512, block_k=1024, q_shift=0, halo=0):
    """Nested-scan online-softmax attention (flash scheduling in XLA).

    ``window`` must be a *static* int (or None): sliding-window layers use
    the banded schedule — each q block visits only the ``ceil(w/bk)+1``
    kv blocks its band can touch, instead of all ``Skv/bk`` (a ~S/w compute
    saving at long context; EXPERIMENTS.md §Perf hymba prefill_32k).

    ``q_shift`` is a (possibly traced) bk-aligned absolute position offset
    of the whole q array (sequence-sharded path: each device owns a
    contiguous q slice).  ``halo`` (static, bk-aligned) says the kv array
    is laid out ``[halo | local]``: kv index i has absolute position
    ``q_shift - halo + i`` (halo-exchange path; the first shard's halo
    rows sit at negative positions and are masked).
    """
    B, Hq, Sq, hd = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    bq = _pick_block(Sq, block_q)
    bk = _pick_block(Skv, block_k)
    nq, nk = Sq // bq, Skv // bk
    qg = q.reshape(B, Hkv, G, Sq, hd)
    # banded schedule needs a *static* window smaller than the kv length
    # (traced windows fall back to the full scan, which is still correct)
    banded = isinstance(window, int) and causal and window < Skv
    if banded:
        # kv blocks per band: the band spans (window-1 back) + bq q-positions
        nb = min(nk, (int(window) + bq - 2) // bk + 2)
    k_base = (q_shift - halo) if halo else 0      # abs position of kv[0]

    def q_block(iq):
        qb = jax.lax.dynamic_slice_in_dim(qg, iq * bq, bq, axis=3)
        qb = qb.astype(jnp.float32)
        qpos = q_offset + q_shift + iq * bq + jnp.arange(bq)

        def kv_step(carry, ik):
            # banded: ik is a backwards offset from the q block's top block
            if banded:
                if halo:
                    # halo layout: local block arithmetic is fully static
                    top = (halo + q_offset + (iq + 1) * bq - 1) // bk
                else:
                    # q_shift is bk-aligned, so the block split is exact
                    top = q_shift // bk + (q_offset + (iq + 1) * bq - 1) // bk
                kb_idx = top - ik
                valid = (kb_idx >= 0) & (kb_idx < nk)
                kb_idx = jnp.clip(kb_idx, 0, nk - 1)
            else:
                kb_idx = ik
                valid = jnp.asarray(True)
            m, l, acc = carry
            kb = jax.lax.dynamic_slice_in_dim(k, kb_idx * bk, bk, axis=2)
            vb = jax.lax.dynamic_slice_in_dim(v, kb_idx * bk, bk, axis=2)
            s = jnp.einsum("bhgqd,bhkd->bhgqk", qb, kb.astype(jnp.float32))
            s = s * scale
            if cap is not None:
                s = L.softcap(s, cap)
            kpos = k_base + kb_idx * bk + jnp.arange(bk)
            mask = jnp.broadcast_to(valid, (bq, bk))
            if halo:
                mask &= kpos[None, :] >= 0        # first-shard halo padding
            if causal:
                mask &= kpos[None, :] <= qpos[:, None]
            if window is not None:
                mask &= qpos[:, None] - kpos[None, :] < window
            s = jnp.where(mask, s, _NEG_INF)
            m_new = jnp.maximum(m, s.max(-1, keepdims=True))
            pfac = jnp.exp(m - m_new)                   # (..., bq, 1)
            pb = jnp.exp(s - m_new) * mask
            l = l * pfac + pb.sum(-1, keepdims=True)
            acc = acc * pfac + jnp.einsum("bhgqk,bhkd->bhgqd", pb,
                                          vb.astype(jnp.float32))
            return (m_new, l, acc), None

        m0 = jnp.full((B, Hkv, G, bq, 1), _NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, bq, 1), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, bq, hd), jnp.float32)
        steps = jnp.arange(nb if banded else nk)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), steps)
        l = jnp.where(l == 0.0, 1.0, l)
        return (acc / l).astype(q.dtype)

    blocks = jax.lax.map(q_block, jnp.arange(nq))     # (nq, B, Hkv, G, bq, hd)
    out = jnp.moveaxis(blocks, 0, 3).reshape(B, Hkv, G, Sq, hd)
    return out.reshape(B, Hq, Sq, hd)


def _flash(q, k, v, *, causal, window, cap, scale, q_offset):
    from repro.kernels import ops

    return ops.flash_attention(q, k, v, causal=causal, scale=scale,
                               window=window, softcap=cap, q_offset=q_offset)


_IMPLS = {"naive": _naive, "chunked": _chunked, "flash": _flash}


# ---------------------------------------------------------------------------
# Public blocks
# ---------------------------------------------------------------------------
def _seq_sharded_chunked(q, k, v, *, causal, window, cap, scale):
    """Sequence-parallel chunked attention over the TP axis.

    When the head count does not divide the TP degree (hymba: 25 heads,
    whisper: 20), GSPMD replicates attention compute across 'model' — a
    tp_size-fold waste.  Here each TP device owns a contiguous q slice
    (KV replicated, cheap vs the S^2 compute) so the quadratic work is
    divided by tp_size regardless of head count.
    """
    from repro.distributed.sharding import current_mesh

    mesh = current_mesh()
    tp = RULES.tp
    tp_size = mesh.shape[tp]
    dp = tuple(a for a in RULES.dp if a in mesh.axis_names)
    S = q.shape[2]
    S_loc = S // tp_size
    bq = min(512, S_loc)
    bk = min(1024, S_loc)

    # Windowed layers: KV stays sequence-sharded too; each shard only needs
    # a ``window``-sized halo from its left neighbour (one ppermute) instead
    # of the full KV all-gather — the dominant collective of this path
    # (EXPERIMENTS.md §Perf, hymba prefill_32k iteration 3).
    halo = 0
    if isinstance(window, int) and causal and window < S_loc:
        halo = -(-window // bk) * bk              # round up to block size

    def body(q_l, k_f, v_f):
        shift = jax.lax.axis_index(tp) * S_loc
        if halo:
            perm = [(i, i + 1) for i in range(tp_size - 1)]
            hk = jax.lax.ppermute(k_f[:, :, S_loc - halo:], tp, perm)
            hv = jax.lax.ppermute(v_f[:, :, S_loc - halo:], tp, perm)
            k_ext = jnp.concatenate([hk, k_f], axis=2)
            v_ext = jnp.concatenate([hv, v_f], axis=2)
            return _chunked(q_l, k_ext, v_ext, causal=causal, window=window,
                            cap=cap, scale=scale, q_offset=0, q_shift=shift,
                            halo=halo, block_q=bq, block_k=bk)
        # block_k must divide S_loc so the traced q_shift stays block-aligned
        return _chunked(q_l, k_f, v_f, causal=causal, window=window,
                        cap=cap, scale=scale, q_offset=0, q_shift=shift,
                        block_q=bq, block_k=bk)

    kv_spec = (P(dp, None, tp, None) if halo else P(dp, None, None, None))
    return shard_map(
        body, mesh=mesh,
        in_specs=(P(dp, None, tp, None), kv_spec, kv_spec),
        out_specs=P(dp, None, tp, None), check_vma=False)(q, k, v)


def _use_seq_shard(cfg, q, k) -> bool:
    from repro.distributed.sharding import current_mesh

    mesh = current_mesh()
    if mesh is None or RULES.tp not in mesh.axis_names:
        return False
    tp_size = mesh.shape[RULES.tp]
    if tp_size == 1 or cfg.n_heads % tp_size == 0:
        return False                       # head sharding already divides work
    S = q.shape[2]
    B = q.shape[0]
    dp = 1
    for a in RULES.dp:
        if a in mesh.axis_names:
            dp *= mesh.shape[a]
    return S % tp_size == 0 and (S // tp_size) >= 8 and B % max(dp, 1) == 0


def attention(x, p, cfg, *, positions, window=None, causal=True,
              impl: str = "chunked", kv_override=None):
    """Full-sequence (training / prefill) attention.

    Returns (out, (k, v)) — k/v in (B, Hkv, S, hd) for cache construction.
    ``kv_override`` supplies external K/V (cross-attention).
    """
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q, k, v = _project_qkv(x, p, cfg, positions)
    q = q.swapaxes(1, 2)                     # (B, H, S, hd)
    if kv_override is not None:
        k, v = kv_override                   # already (B, Hkv, S, hd)
    else:
        k = k.swapaxes(1, 2)
        v = v.swapaxes(1, 2)
    scale = hd ** -0.5
    seq_sharded = impl == "chunked" and _use_seq_shard(cfg, q, k)
    if seq_sharded:
        out = _seq_sharded_chunked(q, k, v, causal=causal, window=window,
                                   cap=cfg.attn_softcap, scale=scale)
    else:
        out = _IMPLS[impl](q, k, v, causal=causal, window=window,
                           cap=cfg.attn_softcap, scale=scale, q_offset=0)
    B, _, S, _ = out.shape
    out = out.swapaxes(1, 2).reshape(B, S, H * hd)
    if seq_sharded:
        # keep the output projection running on sequence shards; only its
        # (B, S, d) result is gathered by the caller's constraint
        out = constrain(out, P(RULES.dp, RULES.tp, None))
    return L.linear(out, p["wo"], jnp.dtype(cfg.compute_dtype)), (k, v)


def init_kv_cache(cfg, batch: int, max_len: int, *, context_parallel=False):
    """Stacked-over-layers KV cache arrays for one layer (scan stacks them)."""
    Hkv, hd = cfg.n_kv_heads, cfg.head_dim
    dt = jnp.dtype(cfg.compute_dtype)
    shape = (batch, Hkv, max_len, hd)
    spec = (RULES.kv_cache_cp(Hkv) if context_parallel
            else RULES.kv_cache(Hkv))
    k = constrain(jnp.zeros(shape, dt), spec)
    v = constrain(jnp.zeros(shape, dt), spec)
    return {"k": k, "v": v}


def _decode_attn_seq_sharded(q, cache_k, cache_v, k_new, v_new, cache_index,
                             *, axis: str, window, softcap, scale):
    """Decode against a sequence-sharded KV cache, zero cache movement.

    The write lands only on the shard owning ``cache_index`` (local masked
    update — no collective); attention is partial-softmax combined across
    shards (distributed/context_parallel.py).  This is what makes the
    kv_heads < TP-degree serving configs (qwen2.5, nemotron, arctic, hymba)
    and the 512k context-parallel cells scale (EXPERIMENTS.md §Perf).
    """
    from repro.distributed.context_parallel import cp_decode_attention
    from repro.distributed.sharding import current_mesh

    mesh = current_mesh()
    dp = tuple(a for a in RULES.dp if a in mesh.axis_names and a != axis)
    B = q.shape[0]
    dp_sz = 1
    for a in dp:
        dp_sz *= mesh.shape[a]
    if B % max(dp_sz, 1) != 0:
        dp = ()                            # batch 1 (context-parallel cells)
    S = cache_k.shape[2]
    S_loc = S // mesh.shape[axis]
    # context-parallel cells (axis='data') can still shard heads over TP —
    # dropping that sharding at the shard_map boundary would all-gather the
    # whole cache over 'model' every layer (EXPERIMENTS.md §Perf, gemma2
    # long_500k: 24.7 GB/step -> ~0).
    head_axis = None
    if (RULES.tp in mesh.axis_names and RULES.tp != axis
            and mesh.shape[RULES.tp] > 1):
        tp_sz = mesh.shape[RULES.tp]
        if cache_k.shape[1] % tp_sz == 0 and q.shape[1] % tp_sz == 0:
            head_axis = RULES.tp

    def body(q, kc, vc, kn, vn):
        j = jax.lax.axis_index(axis)
        li = cache_index - j * S_loc
        owner = jnp.logical_and(li >= 0, li < S_loc)
        lic = jnp.clip(li, 0, S_loc - 1)
        kc = jnp.where(owner,
                       jax.lax.dynamic_update_slice_in_dim(kc, kn, lic, 2), kc)
        vc = jnp.where(owner,
                       jax.lax.dynamic_update_slice_in_dim(vc, vn, lic, 2), vc)
        out = cp_decode_attention(q, kc, vc, axis_name=axis,
                                  kv_valid_len=cache_index + 1,
                                  window=window, softcap=softcap, scale=scale)
        return out, kc, vc

    kv_spec = P(dp, head_axis, axis, None)
    rep = P(dp, head_axis, None, None)
    out, kc, vc = shard_map(
        body, mesh=mesh,
        in_specs=(rep, kv_spec, kv_spec, rep, rep),
        out_specs=(rep, kv_spec, kv_spec), check_vma=False,
    )(q, cache_k, cache_v, k_new, v_new)
    # re-assert the cache sharding so the layer-scan carry keeps it sharded
    # (otherwise GSPMD may replicate the carry and all-gather per layer)
    return out, constrain(kc, kv_spec), constrain(vc, kv_spec)


def decode_attention(x, p, cfg, cache: dict, cache_index, *, window=None,
                     context_parallel=False):
    """Single-token decode: update cache at ``cache_index`` and attend.

    x: (B, 1, d); cache k/v: (B, Hkv, S, hd).  Returns (out, new_cache).

    Cache layouts (matching configs/specs.cache_specs):
      * kv-heads divisible by TP -> heads sharded, GSPMD path below;
      * otherwise the cache *sequence* is sharded (over 'model', or over
        'data' for the context-parallel long_500k cells) and the explicit
        shard_map path runs: local masked write + partial-softmax combine.
    """
    from repro.distributed.sharding import current_mesh

    B = x.shape[0]
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    G = H // Hkv
    positions = jnp.full((B, 1), cache_index, jnp.int32)
    q, k_new, v_new = _project_qkv(x, p, cfg, positions)

    mesh = current_mesh()
    seq_axis = None
    if mesh is not None:
        if context_parallel and RULES.seq in mesh.axis_names:
            seq_axis = RULES.seq
        elif (RULES.tp in mesh.axis_names and mesh.shape[RULES.tp] > 1
              and Hkv % mesh.shape[RULES.tp] != 0
              and cache["k"].shape[2] % mesh.shape[RULES.tp] == 0
              and B % _dp_size(mesh) == 0):
            seq_axis = RULES.tp

    if seq_axis is not None:
        out, k, v = _decode_attn_seq_sharded(
            q.swapaxes(1, 2), cache["k"], cache["v"],
            k_new.swapaxes(1, 2), v_new.swapaxes(1, 2), cache_index,
            axis=seq_axis, window=window, softcap=cfg.attn_softcap,
            scale=hd ** -0.5)
        out = out.swapaxes(1, 2).reshape(B, 1, H * hd)
        out = L.linear(out.astype(x.dtype), p["wo"],
                       jnp.dtype(cfg.compute_dtype))
        return out, {"k": k, "v": v}

    spec = (RULES.kv_cache_cp(Hkv) if context_parallel
            else RULES.kv_cache(Hkv))
    k = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], k_new.swapaxes(1, 2), cache_index, axis=2)
    v = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v_new.swapaxes(1, 2), cache_index, axis=2)
    k = constrain(k, spec)
    v = constrain(v, spec)

    qg = q.reshape(B, 1, Hkv, G, hd).astype(jnp.float32)
    s = jnp.einsum("bqhgd,bhkd->bhgqk", qg, k.astype(jnp.float32))
    s = s * (hd ** -0.5)
    s = L.softcap(s, cfg.attn_softcap)
    kpos = jnp.arange(k.shape[2])
    mask = kpos <= cache_index
    if window is not None:
        mask &= cache_index - kpos < window
    s = jnp.where(mask[None, None, None, None, :], s, _NEG_INF)
    pmax = s.max(-1, keepdims=True)
    pe = jnp.exp(s - pmax)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", pe, v.astype(jnp.float32))
    out = out / pe.sum(-1, keepdims=True)
    out = out.reshape(B, Hkv * G, 1, hd).swapaxes(1, 2).reshape(B, 1, H * hd)
    out = L.linear(out.astype(x.dtype), p["wo"], jnp.dtype(cfg.compute_dtype))
    return out, {"k": k, "v": v}
