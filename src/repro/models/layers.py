"""Shared NN building blocks: norms, activations, MLPs, embeddings, RoPE."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import RULES, constrain
from jax.sharding import PartitionSpec as P

__all__ = ["rms_norm", "layer_norm", "mlp", "init_mlp", "rope", "softcap",
           "init_linear", "linear", "init_norm", "activation"]


def init_norm(d: int, *, bias: bool = False, dtype=jnp.float32) -> dict:
    p = {"scale": jnp.ones((d,), dtype)}
    if bias:
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def rms_norm(x: jnp.ndarray, p: dict, *, eps: float = 1e-6,
             plus_one: bool = False) -> jnp.ndarray:
    """RMSNorm; ``plus_one`` uses the gemma-style (1 + scale) param."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    scale = p["scale"].astype(jnp.float32)
    scale = 1.0 + scale if plus_one else scale
    return (x * scale).astype(dt)


def layer_norm(x: jnp.ndarray, p: dict, *, eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    out = x * p["scale"].astype(jnp.float32)
    if "bias" in p:
        out = out + p["bias"].astype(jnp.float32)
    return out.astype(dt)


def softcap(x: jnp.ndarray, cap: float | None) -> jnp.ndarray:
    """gemma2-style logit soft-capping: cap * tanh(x / cap)."""
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


def activation(x: jnp.ndarray, kind: str) -> jnp.ndarray:
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x, approximate=True)
    if kind == "relu2":            # nemotron-4 squared ReLU
        r = jax.nn.relu(x)
        return r * r
    raise ValueError(f"unknown activation {kind!r}")


# ---------------------------------------------------------------------------
# Linear / MLP
# ---------------------------------------------------------------------------
def init_linear(key, d_in: int, d_out: int, *, bias: bool = False,
                dtype=jnp.float32, scale: float | None = None) -> dict:
    scale = (d_in ** -0.5) if scale is None else scale
    p = {"w": jax.random.normal(key, (d_in, d_out), dtype) * scale}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(x: jnp.ndarray, p: dict, compute_dtype=None) -> jnp.ndarray:
    w = p["w"]
    if compute_dtype is not None:
        x = x.astype(compute_dtype)
        w = w.astype(compute_dtype)
    y = x @ w
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


def init_mlp(key, d: int, d_ff: int, *, gated: bool, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 3)
    p = {
        "w_in": init_linear(ks[0], d, d_ff, dtype=dtype),
        "w_out": init_linear(ks[1], d_ff, d, dtype=dtype),
    }
    if gated:
        p["w_gate"] = init_linear(ks[2], d, d_ff, dtype=dtype)
    return p


def mlp(x: jnp.ndarray, p: dict, *, act: str, compute_dtype=None) -> jnp.ndarray:
    """(Gated) MLP with TP sharding constraints on the hidden activation."""
    h = linear(x, p["w_in"], compute_dtype)
    h_spec = P(RULES.dp, None, RULES.div(h.shape[-1], RULES.tp))
    if "w_gate" in p:
        g = activation(linear(x, p["w_gate"], compute_dtype), act)
        h = constrain(h * g, h_spec)
    else:
        h = constrain(activation(h, act), h_spec)
    return linear(h, p["w_out"], compute_dtype)


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------
def rope(x: jnp.ndarray, positions: jnp.ndarray, *, theta: float) -> jnp.ndarray:
    """Apply RoPE.  x: (B, S, H, hd); positions: (B, S) int32."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)
