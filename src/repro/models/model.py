"""Unified LM: every assigned architecture as one scan-over-layers model.

Block kinds (static per arch): ``dense`` (attn+MLP), ``moe`` (attn+MoE
[+ parallel dense FFN for arctic]), ``rwkv`` (RWKV6 time/channel mix),
``hymba`` (attention ∥ Mamba heads + MLP).  Whisper wraps a non-causal
encoder stack plus a decoder stack with cross-attention.  LLaVA prepends
stub patch embeddings to the token embeddings.

Entry points:
  * ``init_params(key, cfg)``               — stacked per-layer params
  * ``forward(params, cfg, tokens, extra)`` — full-sequence logits (train)
  * ``init_cache(cfg, batch, max_len)``     — decode cache pytree
  * ``prefill(params, cfg, tokens, ...)``   — fill cache, last-pos logits
  * ``decode_step(params, cfg, tok, cache, index)`` — one-token decode
  * ``loss_fn(params, cfg, batch)``         — next-token cross entropy

Layer scan: parameters are stacked on a leading L axis and the per-layer
body is ``jax.checkpoint``-ed (remat) — constant compile size in depth and
the standard activation-memory/compute trade at scale.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import RULES, constrain
from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as M
from repro.models import rwkv6 as R
from repro.models import ssm as S

__all__ = ["init_params", "forward", "loss_fn", "init_cache", "prefill",
           "decode_step", "param_specs"]


def _norm(x, p, cfg):
    if cfg.norm == "layernorm":
        return L.layer_norm(x, p, eps=cfg.norm_eps)
    return L.rms_norm(x, p, eps=cfg.norm_eps, plus_one=cfg.scale_embed)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------
def _init_layer(key, cfg, *, cross: bool = False) -> dict:
    ks = jax.random.split(key, 8)
    dt = jnp.dtype(cfg.param_dtype)
    bias = cfg.norm == "layernorm"
    p: dict[str, Any] = {"norm1": L.init_norm(cfg.d_model, bias=bias, dtype=dt),
                         "norm2": L.init_norm(cfg.d_model, bias=bias, dtype=dt)}
    if cfg.block == "rwkv":
        p["rwkv"] = R.init_rwkv6(ks[0], cfg)
        return p
    p["attn"] = A.init_attention(ks[0], cfg)
    if cfg.sandwich_norm:
        p["norm1b"] = L.init_norm(cfg.d_model, bias=bias, dtype=dt)
        p["norm2b"] = L.init_norm(cfg.d_model, bias=bias, dtype=dt)
    if cross:
        p["norm_x"] = L.init_norm(cfg.d_model, bias=bias, dtype=dt)
        p["xattn"] = A.init_attention(ks[1], cfg)
    if cfg.block == "moe":
        p["moe"] = M.init_moe(ks[2], cfg)
        if cfg.dense_residual:
            p["mlp"] = L.init_mlp(ks[3], cfg.d_model, cfg.d_ff,
                                  gated=cfg.gated, dtype=dt)
    else:
        p["mlp"] = L.init_mlp(ks[3], cfg.d_model, cfg.d_ff, gated=cfg.gated,
                              dtype=dt)
    if cfg.block == "hymba":
        p["mamba"] = S.init_mamba(ks[4], cfg)
        p["norm_attn_out"] = L.init_norm(cfg.d_model, dtype=dt)
        p["norm_ssm_out"] = L.init_norm(cfg.d_model, dtype=dt)
    return p


def init_params(key, cfg) -> dict:
    ks = jax.random.split(key, 8)
    dt = jnp.dtype(cfg.param_dtype)
    d, V = cfg.d_model, cfg.vocab
    params: dict[str, Any] = {
        "embed": jax.random.normal(ks[0], (V, d), dt) * 0.02,
        "final_norm": L.init_norm(d, bias=cfg.norm == "layernorm", dtype=dt),
    }
    layer_keys = jax.random.split(ks[1], cfg.n_layers)
    cross = cfg.enc_layers > 0
    params["layers"] = jax.vmap(
        lambda k: _init_layer(k, cfg, cross=cross))(layer_keys)
    if not cfg.tie_embeddings:
        params["lm_head"] = jax.random.normal(ks[2], (V, d), dt) * 0.02
    if cfg.pos_emb == "learned":
        params["pos_embed"] = jax.random.normal(ks[3], (32768, d), dt) * 0.02
    if cfg.enc_layers:
        enc_keys = jax.random.split(ks[4], cfg.enc_layers)
        params["enc_layers"] = jax.vmap(
            lambda k: _init_layer(k, cfg, cross=False))(enc_keys)
        params["enc_pos"] = jax.random.normal(
            ks[5], (max(cfg.audio_ctx, 1), d), dt) * 0.02
        params["enc_final_norm"] = L.init_norm(
            d, bias=cfg.norm == "layernorm", dtype=dt)
    return params


# ---------------------------------------------------------------------------
# Layer bodies
# ---------------------------------------------------------------------------
def _mix_block(x, lp, cfg, *, positions, window, causal):
    """One full-sequence layer. Returns the new residual stream."""
    if cfg.block == "rwkv":
        return R.rwkv6_block(x, lp["rwkv"], cfg, lp["norm1"], lp["norm2"])

    h = _norm(x, lp["norm1"], cfg)
    attn_out, _ = A.attention(h, lp["attn"], cfg, positions=positions,
                              window=window, causal=causal,
                              impl=cfg.attn_impl)
    # pin the (possibly sequence-sharded) attention output to a single
    # bf16 materialization before the norm — otherwise XLA all-gathers the
    # f32 norm intermediates, twice the bytes (EXPERIMENTS.md §Perf)
    attn_out = constrain(attn_out, RULES.act_btd())
    if cfg.block == "hymba":
        ssm_out = S.mamba(h, lp["mamba"], cfg)
        attn_out = 0.5 * (L.rms_norm(attn_out, lp["norm_attn_out"],
                                     eps=cfg.norm_eps)
                          + L.rms_norm(ssm_out, lp["norm_ssm_out"],
                                       eps=cfg.norm_eps))
    if cfg.sandwich_norm:
        attn_out = _norm(attn_out, lp["norm1b"], cfg)
    x = x + attn_out

    h = _norm(x, lp["norm2"], cfg)
    if cfg.block == "moe":
        ff = M.moe_ffn(h, lp["moe"], cfg)
        if cfg.dense_residual:
            ff = ff + L.mlp(h, lp["mlp"], act=cfg.act,
                            compute_dtype=jnp.dtype(cfg.compute_dtype))
    else:
        ff = L.mlp(h, lp["mlp"], act=cfg.act,
                   compute_dtype=jnp.dtype(cfg.compute_dtype))
    if cfg.sandwich_norm:
        ff = _norm(ff, lp["norm2b"], cfg)
    return x + ff


def _group(tree, p: int):
    """(L, ...) stacked tree -> (L/p, p, ...): window-pattern groups."""
    return jax.tree.map(
        lambda a: a.reshape((a.shape[0] // p, p) + a.shape[1:]), tree)


def _ungroup(tree):
    return jax.tree.map(
        lambda a: a.reshape((a.shape[0] * a.shape[1],) + a.shape[2:]), tree)


def _sub(tree, j: int):
    return jax.tree.map(lambda a: a[j], tree)


def _stack_subs(trees: list):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def _run_stack(params_stack, x, cfg, *, positions, causal):
    """lax.scan over window-pattern groups of stacked layers (remat-ed).

    Grouping keeps every attention window *static* so the banded
    block-skipping schedule applies (see ArchConfig.window_pattern)."""
    pattern = cfg.window_pattern()
    p = len(pattern)

    def body(x, lp_group):
        for j, w in enumerate(pattern):
            x = _mix_block(x, _sub(lp_group, j), cfg, positions=positions,
                           window=w, causal=causal)
        x = constrain(x, RULES.act_btd())
        return x, None

    if cfg.remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, _group(params_stack, p))
    return x


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------
def _embed(params, cfg, tokens, extra):
    emb = params["embed"]
    x = jnp.take(emb, tokens, axis=0).astype(jnp.dtype(cfg.compute_dtype))
    if cfg.scale_embed:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
    if cfg.img_tokens and extra is not None and "img_embeds" in extra:
        img = extra["img_embeds"].astype(x.dtype)
        x = jnp.concatenate([img, x], axis=1)
    if cfg.pos_emb == "learned":
        S_ = x.shape[1]
        x = x + params["pos_embed"][:S_].astype(x.dtype)
    return constrain(x, RULES.act_btd())


def _logits(params, cfg, x):
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("btd,vd->btv", x,
                        head.astype(x.dtype))
    logits = L.softcap(logits.astype(jnp.float32), cfg.logit_softcap)
    return constrain(logits, P(RULES.dp, None,
                               RULES.div(cfg.vocab, RULES.tp)))


def _encode(params, cfg, extra):
    """Whisper encoder on stub frame embeddings (B, audio_ctx, d)."""
    x = extra["audio_embeds"].astype(jnp.dtype(cfg.compute_dtype))
    x = x + params["enc_pos"][:x.shape[1]].astype(x.dtype)
    pos = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])
    x = _run_stack(params["enc_layers"], x, cfg, positions=pos, causal=False)
    return _norm(x, params["enc_final_norm"], cfg)


# ---------------------------------------------------------------------------
# Public: training / full-sequence forward
# ---------------------------------------------------------------------------
def forward(params, cfg, tokens, extra=None):
    """Full-sequence logits.  tokens: (B, S_text); returns (B, S_total, V)."""
    x = _embed(params, cfg, tokens, extra)
    B, S_, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S_), (B, S_))
    if cfg.enc_layers:
        e = _encode(params, cfg, extra)
        ek, ev = _cross_kv_all_layers(params, cfg, e)
        return _forward_with_cross(params, cfg, x, positions, ek, ev)
    x = _run_stack(params["layers"], x, cfg, positions=positions,
                   causal=True)
    x = _norm(x, params["final_norm"], cfg)
    return _logits(params, cfg, x)


def _cross_kv_all_layers(params, cfg, enc_out):
    """Precompute per-layer cross-attention K/V from the encoder output."""
    Hkv, hd = cfg.n_kv_heads, cfg.hd
    B, Se, _ = enc_out.shape
    cdt = jnp.dtype(cfg.compute_dtype)

    def one(lp):
        k = L.linear(enc_out, lp["xattn"]["wk"], cdt).reshape(B, Se, Hkv, hd)
        v = L.linear(enc_out, lp["xattn"]["wv"], cdt).reshape(B, Se, Hkv, hd)
        return k.swapaxes(1, 2), v.swapaxes(1, 2)

    return jax.lax.map(one, params["layers"])       # (L, B, Hkv, Se, hd) x2


def _forward_with_cross(params, cfg, x, positions, ek, ev):
    # enc-dec stacks (whisper) are un-windowed: pattern is (None,)
    def body(x, xs):
        lp, k_l, v_l = xs
        h = _norm(x, lp["norm1"], cfg)
        ao, _ = A.attention(h, lp["attn"], cfg, positions=positions,
                            window=None, causal=True, impl=cfg.attn_impl)
        x = x + constrain(ao, RULES.act_btd())
        h = _norm(x, lp["norm_x"], cfg)
        xo, _ = A.attention(h, lp["xattn"], cfg, positions=positions,
                            causal=False, impl=cfg.attn_impl,
                            kv_override=(k_l, v_l))
        x = x + constrain(xo, RULES.act_btd())
        h = _norm(x, lp["norm2"], cfg)
        x = x + L.mlp(h, lp["mlp"], act=cfg.act,
                      compute_dtype=jnp.dtype(cfg.compute_dtype))
        return constrain(x, RULES.act_btd()), None

    if cfg.remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, (params["layers"], ek, ev))
    x = _norm(x, params["final_norm"], cfg)
    return _logits(params, cfg, x)


def loss_fn(params, cfg, batch, extra=None):
    """Next-token cross entropy (+ z-loss) over (B, S) int32 ``tokens``.

    Vocab-parallel formulation: the picked-logit term is a masked local sum
    over the TP-sharded vocab dim (+ scalar all-reduce) rather than a
    ``take_along_axis`` gather, which GSPMD would implement by all-gathering
    the full (B, S, V) logits to every device (~17 GB/device at train_4k).
    """
    tokens = batch["tokens"]
    logits = forward(params, cfg, tokens[:, :-1], extra)
    # With prepended modality embeddings the text logits sit at the tail.
    logits = logits[:, -(tokens.shape[1] - 1):]
    targets = tokens[:, 1:]
    vpos = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
    vpos = constrain(vpos, P(RULES.dp, None, RULES.div(cfg.vocab, RULES.tp)))
    picked = jnp.sum(jnp.where(vpos == targets[..., None], logits, 0.0),
                     axis=-1)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    nll = logz - picked
    loss = nll.mean() + 1e-4 * (logz ** 2).mean()
    return loss.astype(jnp.float32)


# ---------------------------------------------------------------------------
# Public: serving (prefill + decode)
# ---------------------------------------------------------------------------
def init_cache(cfg, batch: int, max_len: int, *, context_parallel=False):
    """Stacked-over-layers cache pytree (zeros)."""
    def one_layer(_):
        c = {}
        if cfg.block == "rwkv":
            return R.init_rwkv6_cache(cfg, batch)
        c.update(A.init_kv_cache(cfg, batch, max_len,
                                 context_parallel=context_parallel))
        if cfg.block == "hymba":
            c.update(S.init_mamba_cache(cfg, batch))
        if cfg.enc_layers:
            Hkv, hd = cfg.n_kv_heads, cfg.hd
            cdt = jnp.dtype(cfg.compute_dtype)
            c["xk"] = jnp.zeros((batch, Hkv, max(cfg.audio_ctx, 1), hd), cdt)
            c["xv"] = jnp.zeros((batch, Hkv, max(cfg.audio_ctx, 1), hd), cdt)
        return c

    sample = one_layer(0)
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape).copy(),
        sample)


def _decode_layer(x, lp, cfg, cache_l, index, window, context_parallel):
    new_cache = dict(cache_l)
    if cfg.block == "rwkv":
        x, nc = R.rwkv6_decode(x, lp["rwkv"], cfg, cache_l, lp["norm1"],
                               lp["norm2"])
        return x, nc

    h = _norm(x, lp["norm1"], cfg)
    ao, kv = A.decode_attention(h, lp["attn"], cfg,
                                {"k": cache_l["k"], "v": cache_l["v"]},
                                index, window=window,
                                context_parallel=context_parallel)
    new_cache["k"], new_cache["v"] = kv["k"], kv["v"]
    if cfg.block == "hymba":
        so, sc = S.mamba_decode(h, lp["mamba"], cfg,
                                {"conv": cache_l["conv"], "h": cache_l["h"]})
        ao = 0.5 * (L.rms_norm(ao, lp["norm_attn_out"], eps=cfg.norm_eps)
                    + L.rms_norm(so, lp["norm_ssm_out"], eps=cfg.norm_eps))
        new_cache["conv"], new_cache["h"] = sc["conv"], sc["h"]
    if cfg.sandwich_norm:
        ao = _norm(ao, lp["norm1b"], cfg)
    x = x + ao

    if cfg.enc_layers:
        h = _norm(x, lp["norm_x"], cfg)
        B = x.shape[0]
        H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
        q = L.linear(h, lp["xattn"]["wq"],
                     jnp.dtype(cfg.compute_dtype)).reshape(B, 1, H, hd)
        qg = q.reshape(B, 1, Hkv, H // Hkv, hd).astype(jnp.float32)
        s = jnp.einsum("bqhgd,bhkd->bhgqk", qg,
                       cache_l["xk"].astype(jnp.float32)) * (hd ** -0.5)
        pe = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhgqk,bhkd->bhgqd", pe,
                       cache_l["xv"].astype(jnp.float32))
        o = o.reshape(B, H, 1, hd).swapaxes(1, 2).reshape(B, 1, H * hd)
        x = x + L.linear(o.astype(x.dtype), lp["xattn"]["wo"],
                         jnp.dtype(cfg.compute_dtype))

    h = _norm(x, lp["norm2"], cfg)
    if cfg.block == "moe":
        ff = M.moe_ffn(h, lp["moe"], cfg)
        if cfg.dense_residual:
            ff = ff + L.mlp(h, lp["mlp"], act=cfg.act,
                            compute_dtype=jnp.dtype(cfg.compute_dtype))
    else:
        ff = L.mlp(h, lp["mlp"], act=cfg.act,
                   compute_dtype=jnp.dtype(cfg.compute_dtype))
    if cfg.sandwich_norm:
        ff = _norm(ff, lp["norm2b"], cfg)
    return x + ff, new_cache


def decode_step(params, cfg, tokens, cache, index, *,
                context_parallel: bool = False):
    """One decode step.  tokens: (B, 1) int32; ``index``: current position.

    Returns (logits (B, 1, V), new_cache).
    """
    x = jnp.take(params["embed"], tokens, axis=0).astype(
        jnp.dtype(cfg.compute_dtype))
    if cfg.scale_embed:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
    if cfg.pos_emb == "learned":
        x = x + jax.lax.dynamic_slice_in_dim(
            params["pos_embed"], index, 1, axis=0).astype(x.dtype)[None]

    pattern = cfg.window_pattern()
    p = len(pattern)

    def body(x, xs):
        lp_g, cache_g = xs
        ncs = []
        for j, w in enumerate(pattern):
            x, nc = _decode_layer(x, _sub(lp_g, j), cfg, _sub(cache_g, j),
                                  index, w, context_parallel)
            ncs.append(nc)
        return x, _stack_subs(ncs)

    x, new_cache = jax.lax.scan(
        body, x, (_group(params["layers"], p), _group(cache, p)))
    x = _norm(x, params["final_norm"], cfg)
    return _logits(params, cfg, x), _ungroup(new_cache)


def prefill(params, cfg, tokens, extra=None, *, max_len: int,
            context_parallel: bool = False):
    """Run the full prompt, build the cache, return last-position logits.

    Implemented as full-sequence forward capturing per-layer K/V (attention
    archs).  For rwkv/hymba the recurrent states are produced by scanning.
    """
    B = tokens.shape[0]
    cache = init_cache(cfg, B, max_len, context_parallel=context_parallel)
    x = _embed(params, cfg, tokens, extra)
    S_ = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S_), (B, S_))
    pattern = cfg.window_pattern()
    p = len(pattern)

    if cfg.block == "rwkv":
        def body(x, xs):
            lp = xs
            h = L.rms_norm(x, lp["norm1"], eps=cfg.norm_eps)
            hp = R._shift(h)
            out, s_new = R._time_mix(h, hp, lp["rwkv"], cfg, s0=None,
                                     return_state=True)
            x = x + out
            h2 = L.rms_norm(x, lp["norm2"], eps=cfg.norm_eps)
            x = x + R._channel_mix(h2, R._shift(h2), lp["rwkv"])
            nc = {"tm_x": h[:, -1:], "cm_x": h2[:, -1:], "state": s_new}
            return x, nc

        x, cache = jax.lax.scan(body, x, params["layers"])
        x = _norm(x, params["final_norm"], cfg)
        return _logits(params, cfg, x[:, -1:]), cache

    if cfg.enc_layers:
        e = _encode(params, cfg, extra)
        ek, ev = _cross_kv_all_layers(params, cfg, e)

    def layer(x, lp, cache_l, window, cross):
        k_l, v_l = cross if cross is not None else (None, None)
        h = _norm(x, lp["norm1"], cfg)
        ao, (k, v) = A.attention(h, lp["attn"], cfg, positions=positions,
                                 window=window, causal=True,
                                 impl=cfg.attn_impl)
        ao = constrain(ao, RULES.act_btd())
        nc = dict(cache_l)
        spec = (RULES.kv_cache_cp(cfg.n_kv_heads) if context_parallel
                else RULES.kv_cache(cfg.n_kv_heads))
        nc["k"] = constrain(jax.lax.dynamic_update_slice_in_dim(
            cache_l["k"], k.astype(cache_l["k"].dtype), 0, axis=2), spec)
        nc["v"] = constrain(jax.lax.dynamic_update_slice_in_dim(
            cache_l["v"], v.astype(cache_l["v"].dtype), 0, axis=2), spec)
        if cfg.block == "hymba":
            so, tail, hT = S._mamba_core(h, lp["mamba"], cfg)
            so = so.astype(h.dtype)
            ao = 0.5 * (L.rms_norm(ao, lp["norm_attn_out"], eps=cfg.norm_eps)
                        + L.rms_norm(so, lp["norm_ssm_out"], eps=cfg.norm_eps))
            nc["conv"], nc["h"] = tail.astype(nc["conv"].dtype), hT
        if cfg.sandwich_norm:
            ao = _norm(ao, lp["norm1b"], cfg)
        x = x + ao
        if cfg.enc_layers:
            h = _norm(x, lp["norm_x"], cfg)
            xo, _ = A.attention(h, lp["xattn"], cfg, positions=positions,
                                causal=False, impl=cfg.attn_impl,
                                kv_override=(k_l, v_l))
            x = x + constrain(xo, RULES.act_btd())
            nc["xk"], nc["xv"] = (k_l.astype(nc["xk"].dtype),
                                  v_l.astype(nc["xv"].dtype))
        h = _norm(x, lp["norm2"], cfg)
        if cfg.block == "moe":
            ff = M.moe_ffn(h, lp["moe"], cfg)
            if cfg.dense_residual:
                ff = ff + L.mlp(h, lp["mlp"], act=cfg.act,
                                compute_dtype=jnp.dtype(cfg.compute_dtype))
        else:
            ff = L.mlp(h, lp["mlp"], act=cfg.act,
                       compute_dtype=jnp.dtype(cfg.compute_dtype))
        if cfg.sandwich_norm:
            ff = _norm(ff, lp["norm2b"], cfg)
        x = constrain(x + ff, RULES.act_btd())
        return x, nc

    def body(x, xs):
        if cfg.enc_layers:
            lp_g, cache_g, ek_g, ev_g = xs
        else:
            lp_g, cache_g = xs
        ncs = []
        for j, w in enumerate(pattern):
            cross = ((_sub(ek_g, j), _sub(ev_g, j)) if cfg.enc_layers
                     else None)
            x, nc = layer(x, _sub(lp_g, j), _sub(cache_g, j), w, cross)
            ncs.append(nc)
        return x, _stack_subs(ncs)

    if cfg.enc_layers:
        xs = (_group(params["layers"], p), _group(cache, p),
              _group(ek, p), _group(ev, p))
    else:
        xs = (_group(params["layers"], p), _group(cache, p))
    x, cache = jax.lax.scan(body, x, xs)
    x = _norm(x, params["final_norm"], cfg)
    return _logits(params, cfg, x[:, -1:]), _ungroup(cache)


# ---------------------------------------------------------------------------
# Parameter sharding specs (jit in_shardings for the dry-run / launchers)
# ---------------------------------------------------------------------------
def param_specs(cfg, params_tree, mesh, *, serve: bool = False) -> Any:
    """PartitionSpec tree for ``params_tree`` on ``mesh``.

    Train mode: FSDP over 'data' (+ 'pod' when ``RULES.fsdp_pod``), TP over
    'model'; dims shard only when divisible.  Stacked layer params get a
    leading ``None`` for the layer axis.

    ``serve=True`` drops FSDP (params replicated over the batch axes, TP
    only): inference reads every weight once per step, so FSDP's per-layer
    all-gathers are pure collective overhead there (EXPERIMENTS.md §Perf,
    qwen2.5 decode_32k).  Callers gate this on the per-device footprint —
    the >100B archs keep FSDP even when serving.
    """
    def div(dim, axes):
        if axes is None:
            return None
        ax = (axes,) if isinstance(axes, str) else tuple(axes)
        sz = 1
        for a in ax:
            sz *= mesh.shape[a] if a in mesh.axis_names else 1
        return (axes if dim % sz == 0 else None) if sz > 1 else None

    fsdp = None if serve else RULES.fsdp_axes
    tp = RULES.tp

    def spec_for(path, leaf):
        keys = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
        shape = leaf.shape
        stacked = "layers" in keys or "enc_layers" in keys
        core = shape[1:] if stacked else shape
        name = keys[-1]
        parent = keys[-2] if len(keys) > 1 else ""

        def out(*entries):
            entries = tuple(entries[:len(core)])
            entries = entries + (None,) * (len(core) - len(entries))
            return P(*(((None,) if stacked else ()) + entries))

        if name in ("embed", "lm_head"):
            return P(div(shape[0], tp), div(shape[1], fsdp))
        if name in ("pos_embed", "enc_pos"):
            return P(None, div(shape[1], fsdp))
        if len(core) == 0:
            return P(*((None,) if stacked else ()))
        # MoE expert tensors: (E, d_in, d_out)
        if parent == "moe" and len(core) == 3:
            if name == "w_out":
                return out(div(core[0], tp), None, div(core[2], fsdp))
            return out(div(core[0], tp), div(core[1], fsdp), None)
        if parent == "moe" and name == "router":
            return out(div(core[0], fsdp), None)
        # Linear weights by role
        if name == "w" or (len(core) == 2 and name in (
                "in_proj", "x_proj", "dt_proj", "out_proj", "mix_A", "w_A",
                "w_B", "mix_B", "A_log", "conv_w", "router")):
            d_in, d_out = core[-2], core[-1]
            out_side = parent in ("wo", "w_out", "cm_wv") or name == "out_proj"
            if out_side:
                return out(div(d_in, tp), div(d_out, fsdp))
            return out(div(d_in, fsdp), div(d_out, tp))
        if len(core) == 1:
            return out(None)
        return out(*([None] * len(core)))

    import jax.tree_util as jtu

    return jtu.tree_map_with_path(spec_for, params_tree)
