"""Mixture-of-Experts FFN with expert parallelism over the TP mesh axis.

Design (DESIGN.md §3): tokens are data-parallel (replicated across the
``model`` axis), experts are sharded over ``model``.  Dispatch therefore
needs *no token communication at all* — each device routes its local tokens
to its local expert slice and the partial outputs are combined with one
``psum`` over ``model`` (the same collective a dense TP MLP pays).  This is
implemented with ``shard_map`` so the sort-based dispatch stays shard-local
(a global top-k/sort under GSPMD would all-gather the token stream).

Dispatch is the static-shape, capacity-based sort scheme:
  top-k -> mask to local experts -> stable sort by expert id -> position
  within expert group -> scatter into an (E_local, C, d) buffer -> batched
  expert GEMMs -> gather back with gate weights.
Tokens beyond an expert's capacity ``C = ceil(T_local * top_k / E * cf)``
are dropped (standard GShard/Switch behaviour; ``capacity_factor`` tunes it).

The expert GEMMs fold (expert, capacity) into the M dimension of one
``(E_loc, C, d) x (E_loc, d, f)`` batched matmul — the paper's "many small
problems -> one skinny GEMM" layout move (DESIGN.md §4).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.compat import shard_map
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import RULES, current_mesh
from repro.models import layers as L

__all__ = ["init_moe", "moe_ffn"]


def _capacity(tokens: int, top_k: int, n_experts: int, cf: float) -> int:
    """Per-expert capacity.  The ``min(tokens, 16)`` floor makes tiny-token
    calls (single-token decode, smoke tests) drop-free — a token can occupy
    at most one slot per expert, so capacity >= tokens suffices there."""
    cap = max(1, -(-tokens * top_k // n_experts) if cf == 1.0
              else int(tokens * top_k / n_experts * cf) + 1)
    return max(cap, min(tokens, 16))


def init_moe(key, cfg) -> dict:
    ks = jax.random.split(key, 5)
    d, f, E = cfg.d_model, cfg.d_ff_expert, cfg.n_experts
    dt = jnp.dtype(cfg.param_dtype)
    s_in, s_out = d ** -0.5, f ** -0.5
    p = {
        "router": jax.random.normal(ks[0], (d, E), dt) * s_in,
        "w_in": jax.random.normal(ks[1], (E, d, f), dt) * s_in,
        "w_out": jax.random.normal(ks[2], (E, f, d), dt) * s_out,
    }
    if cfg.gated:
        p["w_gate"] = jax.random.normal(ks[3], (E, d, f), dt) * s_in
    return p


def _dispatch_compute(x, router_w, w_in, w_gate, w_out, *, top_k: int,
                      n_experts_global: int, expert_lo, capacity: int,
                      act: str, compute_dtype) -> jnp.ndarray:
    """Route ``x (T, d)`` through the local expert slice. Pure, shard-local."""
    T, d = x.shape
    E_loc, _, f = w_in.shape
    cdt = compute_dtype

    logits = (x.astype(jnp.float32) @ router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)               # (T, E_global)
    gate, eid = jax.lax.top_k(probs, top_k)               # (T, k)
    gate = gate / jnp.clip(gate.sum(-1, keepdims=True), 1e-9)

    # Flatten (T, k) assignments; mask to this shard's expert range.
    eid = eid.reshape(-1)
    gate = gate.reshape(-1)
    tok = jnp.repeat(jnp.arange(T), top_k)
    local_e = eid - expert_lo
    mine = (local_e >= 0) & (local_e < E_loc)
    key = jnp.where(mine, local_e, E_loc)                 # foreign -> sentinel
    order = jnp.argsort(key, stable=True)
    se, stok, sgate = key[order], tok[order], gate[order]

    counts = jnp.bincount(key, length=E_loc + 1)
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                              jnp.cumsum(counts)[:-1]])
    pos = jnp.arange(se.shape[0]) - starts[se]
    keep = (se < E_loc) & (pos < capacity)
    slot = jnp.where(keep, se * capacity + pos, E_loc * capacity)

    buf = jnp.zeros((E_loc * capacity, d), cdt)
    buf = buf.at[slot].set(x[stok].astype(cdt), mode="drop")
    buf = buf.reshape(E_loc, capacity, d)

    h = jnp.einsum("ecd,edf->ecf", buf, w_in.astype(cdt))
    if w_gate is not None:
        g = L.activation(jnp.einsum("ecd,edf->ecf", buf, w_gate.astype(cdt)),
                         act)
        h = h * g
    else:
        h = L.activation(h, act)
    y = jnp.einsum("ecf,efd->ecd", h, w_out.astype(cdt))
    y = y.reshape(E_loc * capacity, d)

    yt = jnp.take(y, slot, axis=0, fill_value=0.0)        # (T*k, d)
    yt = yt * (sgate * keep).astype(cdt)[:, None]
    out = jnp.zeros((T, d), cdt).at[stok].add(yt)
    return out


def moe_ffn(x: jnp.ndarray, p: dict, cfg) -> jnp.ndarray:
    """MoE FFN on (B, S, d) activations, expert-parallel over the TP axis."""
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    cdt = jnp.dtype(cfg.compute_dtype)
    mesh = current_mesh()
    tp = RULES.tp if (mesh is not None and RULES.tp in mesh.axis_names
                      and E % mesh.shape[RULES.tp] == 0
                      and mesh.shape[RULES.tp] > 1) else None

    if tp is None:
        cap = _capacity(B * S, k, E, cfg.capacity_factor)
        out = _dispatch_compute(
            x.reshape(B * S, d), p["router"], p["w_in"], p.get("w_gate"),
            p["w_out"], top_k=k, n_experts_global=E, expert_lo=0,
            capacity=cap, act=cfg.act, compute_dtype=cdt)
        return out.reshape(B, S, d).astype(x.dtype)

    tp_size = mesh.shape[tp]
    E_loc = E // tp_size
    dp = tuple(a for a in RULES.dp if a in mesh.axis_names)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    T_loc = (B // dp_size if B % dp_size == 0 else B) * S
    cap = _capacity(T_loc, k, E, cfg.capacity_factor)

    has_gate = "w_gate" in p
    gate_w = p.get("w_gate")

    def shard_fn(x_l, router_w, w_in, w_gate, w_out):
        tp_idx = jax.lax.axis_index(tp)
        Bl, Sl, _ = x_l.shape
        out = _dispatch_compute(
            x_l.reshape(Bl * Sl, d), router_w, w_in,
            w_gate if has_gate else None, w_out, top_k=k,
            n_experts_global=E, expert_lo=tp_idx * E_loc, capacity=cap,
            act=cfg.act, compute_dtype=cdt)
        out = jax.lax.psum(out, tp)
        return out.reshape(Bl, Sl, d)

    in_specs = (P(dp, None, None), P(), P(tp, None, None),
                P(tp, None, None) if has_gate else P(),
                P(tp, None, None))
    args = (x, p["router"], p["w_in"],
            gate_w if has_gate else jnp.zeros((), cdt), p["w_out"])
    out = shard_map(shard_fn, mesh=mesh, in_specs=in_specs,
                        out_specs=P(dp, None, None), check_vma=False)(*args)
    return out.astype(x.dtype)
