"""RWKV6 "Finch" block: data-dependent-decay linear attention (attn-free).

Faithful structure (arXiv:2404.05892): token-shift ddlerp with low-rank
adapters, data-dependent per-channel decay ``w = exp(-exp(w~))``, bonus
``u``, per-head WKV state recurrence, grouped RMS norm, gated output, and
the squared-ReLU channel-mix.  The WKV recurrence runs through:

  * ``kernels/wkv6.py`` (Pallas; inference/prefill on TPU) — the paper's
    state-streaming optimization (DESIGN.md §4), or
  * ``kernels/ref.wkv6_ref`` (lax.scan; differentiable training path).

Decode carries a tiny recurrent cache: the last token embedding for the two
token-shifts plus the (B, H, hd, hd) WKV state — O(1) in sequence length,
which is what makes the ``long_500k`` cell trivial for this arch.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L

__all__ = ["init_rwkv6", "rwkv6_block", "rwkv6_decode", "init_rwkv6_cache"]

_LORA_MIX = 32
_LORA_DECAY = 64
_WMIN, _WMAX = -8.0, 1.0   # clamp on w~ (kernel stability; exp(-exp(1))~0.066)


def init_rwkv6(key, cfg) -> dict:
    d, H, hd, dff = cfg.d_model, cfg.n_heads, cfg.head_dim, cfg.d_ff
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 12)
    s = d ** -0.5
    return {
        # time-mix
        "mu_x": jnp.zeros((d,), dt),
        "mu": jnp.zeros((5, d), dt),                       # r, k, v, w, g
        "mix_A": jax.random.normal(ks[0], (d, 5 * _LORA_MIX), dt) * s,
        "mix_B": jax.random.normal(ks[1], (5, _LORA_MIX, d), dt) * 0.01,
        "w0": jnp.full((d,), -2.0, dt),
        "w_A": jax.random.normal(ks[2], (d, _LORA_DECAY), dt) * s,
        "w_B": jax.random.normal(ks[3], (_LORA_DECAY, d), dt) * 0.01,
        "u": jax.random.normal(ks[4], (H, hd), dt) * 0.1,
        "wr": L.init_linear(ks[5], d, d, dtype=dt),
        "wk": L.init_linear(ks[6], d, d, dtype=dt),
        "wv": L.init_linear(ks[7], d, d, dtype=dt),
        "wg": L.init_linear(ks[8], d, d, dtype=dt),
        "wo": L.init_linear(ks[9], d, d, dtype=dt),
        "ln_x": L.init_norm(hd, dtype=dt),                 # per-head group norm
        # channel-mix
        "cm_mu_k": jnp.zeros((d,), dt),
        "cm_mu_r": jnp.zeros((d,), dt),
        "cm_wk": L.init_linear(ks[10], d, dff, dtype=dt),
        "cm_wv": L.init_linear(ks[11], dff, d, dtype=dt),
        "cm_wr": L.init_linear(jax.random.fold_in(key, 99), d, d, dtype=dt),
    }


def _ddlerp(x, x_prev, p):
    """Data-dependent lerp producing the 5 mixed streams (r, k, v, w, g).

    Dtype-disciplined: everything stays in the residual dtype (bf16 at
    scale) — the (B, T, 5, d) intermediates dominate RWKV activation memory
    (2.5 GiB each per device at train_4k in f32; see EXPERIMENTS.md §Perf).
    """
    dt = x.dtype
    diff = x_prev - x                                       # (B, T, d)
    xx = x + diff * p["mu_x"].astype(dt)
    mws = jnp.tanh(xx @ p["mix_A"].astype(dt))              # (B, T, 5*rank)
    out = []
    for i in range(5):                                      # r, k, v, w, g
        sel = mws[..., i * _LORA_MIX:(i + 1) * _LORA_MIX]
        adj = sel @ p["mix_B"][i].astype(dt)                # (B, T, d)
        out.append(x + diff * (p["mu"][i].astype(dt) + adj))
    return tuple(out)


def _wkv_apply(r, k, v, w, u, s0, cfg, *, return_state):
    """(B, H, T, hd) WKV — Pallas kernel for inference, chunked-parallel jnp
    for training (differentiable, O(T/chunk) backward residuals), sequential
    scan only for T == 1 (decode)."""
    if getattr(cfg, "use_kernels", False):
        from repro.kernels import ops

        return ops.wkv6(r, k, v, w, u, initial_state=s0,
                        return_state=return_state)
    from repro.kernels.ref import wkv6_chunked, wkv6_ref

    if r.shape[2] == 1:
        return wkv6_ref(r, k, v, w, u, initial_state=s0,
                        return_state=return_state)
    return wkv6_chunked(r, k, v, w, u, initial_state=s0,
                        return_state=return_state)


def _time_mix(x, x_prev, p, cfg, s0=None, *, return_state=False):
    B, T, d = x.shape
    H, hd = cfg.n_heads, cfg.head_dim
    xr, xk, xv, xw, xg = _ddlerp(x, x_prev, p)
    cdt = x.dtype
    r = L.linear(xr.astype(cdt), p["wr"], cdt).reshape(B, T, H, hd).transpose(0, 2, 1, 3)
    k = L.linear(xk.astype(cdt), p["wk"], cdt).reshape(B, T, H, hd).transpose(0, 2, 1, 3)
    v = L.linear(xv.astype(cdt), p["wv"], cdt).reshape(B, T, H, hd).transpose(0, 2, 1, 3)
    g = jax.nn.silu(L.linear(xg.astype(cdt), p["wg"], cdt))
    # decay stays f32: log/exp chains need the mantissa
    wt = p["w0"] + jnp.tanh(xw.astype(jnp.float32) @ p["w_A"]) @ p["w_B"]
    wt = jnp.clip(wt, _WMIN, _WMAX)
    w = jnp.exp(-jnp.exp(wt)).reshape(B, T, H, hd).transpose(0, 2, 1, 3)

    res = _wkv_apply(r, k, v, w, p["u"], s0, cfg, return_state=return_state)
    o, s_new = res if return_state else (res, None)
    o = o.transpose(0, 2, 1, 3)                             # (B, T, H, hd)
    o = L.rms_norm(o, p["ln_x"], eps=cfg.norm_eps).reshape(B, T, d)
    out = L.linear((o * g).astype(x.dtype), p["wo"]).astype(x.dtype)
    return (out, s_new) if return_state else out


def _channel_mix(x, x_prev, p):
    diff = x_prev - x
    xk = (x + diff * p["cm_mu_k"]).astype(x.dtype)
    xr = (x + diff * p["cm_mu_r"]).astype(x.dtype)
    kk = jax.nn.relu(L.linear(xk, p["cm_wk"], x.dtype))
    kk = kk * kk
    out = jax.nn.sigmoid(L.linear(xr, p["cm_wr"], x.dtype)) \
        * L.linear(kk, p["cm_wv"], x.dtype)
    return out.astype(x.dtype)


def _shift(x):
    """Previous-token stream: x_prev[t] = x[t-1], zeros at t=0."""
    return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]


def rwkv6_block(x, p, cfg, norm1, norm2):
    """Training / prefill (parallel over T).  x: (B, T, d)."""
    h = L.rms_norm(x, norm1, eps=cfg.norm_eps)
    x = x + _time_mix(h, _shift(h), p, cfg)
    h = L.rms_norm(x, norm2, eps=cfg.norm_eps)
    x = x + _channel_mix(h, _shift(h), p)
    return x


def init_rwkv6_cache(cfg, batch: int):
    H, hd, d = cfg.n_heads, cfg.head_dim, cfg.d_model
    return {
        "tm_x": jnp.zeros((batch, 1, d), jnp.dtype(cfg.compute_dtype)),
        "cm_x": jnp.zeros((batch, 1, d), jnp.dtype(cfg.compute_dtype)),
        "state": jnp.zeros((batch, H, hd, hd), jnp.float32),
    }


def rwkv6_decode(x, p, cfg, cache, norm1, norm2):
    """Single-token step with recurrent cache.  x: (B, 1, d)."""
    h = L.rms_norm(x, norm1, eps=cfg.norm_eps)
    out, s_new = _time_mix(h, cache["tm_x"], p, cfg, s0=cache["state"],
                           return_state=True)
    x = x + out
    h2 = L.rms_norm(x, norm2, eps=cfg.norm_eps)
    x = x + _channel_mix(h2, cache["cm_x"], p)
    new_cache = {"tm_x": h, "cm_x": h2, "state": s_new}
    return x, new_cache
