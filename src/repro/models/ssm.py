"""Selective SSM (Mamba-style) head for the Hymba hybrid architecture.

Hymba (arXiv:2411.13676) runs attention heads and Mamba heads *in parallel*
within each block on the same input, then averages the two normalized paths.
This module implements the Mamba path: input projection + gate, short causal
depthwise conv, selective SSM with data-dependent (dt, B, C) and
``ssm_state`` channels per inner dim, sequential ``lax.scan`` over time
(chunk-parallel is a known optimization; the state-resident streaming is the
paper-relevant part — DESIGN.md §4).

Decode carries (conv tail, SSM state): O(1) in sequence length.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


__all__ = ["init_mamba", "mamba", "mamba_decode", "init_mamba_cache"]

_CONV_K = 4


def init_mamba(key, cfg) -> dict:
    d = cfg.d_model
    di = 2 * d                              # inner dim
    n = cfg.ssm_state
    dt_rank = max(1, d // 16)
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    s = d ** -0.5
    return {
        "in_proj": jax.random.normal(ks[0], (d, 2 * di), dtype) * s,
        "conv_w": jax.random.normal(ks[1], (_CONV_K, di), dtype) * 0.2,
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": jax.random.normal(ks[2], (di, dt_rank + 2 * n), dtype) * s,
        "dt_proj": jax.random.normal(ks[3], (dt_rank, di), dtype) * dt_rank ** -0.5,
        "dt_bias": jnp.log(jnp.expm1(jnp.full((di,), 0.01, dtype))),
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, n + 1, dtype=jnp.float32), (di, n)).astype(dtype)),
        "D": jnp.ones((di,), dtype),
        "out_proj": jax.random.normal(ks[4], (di, d), dtype) * (di ** -0.5),
    }


def _causal_conv(x, w, b, tail=None):
    """Depthwise causal conv over time.  x: (B, T, di); w: (K, di).

    ``tail``: (B, K-1, di) previous samples for decode; zeros for prefill.
    Returns (y, new_tail).
    """
    B, T, di = x.shape
    K = w.shape[0]
    if tail is None:
        tail = jnp.zeros((B, K - 1, di), x.dtype)
    xp = jnp.concatenate([tail.astype(x.dtype), x], axis=1)  # (B, T+K-1, di)
    y = sum(xp[:, i:i + T] * w[i] for i in range(K)) + b
    return y, xp[:, -(K - 1):]


def _ssm_scan(x, dt, Bc, Cc, A, D, h0):
    """Selective scan.  x, dt: (B, T, di); Bc, Cc: (B, T, n); A: (di, n).

    h_t = exp(dt_t A) * h_{t-1} + dt_t * B_t * x_t;   y_t = h_t . C_t + D x_t
    Returns (y (B, T, di), h_T (B, di, n)).
    """
    def step(h, inp):
        xt, dtt, bt, ct = (t.astype(jnp.float32) for t in inp)  # (B,di)/(B,n)
        da = jnp.exp(dtt[..., None] * A[None])            # (B, di, n)
        h = da * h + (dtt * xt)[..., None] * bt[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, ct)
        return h, y

    xs = (x.swapaxes(0, 1), dt.swapaxes(0, 1),
          Bc.swapaxes(0, 1), Cc.swapaxes(0, 1))
    hT, ys = jax.lax.scan(step, h0, xs)
    y = ys.swapaxes(0, 1) + D * x
    return y, hT


def _mamba_core(x, p, cfg, conv_tail=None, h0=None):
    B, T, d = x.shape
    di = 2 * d
    n = cfg.ssm_state
    dt_rank = max(1, d // 16)
    dt_ = x.dtype                     # keep full-seq tensors in compute dtype
    xz = x @ p["in_proj"].astype(dt_)
    xi, z = jnp.split(xz, 2, axis=-1)                     # (B, T, di) each
    xi, new_tail = _causal_conv(xi, p["conv_w"].astype(dt_),
                                p["conv_b"].astype(dt_), conv_tail)
    xi = jax.nn.silu(xi)
    dbc = xi @ p["x_proj"].astype(dt_)
    dt = jax.nn.softplus(dbc[..., :dt_rank] @ p["dt_proj"].astype(dt_)
                         + p["dt_bias"].astype(dt_))
    Bc = dbc[..., dt_rank:dt_rank + n]
    Cc = dbc[..., dt_rank + n:]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    if h0 is None:
        h0 = jnp.zeros((B, di, n), jnp.float32)
    # scan state stays f32 (decay-chain stability); xs stream in compute
    # dtype and are upcast per step inside the scan body
    y, hT = _ssm_scan(xi, dt, Bc, Cc, A, p["D"].astype(jnp.float32), h0)
    y = y.astype(dt_) * jax.nn.silu(z)
    return y @ p["out_proj"].astype(dt_), new_tail, hT


def mamba(x, p, cfg):
    """Prefill / training path.  x: (B, T, d) -> (B, T, d)."""
    out, _, _ = _mamba_core(x, p, cfg)
    return out.astype(x.dtype)


def init_mamba_cache(cfg, batch: int):
    di = 2 * cfg.d_model
    return {
        "conv": jnp.zeros((batch, _CONV_K - 1, di), jnp.dtype(cfg.compute_dtype)),
        "h": jnp.zeros((batch, di, cfg.ssm_state), jnp.float32),
    }


def mamba_decode(x, p, cfg, cache):
    """Single-token step.  x: (B, 1, d)."""
    out, tail, hT = _mamba_core(x, p, cfg, conv_tail=cache["conv"],
                                h0=cache["h"])
    return out.astype(x.dtype), {"conv": tail.astype(cache["conv"].dtype),
                                 "h": hT}
