"""Solver observability: structured traces, metrics, cost-model drift.

Three layers (DESIGN.md §14), all zero-overhead when tracing is off:

* :mod:`repro.obs.trace` — span/event/counter/gauge API writing JSONL
  trace files with a versioned schema, behind a context-local
  :class:`~repro.obs.trace.Recorder` so jitted drivers stay trace-free.
* :mod:`repro.obs.metrics` — per-solve :class:`~repro.obs.metrics.
  SolveTelemetry` (attached to ``SolveResult`` when tracing is on) and
  the solver-service queue/dispatch metrics.
* :mod:`repro.obs.drift` — compares measured collective counts and
  bytes/iter of the compiled pipelines against the exact ``core/cost.py``
  books and fails loudly when the books no longer describe the program.

Importing ``repro.obs`` stays jax-free; the submodules import jax
lazily where they need it.
"""
from repro.obs import trace  # noqa: F401  (re-export the core surface)
from repro.obs.trace import (  # noqa: F401
    Recorder, active, count, event, gauge, provenance, recording, span,
)

__all__ = ["trace", "Recorder", "active", "count", "event", "gauge",
           "provenance", "recording", "span"]
