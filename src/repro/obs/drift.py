"""Cost-model drift detection — do the ``cost.py`` books still describe
the compiled programs? (DESIGN.md §14.3)

The repo's performance story is an *exact* stream/byte ledger
(:mod:`repro.core.cost`) pinned against measured benches.  Nothing so
far checked the ledger against the **programs**: a kernel that grows an
extra operand, a driver that re-materializes a window per iteration, or
a sharded cycle that picks up a second psum would silently invalidate
every pinned byte row.  This module closes that loop:

* **bytes/iter** — trace the public driver of each pipeline
  (``jax.make_jaxpr``; no execution), walk the jaxpr, and charge array
  traffic at the *stream boundaries*: ``pallas_call`` equations and
  leaf equations (no sub-jaxpr) get their operands/results billed;
  structural equations (pjit/while/scan) are descended into.  For the
  loop-driven v2 family the per-iteration cost is the body of the
  **max-traffic loop** (the CG iteration — inner coarse/smoother loops
  charge less); for s-step the two per-cycle launches come from
  :func:`repro.core.cg_sstep.sstep_cycle_traceables` and are divided
  by ``s``.  The measured bytes/DOF/iter are compared against
  ``cost.bytes_per_dof_iter(..., exact=True)`` as a **ratio** held in a
  per-pipeline calibrated band (:data:`STREAM_BYTE_BANDS`): the jaxpr
  boundary deliberately over-counts the book wherever a pipeline
  materializes halo windows at the XLA level (the book charges those as
  redundant *kernel reads*, not separate gather writes), so the fused
  v2 family sits at ratio ~1.03 while s-step's per-cycle p/r window
  extensions put it at ~2.2.  The band *is* the pin: a kernel or book
  change that moves real traffic lands outside it.

* **collectives** — the jaxpr collective-primitive walk
  (:func:`repro.distributed.sstep.count_collectives`) against the
  pinned contracts: the single-device v2 family is collective-free and
  the sharded s-step cycle is exactly ``{"ppermute": 2, "psum": 1}``
  with a collective-free update (DESIGN.md §10).

``check()`` returns a :class:`DriftReport` (JSON-able ``model_drift``
payload with provenance); ``assert_no_drift()`` raises
:class:`ModelDriftError` with the offending rows — the loud failure the
``obs-smoke`` CI leg runs on fused_v2, fused_v2_jacobi, and sstep_v3.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["DriftRow", "DriftReport", "ModelDriftError",
           "DEFAULT_PIPELINES", "STREAM_BYTE_BANDS",
           "EXPECTED_COLLECTIVES", "charge_streams",
           "measure_call_bytes", "measure_iteration_bytes",
           "check_bytes", "check_collectives", "check", "assert_no_drift"]


#: Pipelines the drift gate covers by default (the acceptance set).
DEFAULT_PIPELINES = ("fused_v2", "fused_v2_jacobi", "sstep_v3")

#: Calibrated (lo, hi) bands for measured/model *total* bytes/DOF/iter.
#: Calibration (CPU, jax 0.7/0.4.37, n=10, grid=(2,2,4), sz=2, f32):
#: fused_v2 1.03, fused_v2_jacobi 1.03 — the jaxpr boundary matches the
#: book almost exactly; sstep_v3 2.25 (s=4; 2.26-2.34 across (s, sz)) —
#: the per-cycle p/r window extensions (L/sz = 5x duplication at the
#: drift grid) are XLA gathers the book prices as redundant kernel
#: reads only.  The band width absorbs jax-version jaxpr differences;
#: real kernel/book changes move the ratio far more than the slack.
STREAM_BYTE_BANDS = {
    "fused_v2": (0.90, 1.15),
    "fused_v2_jacobi": (0.90, 1.15),
    "sstep_v3": (1.90, 2.60),
}

#: Pinned collective contracts per pipeline (single-device trace for the
#: v2 family; the DESIGN.md §10 sharded cycle/update contract for v3).
EXPECTED_COLLECTIVES = {
    "fused_v2": {},
    "fused_v2_jacobi": {},
    "sstep_v3": {"cycle": {"ppermute": 2, "psum": 1}, "update": {}},
}

# The drift case: paper degree (n=10) on the smallest grid every
# pipeline accepts at the pinned (sz, s) — tracing cost stays trivial
# and the books' n-dependence is exercised at the paper's n.
_DRIFT_N = 10
_DRIFT_GRID = (2, 2, 4)
_DRIFT_SZ = 2
_DRIFT_S = 4
_DRIFT_PRECISION = "f32"


# ---------------------------------------------------------------------------
# jaxpr stream-byte charging
# ---------------------------------------------------------------------------

def _nbytes(var) -> int:
    try:
        return int(np.prod(var.aval.shape)) * var.aval.dtype.itemsize
    except Exception:
        return 0                        # tokens / abstract units


def _subjaxprs(eqn):
    """Sub-jaxprs of an equation, duck-typed across jax versions
    (ClosedJaxpr has ``.jaxpr``, Jaxpr has ``.eqns``; they hide under
    different param keys — same convention as the collective walk in
    :mod:`repro.distributed.sstep`)."""
    for val in eqn.params.values():
        vals = val if isinstance(val, (tuple, list)) else (val,)
        for v in vals:
            if hasattr(v, "eqns"):
                yield v
            elif hasattr(v, "jaxpr") and hasattr(v.jaxpr, "eqns"):
                yield v.jaxpr


def charge_streams(jaxpr) -> tuple[int, int]:
    """(read_bytes, write_bytes) charged at the stream boundaries of a
    jaxpr: ``pallas_call`` and leaf equations bill their operands and
    results; structural equations are descended into (their boundary
    arrays are not traffic — the kernels inside are)."""
    r = w = 0
    for eqn in jaxpr.eqns:
        subs = list(_subjaxprs(eqn))
        if eqn.primitive.name == "pallas_call" or not subs:
            r += sum(_nbytes(v) for v in eqn.invars)
            w += sum(_nbytes(v) for v in eqn.outvars)
        else:
            for sub in subs:
                sr, sw = charge_streams(sub)
                r += sr
                w += sw
    return r, w


def _loops(jaxpr, out: list) -> list:
    for eqn in jaxpr.eqns:
        if eqn.primitive.name in ("while", "scan"):
            out.append(eqn)
        for sub in _subjaxprs(eqn):
            _loops(sub, out)
    return out


def _loop_body(eqn):
    body = eqn.params.get("body_jaxpr") or eqn.params.get("jaxpr")
    return body.jaxpr if hasattr(body, "jaxpr") else body


def measure_call_bytes(fn, *args) -> tuple[int, int]:
    """Stream-boundary (read, write) bytes of one call of ``fn``."""
    import jax

    closed = jax.make_jaxpr(fn)(*args)
    return charge_streams(closed.jaxpr)


def measure_iteration_bytes(fn, *args) -> tuple[int, int]:
    """Per-iteration (read, write) bytes of ``fn``'s main loop.

    Traces ``fn(*args)``, collects every while/scan (at any depth), and
    charges the body of the **max-traffic** one — the CG iteration
    dominates any inner coarse-solve or smoother loop.  Raises if the
    program has no loop (use :func:`measure_call_bytes`).
    """
    import jax

    closed = jax.make_jaxpr(fn)(*args)
    cands = _loops(closed.jaxpr, [])
    if not cands:
        raise ValueError("traced program has no while/scan loop")
    bodies = [charge_streams(_loop_body(e)) for e in cands]
    return max(bodies, key=sum)


# ---------------------------------------------------------------------------
# report types
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class DriftRow:
    """One pipeline x one check."""

    pipeline: str
    check: str                          # "bytes_per_dof_iter"|"collectives"
    measured: object                    # bytes: [r, w]; collectives: dict
    expected: object
    ok: bool
    ratio: float | None = None          # bytes only: measured/model total
    band: tuple | None = None
    detail: str = ""

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class DriftReport:
    """The ``model_drift`` payload: one row per (pipeline, check)."""

    rows: list

    @property
    def ok(self) -> bool:
        return all(row.ok for row in self.rows)

    def failures(self) -> list:
        return [row for row in self.rows if not row.ok]

    def to_dict(self) -> dict:
        from repro.obs import trace

        return {"schema": "model-drift/1", "ok": self.ok,
                "provenance": trace.provenance(),
                "rows": [row.to_dict() for row in self.rows]}


class ModelDriftError(RuntimeError):
    """The cost books no longer describe the compiled program."""


# ---------------------------------------------------------------------------
# per-pipeline checks
# ---------------------------------------------------------------------------

def _drift_case(precision: str):
    from repro.core.nekbone import NekboneCase

    return NekboneCase(n=_DRIFT_N, grid=_DRIFT_GRID, ax_impl="fused",
                       precision=precision)


def _v2_driver(case, pipeline: str, precision: str, sz: int, niter: int):
    """The public fused-v2 driver closed over the drift case's operator
    (sz pinned so no measured autotune sweep runs)."""
    from repro.core.precond import pcg_fused_v2_fixed_iters

    spec = (case.precond_spec("jacobi")
            if pipeline == "fused_v2_jacobi" else None)

    def drv(f):
        return pcg_fused_v2_fixed_iters(
            f, D=case.D, g=case.g, grid=case.grid, niter=niter,
            precond=spec, mask=case.mask, c=case.c, precision=precision,
            interpret=True, sz=sz)

    return drv


def check_bytes(pipeline: str, *, precision: str = _DRIFT_PRECISION,
                sz: int = _DRIFT_SZ, s: int = _DRIFT_S) -> DriftRow:
    """Measured vs modeled bytes/DOF/iter for one pipeline."""
    from repro.core import cost

    if pipeline not in STREAM_BYTE_BANDS:
        raise ValueError(
            f"no calibrated drift band for pipeline {pipeline!r} "
            f"(known: {sorted(STREAM_BYTE_BANDS)})")
    case = _drift_case(precision)
    ndof = case.mesh.nelt * _DRIFT_N ** 3
    if pipeline == "sstep_v3":
        from repro.core.cg_sstep import sstep_cycle_traceables

        (pw, pa), (up, ua) = sstep_cycle_traceables(
            case.D, case.g, _DRIFT_GRID, s=s, sz=sz, precision=precision)
        pr, pww = measure_call_bytes(pw, *pa)
        ur, uw = measure_call_bytes(up, *ua)
        meas_r = (pr + ur) / s / ndof
        meas_w = (pww + uw) / s / ndof
        rm, wm = cost.bytes_per_dof_iter(pipeline, precision, exact=True,
                                         n=_DRIFT_N, sz=sz, s=s)
    else:
        _, f = case.manufactured()
        drv = _v2_driver(case, pipeline, precision, sz, niter=3)
        r, w = measure_iteration_bytes(drv, f)
        meas_r, meas_w = r / ndof, w / ndof
        rm, wm = cost.bytes_per_dof_iter(pipeline, precision, exact=True,
                                         n=_DRIFT_N, sz=sz)
    ratio = (meas_r + meas_w) / (rm + wm)
    lo, hi = STREAM_BYTE_BANDS[pipeline]
    ok = lo <= ratio <= hi
    return DriftRow(
        pipeline=pipeline, check="bytes_per_dof_iter",
        measured=[round(meas_r, 3), round(meas_w, 3)],
        expected=[round(rm, 3), round(wm, 3)], ok=ok,
        ratio=round(ratio, 4), band=(lo, hi),
        detail=(f"measured/model total ratio {ratio:.3f} "
                f"{'within' if ok else 'OUTSIDE'} [{lo}, {hi}] "
                f"(n={_DRIFT_N}, grid={_DRIFT_GRID}, sz={sz})"))


def check_collectives(pipeline: str, *,
                      precision: str = _DRIFT_PRECISION,
                      sz: int = _DRIFT_SZ, s: int = _DRIFT_S) -> DriftRow:
    """Measured vs pinned collective counts for one pipeline."""
    if pipeline not in EXPECTED_COLLECTIVES:
        raise ValueError(
            f"no pinned collective contract for pipeline {pipeline!r} "
            f"(known: {sorted(EXPECTED_COLLECTIVES)})")
    expected = EXPECTED_COLLECTIVES[pipeline]
    if pipeline == "sstep_v3":
        from repro.distributed.sstep import cycle_collective_counts

        measured = cycle_collective_counts(grid=_DRIFT_GRID, n=_DRIFT_N,
                                           s=s, sz=sz, ndev=1,
                                           precision=precision)
        where = "sharded cycle/update at ndev=1"
    else:
        from repro.distributed.sstep import count_collectives

        case = _drift_case(precision)
        _, f = case.manufactured()
        drv = _v2_driver(case, pipeline, precision, sz, niter=3)
        measured = count_collectives(drv, f)
        where = "single-device driver"
    ok = measured == expected
    return DriftRow(
        pipeline=pipeline, check="collectives", measured=measured,
        expected=expected, ok=ok,
        detail=(f"{where}: {'matches' if ok else 'DRIFTED from'} "
                f"the pinned contract"))


def check(pipelines=DEFAULT_PIPELINES, *,
          precision: str = _DRIFT_PRECISION) -> DriftReport:
    """Run both drift checks over ``pipelines``; never raises on drift —
    inspect ``report.ok`` / call :func:`assert_no_drift`."""
    rows = []
    for pipeline in pipelines:
        rows.append(check_bytes(pipeline, precision=precision))
        rows.append(check_collectives(pipeline, precision=precision))
    return DriftReport(rows=rows)


def assert_no_drift(report: DriftReport | None = None,
                    pipelines=DEFAULT_PIPELINES) -> DriftReport:
    """Run (or take) a drift report and fail loudly on any drifted row."""
    if report is None:
        report = check(pipelines)
    if not report.ok:
        lines = [f"  {row.pipeline}/{row.check}: measured={row.measured} "
                 f"expected={row.expected} ({row.detail})"
                 for row in report.failures()]
        raise ModelDriftError(
            "cost-model drift detected — core/cost.py books no longer "
            "describe the compiled pipelines:\n" + "\n".join(lines))
    return report
