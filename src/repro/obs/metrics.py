"""Per-solve and service-level metrics (DESIGN.md §14).

Two consumers:

* :func:`capture_solve` builds a :class:`SolveTelemetry` for one routed
  solve — called by :func:`repro.core.solvers.solve_case` **only when a
  recorder is active**, so the tracing-off path allocates nothing and
  the result stays bitwise identical.  The per-phase wall-µs come from
  the same clock discipline as :mod:`repro.kernels.timing`
  (:func:`repro.kernels.timing.stopwatch`), the autotune cache hit/miss
  deltas from :func:`repro.kernels.autotune.cache_stats`, and the
  optional collective counts ride the existing
  :func:`repro.distributed.sstep.count_collectives` jaxpr walk
  (:func:`measure_collectives`).

* :class:`ServiceMetrics` is the solver service's queue/dispatch
  instrument: a queue-depth gauge (+ high-water mark), a dispatch
  counter, and per-bucket latency / batch-occupancy histograms — always
  on (the service is a host-side object; a handful of floats per
  dispatch is free next to a batched solve) and snapshot-able as plain
  JSON for the bench payload.
"""
from __future__ import annotations

import dataclasses
import math

__all__ = ["SolveTelemetry", "capture_solve", "measure_collectives",
           "Histogram", "ServiceMetrics"]


# ---------------------------------------------------------------------------
# per-solve telemetry
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SolveTelemetry:
    """What one routed solve did — attached as ``SolveResult.telemetry``
    when tracing is on (None otherwise; the field is static host data
    and never crosses a jit boundary)."""

    route: str                          # REGISTRY row that served it
    pipeline: str | None                # SolveResult.pipeline
    precond: str | None
    b: int                              # RHS batch
    niter: int | None                   # fixed-iteration request (or None)
    tol: float | None                   # tol-driven request (or None)
    iters: int                          # iterations actually run (max over b)
    achieved_rtol: float                # worst lane for batched solves
    wall_us: float                      # dispatch wall time, host clock
    phases: dict[str, float] = dataclasses.field(default_factory=dict)
    autotune: dict[str, int] = dataclasses.field(default_factory=dict)
    collectives: dict[str, int] | None = None
    provenance: dict | None = None

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def capture_solve(res, *, route: str, b: int, niter: int | None,
                  tol: float | None, wall_us: float,
                  phases: dict[str, float] | None = None,
                  autotune: dict[str, int] | None = None,
                  collectives: dict[str, int] | None = None
                  ) -> SolveTelemetry:
    """Build telemetry from a finished :class:`SolveResult`.

    Reads ``iters_taken``/``achieved_rtol`` off the device (a sync —
    acceptable because this only runs when tracing is on).
    """
    import numpy as np

    from repro.obs import trace

    iters = int(np.max(np.asarray(res.iters_taken)))
    rtol = float(np.max(np.asarray(res.achieved_rtol)))
    return SolveTelemetry(
        route=route, pipeline=res.pipeline, precond=res.precond, b=b,
        niter=niter, tol=tol, iters=iters, achieved_rtol=rtol,
        wall_us=wall_us, phases=dict(phases or {}),
        autotune=dict(autotune or {}), collectives=collectives,
        provenance=trace.provenance())


def measure_collectives(fn, *args) -> dict[str, int]:
    """Collective-primitive counts of ``fn(*args)``'s jaxpr — the
    existing :func:`repro.distributed.sstep.count_collectives` walk,
    re-exported at the obs surface so telemetry consumers don't import
    the distributed layer directly."""
    from repro.distributed.sstep import count_collectives

    return count_collectives(fn, *args)


# ---------------------------------------------------------------------------
# histograms + service metrics
# ---------------------------------------------------------------------------

class Histogram:
    """Fixed-boundary histogram with summary stats.

    ``bounds`` are the upper edges of the finite buckets; everything
    above the last edge lands in the ``+inf`` bucket.  Snapshot is plain
    JSON: counts per bucket plus count/mean/min/max.
    """

    __slots__ = ("bounds", "bucket_counts", "n", "total", "vmin", "vmax")

    def __init__(self, bounds):
        self.bounds = tuple(sorted(float(b) for b in bounds))
        if not self.bounds:
            raise ValueError("Histogram needs at least one bucket bound")
        self.bucket_counts = [0] * (len(self.bounds) + 1)
        self.n = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf

    def record(self, value: float) -> None:
        v = float(value)
        i = 0
        for i, edge in enumerate(self.bounds):  # noqa: B007
            if v <= edge:
                break
        else:
            i = len(self.bounds)
        self.bucket_counts[i] += 1
        self.n += 1
        self.total += v
        self.vmin = min(self.vmin, v)
        self.vmax = max(self.vmax, v)

    def snapshot(self) -> dict:
        labels = [f"le_{edge:g}" for edge in self.bounds] + ["inf"]
        return {"count": self.n,
                "mean": (self.total / self.n) if self.n else None,
                "min": self.vmin if self.n else None,
                "max": self.vmax if self.n else None,
                "buckets": dict(zip(labels, self.bucket_counts))}


# dispatch latency in ms (decade-ish edges: interpret-mode CPU solves sit
# in the 10ms-10s range, compiled TPU solves well under) and batch
# occupancy as a fraction of max_b.
_LATENCY_BOUNDS_MS = (1.0, 10.0, 100.0, 1_000.0, 10_000.0)
_OCCUPANCY_BOUNDS = (0.25, 0.5, 0.75, 1.0)


class ServiceMetrics:
    """Queue/dispatch metrics for :class:`~repro.launch.solver_service.
    SolverService` — always-on host counters, JSON-snapshot-able."""

    def __init__(self):
        self.queue_depth = 0
        self.queue_high_water = 0
        self.submitted = 0
        self.dispatches = 0
        self.requests_served = 0
        self.latency_ms = Histogram(_LATENCY_BOUNDS_MS)
        self.occupancy = Histogram(_OCCUPANCY_BOUNDS)
        self.per_bucket: dict[tuple, dict] = {}

    # -- queue ----------------------------------------------------------
    def observe_submit(self, depth: int) -> None:
        self.submitted += 1
        self.observe_depth(depth)

    def observe_depth(self, depth: int) -> None:
        self.queue_depth = depth
        self.queue_high_water = max(self.queue_high_water, depth)
        from repro.obs import trace

        trace.gauge("service.queue_depth", depth)

    # -- dispatch -------------------------------------------------------
    def observe_dispatch(self, bucket: tuple, batch: int, max_b: int,
                         wall_us: float) -> None:
        ms = wall_us / 1e3
        occ = batch / max(max_b, 1)
        self.dispatches += 1
        self.requests_served += batch
        self.latency_ms.record(ms)
        self.occupancy.record(occ)
        per = self.per_bucket.get(bucket)
        if per is None:
            per = self.per_bucket[bucket] = {
                "latency_ms": Histogram(_LATENCY_BOUNDS_MS),
                "occupancy": Histogram(_OCCUPANCY_BOUNDS),
            }
        per["latency_ms"].record(ms)
        per["occupancy"].record(occ)
        from repro.obs import trace

        trace.count("service.dispatches")
        trace.count("service.requests", batch)

    # -- export ---------------------------------------------------------
    def snapshot(self) -> dict:
        return {
            "queue_depth": self.queue_depth,
            "queue_high_water": self.queue_high_water,
            "submitted": self.submitted,
            "dispatches": self.dispatches,
            "requests_served": self.requests_served,
            "latency_ms": self.latency_ms.snapshot(),
            "occupancy": self.occupancy.snapshot(),
            "per_bucket": {repr(k): {name: h.snapshot()
                                     for name, h in v.items()}
                           for k, v in self.per_bucket.items()},
        }
