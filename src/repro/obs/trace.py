"""Structured solver traces: spans, events, counters -> JSONL files.

The recording surface of the telemetry subsystem (DESIGN.md §14).  A
:class:`Recorder` collects *host-side* spans and events — instrumentation
sits only at the host boundaries of the pipelines (the s-step cycle loop,
the refinement sweep loop, driver dispatch, service drain); nothing is
ever recorded from inside a jitted computation, so the compiled programs
are byte-for-byte the same with tracing on or off.

Zero-overhead-when-off contract:

* the active recorder is a context-local (``contextvars``) slot, read
  once per solve at the host boundary — hot loops hold the local and
  skip every span with a single ``is None`` test;
* :func:`span` with no active recorder returns the shared
  :data:`NULL_SPAN` singleton without evaluating span attributes (the
  instrumented sites spell ``rec.span(...) if rec is not None else
  NULL_SPAN`` so even the attrs dict is never allocated);
* solve *output* is bitwise identical either way — pinned by
  tests/test_obs_trace.py and the ``obs-smoke`` CI leg.

Trace files are JSON Lines with a versioned schema
(:data:`TRACE_SCHEMA`): a ``header`` record first (schema + provenance),
then ``span``/``event`` records in completion order, then one closing
``summary`` record (counters, gauges).  :func:`validate_trace_lines` is
the schema check the obs-smoke leg and the tests share.

Opt-in ``jax.profiler`` hooks: :func:`profiler_annotation` wraps kernel
launches in ``jax.profiler.TraceAnnotation`` when ``$REPRO_PROFILE`` is
set (otherwise it is the no-op span), and :func:`profiling` wires
``start_trace``/``stop_trace`` around a bench when a log dir is given.
"""
from __future__ import annotations

import contextlib
import contextvars
import json
import os
import pathlib
import platform
import time
from typing import Any

__all__ = ["TRACE_SCHEMA", "TRACE_SCHEMA_VERSION", "NULL_SPAN", "Recorder",
           "recording", "active", "span", "event", "count", "gauge",
           "provenance", "machine_tag", "validate_trace_lines",
           "validate_trace_file", "profiler_annotation", "profiling"]

TRACE_SCHEMA = "repro-trace/1"
TRACE_SCHEMA_VERSION = 1

_RECORDER: contextvars.ContextVar["Recorder | None"] = \
    contextvars.ContextVar("repro_obs_recorder", default=None)


class _NullSpan:
    """Shared no-op context manager — what instrumented code enters when
    tracing is off.  A singleton: entering it allocates nothing."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


NULL_SPAN = _NullSpan()


class _Span:
    """One timed region; records itself on ``__exit__`` (completion
    order), carrying the recorder's nesting depth at entry."""

    __slots__ = ("_rec", "name", "attrs", "_t0", "_depth")

    def __init__(self, rec: "Recorder", name: str, attrs: dict):
        self._rec = rec
        self.name = name
        self.attrs = attrs
        self._t0 = 0.0
        self._depth = 0

    def __enter__(self):
        rec = self._rec
        self._depth = rec._depth
        rec._depth += 1
        self._t0 = rec.now_us()
        return self

    def __exit__(self, *exc):
        rec = self._rec
        dur = rec.now_us() - self._t0
        rec._depth -= 1
        ev: dict[str, Any] = {"type": "span", "name": self.name,
                              "t_us": round(self._t0, 3),
                              "dur_us": round(dur, 3),
                              "depth": self._depth}
        if self.attrs:
            ev["attrs"] = self.attrs
        rec.records.append(ev)
        return False


class Recorder:
    """Collects spans/events/counters for one recording session.

    Timestamps are microseconds since the recorder's creation
    (``time.perf_counter_ns`` — monotonic, never wall-clock).  Not
    thread-safe by design: one recorder belongs to one context (the
    ``contextvars`` slot keeps concurrent contexts independent).
    """

    def __init__(self, *, meta: dict | None = None):
        self.records: list[dict] = []
        self.counters: dict[str, float] = {}
        self.gauges: dict[str, float] = {}
        self.meta = dict(meta or {})
        self._depth = 0
        self._t0 = time.perf_counter_ns()

    def now_us(self) -> float:
        return (time.perf_counter_ns() - self._t0) / 1e3

    # -- recording ------------------------------------------------------
    def span(self, name: str, /, **attrs) -> _Span:
        """Context manager timing one host-side region."""
        return _Span(self, name, attrs)

    def event(self, name: str, /, **attrs) -> None:
        """One instantaneous record."""
        ev: dict[str, Any] = {"type": "event", "name": name,
                              "t_us": round(self.now_us(), 3),
                              "depth": self._depth}
        if attrs:
            ev["attrs"] = attrs
        self.records.append(ev)

    def count(self, name: str, value: float = 1) -> None:
        """Monotonic counter increment (totals land in the summary)."""
        self.counters[name] = self.counters.get(name, 0) + value

    def gauge(self, name: str, value: float) -> None:
        """Last-value-wins gauge (e.g. queue depth)."""
        self.gauges[name] = value

    # -- serialization --------------------------------------------------
    def header(self) -> dict:
        h = {"type": "header", "schema": TRACE_SCHEMA,
             "schema_version": TRACE_SCHEMA_VERSION,
             "provenance": provenance()}
        if self.meta:
            h["meta"] = self.meta
        return h

    def summary(self) -> dict:
        return {"type": "summary", "spans": sum(
                    1 for r in self.records if r["type"] == "span"),
                "events": sum(
                    1 for r in self.records if r["type"] == "event"),
                "counters": dict(self.counters),
                "gauges": dict(self.gauges)}

    def lines(self) -> list[str]:
        recs = [self.header(), *self.records, self.summary()]
        return [json.dumps(r, sort_keys=True, default=_jsonable)
                for r in recs]

    def write(self, path) -> pathlib.Path:
        """Write the trace as JSONL (parent dirs created)."""
        path = pathlib.Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text("\n".join(self.lines()) + "\n")
        return path


def _jsonable(x):
    """Trace attrs may carry numpy/jax scalars; coerce, never crash."""
    for conv in (float, str):
        try:
            return conv(x)
        except (TypeError, ValueError):
            continue
    return repr(x)


# ---------------------------------------------------------------------------
# the context-local slot + module-level convenience surface
# ---------------------------------------------------------------------------

def active() -> Recorder | None:
    """The context's active recorder, or None when tracing is off.

    Host boundaries call this **once per solve** and thread the result
    through their loops — the per-iteration cost when off is one local
    ``is None`` test, no allocation.
    """
    return _RECORDER.get()


@contextlib.contextmanager
def recording(path=None, *, meta: dict | None = None,
              recorder: Recorder | None = None):
    """Activate a recorder for the enclosed block; yields it.

        with trace.recording("out/solve.trace.jsonl") as rec:
            repro.solve(1024, niter=100)
        # rec.records / the JSONL file now hold the spans

    ``path`` (optional) writes the JSONL trace on exit — also on
    exception, so a failing solve still leaves its evidence.  Nested
    recordings shadow the outer recorder for their extent.
    """
    rec = recorder if recorder is not None else Recorder(meta=meta)
    token = _RECORDER.set(rec)
    try:
        yield rec
    finally:
        _RECORDER.reset(token)
        if path is not None:
            rec.write(path)


def span(name: str, /, **attrs):
    """Module-level span: records under the active recorder, or returns
    the shared no-op singleton when tracing is off."""
    rec = _RECORDER.get()
    return rec.span(name, **attrs) if rec is not None else NULL_SPAN


def event(name: str, /, **attrs) -> None:
    rec = _RECORDER.get()
    if rec is not None:
        rec.event(name, **attrs)


def count(name: str, value: float = 1) -> None:
    rec = _RECORDER.get()
    if rec is not None:
        rec.count(name, value)


def gauge(name: str, value: float) -> None:
    rec = _RECORDER.get()
    if rec is not None:
        rec.gauge(name, value)


# ---------------------------------------------------------------------------
# provenance — recorded in every trace header and in BENCH_*.json
# ---------------------------------------------------------------------------

def machine_tag() -> str:
    """Hostname-free machine fingerprint: OS, ISA, core count.

    Enough to explain "why do these timings differ" across environments
    without leaking a hostname into committed baselines or uploaded
    artifacts."""
    return "-".join((platform.system().lower() or "unknown",
                     platform.machine() or "unknown",
                     f"{os.cpu_count() or 0}cpu"))


def provenance() -> dict:
    """Where a measurement came from: backend, jax version, x64 flag,
    machine tag.  Degrades gracefully when jax is absent (trace-only
    consumers)."""
    prov = {"machine": machine_tag(),
            "python": platform.python_version()}
    try:
        import jax

        prov["jax_version"] = jax.__version__
        prov["backend"] = jax.default_backend()
        prov["x64"] = bool(jax.config.jax_enable_x64)
    except Exception:  # noqa: BLE001 — provenance must never sink a trace
        prov["backend"] = None
    return prov


# ---------------------------------------------------------------------------
# JSONL schema validation (shared by tests and the obs-smoke CI leg)
# ---------------------------------------------------------------------------

_REQUIRED = {
    "header": ("schema", "schema_version", "provenance"),
    "span": ("name", "t_us", "dur_us", "depth"),
    "event": ("name", "t_us"),
    "summary": ("spans", "events", "counters", "gauges"),
}


def validate_trace_lines(lines) -> list[str]:
    """All schema violations of a JSONL trace (empty list == valid).

    Checks: every line parses as a JSON object; first record is a
    ``header`` with the known schema; last is a ``summary`` whose span
    count matches; required fields per record type; span timings are
    finite and non-negative."""
    problems: list[str] = []
    records = []
    for i, line in enumerate(lines):
        line = line.strip()
        if not line:
            continue
        try:
            rec = json.loads(line)
        except ValueError as e:
            problems.append(f"line {i + 1}: not valid JSON ({e})")
            continue
        if not isinstance(rec, dict):
            problems.append(f"line {i + 1}: not a JSON object")
            continue
        records.append((i + 1, rec))
    if not records:
        problems.append("empty trace: no records")
        return problems
    for ln, rec in records:
        typ = rec.get("type")
        if typ not in _REQUIRED:
            problems.append(f"line {ln}: unknown record type {typ!r}")
            continue
        for field in _REQUIRED[typ]:
            if field not in rec:
                problems.append(f"line {ln}: {typ} record missing "
                                f"{field!r}")
        if typ == "span":
            for field in ("t_us", "dur_us"):
                v = rec.get(field)
                if not isinstance(v, (int, float)) or v < 0 or v != v:
                    problems.append(f"line {ln}: span {field}={v!r} is "
                                    "not a non-negative number")
    first, last = records[0][1], records[-1][1]
    if first.get("type") != "header":
        problems.append("first record is not a header")
    elif first.get("schema") != TRACE_SCHEMA:
        problems.append(f"header schema {first.get('schema')!r} != "
                        f"{TRACE_SCHEMA!r}")
    if last.get("type") != "summary":
        problems.append("last record is not a summary")
    else:
        nspan = sum(1 for _, r in records if r.get("type") == "span")
        if last.get("spans") != nspan:
            problems.append(f"summary claims {last.get('spans')} spans, "
                            f"trace holds {nspan}")
    return problems


def validate_trace_file(path) -> list[str]:
    """:func:`validate_trace_lines` over a file path."""
    try:
        text = pathlib.Path(path).read_text()
    except OSError as e:
        return [f"cannot read trace file {path}: {e}"]
    return validate_trace_lines(text.splitlines())


# ---------------------------------------------------------------------------
# opt-in jax.profiler hooks
# ---------------------------------------------------------------------------

def profiler_annotation(name: str):
    """``jax.profiler.TraceAnnotation(name)`` when ``$REPRO_PROFILE`` is
    set — the kernel launch shows up named on the profiler timeline —
    else the shared no-op span.  Opt-in by env var so the default path
    never imports ``jax.profiler``."""
    if not os.environ.get("REPRO_PROFILE"):
        return NULL_SPAN
    try:
        import jax.profiler

        return jax.profiler.TraceAnnotation(name)
    except Exception:  # noqa: BLE001 — profiling must never sink a solve
        return NULL_SPAN


@contextlib.contextmanager
def profiling(logdir=None):
    """``jax.profiler.start_trace(logdir)`` .. ``stop_trace()`` around a
    block; a no-op when ``logdir`` is falsy.  The benches pass
    ``$REPRO_PROFILE_DIR`` here, so profiling is one env var away without
    touching bench code."""
    if not logdir:
        yield None
        return
    import jax.profiler

    jax.profiler.start_trace(str(logdir))
    try:
        yield str(logdir)
    finally:
        jax.profiler.stop_trace()
