"""AdamW with decoupled weight decay, global-norm clipping, and configurable
moment dtypes (bf16 moments for the largest archs — DESIGN.md §3).

Implemented from scratch (optax is not available in this container); the
update is the standard Loshchilov-Hutter formulation with bias correction.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWState", "adamw_init", "adamw_update", "global_norm"]


class AdamWState(NamedTuple):
    step: jnp.ndarray         # () int32
    mu: Any                   # first moment (pytree like params)
    nu: Any                   # second moment


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def adamw_init(params, *, moment_dtype=jnp.float32) -> AdamWState:
    def zeros(p):
        return jnp.zeros(p.shape, moment_dtype)

    return AdamWState(step=jnp.zeros((), jnp.int32),
                      mu=jax.tree.map(zeros, params),
                      nu=jax.tree.map(zeros, params))


def adamw_update(params, grads, state: AdamWState, *, lr,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1, clip_norm: float | None = 1.0):
    """One AdamW step.  ``lr`` may be a scalar or a traced schedule value.

    Returns (new_params, new_state, metrics).
    """
    gnorm = global_norm(grads)
    if clip_norm is not None:
        scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)

    step = state.step + 1
    b1c = 1.0 - b1 ** step.astype(jnp.float32)
    b2c = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m32 = m.astype(jnp.float32) * b1 + g32 * (1 - b1)
        v32 = v.astype(jnp.float32) * b2 + g32 * g32 * (1 - b2)
        mh = m32 / b1c
        vh = v32 / b2c
        delta = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * delta
        return newp.astype(p.dtype), m32.astype(m.dtype), v32.astype(v.dtype)

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    return new_params, AdamWState(step, new_mu, new_nu), {"grad_norm": gnorm}
