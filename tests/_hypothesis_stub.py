"""Deterministic stand-in for ``hypothesis`` (used when it isn't installed).

This container has no ``hypothesis`` package and nothing may be installed,
so ``conftest.py`` registers this module as ``hypothesis`` in ``sys.modules``
before test collection.  It implements exactly the surface the test suite
uses — ``given``, ``settings`` and the ``strategies`` namespace — by drawing
a fixed number of examples from a PRNG seeded with the test's qualified
name, so every run explores the same inputs (reproducible by construction;
no shrinking, no example database).

If real hypothesis is present, conftest leaves it alone and this module is
never imported.
"""
from __future__ import annotations

import functools
import inspect
import random
import types

MAX_EXAMPLES = 5  # global cap: property tests stay fast without hypothesis


class _Strategy:
    """A value generator: ``draw(rnd) -> value``."""

    def __init__(self, draw):
        self._draw = draw

    def example(self, rnd: random.Random):
        return self._draw(rnd)


def _integers(min_value, max_value):
    return _Strategy(lambda r: r.randint(min_value, max_value))


def _floats(min_value, max_value):
    return _Strategy(lambda r: r.uniform(min_value, max_value))


def _booleans():
    return _Strategy(lambda r: bool(r.getrandbits(1)))


def _none():
    return _Strategy(lambda r: None)


def _sampled_from(seq):
    seq = list(seq)
    return _Strategy(lambda r: r.choice(seq))


def _one_of(*strategies_):
    return _Strategy(lambda r: r.choice(strategies_).example(r))


def _tuples(*strategies_):
    return _Strategy(lambda r: tuple(s.example(r) for s in strategies_))


strategies = types.SimpleNamespace(
    integers=_integers,
    floats=_floats,
    booleans=_booleans,
    none=_none,
    sampled_from=_sampled_from,
    one_of=_one_of,
    tuples=_tuples,
)


def settings(*_args, max_examples: int | None = None, **_kwargs):
    """Records ``max_examples`` on the decorated function; other knobs
    (deadline, database, ...) have no meaning here and are ignored."""

    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn

    return deco


def given(**kwargs):
    """Runs the test for ``min(max_examples, MAX_EXAMPLES)`` deterministic
    draws.  The PRNG is seeded with the test's qualname so each test sees a
    stable, test-specific input sequence across runs and processes."""

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **fixture_kwargs):
            cap = getattr(wrapper, "_stub_max_examples", None) or MAX_EXAMPLES
            rnd = random.Random(fn.__qualname__)
            for _ in range(min(cap, MAX_EXAMPLES)):
                drawn = {name: s.example(rnd) for name, s in kwargs.items()}
                fn(*args, **fixture_kwargs, **drawn)

        # Hide the strategy-bound parameters from pytest's fixture
        # resolution (real hypothesis does the same).
        sig = inspect.signature(fn)
        wrapper.__signature__ = sig.replace(parameters=[
            p for name, p in sig.parameters.items() if name not in kwargs])
        return wrapper

    return deco
