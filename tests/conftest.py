# NOTE: deliberately no XLA_FLAGS device-count override here — smoke tests
# and benches must see the real single CPU device.  Multi-device behaviour
# is tested via subprocesses (tests/test_distributed.py) and the dry-run.
import importlib.util
import os
import pathlib
import sys

import numpy as np
import pytest

os.environ.setdefault("JAX_PLATFORMS", "cpu")

# ---------------------------------------------------------------------------
# hypothesis fallback: this container has no `hypothesis` and nothing may be
# installed, so register tests/_hypothesis_stub.py (deterministic fixed-seed
# example drawing) as the `hypothesis` module before collection imports the
# property-test modules.  Real hypothesis, when present, wins.
# ---------------------------------------------------------------------------
try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    _spec = importlib.util.spec_from_file_location(
        "hypothesis", pathlib.Path(__file__).with_name("_hypothesis_stub.py"))
    _stub = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_stub)
    sys.modules["hypothesis"] = _stub
    sys.modules["hypothesis.strategies"] = _stub.strategies


@pytest.fixture(autouse=True, scope="session")
def _isolated_autotune_cache(tmp_path_factory):
    """Point the autotune disk cache at a session tmp dir.

    Keeps the suite from reading or writing ``~/.cache/repro`` (or any
    pre-exported ``REPRO_CACHE_DIR``) — stale machine-local tuning must not
    leak into test picks, so the override is unconditional.
    """
    os.environ["REPRO_CACHE_DIR"] = str(tmp_path_factory.mktemp("repro-cache"))


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture
def x64():
    """Enable float64 within a single test (SEM oracle accuracy)."""
    import jax

    old = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", True)
    yield
    jax.config.update("jax_enable_x64", old)
