# NOTE: deliberately no XLA_FLAGS device-count override here — smoke tests
# and benches must see the real single CPU device.  Multi-device behaviour
# is tested via subprocesses (tests/test_distributed.py) and the dry-run.
import os

import numpy as np
import pytest

os.environ.setdefault("JAX_PLATFORMS", "cpu")


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture
def x64():
    """Enable float64 within a single test (SEM oracle accuracy)."""
    import jax

    old = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", True)
    yield
    jax.config.update("jax_enable_x64", old)
