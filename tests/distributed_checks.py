"""Multi-device checks, run in a subprocess with 8 fake host devices.

Invoked by tests/test_distributed.py (the device-count flag must be set
before jax initializes, so it cannot run in the main pytest process).
Prints one ``OK <name>`` line per passing check; exits non-zero on failure.

Usage:
    python tests/distributed_checks.py            # run every check
    python tests/distributed_checks.py NAME ...   # run named checks only
    python tests/distributed_checks.py --list     # print check names

Check names live in the ``CHECKS`` registry; ``test_distributed.py``
parametrizes one subprocess per name so a failure pinpoints its check.
"""
import contextlib
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp

from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.compat import axis_size, make_mesh, set_mesh, shard_map  # noqa: E402


def check(name, cond):
    if not cond:
        raise SystemExit(f"FAIL {name}")
    print(f"OK {name}", flush=True)


@contextlib.contextmanager
def _x64():
    """Enable f64 for the fp64-round-off parity checks, restore after."""
    old = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", True)
    try:
        yield
    finally:
        jax.config.update("jax_enable_x64", old)


def mesh2d():
    return make_mesh((2, 4), ("data", "model"))


def mesh1d(name="data"):
    return make_mesh((8,), (name,))


# ---------------------------------------------------------------------------
def check_compressed_psum():
    from repro.distributed.compression import compressed_psum, quantized_psum

    mesh = mesh1d("pod")
    x = jnp.asarray(np.random.default_rng(0).normal(size=(8, 64)), jnp.float32)

    def f(x):
        return compressed_psum(x, "pod")

    y = jax.jit(shard_map(f, mesh=mesh, in_specs=P("pod"),
                              out_specs=P("pod")))(x)
    # bf16 wire: ~3 decimal digits
    rel = float(jnp.abs(y - x.sum(0)).max() / (jnp.abs(x.sum(0)).max()))
    check("compressed_psum_bf16", rel < 2e-2)

    def fq(x):
        return quantized_psum(x, "pod")

    yq = jax.jit(shard_map(fq, mesh=mesh, in_specs=P("pod"),
                               out_specs=P("pod")))(x)
    relq = float(jnp.abs(yq - x.sum(0)).max() / (jnp.abs(x.sum(0)).max()))
    check("quantized_psum_int8", relq < 5e-2)


def check_collective_matmul():
    from repro.distributed.overlap import collective_matmul_allgather

    mesh = mesh1d("model")
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(16, 32)), jnp.float32)  # global rows
    w = jnp.asarray(rng.normal(size=(32, 24)), jnp.float32)

    def f(x_shard, w):
        return collective_matmul_allgather(x_shard, w, "model")

    # after the full ring pass every shard holds the identical full result;
    # the VMA checker can't infer that, hence check_vma=False.
    y_full = jax.jit(shard_map(
        f, mesh=mesh, in_specs=(P("model"), P()), out_specs=P(),
        check_vma=False))(x, w)
    want = x @ w
    err = float(jnp.abs(y_full - want).max())
    check("collective_matmul", err < 1e-4)


def check_cp_decode_attention():
    from repro.distributed.context_parallel import cp_decode_attention
    from repro.kernels.ref import attention_ref

    mesh = mesh1d("data")
    rng = np.random.default_rng(2)
    B, H, Hkv, S, d = 1, 4, 2, 64, 16
    q = jnp.asarray(rng.normal(size=(B, H, 1, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, Hkv, S, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, Hkv, S, d)), jnp.float32)
    valid = 50

    def f(q, k, v):
        return cp_decode_attention(q, k, v, axis_name="data",
                                   kv_valid_len=valid)

    got = jax.jit(shard_map(
        f, mesh=mesh,
        in_specs=(P(), P(None, None, "data", None),
                  P(None, None, "data", None)),
        out_specs=P()))(q, k, v)
    want = attention_ref(q, k[:, :, :valid], v[:, :, :valid], causal=False)
    err = float(jnp.abs(got - want).max())
    check("cp_decode_attention", err < 1e-4)


def check_sharded_gather_scatter():
    from repro.core.gs import ds_sum_local, ds_sum_sharded

    mesh = mesh1d("data")
    n, gridl = 4, (2, 3, 2)            # per-shard: EX=2 EY=3 EZ=2
    E_loc = 2 * 3 * 2
    rng = np.random.default_rng(3)
    u = jnp.asarray(rng.normal(size=(8 * E_loc, n, n, n)), jnp.float32)

    def f(u_loc):
        return ds_sum_sharded(u_loc, gridl, ("data",))

    got = jax.jit(shard_map(f, mesh=mesh, in_specs=P("data"),
                                out_specs=P("data")))(u)
    want = ds_sum_local(u, (2, 3, 16))  # global grid: z stacked over shards
    err = float(jnp.abs(got - want).max())
    check("ds_sum_sharded_1d", err < 1e-5)


def check_sharded_gs_hierarchical():
    from repro.core.gs import ds_sum_local, ds_sum_sharded

    mesh = make_mesh((2, 4), ("pod", "data"))
    n, gridl = 3, (2, 2, 2)
    E_loc = 8
    rng = np.random.default_rng(4)
    u = jnp.asarray(rng.normal(size=(8 * E_loc, n, n, n)), jnp.float32)

    def f(u_loc):
        return ds_sum_sharded(u_loc, gridl, ("pod", "data"))

    got = jax.jit(shard_map(f, mesh=mesh, in_specs=P(("pod", "data")),
                                out_specs=P(("pod", "data"))))(u)
    want = ds_sum_local(u, (2, 2, 16))
    err = float(jnp.abs(got - want).max())
    check("ds_sum_sharded_hierarchical", err < 1e-5)


def check_sharded_nekbone_cg():
    """Distributed CG solve == single-shard solve (bitwise-ish)."""
    import repro.core.cg as cg_mod
    from repro.core.nekbone import NekboneCase

    mesh = mesh1d("data")
    case = NekboneCase(n=4, grid=(2, 2, 8), dtype=jnp.float32)
    u_ex, f = case.manufactured()
    res_local = case.solve(f, niter=40)

    op = case.sharded_ax_full(("data",))
    grid_l = case.shard_grid(8)

    def solve_sharded(f, g, mask, c):
        def A(u):
            return op(u, g, mask, grid_l)

        dot = cg_mod.weighted_dot(c, psum_axes="data")
        return cg_mod.cg_fixed_iters(A, f, niter=40, dot=dot).x

    espec = P("data")
    x = jax.jit(shard_map(
        solve_sharded, mesh=mesh,
        in_specs=(espec, P("data"), espec, espec),
        out_specs=espec))(f, case.g, case.mask, case.c)
    err = float(jnp.abs(x - res_local.x).max())
    scale = float(jnp.abs(res_local.x).max())
    check("sharded_nekbone_cg", err < 1e-4 * max(scale, 1.0))


def check_fused_cg_sharded():
    """Sharded fused-CG pipeline == single-device fused CG.

    Per shard: the fused operator+pap Pallas kernel, ``ds_sum_sharded`` for
    the cross-shard z-planes (``halo_exchange_z`` ppermutes), and psum'd
    inner-product partials.  check_vma off: the replication checker has no
    rule for pallas_call.
    """
    from repro.core.cg_fused import (cg_fused_fixed_iters,
                                     cg_fused_sharded_fixed_iters)
    from repro.core.nekbone import NekboneCase

    mesh = mesh1d("data")
    case = NekboneCase(n=4, grid=(2, 2, 8), dtype=jnp.float32)
    _, f = case.manufactured()
    niter = 30
    ref = cg_fused_fixed_iters(f, D=case.D, g=case.g, mask=case.mask,
                               c=case.c, grid=case.grid, niter=niter,
                               interpret=True)
    grid_l = case.shard_grid(8)

    def solve(f_l, g_l, m_l, c_l):
        res = cg_fused_sharded_fixed_iters(
            f_l, D=case.D, g=g_l, mask=m_l, c=c_l, grid_local=grid_l,
            axis_names=("data",), niter=niter, interpret=True)
        return res.x, res.rnorm_history

    x, hist = jax.jit(shard_map(
        solve, mesh=mesh, in_specs=(P("data"),) * 4,
        out_specs=(P("data"), P()), check_vma=False))(
            f, case.g, case.mask, case.c)
    scale = float(jnp.abs(ref.x).max())
    err = float(jnp.abs(x - ref.x).max())
    check("fused_cg_sharded_x", err < 1e-4 * max(scale, 1.0))
    h_ref = np.asarray(ref.rnorm_history)
    h = np.asarray(hist)
    check("fused_cg_sharded_hist",
          np.isfinite(h).all()
          and float(np.abs(h[:10] - h_ref[:10]).max()) < 1e-4 * h_ref[0])


def check_fused_cg_sharded_precision():
    """Sharded fused CG under non-f64 precision policies (DESIGN.md §7).

    The sharded path was previously only exercised wide: here each of the
    f32 / bf16 storage policies must (a) run SPMD-uniform on the 8-device
    mesh — the psum'd partials travel in the *accum* dtype, so alpha/beta
    stay shard-identical even when storage rounds — and (b) reproduce the
    single-device fused pipeline at the same policy: identical arithmetic
    except the psum association of the inner products.
    """
    from repro.core.cg_fused import (cg_fused_fixed_iters,
                                     cg_fused_sharded_fixed_iters)
    from repro.core.nekbone import NekboneCase

    mesh = mesh1d("data")
    niter = 20
    for policy, tol in (("f32", 1e-4), ("bf16", 2e-2)):
        case = NekboneCase(n=4, grid=(2, 2, 8), dtype=jnp.float32)
        _, f = case.manufactured()
        ref = cg_fused_fixed_iters(f, D=case.D, g=case.g, mask=case.mask,
                                   c=case.c, grid=case.grid, niter=niter,
                                   interpret=True, precision=policy)
        grid_l = case.shard_grid(8)

        def solve(f_l, g_l, m_l, c_l, policy=policy):
            res = cg_fused_sharded_fixed_iters(
                f_l, D=case.D, g=g_l, mask=m_l, c=c_l, grid_local=grid_l,
                axis_names=("data",), niter=niter, interpret=True,
                precision=policy)
            return res.x, res.rnorm_history

        x, hist = jax.jit(shard_map(
            solve, mesh=mesh, in_specs=(P("data"),) * 4,
            out_specs=(P("data"), P()), check_vma=False))(
                f, case.g, case.mask, case.c)
        check(f"fused_cg_sharded_{policy}_dtype",
              x.dtype == ref.x.dtype)
        xs = np.asarray(x, np.float64)
        rs = np.asarray(ref.x, np.float64)
        scale = float(np.abs(rs).max()) + 1e-30
        check(f"fused_cg_sharded_{policy}_x",
              float(np.abs(xs - rs).max()) < tol * scale)
        h = np.asarray(hist, np.float64)
        h_ref = np.asarray(ref.rnorm_history, np.float64)
        # early history must track tightly; late entries drift chaotically
        # once round-off feeds back through alpha/beta (same budget as the
        # wide-path check above) — finiteness + net decrease pin those.
        check(f"fused_cg_sharded_{policy}_hist",
              np.isfinite(h).all()
              and float(np.abs(h[:10] - h_ref[:10]).max()) < tol * h_ref[0]
              and h[-1] < h[0])


def check_seq_sharded_attention():
    """Sequence-parallel chunked attention == plain chunked (odd head count)."""
    from repro.models.attention import _chunked, _seq_sharded_chunked

    mesh = mesh2d()          # data=2, model=4
    rng = np.random.default_rng(5)
    B, H, Hkv, S, d = 2, 5, 5, 256, 16      # 5 heads: not divisible by tp=4
    q = jnp.asarray(rng.normal(size=(B, H, S, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, Hkv, S, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, Hkv, S, d)), jnp.float32)
    for window in (None, 32):
        want = _chunked(q, k, v, causal=True, window=window, cap=None,
                        scale=d ** -0.5, q_offset=0, block_q=64, block_k=64)
        with set_mesh(mesh):
            got = jax.jit(lambda q, k, v, w=window: _seq_sharded_chunked(
                q, k, v, causal=True, window=w, cap=None,
                scale=d ** -0.5))(q, k, v)
        err = float(jnp.abs(got - want).max())
        check(f"seq_sharded_attention_w{window}", err < 1e-4)


def check_seq_sharded_decode():
    """shard_map decode (seq-sharded KV + local write) == plain decode."""
    import dataclasses

    from repro.models import attention as A

    @dataclasses.dataclass(frozen=True)
    class Cfg:
        d_model: int = 32
        n_heads: int = 6          # not divisible by tp=4 -> seq-shard path
        n_kv_heads: int = 2
        head_dim: int = 8
        qkv_bias: bool = False
        qk_norm: bool = False
        attn_softcap: float | None = None
        pos_emb: str = "rope"
        rope_theta: float = 1e4
        norm_eps: float = 1e-6
        param_dtype: str = "float32"
        compute_dtype: str = "float32"

    cfg = Cfg()
    p = A.init_attention(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(6)
    B, S = 2, 32
    x = jnp.asarray(rng.normal(size=(B, 1, 32)), jnp.float32)
    cache = {
        "k": jnp.asarray(rng.normal(size=(B, 2, S, 8)), jnp.float32),
        "v": jnp.asarray(rng.normal(size=(B, 2, S, 8)), jnp.float32),
    }
    idx = jnp.asarray(17, jnp.int32)
    out_plain, nc_plain = A.decode_attention(x, p, cfg, cache, idx, window=9)
    mesh = mesh2d()
    with set_mesh(mesh):
        out_s, nc_s = jax.jit(
            lambda x, c: A.decode_attention(x, p, cfg, c, idx, window=9))(
                x, cache)
    check("seq_sharded_decode_out",
          float(jnp.abs(out_s - out_plain).max()) < 1e-4)
    check("seq_sharded_decode_cache",
          float(jnp.abs(nc_s["k"] - nc_plain["k"]).max()) < 1e-6)


def check_moe_shardmap_equals_local():
    import dataclasses

    from repro.models.moe import init_moe, moe_ffn

    @dataclasses.dataclass(frozen=True)
    class Cfg:
        d_model: int = 32
        d_ff_expert: int = 64
        n_experts: int = 8
        top_k: int = 2
        gated: bool = True
        act: str = "silu"
        capacity_factor: float = 8.0
        param_dtype: str = "float32"
        compute_dtype: str = "float32"

    cfg = Cfg()
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32))
    y_local = moe_ffn(x, p, cfg)
    mesh = mesh2d()
    with set_mesh(mesh):
        y_sharded = jax.jit(lambda x: moe_ffn(x, p, cfg))(x)
    err = float(jnp.abs(y_sharded - y_local).max())
    check("moe_shardmap_equals_local", err < 1e-5)


def check_pipeline_parallel():
    """2-stage GPipe pipeline == sequential application of both stages."""
    from repro.distributed.pipeline import pipeline_apply

    mesh = make_mesh((2, 4), ("pod", "data"))
    rng = np.random.default_rng(7)
    L, M, mb, d = 4, 6, 3, 16             # 4 layers -> 2 stages x 2 layers
    Ws = jnp.asarray(rng.normal(size=(L, d, d)) * 0.3, jnp.float32)
    x = jnp.asarray(rng.normal(size=(M, mb, d)), jnp.float32)

    def stage_fn(W_stage, x):
        for i in range(W_stage.shape[0]):
            x = jnp.tanh(x @ W_stage[i])
        return x

    want = jnp.stack([stage_fn(Ws, x[m]) for m in range(M)])  # sequential
    Ws_staged = Ws.reshape(2, 2, d, d)     # (stage, layers/stage, d, d)

    def wrapped(ws, x):
        from jax.sharding import PartitionSpec as P

        def body(ws_local, x_full):
            out = pipeline_apply(ws_local[0], x_full, stage_fn,
                                 axis_name="pod")
            sid = jax.lax.axis_index("pod")
            S = axis_size("pod")
            return jnp.where(sid == S - 1, out, 0.0)[None]

        out = shard_map(
            body, mesh=mesh,
            in_specs=(P("pod"), P()), out_specs=P("pod"),
            check_vma=False)(ws, x)
        return out.sum(0)                  # only the last stage is nonzero

    got = jax.jit(wrapped)(Ws_staged, x)
    err = float(jnp.abs(got - want).max())
    check("pipeline_parallel_gpipe", err < 1e-5)


def check_elastic_checkpoint_reshard():
    """Save on one sharding, restore onto another mesh layout."""
    import tempfile

    from repro.checkpoint import CheckpointManager

    mesh = mesh2d()
    x = jnp.arange(64.0).reshape(8, 8)
    xs = jax.device_put(x, NamedSharding(mesh, P("data", "model")))
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        mgr.save(1, {"x": xs}, blocking=True)
        mesh_b = mesh1d("data")
        shard_b = {"x": NamedSharding(mesh_b, P(None, "data"))}
        _, back = mgr.restore({"x": x}, shardings=shard_b)
        np.testing.assert_array_equal(np.asarray(back["x"]), np.asarray(x))
        check("elastic_checkpoint_reshard",
              back["x"].sharding.spec == P(None, "data"))


def check_collective_matmul_colsharded():
    """Collective matmul, column-sharded weight layout: each shard holds a
    column slice of w and produces its column slice of all_gather(x) @ w —
    the ring body is layout-agnostic, only the specs change."""
    from repro.distributed.overlap import collective_matmul_allgather

    mesh = mesh1d("model")
    rng = np.random.default_rng(8)
    x = jnp.asarray(rng.normal(size=(16, 32)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(32, 24)), jnp.float32)

    def f(x_shard, w_cols):
        return collective_matmul_allgather(x_shard, w_cols, "model")

    y = jax.jit(shard_map(
        f, mesh=mesh, in_specs=(P("model"), P(None, "model")),
        out_specs=P(None, "model"), check_vma=False))(x, w)
    err = float(jnp.abs(y - x @ w).max())
    check("collective_matmul_colsharded", err < 1e-4)


def check_collective_matmul_sweep():
    """Collective matmul over 1/2/4/8-device sub-meshes (solver_mesh)."""
    from repro.distributed.overlap import collective_matmul_allgather
    from repro.distributed.sharding import solver_mesh

    rng = np.random.default_rng(9)
    x = jnp.asarray(rng.normal(size=(16, 32)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(32, 8)), jnp.float32)
    want = x @ w

    def f(x_shard, w_rep):
        return collective_matmul_allgather(x_shard, w_rep, "model")

    for p in (1, 2, 4, 8):
        mesh = solver_mesh(p, axis_name="model")
        y = jax.jit(shard_map(
            f, mesh=mesh, in_specs=(P("model"), P()), out_specs=P(),
            check_vma=False))(x, w)
        err = float(jnp.abs(y - want).max())
        check(f"collective_matmul_p{p}", err < 1e-4)


# -- sharded Nekbone solvers (DESIGN.md §10) --------------------------------

def _sstep_sharded_parity(s, grid, sz, niter, label):
    """Sharded s-step CG == single-device trajectory to fp64 round-off.

    ``niter`` stays pre-asymptotic (the in-cycle history floor caveat of
    tests/test_cg_sstep.py: once the residual collapses many orders within
    one cycle, late history entries sit at the f64-Gram round-off floor in
    *both* drivers but need not agree bitwise)."""
    with _x64():
        from repro.core.cg_sstep import cg_sstep_fixed_iters
        from repro.core.nekbone import NekboneCase
        from repro.distributed.sstep import cg_sstep_sharded_fixed_iters

        case = NekboneCase(n=4, grid=grid, dtype=jnp.float64)
        _, f = case.manufactured()
        kw = dict(D=case.D, g=case.g, grid=grid, niter=niter, s=s,
                  mask=case.mask, c=case.c, sz=sz, theta=2.25,
                  interpret=True)
        ref = cg_sstep_fixed_iters(f, **kw)
        got = cg_sstep_sharded_fixed_iters(f, ndev=8, **kw)
        h_ref = np.asarray(ref.rnorm_history, np.float64)
        h = np.asarray(got.rnorm_history, np.float64)
        check(f"{label}_hist",
              h.shape == h_ref.shape
              and float(np.abs(h - h_ref).max()) < 1e-9 * h_ref[0])
        xs = np.asarray(got.x, np.float64)
        rs = np.asarray(ref.x, np.float64)
        scale = float(np.abs(rs).max()) + 1e-30
        check(f"{label}_x", float(np.abs(xs - rs).max()) < 1e-8 * scale)


def check_sstep_sharded_s1():
    _sstep_sharded_parity(1, (2, 2, 16), 2, 10, "sstep_sharded_s1")


def check_sstep_sharded_s2():
    _sstep_sharded_parity(2, (2, 2, 16), 2, 10, "sstep_sharded_s2")


def check_sstep_sharded_s4():
    # EZ=32 over 8 shards: ez_local=4 >= s=4 (single-neighbour halo)
    _sstep_sharded_parity(4, (1, 2, 32), 2, 8, "sstep_sharded_s4")


def check_sstep_collective_counts():
    """The acceptance contract: exactly one stacked halo exchange
    (2 ppermutes) and one Gram psum per cycle; collective-free update.
    Covers both cycle paths: thin shards (single powers call) and the
    interior/boundary overlap split."""
    from repro.distributed.sstep import cycle_collective_counts

    cases = (
        (1, 1, (2, 2, 16)),   # thin: 2*nb >= nblk, single powers call
        (2, 2, (2, 2, 16)),
        (4, 2, (1, 2, 32)),
        (1, 1, (1, 1, 32)),   # ez_local=4, nblk=4: interior/boundary split
    )
    for s, sz, grid in cases:
        counts = cycle_collective_counts(grid=grid, n=4, s=s, sz=sz, ndev=8)
        check(f"sstep_counts_s{s}_sz{sz}_ez{grid[2]}",
              counts["cycle"] == {"ppermute": 2, "psum": 1}
              and counts["update"] == {})


def check_pcg_jacobi_sharded():
    """Sharded Jacobi PCG == single-device fused-v2 trajectory (f64)."""
    with _x64():
        from repro.core.nekbone import NekboneCase
        from repro.core.precond import pcg_fused_v2_fixed_iters
        from repro.distributed.pcg import pcg_sharded_fixed_iters

        grid = (2, 2, 16)
        case = NekboneCase(n=4, grid=grid, dtype=jnp.float64)
        _, f = case.manufactured()
        kw = dict(D=case.D, g=case.g, grid=grid, niter=12,
                  precond="jacobi", mask=case.mask, c=case.c, sz=2,
                  interpret=True)
        ref = pcg_fused_v2_fixed_iters(f, **kw)
        got = pcg_sharded_fixed_iters(f, ndev=8, **kw)
        h_ref = np.asarray(ref.rnorm_history, np.float64)
        h = np.asarray(got.rnorm_history, np.float64)
        ok = np.isfinite(h_ref)
        check("pcg_jacobi_sharded_hist",
              float(np.abs(h[ok] - h_ref[ok]).max()) < 1e-10 * h_ref[0])
        xs = np.asarray(got.x, np.float64)
        rs = np.asarray(ref.x, np.float64)
        scale = float(np.abs(rs).max()) + 1e-30
        check("pcg_jacobi_sharded_x",
              float(np.abs(xs - rs).max()) < 1e-9 * scale)


def check_pcg_cheb_sharded():
    """Sharded Chebyshev PCG == single-device fused-v2 trajectory (f64).

    ``cheb2``: k=2 ghost slabs <= ez_local=2 on the 8-way split of EZ=16.
    """
    with _x64():
        from repro.core.nekbone import NekboneCase
        from repro.core.precond import pcg_fused_v2_fixed_iters
        from repro.distributed.pcg import pcg_sharded_fixed_iters

        grid = (2, 2, 16)
        case = NekboneCase(n=4, grid=grid, dtype=jnp.float64)
        _, f = case.manufactured()
        kw = dict(D=case.D, g=case.g, grid=grid, niter=12,
                  precond="cheb2", mask=case.mask, c=case.c, sz=2,
                  cheb_sz=2, interpret=True)
        ref = pcg_fused_v2_fixed_iters(f, **kw)
        got = pcg_sharded_fixed_iters(f, ndev=8, **kw)
        h_ref = np.asarray(ref.rnorm_history, np.float64)
        h = np.asarray(got.rnorm_history, np.float64)
        ok = np.isfinite(h_ref)
        check("pcg_cheb_sharded_hist",
              float(np.abs(h[ok] - h_ref[ok]).max()) < 1e-10 * h_ref[0])
        xs = np.asarray(got.x, np.float64)
        rs = np.asarray(ref.x, np.float64)
        scale = float(np.abs(rs).max()) + 1e-30
        check("pcg_cheb_sharded_x",
              float(np.abs(xs - rs).max()) < 1e-9 * scale)


def check_pcg_sharded_precision():
    """Sharded PCG under the f32/bf16 storage policies (DESIGN.md §7):
    SPMD-uniform on 8 devices and within policy round-off of the
    single-device pipeline at the same policy."""
    from repro.core.nekbone import NekboneCase
    from repro.core.precond import pcg_fused_v2_fixed_iters
    from repro.distributed.pcg import pcg_sharded_fixed_iters

    grid = (2, 2, 16)
    for precond, policy, tol in (("jacobi", "f32", 1e-4),
                                 ("jacobi", "bf16", 2e-2),
                                 ("cheb2", "f32", 1e-4)):
        case = NekboneCase(n=4, grid=grid, dtype=jnp.float32)
        _, f = case.manufactured()
        kw = dict(D=case.D, g=case.g, grid=grid, niter=12, precond=precond,
                  mask=case.mask, c=case.c, sz=2, cheb_sz=2,
                  interpret=True, precision=policy)
        ref = pcg_fused_v2_fixed_iters(f, **kw)
        got = pcg_sharded_fixed_iters(f, ndev=8, **kw)
        check(f"pcg_sharded_{precond}_{policy}_dtype",
              got.x.dtype == ref.x.dtype)
        xs = np.asarray(got.x, np.float64)
        rs = np.asarray(ref.x, np.float64)
        scale = float(np.abs(rs).max()) + 1e-30
        check(f"pcg_sharded_{precond}_{policy}_x",
              float(np.abs(xs - rs).max()) < tol * scale)
        h = np.asarray(got.rnorm_history, np.float64)
        h_ref = np.asarray(ref.rnorm_history, np.float64)
        # early history tracks tightly; late entries drift chaotically once
        # storage round-off feeds back through alpha/beta (same budget as
        # check_fused_cg_sharded_precision) — finiteness + net decrease pin
        # the tail.
        check(f"pcg_sharded_{precond}_{policy}_hist",
              np.isfinite(h).all()
              and float(np.abs(h[:8] - h_ref[:8]).max()) < tol * h_ref[0]
              and h[-1] < h[0])


def check_pcg_sharded_tol_prefix():
    """Tol-driven sharded PCG is a bitwise prefix of the fixed-iteration
    trajectory (the tol2 = -1 sentinel contract of core/precond.py)."""
    with _x64():
        from repro.core.nekbone import NekboneCase
        from repro.distributed.pcg import (pcg_sharded_fixed_iters,
                                           pcg_sharded_tol)

        grid = (2, 2, 16)
        case = NekboneCase(n=4, grid=grid, dtype=jnp.float64)
        _, f = case.manufactured()
        kw = dict(D=case.D, g=case.g, grid=grid, precond="jacobi",
                  mask=case.mask, c=case.c, sz=2, interpret=True)
        full = pcg_sharded_fixed_iters(f, niter=20, ndev=8, **kw)
        tol = float(np.asarray(full.rnorm_history, np.float64)[12]) * 1.01
        got = pcg_sharded_tol(f, tol=tol, max_iter=20, ndev=8, **kw)
        kk = int(got.iters)
        check("pcg_sharded_tol_stops", 0 < kk < 20)
        h = np.asarray(got.rnorm_history, np.float64)
        h_full = np.asarray(full.rnorm_history, np.float64)
        check("pcg_sharded_tol_prefix",
              np.array_equal(h[:kk + 1], h_full[:kk + 1]))
        check("pcg_sharded_tol_nan_tail",
              np.isnan(h[kk + 1:]).all())


# ---------------------------------------------------------------------------
# registry + CLI
# ---------------------------------------------------------------------------

CHECKS = {
    "device_count": lambda: check("device_count", jax.device_count() == 8),
    "compressed_psum": check_compressed_psum,
    "collective_matmul": check_collective_matmul,
    "collective_matmul_colsharded": check_collective_matmul_colsharded,
    "collective_matmul_sweep": check_collective_matmul_sweep,
    "cp_decode_attention": check_cp_decode_attention,
    "sharded_gather_scatter": check_sharded_gather_scatter,
    "sharded_gs_hierarchical": check_sharded_gs_hierarchical,
    "sharded_nekbone_cg": check_sharded_nekbone_cg,
    "fused_cg_sharded": check_fused_cg_sharded,
    "fused_cg_sharded_precision": check_fused_cg_sharded_precision,
    "sstep_sharded_s1": check_sstep_sharded_s1,
    "sstep_sharded_s2": check_sstep_sharded_s2,
    "sstep_sharded_s4": check_sstep_sharded_s4,
    "sstep_collective_counts": check_sstep_collective_counts,
    "pcg_jacobi_sharded": check_pcg_jacobi_sharded,
    "pcg_cheb_sharded": check_pcg_cheb_sharded,
    "pcg_sharded_precision": check_pcg_sharded_precision,
    "pcg_sharded_tol_prefix": check_pcg_sharded_tol_prefix,
    "seq_sharded_attention": check_seq_sharded_attention,
    "seq_sharded_decode": check_seq_sharded_decode,
    "moe_shardmap_equals_local": check_moe_shardmap_equals_local,
    "pipeline_parallel": check_pipeline_parallel,
    "elastic_checkpoint_reshard": check_elastic_checkpoint_reshard,
}


def main(argv=None):
    import sys

    argv = list(sys.argv[1:] if argv is None else argv)
    if "--list" in argv:
        for name in CHECKS:
            print(name)
        return
    names = argv or list(CHECKS)
    unknown = [a for a in names if a not in CHECKS]
    if unknown:
        raise SystemExit(
            f"unknown checks {unknown}; see --list for valid names")
    for name in names:
        CHECKS[name]()
    print("ALL-DISTRIBUTED-OK")


if __name__ == "__main__":
    main()
