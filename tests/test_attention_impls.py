"""Attention implementation ladder: chunked == naive, MoE properties."""
import dataclasses

import numpy as np
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.models.attention import _chunked, _naive
from repro.models.moe import init_moe, moe_ffn


@settings(deadline=None, max_examples=12)
@given(seed=st.integers(0, 2 ** 16),
       causal=st.booleans(),
       window=st.one_of(st.none(), st.integers(4, 40)),
       cap=st.one_of(st.none(), st.floats(10.0, 60.0)))
def test_chunked_equals_naive(seed, causal, window, cap):
    rng = np.random.default_rng(seed)
    B, Hq, Hkv, S, d = 1, 4, 2, 48, 16
    q = jnp.asarray(rng.normal(size=(B, Hq, S, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, Hkv, S, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, Hkv, S, d)), jnp.float32)
    kw = dict(causal=causal, window=window, cap=cap, scale=d ** -0.5,
              q_offset=0)
    a = _chunked(q, k, v, block_q=16, block_k=16, **kw)
    b = _naive(q, k, v, **kw)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-4, atol=1e-5)


def test_chunked_grad_flows(rng):
    B, H, S, d = 1, 2, 32, 16
    q = jnp.asarray(rng.normal(size=(B, H, S, d)), jnp.float32)
    k, v = q + 0.1, q - 0.1
    g = jax.grad(lambda q_: _chunked(
        q_, k, v, causal=True, window=None, cap=None, scale=d ** -0.5,
        q_offset=0, block_q=16, block_k=16).sum())(q)
    assert bool(jnp.isfinite(g).all())


# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class _MoECfg:
    d_model: int = 32
    d_ff_expert: int = 64
    n_experts: int = 8
    top_k: int = 2
    gated: bool = True
    act: str = "silu"
    capacity_factor: float = 8.0
    param_dtype: str = "float32"
    compute_dtype: str = "float32"


def _moe_dense_ref(x, p, cfg):
    T = x.shape[0] * x.shape[1]
    xf = x.reshape(T, -1)
    probs = jax.nn.softmax(xf @ p["router"], -1)
    gate, eid = jax.lax.top_k(probs, cfg.top_k)
    gate = gate / gate.sum(-1, keepdims=True)
    out = jnp.zeros_like(xf)
    for e in range(cfg.n_experts):
        h = xf @ p["w_in"][e]
        g = jax.nn.silu(xf @ p["w_gate"][e])
        y = (h * g) @ p["w_out"][e]
        out += ((eid == e) * gate).sum(-1)[:, None] * y
    return out.reshape(x.shape)


def test_moe_matches_dense_reference(rng):
    cfg = _MoECfg()
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(rng.normal(size=(4, 16, 32)), jnp.float32)
    got = moe_ffn(x, p, cfg)
    want = _moe_dense_ref(x, p, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


@settings(deadline=None, max_examples=8)
@given(seed=st.integers(0, 2 ** 16), top_k=st.integers(1, 4))
def test_moe_permutation_equivariance(seed, top_k):
    """Token order must not matter: MoE(perm(x)) == perm(MoE(x))."""
    rng = np.random.default_rng(seed)
    cfg = _MoECfg(top_k=top_k)
    p = init_moe(jax.random.PRNGKey(1), cfg)
    x = jnp.asarray(rng.normal(size=(1, 12, 32)), jnp.float32)
    perm = rng.permutation(12)
    y = moe_ffn(x, p, cfg)
    y_p = moe_ffn(x[:, perm], p, cfg)
    np.testing.assert_allclose(np.asarray(y[:, perm]), np.asarray(y_p),
                               rtol=1e-4, atol=1e-5)


def test_moe_capacity_drops_gracefully(rng):
    """With capacity_factor -> tiny, output magnitude shrinks (drops) but
    stays finite — no garbage from dropped tokens."""
    cfg_full = _MoECfg(capacity_factor=8.0)
    cfg_tight = _MoECfg(capacity_factor=0.01)
    p = init_moe(jax.random.PRNGKey(0), cfg_full)
    x = jnp.asarray(rng.normal(size=(2, 64, 32)), jnp.float32)
    y_full = moe_ffn(x, p, cfg_full)
    y_tight = moe_ffn(x, p, cfg_tight)
    assert bool(jnp.isfinite(y_tight).all())
    assert float(jnp.abs(y_tight).sum()) <= float(jnp.abs(y_full).sum())


def test_moe_grad(rng):
    cfg = _MoECfg()
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(rng.normal(size=(2, 8, 32)), jnp.float32)
    g = jax.grad(lambda p_: moe_ffn(x, p_, cfg).sum())(p)
    total = sum(float(jnp.abs(l).sum()) for l in jax.tree.leaves(g))
    assert np.isfinite(total) and total > 0
