"""block_e autotuner: heuristic bounds, measurement path, cache behavior."""
import pytest

import jax.numpy as jnp

from repro.kernels import autotune


@pytest.fixture(autouse=True)
def _fresh_cache():
    autotune.clear_cache()
    yield
    autotune.clear_cache()


def test_vmem_heuristic_fits_budget():
    for n in (4, 8, 10, 12, 16):
        be = autotune.vmem_block_e(1024, n)
        n3p = -(-(n ** 3) // 128) * 128
        assert be >= 1
        assert 14 * n3p * 4 * be <= autotune.VMEM_BUDGET_BYTES


def test_candidates_divide_E():
    for E in (6, 8, 24, 1024):
        cands = autotune.candidate_blocks(E, 10)
        assert cands, (E,)
        assert all(E % be == 0 for be in cands)
        assert cands == sorted(cands, reverse=True)


def test_pick_is_cached_per_key():
    calls = []

    def measure(be):
        calls.append(be)
        return float(be)            # smaller block "faster": picks 1

    be1 = autotune.pick_block_e(8, 4, jnp.float32, backend="tpu",
                                measure=measure)
    assert be1 == 1
    n_calls = len(calls)
    assert n_calls == len(autotune.candidate_blocks(8, 4))

    # same key: served from cache, measure never re-runs
    be2 = autotune.pick_block_e(8, 4, jnp.float32, backend="tpu",
                                measure=measure)
    assert be2 == be1
    assert len(calls) == n_calls

    # different dtype / backend / shape are distinct cache keys
    autotune.pick_block_e(8, 4, jnp.float64, backend="tpu", measure=measure)
    assert len(calls) > n_calls
    assert len(autotune.cache_info()) == 2


def test_cpu_backend_uses_heuristic_without_measuring():
    def boom(be):
        raise AssertionError("must not measure on cpu")

    be = autotune.pick_block_e(64, 10, jnp.float32, backend="cpu")
    assert be == autotune.candidate_blocks(64, 10)[0]
    assert (10, 64, "float32", "cpu") in autotune.cache_info()


def test_measured_winner_beats_heuristic_order():
    # fastest candidate in the middle of the ladder must win
    target = {8: 3.0, 4: 1.0, 2: 2.0, 1: 5.0}

    def measure(be):
        return target[be]

    be = autotune.pick_block_e(8, 4, jnp.float32, backend="tpu",
                               measure=measure)
    assert be == 4
