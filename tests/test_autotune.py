"""block_e autotuner: heuristic bounds, measurement path, cache behavior,
slab-mode candidates, and the JSON disk cache."""
import json

import pytest

import jax.numpy as jnp

from repro.kernels import autotune


@pytest.fixture(autouse=True)
def _fresh_cache(tmp_path, monkeypatch):
    # point the disk layer at a per-test dir so tests never touch ~/.cache
    monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path))
    autotune.clear_cache()
    yield
    autotune.clear_cache()


def test_vmem_heuristic_fits_budget():
    for n in (4, 8, 10, 12, 16):
        be = autotune.vmem_block_e(1024, n)
        n3p = -(-(n ** 3) // 128) * 128
        assert be >= 1
        assert 14 * n3p * 4 * be <= autotune.VMEM_BUDGET_BYTES


def test_candidates_divide_E():
    for E in (6, 8, 24, 1024):
        cands = autotune.candidate_blocks(E, 10)
        assert cands, (E,)
        assert all(E % be == 0 for be in cands)
        assert cands == sorted(cands, reverse=True)


def test_pick_is_cached_per_key():
    calls = []

    def measure(be):
        calls.append(be)
        return float(be)            # smaller block "faster": picks 1

    be1 = autotune.pick_block_e(8, 4, jnp.float32, backend="tpu",
                                measure=measure)
    assert be1 == 1
    n_calls = len(calls)
    assert n_calls == len(autotune.candidate_blocks(8, 4))

    # same key: served from cache, measure never re-runs
    be2 = autotune.pick_block_e(8, 4, jnp.float32, backend="tpu",
                                measure=measure)
    assert be2 == be1
    assert len(calls) == n_calls

    # different dtype / backend / shape are distinct cache keys
    autotune.pick_block_e(8, 4, jnp.float64, backend="tpu", measure=measure)
    assert len(calls) > n_calls
    assert len(autotune.cache_info()) == 2


def test_cpu_backend_uses_heuristic_without_measuring():
    def boom(be):
        raise AssertionError("must not measure on cpu")

    be = autotune.pick_block_e(64, 10, jnp.float32, backend="cpu")
    assert be == autotune.candidate_blocks(64, 10)[0]
    # keys carry the resolved (storage, accum) dtype pair (DESIGN.md §7)
    assert (10, 64, "float32", "float32", "cpu") in autotune.cache_info()


def test_measured_winner_beats_heuristic_order():
    # fastest candidate in the middle of the ladder must win
    target = {8: 3.0, 4: 1.0, 2: 2.0, 1: 5.0}

    def measure(be):
        return target[be]

    be = autotune.pick_block_e(8, 4, jnp.float32, backend="tpu",
                               measure=measure)
    assert be == 4


# ---------------------------------------------------------------------------
# precision-policy keys: (storage, accum) dtype pairs must never collide
# ---------------------------------------------------------------------------

def test_block_keys_distinct_per_dtype_pair():
    """(bf16,f32), (bf16,f64), (f32,f32), (f32,f64): four distinct keys.

    A collision would hand a slab/block size tuned for one VMEM working set
    (accum dtype decides the resident bytes) to a different kernel.
    """
    calls = []

    def measure_factory(tag):
        def measure(be):
            calls.append((tag, be))
            return float(be)
        return measure

    pairs = [("bfloat16", None), ("bfloat16", "float64"),
             ("float32", None), ("float32", "float64")]
    for i, (storage, acc) in enumerate(pairs):
        autotune.pick_block_e(8, 4, jnp.dtype(storage), acc_dtype=acc,
                              backend="tpu", measure=measure_factory(i))
    # every pair measured independently (no cache hits across pairs) ...
    assert {t for t, _ in calls} == set(range(len(pairs)))
    # ... under four distinct keys
    assert len(autotune.cache_info()) == len(pairs)

    # explicit accum equal to the storage-derived default is the SAME key:
    # the resolved pair, not the spelling, is what identifies the kernel.
    def boom(be):
        raise AssertionError("resolved-identical pair must hit the cache")

    autotune.pick_block_e(8, 4, jnp.bfloat16, acc_dtype="float32",
                          backend="tpu", measure=boom)


def test_slab_keys_distinct_per_dtype_pair():
    seen = []

    def measure(sz):
        seen.append(sz)
        return float(sz)

    autotune.pick_slab_sz((2, 2, 8), 4, jnp.bfloat16, backend="tpu",
                          measure=measure)
    n1 = len(seen)
    autotune.pick_slab_sz((2, 2, 8), 4, jnp.bfloat16, acc_dtype="float64",
                          backend="tpu", measure=measure)
    assert len(seen) > n1              # distinct key -> re-measured
    keys = set(autotune.cache_info())
    assert ("slab", 4, 2, 2, 8, "bfloat16", "float32", "tpu") in keys
    assert ("slab", 4, 2, 2, 8, "bfloat16", "float64", "tpu") in keys


# ---------------------------------------------------------------------------
# slab mode (v2 pipeline)
# ---------------------------------------------------------------------------

def test_slab_candidates_divide_ez_and_fit_budget():
    for grid in ((2, 2, 8), (4, 8, 16), (1, 3, 5), (16, 16, 14)):
        for n in (4, 10):
            cands = autotune.candidate_slab_sizes(grid, n)
            assert cands, (grid, n)
            assert all(grid[2] % sz == 0 for sz in cands)
            assert cands == sorted(cands, reverse=True)
            assert cands[-1] == 1          # one slab is always viable
            ex, ey, _ = grid
            n3p = -(-(n ** 3) // 128) * 128
            # every candidate above the floor fits the working-set budget
            for sz in cands:
                if sz > 1:
                    assert (autotune._LIVE_ARRAYS * n3p * 4 * sz * ex * ey
                            <= autotune.VMEM_BUDGET_BYTES), (grid, n, sz)


def test_pick_slab_sz_cached_per_grid():
    calls = []

    def measure(sz):
        calls.append(sz)
        return float(sz)               # smallest "fastest": picks 1

    sz1 = autotune.pick_slab_sz((2, 2, 8), 4, jnp.float32, backend="tpu",
                                measure=measure)
    assert sz1 == 1
    n_calls = len(calls)
    assert n_calls == len(autotune.candidate_slab_sizes((2, 2, 8), 4))
    # same key: cached; different grid: distinct key
    autotune.pick_slab_sz((2, 2, 8), 4, jnp.float32, backend="tpu",
                          measure=measure)
    assert len(calls) == n_calls
    autotune.pick_slab_sz((2, 2, 4), 4, jnp.float32, backend="tpu",
                          measure=measure)
    assert len(calls) > n_calls
    assert (("slab", 4, 2, 2, 8, "float32", "float32", "tpu")
            in autotune.cache_info())


def test_slab_heuristic_on_cpu_prefers_largest():
    sz = autotune.pick_slab_sz((2, 2, 8), 4, jnp.float32, backend="cpu")
    assert sz == autotune.candidate_slab_sizes((2, 2, 8), 4)[0]


# ---------------------------------------------------------------------------
# disk persistence
# ---------------------------------------------------------------------------

def test_measured_pick_persists_and_reloads():
    def measure(be):
        return {8: 3.0, 4: 1.0, 2: 2.0, 1: 5.0}[be]

    be = autotune.pick_block_e(8, 4, jnp.float32, backend="tpu",
                               measure=measure)
    assert be == 4
    assert autotune.cache_path().exists()

    # simulate a fresh process: drop memory but keep the file
    autotune._CACHE.clear()
    autotune._DISK_LOADED = False

    def boom(be):
        raise AssertionError("disk-cached pick must not re-measure")

    be2 = autotune.pick_block_e(8, 4, jnp.float32, backend="tpu",
                                measure=boom)
    assert be2 == 4


def test_heuristic_pick_does_not_write_disk():
    autotune.pick_block_e(64, 10, jnp.float32, backend="cpu")
    assert not autotune.cache_path().exists()


def test_heuristic_picks_stay_out_of_measured_disk_cache():
    # a heuristic pick memoized before a measured one must not be persisted
    # alongside it — heuristic values recompute when the budget constants
    # change, so pinning them on disk would mask that.
    autotune.pick_block_e(64, 10, jnp.float32, backend="cpu")
    autotune.pick_block_e(8, 4, jnp.float32, backend="tpu",
                          measure=lambda be: float(be))
    data = json.loads(autotune.cache_path().read_text())
    keys = {tuple(e["key"]) for e in data["entries"]}
    assert keys == {(4, 8, "float32", "float32", "tpu")}


def test_sstep_candidates_shrink_with_s():
    """The joint (sz, s) working set: more powers -> deeper halo + more
    live basis vectors -> a lower VMEM ceiling on sz."""
    for grid in ((2, 2, 8), (4, 4, 16)):
        for n in (4, 10):
            prev_max = None
            for s in (1, 2, 4, 8):
                cands = autotune.candidate_slab_sizes_sstep(grid, n, s)
                assert cands, (grid, n, s)
                assert all(grid[2] % sz == 0 for sz in cands)
                assert cands[-1] == 1
                if prev_max is not None:
                    assert cands[0] <= prev_max, (grid, n, s)
                prev_max = cands[0]


def test_pick_slab_sz_sstep_keys_carry_s():
    """A pick for one s must never be reused for another — s changes the
    halo depth and the live basis count."""
    calls = []

    def measure(sz):
        calls.append(sz)
        return float(sz)

    sz_a = autotune.pick_slab_sz_sstep((2, 2, 8), 4, 2, jnp.float32,
                                       backend="tpu", measure=measure)
    assert sz_a == 1
    n_calls = len(calls)
    # same (grid, s): cached
    autotune.pick_slab_sz_sstep((2, 2, 8), 4, 2, jnp.float32,
                                backend="tpu", measure=measure)
    assert len(calls) == n_calls
    # different s: distinct key, fresh sweep
    autotune.pick_slab_sz_sstep((2, 2, 8), 4, 4, jnp.float32,
                                backend="tpu", measure=measure)
    assert len(calls) > n_calls
    info = autotune.cache_info()
    assert ("sstep", 4, 2, 2, 8, 2, "float32", "float32", "tpu") in info
    assert ("sstep", 4, 2, 2, 8, 4, "float32", "float32", "tpu") in info
    # and the sstep keys never collide with the plain slab keys
    autotune.pick_slab_sz((2, 2, 8), 4, jnp.float32, backend="tpu",
                          measure=measure)
    assert ("slab", 4, 2, 2, 8, "float32", "float32", "tpu") \
        in autotune.cache_info()


def test_cheb_candidates_shrink_with_k():
    """The Chebyshev-apply working set: deeper polynomial -> deeper halo
    -> a lower VMEM ceiling on sz (DESIGN.md §9.3)."""
    for grid in ((2, 2, 8), (4, 4, 16)):
        for n in (4, 10):
            prev_max = None
            for k in (1, 2, 4, 8):
                cands = autotune.candidate_slab_sizes_cheb(grid, n, k)
                assert cands, (grid, n, k)
                assert all(grid[2] % sz == 0 for sz in cands)
                assert cands[-1] == 1
                if prev_max is not None:
                    assert cands[0] <= prev_max, (grid, n, k)
                prev_max = cands[0]


def test_pick_slab_sz_cheb_keys_carry_k():
    """A pick for one Chebyshev order must never serve another — k sets
    the halo depth (the precond cache-key dimension)."""
    calls = []

    def measure(sz):
        calls.append(sz)
        return float(sz)

    sz_a = autotune.pick_slab_sz_cheb((2, 2, 8), 4, 2, jnp.float32,
                                      backend="tpu", measure=measure)
    assert sz_a == 1
    n_calls = len(calls)
    autotune.pick_slab_sz_cheb((2, 2, 8), 4, 2, jnp.float32,
                               backend="tpu", measure=measure)
    assert len(calls) == n_calls       # same (grid, k): cached
    autotune.pick_slab_sz_cheb((2, 2, 8), 4, 4, jnp.float32,
                               backend="tpu", measure=measure)
    assert len(calls) > n_calls        # different k: fresh sweep
    info = autotune.cache_info()
    assert ("cheb", 4, 2, 2, 8, 2, "float32", "float32", "tpu") in info
    assert ("cheb", 4, 2, 2, 8, 4, "float32", "float32", "tpu") in info


def test_pick_slab_sz_precond_key_dimension():
    """The PCG update kernel's pick is keyed apart from the plain v2 one
    (one extra live block array), and None keeps the pre-precond key."""
    calls = []

    def measure(sz):
        calls.append(sz)
        return float(sz)

    autotune.pick_slab_sz((2, 2, 8), 4, jnp.float32, backend="tpu",
                          measure=measure)
    n_plain = len(calls)
    autotune.pick_slab_sz((2, 2, 8), 4, jnp.float32, backend="tpu",
                          precond="jacobi", measure=measure)
    assert len(calls) > n_plain        # distinct key -> re-measured
    info = autotune.cache_info()
    assert ("slab", 4, 2, 2, 8, "float32", "float32", "tpu") in info
    assert ("slab", 4, 2, 2, 8, "float32", "float32", "tpu",
            "pc:jacobi") in info


def test_corrupt_cache_file_is_tolerated():
    path = autotune.cache_path()
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text("{ not json !!")

    calls = []

    def measure(be):
        calls.append(be)
        return float(be)

    be = autotune.pick_block_e(8, 4, jnp.float32, backend="tpu",
                               measure=measure)
    assert be == 1 and calls           # re-measured, no crash
    # and the rewritten file is valid JSON with the new entry
    data = json.loads(path.read_text())
    assert any(tuple(e["key"]) == (4, 8, "float32", "float32", "tpu")
               for e in data["entries"])


def test_clear_cache_removes_disk():
    def measure(be):
        return float(be)

    autotune.pick_block_e(8, 4, jnp.float32, backend="tpu", measure=measure)
    assert autotune.cache_path().exists()
    autotune.clear_cache()
    assert not autotune.cache_path().exists()
    assert not autotune.cache_info()


# ---------------------------------------------------------------------------
# joint (sz x layout x grid_order) configs + pipeline dispatch (DESIGN.md §11)
# ---------------------------------------------------------------------------

def test_candidate_configs_cover_the_sweep_space():
    from repro.kernels.nekbone_ax import GRID_ORDERS, LAYOUTS

    cands = autotune.candidate_configs([4, 2, 1])
    assert len(cands) == 3 * len(LAYOUTS) * len(GRID_ORDERS)
    assert len(set(cands)) == len(cands)
    # sz-major with the historical (fold, parallel) point first per sz,
    # so a measured tie keeps the established configuration
    assert cands[0] == (4, "fold", "parallel")
    assert cands[len(LAYOUTS) * len(GRID_ORDERS)] == (2, "fold", "parallel")


def test_pick_slab_config_heuristic_is_pre_sweep_point():
    def boom(sz, layout, grid_order):
        raise AssertionError("must not measure on cpu")

    cfg = autotune.pick_slab_config((2, 2, 8), 4, jnp.float32, backend="cpu")
    assert cfg == (autotune.candidate_slab_sizes((2, 2, 8), 4)[0],
                   "fold", "parallel")
    # heuristic picks stay memory-only (like the sz-only picks)
    assert not autotune.cache_path().exists()


def test_pick_slab_config_measured_winner_and_persistence():
    def measure(sz, layout, grid_order):
        # a non-default point must win: (2, dng, arbitrary)
        return 0.0 if (sz, layout, grid_order) == (2, "dng", "arbitrary") \
            else 1.0 + sz

    cfg = autotune.pick_slab_config((2, 2, 8), 4, jnp.float32,
                                    backend="tpu", measure=measure)
    assert cfg == (2, "dng", "arbitrary")
    assert autotune.cache_path().exists()

    # fresh process: reload from disk, tuple round-trips intact
    autotune._CACHE.clear()
    autotune._DISK_LOADED = False

    def boom(sz, layout, grid_order):
        raise AssertionError("disk-cached pick must not re-measure")

    cfg2 = autotune.pick_slab_config((2, 2, 8), 4, jnp.float32,
                                     backend="tpu", measure=boom)
    assert cfg2 == cfg
    assert isinstance(cfg2, tuple)


def test_cfg_keys_never_alias_sz_only_keys():
    """The joint picks live under a ("cfg", kind, ...) namespace: a
    measured sz-only pick and a joint pick for the same case must coexist
    under distinct keys."""
    autotune.pick_slab_sz((2, 2, 8), 4, jnp.float32, backend="tpu",
                          measure=lambda sz: float(sz))
    autotune.pick_slab_config((2, 2, 8), 4, jnp.float32, backend="tpu",
                              measure=lambda sz, ly, go: float(sz))
    info = autotune.cache_info()
    assert ("slab", 4, 2, 2, 8, "float32", "float32", "tpu") in info
    assert ("cfg", "slab", 4, 2, 2, 8, "float32", "float32", "tpu") in info


def test_cfg_keys_carry_s_k_and_precond_dimensions():
    calls = []

    def measure(sz, layout, grid_order):
        calls.append((sz, layout, grid_order))
        return float(sz)

    autotune.pick_sstep_config((2, 2, 8), 4, 2, jnp.float32,
                               backend="tpu", measure=measure)
    autotune.pick_sstep_config((2, 2, 8), 4, 4, jnp.float32,
                               backend="tpu", measure=measure)
    autotune.pick_cheb_config((2, 2, 8), 4, 2, jnp.float32,
                              backend="tpu", measure=measure)
    autotune.pick_slab_config((2, 2, 8), 4, jnp.float32, backend="tpu",
                              precond="jacobi", measure=measure)
    info = autotune.cache_info()
    assert ("cfg", "sstep", 4, 2, 2, 8, 2, "float32", "float32", "tpu") \
        in info
    assert ("cfg", "sstep", 4, 2, 2, 8, 4, "float32", "float32", "tpu") \
        in info
    assert ("cfg", "cheb", 4, 2, 2, 8, 2, "float32", "float32", "tpu") \
        in info
    assert ("cfg", "slab", 4, 2, 2, 8, "float32", "float32", "tpu",
            "pc:jacobi") in info


def test_pick_pipeline_heuristic_threshold():
    # below AUTO_V2_MIN_E the fixed v2 overhead is not amortized -> v1
    assert autotune.pick_pipeline((2, 2, 2), 4, backend="cpu") \
        == "pallas_fused_cg"
    assert autotune.pick_pipeline((4, 4, 4), 4, backend="cpu") \
        == "pallas_fused_cg_v2"
    # heuristic picks never reach the disk cache
    assert not autotune.cache_path().exists()


def test_pick_pipeline_preconditioned_always_v2():
    """The fused PCG drivers only exist in v2 — no measurement, no cache
    entry, any E."""
    before = len(autotune.cache_info())

    def boom(pipeline):
        raise AssertionError("precond dispatch must not measure")

    got = autotune.pick_pipeline((2, 2, 2), 4, backend="tpu",
                                 precond="jacobi", measure=boom)
    assert got == "pallas_fused_cg_v2"
    assert len(autotune.cache_info()) == before


def test_pick_pipeline_measured_winner_persists():
    def measure(pipeline):
        return 1.0 if pipeline == "pallas_fused_cg_v2" else 2.0

    got = autotune.pick_pipeline((4, 4, 8), 4, jnp.float32, backend="tpu",
                                 measure=measure)
    assert got == "pallas_fused_cg_v2"

    autotune._CACHE.clear()
    autotune._DISK_LOADED = False

    def boom(pipeline):
        raise AssertionError("disk-cached pipeline must not re-measure")

    assert autotune.pick_pipeline((4, 4, 8), 4, jnp.float32, backend="tpu",
                                  measure=boom) == "pallas_fused_cg_v2"
    # str values survive the JSON round-trip as str (not listified)
    assert isinstance(autotune.pick_pipeline((4, 4, 8), 4, jnp.float32,
                                             backend="tpu"), str)


def test_case_ax_impl_auto_resolves_and_records_request():
    from repro.core.nekbone import NekboneCase

    case = NekboneCase(n=3, grid=(2, 2, 2), dtype=jnp.float32,
                       ax_impl="auto")
    assert case.ax_impl_requested == "auto"
    assert case.ax_impl in ("pallas_fused_cg", "pallas_fused_cg_v2")
    # E=8 < AUTO_V2_MIN_E on the CPU heuristic -> v1
    if autotune.jax.default_backend() == "cpu":
        assert case.ax_impl == "pallas_fused_cg"
    big = NekboneCase(n=3, grid=(4, 4, 4), dtype=jnp.float32,
                      ax_impl="auto")
    assert big.ax_impl == "pallas_fused_cg_v2"
    # preconditioned auto: the fused PCG drivers force v2 at any E
    pc = NekboneCase(n=3, grid=(2, 2, 2), dtype=jnp.float32,
                     ax_impl="auto", precond="jacobi")
    assert pc.ax_impl == "pallas_fused_cg_v2"
