"""Local Poisson operator: implementation equivalence + SPD properties."""
import numpy as np
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core.ax import ax_local_fused, ax_local_listing1
from repro.core.geom import BoxMesh, random_spd_metric
from repro.core.gs import ds_sum_local
from repro.core.nekbone import NekboneCase
from repro.core.sem import derivative_matrix


def _rand_case(rng, n=6, grid=(2, 2, 2)):
    case = NekboneCase(n=n, grid=grid, dtype=jnp.float32)
    E = case.mesh.nelt
    u = jnp.asarray(rng.normal(size=(E, n, n, n)), jnp.float32)
    return case, ds_sum_local(u, grid) * case.mask


def test_listing1_equals_fused(rng):
    n, E = 8, 6
    u = jnp.asarray(rng.normal(size=(E, n, n, n)), jnp.float32)
    g = jnp.asarray(rng.normal(size=(E, 6, n, n, n)), jnp.float32)
    D = jnp.asarray(derivative_matrix(n), jnp.float32)
    w1 = ax_local_listing1(u, D, g)
    w2 = ax_local_fused(u, D, g)
    np.testing.assert_allclose(np.asarray(w1), np.asarray(w2),
                               rtol=1e-5, atol=1e-5)


@settings(deadline=None, max_examples=10)
@given(seed=st.integers(0, 2 ** 16), n=st.integers(3, 8))
def test_operator_spd_random_metric(seed, n):
    """With any SPD metric, u^T A u >= 0 and A is symmetric on the
    continuous subspace — the defining property of the weak Laplacian."""
    rng = np.random.default_rng(seed)
    E = 8
    g = jnp.asarray(random_spd_metric(rng, E, n), jnp.float32)
    D = jnp.asarray(derivative_matrix(n), jnp.float32)
    grid = (2, 2, 2)
    u = ds_sum_local(jnp.asarray(rng.normal(size=(E, n, n, n)), jnp.float32),
                     grid)
    v = ds_sum_local(jnp.asarray(rng.normal(size=(E, n, n, n)), jnp.float32),
                     grid)
    mesh = BoxMesh(n, grid)
    c = jnp.asarray(1.0 / mesh.multiplicity(), jnp.float32)

    def A(x):
        return ds_sum_local(ax_local_fused(x, D, g), grid)

    uau = float(jnp.sum(u * c * A(u)))
    vau = float(jnp.sum(v * c * A(u)))
    uav = float(jnp.sum(u * c * A(v)))
    scale = float(jnp.abs(A(u)).max()) + 1e-6
    assert uau >= -1e-3 * scale, "not PSD"
    assert abs(vau - uav) < 5e-3 * scale, "not symmetric"


def test_operator_kills_constants(rng):
    """A @ const = 0: the Laplacian of a constant field vanishes (before
    masking) — discrete conservation."""
    case = NekboneCase(n=6, grid=(2, 2, 2), dtype=jnp.float32)
    const = jnp.ones((case.mesh.nelt, 6, 6, 6), jnp.float32)
    w = case.ax_local(const)
    assert float(jnp.abs(w).max()) < 1e-4


def test_pallas_impl_in_case(rng):
    case_p = NekboneCase(n=10, grid=(2, 2, 2), dtype=jnp.float32,
                         ax_impl="pallas")
    case_f = NekboneCase(n=10, grid=(2, 2, 2), dtype=jnp.float32,
                         ax_impl="fused")
    u = jnp.asarray(rng.normal(size=(8, 10, 10, 10)), jnp.float32)
    np.testing.assert_allclose(np.asarray(case_p.ax_full(u)),
                               np.asarray(case_f.ax_full(u)),
                               rtol=2e-5, atol=2e-5)


def test_operator_diagonal_matches_probing():
    """Structural diag(A) == probing with unit vectors (small case)."""
    case = NekboneCase(n=3, grid=(2, 2, 2), dtype=jnp.float64)
    diag = case.operator_diagonal()
    E, n = case.mesh.nelt, case.n
    # probe a handful of entries
    idx = [(0, 0, 0, 0), (1, 1, 1, 1), (4, 2, 1, 0), (7, 2, 2, 2)]
    for e, k, j, i in idx:
        u = jnp.zeros((E, n, n, n), jnp.float64).at[e, k, j, i].set(1.0)
        a_col = ds_sum_local(case.ax_local(u), case.grid)
        got = float(a_col[e, k, j, i])
        want = float(diag[e, k, j, i])
        if case.mask[e, k, j, i] > 0:
            assert abs(got - want) < 1e-9 * max(1.0, abs(want))
