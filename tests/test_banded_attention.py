"""Banded (block-skipping) attention schedule — parity with the full scan.

These are the single-device halves of the §Perf Cell-A optimizations; the
multi-device halves (sequence sharding, halo exchange) are covered by
tests/distributed_checks.py.
"""
import numpy as np
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.models.attention import _chunked, _naive


@settings(deadline=None, max_examples=15)
@given(seed=st.integers(0, 2 ** 16),
       window=st.integers(2, 48),
       bq=st.sampled_from([8, 16]),
       bk=st.sampled_from([8, 16, 32]))
def test_banded_matches_naive(seed, window, bq, bk):
    rng = np.random.default_rng(seed)
    B, H, S, d = 1, 2, 64, 8
    q = jnp.asarray(rng.normal(size=(B, H, S, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, H, S, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, H, S, d)), jnp.float32)
    got = _chunked(q, k, v, causal=True, window=window, cap=None,
                   scale=d ** -0.5, q_offset=0, block_q=bq, block_k=bk)
    want = _naive(q, k, v, causal=True, window=window, cap=None,
                  scale=d ** -0.5, q_offset=0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


def test_banded_visits_fewer_blocks():
    """The banded schedule's HLO contains a shorter kv loop."""
    import jax

    from repro.launch.hlo_analysis import analyze_hlo

    B, H, S, d = 1, 2, 512, 16
    q = jax.ShapeDtypeStruct((B, H, S, d), jnp.float32)

    def run(window):
        return jax.jit(lambda q: _chunked(
            q, q, q, causal=True, window=window, cap=None, scale=1.0,
            q_offset=0, block_q=64, block_k=64)).lower(q).compile()

    flops_banded = analyze_hlo(run(64).as_text())["dot_flops"]
    flops_full = analyze_hlo(run(None).as_text())["dot_flops"]
    assert flops_banded < 0.45 * flops_full, (flops_banded, flops_full)


def test_halo_layout_matches_reference():
    """halo>0 path: kv laid out [halo | local] with absolute positions."""
    rng = np.random.default_rng(0)
    B, H, d = 1, 1, 8
    S, S_loc, window = 64, 16, 8
    q = jnp.asarray(rng.normal(size=(B, H, S, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, H, S, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, H, S, d)), jnp.float32)
    want = _naive(q, k, v, causal=True, window=window, cap=None,
                  scale=d ** -0.5, q_offset=0)
    halo = 8
    for shard in range(S // S_loc):
        lo = shard * S_loc
        q_l = q[:, :, lo:lo + S_loc]
        pad_k = jnp.pad(k, ((0, 0), (0, 0), (halo, 0), (0, 0)))
        pad_v = jnp.pad(v, ((0, 0), (0, 0), (halo, 0), (0, 0)))
        k_ext = pad_k[:, :, lo:lo + halo + S_loc]
        v_ext = pad_v[:, :, lo:lo + halo + S_loc]
        got = _chunked(q_l, k_ext, v_ext, causal=True, window=window,
                       cap=None, scale=d ** -0.5, q_offset=0,
                       q_shift=lo, halo=halo, block_q=8, block_k=8)
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(want[:, :, lo:lo + S_loc]),
                                   rtol=1e-4, atol=1e-5)
