"""Multi-RHS block CG (core/cg_block.py) + the SolveResult surface.

Pins the acceptance contract of the batched fast path (DESIGN.md §12):

* b=1 through ``cg_block_fixed_iters`` is fp64-BITWISE identical to
  ``cg_fused_v2_fixed_iters`` — the block kernels ARE the v2 arithmetic
  with a static batch loop, not a reimplementation;
* each lane of a b>1 batch matches its own independent single-RHS solve
  bitwise (the CG recurrences never mix lanes);
* the tolerance driver stops every lane at (or past) its target;
* ``SolveResult`` keeps the legacy ``x, hist = res`` two-tuple protocol
  and the CGResult attribute aliases while carrying the new named fields.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.cg import SolveResult
from repro.core.cg_block import cg_block_fixed_iters, cg_block_tol
from repro.core.cg_fused import cg_fused_v2_fixed_iters
from repro.core.gs import ds_sum_local
from repro.core.nekbone import NekboneCase


def _case64():
    return NekboneCase(n=5, grid=(2, 2, 4), dtype=jnp.float64,
                       ax_impl="pallas_fused_cg_v2")


def _masked_rhs(rng, case):
    u = jnp.asarray(rng.normal(size=case.mask.shape), case.dtype)
    return ds_sum_local(u, case.grid) * case.mask


def _kw(case, niter):
    return dict(D=case.D, g=case.g, grid=case.grid, niter=niter,
                mask=case.mask, c=case.c)


# ---------------------------------------------------------------------------
# bitwise parity
# ---------------------------------------------------------------------------

def test_b1_bitwise_parity_with_v2(x64):
    case = _case64()
    _, f = case.manufactured()
    niter = 12
    ref = cg_fused_v2_fixed_iters(f, **_kw(case, niter))
    res = cg_block_fixed_iters(f, **_kw(case, niter))     # 4-D lift, b=1
    np.testing.assert_array_equal(np.asarray(res.x[0]), np.asarray(ref.x))
    np.testing.assert_array_equal(np.asarray(res.history[0]),
                                  np.asarray(ref.history))
    assert res.pipeline == "fused_v2_rhs1"


@pytest.mark.parametrize("b", [2, 3])
def test_lanes_match_independent_solves(rng, x64, b):
    case = _case64()
    _, f0 = case.manufactured()
    lanes = [f0] + [_masked_rhs(rng, case) for _ in range(b - 1)]
    niter = 10
    res = cg_block_fixed_iters(jnp.stack(lanes), **_kw(case, niter))
    assert res.x.shape == (b,) + f0.shape
    assert res.history.shape == (b, niter + 1)
    for j in range(b):
        solo = cg_fused_v2_fixed_iters(lanes[j], **_kw(case, niter))
        np.testing.assert_array_equal(np.asarray(res.x[j]),
                                      np.asarray(solo.x))
        np.testing.assert_array_equal(np.asarray(res.history[j]),
                                      np.asarray(solo.history))


# ---------------------------------------------------------------------------
# tolerance driver
# ---------------------------------------------------------------------------

def test_tol_driver_converges_every_lane(x64):
    case = _case64()
    _, f = case.manufactured()
    B = jnp.stack([f, 0.5 * f])
    tol = 1e-8
    res = cg_block_tol(B, D=case.D, g=case.g, grid=case.grid, tol=tol,
                       max_iter=60, mask=case.mask, c=case.c)
    k = int(res.iters)
    assert 0 < k < 60
    # stopping rule is |rtz| > tol^2 checked before each iteration: at
    # exit every lane's rtz (= rnorm^2) is at or below tol^2.
    assert np.all(np.asarray(res.rnorm) <= tol)
    # scaling the rhs scales the whole (linear) trajectory: the two lanes
    # converge in lockstep and history stays per-lane.
    np.testing.assert_allclose(np.asarray(res.history[1, :k]),
                               0.5 * np.asarray(res.history[0, :k]),
                               rtol=1e-12)


def test_rejects_bad_rank(x64):
    case = _case64()
    _, f = case.manufactured()
    with pytest.raises(ValueError, match=r"\(b, E, n, n, n\)"):
        cg_block_fixed_iters(f[0], **_kw(case, 3))


# ---------------------------------------------------------------------------
# SolveResult surface
# ---------------------------------------------------------------------------

def test_solve_result_tuple_compat(x64):
    case = _case64()
    _, f = case.manufactured()
    res = case.solve(f, niter=5)
    assert isinstance(res, SolveResult)
    x, hist = res                       # legacy (x, hist) unpack
    assert x is res.x and hist is res.history
    assert len(res) == 2 and res[0] is res.x and res[1] is res.history
    # CGResult attribute aliases
    assert int(res.iters) == 5
    assert res.rnorm_history is res.history
    # named fields
    assert res.pipeline == "fused_v2"
    assert res.precond is None
    np.testing.assert_allclose(
        float(res.achieved_rtol),
        float(res.rnorm) / float(res.history[0]), rtol=1e-12)


def test_precond_boolean_removed(x64):
    """The deprecated booleans completed their cycle: TypeError now."""
    case = _case64()
    _, f = case.manufactured()
    with pytest.raises(TypeError, match="precond='jacobi'"):
        case.solve(f, niter=3, precond=True)
    case_pc = NekboneCase(n=5, grid=(2, 2, 4), dtype=jnp.float64,
                          ax_impl="pallas_fused_cg_v2", precond="jacobi")
    with pytest.raises(TypeError, match="removed"):
        case_pc.solve(f, niter=3, precond=False)
    # the registry-name spelling is the API that remains
    res = case_pc.solve(f, niter=3, precond="jacobi")
    assert res.precond == "jacobi"


def test_case_batched_solve_routes_to_block(x64):
    case = _case64()
    _, f = case.manufactured()
    res = case.solve(jnp.stack([f, 2.0 * f]), niter=6)
    assert res.pipeline == "fused_v2_rhs2"
    ref = case.solve(f, niter=6)
    np.testing.assert_array_equal(np.asarray(res.x[0]), np.asarray(ref.x))
