"""Step-fused CG pipeline: kernel partials + solver parity (DESIGN.md §3)."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import cg as cg_mod
from repro.core.ax import ax_local_fused
from repro.core.cg_fused import cg_fused_fixed_iters
from repro.core.gs import ds_sum_local
from repro.core.nekbone import NekboneCase
from repro.kernels import ops


def _continuous_field(rng, case):
    """A continuous, masked field — the CG invariant the pap identity needs."""
    u = jnp.asarray(rng.normal(size=case.mask.shape), case.dtype)
    return ds_sum_local(u, case.grid) * case.mask


# ---------------------------------------------------------------------------
# Kernel: masked Ax + partial dots vs the einsum reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,grid,block_e", [(4, (2, 2, 2), 4),
                                            (5, (2, 3, 2), 4),
                                            (6, (1, 2, 2), 2)])
def test_ax_dots_kernel_vs_reference(rng, x64, n, grid, block_e):
    case = NekboneCase(n=n, grid=grid, dtype=jnp.float64)
    p = _continuous_field(rng, case)
    r = jnp.asarray(rng.normal(size=case.mask.shape), jnp.float64)

    w, pap, rcz = ops.nekbone_ax_dots(p, case.D, case.g, case.mask, r,
                                      case.c, block_e=block_e, interpret=True)

    w_ref = ax_local_fused(p, case.D, case.g) * case.mask
    np.testing.assert_allclose(np.asarray(w), np.asarray(w_ref),
                               rtol=1e-12, atol=1e-12)

    # pap partial == p·c·Ap with the fully assembled operator (continuity
    # identity: gather-scatter transfers onto the continuous factor).
    Ap = ds_sum_local(ax_local_fused(p, case.D, case.g), case.grid) * case.mask
    pap_ref = float(jnp.sum(p * case.c * Ap))
    assert abs(float(pap) - pap_ref) <= 1e-12 * abs(pap_ref)

    rcz_ref = float(jnp.sum(r * case.c * r))
    assert abs(float(rcz) - rcz_ref) <= 1e-12 * abs(rcz_ref)


def test_ax_dots_padding_path(rng):
    """Non-divisible E: zero-padded blocks must not perturb the partials."""
    case = NekboneCase(n=4, grid=(1, 1, 3), dtype=jnp.float32)  # E = 3
    p = _continuous_field(rng, case)
    r = jnp.asarray(rng.normal(size=case.mask.shape), jnp.float32)
    w, pap, rcz = ops.nekbone_ax_dots(p, case.D, case.g, case.mask, r,
                                      case.c, block_e=2, interpret=True)
    assert w.shape == case.mask.shape
    w_ref = ax_local_fused(p, case.D, case.g) * case.mask
    scale = float(jnp.abs(w_ref).max()) + 1e-6
    np.testing.assert_allclose(np.asarray(w), np.asarray(w_ref),
                               atol=1e-5 * scale)
    rcz_ref = float(jnp.sum(r * case.c * r))
    assert abs(float(rcz) - rcz_ref) <= 1e-5 * abs(rcz_ref)


# ---------------------------------------------------------------------------
# Solver parity: fused CG vs cg_fixed_iters, fp64 interpret mode
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,grid,niter", [
    (4, (2, 2, 2), 10),
    (5, (2, 3, 2), 8),
    (10, (2, 2, 4), 5),     # the paper's degree (n=10, E=1024-class) scaled
])
def test_cg_fused_matches_fixed_iters_fp64(x64, n, grid, niter):
    case = NekboneCase(n=n, grid=grid, dtype=jnp.float64)
    _, f = case.manufactured()

    ref = cg_mod.cg_fixed_iters(case.ax_full, f, niter=niter, dot=case.dot())
    fused = cg_fused_fixed_iters(f, D=case.D, g=case.g, mask=case.mask,
                                 c=case.c, grid=case.grid, niter=niter,
                                 interpret=True)

    h_ref = np.asarray(ref.rnorm_history)
    h_fus = np.asarray(fused.rnorm_history)
    assert h_fus.shape == h_ref.shape
    # rtol pins the different summation association to fp64 round-off; the
    # atol floor covers entries that already converged to machine epsilon
    # relative to the initial residual.
    np.testing.assert_allclose(h_fus, h_ref, rtol=1e-12,
                               atol=1e-13 * h_ref[0])
    xs = np.abs(np.asarray(ref.x)).max() + 1e-300
    np.testing.assert_allclose(np.asarray(fused.x), np.asarray(ref.x),
                               atol=1e-12 * xs)


def test_cg_fused_through_case_fp32():
    """NekboneCase(ax_impl='pallas_fused_cg') dispatches fixed-iter solves to
    the fused pipeline and converges like the XLA path in fp32."""
    fused_case = NekboneCase(n=6, grid=(2, 2, 2), dtype=jnp.float32,
                             ax_impl="pallas_fused_cg")
    res, u_ex = fused_case.solve_manufactured(niter=40)
    assert int(res.iters) == 40
    hist = np.asarray(res.rnorm_history)
    assert np.isfinite(hist).all()
    assert hist[-1] < hist[0] * 1e-3, "fused CG must actually converge"

    xla_case = NekboneCase(n=6, grid=(2, 2, 2), dtype=jnp.float32,
                           ax_impl="fused")
    ref, _ = xla_case.solve_manufactured(niter=40)
    # fp32 trajectories drift once round-off accumulates through alpha/beta;
    # the early history must agree tightly (fp64 parity is pinned elsewhere),
    # late iterations only to within the drift envelope.
    h_ref = np.asarray(ref.rnorm_history)
    np.testing.assert_allclose(hist[:15], h_ref[:15], rtol=5e-3)
    np.testing.assert_allclose(hist, h_ref, rtol=0.5, atol=1e-4 * hist[0])
    # both reach the same discretization-limited solution accuracy
    err_f = float(fused_case.solution_error(res.x, u_ex))
    err_x = float(xla_case.solution_error(ref.x, u_ex))
    assert err_f <= err_x * 1.1 + 1e-6


def test_cg_fused_bf16_runs_and_converges():
    """bf16 fields with f32 in-kernel accumulation (the TPU target dtype):
    the fori_loop carry must stay bf16 despite f32 dot partials."""
    case = NekboneCase(n=4, grid=(2, 2, 2), dtype=jnp.bfloat16,
                       ax_impl="pallas_fused_cg")
    res, _ = case.solve_manufactured(niter=5)
    assert res.x.dtype == jnp.bfloat16
    hist = np.asarray(res.rnorm_history, np.float32)
    assert np.isfinite(hist).all()
    assert hist[-1] < hist[0]


def test_cg_fused_tol_and_precond_fall_back():
    """tol-driven and preconditioned solves route to the generic CG."""
    case = NekboneCase(n=4, grid=(2, 2, 2), dtype=jnp.float32,
                       ax_impl="pallas_fused_cg")
    res, _ = case.solve_manufactured(tol=1e-4, max_iter=100)
    assert int(res.iters) < 100
    res_pc, _ = case.solve_manufactured(niter=10, precond="jacobi")
    assert res_pc.rnorm_history.shape == (11,)
