"""v2 fused-CG pipeline: slab gather-scatter + merged update (DESIGN.md §3.4).

Three layers are pinned:

* the slab dots kernel's in-block direct-stiffness summation (+ host plane
  stitch) against ``ds_sum_local`` over randomized element grids — the
  assembly must be *bitwise* the same pair sums;
* the merged vector-update kernel against the XLA axpy reference, including
  the cross-block plane corrections;
* the whole ``cg_fused_v2_fixed_iters`` against ``cg_fixed_iters`` to fp64
  round-off in interpret mode, plus fp32/bf16 behaviour through
  ``NekboneCase(ax_impl='pallas_fused_cg_v2')``.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import cg as cg_mod
from repro.core.ax import ax_local_fused
from repro.core.cg_fused import cg_fused_v2_fixed_iters
from repro.core.gs import ds_sum_local
from repro.core.nekbone import NekboneCase
from repro.kernels import ops


def _continuous_field(rng, case):
    """A continuous, masked field — the CG invariant the pap identity needs."""
    u = jnp.asarray(rng.normal(size=case.mask.shape), case.dtype)
    return ds_sum_local(u, case.grid) * case.mask


def _random_slab_setup(seed):
    """Randomized (EX, EY, EZ, n, sz) with sz a divisor of EZ."""
    r = np.random.default_rng(seed)
    grid = tuple(int(v) for v in r.integers(1, 4, size=3))
    n = int(r.integers(3, 7))
    divisors = [d for d in range(1, grid[2] + 1) if grid[2] % d == 0]
    sz = int(r.choice(divisors))
    return grid, n, sz


# ---------------------------------------------------------------------------
# Slab kernel: in-block assembly + plane stitch vs ds_sum_local
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1, 2, 3, 4])
def test_slab_assembly_matches_ds_sum_local(rng, x64, seed):
    grid, n, sz = _random_slab_setup(seed)
    case = NekboneCase(n=n, grid=grid, dtype=jnp.float64)
    p = _continuous_field(rng, case)

    # beta = 0 makes the kernel's direction p == r; the zeros passed as
    # p_prev must not leak through.
    p_out, w, pap = ops.nekbone_ax_dots_slab(
        jnp.zeros_like(p), p, case.D, case.g, grid, beta=0.0, sz=sz,
        interpret=True)

    np.testing.assert_array_equal(np.asarray(p_out), np.asarray(p))
    # the in-kernel gather-scatter performs the same pair sums as the
    # reference assembly; round-off tolerance only covers the operator's
    # matmul-vs-einsum contraction order.
    w_ref = ds_sum_local(ax_local_fused(p, case.D, case.g) * case.mask, grid)
    scale = float(np.abs(np.asarray(w_ref)).max()) + 1e-300
    np.testing.assert_allclose(np.asarray(w), np.asarray(w_ref),
                               rtol=1e-12, atol=1e-12 * scale,
                               err_msg=f"{grid=} {n=} {sz=}")
    # continuity identity: pap partials (pre-assembly) sum to p·c·Ap.
    pap_ref = float(jnp.sum(p * case.c * w_ref))
    assert abs(float(pap) - pap_ref) <= 1e-12 * max(abs(pap_ref), 1e-30)


def test_slab_beta_folds_direction_update(rng, x64):
    """p = r + beta * p_prev inside the kernel, exactly."""
    grid, n, sz = (2, 2, 4), 4, 2
    case = NekboneCase(n=n, grid=grid, dtype=jnp.float64)
    p_prev = _continuous_field(rng, case)
    r = _continuous_field(rng, case)
    beta = 0.73
    p_out, w, pap = ops.nekbone_ax_dots_slab(
        p_prev, r, case.D, case.g, grid, beta=beta, sz=sz, interpret=True)
    p_ref = r + beta * p_prev
    np.testing.assert_allclose(np.asarray(p_out), np.asarray(p_ref),
                               rtol=1e-15, atol=1e-15)
    w_ref = ds_sum_local(ax_local_fused(p_ref, case.D, case.g) * case.mask,
                         grid)
    np.testing.assert_allclose(np.asarray(w), np.asarray(w_ref),
                               rtol=1e-12, atol=1e-12)


# ---------------------------------------------------------------------------
# Merged vector-update kernel vs the XLA axpy reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("grid,n,sz", [((2, 3, 4), 4, 2), ((1, 2, 3), 5, 1),
                                       ((2, 2, 2), 3, 2)])
def test_update_kernel_vs_xla_reference(rng, x64, grid, n, sz):
    ex, ey, ez = grid
    case = NekboneCase(n=n, grid=grid, dtype=jnp.float64)
    E = case.mesh.nelt
    shp = (E, n, n, n)
    x, p, r, w = (jnp.asarray(rng.normal(size=shp), jnp.float64)
                  for _ in range(4))
    nblk = ez // sz
    pln = ey * ex * n * n
    addb = jnp.asarray(rng.normal(size=(nblk, pln)), jnp.float64)
    addt = jnp.asarray(rng.normal(size=(nblk, pln)), jnp.float64)
    alpha = 0.37

    x2, r2, rtz = ops.nekbone_cg_update(x, p, r, w, alpha, grid,
                                        addb=addb, addt=addt, sz=sz,
                                        interpret=True)

    # reference: stitch the planes into w, then the two axpys + weighted norm
    vb = np.asarray(w).reshape(nblk, sz, ey, ex, n, n, n).copy()
    vb[:, 0, :, :, 0, :, :] += np.asarray(addb).reshape(nblk, ey, ex, n, n)
    vb[:, -1, :, :, -1, :, :] += np.asarray(addt).reshape(nblk, ey, ex, n, n)
    w_full = vb.reshape(shp)
    x_ref = np.asarray(x) + alpha * np.asarray(p)
    r_ref = np.asarray(r) - alpha * w_full
    rtz_ref = float(np.sum(r_ref * np.asarray(case.c) * r_ref))

    np.testing.assert_allclose(np.asarray(x2), x_ref, rtol=1e-15, atol=1e-15)
    np.testing.assert_allclose(np.asarray(r2), r_ref, rtol=1e-14, atol=1e-14)
    assert abs(float(rtz) - rtz_ref) <= 1e-12 * abs(rtz_ref)


# ---------------------------------------------------------------------------
# Solver parity: v2 fused CG vs cg_fixed_iters, fp64 interpret mode
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,grid,niter", [
    (4, (2, 2, 2), 10),
    (5, (2, 3, 2), 8),
    (10, (2, 2, 4), 5),     # the paper's degree (n=10, E=1024-class) scaled
])
def test_cg_fused_v2_matches_fixed_iters_fp64(x64, n, grid, niter):
    case = NekboneCase(n=n, grid=grid, dtype=jnp.float64)
    _, f = case.manufactured()

    ref = cg_mod.cg_fixed_iters(case.ax_full, f, niter=niter, dot=case.dot())
    fused = cg_fused_v2_fixed_iters(f, D=case.D, g=case.g, grid=case.grid,
                                    niter=niter, mask=case.mask, c=case.c,
                                    interpret=True)

    h_ref = np.asarray(ref.rnorm_history)
    h_fus = np.asarray(fused.rnorm_history)
    assert h_fus.shape == h_ref.shape
    # rtol pins the different summation association to fp64 round-off; the
    # atol floor covers entries that already converged to machine epsilon
    # relative to the initial residual.
    np.testing.assert_allclose(h_fus, h_ref, rtol=1e-12,
                               atol=1e-13 * h_ref[0])
    xs = np.abs(np.asarray(ref.x)).max() + 1e-300
    np.testing.assert_allclose(np.asarray(fused.x), np.asarray(ref.x),
                               atol=1e-12 * xs)


@pytest.mark.parametrize("sz", [1, 2, 4])
def test_cg_fused_v2_invariant_to_slab_split_fp64(x64, sz):
    """The slab split changes only the partial-sum association."""
    case = NekboneCase(n=4, grid=(2, 2, 4), dtype=jnp.float64)
    _, f = case.manufactured()
    ref = cg_mod.cg_fixed_iters(case.ax_full, f, niter=6, dot=case.dot())
    fused = cg_fused_v2_fixed_iters(f, D=case.D, g=case.g, grid=case.grid,
                                    niter=6, sz=sz, interpret=True)
    np.testing.assert_allclose(np.asarray(fused.rnorm_history),
                               np.asarray(ref.rnorm_history), rtol=1e-12,
                               atol=1e-13 * float(ref.rnorm_history[0]))


def test_cg_fused_v2_through_case_fp32():
    """NekboneCase(ax_impl='pallas_fused_cg_v2') dispatches fixed-iter solves
    to the two-kernel pipeline and converges like the XLA path in fp32."""
    fused_case = NekboneCase(n=6, grid=(2, 2, 2), dtype=jnp.float32,
                             ax_impl="pallas_fused_cg_v2")
    res, u_ex = fused_case.solve_manufactured(niter=40)
    assert int(res.iters) == 40
    hist = np.asarray(res.rnorm_history)
    assert np.isfinite(hist).all()
    assert hist[-1] < hist[0] * 1e-3, "v2 fused CG must actually converge"

    xla_case = NekboneCase(n=6, grid=(2, 2, 2), dtype=jnp.float32,
                           ax_impl="fused")
    ref, _ = xla_case.solve_manufactured(niter=40)
    h_ref = np.asarray(ref.rnorm_history)
    # fp32 trajectories drift once round-off accumulates through alpha/beta;
    # early history must agree tightly (fp64 parity is pinned above).
    np.testing.assert_allclose(hist[:15], h_ref[:15], rtol=5e-3)
    np.testing.assert_allclose(hist, h_ref, rtol=0.5, atol=1e-4 * hist[0])
    err_f = float(fused_case.solution_error(res.x, u_ex))
    err_x = float(xla_case.solution_error(ref.x, u_ex))
    assert err_f <= err_x * 1.1 + 1e-6


def test_cg_fused_v2_bf16_runs_and_converges():
    """bf16 fields with f32 in-kernel accumulation (the TPU target dtype)."""
    case = NekboneCase(n=4, grid=(2, 2, 2), dtype=jnp.bfloat16,
                       ax_impl="pallas_fused_cg_v2")
    res, _ = case.solve_manufactured(niter=5)
    assert res.x.dtype == jnp.bfloat16
    hist = np.asarray(res.rnorm_history, np.float32)
    assert np.isfinite(hist).all()
    assert hist[-1] < hist[0]


# ---------------------------------------------------------------------------
# Guard rails: the v2 path must refuse non-box fields
# ---------------------------------------------------------------------------

def test_cg_fused_v2_rejects_foreign_mask():
    case = NekboneCase(n=4, grid=(2, 2, 2), dtype=jnp.float32)
    _, f = case.manufactured()
    bad_mask = case.mask.at[0, 1, 1, 1].set(0.0)   # interior node masked
    with pytest.raises(ValueError, match="structured box mask"):
        cg_fused_v2_fixed_iters(f, D=case.D, g=case.g, grid=case.grid,
                                niter=2, mask=bad_mask, interpret=True)


def test_cg_fused_v2_rejects_nondiagonal_metric(rng):
    from repro.core.geom import random_spd_metric

    case = NekboneCase(n=4, grid=(2, 2, 2), dtype=jnp.float32)
    _, f = case.manufactured()
    g_bad = jnp.asarray(random_spd_metric(rng, case.mesh.nelt, 4),
                        jnp.float32)
    with pytest.raises(ValueError, match="axis-aligned"):
        cg_fused_v2_fixed_iters(f, D=case.D, g=g_bad, grid=case.grid,
                                niter=2, interpret=True)


def test_cg_fused_v2_tol_and_precond_stay_fused():
    """tol-driven and preconditioned v2 solves route to the fused drivers
    (core/precond.py, DESIGN.md §9) — no fall-back to the XLA path."""
    case = NekboneCase(n=4, grid=(2, 2, 2), dtype=jnp.float32,
                       ax_impl="pallas_fused_cg_v2")
    res, _ = case.solve_manufactured(tol=1e-4, max_iter=100)
    assert int(res.iters) < 100
    assert float(res.rnorm) <= 1e-4
    assert res.rnorm_history.shape == (101,)      # padded to max_iter + 1
    res_pc, _ = case.solve_manufactured(niter=10, precond="jacobi")
    assert res_pc.rnorm_history.shape == (11,)
    assert np.isfinite(np.asarray(res_pc.rnorm_history,
                                  np.float64)).all()
