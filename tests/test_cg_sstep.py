"""v3 s-step CG: matrix-powers kernel + multi-axpy update (DESIGN.md §8).

Four layers are pinned:

* the matrix-powers kernel's basis against repeated applications of the
  reference assembled operator — including the halo correctness claim:
  blocks with s ghost slabs emit *fully assembled* owned basis vectors
  (no plane side channel), over randomized grids and slab splits;
* the in-kernel Gram partials against the host-side ``V^T C V``;
* the multi-axpy update kernel against the XLA linear-combination
  reference;
* the whole ``cg_sstep_fixed_iters`` against ``cg_fixed_iters`` to fp64
  round-off for s <= 4, the s=1 degeneracy, remainder cycles, precision
  policies, and the ``NekboneCase(ax_impl='pallas_sstep_v3')`` dispatch.

History caveat (tested where it bites): in-cycle residual norms are f64
Gram quadratic forms ``b' G b`` — exact-arithmetic equal to the device
reduction but floored near ``eps * (basis scale / |r_j|)`` relative once
the residual has dropped many orders *within one cycle*.  Parity cases
therefore use pre-asymptotic iteration counts, as the v2 suite does; the
returned ``x`` is pinned independently (it re-anchors every cycle).
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import cg as cg_mod
from repro.core.ax import ax_local_fused
from repro.core.cg_sstep import cg_sstep_fixed_iters, sstep_recurrence
from repro.core.gs import ds_sum_local
from repro.core.nekbone import NekboneCase
from repro.kernels import ops


def _continuous_field(rng, case):
    u = jnp.asarray(rng.normal(size=case.mask.shape), case.dtype)
    return ds_sum_local(u, case.grid) * case.mask


def _apply_a_ref(case, v):
    """Reference assembled masked operator (the basis ground truth)."""
    return ds_sum_local(ax_local_fused(v, case.D, case.g), case.grid) \
        * case.mask


def _random_setup(seed):
    r = np.random.default_rng(seed)
    grid = tuple(int(v) for v in r.integers(1, 4, size=3))
    n = int(r.integers(3, 6))
    divisors = [d for d in range(1, grid[2] + 1) if grid[2] % d == 0]
    sz = int(r.choice(divisors))
    s = int(r.choice([1, 2, 3, 4]))
    return grid, n, sz, s


# ---------------------------------------------------------------------------
# Matrix-powers kernel: basis + Gram vs the reference operator
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1, 2, 3, 4])
def test_powers_basis_matches_operator_chain(rng, x64, seed):
    grid, n, sz, s = _random_setup(seed)
    case = NekboneCase(n=n, grid=grid, dtype=jnp.float64)
    p = _continuous_field(rng, case)
    r = _continuous_field(rng, case)
    theta = 2.25          # exact binary: scaling must be exactly invertible

    basis, gram = ops.nekbone_ax_powers(p, r, case.D, case.g, case.grid,
                                        s=s, theta=theta, sz=sz,
                                        interpret=True)
    assert basis.shape == (case.mesh.nelt, 2 * s - 1, n, n, n)

    # reference: the same scaled chain through the assembled operator;
    # the owned outputs must be *fully* assembled (the halo replaces the
    # v2 plane side channel — this is the matrix-powers correctness claim)
    want = []
    v = p
    for _ in range(s):
        v = _apply_a_ref(case, v) / theta
        want.append(v)
    v = r
    for _ in range(s - 1):
        v = _apply_a_ref(case, v) / theta
        want.append(v)
    for m, w_ref in enumerate(want):
        scale = float(np.abs(np.asarray(w_ref)).max()) + 1e-300
        np.testing.assert_allclose(
            np.asarray(basis[:, m]), np.asarray(w_ref), rtol=1e-12,
            atol=1e-12 * scale,
            err_msg=f"{grid=} {n=} {sz=} {s=} basis[{m}]")

    # Gram partials: V^T C V over [p, powers, r, r-powers]
    V = [p] + want[:s] + [r] + want[s:]
    K = 2 * s + 1
    G_ref = np.zeros((K, K))
    c = np.asarray(case.c, np.float64)
    for a in range(K):
        for b_ in range(K):
            G_ref[a, b_] = float(np.sum(np.asarray(V[a], np.float64) * c
                                        * np.asarray(V[b_], np.float64)))
    scale = np.abs(G_ref).max()
    np.testing.assert_allclose(np.asarray(gram), G_ref, rtol=1e-11,
                               atol=1e-12 * scale)


def test_powers_halo_is_invariant_to_slab_split(rng, x64):
    """sz only changes the block decomposition (and the redundant halo
    work) — the emitted basis must be identical."""
    case = NekboneCase(n=4, grid=(2, 2, 4), dtype=jnp.float64)
    p = _continuous_field(rng, case)
    r = _continuous_field(rng, case)
    b1, g1 = ops.nekbone_ax_powers(p, r, case.D, case.g, case.grid, s=3,
                                   sz=1, interpret=True)
    b4, g4 = ops.nekbone_ax_powers(p, r, case.D, case.g, case.grid, s=3,
                                   sz=4, interpret=True)
    scale = float(np.abs(np.asarray(b4)).max()) + 1e-300
    np.testing.assert_allclose(np.asarray(b1), np.asarray(b4),
                               rtol=1e-12, atol=1e-13 * scale)
    gs = np.abs(np.asarray(g4)).max()
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g4),
                               rtol=1e-12, atol=1e-13 * gs)


# ---------------------------------------------------------------------------
# Multi-axpy update kernel vs the XLA reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("grid,n,sz,s", [((2, 3, 4), 4, 2, 2),
                                         ((1, 2, 3), 5, 1, 4),
                                         ((2, 2, 2), 3, 2, 1)])
def test_sstep_update_vs_xla_reference(rng, x64, grid, n, sz, s):
    case = NekboneCase(n=n, grid=grid, dtype=jnp.float64)
    E = case.mesh.nelt
    shp = (E, n, n, n)
    x, p, r = (jnp.asarray(rng.normal(size=shp), jnp.float64)
               for _ in range(3))
    basis = jnp.asarray(rng.normal(size=(E, 2 * s - 1, n, n, n)),
                        jnp.float64)
    K = 2 * s + 1
    coef = rng.normal(size=(3, K))

    x2, r2, p2, rcr = ops.nekbone_sstep_update(x, p, r, basis, coef,
                                               grid, s=s, sz=sz,
                                               interpret=True)

    # reference: V columns in kernel order [p, A'p.., r, A'r..]
    V = ([np.asarray(p)]
         + [np.asarray(basis[:, m]) for m in range(s)]
         + [np.asarray(r)]
         + [np.asarray(basis[:, s + m]) for m in range(s - 1)])
    x_ref = np.asarray(x) + sum(coef[0, k] * V[k] for k in range(K))
    r_ref = sum(coef[1, k] * V[k] for k in range(K))
    p_ref = sum(coef[2, k] * V[k] for k in range(K))
    rcr_ref = float(np.sum(r_ref * np.asarray(case.c) * r_ref))

    np.testing.assert_allclose(np.asarray(x2), x_ref, rtol=1e-13,
                               atol=1e-13)
    np.testing.assert_allclose(np.asarray(r2), r_ref, rtol=1e-13,
                               atol=1e-13)
    np.testing.assert_allclose(np.asarray(p2), p_ref, rtol=1e-13,
                               atol=1e-13)
    assert abs(rcr - rcr_ref) <= 1e-11 * max(abs(rcr_ref), 1e-30)


# ---------------------------------------------------------------------------
# Host recurrence: coefficient algebra in f64
# ---------------------------------------------------------------------------

def test_recurrence_matches_explicit_cg_on_small_system(rng):
    """On an explicit SPD matrix the coefficient recurrence reproduces
    textbook CG exactly (same f64 arithmetic, coefficient coordinates)."""
    N, s = 12, 4
    A0 = rng.normal(size=(N, N))
    A = A0 @ A0.T + N * np.eye(N)
    b = rng.normal(size=N)
    theta = float(np.linalg.norm(A, 2))

    # explicit CG, s steps
    x = np.zeros(N)
    r = b.copy()
    p = r.copy()
    rtz_hist = []
    for _ in range(s):
        rtz = r @ r
        rtz_hist.append(rtz)
        Ap = A @ p
        alpha = rtz / (p @ Ap)
        x = x + alpha * p
        r = r - alpha * Ap
        beta = (r @ r) / rtz
        p = r + beta * p

    # s-step coordinates: V = [p0, A'p0.., r0, A'r0..] with p0 = r0 = b
    V = [b]
    v = b
    for _ in range(s):
        v = A @ v / theta
        V.append(v)
    V += [b]
    v = b
    for _ in range(s - 1):
        v = A @ v / theta
        V.append(v)
    Vm = np.stack(V, axis=1)              # (N, 2s+1)
    G = Vm.T @ Vm                         # C = I
    e_c, b_c, a_c, hist = sstep_recurrence(G, s, s, theta)
    np.testing.assert_allclose(hist, rtz_hist, rtol=1e-10)
    np.testing.assert_allclose(Vm @ e_c, x, rtol=1e-9, atol=1e-12)
    np.testing.assert_allclose(Vm @ b_c, r, rtol=1e-8, atol=1e-10)
    np.testing.assert_allclose(Vm @ a_c, p, rtol=1e-8, atol=1e-10)


# ---------------------------------------------------------------------------
# Solver parity: s-step CG vs cg_fixed_iters, fp64 interpret mode
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,grid,niter,s", [
    (4, (2, 2, 2), 10, 1),
    (4, (2, 2, 4), 10, 2),
    (5, (2, 3, 2), 8, 4),
    (10, (2, 2, 4), 5, 4),  # the paper's degree, scaled; partial cycle
])
def test_cg_sstep_matches_fixed_iters_fp64(x64, n, grid, niter, s):
    case = NekboneCase(n=n, grid=grid, dtype=jnp.float64)
    _, f = case.manufactured()

    ref = cg_mod.cg_fixed_iters(case.ax_full, f, niter=niter,
                                dot=case.dot())
    got = cg_sstep_fixed_iters(f, D=case.D, g=case.g, grid=case.grid,
                               niter=niter, s=s, mask=case.mask, c=case.c,
                               interpret=True)
    h_ref = np.asarray(ref.rnorm_history)
    h = np.asarray(got.rnorm_history)
    assert h.shape == h_ref.shape
    # fp64 round-off through the Gram quadratic forms; pre-asymptotic
    # iteration counts keep the in-cycle cancellation floor (module
    # docstring) below this budget.
    np.testing.assert_allclose(h, h_ref, rtol=1e-9, atol=1e-11 * h_ref[0])
    xs = np.abs(np.asarray(ref.x)).max() + 1e-300
    np.testing.assert_allclose(np.asarray(got.x), np.asarray(ref.x),
                               atol=1e-10 * xs)


def test_cg_sstep_s1_matches_v2_trajectory(x64):
    """s=1 is the degeneracy point: same per-iteration algebra as the v2
    pipeline (and the same 13-stream budget, pinned in test_cost_model)."""
    from repro.core.cg_fused import cg_fused_v2_fixed_iters

    case = NekboneCase(n=4, grid=(2, 2, 4), dtype=jnp.float64)
    _, f = case.manufactured()
    v2 = cg_fused_v2_fixed_iters(f, D=case.D, g=case.g, grid=case.grid,
                                 niter=8, interpret=True)
    v3 = cg_sstep_fixed_iters(f, D=case.D, g=case.g, grid=case.grid,
                              niter=8, s=1, interpret=True)
    h2 = np.asarray(v2.rnorm_history)
    np.testing.assert_allclose(np.asarray(v3.rnorm_history), h2,
                               rtol=1e-10, atol=1e-12 * h2[0])


def test_cg_sstep_remainder_cycle(x64):
    """niter not divisible by s: the final cycle advances niter % s steps."""
    case = NekboneCase(n=4, grid=(2, 2, 2), dtype=jnp.float64)
    _, f = case.manufactured()
    ref = cg_mod.cg_fixed_iters(case.ax_full, f, niter=7, dot=case.dot())
    got = cg_sstep_fixed_iters(f, D=case.D, g=case.g, grid=case.grid,
                               niter=7, s=4, interpret=True)
    assert got.rnorm_history.shape == (8,)
    assert int(got.iters) == 7
    h_ref = np.asarray(ref.rnorm_history)
    np.testing.assert_allclose(np.asarray(got.rnorm_history), h_ref,
                               rtol=1e-9, atol=1e-11 * h_ref[0])


def test_cg_sstep_invariant_to_slab_split(x64):
    case = NekboneCase(n=4, grid=(2, 2, 4), dtype=jnp.float64)
    _, f = case.manufactured()
    h = [np.asarray(cg_sstep_fixed_iters(
        f, D=case.D, g=case.g, grid=case.grid, niter=6, s=2, sz=sz,
        interpret=True).rnorm_history) for sz in (1, 2, 4)]
    np.testing.assert_allclose(h[1], h[0], rtol=1e-11, atol=1e-13 * h[0][0])
    np.testing.assert_allclose(h[2], h[0], rtol=1e-11, atol=1e-13 * h[0][0])


# ---------------------------------------------------------------------------
# Case dispatch + precision policies
# ---------------------------------------------------------------------------

def test_cg_sstep_through_case_fp32():
    fused_case = NekboneCase(n=6, grid=(2, 2, 2), dtype=jnp.float32,
                             ax_impl="pallas_sstep_v3", s=4)
    res, u_ex = fused_case.solve_manufactured(niter=40)
    assert int(res.iters) == 40
    hist = np.asarray(res.rnorm_history, np.float64)
    assert np.isfinite(hist).all()
    assert hist[-1] < hist[0] * 1e-3, "s-step CG must actually converge"

    xla_case = NekboneCase(n=6, grid=(2, 2, 2), dtype=jnp.float32,
                           ax_impl="fused")
    ref, _ = xla_case.solve_manufactured(niter=40)
    h_ref = np.asarray(ref.rnorm_history, np.float64)
    # early history must track the XLA path tightly; the trajectories fork
    # sooner than v2's do — f32-stored monomial powers amplify round-off
    # by kappa^{s} within a cycle (DESIGN.md §8's stability budget), which
    # is round-off *noise*, not divergence: convergence above and the
    # solution floor below pin the asymptote.
    np.testing.assert_allclose(hist[:12], h_ref[:12], rtol=5e-3)
    err_f = float(fused_case.solution_error(res.x, u_ex))
    err_x = float(xla_case.solution_error(ref.x, u_ex))
    assert err_f <= max(10.0 * err_x, 2e-5)


def test_cg_sstep_bf16_runs_and_converges():
    case = NekboneCase(n=4, grid=(2, 2, 2), dtype=jnp.bfloat16,
                       ax_impl="pallas_sstep_v3", s=2)
    res, _ = case.solve_manufactured(niter=6)
    assert res.x.dtype == jnp.bfloat16
    hist = np.asarray(res.rnorm_history, np.float32)
    assert np.isfinite(hist).all()
    assert hist[-1] < hist[0]


def test_cg_sstep_precision_policy_dtypes():
    """bf16 policy: storage-width basis/vectors, f32 Gram partials, and
    the x carry in the policy's x-storage dtype."""
    case = NekboneCase(n=4, grid=(2, 2, 2), dtype=jnp.float32)
    _, f = case.manufactured()
    res = cg_sstep_fixed_iters(f, D=case.D, g=case.g, grid=case.grid,
                               niter=4, s=2, interpret=True,
                               precision="bf16")
    assert res.x.dtype == jnp.bfloat16
    assert np.isfinite(np.asarray(res.rnorm_history, np.float32)).all()


def test_cg_sstep_ir_composition():
    """cg_ir_fixed_iters(variant='sstep'): s-step sweeps inside iterative
    refinement — outer residuals must compound downward."""
    from repro.core.cg_fused import cg_ir_fixed_iters

    case = NekboneCase(n=4, grid=(2, 2, 4), dtype=jnp.float32)
    _, f = case.manufactured()
    ir = cg_ir_fixed_iters(f, D=case.D, g=case.g, grid=case.grid,
                           niter=10, precision="bf16_ir", outer_iters=2,
                           variant="sstep", s=2, interpret=True)
    h = np.asarray(ir.rnorm_history, np.float64)
    assert h.shape == (3,)
    assert h[-1] < h[0] * 1e-1


def test_cg_sstep_rejects_bad_inputs():
    case = NekboneCase(n=4, grid=(2, 2, 2), dtype=jnp.float32)
    _, f = case.manufactured()
    with pytest.raises(ValueError, match="s >= 1"):
        cg_sstep_fixed_iters(f, D=case.D, g=case.g, grid=case.grid,
                             niter=2, s=0, interpret=True)
    bad_mask = case.mask.at[0, 1, 1, 1].set(0.0)
    with pytest.raises(ValueError, match="structured box mask"):
        cg_sstep_fixed_iters(f, D=case.D, g=case.g, grid=case.grid,
                             niter=2, s=2, mask=bad_mask, interpret=True)


def test_cg_sstep_tol_and_precond_fall_back():
    """tol-driven and preconditioned solves route to the generic CG."""
    case = NekboneCase(n=4, grid=(2, 2, 2), dtype=jnp.float32,
                       ax_impl="pallas_sstep_v3")
    res, _ = case.solve_manufactured(tol=1e-4, max_iter=100)
    assert int(res.iters) < 100
    res_pc, _ = case.solve_manufactured(niter=10, precond="jacobi")
    assert res_pc.rnorm_history.shape == (11,)
