"""The CI perf-regression gate (benchmarks/check_regression.py) and the
bench driver's atomic JSON write (benchmarks/run.py)."""
import json
import os
import pathlib
import stat

import pytest

from benchmarks import run as bench_run
from benchmarks.check_regression import compare, load_bench_json, main

BASELINE = (pathlib.Path(__file__).parent.parent / "benchmarks" /
            "baseline" / "BENCH_baseline.json")
_BASELINE_DATA = json.loads(BASELINE.read_text())


def _payload(**overrides):
    # the analytic tables come from the live helpers (so the test fails
    # when run.py and the cost model drift apart); the measured us/iter
    # rows and their backend are mirrored from the committed baseline —
    # a synthetic payload has no wall clock of its own to offer.
    base = {
        "schema": "repro-bench/7",
        "schema_version": 7,
        "reference_backend": _BASELINE_DATA.get("reference_backend", "cpu"),
        "streams_per_iter": bench_run._streams_ladder(),
        "bytes_per_dof_iter": bench_run._precision_table(),
        "streams_per_rhs": bench_run._streams_per_rhs_table(),
        "us_per_iter": dict(_BASELINE_DATA.get("us_per_iter", {})),
        "solver_service": dict(_BASELINE_DATA.get("solver_service") or {}),
        "sections": [],
    }
    base.update(overrides)
    return base


def test_streams_ladder_values():
    """The ladder run.py publishes: the 30 -> 17 -> 13 -> 6.25 fusion
    story plus the §10 sharded rungs at the 8-device EZ=32 point."""
    ladder = bench_run._streams_ladder()
    assert ladder["eq2"] == 30
    assert ladder["fused_v1"] == 17
    assert ladder["fused_v2"] == 13
    assert ladder["sstep_v3"] == 6.25
    assert ladder["sstep_v3_s1"] == 13.0
    assert ladder["fused_v2_jacobi"] == 14
    assert ladder["fused_v2_cheb"] == 18
    # sharded: headline + halo + the per-device collective channel
    assert ladder["sstep_v3_sharded_d8"] == 6.25 + 2.5 + 2.0
    assert abs(ladder["fused_v2_jacobi_sharded_d8"] - 14.1) < 1e-12
    assert abs(ladder["fused_v2_cheb_sharded_d8"] - (18 + 8 + 4 + 0.1)) \
        < 1e-12


# ---------------------------------------------------------------------------
# compare(): the gate's three checks
# ---------------------------------------------------------------------------

def test_identical_payload_passes():
    assert compare(_payload(), _payload()) == []


def test_precision_table_from_cost_model_halves():
    """The committed table itself satisfies the bf16 == f32/2 headline."""
    table = bench_run._precision_table()
    for pipeline, pols in table.items():
        f32 = pols["f32"]["read"] + pols["f32"]["write"]
        bf16 = pols["bf16"]["read"] + pols["bf16"]["write"]
        f64 = pols["f64"]["read"] + pols["f64"]["write"]
        assert bf16 * 2 == f32, pipeline
        assert f32 * 2 == f64, pipeline


def test_stream_ladder_regression_fails():
    fresh = _payload()
    fresh["streams_per_iter"]["fused_v2"] = 15
    problems = compare(fresh, _payload())
    assert any("fused_v2" in p and "regressed" in p for p in problems)


def test_stream_ladder_improvement_also_fails():
    """A *better* number still fails: the baseline must be refreshed so
    the win is pinned, not floating."""
    fresh = _payload()
    fresh["streams_per_iter"]["fused_v2"] = 11
    problems = compare(fresh, _payload())
    assert any("improved" in p for p in problems)


def test_missing_tables_fail():
    fresh = _payload()
    del fresh["streams_per_iter"]
    del fresh["bytes_per_dof_iter"]
    problems = compare(fresh, _payload())
    assert any("streams_per_iter" in p for p in problems)
    assert any("bytes_per_dof_iter" in p for p in problems)


def test_bytes_within_tolerance_passes_and_outside_fails():
    fresh = _payload()
    fresh["bytes_per_dof_iter"]["fused_v2"]["f32"]["read"] *= 1.04
    assert compare(fresh, _payload(), tol=0.05) == []
    fresh["bytes_per_dof_iter"]["fused_v2"]["f32"]["read"] *= 1.10
    assert compare(fresh, _payload(), tol=0.05)


def test_bf16_half_of_f32_invariant():
    fresh = _payload()
    # consistent with baseline per-entry tolerance is not enough: breaking
    # the ratio beyond tol must fail even if each entry drifted "legally"
    fresh["bytes_per_dof_iter"]["fused_v2"]["bf16"]["read"] = 40
    problems = compare(fresh, _payload(), tol=0.05)
    assert any("half" in p for p in problems)


# ---------------------------------------------------------------------------
# us/iter wall-clock band (schema v6, DESIGN.md §11.4)
# ---------------------------------------------------------------------------

def _with_timing(payload, row="cg_fused_v2_iter_e8", us=1000.0):
    payload["us_per_iter"] = {row: us}
    return payload


def test_timing_within_band_passes_and_regression_fails():
    base = _with_timing(_payload())
    ok = _with_timing(_payload(), us=1050.0)          # +5% < +10% band
    assert compare(ok, base) == []
    slow = _with_timing(_payload(), us=1200.0)        # +20%
    problems = compare(slow, base)
    assert any("us/iter" in p and "regressed" in p for p in problems)


def test_timing_band_is_one_sided_faster_warns_to_refresh():
    base = _with_timing(_payload())
    fast = _with_timing(_payload(), us=500.0)
    warnings = []
    assert compare(fast, base, warnings=warnings) == []
    assert any("faster" in w and "refresh" in w for w in warnings)


def test_timing_tol_is_adjustable():
    base = _with_timing(_payload())
    slow = _with_timing(_payload(), us=1200.0)
    assert compare(slow, base, timing_tol=0.25) == []


def test_timing_backend_mismatch_downgrades_to_warning():
    """Wall time measured on another backend kind says nothing — even a
    10x 'regression' must not fail, only warn that the rows are skipped."""
    base = _with_timing(_payload(reference_backend="cpu"))
    fresh = _with_timing(_payload(reference_backend="tpu"), us=10000.0)
    warnings = []
    assert compare(fresh, base, warnings=warnings) == []
    assert any("backend mismatch" in w for w in warnings)


def test_timing_table_vanishing_is_a_violation():
    base = _with_timing(_payload())
    fresh = _payload()
    del fresh["us_per_iter"]
    problems = compare(fresh, base)
    assert any("us_per_iter" in p for p in problems)
    # a pinned row individually missing is a violation too
    fresh = _with_timing(_payload(), row="some_other_row")
    problems = compare(fresh, base)
    assert any("missing" in p and "cg_fused_v2_iter_e8" in p
               for p in problems)


def test_timing_problems_routed_separately_when_asked():
    """The caller's timing_problems list receives the violations so main()
    can soften them (--timing-warn-only) without touching hard rows."""
    base = _with_timing(_payload())
    slow = _with_timing(_payload(), us=1200.0)
    timing = []
    assert compare(slow, base, timing_problems=timing) == []
    assert len(timing) == 1 and "regressed" in timing[0]


def test_new_timing_row_warns_not_fails():
    base = _with_timing(_payload())
    fresh = _payload()
    fresh["us_per_iter"] = {"cg_fused_v2_iter_e8": 1000.0,
                            "brand_new_iter_e8": 5.0}
    warnings = []
    assert compare(fresh, base, warnings=warnings) == []
    assert any("brand_new_iter_e8" in w for w in warnings)


def test_timing_warn_only_main_exits_zero_with_annotation(tmp_path, capsys):
    base = tmp_path / "base.json"
    base.write_text(json.dumps(_with_timing(_payload())))
    fresh = tmp_path / "BENCH_fresh.json"
    fresh.write_text(json.dumps(_with_timing(_payload(), us=1200.0)))
    # hard by default ...
    assert main([str(fresh), "--baseline", str(base)]) == 1
    capsys.readouterr()
    # ... softened to a GitHub annotation under --timing-warn-only
    assert main([str(fresh), "--baseline", str(base),
                 "--timing-warn-only"]) == 0
    out = capsys.readouterr().out
    assert "::warning::timing:" in out
    # ... and --timing-tol widens the band instead
    assert main([str(fresh), "--baseline", str(base),
                 "--timing-tol", "0.5"]) == 0


def test_timing_warn_only_keeps_stream_rows_hard(tmp_path, capsys):
    base = tmp_path / "base.json"
    base.write_text(json.dumps(_payload()))
    bad = _payload()
    bad["streams_per_iter"]["fused_v2"] = 15
    fresh = tmp_path / "BENCH_fresh.json"
    fresh.write_text(json.dumps(bad))
    assert main([str(fresh), "--baseline", str(base),
                 "--timing-warn-only"]) == 1


# ---------------------------------------------------------------------------
# forward compatibility: rows *added* by a PR warn instead of failing
# (missing/regressed rows still fail — tested above)
# ---------------------------------------------------------------------------

def test_added_stream_rung_warns_not_fails():
    fresh = _payload()
    fresh["streams_per_iter"]["sstep_v4"] = 5.0
    warnings = []
    assert compare(fresh, _payload(), warnings=warnings) == []
    assert any("sstep_v4" in w and "not in baseline" in w for w in warnings)


def test_added_bytes_pipeline_warns_not_fails():
    fresh = _payload()
    fresh["bytes_per_dof_iter"]["sstep_v4"] = {
        "f32": {"read": 10, "write": 5}}
    warnings = []
    assert compare(fresh, _payload(), warnings=warnings) == []
    assert any("sstep_v4" in w for w in warnings)


def test_added_policy_and_column_warn_not_fail():
    """A new policy under an existing pipeline, or a new numeric column
    under an existing policy, surfaces as a warning (never silent, never
    failing)."""
    fresh = _payload()
    fresh["bytes_per_dof_iter"]["fused_v2"]["fp8"] = {"read": 9, "write": 4}
    fresh["bytes_per_dof_iter"]["fused_v2"]["f32"]["read_padded"] = 40
    warnings = []
    assert compare(fresh, _payload(), warnings=warnings) == []
    assert any("fused_v2/fp8" in w for w in warnings)
    assert any("read_padded" in w for w in warnings)


def test_schema_version_skew_warns_not_fails():
    old_base = _payload(schema_version=2)
    warnings = []
    assert compare(_payload(), old_base, warnings=warnings) == []
    assert any("schema_version" in w for w in warnings)


def test_added_rows_warn_in_main_but_exit_zero(tmp_path, capsys):
    base = tmp_path / "base.json"
    base.write_text(json.dumps(_payload()))
    fresh_payload = _payload()
    fresh_payload["streams_per_iter"]["sstep_v4"] = 5.0
    fresh = tmp_path / "BENCH_fresh.json"
    fresh.write_text(json.dumps(fresh_payload))
    assert main([str(fresh), "--baseline", str(base)]) == 0
    assert "WARNING" in capsys.readouterr().err


def test_exact_column_pinned_when_baseline_has_it():
    """A baseline that holds the *_exact side-channel books makes them
    load-bearing: drifting only the exact column must fail."""
    fresh = _payload()
    fresh["bytes_per_dof_iter"]["fused_v2"]["f32"]["read_exact"] *= 1.5
    problems = compare(fresh, _payload(), tol=0.05)
    assert any("read_exact" in p for p in problems)


def test_sstep_s1_rung_equals_v2_in_committed_baseline():
    """The committed baseline pins the s=1 == v2 degeneracy identity, and
    it agrees with the live cost model — the gate holds it across PRs."""
    from repro.core import cost

    data = load_bench_json(BASELINE, "baseline")
    streams = data["streams_per_iter"]
    assert streams["sstep_v3_s1"] == streams["fused_v2"]
    assert sum(cost.sstep_streams(1)) == streams["fused_v2"]


# ---------------------------------------------------------------------------
# file handling: corrupt / missing inputs exit with a clear error
# ---------------------------------------------------------------------------

def test_corrupt_fresh_json_exits_cleanly(tmp_path, capsys):
    bad = tmp_path / "BENCH_bad.json"
    bad.write_text("{ definitely not json")
    with pytest.raises(SystemExit) as e:
        load_bench_json(bad, "fresh")
    assert e.value.code == 2
    assert "corrupt" in capsys.readouterr().err


def test_missing_fresh_json_exits_cleanly(tmp_path):
    with pytest.raises(SystemExit) as e:
        load_bench_json(tmp_path / "nope.json", "fresh")
    assert e.value.code == 2


def test_malformed_table_exits_cleanly(tmp_path, capsys):
    """Valid JSON, wrong shape (scalar where {read,write} belongs): same
    contract as corrupt JSON — clear message, exit 2, no traceback."""
    base = tmp_path / "base.json"
    base.write_text(json.dumps(_payload()))
    bad = _payload()
    bad["bytes_per_dof_iter"]["fused_v2"]["f32"] = 52
    fresh = tmp_path / "BENCH_f.json"
    fresh.write_text(json.dumps(bad))
    with pytest.raises(SystemExit) as e:
        main([str(fresh), "--baseline", str(base)])
    assert e.value.code == 2
    assert "malformed" in capsys.readouterr().err


def test_main_end_to_end(tmp_path):
    base = tmp_path / "base.json"
    base.write_text(json.dumps(_payload()))
    fresh = tmp_path / "BENCH_fresh.json"
    fresh.write_text(json.dumps(_payload()))
    assert main([str(fresh), "--baseline", str(base)]) == 0

    bad = _payload()
    bad["streams_per_iter"]["eq2"] = 31
    fresh.write_text(json.dumps(bad))
    assert main([str(fresh), "--baseline", str(base)]) == 1


def test_committed_baseline_is_valid_and_self_consistent():
    """The checked-in baseline parses and matches the live cost model —
    i.e. HEAD would pass its own gate."""
    data = load_bench_json(BASELINE, "baseline")
    assert compare(_payload(), data) == []


# ---------------------------------------------------------------------------
# benchmarks/run.py atomic write (the satellite bugfix)
# ---------------------------------------------------------------------------

def test_write_json_atomic_success_and_no_tmp_left(tmp_path):
    path = tmp_path / "out" / "BENCH_t.json"
    assert bench_run.write_json_atomic(path, {"a": 1})
    assert json.loads(path.read_text()) == {"a": 1}
    assert list(path.parent.glob("*.tmp.*")) == []


def test_write_json_atomic_replaces_corrupt_stale_file(tmp_path):
    path = tmp_path / "BENCH_t.json"
    path.write_text("{ stale half-written garbage")
    assert bench_run.write_json_atomic(path, {"b": 2})
    assert json.loads(path.read_text()) == {"b": 2}


def test_write_json_atomic_unwritable_dir_is_clear_error(tmp_path, capsys):
    if os.geteuid() == 0:
        pytest.skip("running as root: chmod cannot make a dir unwritable")
    ro = tmp_path / "ro"
    ro.mkdir()
    ro.chmod(stat.S_IRUSR | stat.S_IXUSR)
    try:
        ok = bench_run.write_json_atomic(ro / "BENCH_t.json", {"c": 3})
    finally:
        ro.chmod(stat.S_IRWXU)
    assert not ok
    err = capsys.readouterr().err
    assert "could not write bench json" in err


def test_write_json_atomic_path_is_directory_is_clear_error(tmp_path,
                                                           capsys):
    target = tmp_path / "BENCH_t.json"
    target.mkdir()                      # occupied by a directory
    assert not bench_run.write_json_atomic(target, {"d": 4})
    assert "could not write bench json" in capsys.readouterr().err


# ---------------------------------------------------------------------------
# schema v7: multi-RHS rungs, the streams/RHS table, solver_service rows
# ---------------------------------------------------------------------------

def test_multi_rhs_ladder_rung_values():
    """The v7 rungs: shared operator streams (3) divide by b on top of
    the per-RHS vector streams — and the b=8 s-step point sits below the
    single-RHS 6.25 headline."""
    ladder = bench_run._streams_ladder()
    assert ladder["fused_v2_rhs2"] == 11.5
    assert ladder["fused_v2_rhs4"] == 10.75
    assert ladder["fused_v2_rhs8"] == 10.375
    assert ladder["sstep_v3_rhs2"] == 5.875
    assert ladder["sstep_v3_rhs4"] == 5.6875
    assert ladder["sstep_v3_rhs8"] == 5.59375
    assert ladder["sstep_v3_rhs8"] < 6.25


def test_streams_per_rhs_table_strictly_decreasing():
    table = bench_run._streams_per_rhs_table()
    for pipeline, rows in table.items():
        seq = [rows[str(b)] for b in (1, 2, 4, 8)]
        assert all(a > b for a, b in zip(seq, seq[1:])), (pipeline, seq)
    assert table["fused_v2"]["1"] == 13
    assert table["sstep_v3"]["1"] == 6.25


def _payload_v7(**overrides):
    base = _payload(schema_version=7,
                    streams_per_rhs=bench_run._streams_per_rhs_table(),
                    solver_service={"rows": {"1": {}}})
    base.update(overrides)
    return base


def test_streams_per_rhs_exact_and_monotone_gate():
    base = _payload_v7()
    fresh = _payload_v7()
    # exact pin: any drift on a baseline row fails
    fresh["streams_per_rhs"]["fused_v2"]["8"] = 10.5
    problems = compare(fresh, base)
    assert any("streams/RHS 'fused_v2' b=8" in p for p in problems)
    # monotonicity: a non-decreasing step fails even when the baseline
    # holds the same (broken) curve
    broken = _payload_v7()
    broken["streams_per_rhs"]["fused_v2"]["8"] = 11.0
    broken["streams_per_rhs"]["fused_v2"]["4"] = 11.0
    problems = compare(broken, broken)
    assert any("strictly decreasing" in p for p in problems)


def test_streams_per_rhs_missing_fails_when_pinned():
    fresh = _payload_v7()
    del fresh["streams_per_rhs"]
    problems = compare(fresh, _payload_v7())
    assert any("streams_per_rhs" in p for p in problems)
    # ...but a v6 baseline without the table doesn't demand it
    assert compare(_payload(), _payload()) == []


def test_solver_service_presence_is_timing_like():
    fresh = _payload_v7()
    del fresh["solver_service"]
    timing = []
    problems = compare(fresh, _payload_v7(), timing_problems=timing)
    assert not any("solver_service" in p for p in problems)
    assert any("solver_service" in t for t in timing)


# ---------------------------------------------------------------------------
# schema v9: provenance-annotated backend mismatch + the telemetry section
# ---------------------------------------------------------------------------

def _payload_v9(**overrides):
    base = _payload(schema="repro-bench/9", schema_version=9,
                    provenance={"machine": "linux-x86_64-1cpu",
                                "python": "3.10.16",
                                "jax_version": "0.4.37",
                                "backend": "cpu", "x64": False},
                    telemetry={"drift": {"ok": True, "rows": []}})
    base.update(overrides)
    return base


def test_backend_mismatch_explained_by_provenance():
    base = _payload_v9()
    fresh = _payload_v9(reference_backend="tpu")
    fresh["provenance"] = dict(fresh["provenance"], backend="tpu",
                               jax_version="0.7.0")
    warnings = []
    problems = compare(fresh, base, warnings=warnings)
    assert problems == []
    msg = next(w for w in warnings if "backend mismatch" in w)
    # the schema-v9 provenance delta names exactly what differs
    assert "provenance delta" in msg
    assert "backend: fresh='tpu' baseline='cpu'" in msg
    assert "jax_version: fresh='0.7.0' baseline='0.4.37'" in msg
    assert "machine" not in msg.split("provenance delta")[1]


def test_backend_mismatch_without_provenance_stays_bare():
    """Pre-v9 files have no provenance record; the warning must still
    fire, just without the delta suffix."""
    base = _payload()
    fresh = _payload(reference_backend="tpu")
    warnings = []
    compare(fresh, base, warnings=warnings)
    msg = next(w for w in warnings if "backend mismatch" in w)
    assert "provenance delta" not in msg


def test_v9_payload_passes_and_telemetry_is_not_gated():
    """The telemetry section is informational: absent, present, or
    drifted-false it must never fail the gate."""
    assert compare(_payload_v9(), _payload_v9()) == []
    fresh = _payload_v9(telemetry=None)
    assert compare(fresh, _payload_v9()) == []
    fresh = _payload_v9(telemetry={"drift": {"ok": False, "rows": []}})
    assert compare(fresh, _payload_v9()) == []
