"""The paper's cost model (Eq. 1-2) and our operator's adherence to it."""

import jax
import jax.numpy as jnp

from repro.core.cost import (CostModel, ax_local_flops, cg_iter_bytes,
                             cg_iter_flops, flops_per_dof, intensity,
                             roofline_gflops)


def test_eq1_values():
    # Paper §III-A with n = 10 (degree 9): 12*10 + 34 = 154 flops per DOF.
    assert flops_per_dof(10) == 154
    D = 1024 * 1000                       # 1024 elements at n=10
    assert cg_iter_flops(D, 10) == D * 154


def test_eq2_intensity():
    # I(10) = 154/240 ~= 0.6417 flop/byte in fp64 (paper Eq. 2).
    assert abs(intensity(10) - 154 / 240) < 1e-12
    # fp32 doubles it (DESIGN.md §5).
    assert abs(intensity(10, itemsize=4) - 154 / 120) < 1e-12


def test_paper_roofline_numbers():
    """§VI-B: theoretical peak BW gives 462 GF/s (P100) / 577 GF/s (V100)."""
    assert abs(roofline_gflops(720, 10) - 462) < 1.0
    assert abs(roofline_gflops(900, 10) - 577.5) < 1.0


def test_bytes_model():
    r, w = cg_iter_bytes(1000, itemsize=8)
    assert r == 24 * 1000 * 8 and w == 6 * 1000 * 8


def test_cost_model_dataclass():
    cm = CostModel(nelt=1024, n=10)
    assert cm.ndof == 1_024_000
    assert cm.cg_flops == 1_024_000 * 154
    assert abs(cm.intensity - 154 / 240) < 1e-12


def test_hlo_flops_match_cost_model():
    """Compiled local operator's dot flops ~= the 12n-term of Eq. 1.

    The contractions are 12n flops/DOF; the metric apply (elementwise, not
    dots) is the remaining 17.  Checks the implementation does not do
    redundant contraction work.
    """
    from repro.core.ax import ax_local_fused
    from repro.core.sem import derivative_matrix
    from repro.launch.hlo_analysis import analyze_hlo

    n, E = 10, 64
    u = jax.ShapeDtypeStruct((E, n, n, n), jnp.float32)
    g = jax.ShapeDtypeStruct((E, 6, n, n, n), jnp.float32)
    D = jnp.asarray(derivative_matrix(n), jnp.float32)
    compiled = jax.jit(lambda u, g: ax_local_fused(u, D, g)).lower(u, g).compile()
    got = analyze_hlo(compiled.as_text())["dot_flops"]
    want = E * n ** 3 * 12 * n            # 6 contractions x 2n flops
    assert 0.95 * want <= got <= 1.10 * want, (got, want)


def test_ax_local_flops_formula():
    assert ax_local_flops(1, 10) == 1000 * (120 + 17)
