"""The paper's cost model (Eq. 1-2) and our operator's adherence to it."""

import jax
import jax.numpy as jnp

from repro.core.cost import (CostModel, ax_local_flops, cg_iter_bytes,
                             cg_iter_flops, flops_per_dof, intensity,
                             roofline_gflops)


def test_eq1_values():
    # Paper §III-A with n = 10 (degree 9): 12*10 + 34 = 154 flops per DOF.
    assert flops_per_dof(10) == 154
    D = 1024 * 1000                       # 1024 elements at n=10
    assert cg_iter_flops(D, 10) == D * 154


def test_eq2_intensity():
    # I(10) = 154/240 ~= 0.6417 flop/byte in fp64 (paper Eq. 2).
    assert abs(intensity(10) - 154 / 240) < 1e-12
    # fp32 doubles it (DESIGN.md §5).
    assert abs(intensity(10, itemsize=4) - 154 / 120) < 1e-12


def test_paper_roofline_numbers():
    """§VI-B: theoretical peak BW gives 462 GF/s (P100) / 577 GF/s (V100)."""
    assert abs(roofline_gflops(720, 10) - 462) < 1.0
    assert abs(roofline_gflops(900, 10) - 577.5) < 1.0


def test_bytes_model():
    r, w = cg_iter_bytes(1000, itemsize=8)
    assert r == 24 * 1000 * 8 and w == 6 * 1000 * 8


def test_cost_model_dataclass():
    cm = CostModel(nelt=1024, n=10)
    assert cm.ndof == 1_024_000
    assert cm.cg_flops == 1_024_000 * 154
    assert abs(cm.intensity - 154 / 240) < 1e-12


def test_hlo_flops_match_cost_model():
    """Compiled local operator's dot flops ~= the 12n-term of Eq. 1.

    The contractions are 12n flops/DOF; the metric apply (elementwise, not
    dots) is the remaining 17.  Checks the implementation does not do
    redundant contraction work.
    """
    from repro.core.ax import ax_local_fused
    from repro.core.sem import derivative_matrix
    from repro.launch.hlo_analysis import analyze_hlo

    n, E = 10, 64
    u = jax.ShapeDtypeStruct((E, n, n, n), jnp.float32)
    g = jax.ShapeDtypeStruct((E, 6, n, n, n), jnp.float32)
    D = jnp.asarray(derivative_matrix(n), jnp.float32)
    compiled = jax.jit(lambda u, g: ax_local_fused(u, D, g)).lower(u, g).compile()
    got = analyze_hlo(compiled.as_text())["dot_flops"]
    want = E * n ** 3 * 12 * n            # 6 contractions x 2n flops
    assert 0.95 * want <= got <= 1.10 * want, (got, want)


def test_ax_local_flops_formula():
    assert ax_local_flops(1, 10) == 1000 * (120 + 17)


# ---------------------------------------------------------------------------
# v3 s-step stream accounting (DESIGN.md §8)
# ---------------------------------------------------------------------------

def test_sstep_s1_degenerates_to_v2():
    """4s+9 at s=1 is exactly the v2 budget — reads and writes separately,
    not just the total (the ISSUE's degeneracy acceptance)."""
    from repro.core.cost import (FUSED_V2_READ_STREAMS,
                                 FUSED_V2_WRITE_STREAMS, sstep_streams)

    r, w = sstep_streams(1)
    assert (r, w) == (FUSED_V2_READ_STREAMS, FUSED_V2_WRITE_STREAMS)


def test_sstep_cycle_budget():
    """Per cycle: powers kernel 5R + (2s-1)W, update (2s+2)R + 3W."""
    from repro.core.cost import sstep_cycle_streams, sstep_streams

    for s in (1, 2, 3, 4, 8):
        r, w = sstep_cycle_streams(s)
        assert (r, w) == (2 * s + 7, 2 * s + 2)
        ri, wi = sstep_streams(s)
        assert abs(ri - r / s) < 1e-12 and abs(wi - w / s) < 1e-12


def test_sstep_effective_streams_meets_target():
    """The acceptance number: <= 9 effective streams/iter at (s, sz) =
    (4, 4), halo side channel included; strictly below v2's 13."""
    from repro.core.cost import sstep_effective_streams, sstep_streams

    eff = sstep_effective_streams(4, 4)
    assert eff <= 9.0, eff
    assert sum(sstep_streams(4)) == 25 / 4
    # monotone in s at fixed sz: amortization only improves
    assert (sstep_effective_streams(4, 4) < sstep_effective_streams(2, 4)
            < sstep_effective_streams(1, 4))


def test_sstep_halo_streams_scaling():
    """Halo = 10/sz stream-equivalents per iteration (5 fields, 2s ghost
    slabs per block, amortized over s iterations — independent of s)."""
    from repro.core.cost import sstep_halo_streams

    assert abs(sstep_halo_streams(4, 4) - 2.5) < 1e-12
    assert abs(sstep_halo_streams(2, 8) - 1.25) < 1e-12
    assert sstep_halo_streams(2, 4) == sstep_halo_streams(8, 4)


def test_pcg_stream_budgets():
    """DESIGN.md §9: Jacobi = v2 + 1 (the fused diagonal stream);
    Chebyshev = v2 + 5 (the polynomial-apply kernel), k-independent."""
    from repro.core.cost import (CHEB_V2_READ_STREAMS,
                                 CHEB_V2_WRITE_STREAMS,
                                 FUSED_V2_READ_STREAMS,
                                 FUSED_V2_WRITE_STREAMS,
                                 JACOBI_V2_READ_STREAMS,
                                 JACOBI_V2_WRITE_STREAMS, PIPELINE_STREAMS)

    v2 = FUSED_V2_READ_STREAMS + FUSED_V2_WRITE_STREAMS
    jac = JACOBI_V2_READ_STREAMS + JACOBI_V2_WRITE_STREAMS
    chb = CHEB_V2_READ_STREAMS + CHEB_V2_WRITE_STREAMS
    assert jac == v2 + 1 == 14
    assert chb == v2 + 5 == 18
    assert PIPELINE_STREAMS["fused_v2_jacobi"] == (10, 4)
    assert PIPELINE_STREAMS["fused_v2_cheb"] == (13, 5)


def test_cheb_halo_and_flops_scaling():
    from repro.core.cost import (cheb_effective_streams, cheb_flops_per_dof,
                                 cheb_halo_streams)

    # 4 halo'd fields over 2k ghost slabs per sz-slab block, per iteration
    assert cheb_halo_streams(4, 4) == 8.0
    assert cheb_halo_streams(2, 4) == 4.0          # linear in k
    assert cheb_halo_streams(4, 8) == 4.0          # inverse in sz
    assert cheb_effective_streams(4, 4) == 18 + 8.0
    # each polynomial order adds one operator application's flops
    assert (cheb_flops_per_dof(10, 2) - cheb_flops_per_dof(10, 1)
            == 12 * 10 + 17 + 6)


def test_pcg_bytes_per_dof_iter():
    from repro.core.cost import bytes_per_dof_iter, fused_v2_plane_streams

    for pol, itemsize in (("f64", 8), ("f32", 4), ("bf16", 2)):
        assert bytes_per_dof_iter("fused_v2_jacobi", pol) == \
            (10 * itemsize, 4 * itemsize)
        assert bytes_per_dof_iter("fused_v2_cheb", pol) == \
            (13 * itemsize, 5 * itemsize)
    # bf16 is exactly half of f32 on both rungs (the gate's invariant)
    for pipe in ("fused_v2_jacobi", "fused_v2_cheb"):
        assert (sum(bytes_per_dof_iter(pipe, "bf16")) * 2
                == sum(bytes_per_dof_iter(pipe, "f32")))
    # exact books: both PCG rungs inherit the v2 plane channel; cheb adds
    # its per-iteration halo reads (8k/sz at the defaults)
    half = fused_v2_plane_streams(10, 4) / 2.0
    rj, wj = bytes_per_dof_iter("fused_v2_jacobi", "f32", exact=True)
    assert abs(rj - (10 + half) * 4) < 1e-9
    assert abs(wj - (4 + half) * 4) < 1e-9
    rc, wc = bytes_per_dof_iter("fused_v2_cheb", "f32", exact=True)
    assert abs(rc - (13 + half + 8.0) * 4) < 1e-9
    assert abs(wc - (5 + half) * 4) < 1e-9


def test_bytes_per_dof_iter_exact_mode():
    """exact=True folds in the side channels: v2 boundary planes (split
    evenly read/write), v3 halo (reads only); eq2/v1 are unchanged."""
    from repro.core.cost import (bytes_per_dof_iter, fused_v2_plane_streams,
                                 sstep_halo_streams)

    for pipeline in ("eq2", "fused_v1"):
        assert (bytes_per_dof_iter(pipeline, "f32", exact=True)
                == bytes_per_dof_iter(pipeline, "f32"))
    rb, wb = bytes_per_dof_iter("fused_v2", "f32")
    re_, we = bytes_per_dof_iter("fused_v2", "f32", exact=True, n=10, sz=4)
    half = fused_v2_plane_streams(10, 4) / 2 * 4
    assert abs(re_ - rb - half) < 1e-9 and abs(we - wb - half) < 1e-9
    rb, wb = bytes_per_dof_iter("sstep_v3", "f32")
    re_, we = bytes_per_dof_iter("sstep_v3", "f32", exact=True, sz=4)
    assert abs(re_ - rb - sstep_halo_streams(4, 4) * 4) < 1e-9
    assert we == wb


def test_sstep_bytes_strictly_below_v2_for_s_above_1():
    """The s-sweep acceptance: fewer bytes/DOF/iter than v2 at equal
    precision for every s > 1 (headline and exact books alike)."""
    from repro.core.cost import bytes_per_dof_iter

    for pol in ("f64", "f32", "bf16"):
        v2 = sum(bytes_per_dof_iter("fused_v2", pol))
        v2x = sum(bytes_per_dof_iter("fused_v2", pol, exact=True))
        for s in (2, 4, 8):
            assert sum(bytes_per_dof_iter("sstep_v3", pol, s=s)) < v2
            assert sum(bytes_per_dof_iter("sstep_v3", pol, s=s,
                                          exact=True)) < v2x
        assert sum(bytes_per_dof_iter("sstep_v3", pol, s=1)) == v2


def test_sstep_intensity_scales():
    from repro.core.cost import fused_v2_intensity, sstep_intensity

    assert abs(sstep_intensity(10, 1) - fused_v2_intensity(10)) < 1e-12
    assert sstep_intensity(10, 4) > 2 * fused_v2_intensity(10) * 0.95


# ---------------------------------------------------------------------------
# sharded collective accounting (DESIGN.md §10)
# ---------------------------------------------------------------------------

def test_collective_stream_values():
    """s-step: 8/ez_local per iteration, s-independent (the two s factors
    cancel — communication-avoidance shows up against a per-iteration
    exchange, not in this number); cheb: 4k/ez_local; v2 plane stitch:
    4/(n*ez_local)."""
    from repro.core.cost import (cheb_collective_streams,
                                 sstep_collective_streams,
                                 v2_plane_collective_streams)

    assert sstep_collective_streams(4, 4) == 2.0
    assert sstep_collective_streams(1, 4) == 2.0      # s-independent
    assert sstep_collective_streams(4, 8) == 1.0      # inverse in ez_local
    assert cheb_collective_streams(4, 4) == 4.0
    assert cheb_collective_streams(2, 4) == 2.0       # linear in k
    assert abs(v2_plane_collective_streams(10, 4) - 0.1) < 1e-12


def test_effective_streams_ndev1_identity():
    """ndev=1 is the exact single-device identity — with or without ez —
    and ndev>1 adds exactly the collective channel."""
    from repro.core.cost import (cheb_collective_streams,
                                 cheb_effective_streams,
                                 sstep_effective_streams,
                                 v2_plane_collective_streams)

    base = sstep_effective_streams(4, 4)
    assert sstep_effective_streams(4, 4, ndev=1) == base
    assert sstep_effective_streams(4, 4, ndev=1, ez=32) == base
    assert (sstep_effective_streams(4, 4, ndev=8, ez=32)
            == base + 2.0)                            # + 8/ez_local
    cbase = cheb_effective_streams(4, 4)
    assert cheb_effective_streams(4, 4, ndev=1, ez=32) == cbase
    assert (cheb_effective_streams(4, 4, ndev=8, ez=32, n=10)
            == cbase + cheb_collective_streams(4, 4)
            + v2_plane_collective_streams(10, 4))


def test_effective_streams_ndev_validation():
    import pytest

    from repro.core.cost import sstep_effective_streams

    with pytest.raises(ValueError, match="needs the global EZ"):
        sstep_effective_streams(4, 4, ndev=8)
    with pytest.raises(ValueError, match="not divisible"):
        sstep_effective_streams(4, 4, ndev=8, ez=30)


def test_bytes_per_dof_iter_ndev():
    """ndev threads through the exact books: the collective channel is
    split evenly read/write; ndev=1 stays the identity; eq2/fused_v1 and
    non-exact calls reject ndev>1 instead of lying."""
    import pytest

    from repro.core.cost import (bytes_per_dof_iter, cheb_collective_streams,
                                 sstep_collective_streams,
                                 v2_plane_collective_streams)

    assert (bytes_per_dof_iter("sstep_v3", "f32", exact=True, ndev=1, ez=32)
            == bytes_per_dof_iter("sstep_v3", "f32", exact=True))
    r1, w1 = bytes_per_dof_iter("sstep_v3", "f32", exact=True, sz=4)
    r8, w8 = bytes_per_dof_iter("sstep_v3", "f32", exact=True, sz=4,
                                ndev=8, ez=32)
    half = sstep_collective_streams(4, 32 // 8) / 2.0 * 4
    assert abs(r8 - r1 - half) < 1e-9 and abs(w8 - w1 - half) < 1e-9
    rc1, wc1 = bytes_per_dof_iter("fused_v2_cheb", "f32", exact=True)
    rc8, wc8 = bytes_per_dof_iter("fused_v2_cheb", "f32", exact=True,
                                  ndev=8, ez=32)
    halfc = (cheb_collective_streams(4, 4)
             + v2_plane_collective_streams(10, 4)) / 2.0 * 4
    assert abs(rc8 - rc1 - halfc) < 1e-9 and abs(wc8 - wc1 - halfc) < 1e-9
    with pytest.raises(ValueError, match="no sharded variant"):
        bytes_per_dof_iter("eq2", "f32", exact=True, ndev=8, ez=32)
    with pytest.raises(ValueError, match="no sharded variant"):
        bytes_per_dof_iter("fused_v1", "f32", exact=True, ndev=8, ez=32)
    with pytest.raises(ValueError, match="exact=True"):
        bytes_per_dof_iter("sstep_v3", "f32", ndev=8, ez=32)


def test_multi_rhs_stream_books():
    """DESIGN.md §12: per-RHS streams = vector + shared/b, strictly
    decreasing in b, approaching the vector floor; halo amortizes too;
    bf16 prices at exactly half of f32 on every rhs rung."""
    import pytest

    from repro.core.cost import (MULTI_RHS_BATCHES, MULTI_RHS_SHARED_STREAMS,
                                 PIPELINE_STREAMS, bytes_per_dof_iter,
                                 multi_rhs_halo_streams, multi_rhs_streams,
                                 streams_per_rhs)

    assert MULTI_RHS_SHARED_STREAMS == 3.0
    # b=1 degenerates to the single-RHS rungs
    assert streams_per_rhs(1, "fused_v2") == 13
    assert streams_per_rhs(1, "sstep_v3") == 6.25
    # the b=8 acceptance points
    assert streams_per_rhs(8, "fused_v2") == 10.375
    assert streams_per_rhs(8, "sstep_v3") == 5.59375 < 6.25
    for pipeline in ("fused_v2", "sstep_v3"):
        seq = [streams_per_rhs(b, pipeline) for b in (1, 2, 4, 8)]
        assert all(a > b for a, b in zip(seq, seq[1:]))
        # the shared streams vanish as b -> inf: the floor is vector-only
        floor = streams_per_rhs(1, pipeline) - (
            MULTI_RHS_SHARED_STREAMS if pipeline == "fused_v2"
            else MULTI_RHS_SHARED_STREAMS / 4)
        assert streams_per_rhs(10 ** 6, pipeline) == pytest.approx(floor)
        for b in MULTI_RHS_BATCHES:
            r, w = PIPELINE_STREAMS[f"{pipeline}_rhs{b}"]
            assert r + w == streams_per_rhs(b, pipeline)
            f32 = sum(bytes_per_dof_iter(f"{pipeline}_rhs{b}", "f32"))
            bf16 = sum(bytes_per_dof_iter(f"{pipeline}_rhs{b}", "bf16"))
            assert bf16 * 2 == f32
            ex32 = sum(bytes_per_dof_iter(f"{pipeline}_rhs{b}", "f32",
                                          exact=True))
            ex16 = sum(bytes_per_dof_iter(f"{pipeline}_rhs{b}", "bf16",
                                          exact=True))
            assert ex16 * 2 == pytest.approx(ex32)
    # halo side channel: (4 + 6/b)/sz per RHS — b=1 is the v3 10/sz
    assert multi_rhs_halo_streams(1, 4, 4) == pytest.approx(10 / 4)
    assert multi_rhs_halo_streams(8, 4, 4) == pytest.approx(4.75 / 4)
    with pytest.raises(ValueError):
        multi_rhs_streams(0)
    with pytest.raises(ValueError):
        multi_rhs_streams(2, "eq2")
