"""Multi-device behaviour (shard_map, collectives) via subprocesses.

The 8-device host-platform flag must be set before jax initializes, so
these checks run in ``distributed_checks.py`` as child processes — keeping
the main pytest process at 1 device per the dry-run contract.  One
subprocess per check name: a failure names its check instead of taking the
whole suite down, and the slow checks parallelize under ``pytest -n``.
"""
import os
import pathlib
import subprocess
import sys

import pytest

_SCRIPT = pathlib.Path(__file__).parent / "distributed_checks.py"
_SRC = pathlib.Path(__file__).parents[1] / "src"

# Mirrors distributed_checks.CHECKS (cannot import it here: the module sets
# the device-count flag at import).  test_check_names_consistent pins the
# two lists together via the subprocess --list protocol.
CHECK_NAMES = [
    "device_count",
    "compressed_psum",
    "collective_matmul",
    "collective_matmul_colsharded",
    "collective_matmul_sweep",
    "cp_decode_attention",
    "sharded_gather_scatter",
    "sharded_gs_hierarchical",
    "sharded_nekbone_cg",
    "fused_cg_sharded",
    "fused_cg_sharded_precision",
    "sstep_sharded_s1",
    "sstep_sharded_s2",
    "sstep_sharded_s4",
    "sstep_collective_counts",
    "pcg_jacobi_sharded",
    "pcg_cheb_sharded",
    "pcg_sharded_precision",
    "pcg_sharded_tol_prefix",
    "seq_sharded_attention",
    "seq_sharded_decode",
    "moe_shardmap_equals_local",
    "pipeline_parallel",
    "elastic_checkpoint_reshard",
]


def _run_checks(args, timeout=580):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(_SRC)
    env["JAX_PLATFORMS"] = "cpu"
    env.pop("XLA_FLAGS", None)
    return subprocess.run(
        [sys.executable, str(_SCRIPT), *args],
        capture_output=True, text=True, timeout=timeout, env=env,
    )


def test_check_names_consistent():
    proc = _run_checks(["--list"])
    assert proc.returncode == 0, proc.stderr
    assert proc.stdout.split() == CHECK_NAMES


@pytest.mark.parametrize("name", CHECK_NAMES)
def test_distributed_check(name):
    proc = _run_checks([name])
    sys.stdout.write(proc.stdout)
    sys.stderr.write(proc.stderr[-2000:])
    assert proc.returncode == 0, f"check {name} failed:\n{proc.stdout}"
    assert "ALL-DISTRIBUTED-OK" in proc.stdout
