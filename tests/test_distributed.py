"""Multi-device behaviour (shard_map, collectives) via a subprocess.

The 8-device host-platform flag must be set before jax initializes, so
these checks run in ``distributed_checks.py`` as a child process — keeping
the main pytest process at 1 device per the dry-run contract.
"""
import os
import pathlib
import subprocess
import sys

_SCRIPT = pathlib.Path(__file__).parent / "distributed_checks.py"
_SRC = pathlib.Path(__file__).parents[1] / "src"


def test_distributed_suite():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(_SRC)
    env["JAX_PLATFORMS"] = "cpu"
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, str(_SCRIPT)],
        capture_output=True, text=True, timeout=580, env=env,
    )
    sys.stdout.write(proc.stdout)
    sys.stderr.write(proc.stderr[-2000:])
    assert proc.returncode == 0, f"distributed checks failed:\n{proc.stdout}"
    assert "ALL-DISTRIBUTED-OK" in proc.stdout
