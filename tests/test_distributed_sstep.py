"""Single-device (tier-1) coverage of the sharded solver drivers.

The multi-device behaviour lives in tests/distributed_checks.py (8 fake
host devices, subprocess).  Everything here runs on the 1-device mesh the
main pytest process has: at ndev=1 the collectives are identities, so the
sharded drivers must reproduce the single-device trajectories — s=4 even
bitwise, since a 1-shard cycle takes the same single-powers-call path and
the psum/host-sum reassociation degenerates.  The collective-count
contract (one stacked halo exchange + one Gram psum per cycle,
collective-free update) is traced, not executed, so it is asserted here
at full strength.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cg_sstep import cg_sstep_fixed_iters
from repro.core.nekbone import NekboneCase
from repro.core.precond import pcg_fused_v2_fixed_iters
from repro.distributed.pcg import pcg_sharded_fixed_iters, pcg_sharded_tol
from repro.distributed.sstep import (cg_sstep_sharded_fixed_iters,
                                     cycle_collective_counts)

GRID = (2, 2, 8)


def _case():
    case = NekboneCase(n=4, grid=GRID, dtype=jnp.float64)
    _, f = case.manufactured()
    return case, f


@pytest.mark.parametrize("s,sz", [(1, 2), (2, 2), (4, 2)])
def test_sstep_sharded_matches_single_device(x64, s, sz):
    case, f = _case()
    kw = dict(D=case.D, g=case.g, grid=GRID, niter=10, s=s, mask=case.mask,
              c=case.c, sz=sz, theta=2.25, interpret=True)
    ref = cg_sstep_fixed_iters(f, **kw)
    got = cg_sstep_sharded_fixed_iters(f, ndev=1, **kw)
    h_ref = np.asarray(ref.rnorm_history, np.float64)
    h = np.asarray(got.rnorm_history, np.float64)
    assert h.shape == h_ref.shape
    np.testing.assert_allclose(h, h_ref, rtol=0, atol=1e-12 * h_ref[0])
    xs = np.asarray(got.x, np.float64)
    rs = np.asarray(ref.x, np.float64)
    scale = float(np.abs(rs).max()) + 1e-30
    assert float(np.abs(xs - rs).max()) < 1e-12 * scale


@pytest.mark.parametrize("s,sz,grid", [(1, 1, (2, 2, 8)), (2, 2, (2, 2, 8)),
                                       (4, 2, (2, 2, 8)),
                                       (1, 1, (1, 1, 8))])
def test_cycle_collective_counts_contract(s, sz, grid):
    counts = cycle_collective_counts(grid=grid, n=4, s=s, sz=sz, ndev=1)
    assert counts["cycle"] == {"ppermute": 2, "psum": 1}
    assert counts["update"] == {}


@pytest.mark.parametrize("precond", ["jacobi", "cheb2"])
def test_pcg_sharded_matches_single_device(x64, precond):
    case, f = _case()
    kw = dict(D=case.D, g=case.g, grid=GRID, niter=10, precond=precond,
              mask=case.mask, c=case.c, sz=2, cheb_sz=2, interpret=True)
    ref = pcg_fused_v2_fixed_iters(f, **kw)
    got = pcg_sharded_fixed_iters(f, ndev=1, **kw)
    h_ref = np.asarray(ref.rnorm_history, np.float64)
    h = np.asarray(got.rnorm_history, np.float64)
    np.testing.assert_allclose(h, h_ref, rtol=0, atol=1e-13 * h_ref[0])
    xs = np.asarray(got.x, np.float64)
    rs = np.asarray(ref.x, np.float64)
    scale = float(np.abs(rs).max()) + 1e-30
    assert float(np.abs(xs - rs).max()) < 1e-13 * scale


def test_pcg_sharded_tol_is_prefix(x64):
    case, f = _case()
    kw = dict(D=case.D, g=case.g, grid=GRID, precond="jacobi",
              mask=case.mask, c=case.c, sz=2, interpret=True)
    full = pcg_sharded_fixed_iters(f, niter=16, ndev=1, **kw)
    h_full = np.asarray(full.rnorm_history, np.float64)
    tol = float(h_full[8]) * 1.01
    got = pcg_sharded_tol(f, tol=tol, max_iter=16, ndev=1, **kw)
    kk = int(got.iters)
    assert 0 < kk < 16
    h = np.asarray(got.rnorm_history, np.float64)
    assert np.array_equal(h[:kk + 1], h_full[:kk + 1])
    assert np.isnan(h[kk + 1:]).all()


def test_sstep_sharded_validation_errors():
    case, f = _case()
    kw = dict(D=case.D, g=case.g, grid=GRID, niter=2, mask=case.mask,
              c=case.c, interpret=True)
    with pytest.raises(ValueError, match="halo depth"):
        cg_sstep_sharded_fixed_iters(f, s=16, sz=1, ndev=1, **kw)
    with pytest.raises(ValueError, match="not divisible by sz"):
        cg_sstep_sharded_fixed_iters(f, s=2, sz=3, ndev=1, **kw)
    with pytest.raises(ValueError, match="needs s >= 1"):
        cg_sstep_sharded_fixed_iters(f, s=0, ndev=1, **kw)


def test_pcg_sharded_requires_preconditioner():
    case, f = _case()
    with pytest.raises(ValueError, match="needs a preconditioner"):
        pcg_sharded_fixed_iters(f, D=case.D, g=case.g, grid=GRID, niter=2,
                                precond=None, mask=case.mask, c=case.c,
                                ndev=1, interpret=True)
