"""End-to-end loops: Nekbone solve, LM training convergence, serving."""
import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import ARCHS
from repro.core.nekbone import NekboneCase


def test_nekbone_end_to_end_paper_protocol():
    """Miniature of the paper's run: degree 9, CG, manufactured solution."""
    case = NekboneCase(n=10, grid=(2, 2, 2), dtype=jnp.float32,
                       ax_impl="pallas")
    res, u_ex = case.solve_manufactured(tol=1e-5, max_iter=200)
    assert float(case.solution_error(res.x, u_ex)) < 1e-3
    # the fused pallas path and fused XLA path agree end to end
    case_f = NekboneCase(n=10, grid=(2, 2, 2), dtype=jnp.float32,
                         ax_impl="fused")
    res_f, _ = case_f.solve_manufactured(tol=1e-5, max_iter=200)
    np.testing.assert_allclose(np.asarray(res.x), np.asarray(res_f.x),
                               rtol=1e-3, atol=1e-4)


def test_lm_training_reduces_loss():
    """~30 steps on the structured synthetic stream must cut the loss."""
    from repro.launch.train import train

    cfg = ARCHS["qwen2.5-14b"].reduced()
    _, losses = train(cfg, steps=25, batch=8, seq=32, peak_lr=3e-3)
    first = np.mean(losses[:3])
    last = np.mean(losses[-3:])
    assert last < first - 0.5, f"no learning: {first:.3f} -> {last:.3f}"


def test_grad_accumulation_equivalence():
    """grad_accum=2 must match the full-batch gradient step."""
    from repro.launch import steps as St

    cfg = ARCHS["qwen2.5-14b"].reduced()
    key = jax.random.PRNGKey(0)
    s0 = St.make_train_state(key, cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 33), 0, cfg.vocab)
    s1, m1 = St.make_train_step(cfg, grad_accum=1)(s0, {"tokens": tokens})
    s0b = St.make_train_state(key, cfg)
    s2, m2 = St.make_train_step(cfg, grad_accum=2)(s0b, {"tokens": tokens})
    for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-4, atol=2e-5)


def test_serve_loop_runs_and_is_deterministic():
    from repro.launch.serve import serve

    cfg = ARCHS["rwkv6-1.6b"].reduced()
    t1, stats = serve(cfg, batch=2, prompt_len=16, gen=8)
    t2, _ = serve(cfg, batch=2, prompt_len=16, gen=8)
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))
    assert t1.shape == (2, 8)


def test_serve_vlm_with_stub_frontend():
    from repro.launch.serve import serve

    cfg = ARCHS["llava-next-mistral-7b"].reduced()
    toks, _ = serve(cfg, batch=2, prompt_len=12, gen=4)
    assert toks.shape == (2, 4)
    assert bool((toks >= 0).all()) and bool((toks < cfg.vocab).all())
